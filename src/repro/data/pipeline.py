"""Deterministic, shardable data pipeline.

Two sources:
  * SyntheticLM - counter-based PRNG token streams (threefry over (step, shard));
    deterministic under restart and under *re-sharding* (elastic scaling): the
    global batch for a step is a pure function of (seed, step), independent of
    the number of hosts that materialize slices of it.
  * MemmapCorpus - packed uint16/uint32 token files read by memmap with
    deterministic window sampling (the same (seed, step) -> same windows).

Both produce per-step global batches; `host_slice` cuts the per-host shard for
multi-host deployment (jax.process_index-based), and `device_put_sharded`
placement is left to the caller (launch/train.py uses jit donation instead).

A double-buffered prefetch thread hides host-side generation latency.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    vocab_size: int = 50257
    seq_len: int = 1024
    global_batch: int = 8
    corpus_path: Optional[str] = None  # None -> synthetic


class SyntheticLM:
    """Counter-based synthetic LM stream: batch(step) is pure in (seed, step).

    Generates Zipf-ish token draws with a per-sequence Markov flavour so the
    loss actually decreases during example training runs.
    """

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.Generator(
            np.random.Philox(key=cfg.seed, counter=[0, 0, 0, step])
        )
        b, s, v = cfg.global_batch, cfg.seq_len, cfg.vocab_size
        # Zipf-ish marginal via exponential rank transform
        u = rng.random((b, s))
        ranks = np.floor((v ** u - 1) / (v - 1) * v).astype(np.int64) % v
        # Markov flavour: every other token repeats its predecessor's bucket
        rep = rng.random((b, s)) < 0.3
        shifted = np.roll(ranks, 1, axis=1)
        toks = np.where(rep, (shifted + 1) % v, ranks)
        return {"tokens": toks.astype(np.int32)}


class MemmapCorpus:
    """Packed token file (uint16 when vocab < 65536 else uint32)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        dtype = np.uint16 if cfg.vocab_size < 65536 else np.uint32
        self.data = np.memmap(cfg.corpus_path, dtype=dtype, mode="r")
        self.n_windows = max(len(self.data) - cfg.seq_len - 1, 1)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.Generator(
            np.random.Philox(key=cfg.seed + 1, counter=[0, 0, 0, step])
        )
        starts = rng.integers(0, self.n_windows, size=(cfg.global_batch,))
        toks = np.stack(
            [self.data[s : s + cfg.seq_len] for s in starts]
        ).astype(np.int32)
        return {"tokens": toks}


def make_source(cfg: DataConfig):
    return MemmapCorpus(cfg) if cfg.corpus_path else SyntheticLM(cfg)


def host_slice(batch: Dict[str, np.ndarray], process_index: int, process_count: int):
    """Deterministic per-host slice of a global batch (batch dim 0)."""
    out = {}
    for k, v in batch.items():
        n = v.shape[0]
        per = n // process_count
        out[k] = v[process_index * per : (process_index + 1) * per]
    return out


class Prefetcher:
    """Double-buffered background prefetch; restart-safe via explicit step."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self.step = start_step
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._run, daemon=True)
        self.thread.start()

    def _run(self):
        step = self.step
        while not self._stop.is_set():
            batch = self.source.batch(step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()
