"""Deterministic sharded data pipeline."""
from repro.data.pipeline import DataConfig, MemmapCorpus, Prefetcher, SyntheticLM, host_slice, make_source  # noqa: F401
