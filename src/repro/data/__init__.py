"""Deterministic sharded data pipeline."""
from repro.data.pipeline import (  # noqa: F401
    DataConfig,
    MemmapCorpus,
    Prefetcher,
    SyntheticLM,
    host_slice,
    make_source,
)
