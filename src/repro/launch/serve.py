"""Batched serving driver: continuous-batching-lite with prefill + decode,
optionally executing every matmul through the IMC simulation (the paper's
technique in deployment position).

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b --smoke \
      --batch 4 --prompt-len 32 --gen 16 --imc-mode imc_analytic

Serving loop: a request queue feeds fixed-batch slots; finished sequences are
replaced by the next request (continuous batching); prefill runs per-request
(cache scatter at its slot), decode runs batched.  Greedy sampling.

Limitation (documented): the decode cache carries a single scalar position, so
slots must stay position-synchronized - equal prompt lengths admitted in
waves.  Per-slot position vectors (full continuous batching) are a planned
extension; the wave pattern already exercises prefill/decode cache scatter.
"""
from __future__ import annotations

import argparse
import dataclasses
import logging
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import decode_step, init_cache, init_params, prefill

log = logging.getLogger("repro.serve")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (P,)
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Server:
    """Fixed-slot continuous batching server (functional JAX inner steps)."""

    def __init__(self, cfg, params, batch_slots: int, cache_len: int,
                 rng: Optional[jax.Array] = None):
        self.cfg = cfg
        self.params = params
        self.slots: List[Optional[Request]] = [None] * batch_slots
        self.cache = init_cache(cfg, batch_slots, cache_len)
        self.cache_len = cache_len
        self.slot_pos = np.zeros(batch_slots, np.int32)
        self.last_token = np.zeros(batch_slots, np.int32)
        self.rng = rng
        self._decode = jax.jit(
            lambda p, t, c, key: decode_step(p, cfg, t, c, rng=key)
        )

    # -- admission -----------------------------------------------------------
    def admit(self, req: Request) -> bool:
        for i, s in enumerate(self.slots):
            if s is None:
                self.slots[i] = req
                self._prefill_slot(i, req)
                return True
        return False

    def _prefill_slot(self, i: int, req: Request):
        toks = jnp.asarray(req.prompt)[None, :]
        logits, cache1 = prefill(self.params, self.cfg, toks,
                                 cache_len=self.cache_len, rng=self.rng)
        # scatter the single-request cache into slot i of the batched cache
        def put(batched, single):
            if batched.ndim == 0 or batched.shape == single.shape == ():
                return batched
            # slot axis is the batch axis: blocks (n, B, ...) / tail (B, ...)
            for axis in range(batched.ndim):
                if (batched.shape[axis] == len(self.slots)
                        and single.shape[axis] == 1):
                    idx = [slice(None)] * batched.ndim
                    idx[axis] = i
                    sidx = [slice(None)] * single.ndim
                    sidx[axis] = 0
                    return batched.at[tuple(idx)].set(single[tuple(sidx)])
            return batched

        self.cache = jax.tree_util.tree_map(
            lambda b, s: put(b, s) if hasattr(b, "at") else b,
            {k: v for k, v in self.cache.items() if k != "pos"},
            {k: v for k, v in cache1.items() if k != "pos"},
        )
        self.cache["pos"] = jnp.asarray(int(cache1["pos"]), jnp.int32)
        self.slot_pos[i] = len(req.prompt)
        self.last_token[i] = int(jnp.argmax(logits[0, -1]))
        req.out.append(int(self.last_token[i]))

    # -- one decode tick -------------------------------------------------------
    def tick(self):
        toks = jnp.asarray(self.last_token)
        key = None
        if self.rng is not None:
            self.rng, key = jax.random.split(self.rng)
        logits, self.cache = self._decode(self.params, toks, self.cache, key)
        # np.array (copy): np.asarray of a jax array is a read-only view
        nxt = np.array(jnp.argmax(logits[:, 0], axis=-1), np.int32)
        for i, req in enumerate(self.slots):
            if req is None or req.done:
                continue
            req.out.append(int(nxt[i]))
            self.slot_pos[i] += 1
            if len(req.out) >= req.max_new:
                req.done = True
                self.slots[i] = None
        self.last_token = nxt

    @property
    def active(self) -> int:
        return sum(1 for s in self.slots if s is not None)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(configs.ARCH_NAMES))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--imc-mode", default=None,
                    choices=[None, "fakequant", "imc_analytic",
                             "imc_bitserial"])
    ap.add_argument("--imc-vwl", type=float, default=0.7)
    args = ap.parse_args(argv)
    logging.basicConfig(level=logging.INFO)

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    rng = None
    if args.imc_mode:
        from repro.core.imc_linear import IMCConfig

        cfg = cfg.replace(imc=IMCConfig(mode=args.imc_mode, bx=7, bw=7,
                                        v_wl=args.imc_vwl))
        rng = jax.random.PRNGKey(7)

    params = init_params(jax.random.PRNGKey(0), cfg)
    cache_len = args.prompt_len + args.gen + 8
    server = Server(cfg, params, args.batch, cache_len, rng=rng)

    rnp = np.random.default_rng(0)
    pending = [
        Request(rid=i,
                prompt=rnp.integers(0, cfg.vocab_size, args.prompt_len),
                max_new=args.gen)
        for i in range(args.requests)
    ]
    finished = []
    t0 = time.perf_counter()
    ticks = 0
    while pending or server.active:
        while pending and server.admit(pending[0]):
            req = pending.pop(0)
            log.info("admitted request %d (active=%d)", req.rid, server.active)
        before = [s for s in server.slots if s is not None]
        server.tick()
        ticks += 1
        for r in before:
            if r.done:
                finished.append(r)
                log.info("finished request %d: %d tokens", r.rid, len(r.out))
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.out) for r in finished)
    log.info("served %d requests, %d tokens, %d ticks, %.1f tok/s",
             len(finished), total_tokens, ticks, total_tokens / dt)
    return finished


if __name__ == "__main__":
    main()
