"""Device-resident continuous-batching serve engine: prefill + fused decode,
optionally executing every matmul through the IMC simulation (the paper's
technique in deployment position).

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b --smoke \
      --batch 4 --prompt-len 32 --gen 16 --imc-mode imc_analytic

Engine design (the decode hot loop never leaves the device):

  per-slot positions   the decode cache carries a (slots,) position vector,
                       so every slot sits at its own sequence depth - requests
                       with unequal prompt lengths are admitted into one batch
                       the moment a slot frees (true continuous batching, no
                       position-synchronized waves).
  fused decode scan    decode runs T steps at a time inside ONE jitted call
                       (``jax.lax.scan`` over the step), with slot state
                       (last token, position, active mask) and greedy argmax
                       resident on device.  Exactly one (slots, T) int32 block
                       crosses to the host per chunk - the per-token logits
                       readback + blocking sync of a Python-tick loop is gone.
                       T is the largest power of two that no active request
                       overruns, so chunking never generates waste tokens and
                       the jit cache stays O(log max_chunk).
  bucketed prefill     prompts are right-padded to power-of-two length buckets
                       (one compile per bucket, not per length); causality
                       isolates the pad positions, logits are gathered at each
                       row's true last position, and sliding-window ring
                       caches are packed per-row from the true tail.  The slot
                       cache-insert is a single jitted dynamic_update_slice
                       scatter over the cache tree.  Recurrent (ssm/rglru) and
                       MoE patterns use exact-length prefill instead: a
                       recurrent state would integrate the pad garbage, and
                       pad tokens would contend for expert capacity.

Greedy sampling.  Finished sequences free their slot for the next request.
"""
from __future__ import annotations

import argparse
import dataclasses
import logging
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import decode_step, init_cache, init_params, prefill

log = logging.getLogger("repro.serve")

MIN_BUCKET = 8


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (P,)
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    t_submit: Optional[float] = None
    t_first: Optional[float] = None  # first generated token on the host

    @property
    def ttft(self) -> Optional[float]:
        if self.t_submit is None or self.t_first is None:
            return None
        return self.t_first - self.t_submit


def needs_exact_prefill(cfg) -> bool:
    """Patterns that cannot take padded (bucketed) prefill: recurrent state
    integrates pad garbage; MoE pad tokens contend for expert capacity."""
    kinds = tuple(cfg.pattern) + tuple(cfg.tail_kinds)
    return any(k in ("ssm", "rglru") for k in kinds) or cfg.n_experts > 0


def prefill_bucket(length: int, bucketable: bool, cache_len: int) -> int:
    """Power-of-two prefill bucket for a prompt length (>= length, one jit
    compile per bucket); exact length when the pattern requires it."""
    if not bucketable:
        return length
    p = MIN_BUCKET
    while p < length:
        p *= 2
    return min(p, cache_len) if cache_len >= length else p


class Engine:
    """Fixed-slot continuous-batching engine with a fused decode scan.

    Host-side state is bookkeeping only (which request owns which slot);
    everything the decode loop touches - cache, per-slot positions, last
    tokens - lives on device between jitted calls.
    """

    def __init__(self, cfg, params, batch_slots: int, cache_len: int,
                 rng: Optional[jax.Array] = None, max_chunk: int = 8):
        self.cfg = cfg
        self.params = params
        self.batch_slots = batch_slots
        self.cache_len = cache_len
        self.max_chunk = max_chunk
        self.rng = rng
        self.bucketable = not needs_exact_prefill(cfg)

        self.slots: List[Optional[Request]] = [None] * batch_slots
        cache = init_cache(cfg, batch_slots, cache_len)
        cache.pop("pos")
        self.cache = cache  # blocks/tail only: positions are engine state
        self.pos = jnp.zeros((batch_slots,), jnp.int32)
        self.last_token = jnp.zeros((batch_slots,), jnp.int32)
        self.finished: List[Request] = []

        # perf counters (consumed by benchmarks/serve_bench.py)
        self.decode_calls = 0
        self.decode_steps = 0
        self.host_transfer_bytes = 0

        self._prefill_fns: Dict[int, object] = {}
        self._decode_fns: Dict[int, object] = {}
        self._insert_fn = jax.jit(self._insert_impl)

    # -- rng ------------------------------------------------------------------
    def _next_key(self):
        if self.rng is None:
            return None
        self.rng, key = jax.random.split(self.rng)
        return key

    # -- admission ------------------------------------------------------------
    @property
    def active(self) -> int:
        return sum(1 for s in self.slots if s is not None)

    def admit(self, req: Request) -> bool:
        free = next((i for i, s in enumerate(self.slots) if s is None), None)
        if free is None:
            return False
        if req.t_submit is None:
            req.t_submit = time.perf_counter()
        length = len(req.prompt)
        # decode writes K/V at positions length .. length + max_new - 2
        if length + req.max_new - 1 > self.cache_len:
            raise ValueError(
                f"prompt ({length}) + max_new ({req.max_new}) exceeds "
                f"cache_len ({self.cache_len})")
        bucket = prefill_bucket(length, self.bucketable, self.cache_len)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :length] = req.prompt
        pf = self._prefill_fns.get(bucket)
        if pf is None:
            pf = self._prefill_fns[bucket] = self._make_prefill()
        tok0, cache1 = pf(self.params, jnp.asarray(toks),
                          jnp.asarray([length], jnp.int32), self._next_key())
        self.cache, self.last_token, self.pos = self._insert_fn(
            self.cache, {k: v for k, v in cache1.items() if k != "pos"},
            jnp.asarray(free, jnp.int32), tok0[0],
            jnp.asarray(length, jnp.int32), self.last_token, self.pos,
        )
        self.slots[free] = req
        req.out.append(int(tok0[0]))  # 4-byte sync, once per request (TTFT)
        req.t_first = time.perf_counter()
        if len(req.out) >= req.max_new:
            self._retire(free)
        return True

    def _make_prefill(self):
        cfg, cache_len, bucketable = self.cfg, self.cache_len, self.bucketable

        def pf(params, toks, true_len, key):
            logits, cache1 = prefill(
                params, cfg, toks, cache_len=cache_len, rng=key,
                true_len=true_len if bucketable else None,
            )
            tok0 = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            return tok0, cache1

        return jax.jit(pf)

    def _insert_impl(self, cache, cache1, slot, tok0, length, last_token, pos):
        n_slots = self.batch_slots

        def put(batched, single):
            if getattr(batched, "ndim", 0) == 0:
                return batched
            # slot axis is the batch axis: blocks (n_cycles, B, ...) / (B, ...)
            for axis in range(batched.ndim):
                if (batched.shape[axis] == n_slots
                        and single.shape[axis] == 1):
                    starts = [0] * batched.ndim
                    starts[axis] = slot
                    return jax.lax.dynamic_update_slice(
                        batched, single.astype(batched.dtype), tuple(starts)
                    )
            return batched

        new_cache = jax.tree_util.tree_map(put, cache, cache1)
        return (new_cache, last_token.at[slot].set(tok0),
                pos.at[slot].set(length))

    def _retire(self, i: int):
        req = self.slots[i]
        req.done = True
        self.slots[i] = None
        self.finished.append(req)

    # -- fused decode ----------------------------------------------------------
    def next_chunk(self) -> int:
        """Largest power-of-two scan length no active request overruns."""
        rem = [r.max_new - len(r.out) for r in self.slots if r is not None]
        if not rem:
            return 0
        cap = min(min(rem), self.max_chunk)
        t = 1
        while t * 2 <= cap:
            t *= 2
        return t

    def _make_decode(self, n_steps: int):
        cfg = self.cfg

        def chunk(params, cache, last_tok, pos, active, key):
            def step(carry, t):
                cache, tok, pos = carry
                k = None if key is None else jax.random.fold_in(key, t)
                logits, new_cache = decode_step(
                    params, cfg, tok, dict(cache, pos=pos), rng=k
                )
                nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
                nxt = jnp.where(active, nxt, tok)
                new_pos = jnp.where(active, pos + 1, pos)
                new_cache.pop("pos")
                return (new_cache, nxt, new_pos), nxt

            (cache, tok, pos), toks = jax.lax.scan(
                step, (cache, last_tok, pos), jnp.arange(n_steps)
            )
            return cache, tok, pos, toks.T  # (slots, T)

        return jax.jit(chunk)

    def decode_chunk(self, n_steps: Optional[int] = None) -> np.ndarray:
        """Run ``n_steps`` fused decode steps; returns the (slots, T) token
        block (the single device->host transfer of the chunk)."""
        if n_steps is None:
            n_steps = self.next_chunk()
        if n_steps <= 0:
            return np.zeros((self.batch_slots, 0), np.int32)
        fn = self._decode_fns.get(n_steps)
        if fn is None:
            fn = self._decode_fns[n_steps] = self._make_decode(n_steps)
        active = jnp.asarray(
            np.array([s is not None for s in self.slots]))
        self.cache, self.last_token, self.pos, toks = fn(
            self.params, self.cache, self.last_token, self.pos, active,
            self._next_key(),
        )
        block = np.asarray(toks)  # the one host transfer per chunk
        self.decode_calls += 1
        self.decode_steps += n_steps
        self.host_transfer_bytes += block.nbytes
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            take = min(n_steps, req.max_new - len(req.out))
            req.out.extend(int(t) for t in block[i, :take])
            if len(req.out) >= req.max_new:
                self._retire(i)
        return block


def serve(engine: Engine, requests: List[Request]) -> List[Request]:
    """Drive the engine until every request finishes; returns them in
    completion order."""
    pending = list(requests)
    done_mark = len(engine.finished)
    while pending or engine.active:
        while pending and engine.admit(pending[0]):
            req = pending.pop(0)
            log.info("admitted request %d len=%d (active=%d)",
                     req.rid, len(req.prompt), engine.active)
        engine.decode_chunk()
        for r in engine.finished[done_mark:]:
            log.info("finished request %d: %d tokens", r.rid, len(r.out))
        done_mark = len(engine.finished)
    return engine.finished


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(configs.ARCH_NAMES))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--prompt-lens", default=None,
                    help="comma list of prompt lengths cycled over the "
                         "requests (unequal-length admission); overrides "
                         "--prompt-len")
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--chunk", type=int, default=8,
                    help="max fused decode steps per jitted scan call")
    ap.add_argument("--imc-mode", default=None,
                    choices=[None, "fakequant", "imc_analytic",
                             "imc_bitserial"])
    ap.add_argument("--imc-vwl", type=float, default=0.7)
    args = ap.parse_args(argv)
    logging.basicConfig(level=logging.INFO)

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    rng = None
    if args.imc_mode:
        from repro.core.imc_linear import IMCConfig

        cfg = cfg.replace(imc=IMCConfig(mode=args.imc_mode, bx=7, bw=7,
                                        v_wl=args.imc_vwl))
        rng = jax.random.PRNGKey(7)

    if args.prompt_lens:
        lens = [int(x) for x in args.prompt_lens.split(",")]
    else:
        lens = [args.prompt_len]
    params = init_params(jax.random.PRNGKey(0), cfg)
    bucketable = not needs_exact_prefill(cfg)
    max_bucket = max(prefill_bucket(l, bucketable, 10**9) for l in lens)
    cache_len = max_bucket + args.gen + 8
    engine = Engine(cfg, params, args.batch, cache_len, rng=rng,
                    max_chunk=args.chunk)

    rnp = np.random.default_rng(0)
    requests = [
        Request(rid=i,
                prompt=rnp.integers(0, cfg.vocab_size, lens[i % len(lens)]),
                max_new=args.gen)
        for i in range(args.requests)
    ]
    t0 = time.perf_counter()
    finished = serve(engine, requests)
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.out) for r in finished)
    tok_s = total_tokens / dt if dt > 0 else float("nan")
    ttfts = [r.ttft for r in finished if r.ttft is not None]
    ttft_ms = 1e3 * float(np.mean(ttfts)) if ttfts else float("nan")
    log.info(
        "served %d requests, %d tokens, %d fused chunks (%d steps), "
        "%.1f tok/s, mean TTFT %.1f ms, %d host-transfer bytes",
        len(finished), total_tokens, engine.decode_calls,
        engine.decode_steps, tok_s, ttft_ms, engine.host_transfer_bytes,
    )
    return finished


if __name__ == "__main__":
    main()
