"""Device-resident continuous-batching serve engine with a PAGED KV cache:
batched bucketed prefill + fused decode, optionally executing every matmul
through the IMC simulation (the paper's technique in deployment position).
The execution substrate is a first-class ``repro.core.substrate.Substrate``
(``cfg.imc``); with a ``frozen`` calibration policy the IMC quantizer ranges
are compile-time constants, so batched engine output is bit-identical to
sequential single-request execution on every substrate (``--imc-policy
frozen``).

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b --smoke \
      --batch 4 --prompt-len 32 --gen 16 --imc-mode imc_analytic

Engine design (the decode hot loop never leaves the device):

  paged KV cache       global-attention K/V lives in a shared block pool
                       (num_blocks, block, Hkv, hd) indexed through a
                       device-resident per-slot block table (slots,
                       max_blocks).  A host-side free-list allocator
                       (``BlockAllocator``) hands each request exactly
                       ceil((prompt + max_new - 1) / block) blocks, so mixed
                       short/long traffic holds KV memory proportional to the
                       tokens it actually keeps, not slots x longest-request.
                       Physical block 0 is a reserved garbage block: block
                       tables point to it for unallocated logical blocks and
                       inactive rows' decode writes are routed to it (a
                       retired slot's stale table may reference blocks the
                       allocator already handed to another request).
                       Sliding-window rings (bounded at the window span) and
                       recurrent states (fixed size) stay contiguous.
  batched prefill      the FIFO prefix of pending requests sharing one
                       bucket is admitted as ONE (R, bucket) prefill call
                       (R padded to a power of two: one compile per
                       (R, bucket), dummy rows dropped via out-of-bounds
                       scatter), followed by ONE jitted multi-slot insert
                       that writes each row's prompt K/V into its allocated
                       blocks and its block-table row.  Prefix-only grouping
                       keeps strict arrival order (no short prompt overtakes
                       an earlier long one).  MoE patterns prefill one
                       request at a time (expert capacity is batch-coupled,
                       so batching would change routing vs the solo
                       reference).
  per-slot positions   the decode cache carries a (slots,) position vector,
                       so every slot sits at its own sequence depth.
  fused decode scan    decode runs T steps at a time inside ONE jitted call
                       (``jax.lax.scan`` over the step), with slot state
                       (last token, position, active mask) and greedy argmax
                       resident on device.  Exactly one (slots, T) int32 block
                       crosses to the host per chunk.  T is the largest power
                       of two that no active request overruns.
  bucketed prefill     prompts are right-padded to power-of-two length buckets
                       (one compile per bucket, not per length); causality
                       isolates the pad positions, logits are gathered at each
                       row's true last position.  Recurrent (ssm/rglru) and
                       MoE patterns use exact-length prefill instead: a
                       recurrent state would integrate the pad garbage, and
                       pad tokens would contend for expert capacity.

Greedy sampling.  Finished sequences free their slot (and blocks) for the
next request.

Robustness (drift + faults):

  shadow calibration   with a ``runtime.drift.DriftMonitor`` attached, every
                       Nth decode chunk / prefill group runs through a
                       shadow-traced variant of the same jitted function that
                       streams running-maxima stats to the monitor's recorder
                       (``core.substrate.shadow_recording`` - passive, outputs
                       bit-identical, still one (slots, T) transfer per chunk);
  atomic hot-swap      the Calibration pytree is a TRACED argument of every
                       decode/prefill jit, so the jit cache is keyed on its
                       treedef; ``swap_calibration`` installs a refreshed
                       calibration with the same site names between chunks as
                       a pure host-side pointer update - the compiled scan is
                       reused, and within any one chunk all rows quantize
                       against one consistent calibration;
  failure isolation    a request that cannot be admitted (oversized) or whose
                       prefill keeps failing retires with a per-request
                       ``error`` status instead of killing the engine; a
                       transient ``XlaRuntimeError`` on a decode chunk is
                       retried once (the ``runtime.fault`` retry idiom) and,
                       if it persists, fails only the requests in flight.

Overload resilience (scheduling + preemption contract):

  lazy paged blocks    under ``alloc_policy="lazy"`` (the default) admission
                       allocates only the blocks the prompt insert needs
                       (``ceil(len(prompt)/block)``); generation-tail blocks
                       are allocated ON the block-boundary crossing, right
                       before each decode chunk (``_ensure_blocks``), so the
                       early-stopping mix no longer pays worst-case
                       reservation.  ``alloc_policy="reserve"`` keeps the old
                       worst-case behaviour.  Feasibility (``_fits``) still
                       checks the worst case, so a solo request can always
                       finish once the pool drains.
  recompute-preempt    a mid-generation allocation failure preempts a VICTIM
                       (the latest-admitted active slot newer than the
                       grower; the grower itself if none is newer - the
                       oldest resident always progresses, so the scheme
                       cannot livelock): its blocks are freed and it joins
                       ``engine.preempted`` keeping its generated tokens.
                       Serve loops re-queue it; re-admission prefills
                       ``prompt + out`` (the resume prompt), whose final
                       argmax IS the next token decode would have produced -
                       under frozen calibration the resumed request is
                       bit-exact with its uninterrupted counterpart
                       (test-pinned on all three substrates).  Preemption
                       never kills the engine and never loses a request.
  scheduler policies   ``serve_slo`` drives the engine under a
                       ``launch.scheduler`` policy object (FIFO /
                       shortest-prompt-first / SLO-deadline with load
                       shedding); shed requests retire through
                       ``fail_request`` with ``error_kind="shed"`` (PR 6's
                       graceful per-request contract - never engine death).
                       Time is virtual (``runtime.workload.VirtualClock``,
                       decode-step units), so every SLO metric is a
                       deterministic function of the workload seed.
  frontier degradation a ``scheduler.PressureController`` watches queue
                       depth / pool occupancy and hot-swaps the substrate
                       one step down the EDAP frontier
                       (``Engine.swap_substrate``, jit caches keyed on
                       ``Substrate.trace_key`` - one compile per ladder
                       level, then pure pointer updates), stepping back up
                       when pressure clears.  While the queue is saturated
                       (``drift_pause_depth``), drift shadow sampling is
                       paused so the callback tax never lands at peak load.
"""
from __future__ import annotations

import argparse
import dataclasses
import logging
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.core import substrate as substrate_lib
from repro.launch.mesh import axis_size, dp_axes
from repro.launch.sharding import (
    activation_rules,
    axis_rules,
    fallback_replicate,
    kv_head_partition,
    tree_param_specs,
    tree_shardings,
    validate_divisibility,
)
from repro.models import decode_step, init_paged_cache, init_params, prefill
from repro.runtime import drift as drift_lib
from repro.runtime import fault as fault_lib
from repro.runtime.prefix_cache import PrefixCache

log = logging.getLogger("repro.serve")

MIN_BUCKET = 8
DEFAULT_BLOCK = 8  # tokens per KV block; divides every pow2 bucket >= MIN_BUCKET


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (P,)
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    t_submit: Optional[float] = None
    t_first: Optional[float] = None  # first generated token on the host
    # per-request failure status: a request that cannot be served (oversized,
    # poisoned prefill, persistent device error mid-decode, shed under
    # overload) finishes with done=True, the reason in ``error`` and a typed
    # category in ``error_kind`` - failures never escape to the engine
    error: Optional[str] = None
    error_kind: Optional[str] = None  # "admission"|"prefill"|"decode"|"shed"
    # SLO workload metadata (runtime.workload): virtual arrival time,
    # per-class deadlines (relative to arrival / between tokens), tenant
    # class.  All None/default for plain offline serving.
    arrive_at: Optional[float] = None
    ttft_deadline: Optional[float] = None
    itl_deadline: Optional[float] = None
    rclass: str = "default"
    # true generation length (the EOS the engine cannot know at admission):
    # generation stops at min(max_new, stop_at).  Worst-case reservation
    # must still budget max_new blocks - that gap is the lazy-allocation win.
    stop_at: Optional[int] = None
    # recompute-preemption bookkeeping
    preemptions: int = 0
    # virtual completion time of each generated token (only stamped when the
    # engine runs under a VirtualClock; feeds p50/p99 inter-token latency)
    token_times: List[float] = dataclasses.field(default_factory=list)

    @property
    def ok(self) -> bool:
        return self.done and self.error is None

    @property
    def shed(self) -> bool:
        return self.error_kind == "shed"

    @property
    def effective_max(self) -> int:
        """Tokens this request will actually generate (EOS-capped)."""
        if self.stop_at is None:
            return self.max_new
        return min(self.max_new, self.stop_at)

    @property
    def full_prompt(self) -> np.ndarray:
        """The resume prompt: original prompt plus every generated token.
        Prefilling it reproduces the exact decode state - the final
        position's argmax is the next token the uninterrupted run would
        produce (bit-exact under frozen calibration)."""
        if not self.out:
            return self.prompt
        return np.concatenate(
            [np.asarray(self.prompt), np.asarray(self.out)]).astype(
                np.asarray(self.prompt).dtype)

    @property
    def ttft(self) -> Optional[float]:
        if self.t_submit is None or self.t_first is None:
            return None
        return self.t_first - self.t_submit


def needs_exact_prefill(cfg) -> bool:
    """Patterns that cannot take padded (bucketed) prefill: recurrent state
    integrates pad garbage; MoE pad tokens contend for expert capacity."""
    kinds = tuple(cfg.pattern) + tuple(cfg.tail_kinds)
    return any(k in ("ssm", "rglru") for k in kinds) or cfg.n_experts > 0


def prefill_bucket(length: int, bucketable: bool, cache_len: int) -> int:
    """Power-of-two prefill bucket for a prompt length (>= length, one jit
    compile per bucket); exact length when the pattern requires it."""
    if not bucketable:
        return length
    p = MIN_BUCKET
    while p < length:
        p *= 2
    return min(p, cache_len) if cache_len >= length else p


class BlockAllocator:
    """Refcounting free-list allocator over the physical KV block pool.

    Contract (pinned by the hypothesis property tests):
      - block 0 is reserved (the garbage block) and is never handed out;
      - ``alloc(n)`` returns n distinct blocks none of which is currently
        allocated elsewhere, or None (caller must not admit) - it never
        partially allocates;
      - ``free(blocks)`` returns blocks to the pool; freed blocks are
        immediately reusable;
      - ``free_count + sum(len(owned))`` is conserved at ``num_blocks - 1``.

    Prefix-sharing extension (same conservation law, refined):
      - ``alloc`` acquires each block at refcount 1; ``retain`` takes an
        extra reference (a second request linking a shared prefix block);
        ``free`` is a ref-RELEASE - the block only leaves ``used`` when its
        last reference drops, so preempting/retiring one sharer never pulls
        a block out from under its peers;
      - ``register_cached`` marks a block as owned by the prefix index:
        when its refcount hits zero it parks on an insertion-ordered IDLE
        list (still occupying pool memory, still serving future prefix
        hits) instead of returning to the free list;
      - ``evict`` reclaims one idle cached block (the engine picks WHICH -
        leaf-first LRU over the radix index) back onto the free list;
      - conservation: ``free_count + referenced + idle_cached`` is invariant
        at ``num_blocks - 1``; the free list never contains a block that is
        referenced or cached.
    """

    def __init__(self, num_blocks: int):
        if num_blocks < 1:
            raise ValueError("need at least the reserved garbage block")
        self.num_blocks = num_blocks
        # LIFO free list: recently freed (cache-warm) blocks are reused first
        self._free: List[int] = list(range(num_blocks - 1, 0, -1))
        self._allocated: set = set()
        self._ref: Dict[int, int] = {}
        self._cached: set = set()
        # refcount-zero cached blocks, insertion-ordered = release-time LRU
        self._idle: Dict[int, None] = {}

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def used_count(self) -> int:
        return len(self._allocated)

    @property
    def evictable_count(self) -> int:
        return len(self._idle)

    def refcount(self, b: int) -> int:
        return self._ref.get(b, 0)

    def is_evictable(self, b: int) -> bool:
        return b in self._idle

    def alloc(self, n: int) -> Optional[List[int]]:
        if n > len(self._free):
            return None
        blocks = [self._free.pop() for _ in range(n)]
        self._allocated.update(blocks)
        for b in blocks:
            self._ref[b] = 1
        return blocks

    def retain(self, blocks: List[int]):
        """Take one extra reference on each block (prefix-hit linking).
        A retained idle cached block leaves the eviction candidate set."""
        for b in blocks:
            if b not in self._allocated:
                raise ValueError(f"retain of unallocated block {b}")
            self._ref[b] = self._ref.get(b, 0) + 1
            self._idle.pop(b, None)

    def free(self, blocks: List[int]):
        """Release one reference per block.  At refcount zero a cached
        block goes idle (evictable, still resident); an uncached block
        returns to the free list."""
        for b in blocks:
            if b not in self._allocated or self._ref.get(b, 0) <= 0:
                raise ValueError(f"double free / foreign block {b}")
            self._ref[b] -= 1
            if self._ref[b] > 0:
                continue
            if b in self._cached:
                self._idle[b] = None
            else:
                del self._ref[b]
                self._allocated.remove(b)
                self._free.append(b)

    def register_cached(self, b: int):
        """Hand a block's zero-ref lifetime to the prefix index."""
        if b not in self._allocated:
            raise ValueError(f"cannot cache unallocated block {b}")
        self._cached.add(b)
        if self._ref.get(b, 0) == 0:
            self._idle[b] = None

    def evict(self, b: int):
        """Reclaim one idle cached block onto the free list (the caller
        must have dropped it from the prefix index first)."""
        if b not in self._idle:
            raise ValueError(
                f"block {b} is not evictable (referenced or uncached)")
        del self._idle[b]
        self._cached.remove(b)
        self._ref.pop(b, None)
        self._allocated.remove(b)
        self._free.append(b)


def _cfg_with_calibration(cfg, calib):
    """``cfg`` with its substrate's calibration replaced by ``calib`` (a
    possibly-traced Calibration pytree).  Runs INSIDE jitted traces: this is
    how the frozen quantizer ranges become runtime arguments of the decode
    scan / prefill instead of baked compile-time constants, which is what
    makes the hot-swap recompile-free."""
    if calib is None:
        return cfg
    sub = dataclasses.replace(substrate_lib.as_substrate(cfg.imc),
                              calibration=calib)
    return cfg.replace(imc=sub)


class Engine:
    """Fixed-slot continuous-batching engine: paged KV cache, batched
    bucketed prefill, fused decode scan.

    Host-side state is bookkeeping only (which request owns which slot and
    which physical blocks); everything the decode loop touches - block pools,
    block tables, per-slot positions, last tokens - lives on device between
    jitted calls.
    """

    def __init__(self, cfg, params, batch_slots: int, cache_len: int,
                 rng: Optional[jax.Array] = None, max_chunk: int = 8,
                 block_size: int = DEFAULT_BLOCK,
                 kv_blocks: Optional[int] = None, meter=None,
                 drift_monitor: Optional[drift_lib.DriftMonitor] = None,
                 failure_injector: Optional[Callable[[str, Any], None]] = None,
                 alloc_policy: str = "lazy", clock=None,
                 drift_pause_depth: Optional[int] = None, mesh=None,
                 prefix_cache: bool = False):
        # tensor-parallel serving: a (data=1, model=N) mesh shards the
        # weights (path-based param specs) and the paged KV pools (heads
        # over ``model``); None = the classic single-device engine
        self.mesh = mesh
        self.tp = axis_size(mesh, "model") if mesh is not None else 1
        if self.tp > 1 and cfg.decode_attn == "kernel":
            # per-shard Pallas paged-attention dispatch is out of scope: the
            # sharded engine serves through the gather reference path
            log.info("model-parallel mesh (%d-way): decode_attn='kernel' "
                     "falls back to the gather path under sharding", self.tp)
            cfg = cfg.replace(decode_attn="gather")
        self.cfg = cfg
        self.params = params
        # the first-class execution substrate every matmul routes through
        # (cfg.imc may be a bare IMCConfig - normalized here once)
        self.substrate = substrate_lib.as_substrate(cfg.imc)
        # optional launch.metering.DPMeter: billed-work accounting.  Both
        # hook points are O(1) host-side counter updates driven by values
        # the engine already holds, so the device contracts (fused scan,
        # one (slots, T) transfer per chunk) are untouched.  The meter is
        # stamped with the substrate that actually runs, so the energy
        # rollup bills the design points the substrate objects carry - no
        # side-channel flag plumbing.
        self.meter = meter
        if meter is not None and getattr(meter, "substrate", None) is None:
            meter.substrate = self.substrate
        # hot-swappable frozen calibration: passed as a TRACED argument to
        # every decode/prefill jit (None under dynamic/digital substrates)
        self._calib = (self.substrate.calibration
                       if self.substrate.policy == "frozen" else None)
        self.swap_count = 0
        # online drift monitoring (requires a frozen substrate: shadow stats
        # are compared against the frozen ranges)
        if drift_monitor is not None and self._calib is None:
            raise ValueError(
                "drift monitoring requires a frozen-policy substrate "
                "(there are no frozen ranges to compare shadow stats "
                "against)")
        self._drift = drift_monitor
        # test/chaos hook: called as failure_injector(phase, info) right
        # before the device call of a prefill ("prefill", rid tuple) or a
        # decode chunk ("decode", chunk index); raising simulates a device
        # error at exactly that point
        self.failure_injector = failure_injector
        self.batch_slots = batch_slots
        self.block = block_size
        self.max_blocks = -(-cache_len // block_size)
        # logical per-request capacity, rounded up to whole blocks
        self.cache_len = self.max_blocks * block_size
        self.max_chunk = max_chunk
        self.rng = rng
        self.bucketable = not needs_exact_prefill(cfg)
        # MoE expert capacity couples rows of a batch: batched prefill would
        # route differently than the solo reference, so keep R = 1 there
        self.batch_prefill = cfg.n_experts == 0
        kinds = tuple(cfg.pattern) + tuple(cfg.tail_kinds)
        self.has_paged = "attn" in kinds
        if kv_blocks is None:
            # full provisioning: admission can never stall on blocks
            kv_blocks = batch_slots * self.max_blocks + 1
        self.alloc = BlockAllocator(kv_blocks if self.has_paged else 1)

        if alloc_policy not in ("lazy", "reserve"):
            raise ValueError(f"unknown alloc_policy {alloc_policy!r}")
        self.alloc_policy = alloc_policy
        # prefix-sharing radix cache (host-side index; refcounts live in the
        # allocator).  Only sound when EVERY per-request KV byte lives in the
        # paged pool: a sliding-window ring or recurrent/MoE state cannot be
        # reconstructed by linking blocks, so those configs serve cold.
        self.prefix: Optional[PrefixCache] = None
        if prefix_cache:
            eligible = (self.has_paged and cfg.n_experts == 0
                        and all(k == "attn" for k in kinds))
            if eligible:
                self.prefix = PrefixCache(block_size)
            else:
                log.info("prefix cache disabled: pattern %s carries "
                         "non-paged per-request state", kinds)
        # prefix-sharing counters (miss = a cold admission with the cache on)
        self.prefix_lookups = 0
        self.prefix_hits = 0
        self.prefix_hit_tokens = 0
        self.prefix_saved_tokens = 0
        self.cow_copies = 0
        self.prefix_evictions = 0
        # optional runtime.workload.VirtualClock: when present, admission /
        # decode advance it and stamp t_submit/t_first/token_times in virtual
        # decode-step units (deterministic SLO metrics); None = wall clock
        self.clock = clock
        # drift shadow sampling pauses while queue_depth exceeds this
        # (serve loops publish their queue length here each tick)
        self.drift_pause_depth = drift_pause_depth
        self.queue_depth = 0

        self.slots: List[Optional[Request]] = [None] * batch_slots
        self._slot_blocks: List[List[int]] = [[] for _ in range(batch_slots)]
        # host-side per-slot sequence depth (mirror of the device pos vector;
        # drives lazy block-boundary math without a device read)
        self._slot_pos: List[int] = [0] * batch_slots
        # admission sequence number per slot: the preemption victim order
        self._slot_seq: List[int] = [0] * batch_slots
        self._admit_seq = 0
        # recompute-preempted requests wait here for the serve loop to
        # re-queue them (they keep their generated tokens - the resume
        # prompt is prompt + out)
        self.preempted: List[Request] = []
        cache = init_paged_cache(cfg, batch_slots, self.cache_len,
                                 kv_blocks if self.has_paged else 1,
                                 block_size)
        cache.pop("pos")
        self.cache = cache  # blocks/tail only: positions are engine state
        self.pos = jnp.zeros((batch_slots,), jnp.int32)
        self.last_token = jnp.zeros((batch_slots,), jnp.int32)
        self.finished: List[Request] = []

        # sharded placement (no-op on the single-device engine): params get
        # their TP specs, KV pools get head-sharded iff Hkv divides the
        # model axis (else replicated - the partition helper refuses uneven
        # splits), and everything else replicates
        self._rules = None
        self._cache_shardings = None
        self._rep_sharding = None
        self.kv_shard = False
        if self.tp > 1:
            self._init_sharding()

        # perf counters (consumed by benchmarks/serve_bench.py)
        self.decode_calls = 0
        self.decode_steps = 0
        self.host_transfer_bytes = 0
        self.prefill_calls = 0
        self.prefill_rows = 0
        # robustness counters
        self.failed_requests = 0
        self.decode_failures = 0
        self.shed_requests = 0
        self.preempt_count = 0
        self.substrate_swaps = 0
        # pool-utilization accounting (sampled once per decode chunk):
        # live tokens vs the token capacity of the blocks actually allocated
        self._util_token_sum = 0
        self._util_cap_sum = 0

        # jit caches keyed (..., shadow, substrate.trace_key): the shadow
        # variant of a function is traced under shadow_recording and carries
        # the observation callbacks; the calibration pytree is a traced
        # ARGUMENT of both, so a calibration hot-swap (same site names ->
        # same treedef) re-uses every entry, and a frontier-ladder substrate
        # swap (different trace_key) compiles once per level then re-uses -
        # no recompile storms on either axis
        self._prefill_fns: Dict[Tuple[int, int, bool, Any], Any] = {}
        self._decode_fns: Dict[Tuple[int, bool, Any], Any] = {}
        self._warm_fns: Dict[Tuple[int, Any], Any] = {}
        if self.tp > 1:
            rep = self._rep_sharding
            self._insert_fn = self._with_rules(jax.jit(
                self._insert_impl,
                out_shardings=(self._cache_shardings, rep, rep)))
            self._extend_fn = self._with_rules(jax.jit(
                self._extend_impl, out_shardings=self._cache_shardings))
            self._cow_fn = self._with_rules(jax.jit(
                self._cow_impl, out_shardings=self._cache_shardings))
        else:
            self._insert_fn = jax.jit(self._insert_impl)
            self._extend_fn = jax.jit(self._extend_impl)
            self._cow_fn = jax.jit(self._cow_impl)
        self._block_bytes, self._fixed_kv_bytes = self._kv_accounting()
        if self.prefix is not None and self._fixed_kv_bytes > 0:
            # belt-and-braces: a contiguous ring/recurrent leaf means part of
            # the per-request state is NOT addressable through block tables
            log.info("prefix cache disabled: %d bytes of contiguous "
                     "per-request KV state", self._fixed_kv_bytes)
            self.prefix = None
        # per-device KV footprint: head-sharded pool/ring leaves split their
        # bytes over the model axis; the block tables and everything else
        # replicate (the allocator is whole per shard group)
        div = self.tp if self.kv_shard else 1
        self._block_bytes_per_device = self._block_bytes // div
        self._fixed_kv_bytes_per_device = self._fixed_kv_bytes // div
        if meter is not None and self.mesh is not None:
            meter.note_mesh(self.mesh_shape, self.mesh.devices.size,
                            self.kv_pool_bytes_per_device())

    # -- tensor-parallel placement --------------------------------------------
    def _init_sharding(self):
        mesh = self.mesh
        hkv = self.cfg.n_kv_heads
        self.kv_shard = self.has_paged and hkv % self.tp == 0
        if self.kv_shard:
            # contract: contiguous per-shard-group head ranges (no loss, no
            # overlap); raises - instead of padding - on uneven splits
            kv_head_partition(hkv, self.tp)
        elif self.has_paged:
            log.info("KV pools replicated: %d KV heads do not divide the "
                     "%d-way model axis", hkv, self.tp)
        rules = activation_rules(mesh)
        dp = dp_axes(mesh)
        rules["paged_kv_bshd"] = (
            P(dp, None, "model", None) if self.kv_shard else P())
        self._rules = rules
        self._rep_sharding = NamedSharding(mesh, P())

        specs = tree_param_specs(self.params)
        issues = validate_divisibility(self.params, specs, mesh)
        if issues:
            log.info("serve TP: replicating %d param tensor(s) whose "
                     "sharded dims do not divide the mesh", len(issues))
            specs = fallback_replicate(specs, {p for p, _, _ in issues})
        self.params = jax.device_put(self.params, tree_shardings(mesh, specs))

        def cache_spec(path, leaf):
            name = str(getattr(path[-1], "key", ""))
            if name in ("pk", "pv", "k", "v") and self.kv_shard:
                # (..., block/seq, Hkv, hd): heads ride the model axis
                entries = [None] * leaf.ndim
                entries[-2] = "model"
                return P(*entries)
            return P()  # block tables, recurrent states, positions

        cache_specs = jax.tree_util.tree_map_with_path(cache_spec, self.cache)
        self._cache_shardings = tree_shardings(mesh, cache_specs)
        self.cache = jax.device_put(self.cache, self._cache_shardings)
        self.pos = jax.device_put(self.pos, self._rep_sharding)
        self.last_token = jax.device_put(self.last_token, self._rep_sharding)

    def _with_rules(self, fn):
        """Bind the engine's logical-axis rules around a jitted callable so
        every ``ws``/``ws_attn`` annotation resolves at trace time (identity
        on the single-device engine)."""
        if self._rules is None:
            return fn
        mesh, rules = self.mesh, self._rules

        def call(*args, **kwargs):
            with axis_rules(mesh, rules):
                return fn(*args, **kwargs)

        return call

    @property
    def mesh_shape(self) -> Optional[str]:
        """The mesh as an ``RxC`` string ("1x4"), None when single-device."""
        if self.mesh is None:
            return None
        return f"{axis_size(self.mesh, 'data')}x{axis_size(self.mesh, 'model')}"

    # -- kv memory accounting --------------------------------------------------
    def _kv_accounting(self) -> Tuple[int, int]:
        """(bytes per physical block summed over paged layers, bytes of the
        always-allocated contiguous KV leaves: sliding-window rings)."""
        block_bytes = 0
        fixed = 0

        def walk(sub):
            nonlocal block_bytes, fixed
            if isinstance(sub, dict) and "pk" in sub:
                for leaf in (sub["pk"], sub["pv"]):
                    # (NB, bs, H, hd) or stacked (n_full, NB, bs, H, hd)
                    per_block = leaf.size // leaf.shape[-4] * leaf.dtype.itemsize
                    block_bytes += per_block
                return
            if isinstance(sub, dict):
                for key, v in sub.items():
                    if key in ("k", "v"):
                        fixed += v.size * v.dtype.itemsize
                    else:
                        walk(v)

        walk({"blocks": self.cache.get("blocks", {}),
              "tail": self.cache.get("tail", {})})
        return block_bytes, fixed

    def kv_bytes_in_use(self) -> int:
        """Bytes of KV memory currently backing live tokens: allocated blocks
        across every paged layer plus the fixed ring caches."""
        return self._fixed_kv_bytes + self.alloc.used_count * self._block_bytes

    def kv_pool_bytes(self) -> int:
        """Whole-pool KV capacity in bytes (a pure function of shapes)."""
        return (self._fixed_kv_bytes
                + self.alloc.num_blocks * self._block_bytes)

    def kv_pool_bytes_per_device(self) -> int:
        """Per-device whole-pool KV capacity: head-sharded pools carry
        ``1/model_axis`` of the bytes per device; replicated pools carry all
        of them.  Structural (shape-derived), so the bench gate pins it
        exactly."""
        return (self._fixed_kv_bytes_per_device
                + self.alloc.num_blocks * self._block_bytes_per_device)

    def kv_bytes_in_use_per_device(self) -> int:
        """Per-device bytes currently backing live tokens."""
        return (self._fixed_kv_bytes_per_device
                + self.alloc.used_count * self._block_bytes_per_device)

    def live_tokens(self) -> int:
        """Tokens currently resident in active slots' caches."""
        return sum(len(r.prompt) + len(r.out) for r in self.slots
                   if r is not None)

    def pool_utilization(self) -> float:
        """Chunk-averaged live tokens per allocated-block token capacity:
        the fraction of reserved KV memory actually backing live tokens
        (worst-case reservation scores low on early-stopping traffic; lazy
        allocation is the fix)."""
        if self._util_cap_sum == 0:
            return 0.0
        return self._util_token_sum / self._util_cap_sum

    # -- rng ------------------------------------------------------------------
    def _next_key(self):
        if self.rng is None:
            return None
        self.rng, key = jax.random.split(self.rng)
        return key

    # -- time -----------------------------------------------------------------
    def _now(self) -> float:
        return self.clock.now if self.clock is not None else time.perf_counter()

    # -- admission ------------------------------------------------------------
    @property
    def active(self) -> int:
        return sum(1 for s in self.slots if s is not None)

    def _bucket(self, req: Request) -> int:
        return prefill_bucket(len(req.full_prompt), self.bucketable,
                              self.cache_len)

    def _total_positions(self, req: Request) -> int:
        """WORST-CASE K/V positions the request may write over its whole
        life: the original prompt plus a full ``max_new`` generation tail.
        Deliberately ignores ``stop_at`` (the EOS is unknowable at
        admission - budgeting on it would leak the oracle; early stopping
        is exactly what lazy allocation profits from).  Invariant under
        preemption: prompt + out + (max_new - out) - 1."""
        return len(req.prompt) + req.max_new - 1

    def _blocks_total(self, req: Request) -> int:
        """Worst-case block demand (feasibility: can this EVER finish?)."""
        if not self.has_paged:
            return 0
        return -(-self._total_positions(req) // self.block)

    def _blocks_needed(self, req: Request) -> int:
        """Blocks allocated AT ADMISSION.  Lazy: just the prompt-insert
        coverage (ceil(len(full_prompt)/block)); generation-tail blocks
        arrive later via ``_ensure_blocks``.  Reserve: the old worst case."""
        if not self.has_paged:
            return 0
        if self.alloc_policy == "reserve":
            return self._blocks_total(req)
        return -(-len(req.full_prompt) // self.block)

    def _fits(self, req: Request) -> bool:
        return (self._total_positions(req) <= self.cache_len
                and self._blocks_total(req) <= self.alloc.num_blocks - 1)

    def _admission_error(self, req: Request) -> Optional[str]:
        """Why ``req`` can NEVER be admitted (None if it can): the graceful
        replacement for the old hard ``ValueError`` - an oversized request
        retires with this as its per-request error status."""
        length = len(req.prompt)
        if self._total_positions(req) > self.cache_len:
            return (f"prompt ({length}) + max_new ({req.max_new}) exceeds "
                    f"cache_len ({self.cache_len})")
        if self._blocks_total(req) > self.alloc.num_blocks - 1:
            return (f"request {req.rid} needs {self._blocks_total(req)} KV "
                    f"blocks; pool has {self.alloc.num_blocks - 1}")
        return None

    def fail_request(self, req: Request, error: str,
                     kind: str = "admission"):
        """Retire an unadmitted request with a per-request error status
        (failure isolation: the engine and every other request keep going).
        ``kind`` types the failure ("admission" | "prefill" | "decode" |
        "shed" - the scheduler's load-shedding path)."""
        req.done = True
        req.error = error
        req.error_kind = kind
        self.finished.append(req)
        self.failed_requests += 1
        if kind == "shed":
            self.shed_requests += 1
            if self.meter is not None:
                self.meter.note_shed()
        if self.meter is not None:
            self.meter.note_request_failure()
        log.warning("request %d failed (%s): %s", req.rid, kind, error)

    def admit(self, req: Request) -> bool:
        """Single-request admission (compat shim over the batched path)."""
        pending = [req]
        return len(self.admit_pending(pending)) == 1

    def admit_pending(self, pending: List[Request]) -> List[Request]:
        """Admit as many pending requests as slots + KV blocks allow, one
        batched (R, bucket) prefill call per group.  A group is the FIFO
        PREFIX of the queue sharing the head's bucket: strict arrival order
        is preserved (grouping across later same-bucket requests would let
        short prompts overtake an earlier long one and inflate its TTFT).
        Removes admitted requests from ``pending`` and returns the ones that
        reached a slot; a head request that can never fit retires with an
        error status instead of blocking the queue."""
        admitted: List[Request] = []
        while pending:
            free_slots = [i for i, s in enumerate(self.slots) if s is None]
            if not free_slots:
                break
            err = self._admission_error(pending[0])
            if err is not None:
                self.fail_request(pending.pop(0), err)
                continue
            if self.prefix is not None:
                # prefix-hit heads admit SOLO through the warm path (linked
                # shared blocks + suffix-only prefill); strict FIFO order is
                # preserved because only the head is considered
                state = self._try_admit_prefix(pending[0], free_slots[0])
                if state == "admitted":
                    admitted.append(pending.pop(0))
                    continue
                if state == "defer":
                    break  # head waits for blocks/evictions to free up
                if state == "failed":
                    pending.pop(0)  # already retired via fail_request
                    continue
                # "miss": fall through to the cold batched path
            bucket = self._bucket(pending[0])
            group: List[Request] = []
            reserved = 0
            limit = len(free_slots) if self.batch_prefill else 1
            for r in pending:
                if len(group) >= limit or self._bucket(r) != bucket:
                    break
                if not self._fits(r):
                    # an oversized non-head request ends the prefix BEFORE
                    # any allocation; it retires with an error status when
                    # it reaches the head (nothing admitted behind it leaks)
                    break
                need = self._blocks_needed(r)
                # idle cached prefix blocks count as capacity: _alloc_blocks
                # evicts them (LRU leaf-first) when the free list runs short
                if reserved + need > (self.alloc.free_count
                                      + self.alloc.evictable_count):
                    break
                group.append(r)
                reserved += need
            if not group:
                break  # head-of-line request waits for blocks to free
            ok = self._admit_group(group, free_slots[: len(group)], bucket)
            del pending[: len(group)]
            admitted.extend(ok)
        return admitted

    def _admit_group(self, group: List[Request], slot_ids: List[int],
                     bucket: int) -> List[Request]:
        """Prefill + insert one admitted group; returns the requests that
        actually reached a slot.  A transient device error is retried once
        (the shared ``runtime.fault`` idiom); if the batched prefill still
        fails, its blocks are freed and each member retries SOLO, so a single
        poison request errors out alone instead of taking the group (or the
        engine) down with it."""
        now = self._now()
        r_real = len(group)
        r_pad = 1
        while r_pad < r_real:
            r_pad *= 2
        toks = np.zeros((r_pad, bucket), np.int32)
        true_len = np.ones((r_pad,), np.int32)
        # dummy rows scatter to slot index == batch_slots: out of bounds,
        # dropped by the insert's mode="drop" scatters
        slot_vec = np.full((r_pad,), self.batch_slots, np.int32)
        bt_rows = np.zeros((r_pad, self.max_blocks), np.int32)
        for r, req in enumerate(group):
            if req.t_submit is None:
                req.t_submit = now
            # the RESUME prompt: original prompt plus any tokens generated
            # before a preemption (empty out = plain admission, unchanged)
            pvec = req.full_prompt
            length = len(pvec)
            toks[r, :length] = pvec
            true_len[r] = length
            slot_vec[r] = slot_ids[r]
            blocks = self._alloc_blocks(self._blocks_needed(req))
            assert blocks is not None  # reserved in admit_pending
            self._slot_blocks[slot_ids[r]] = blocks
            bt_rows[r, : len(blocks)] = blocks
        shadow = (self._drift is not None and not self._drift_paused()
                  and self._drift.take_prefill_sample())
        pf_key = (r_pad, bucket, shadow, self.substrate.trace_key)
        pf = self._prefill_fns.get(pf_key)
        if pf is None:
            pf = self._prefill_fns[pf_key] = self._make_prefill()
        rids = tuple(r.rid for r in group)

        def run_pf():
            if self.failure_injector is not None:
                self.failure_injector("prefill", rids)
            if shadow:
                with substrate_lib.shadow_recording(self._drift.recorder):
                    return pf(self.params, jnp.asarray(toks),
                              jnp.asarray(true_len), self._next_key(),
                              self._calib)
            return pf(self.params, jnp.asarray(toks), jnp.asarray(true_len),
                      self._next_key(), self._calib)

        try:
            tok0, cache1 = fault_lib.call_with_retries(
                run_pf, 1, retryable=fault_lib.is_transient_device_error,
                describe=f"prefill group rids={list(rids)}", logger=log)
        except Exception as e:
            if not fault_lib.is_transient_device_error(e):
                raise  # programming bugs must surface, not retire requests
            for r in range(r_real):  # nothing was inserted: free the blocks
                sid = slot_ids[r]
                if self._slot_blocks[sid]:
                    self.alloc.free(self._slot_blocks[sid])
                    self._slot_blocks[sid] = []
            if r_real == 1:
                self.fail_request(
                    group[0], f"prefill failed after retry: {e!r}",
                    kind="prefill")
                return []
            log.warning("batched prefill of %d requests failed (%r); "
                        "re-admitting each solo to isolate the poison row",
                        r_real, e)
            ok: List[Request] = []
            for r, req in enumerate(group):
                ok.extend(self._admit_group([req], [slot_ids[r]],
                                            self._bucket(req)))
            return ok
        self.cache, self.last_token, self.pos = self._insert_fn(
            self.cache, {k: v for k, v in cache1.items() if k != "pos"},
            jnp.asarray(slot_vec), jnp.asarray(bt_rows), tok0,
            jnp.asarray(true_len), self.last_token, self.pos,
        )
        self.prefill_calls += 1
        self.prefill_rows += r_real
        if self.meter is not None:
            # bucket padding is billed work; pow2 pad rows are not
            self.meter.note_prefill(r_real, bucket,
                                    [len(r.prompt) for r in group])
            if shadow:
                self.meter.note_shadow_sample()
        tok0_host = np.asarray(tok0)  # one sync per GROUP (TTFT for all rows)
        if self.clock is not None:
            # batched prefill cost: one bucket's worth of token-forwards
            # (rows run in parallel across the banks)
            self.clock.advance(bucket * self.clock.prefill_token_cost)
        t_first = self._now()
        for r, req in enumerate(group):
            sid = slot_vec[r]
            self.slots[sid] = req
            self._slot_pos[sid] = int(true_len[r])
            self._slot_seq[sid] = self._admit_seq
            self._admit_seq += 1
            if self.prefix is not None:
                # a cold admission under an enabled cache is a prefix MISS;
                # its full prompt blocks are indexed for future sharers
                self.prefix_lookups += 1
                if self.meter is not None:
                    self.meter.note_prefix_miss()
                self._register_prefix(req, sid)
            req.out.append(int(tok0_host[r]))
            if req.t_first is None:  # a resumed request keeps its real TTFT
                req.t_first = t_first
            if self.clock is not None:
                req.token_times.append(t_first)
            if len(req.out) >= req.effective_max:
                self._retire(sid)
        return list(group)

    def _make_prefill(self):
        cfg, bucketable = self.cfg, self.bucketable

        def pf(params, toks, true_len, key, calib):
            # cache_len == bucket: the insert redistributes rows into blocks,
            # so prefill never materializes the full-length contiguous cache.
            # calib is the (traced) hot-swappable frozen calibration; None
            # (an empty pytree) under dynamic/digital substrates.
            run_cfg = _cfg_with_calibration(cfg, calib)
            logits, cache1 = prefill(
                params, run_cfg, toks, cache_len=toks.shape[1], rng=key,
                true_len=true_len if bucketable else None,
            )
            tok0 = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            return tok0, cache1

        return self._with_rules(jax.jit(pf))

    # -- multi-slot cache insert ----------------------------------------------
    def _insert_impl(self, cache, cache1, slot_vec, bt_rows, tok0, true_len,
                     last_token, pos):
        """One jitted scatter of a whole prefill group into the engine cache:
        paged layers write each row's prompt K/V blocks into the pool and its
        block-table row; contiguous leaves (rings, recurrent states) scatter
        along the slot axis.  Out-of-bounds slot ids (dummy pad rows) drop."""
        bs = self.block

        def put_paged(eng: Dict[str, Any], pref: Dict[str, Any], stacked: bool):
            seq_ax = 2 if stacked else 1
            out = dict(eng)
            bt = eng["bt"]
            if stacked:
                out["bt"] = bt.at[:, slot_vec].set(bt_rows, mode="drop")
            else:
                out["bt"] = bt.at[slot_vec].set(bt_rows, mode="drop")
            for pool_key, kv_key in (("pk", "k"), ("pv", "v")):
                pool, src = eng[pool_key], pref[kv_key]
                s = src.shape[seq_ax]
                s_pad = -(-s // bs) * bs
                pads = [(0, 0)] * src.ndim
                pads[seq_ax] = (0, s_pad - s)
                src = jnp.pad(src, pads).astype(pool.dtype)
                nbb = s_pad // bs
                # logical block j of row r -> physical block bt_rows[r, j]
                # (0 = garbage for blocks past the row's allocation: pad-only
                # bucket tails are discarded, never read)
                dest = bt_rows[:, :nbb].reshape(-1)
                if stacked:
                    nf, r = src.shape[0], src.shape[1]
                    src = src.reshape((nf, r * nbb, bs) + src.shape[3:])
                    out[pool_key] = pool.at[:, dest].set(src)
                else:
                    r = src.shape[0]
                    src = src.reshape((r * nbb, bs) + src.shape[2:])
                    out[pool_key] = pool.at[dest].set(src)
            return out

        def put_leaf(eng, pref, stacked: bool):
            if getattr(eng, "ndim", 0) == 0:
                return eng
            slot_ax = 1 if stacked else 0
            # right-pad short leaves (a prefill ring narrower than the
            # engine's span is identity-layout: bucket < window)
            pads = [(0, 0)] * pref.ndim
            for ax in range(pref.ndim):
                if ax != slot_ax:
                    pads[ax] = (0, eng.shape[ax] - pref.shape[ax])
            src = jnp.pad(pref, pads).astype(eng.dtype)
            if stacked:
                return eng.at[:, slot_vec].set(src, mode="drop")
            return eng.at[slot_vec].set(src, mode="drop")

        def walk(eng, pref, stacked: bool):
            if isinstance(eng, dict) and "pk" in eng:
                return put_paged(eng, pref, stacked)
            if isinstance(eng, dict):
                return {k: walk(v, pref[k], stacked) for k, v in eng.items()}
            return put_leaf(eng, pref, stacked)

        new_cache = {}
        for key, sub in cache.items():
            stacked = key == "blocks"
            new_cache[key] = walk(sub, cache1[key], stacked)
        return (
            new_cache,
            last_token.at[slot_vec].set(tok0, mode="drop"),
            pos.at[slot_vec].set(true_len, mode="drop"),
        )

    def _retire(self, i: int, error: Optional[str] = None,
                kind: str = "decode"):
        req = self.slots[i]
        req.done = True
        req.error = error
        self.slots[i] = None
        self._slot_pos[i] = 0
        self.finished.append(req)
        if error is not None:
            req.error_kind = kind
            self.failed_requests += 1
            if self.meter is not None:
                self.meter.note_request_failure()
            log.warning("request %d failed in slot %d: %s", req.rid, i, error)
        if self._slot_blocks[i]:
            # the stale device block table keeps pointing at these blocks;
            # that is safe because inactive rows write to the garbage block
            self.alloc.free(self._slot_blocks[i])
            self._slot_blocks[i] = []

    # -- lazy allocation + recompute-preemption --------------------------------
    def _preempt(self, i: int):
        """Evict slot ``i`` mid-generation: free its blocks and park the
        request (with its generated tokens) on ``self.preempted`` for the
        serve loop to re-queue.  Re-admission prefills prompt + out, which
        reproduces the decode state exactly - recompute-preemption is
        bit-exact under frozen calibration.  The stale device block table /
        last-token row is safe for the same reason retirement is: inactive
        rows write to the garbage block."""
        req = self.slots[i]
        self.slots[i] = None
        self._slot_pos[i] = 0
        req.preemptions += 1
        if self._slot_blocks[i]:
            self.alloc.free(self._slot_blocks[i])
            self._slot_blocks[i] = []
        self.preempted.append(req)
        self.preempt_count += 1
        if self.meter is not None:
            self.meter.note_preemption()
        log.info("preempted request %d from slot %d (%d tokens kept)",
                 req.rid, i, len(req.out))

    def _pick_victim(self, grower: int) -> Optional[int]:
        """Victim slot for a failed block grow: the LATEST-admitted active
        slot newer than the grower (None if the grower itself is newest).
        Never preempting an older resident means the oldest one always makes
        progress, so grow/preempt cycles terminate."""
        candidates = [i for i, s in enumerate(self.slots)
                      if s is not None and i != grower
                      and self._slot_seq[i] > self._slot_seq[grower]]
        if not candidates:
            return None
        return max(candidates, key=lambda i: self._slot_seq[i])

    def _ensure_blocks(self, n_steps: int):
        """Lazy allocation on block-boundary crossing: before a chunk of
        ``n_steps`` decode writes, every active slot must own blocks covering
        positions ``0 .. pos + n_steps - 1``.  Grows oldest-first; an
        allocation failure preempts victims (``_pick_victim``) until the grow
        fits or the grower itself is preempted.  New (slot, logical block,
        physical block) entries are scattered into the device block tables in
        ONE jitted call."""
        if not self.has_paged or self.alloc_policy != "lazy":
            return
        triples: List[Tuple[int, int, int]] = []
        order = sorted(
            (i for i, s in enumerate(self.slots) if s is not None),
            key=lambda i: self._slot_seq[i])
        for i in order:
            if self.slots[i] is None:
                continue  # preempted as a victim earlier in this pass
            need = -(-(self._slot_pos[i] + n_steps) // self.block)
            deficit = need - len(self._slot_blocks[i])
            if deficit <= 0:
                continue
            got = self._alloc_blocks(deficit)
            while got is None:
                victim = self._pick_victim(i)
                if victim is None:
                    # the grower is the newest resident: it yields (keeping
                    # its tokens) rather than evicting older work
                    self._preempt(i)
                    break
                self._preempt(victim)
                got = self._alloc_blocks(deficit)
            if got is None:
                continue
            have = len(self._slot_blocks[i])
            triples.extend((i, have + j, b) for j, b in enumerate(got))
            self._slot_blocks[i].extend(got)
        if not triples:
            return
        # pad to a power of two (dropped via slot == batch_slots) so the
        # jitted block-table extend compiles per size class, not per count
        n_pad = 1
        while n_pad < len(triples):
            n_pad *= 2
        slot_vec = np.full((n_pad,), self.batch_slots, np.int32)
        log_vec = np.zeros((n_pad,), np.int32)
        phys_vec = np.zeros((n_pad,), np.int32)
        for j, (s, l, p) in enumerate(triples):
            slot_vec[j], log_vec[j], phys_vec[j] = s, l, p
        self.cache = self._extend_fn(
            self.cache, jnp.asarray(slot_vec), jnp.asarray(log_vec),
            jnp.asarray(phys_vec))

    def _extend_impl(self, cache, slot_vec, log_vec, phys_vec):
        """Scatter freshly-allocated physical block ids into every paged
        layer group's block table at (slot, logical) - the device half of a
        lazy grow.  Out-of-bounds slot ids (pad entries) drop."""

        def walk(sub, stacked: bool):
            if isinstance(sub, dict) and "pk" in sub:
                out = dict(sub)
                bt = sub["bt"]
                if stacked:
                    src = jnp.broadcast_to(
                        phys_vec, (bt.shape[0],) + phys_vec.shape)
                    out["bt"] = bt.at[:, slot_vec, log_vec].set(
                        src, mode="drop")
                else:
                    out["bt"] = bt.at[slot_vec, log_vec].set(
                        phys_vec, mode="drop")
                return out
            if isinstance(sub, dict):
                return {k: walk(v, stacked) for k, v in sub.items()}
            return sub

        return {k: walk(v, k == "blocks") for k, v in cache.items()}

    # -- prefix sharing --------------------------------------------------------
    def _alloc_blocks(self, n: int) -> Optional[List[int]]:
        """``alloc`` with eviction pressure: when the free list runs short,
        reclaim idle cached prefix blocks (LRU, leaf-first over the radix
        index) until the allocation fits or nothing is evictable."""
        if n == 0:
            return []
        blocks = self.alloc.alloc(n)
        while blocks is None and self._evict_one():
            blocks = self.alloc.alloc(n)
        return blocks

    def _evict_one(self) -> bool:
        """Evict ONE idle cached block: drop the least-recently-used leaf of
        the radix index whose block holds no references.  Leaf-first keeps
        every remaining chain reachable; referenced leaves (a sharer is
        mid-flight) are skipped."""
        if self.prefix is None:
            return False
        for node in self.prefix.leaves_lru():
            if self.alloc.is_evictable(node.block):
                self.prefix.remove(node)
                self.alloc.evict(node.block)
                self.prefix_evictions += 1
                return True
        return False

    def _register_prefix(self, req: Request, sid: int):
        """Index the admitted request's full prompt blocks so later sharers
        can link them.  Only FULL blocks enter the index (they are immutable:
        every later write for this slot lands at position >= the prompt
        length); newly indexed blocks hand their zero-ref lifetime to the
        allocator's cached set."""
        n_full = len(req.full_prompt) // self.block
        chain = self._slot_blocks[sid][:n_full]
        if n_full == 0 or len(chain) < n_full:
            return
        for b in self.prefix.insert(req.full_prompt, chain):
            self.alloc.register_cached(b)

    def _try_admit_prefix(self, req: Request, slot: int) -> str:
        """Warm admission of a prefix-hit head request into ``slot``.

        Matches the longest chain of cached full blocks prefixing the
        request's (resume) prompt, retains + links those physical blocks
        into the slot's block table, and runs prefill ONLY over the uncached
        suffix as a teacher-forced decode scan (the same decode==prefill
        argmax equivalence the recompute-preemption contract pins, in
        reverse).  At least one token is always re-fed so the final
        position's logits exist; when the whole prompt is cached that
        re-feed writes INSIDE the last shared block, which triggers
        copy-on-write: a one-block jitted pool copy into a fresh private
        block that replaces the shared one in this slot's table only.

        Returns "miss" (no cached prefix - caller takes the cold path),
        "defer" (hit, but blocks are short - head waits), "failed"
        (persistent device error - request retired), or "admitted".
        """
        pvec = req.full_prompt
        nodes = self.prefix.match(pvec)
        if not nodes:
            return "miss"
        length = len(pvec)
        bs = self.block
        m = len(nodes)
        start = min(bs * m, length - 1)  # always re-feed >= 1 token
        t_true = length - start
        cow = (start // bs) < m  # re-feed write lands in a shared block
        keep = nodes[:-1] if cow else nodes
        total = (self._blocks_total(req) if self.alloc_policy == "reserve"
                 else -(-length // bs))
        fresh_n = total - len(keep)
        shared = [n.block for n in keep]
        matched = [n.block for n in nodes]
        # retain EVERY matched block (incl. a CoW source) BEFORE allocating:
        # eviction pressure inside _alloc_blocks must never reclaim a block
        # this admission is about to link or copy from
        self.alloc.retain(matched)
        fresh = self._alloc_blocks(fresh_n)
        if fresh is None:
            self.alloc.free(matched)
            return "defer"
        blocks = list(shared)
        if cow:
            blocks.append(fresh[0])
        blocks.extend(fresh[1:] if cow else fresh)
        if req.t_submit is None:
            req.t_submit = self._now()
        if cow:
            # private copy of the shared block's earlier positions; the
            # suffix scan then overwrites only position ``length - 1``.  The
            # source's extra reference drops once the copy is taken (it
            # stays indexed for other sharers).
            self.cache = self._cow_fn(self.cache,
                                      jnp.int32(nodes[-1].block),
                                      jnp.int32(fresh[0]))
            self.alloc.free([nodes[-1].block])
            self.cow_copies += 1
            if self.meter is not None:
                self.meter.note_cow_copy()
        bt_row = np.zeros((self.max_blocks,), np.int32)
        bt_row[: len(blocks)] = blocks
        t_pad = 1
        while t_pad < t_true:
            t_pad *= 2
        toks = np.zeros((self.batch_slots, t_pad), np.int32)
        toks[slot, :t_true] = pvec[start:]
        fn_key = (t_pad, self.substrate.trace_key)
        fn = self._warm_fns.get(fn_key)
        if fn is None:
            fn = self._warm_fns[fn_key] = self._make_warm(t_pad)

        def run_warm():
            if self.failure_injector is not None:
                self.failure_injector("prefill", (req.rid,))
            return fn(self.params, self.cache, jnp.asarray(bt_row),
                      jnp.int32(slot), jnp.asarray(toks),
                      jnp.int32(t_true), jnp.int32(start),
                      self.last_token, self.pos, self._next_key(),
                      self._calib)

        try:
            cache, last_token, pos, tok0 = fault_lib.call_with_retries(
                run_warm, 1, retryable=fault_lib.is_transient_device_error,
                describe=f"warm prefill rid={req.rid}", logger=log)
        except Exception as e:
            if not fault_lib.is_transient_device_error(e):
                raise
            # the pure warm fn never committed: device block tables are
            # untouched, so releasing the references fully unwinds
            self.alloc.free(blocks)
            self.fail_request(
                req, f"warm prefill failed after retry: {e!r}",
                kind="prefill")
            return "failed"
        self.cache, self.last_token, self.pos = cache, last_token, pos
        self._slot_blocks[slot] = blocks
        self.slots[slot] = req
        self._slot_pos[slot] = length
        self._slot_seq[slot] = self._admit_seq
        self._admit_seq += 1
        self.prefill_calls += 1
        self.prefill_rows += 1
        self.prefix_lookups += 1
        self.prefix_hits += 1
        self.prefix_hit_tokens += start
        cold_bucket = self._bucket(req)
        self.prefix_saved_tokens += max(0, cold_bucket - t_true)
        if self.meter is not None:
            self.meter.note_prefix_admission(t_true, cold_bucket, start)
        if self.clock is not None:
            self.clock.advance(t_true * self.clock.prefill_token_cost)
        # register BEFORE appending tok0: tok0's K/V is not in the cache yet
        # (it is written when fed back on the first decode step)
        self._register_prefix(req, slot)
        t_first = self._now()
        req.out.append(int(tok0))
        if req.t_first is None:
            req.t_first = t_first
        if self.clock is not None:
            req.token_times.append(t_first)
        if len(req.out) >= req.effective_max:
            self._retire(slot)
        log.info("prefix hit request %d: %d/%d tokens cached (%d blocks, "
                 "suffix %d%s)", req.rid, start, length, m, t_true,
                 ", CoW" if cow else "")
        return "admitted"

    def _make_warm(self, t_pad: int):
        """Suffix prefill as a teacher-forced fused decode scan: link the
        slot's block-table row, start from ``start_pos``, feed the suffix
        tokens one step at a time (inactive rows and pad steps write to the
        garbage block), and return the final true step's argmax - exactly
        the ``tok0`` a cold bucketed prefill of the full prompt produces."""
        cfg = self.cfg

        def warm(params, cache, bt_row, slot, toks, t_true, start_pos,
                 last_tok, pos, key, calib):
            run_cfg = _cfg_with_calibration(cfg, calib)

            def link(sub, stacked):
                if isinstance(sub, dict) and "pk" in sub:
                    out = dict(sub)
                    bt = sub["bt"]
                    if stacked:
                        src = jnp.broadcast_to(
                            bt_row, (bt.shape[0],) + bt_row.shape)
                        out["bt"] = bt.at[:, slot].set(src)
                    else:
                        out["bt"] = bt.at[slot].set(bt_row)
                    return out
                if isinstance(sub, dict):
                    return {k: link(v, stacked) for k, v in sub.items()}
                return sub

            cache = {k: link(v, k == "blocks") for k, v in cache.items()}
            pos = pos.at[slot].set(start_pos)
            row = jnp.arange(pos.shape[0]) == slot

            def step(carry, t):
                cache, pos, out = carry
                fed = toks[:, t]
                act = row & (t < t_true)
                k = None if key is None else jax.random.fold_in(key, t)
                logits, new_cache = decode_step(
                    params, run_cfg, fed, dict(cache, pos=pos), rng=k,
                    active=act,
                )
                nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
                out = jnp.where(t < t_true, nxt[slot], out)
                new_pos = jnp.where(act, pos + 1, pos)
                new_cache.pop("pos")
                return (new_cache, new_pos, out), None

            (cache, pos, out), _ = jax.lax.scan(
                step, (cache, pos, last_tok[slot]), jnp.arange(t_pad))
            return cache, last_tok.at[slot].set(out), pos, out

        if self.tp > 1:
            rep = self._rep_sharding
            return self._with_rules(jax.jit(
                warm, out_shardings=(self._cache_shardings, rep, rep, rep)))
        return jax.jit(warm)

    def _cow_impl(self, cache, src, dst):
        """Copy one physical block's K/V across every paged layer group -
        the device half of copy-on-write.  One jitted call per CoW event;
        the block-table rewrite rides the warm scan's link step."""

        def walk(sub, stacked):
            if isinstance(sub, dict) and "pk" in sub:
                out = dict(sub)
                for pool_key in ("pk", "pv"):
                    pool = sub[pool_key]
                    if stacked:
                        out[pool_key] = pool.at[:, dst].set(pool[:, src])
                    else:
                        out[pool_key] = pool.at[dst].set(pool[src])
                return out
            if isinstance(sub, dict):
                return {k: walk(v, stacked) for k, v in sub.items()}
            return sub

        return {k: walk(v, k == "blocks") for k, v in cache.items()}

    def prefix_stats(self) -> Dict[str, Any]:
        """Host-side prefix-sharing scoreboard (bench/CLI surface)."""
        lookups = self.prefix_lookups
        return {
            "enabled": self.prefix is not None,
            "lookups": lookups,
            "hits": self.prefix_hits,
            "hit_rate": round(self.prefix_hits / lookups, 4) if lookups
            else 0.0,
            "hit_tokens": self.prefix_hit_tokens,
            "saved_billed_tokens": self.prefix_saved_tokens,
            "cow_copies": self.cow_copies,
            "evictions": self.prefix_evictions,
            "cached_blocks": len(self.prefix) if self.prefix else 0,
        }

    # -- online calibration ----------------------------------------------------
    def swap_calibration(self, calibration: substrate_lib.Calibration):
        """Atomically install a refreshed frozen calibration.

        Contract (documented in ``core.substrate``): call ONLY between
        chunks - the engine is synchronous, so any call site outside
        ``decode_chunk``/``_admit_group`` is a chunk boundary.  The refreshed
        calibration must carry the same site names as the frozen one (same
        pytree treedef; build it with ``runtime.drift.refreshed_calibration``)
        so every compiled decode/prefill executable is re-used - the swap is
        a host-side pointer update, never a recompile.
        """
        if self._calib is None:
            raise ValueError(
                "swap_calibration requires a frozen-policy substrate")
        if calibration.site_names() != self._calib.site_names():
            raise ValueError(
                "refreshed calibration must preserve the frozen site-name "
                "structure (same pytree treedef); merge with the frozen "
                "calibration first (runtime.drift.refreshed_calibration): "
                f"{calibration.site_names()} != {self._calib.site_names()}")
        old = self.substrate
        self.substrate = old.frozen(calibration)
        self.cfg = self.cfg.replace(imc=self.substrate)
        self._calib = calibration
        self.swap_count += 1
        if self.meter is not None:
            self.meter.note_swap()
            if self.meter.substrate is old:
                self.meter.substrate = self.substrate

    def swap_substrate(self, substrate, time_scale: float = 1.0):
        """Hot-swap the execution substrate (load-adaptive frontier
        degradation).  Call only between chunks - same atomicity contract as
        ``swap_calibration``.  The engine's live frozen calibration (if any)
        is re-attached to the incoming substrate, so site names - and with
        them the calibration treedef - are preserved; the prefill/decode jit
        caches are keyed on ``Substrate.trace_key``, so each distinct ladder
        level compiles once and every later move to it is a host-side
        pointer update.  ``time_scale`` is the new per-decode-step virtual
        cost (a degraded design point's frontier delay ratio < 1)."""
        sub = substrate_lib.as_substrate(substrate)
        if self._calib is not None:
            sub = sub.frozen(self._calib)
        old = self.substrate
        self.substrate = sub
        self.cfg = self.cfg.replace(imc=sub)
        self.substrate_swaps += 1
        if self.clock is not None:
            self.clock.time_scale = time_scale
        if self.meter is not None:
            self.meter.note_substrate_swap(sub)
            if self.meter.substrate is old:
                self.meter.substrate = sub

    def _drift_paused(self) -> bool:
        """Shadow sampling pauses while the serve loop reports a queue above
        the pressure threshold: the cadence phase freezes (``take_sample`` is
        simply not consulted) and resumes untouched when pressure clears, so
        the DriftMonitor callback tax never lands at peak load."""
        return (self.drift_pause_depth is not None
                and self.queue_depth > self.drift_pause_depth)

    def _maybe_check_drift(self):
        """After a shadow-sampled chunk: run the detector at the monitor's
        cadence and hot-swap the refreshed calibration on a drifted report
        (we are between chunks here, so the swap is atomic by construction)."""
        mon = self._drift
        report = mon.check(self._calib)
        if report is None:
            return
        if self.meter is not None:
            self.meter.note_drift_report(report.to_dict())
        log.info("drift check %d: %s", mon.checks, report.summary_line())
        if report.drifted and mon.cfg.auto_swap:
            self.swap_calibration(mon.refreshed(self._calib))
            mon.note_swap()
            log.info("hot-swapped refreshed calibration (swap %d) at sites "
                     "%s", self.swap_count, list(report.drifted_sites))

    # -- fused decode ----------------------------------------------------------
    def next_chunk(self) -> int:
        """Largest power-of-two scan length no active request overruns
        (EOS-capped: an early-stopping request bounds the chunk at its true
        remaining generation, not its worst-case cap)."""
        rem = [r.effective_max - len(r.out) for r in self.slots
               if r is not None]
        if not rem:
            return 0
        cap = min(min(rem), self.max_chunk)
        t = 1
        while t * 2 <= cap:
            t *= 2
        return t

    def _make_decode(self, n_steps: int):
        cfg = self.cfg

        def chunk(params, cache, last_tok, pos, active, key, calib):
            # calib: the hot-swappable frozen calibration, a traced pytree
            # argument - one consistent set of ranges for the WHOLE chunk
            run_cfg = _cfg_with_calibration(cfg, calib)

            def step(carry, t):
                cache, tok, pos = carry
                k = None if key is None else jax.random.fold_in(key, t)
                logits, new_cache = decode_step(
                    params, run_cfg, tok, dict(cache, pos=pos), rng=k,
                    active=active,
                )
                nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
                nxt = jnp.where(active, nxt, tok)
                new_pos = jnp.where(active, pos + 1, pos)
                new_cache.pop("pos")
                return (new_cache, nxt, new_pos), nxt

            (cache, tok, pos), toks = jax.lax.scan(
                step, (cache, last_tok, pos), jnp.arange(n_steps)
            )
            return cache, tok, pos, toks.T  # (slots, T)

        if self.tp > 1:
            # the (slots, T) token block is REPLICATED (every shard holds the
            # same argmax'd tokens), so the one-transfer-per-chunk contract
            # survives sharding: nothing else crosses to the host.  The cache
            # keeps its head-sharded placement across chunks.
            rep = self._rep_sharding
            return self._with_rules(jax.jit(
                chunk,
                out_shardings=(self._cache_shardings, rep, rep, rep)))
        return jax.jit(chunk)

    def decode_chunk(self, n_steps: Optional[int] = None) -> np.ndarray:
        """Run ``n_steps`` fused decode steps; returns the (slots, T) token
        block (the single device->host transfer of the chunk).

        A transient device error (``XlaRuntimeError``) is retried once via
        the shared ``runtime.fault`` idiom - the chunk function is pure, so
        the re-run is exact; if the error persists, only the requests in
        flight retire with an error status and the engine survives."""
        if n_steps is None:
            n_steps = self.next_chunk()
        if n_steps <= 0:
            return np.zeros((self.batch_slots, 0), np.int32)
        # lazy growth (may preempt: the active set below reflects it)
        self._ensure_blocks(n_steps)
        if self.active == 0:
            return np.zeros((self.batch_slots, 0), np.int32)
        shadow = (self._drift is not None and self.active > 0
                  and not self._drift_paused()
                  and self._drift.take_sample())
        fn_key = (n_steps, shadow, self.substrate.trace_key)
        fn = self._decode_fns.get(fn_key)
        if fn is None:
            fn = self._decode_fns[fn_key] = self._make_decode(n_steps)
        active = jnp.asarray(
            np.array([s is not None for s in self.slots]))
        args = (self.params, self.cache, self.last_token, self.pos, active,
                self._next_key(), self._calib)

        def run_chunk():
            if self.failure_injector is not None:
                self.failure_injector("decode", self.decode_calls)
            if shadow:
                with substrate_lib.shadow_recording(self._drift.recorder):
                    return fn(*args)
            return fn(*args)

        try:
            cache, last_token, pos, toks = fault_lib.call_with_retries(
                run_chunk, 1,
                retryable=fault_lib.is_transient_device_error,
                describe=f"decode chunk {self.decode_calls}", logger=log)
        except Exception as e:
            if not fault_lib.is_transient_device_error(e):
                raise  # programming bugs must surface, not retire requests
            # persistent device error: the chunk never committed (device
            # state is untouched - assignment below did not happen), so
            # fail exactly the requests that were in flight and keep serving
            self.decode_failures += 1
            msg = f"decode chunk failed after retry: {e!r}"
            log.warning("%s; failing %d in-flight requests", msg, self.active)
            for i, req in enumerate(self.slots):
                if req is not None:
                    self._retire(i, error=msg)
            return np.zeros((self.batch_slots, 0), np.int32)
        self.cache, self.last_token, self.pos = cache, last_token, pos
        if self.meter is not None:
            # active slots at chunk start each ran n_steps token-forwards
            self.meter.note_decode(int(np.asarray(active).sum()), n_steps)
            if shadow:
                self.meter.note_shadow_sample()
        block = np.asarray(toks)  # the one host transfer per chunk
        self.decode_calls += 1
        self.decode_steps += n_steps
        self.host_transfer_bytes += block.nbytes
        # pool-utilization sample: tokens live in active caches vs the token
        # capacity of the blocks currently allocated (lazy vs reserve signal)
        if self.has_paged and self.alloc.used_count:
            self._util_token_sum += self.live_tokens()
            self._util_cap_sum += self.alloc.used_count * self.block
        dt = None
        if self.clock is not None:
            dt = self.clock.time_scale
            self.clock.advance(n_steps * dt)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            self._slot_pos[i] += n_steps
            take = min(n_steps, req.effective_max - len(req.out))
            req.out.extend(int(t) for t in block[i, :take])
            if dt is not None:
                t_end = self.clock.now
                req.token_times.extend(
                    t_end - (take - 1 - j) * dt for j in range(take))
            if len(req.out) >= req.effective_max:
                self._retire(i)
        if shadow:
            self._maybe_check_drift()
        return block


def serve(engine: Engine, requests: List[Request]) -> List[Request]:
    """Drive the engine until every request finishes (successfully or with a
    per-request error status); returns them in completion order.

    Graceful degradation: a head-of-line request the idle engine can never
    admit (the old hard ``RuntimeError`` deadlock) retires with an error
    status and serving continues for everyone else."""
    pending = list(requests)
    done_mark = len(engine.finished)
    while pending or engine.active:
        admitted = engine.admit_pending(pending)
        for req in admitted:
            log.info("admitted request %d len=%d (active=%d)",
                     req.rid, len(req.prompt), engine.active)
        if pending and not engine.active and not admitted:
            # nothing is running, nothing could be admitted, and the queue
            # is non-empty: the head request is stuck (e.g. its block demand
            # exceeds what an idle pool can ever free).  Retire IT, not the
            # engine - everyone behind it gets served.
            engine.fail_request(
                pending.pop(0),
                "cannot be admitted into an idle engine (slots or KV block "
                "pool too small)")
            continue
        engine.decode_chunk()
        if engine.preempted:
            # recompute-preempted requests re-enter at the FRONT: they hold
            # generated tokens (partial work) and freeing their successor
            # blocks fastest means finishing them first
            pending[:0] = engine.preempted
            engine.preempted.clear()
        for r in engine.finished[done_mark:]:
            if r.error is None:
                log.info("finished request %d: %d tokens", r.rid, len(r.out))
        done_mark = len(engine.finished)
    return engine.finished


def serve_slo(engine: Engine, requests: List[Request], policy=None,
              controller=None) -> List[Request]:
    """Real-time SLO serve loop: requests ARRIVE at their ``arrive_at``
    virtual times, a ``launch.scheduler`` policy orders the queue and sheds
    hopeless work, preempted requests re-queue at the front, and an optional
    ``PressureController`` walks the EDAP frontier under load.

    Every submitted request leaves through ``engine.finished`` exactly once -
    completed, errored, or shed (request conservation, property-pinned).  The
    loop is duck-typed over the engine (attributes: ``clock``, ``queue_depth``,
    ``active``, ``preempted``, ``finished``; methods: ``admit_pending``,
    ``decode_chunk``, ``fail_request``), so model-free fakes can drive the
    scheduling invariants in tests."""
    from repro.launch.scheduler import FIFOPolicy
    from repro.runtime.workload import VirtualClock

    if policy is None:
        policy = FIFOPolicy()
    if engine.clock is None:
        engine.clock = VirtualClock()
    clock = engine.clock
    arrivals = sorted(requests, key=lambda r: (
        r.arrive_at if r.arrive_at is not None else 0.0, r.rid))
    queue: List[Request] = []
    while arrivals or queue or engine.active:
        while arrivals and (arrivals[0].arrive_at is None
                            or arrivals[0].arrive_at <= clock.now):
            queue.append(arrivals.pop(0))
        if not queue and not engine.active:
            # idle gap: jump to the next arrival instead of spinning
            clock.advance(max(0.0, arrivals[0].arrive_at - clock.now))
            continue
        engine.queue_depth = len(queue)
        if controller is not None:
            controller.update()
        for r in policy.shed(queue, clock.now):
            engine.fail_request(
                r, f"shed by {policy.name} policy at t={clock.now:.1f} "
                   f"(TTFT deadline unmeetable)", kind="shed")
        policy.order(queue, clock.now)
        admitted = engine.admit_pending(queue)
        engine.queue_depth = len(queue)
        if queue and not engine.active and not admitted:
            engine.fail_request(
                queue.pop(0),
                "cannot be admitted into an idle engine (slots or KV block "
                "pool too small)")
            continue
        engine.decode_chunk()
        if engine.preempted:
            queue[:0] = engine.preempted
            engine.preempted.clear()
    engine.queue_depth = 0
    return engine.finished


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(configs.ARCH_NAMES))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--prompt-lens", default=None,
                    help="comma list of prompt lengths cycled over the "
                         "requests (unequal-length admission); overrides "
                         "--prompt-len")
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--chunk", type=int, default=8,
                    help="max fused decode steps per jitted scan call")
    ap.add_argument("--block", type=int, default=DEFAULT_BLOCK,
                    help="tokens per paged-KV block")
    ap.add_argument("--kv-blocks", type=int, default=None,
                    help="physical KV pool size in blocks (default: full "
                         "provisioning, slots * max_blocks + 1)")
    ap.add_argument("--imc-mode", default=None,
                    choices=[None, "fakequant", "imc_analytic",
                             "imc_bitserial"])
    ap.add_argument("--imc-vwl", type=float, default=0.7)
    ap.add_argument("--imc-policy", default="dynamic",
                    choices=["dynamic", "frozen"],
                    help="substrate calibration policy: 'frozen' calibrates "
                         "quantizer ranges on a synthetic reference batch "
                         "before serving and disables the shared analog-"
                         "noise RNG, making IMC outputs batch-composition-"
                         "invariant (batched == sequential, bit-identical)")
    ap.add_argument("--recalibrate", action="store_true",
                    help="online calibration (requires --imc-policy frozen): "
                         "shadow-record a sample of live chunks, detect "
                         "range drift with the one-sided superset test, and "
                         "hot-swap a refreshed calibration at a chunk "
                         "boundary (no recompile, no pause)")
    ap.add_argument("--drift-sample-every", type=int, default=2,
                    help="shadow-record every Nth decode chunk / prefill "
                         "group (with --recalibrate)")
    ap.add_argument("--drift-check-every", type=int, default=2,
                    help="run the drift detector every Nth shadow sample")
    ap.add_argument("--inject-drift", default=None, metavar="SCALE@REQS",
                    help="drift-injection demo: serve the first REQS "
                         "requests, then scale the token embedding by SCALE "
                         "(an activation-scale shift at every downstream "
                         "site) and serve the rest; prints the drift report "
                         "and the post-swap SNR_T recovery table")
    ap.add_argument("--energy-report", action="store_true",
                    help="meter the served traffic and print J/token, "
                         "J/request and EDP/token at the min-energy QS/QR/CM "
                         "design points (512-row banks, two SNR_T targets); "
                         "sites are the FULL (non-smoke) model's matmuls, so "
                         "smoke runs still report deployment-scale energy")
    ap.add_argument("--energy-snr-db", default="14,26",
                    help="comma list of SNR_T targets for --energy-report")
    ap.add_argument("--workload", default="none",
                    choices=["none", "poisson", "bursty"],
                    help="SLO workload mode: generate seeded timed arrivals "
                         "(runtime.workload) and drive the engine through "
                         "the real-time serve_slo loop under --slo-policy "
                         "instead of replaying --prompt-lens offline")
    ap.add_argument("--workload-seed", type=int, default=0,
                    help="workload generator seed (every draw - arrivals, "
                         "lengths, classes - is reproducible from it)")
    ap.add_argument("--overload", type=float, default=2.0,
                    help="offered load as a multiple of engine capacity "
                         "(with --workload)")
    ap.add_argument("--slo-policy", default="fifo",
                    choices=["fifo", "sjf", "deadline"],
                    help="scheduler policy for the SLO loop: fifo, "
                         "shortest-prompt-first, or SLO-deadline admission "
                         "with load shedding")
    ap.add_argument("--alloc", default="lazy", choices=["lazy", "reserve"],
                    help="KV block allocation: lazy (allocate on block-"
                         "boundary crossing, preempt on pool exhaustion) or "
                         "reserve (worst-case at admission)")
    ap.add_argument("--degrade", action="store_true",
                    help="load-adaptive frontier degradation: under queue/"
                         "pool pressure, hot-swap the substrate one step "
                         "down the EDAP frontier (lower B_ADC) and back up "
                         "when pressure clears (requires --imc-mode "
                         "imc_analytic --imc-policy frozen)")
    ap.add_argument("--drift-pause-depth", type=int, default=None,
                    help="pause drift shadow sampling while the queue is "
                         "deeper than this (saturation guard)")
    ap.add_argument("--prefix-cache", action=argparse.BooleanOptionalAction,
                    default=False,
                    help="prefix-sharing paged KV: a radix index over token "
                         "prefixes at block granularity links already-cached "
                         "blocks into new requests' block tables (refcounted "
                         "copy-on-write); admission prefills only the "
                         "uncached suffix.  Greedy tokens are identical to "
                         "a cold-cache run under frozen calibration")
    ap.add_argument("--shared-prefix-len", type=int, default=0,
                    help="shared-system-prompt traffic: every request's "
                         "prompt starts with this many common tokens "
                         "(drawn once per run) followed by its unique "
                         "--prompt-len(s) tail; with --workload the shared "
                         "prefixes come from per-class seeded pools instead")
    ap.add_argument("--prefix-dup", type=int, default=4,
                    help="with --workload and --shared-prefix-len: requests "
                         "per distinct shared prefix within each request "
                         "class (the duplication factor)")
    ap.add_argument("--decode-attn", default="kernel",
                    choices=["kernel", "gather"],
                    help="paged decode attention: 'kernel' streams KV blocks "
                         "through the fused online-softmax paged-attention "
                         "kernel (default); 'gather' is the reference escape "
                         "hatch that materializes pool[bt] each step.  Baked "
                         "into the engine cfg at construction (static at "
                         "trace time), so it cannot thrash the jit caches")
    ap.add_argument("--mesh", default=None, metavar="RxC",
                    help="serve over a (data, model) device mesh, e.g. 1x8: "
                         "tensor-parallel weights + head-sharded paged KV "
                         "pools (replicated pools when Hkv does not divide "
                         "the model axis).  Needs R*C devices - on CPU set "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=N")
    args = ap.parse_args(argv)
    logging.basicConfig(level=logging.INFO)

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    cfg = cfg.replace(decode_attn=args.decode_attn)
    rng = None
    base_pt = None
    if args.degrade:
        if not (args.imc_mode == "imc_analytic"
                and args.imc_policy == "frozen"):
            ap.error("--degrade requires --imc-mode imc_analytic "
                     "--imc-policy frozen (the frontier ladder re-freezes "
                     "each level against the live calibration)")
        from repro.core.design import optimize

        base_pt = optimize(n=512, snr_t_target_db=26.0, kinds=("qr",))
    if args.imc_mode:
        from repro.core.imc_linear import IMCConfig

        if base_pt is not None:
            # start at the committed frontier point: the PressureController's
            # ladder level 0 IS this substrate
            sub = substrate_lib.substrate_for_design(base_pt)
        else:
            sub = substrate_lib.as_substrate(
                IMCConfig(mode=args.imc_mode, bx=7, bw=7, v_wl=args.imc_vwl))
        cfg = cfg.replace(imc=sub)
        rng = jax.random.PRNGKey(7)

    if args.prompt_lens:
        lens = [int(x) for x in args.prompt_lens.split(",")]
    else:
        lens = [args.prompt_len]
    params = init_params(jax.random.PRNGKey(0), cfg)
    if args.imc_mode and args.imc_policy == "frozen":
        # freeze quantizer ranges on a synthetic reference batch: served
        # outputs become independent of how requests are batched together.
        # The engine-wide noise RNG must also go: its draws are shaped by
        # the batch (slots x step), so leaving it on would break the
        # batched == sequential bit-identity the frozen policy advertises.
        rng = None
        ref = np.random.default_rng(1).integers(
            0, cfg.vocab_size, (2, max(lens) if lens else 32))
        cfg = substrate_lib.calibrate_model(cfg, params, [ref])
        log.info("froze substrate calibration on a %s reference batch "
                 "(%d sites); analog-noise RNG disabled for "
                 "batch-invariance", ref.shape,
                 len(cfg.imc.calibration.site_names()))
    bucketable = not needs_exact_prefill(cfg)
    max_bucket = max(prefill_bucket(l + args.shared_prefix_len, bucketable,
                                    10**9) for l in lens)
    cache_len = max_bucket + args.gen + 8
    meter = None
    if args.energy_report:
        from repro.launch.metering import DPMeter

        meter = DPMeter(configs.get(args.arch))
    monitor = None
    if args.recalibrate:
        if not (args.imc_mode and args.imc_policy == "frozen"):
            ap.error("--recalibrate requires --imc-mode and "
                     "--imc-policy frozen")
        monitor = drift_lib.DriftMonitor(drift_lib.DriftConfig(
            sample_every=args.drift_sample_every,
            check_every=args.drift_check_every))
    frozen0 = cfg.imc.calibration if args.imc_policy == "frozen" and \
        args.imc_mode else None
    clock = None
    if args.workload != "none":
        from repro.runtime.workload import VirtualClock

        clock = VirtualClock()
    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_serve_mesh, parse_mesh_shape

        try:
            mesh = make_serve_mesh(*parse_mesh_shape(args.mesh))
        except ValueError as e:
            ap.error(str(e))
        log.info("serving over a %s mesh (%d devices visible)", args.mesh,
                 len(jax.devices()))
    engine = Engine(cfg, params, args.batch, cache_len, rng=rng,
                    max_chunk=args.chunk, block_size=args.block,
                    kv_blocks=args.kv_blocks, meter=meter,
                    drift_monitor=monitor, alloc_policy=args.alloc,
                    clock=clock, drift_pause_depth=args.drift_pause_depth,
                    mesh=mesh, prefix_cache=args.prefix_cache)

    if args.workload != "none":
        from repro.launch.metering import format_slo_summary, slo_summary
        from repro.launch.scheduler import PressureController, make_policy
        from repro.runtime import workload as workload_lib

        wcfg = workload_lib.make_overload_config(
            n_requests=args.requests, seed=args.workload_seed,
            overload=args.overload, slots=args.batch, max_new=args.gen,
            arrival=args.workload, prefix_len=args.shared_prefix_len,
            prefix_dup=args.prefix_dup)
        requests = workload_lib.generate(wcfg, cfg.vocab_size)
        policy = make_policy(args.slo_policy)
        controller = None
        if base_pt is not None:
            controller = PressureController(
                engine, substrate_lib.substrate_ladder(base_pt, steps=2))
        finished = serve_slo(engine, requests, policy=policy,
                             controller=controller)
        summary = slo_summary(finished, elapsed=engine.clock.now,
                              policy=policy.name,
                              prefix_hits=engine.prefix_hits,
                              cow_copies=engine.cow_copies)
        summary.update(
            preemptions=engine.preempt_count,
            shed=engine.shed_requests,
            pool_utilization=round(engine.pool_utilization(), 4),
            substrate_swaps=engine.substrate_swaps,
        )
        if controller is not None:
            summary.update(controller.counters())
        print(f"serve_slo [{args.workload} x{args.overload:g} overload, "
              f"policy={policy.name}, alloc={args.alloc}]:")
        print(format_slo_summary(summary))
        return finished

    rnp = np.random.default_rng(0)
    shared_prefix = (rnp.integers(0, cfg.vocab_size, args.shared_prefix_len)
                     if args.shared_prefix_len else None)
    requests = []
    for i in range(args.requests):
        tail = rnp.integers(0, cfg.vocab_size, lens[i % len(lens)])
        prompt = (np.concatenate([shared_prefix, tail])
                  if shared_prefix is not None else tail)
        requests.append(Request(rid=i, prompt=prompt, max_new=args.gen))
    t0 = time.perf_counter()
    if args.inject_drift:
        scale_s, _, after_s = args.inject_drift.partition("@")
        scale, after = float(scale_s), int(after_s or len(requests) // 2)
        serve(engine, requests[:after])

        # mid-workload scale shift on every mlp.wi weight: drifts w_max at
        # mlp.wi and the activation range feeding mlp.wo.  The shift must
        # live in the weights - the model is pre-norm, so an embedding-scale
        # shift would be normalized away before every matmul site
        def _scale_wi(p):
            if isinstance(p, dict):
                return {k: (v * scale if k == "wi" else _scale_wi(v))
                        for k, v in p.items()}
            return p

        engine.params = _scale_wi(engine.params)
        log.info("injected mlp.wi weight-scale drift x%.2f after %d "
                 "requests", scale, after)
        serve(engine, requests[after:])
        finished = engine.finished
    else:
        finished = serve(engine, requests)
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.out) for r in finished)
    tok_s = total_tokens / dt if dt > 0 else float("nan")
    ttfts = [r.ttft for r in finished if r.ttft is not None]
    ttft_ms = 1e3 * float(np.mean(ttfts)) if ttfts else float("nan")
    log.info(
        "served %d requests, %d tokens, %d fused chunks (%d steps), "
        "%d prefill calls (%d rows), %.1f tok/s, mean TTFT %.1f ms, "
        "%d host-transfer bytes, %d KV blocks in pool",
        len(finished), total_tokens, engine.decode_calls,
        engine.decode_steps, engine.prefill_calls, engine.prefill_rows,
        tok_s, ttft_ms, engine.host_transfer_bytes, engine.alloc.num_blocks,
    )
    failed = [r for r in finished if r.error is not None]
    if failed:
        log.warning("%d request(s) finished with an error status: %s",
                    len(failed), [r.rid for r in failed])
    if args.prefix_cache:
        ps = engine.prefix_stats()
        print(f"prefix cache: hit_rate={ps['hit_rate']:.2f} "
              f"({ps['hits']}/{ps['lookups']} admissions), "
              f"prefill tokens skipped={ps['hit_tokens']}, "
              f"billed prefill tokens saved={ps['saved_billed_tokens']}, "
              f"cow_copies={ps['cow_copies']}, "
              f"evictions={ps['evictions']}, "
              f"cached_blocks={ps['cached_blocks']}")
    if monitor is not None:
        c = monitor.counters()
        print(f"online calibration: {c['shadow_samples']} shadow samples / "
              f"{c['chunks_seen']} chunks, {c['drift_checks']} checks, "
              f"{c['drift_events']} drift events, "
              f"{c['calibration_swaps']} hot-swaps "
              f"({engine.swap_count} applied)")
        if monitor.last_report is not None:
            print("last drift report: " + monitor.last_report.summary_line())
        if monitor.last_observed is not None and frozen0 is not None:
            rows = drift_lib.site_snr_table(
                frozen0, engine._calib, monitor.last_observed,
                bx=engine.substrate.imc.bx)
            print("per-site SNR_T (stale frozen vs post-swap vs "
                  "fresh-frozen reference):")
            print(drift_lib.format_snr_table(rows))
    if meter is not None:
        from repro.core.design import optimize
        from repro.launch.metering import format_report, serve_energy_report

        reports = []
        for snr_db in (float(s) for s in args.energy_snr_db.split(",")):
            for kind in ("qs", "qr", "cm"):
                pt = optimize(n=512, snr_t_target_db=snr_db, kinds=(kind,))
                if pt is None:
                    continue
                # bill through the substrate the design point implies: the
                # rollup reads its design from the substrate object itself
                reports.append(serve_energy_report(
                    meter, substrate=substrate_lib.substrate_for_design(pt),
                    generated_tokens=total_tokens, requests=len(finished)))
        print(f"serve-path energy (billed prefill tokens="
              f"{meter.prefill_billed_tokens} of which padding="
              f"{meter.prefill_pad_tokens}, decode tokens="
              f"{meter.decode_billed_tokens}):")
        print(format_report(reports))
        if meter.prefix_saved_billed_tokens:
            print(f"prefix-cache energy savings ("
                  f"{meter.prefix_saved_billed_tokens} billed prefill "
                  f"tokens avoided):")
            for r in reports:
                frac = r.saved_prefill_j / max(
                    r.total_j + r.saved_prefill_j, 1e-30)
                print(f"  {r.design.arch_kind:>4s} @ "
                      f"{r.design.snr_t_db:5.1f} dB: "
                      f"-{r.j_per_token_saved:.3e} J/token "
                      f"({100 * frac:.1f}% of the cold bill)")
    return finished


if __name__ == "__main__":
    main()
