import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS_EXTRA", "")
)
# ^ MUST precede any jax import: jax locks the device count on first init.
#   512 placeholder host devices let jax.make_mesh build the production
#   (2, 16, 16) multi-pod mesh on a single CPU for the dry-run.

"""Multi-pod dry-run (deliverable (e)).

For every (architecture x input shape x mesh) cell:
  * build the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  * build the jit'd step (train_step for train shapes, prefill/decode for
    serve shapes) with full sharding specs,
  * ``.lower()`` against ShapeDtypeStruct stand-ins (no allocation),
  * ``.compile()`` - success proves the distribution config is coherent,
  * record ``memory_analysis()`` (fits-in-HBM proof), ``cost_analysis()``
    (FLOPs/bytes for the roofline), and the per-device collective traffic
    parsed from the post-SPMD HLO text.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --out results/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-9b \
      --shape train_4k --mesh single
"""
import argparse
import json
import re
import time
import traceback
from typing import Dict, Optional

import jax

from repro import configs
from repro.configs.shapes import SHAPES, input_specs, shape_applicable
from repro.launch import hlo_analysis
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_production_mesh
from repro.models import init_cache, init_params

# v5e-ish hardware constants for the roofline (EXPERIMENTS.md SSRoofline)
PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

_COLLECTIVE_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\][^ ]*))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-device collective traffic from post-SPMD HLO: sums the *output*
    bytes of every collective op, per op kind (plus op counts)."""
    out: Dict[str, Dict[str, float]] = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        b = _shape_bytes(shape_str)
        rec = out.setdefault(kind, {"bytes": 0.0, "count": 0})
        rec["bytes"] += b
        rec["count"] += 1
    return out


def collective_wire_bytes(colls: Dict[str, Dict[str, float]]) -> float:
    """Approximate per-device wire traffic: ring all-reduce moves ~2x the
    shard bytes; all-gather/reduce-scatter ~1x the full output/input; a2a and
    permute ~1x."""
    factors = {
        "all-reduce": 2.0,
        "all-gather": 1.0,
        "reduce-scatter": 1.0,
        "all-to-all": 1.0,
        "collective-permute": 1.0,
    }
    return sum(factors[k] * v["bytes"] for k, v in colls.items())


def make_layout_mesh(layout: str):
    """'32x8' -> (data=32, model=8); '2x32x8' -> (pod, data, model).
    Total chips must be 256 (single-pod) or 512 (multi-pod)."""
    dims = tuple(int(x) for x in layout.split("x"))
    axes = ("pod", "data", "model") if len(dims) == 3 else ("data", "model")
    import jax as _jax
    return _jax.make_mesh(dims, axes)


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    fsdp: bool = True,
    extra_tag: str = "",
    layout: str = "",
) -> Dict:
    """Lower + compile one (arch x shape x mesh) cell; returns the record."""
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    rec: Dict = {
        "arch": arch,
        "shape": shape_name,
        "mesh": layout or ("2x16x16" if multi_pod else "16x16"),
        "tag": extra_tag,
        "params": cfg.param_count(),
        "params_active": cfg.param_count(active_only=True),
    }
    skip = shape_applicable(cfg, shape)
    if skip:
        rec["status"] = "skipped"
        rec["reason"] = skip
        return rec

    t0 = time.time()
    mesh = (make_layout_mesh(layout) if layout
            else make_production_mesh(multi_pod=multi_pod))
    sds = input_specs(cfg, shape)
    try:
        if shape.kind == "train":
            bundle = steps_lib.build_train_step(cfg, mesh, sds, fsdp=fsdp)
            state_sds = bundle.state_shapes
            lowered = bundle.step_fn.lower(state_sds, sds)
        elif shape.kind == "prefill":
            bundle = steps_lib.build_prefill_step(cfg, mesh, shape, sds, fsdp=fsdp)
            p_sds = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
            lowered = bundle.step_fn.lower(p_sds, sds)
        else:  # decode
            bundle = steps_lib.build_decode_step(cfg, mesh, shape, sds, fsdp=fsdp)
            p_sds = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
            c_sds = jax.eval_shape(
                lambda: init_cache(cfg, shape.global_batch, shape.seq_len)
            )
            lowered = bundle.step_fn.lower(p_sds, sds, c_sds)
        rec["lower_s"] = round(time.time() - t0, 1)

        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "peak_bytes": int(
                getattr(mem, "peak_memory_in_bytes", 0)
                or getattr(mem, "temp_size_in_bytes", 0)
            ),
        }
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        # NOTE: XLA cost_analysis counts while (scan) bodies ONCE - kept for
        # reference only; the roofline uses the corrected HLO-walk numbers.
        rec["cost_xla_raw"] = {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        }
        hlo = compiled.as_text()
        ana = hlo_analysis.analyze(hlo)
        rec["cost"] = {
            "flops": ana["flops"],
            "dot_bytes": ana["dot_bytes"],
            "dot_bytes_bf16c": ana["dot_bytes_bf16c"],
        }
        rec["collectives"] = {
            k: {"bytes": ana["collective_bytes"][k],
                "count": ana["collective_counts"][k]}
            for k in ana["collective_bytes"]
        }
        rec["collective_wire_bytes"] = hlo_analysis.collective_wire_bytes(
            ana["collective_bytes"]
        ) * ana["collective_bf16c_scale"]
        rec["hlo_lines"] = hlo.count("\n")
        rec["status"] = "ok"

        n_chips = mesh.size
        tokens = shape.global_batch * (
            shape.seq_len if shape.kind in ("train", "prefill") else 1
        )
        mult = 6.0 if shape.kind == "train" else 2.0
        model_flops_per_chip = mult * rec["params_active"] * tokens / n_chips
        rec["model_flops_per_chip"] = model_flops_per_chip
        rec["useful_flops_ratio"] = (
            model_flops_per_chip / ana["flops"] if ana["flops"] else 0.0
        )
        # roofline terms (seconds, per device; HLO quantities are per-device
        # in post-SPMD modules)
        rec["roofline"] = {
            "t_compute_s": ana["flops"] / PEAK_FLOPS,
            "t_memory_s": ana["dot_bytes_bf16c"] / HBM_BW,
            "t_collective_s": rec["collective_wire_bytes"] / ICI_BW,
            "n_chips": n_chips,
        }
    except Exception as e:  # noqa: BLE001
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--layout", default="",
                    help="override mesh, e.g. 32x8 or 2x32x8 (SSPerf)")
    args = ap.parse_args()

    archs = list(configs.ARCH_NAMES) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                mesh_tag = "multi" if multi else "single"
                fname = os.path.join(
                    args.out,
                    f"{arch}__{shape}__{mesh_tag}"
                    + (f"__{args.layout}" if args.layout else "")
                    + (f"__{args.tag}" if args.tag else "")
                    + ".json",
                )
                if os.path.exists(fname):
                    with open(fname) as f:
                        old = json.load(f)
                    if old.get("status") in ("ok", "skipped"):
                        print(f"[cached] {fname}")
                        continue
                rec = run_cell(arch, shape, multi, fsdp=not args.no_fsdp,
                               extra_tag=args.tag, layout=args.layout)
                with open(fname, "w") as f:
                    json.dump(rec, f, indent=1)
                status = rec["status"]
                extra = (
                    f"temp={rec['memory']['temp_bytes']/2**30:.2f}GiB "
                    f"flops={rec['cost']['flops']:.3g} "
                    f"useful={rec['useful_flops_ratio']:.2f} "
                    f"coll={rec['collective_wire_bytes']/2**20:.1f}MiB "
                    f"compile={rec.get('compile_s', 0)}s"
                    if status == "ok"
                    else rec.get("reason", rec.get("error", ""))[:200]
                )
                print(f"[{status}] {arch} {shape} {mesh_tag}: {extra}", flush=True)


if __name__ == "__main__":
    main()
