"""Step builders: jit'd train / prefill / decode steps with full sharding specs.

Parallelism layout (DESIGN.md SS5):
  * TP over ``model``: heads, d_ff, vocab, experts (param specs in
    launch/sharding.py).
  * DP over ``pod`` x ``data``: batch; FSDP - large params additionally shard
    their largest free dim over the DP axes (GSPMD inserts the use-site
    all-gathers), which is what fits dbrx-132b's optimizer state in HBM.
  * SP: activations between blocks are sequence-sharded over ``model``
    (Megatron-SP style; logical axis "act_btd"), which also bounds the
    scan-over-layers backward carry memory.
  * Decode KV caches are sequence-sharded over ``model`` (flash-decode).

Batch dims that do not divide the DP axes (long_500k's batch=1) fall back to
replication automatically.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.configs.shapes import ShapeSpec
from repro.launch import sharding as shd
from repro.launch.mesh import axis_size, dp_axes, dp_size
from repro.models import model as model_lib
from repro.optim import adamw

FSDP_MIN_SIZE = 1 << 20  # only FSDP-shard params with >= 1M elements


# ---------------------------------------------------------------------------
# spec construction
# ---------------------------------------------------------------------------


def _divides(shape_dim: int, mesh: Mesh, names) -> bool:
    names = names if isinstance(names, tuple) else (names,)
    size = int(np.prod([axis_size(mesh, n) for n in names]))
    return size > 1 and shape_dim % size == 0


def _fix_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop sharding on dims that don't divide; try moving 'model' to another
    free dim first (e.g. odd vocab sizes shard d_model instead)."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    for i, e in enumerate(entries):
        if e is None:
            continue
        names = e if isinstance(e, tuple) else (e,)
        size = int(np.prod([axis_size(mesh, n) for n in names]))
        if shape[i] % size != 0:
            entries[i] = None
            # try to relocate to another dim
            for j in range(len(shape)):
                if entries[j] is None and shape[j] % size == 0 and j != i:
                    entries[j] = e
                    break
    return P(*entries)


def _add_fsdp(spec: P, shape, mesh: Mesh) -> P:
    """Shard the largest unsharded dim over the DP axes (FSDP / ZeRO-3)."""
    if int(np.prod(shape)) < FSDP_MIN_SIZE:
        return spec
    dp = dp_axes(mesh)
    if not dp:
        return spec
    entries = list(spec) + [None] * (len(shape) - len(spec))
    dims = sorted(range(len(shape)), key=lambda i: -shape[i])
    for i in dims:
        if entries[i] is None and _divides(shape[i], mesh, dp):
            entries[i] = dp if len(dp) > 1 else dp[0]
            return P(*entries)
    return spec


def param_shardings(params_shapes, mesh: Mesh, fsdp: bool = True):
    """NamedShardings for a param pytree (shapes or arrays)."""

    def visit(path, leaf):
        pstr = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        )
        stacked = pstr.startswith("blocks")
        spec = shd.param_spec(pstr, len(leaf.shape), stacked)
        spec = _fix_spec(spec, leaf.shape, mesh)
        if fsdp:
            spec = _add_fsdp(spec, leaf.shape, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(visit, params_shapes)


def batch_shardings(specs: Dict[str, jax.ShapeDtypeStruct], mesh: Mesh):
    dp = dp_axes(mesh)
    dsize = dp_size(mesh)

    def one(s):
        if s.shape and dsize > 1 and s.shape[0] % dsize == 0:
            return NamedSharding(mesh, P(dp if len(dp) > 1 else dp[0]))
        return NamedSharding(mesh, P())

    return {k: one(v) for k, v in specs.items()}


def cache_shardings(cache_shapes, mesh: Mesh, batch: int):
    """Decode-cache shardings: KV seq-sharded over model; states head-sharded."""
    dp = dp_axes(mesh)
    dsize = dp_size(mesh)
    dpe = dp if len(dp) > 1 else (dp[0] if dp else None)
    shard_b = dsize > 1 and batch % dsize == 0
    bspec = dpe if shard_b else None

    def visit(path, leaf):
        pstr = jax.tree_util.keystr(path)
        nd = len(leaf.shape)
        stacked = "'blocks'" in pstr
        off = 1 if stacked else 0
        ent = [None] * nd
        if nd == 0:
            return NamedSharding(mesh, P())
        if "'k'" in pstr or "'v'" in pstr:
            # (n, B, S, Hkv, hd)
            ent[off + 0] = bspec
            if _divides(leaf.shape[off + 1], mesh, "model"):
                ent[off + 1] = "model"
        elif "'state'" in pstr:
            # (n, B, H, N, P)
            ent[off + 0] = bspec
            if _divides(leaf.shape[off + 1], mesh, "model"):
                ent[off + 1] = "model"
        elif "'conv'" in pstr:
            # (n, B, W, C)
            ent[off + 0] = bspec
            if _divides(leaf.shape[-1], mesh, "model"):
                ent[-1] = "model"
        elif "'h'" in pstr:
            # (n, B, W)
            ent[off + 0] = bspec
            if _divides(leaf.shape[-1], mesh, "model"):
                ent[-1] = "model"
        return NamedSharding(mesh, P(*ent))

    return jax.tree_util.tree_map_with_path(visit, cache_shapes)


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TrainStepBundle:
    step_fn: Callable  # jit'd (state, batch) -> (state, metrics)
    state_shapes: Any
    state_shardings: Any
    batch_shardings: Any
    init_state: Callable  # (key) -> state (sharded)


def make_train_state_shapes(cfg: ArchConfig, opt_cfg: adamw.AdamWConfig):
    p_shapes = jax.eval_shape(
        lambda: model_lib.init_params(jax.random.PRNGKey(0), cfg)
    )
    o_shapes = jax.eval_shape(lambda: adamw.init(_zeros_like_tree(p_shapes)))
    return {"params": p_shapes, "opt": o_shapes,
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def _zeros_like_tree(shapes):
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), shapes
    )


def build_train_step(
    cfg: ArchConfig,
    mesh: Mesh,
    input_sds: Dict[str, jax.ShapeDtypeStruct],
    opt_cfg: adamw.AdamWConfig = adamw.AdamWConfig(),
    total_steps: int = 10_000,
    fsdp: bool = True,
) -> TrainStepBundle:
    sched = adamw.warmup_cosine(opt_cfg.lr, min(100, total_steps // 10 + 1),
                                total_steps)
    state_shapes = make_train_state_shapes(cfg, opt_cfg)
    p_shard = param_shardings(state_shapes["params"], mesh, fsdp)
    state_shardings = {
        "params": p_shard,
        "opt": adamw.OptState(
            m=jax.tree_util.tree_map(lambda s: s, p_shard),
            v=jax.tree_util.tree_map(lambda s: s, p_shard),
            count=NamedSharding(mesh, P()),
        ),
        "step": NamedSharding(mesh, P()),
    }
    b_shard = batch_shardings(input_sds, mesh)
    repl = NamedSharding(mesh, P())

    rules = train_rules(mesh)

    def step_fn(state, batch):
        # rules must bind during *tracing* (which happens at .lower(), after
        # the builder returns), so the context lives inside the traced body
        with shd.axis_rules(mesh, rules):
            return _step_impl(state, batch)

    def _step_impl(state, batch):
        def loss_of(p):
            return model_lib.loss_fn(p, cfg, batch)

        (loss, aux_metrics), grads = jax.value_and_grad(
            loss_of, has_aux=True
        )(state["params"])
        lr = sched(state["step"])
        new_params, new_opt, opt_metrics = adamw.update(
            grads, state["opt"], state["params"], opt_cfg, lr
        )
        metrics = {"loss": loss, "lr": lr, **aux_metrics, **opt_metrics}
        metrics = {k: v.astype(jnp.float32) for k, v in metrics.items()}
        return (
            {"params": new_params, "opt": new_opt, "step": state["step"] + 1},
            metrics,
        )

    with mesh:
        jitted = jax.jit(
            step_fn,
            in_shardings=(state_shardings, b_shard),
            out_shardings=(state_shardings, repl),
            donate_argnums=(0,),
        )

    def init_state(key):
        with mesh:
            return jax.jit(
                lambda k: {
                    "params": model_lib.init_params(k, cfg),
                    "opt": adamw.init(
                        _zeros_like_tree(state_shapes["params"])
                    ),
                    "step": jnp.zeros((), jnp.int32),
                },
                out_shardings=state_shardings,
            )(key)

    return TrainStepBundle(
        step_fn=jitted,
        state_shapes=state_shapes,
        state_shardings=state_shardings,
        batch_shardings=b_shard,
        init_state=init_state,
    )


# ---------------------------------------------------------------------------
# serve: prefill & decode
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ServeStepBundle:
    step_fn: Callable
    param_shardings: Any
    in_shardings: Any
    out_shardings: Any


def build_prefill_step(
    cfg: ArchConfig,
    mesh: Mesh,
    shape: ShapeSpec,
    input_sds: Dict[str, jax.ShapeDtypeStruct],
    fsdp: bool = True,
) -> ServeStepBundle:
    p_shapes = jax.eval_shape(
        lambda: model_lib.init_params(jax.random.PRNGKey(0), cfg)
    )
    p_shard = param_shardings(p_shapes, mesh, fsdp)
    b_shard = batch_shardings(input_sds, mesh)
    cache_shapes = jax.eval_shape(
        lambda: model_lib.init_cache(cfg, shape.global_batch, shape.seq_len)
    )
    c_shard = cache_shardings(cache_shapes, mesh, shape.global_batch)
    repl = NamedSharding(mesh, P())

    rules = train_rules(mesh, backward=False)

    def prefill_fn(params, batch):
        with shd.axis_rules(mesh, rules):
            logits, cache = model_lib.prefill(
                params, cfg, batch["tokens"], cache_len=shape.seq_len,
                prefix_embeds=batch.get("prefix_embeds"),
            )
        return logits, cache

    with mesh:
        jitted = jax.jit(
            prefill_fn,
            in_shardings=(p_shard, b_shard),
            out_shardings=(repl, c_shard),
        )
    return ServeStepBundle(jitted, p_shard, (p_shard, b_shard), (repl, c_shard))


def build_decode_step(
    cfg: ArchConfig,
    mesh: Mesh,
    shape: ShapeSpec,
    input_sds: Dict[str, jax.ShapeDtypeStruct],
    fsdp: bool = True,
) -> ServeStepBundle:
    p_shapes = jax.eval_shape(
        lambda: model_lib.init_params(jax.random.PRNGKey(0), cfg)
    )
    p_shard = param_shardings(p_shapes, mesh, fsdp)
    b_shard = batch_shardings(input_sds, mesh)
    cache_shapes = jax.eval_shape(
        lambda: model_lib.init_cache(cfg, shape.global_batch, shape.seq_len)
    )
    c_shard = cache_shardings(cache_shapes, mesh, shape.global_batch)
    repl = NamedSharding(mesh, P())

    rules = _serve_rules(mesh)

    def decode_fn(params, batch, cache):
        with shd.axis_rules(mesh, rules):
            return model_lib.decode_step(params, cfg, batch["token"], cache)

    with mesh:
        jitted = jax.jit(
            decode_fn,
            in_shardings=(p_shard, b_shard, c_shard),
            out_shardings=(repl, c_shard),
            donate_argnums=(2,),
        )
    return ServeStepBundle(
        jitted, p_shard, (p_shard, b_shard, c_shard), (repl, c_shard)
    )


def _serve_rules(mesh: Mesh):
    """Decode has seq-len 1: activations can't sequence-shard; override
    act_btd to batch-only."""
    rules = shd.activation_rules(mesh)
    dp = dp_axes(mesh)
    rules["act_btd"] = P(dp if len(dp) > 1 else (dp[0] if dp else None), None, None)
    return rules


def train_rules(mesh: Mesh, backward: bool = True):
    """Sequence parallelism between blocks for train/prefill.  ``backward``
    enables the GQA->MHA flash expansion (pays off only when the backward
    pass amplifies carry reshards - see sharding.attn_expand_groups)."""
    rules = shd.activation_rules(mesh)
    dp = dp_axes(mesh)
    dpe = dp if len(dp) > 1 else (dp[0] if dp else None)
    mdl = "model" if "model" in mesh.axis_names else None
    rules["act_btd"] = P(dpe, mdl, None)
    rules["flash_expand_gqa"] = backward
    return rules
