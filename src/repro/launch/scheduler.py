"""Scheduler policies and load-adaptive frontier degradation for the paged
serve engine.

The admission order, the load-shedding decision and the overload response are
POLICY, not engine mechanics - this module makes each a first-class object so
``launch.serve.serve_slo`` can run the same engine under FIFO,
shortest-prompt-first or SLO-deadline scheduling and the bench can compare
them on identical seeded traffic.

Shedding reuses PR 6's graceful per-request degradation contract: a shed
request retires through ``Engine.fail_request`` with a typed
``error_kind="shed"`` status - never an engine death.

:class:`PressureController` is the overload response the paper uniquely
enables: under pressure (queue depth / pool occupancy) it steps the engine
DOWN the committed EDAP frontier (lower B_ADC: less energy and delay per DP,
lower SNR_T - ``core.design.frontier_ladder``), and back up when pressure
clears.  The swap reuses the treedef-keyed zero-recompile machinery
(``Engine.swap_substrate`` keys jit caches on ``Substrate.trace_key``), so
each ladder level compiles once and every subsequent move is a host-side
pointer update.
"""
from __future__ import annotations

import logging
from typing import Dict, List, Optional, Sequence, Type

log = logging.getLogger("repro.scheduler")

_FAR_FUTURE = float("inf")


def _ttft_deadline_abs(req) -> float:
    """Absolute virtual-time TTFT deadline (inf if the request has none)."""
    if req.arrive_at is None or req.ttft_deadline is None:
        return _FAR_FUTURE
    return req.arrive_at + req.ttft_deadline


class SchedulerPolicy:
    """Admission-order + shedding policy over the pending queue.

    ``order`` permutes the queue in place (the engine still admits the FIFO
    prefix of whatever order the policy chose); ``shed`` removes and returns
    the requests to retire with a typed shed status BEFORE admission, so a
    hopeless request never consumes prefill compute.  Stateless by default;
    instances may carry counters."""

    name = "fifo"

    def order(self, queue: List, now: float) -> None:
        return None

    def shed(self, queue: List, now: float) -> List:
        return []


class FIFOPolicy(SchedulerPolicy):
    """Strict arrival order, never sheds - the baseline every other policy
    is measured against."""

    name = "fifo"


class ShortestPromptFirst(SchedulerPolicy):
    """Admit cheap prefills first (classic SJF on the known cost component).
    Stable sort: equal lengths keep arrival order.  Resumed (preempted)
    requests sort by their full effective prompt - they are mid-flight and
    cheap to finish, so they naturally stay near the front."""

    name = "sjf"

    def order(self, queue: List, now: float) -> None:
        queue.sort(key=lambda r: len(r.prompt) + len(r.out))


class DeadlineSLOPolicy(SchedulerPolicy):
    """Earliest-TTFT-deadline-first admission with load shedding.

    Ordering: resumed requests (generation already started - their TTFT is
    already decided) go first to finish and free blocks; fresh requests run
    earliest-deadline-first.  Shedding: a fresh request whose TTFT deadline
    has already passed can no longer meet its SLO no matter what - serving
    it would only steal capacity from requests that still can, so it is
    shed (typed ``error_kind="shed"``, counted, never an engine death)."""

    name = "deadline"

    def __init__(self, slack: float = 0.0):
        # shed only once the deadline is `slack` past due: slack > 0 trades
        # a little wasted work for serving near-miss requests anyway
        self.slack = slack
        self.shed_count = 0

    def order(self, queue: List, now: float) -> None:
        queue.sort(key=lambda r: (-_FAR_FUTURE if r.out
                                  else _ttft_deadline_abs(r)))

    def shed(self, queue: List, now: float) -> List:
        doomed = [r for r in queue
                  if not r.out and now > _ttft_deadline_abs(r) + self.slack]
        for r in doomed:
            queue.remove(r)
        self.shed_count += len(doomed)
        return doomed


POLICIES: Dict[str, Type[SchedulerPolicy]] = {
    FIFOPolicy.name: FIFOPolicy,
    ShortestPromptFirst.name: ShortestPromptFirst,
    DeadlineSLOPolicy.name: DeadlineSLOPolicy,
}


def make_policy(name: str) -> SchedulerPolicy:
    try:
        return POLICIES[name]()
    except KeyError:
        raise ValueError(
            f"unknown scheduler policy {name!r}; have {sorted(POLICIES)}")


class PressureController:
    """Load-adaptive frontier degradation with hysteresis.

    Watches the engine's queue depth and KV pool occupancy each serve-loop
    tick; after ``hold`` consecutive high-pressure ticks it steps one level
    DOWN the substrate ladder (``core.substrate.substrate_ladder`` - lower
    B_ADC, lower energy/delay per DP, lower SNR_T), after ``hold``
    consecutive low-pressure ticks it steps back up.  The engine re-freezes
    each ladder substrate with its own live calibration, so site names (and
    the jit treedef) are preserved; each level compiles once
    (``Substrate.trace_key``-keyed caches) and later moves are pointer
    updates.

    Virtual time: each level's decode step costs its frontier delay ratio
    (``design.delay_per_dp / base.delay_per_dp`` < 1 when degraded), which is
    exactly how stepping down the frontier buys goodput under overload.
    """

    def __init__(self, engine, ladder: Sequence, high: float = 1.0,
                 low: float = 0.25, hold: int = 2):
        if not ladder:
            raise ValueError("need a non-empty substrate ladder")
        if high <= low:
            raise ValueError(f"need high > low (got {high} <= {low})")
        self.engine = engine
        self.ladder = list(ladder)
        base = self.ladder[0].design
        self.time_scales = [
            (s.design.delay_per_dp / base.delay_per_dp
             if (base is not None and s.design is not None) else 1.0)
            for s in self.ladder
        ]
        self.high = high
        self.low = low
        self.hold = hold
        self.level = 0
        self.degrade_steps = 0
        self.upgrade_steps = 0
        self._hot = 0
        self._cool = 0

    def pressure(self) -> float:
        """max(queue depth per slot, KV pool occupancy): either resource
        saturating is pressure."""
        qp = self.engine.queue_depth / max(self.engine.batch_slots, 1)
        cap = self.engine.alloc.num_blocks - 1
        pp = self.engine.alloc.used_count / cap if cap > 0 else 0.0
        return max(qp, pp)

    def update(self) -> int:
        """One serve-loop tick; returns the (possibly new) ladder level."""
        p = self.pressure()
        if p >= self.high:
            self._hot += 1
            self._cool = 0
        elif p <= self.low:
            self._cool += 1
            self._hot = 0
        else:
            self._hot = self._cool = 0
        if self._hot >= self.hold and self.level < len(self.ladder) - 1:
            self.level += 1
            self.degrade_steps += 1
            self._hot = 0
            self._apply("degrade", p)
        elif self._cool >= self.hold and self.level > 0:
            self.level -= 1
            self.upgrade_steps += 1
            self._cool = 0
            self._apply("upgrade", p)
        return self.level

    def _apply(self, direction: str, p: float):
        sub = self.ladder[self.level]
        self.engine.swap_substrate(sub, time_scale=self.time_scales[self.level])
        log.info("pressure %.2f: %s to frontier level %d (b_adc=%s, "
                 "time_scale=%.3f)", p, direction, self.level,
                 getattr(sub.design, "b_adc", None),
                 self.time_scales[self.level])

    def counters(self) -> Dict[str, float]:
        return {
            "level": self.level,
            "degrade_steps": self.degrade_steps,
            "upgrade_steps": self.upgrade_steps,
        }
