"""Launch layer: meshes, sharding rules, steps, dry-run and drivers."""
