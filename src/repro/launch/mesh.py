"""Mesh construction for single-pod and multi-pod deployments.

Production target: TPU v5e-256 pods (16 x 16 chips); multi-pod couples 2 pods
over DCN.  Axes:

  pod    - data parallelism across pods (gradient all-reduce over DCN;
           optionally int8-compressed, see repro.optim.compression)
  data   - data parallelism within a pod (batch sharding, ZeRO-1)
  model  - tensor/expert parallelism (heads, d_ff, vocab, experts, and
           sequence-sharded KV caches for decode)

These are FUNCTIONS (not module constants) so importing never touches jax
device state - jax locks the device count on first backend initialization.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """The dry-run / deployment mesh: (16, 16) or (2, 16, 16)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_host_mesh(model_axis: Optional[int] = None):
    """Best-effort mesh over whatever devices exist (CPU smoke tests, elastic
    restarts after losing hosts): (data, model) with model_axis dividing the
    device count.

    An explicit ``model_axis`` is CLAMPED to the largest divisor of the
    device count that does not exceed it (asking for model=8 on a 1-device
    host yields the trivial (1, 1) mesh, not the degenerate (0, 8) shape the
    unclamped division used to produce)."""
    n = len(jax.devices())
    if model_axis is None:
        model_axis = 1
        for cand in (16, 8, 4, 2):
            if n % cand == 0 and n >= cand:
                model_axis = cand
                break
    else:
        if model_axis < 1:
            raise ValueError(f"model_axis must be >= 1, got {model_axis}")
        model_axis = min(model_axis, n)
        while n % model_axis != 0:
            model_axis -= 1
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))


def parse_mesh_shape(spec: str) -> Tuple[int, int]:
    """Parse an ``RxC`` mesh flag ("1x8" -> (1, 8)): (data, model) axes."""
    parts = spec.lower().split("x")
    if len(parts) != 2:
        raise ValueError(f"mesh spec must be RxC (e.g. 1x8), got {spec!r}")
    try:
        data, model = int(parts[0]), int(parts[1])
    except ValueError:
        raise ValueError(
            f"mesh spec must be RxC with integer axes, got {spec!r}") from None
    if data < 1 or model < 1:
        raise ValueError(f"mesh axes must be >= 1, got {spec!r}")
    return data, model


def make_serve_mesh(data: int, model: int):
    """A (data, model) mesh over the FIRST data*model devices.

    Unlike :func:`make_host_mesh` this takes the requested shape literally
    (the serve engine's sharded jit closures are traced against it), but it
    tolerates the process holding MORE devices than the mesh needs - e.g. a
    (1, 4) serve mesh inside an 8-host-device test process."""
    need = data * model
    devs = jax.devices()
    if need > len(devs):
        raise ValueError(
            f"mesh {data}x{model} needs {need} devices; only "
            f"{len(devs)} available")
    from jax.sharding import Mesh

    return Mesh(np.array(devs[:need]).reshape(data, model),
                ("data", "model"))


def dp_axes(mesh) -> Tuple[str, ...]:
    """The data-parallel axes of a mesh (('pod','data') when multi-pod)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def axis_size(mesh, name: str) -> int:
    if name not in mesh.axis_names:
        return 1
    return mesh.shape[name]


def dp_size(mesh) -> int:
    return int(np.prod([axis_size(mesh, a) for a in dp_axes(mesh)]))
