"""Mesh construction for single-pod and multi-pod deployments.

Production target: TPU v5e-256 pods (16 x 16 chips); multi-pod couples 2 pods
over DCN.  Axes:

  pod    - data parallelism across pods (gradient all-reduce over DCN;
           optionally int8-compressed, see repro.optim.compression)
  data   - data parallelism within a pod (batch sharding, ZeRO-1)
  model  - tensor/expert parallelism (heads, d_ff, vocab, experts, and
           sequence-sharded KV caches for decode)

These are FUNCTIONS (not module constants) so importing never touches jax
device state - jax locks the device count on first backend initialization.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """The dry-run / deployment mesh: (16, 16) or (2, 16, 16)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_host_mesh(model_axis: Optional[int] = None):
    """Best-effort mesh over whatever devices exist (CPU smoke tests, elastic
    restarts after losing hosts): (data, model) with model_axis dividing the
    device count."""
    n = len(jax.devices())
    if model_axis is None:
        model_axis = 1
        for cand in (16, 8, 4, 2):
            if n % cand == 0 and n >= cand:
                model_axis = cand
                break
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))


def dp_axes(mesh) -> Tuple[str, ...]:
    """The data-parallel axes of a mesh (('pod','data') when multi-pod)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def axis_size(mesh, name: str) -> int:
    if name not in mesh.axis_names:
        return 1
    return mesh.shape[name]


def dp_size(mesh) -> int:
    return int(np.prod([axis_size(mesh, a) for a in dp_axes(mesh)]))
