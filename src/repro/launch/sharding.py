"""Sharding rules: logical activation axes + parameter partition specs.

Models annotate activations with logical names via :func:`ws`; the launch layer
activates a rule set (mesh + name -> PartitionSpec) with :func:`axis_rules`.
Outside any rule context the annotations are no-ops, so models run untouched on
a single CPU device (smoke tests).

Parameter sharding is path-based (:func:`param_spec`): TP over the ``model``
axis for heads / d_ff / vocab / experts, replication for small tensors, and an
optional ZeRO-1 extension over the data axes for optimizer state.
"""
from __future__ import annotations

import contextlib
import re
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import axis_size, dp_axes

_ACTIVE: Optional[Tuple[Mesh, Dict[str, P]]] = None


@contextlib.contextmanager
def axis_rules(mesh: Mesh, rules: Optional[Dict[str, P]] = None):
    global _ACTIVE
    old = _ACTIVE
    _ACTIVE = (mesh, rules if rules is not None else activation_rules(mesh))
    try:
        yield
    finally:
        _ACTIVE = old


def ws(x, name: str):
    """with_sharding_constraint by logical name (no-op outside axis_rules)."""
    if _ACTIVE is None:
        return x
    mesh, rules = _ACTIVE
    spec = rules.get(name)
    if spec is None:
        return x
    if x.ndim < len([s for s in spec if s is not None]):
        return x
    # pad spec to rank
    entries = list(spec) + [None] * (x.ndim - len(spec))
    try:
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*entries[: x.ndim]))
        )
    except (ValueError, TypeError):
        return x


def ws_attn(qg, k, v):
    """Flash-attention operand constraints, MQA/GQA-aware.

    qg: (B, S, Hkv, G, hd); k/v: (B, S, Hkv, hd).  Shard KV heads over
    ``model`` when they divide; otherwise (MQA, Hkv < model axis) shard the q
    head-group dim G and replicate the (small) K/V - without this, the
    unsatisfiable Hkv constraint silently no-ops and every model shard computes
    ALL q heads (observed as 16x redundant attention FLOPs on granite-20b;
    EXPERIMENTS.md SSPerf iteration 1)."""
    if _ACTIVE is None:
        return qg, k, v
    mesh, _rules = _ACTIVE
    mdl = axis_size(mesh, "model")
    dp = dp_axes(mesh)
    dpe = dp if len(dp) > 1 else (dp[0] if dp else None)
    hkv, g = qg.shape[2], qg.shape[3]

    def cons(x, spec):
        try:
            return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
        except (ValueError, TypeError):
            return x

    if mdl > 1 and hkv % mdl == 0:
        qg = cons(qg, P(dpe, None, "model", None, None))
        k = cons(k, P(dpe, None, "model", None))
        v = cons(v, P(dpe, None, "model", None))
    elif mdl > 1 and g % mdl == 0:
        qg = cons(qg, P(dpe, None, None, "model", None))
        k = cons(k, P(dpe, None, None, None))
        v = cons(v, P(dpe, None, None, None))
    elif mdl > 1 and hkv >= mdl:
        # non-divisible but hkv >= axis: UNEVEN head sharding (GSPMD pads,
        # worst shard <=2x work) beats replication (musicgen MHA kv=24 on 16
        # regressed 4.8 -> 35 s without this; SSPerf).  For hkv < axis the
        # padding doubles KV compute - leave unconstrained (deepseek case).
        qg = cons(qg, P(dpe, None, "model", None, None))
        k = cons(k, P(dpe, None, "model", None))
        v = cons(v, P(dpe, None, "model", None))
    return qg, k, v


def moe_vmap_axes():
    """spmd_axis_name for the vmapped MoE group dim: the DP axes (groups
    follow batch).  None outside a rules context (single-device tests)."""
    if _ACTIVE is None:
        return None
    mesh, _ = _ACTIVE
    dp = dp_axes(mesh)
    if not dp:
        return None
    return dp if len(dp) > 1 else dp[0]


def attn_expand_groups(hkv: int, g: int) -> bool:
    """True when GQA should expand KV to full q-heads for sharding: Hkv does
    not divide the model axis but Hq = Hkv*G does.  Trades G-fold KV
    replication (one all-gather per layer) for fully-local flash loops.

    Worth it only when a backward pass amplifies the per-iteration carry
    reshards (train); for forward-only prefill the replicated-KV gathers cost
    more than the small carry all-to-alls (SSPerf deepseek iter 1: expansion
    made prefill 6x worse; gated off via the rules flag)."""
    if _ACTIVE is None:
        return False
    mesh, rules = _ACTIVE
    if not rules.get("flash_expand_gqa", False):
        return False
    mdl = axis_size(mesh, "model")
    return mdl > 1 and hkv % mdl != 0 and g % mdl != 0 and (hkv * g) % mdl == 0


def attn_carry_pin(shape_hkv: int, shape_g: int):
    """Returns a pin function for flash-attention scan carries, MQA-aware.

    Handles rank-5 (B, Hkv, G, QB, hd) acc/dq and rank-4 (B, Hkv, G, QB) m/l:
    shard Hkv over ``model`` when divisible, else shard G (MQA).  Unpinned
    carries get resharded by GSPMD on every loop iteration (observed as
    all-to-alls inside the innermost flash loop, 20 TiB/step on granite-20b -
    EXPERIMENTS.md SSPerf iteration 2)."""
    if _ACTIVE is None:
        return lambda x: x
    mesh, _ = _ACTIVE
    mdl = axis_size(mesh, "model")
    dp = dp_axes(mesh)
    dpe = dp if len(dp) > 1 else (dp[0] if dp else None)
    if mdl <= 1:
        return lambda x: x
    if shape_hkv % mdl == 0:
        head_entry, g_entry = "model", None
    elif shape_g % mdl == 0:
        head_entry, g_entry = None, "model"
    elif shape_hkv >= mdl:
        head_entry, g_entry = "model", None  # uneven (see ws_attn fallback)
    else:
        # hkv < axis and nothing divides: pinning forces replication or
        # 2x padding - leave unpinned (SSPerf deepseek iter 2)
        return lambda x: x

    def pin(x):
        ent = [dpe, head_entry, g_entry] + [None] * (x.ndim - 3)
        try:
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(*ent[: x.ndim]))
            )
        except (ValueError, TypeError):
            return x

    return pin


def attn_grad_spec(shape_hkv: int, shape_g: int):
    """Matching spec names for flash-bwd carriers (see ws_attn)."""
    if _ACTIVE is None:
        return None
    mesh, _ = _ACTIVE
    mdl = axis_size(mesh, "model")
    dp = dp_axes(mesh)
    dpe = dp if len(dp) > 1 else (dp[0] if dp else None)
    if mdl > 1 and (shape_hkv % mdl == 0 or shape_hkv >= mdl):
        return mesh, P(dpe, None, "model", None)  # uneven ok (see ws_attn)
    if mdl > 1:
        return None  # hkv < axis: leave dk/dv layout to GSPMD
    return None


def activation_rules(mesh: Mesh) -> Dict[str, P]:
    dp = dp_axes(mesh)
    mdl = "model" if "model" in mesh.axis_names else None
    return {
        # (batch, time, d_model)
        "act_btd": P(dp, None, None),
        # (batch, time, d_ff) / gated hidden
        "act_btf": P(dp, None, mdl),
        # (batch, time, heads, head_dim)
        "act_bthd": P(dp, None, mdl, None),
        # logits (batch, time, vocab)
        "act_btv": P(dp, None, mdl),
        # decode KV cache (batch, seq, kv_heads, head_dim): sequence-sharded
        # over the model axis => distributed flash-decode softmax (DESIGN SS5)
        "kv_bshd": P(dp, mdl, None, None),
        # paged-pool gather view (batch, seq, kv_heads, head_dim): HEAD-sharded
        # to match the serve engine's head-sharded KV pools, so the pool[bt]
        # gather stays local per shard (the serve engine overrides this to P()
        # when Hkv does not divide the model axis and the pools are replicated)
        "paged_kv_bshd": P(dp, None, mdl, None),
        # flash-attention internals (full-seq, heads on model)
        "attn_kv": P(dp, None, mdl, None),  # (B, S, Hkv, hd)
        "attn_q": P(dp, None, mdl, None, None),  # (B, S, Hkv, G, hd)
        "attn_acc": P(dp, mdl, None, None, None),  # (B, Hkv, G, QB, hd)
        # ssm state (batch, heads, head_dim, state)
        "ssm_state": P(dp, mdl, None, None),
        # rglru hidden (batch, width)
        "act_bw": P(dp, mdl),
        # MoE buffers
        "moe_gec": P(dp, None, mdl),  # dispatch/combine (groups, g, E, c)->pad
        "moe_ecd": P(None, mdl, None, None),  # (groups, E, c, d) expert-major
        "moe_ecf": P(mdl, None, None),  # (E, c, d) expert-major buffers
        # grouped tokens (n_groups, g, d): groups follow batch (dp), tokens
        # within a group follow the SP seq sharding - matches the (B, S, d)
        # residual layout exactly when group_size == seq_len.  Routing is
        # vmapped over groups (lax.map would dynamic-slice the sharded groups
        # dim and all-gather everything - SSPerf dbrx iters 1-4)
        "moe_gxd": P(dp, mdl, None),
        # flat per-slot tensors (g*k, d)/(g*k, E): seq-sharded rows so the
        # dispatch scatter lowers to the token->expert all-to-all
        "moe_td": P(mdl, None),
        "moe_ge": P(mdl, None),
    }


def kv_head_partition(hkv: int, n: int) -> list:
    """Per-shard-group KV head ranges for head-sharded paged pools.

    Returns ``n`` contiguous ``(start, stop)`` half-open ranges partitioning
    ``range(hkv)``: every head lands in exactly one shard group (no loss, no
    overlap; hypothesis-pinned in tests/test_serve_sharded.py).  The block
    table and BlockAllocator stay WHOLE per shard group - only the head axis
    of the ``(num_blocks, block, Hkv, hd)`` pools is split.

    Raises ValueError when ``hkv`` does not divide evenly over ``n`` shards:
    uneven head padding would silently change per-device KV accounting, so
    callers must fall back to replicated pools explicitly instead.
    """
    if n < 1 or hkv < 1:
        raise ValueError(f"need hkv >= 1 and n >= 1, got hkv={hkv}, n={n}")
    if hkv % n != 0:
        raise ValueError(
            f"{hkv} KV heads do not partition over {n} shard groups "
            f"({hkv} % {n} != 0); replicate the pools instead")
    per = hkv // n
    return [(i * per, (i + 1) * per) for i in range(n)]


# ---------------------------------------------------------------------------
# parameter specs by path
# ---------------------------------------------------------------------------

_PARAM_RULES = [
    # (regex on joined path, spec WITHOUT the stacked-layer leading axis)
    # NOTE: first match wins - expert rules MUST precede the generic matmul
    # rules (a mis-ordering here sharded expert weights on d_model instead of
    # the expert dim; caught by tests/test_sharding_rules.py)
    (r"experts/.*(wi|wg)$", P("model", None, None)),  # (E, d, f)
    (r"experts/.*wo$", P("model", None, None)),  # (E, f, d)
    (r"router", P(None, "model")),  # (d, E)
    (r"embed", P("model", None)),  # (vocab, d)
    (r"pos_table", P(None, "model")),  # (max_seq, d)
    (r"lm_head", P(None, "model")),  # (d, vocab)
    (r"(wq|wk|wv)$", P(None, "model")),  # (d, heads*hd)
    (r"wo$", P("model", None)),  # (heads*hd, d) / (f, d)
    (r"(wi|wg)$", P(None, "model")),  # (d, f)
    (r"in_proj$", P(None, "model")),  # ssm (d, inner+...)
    (r"out_proj$", P("model", None)),  # ssm (inner, d)
    (r"(conv_w|conv_b|A_log|dt_bias|D)$", P("model")),  # ssm per-channel
    (r"(rg_x|rg_gate)$", P(None, "model")),  # rglru (d, w)
    (r"rg_out$", P("model", None)),  # (w, d)
    (r"(rg_a|rg_input_gate_w|rg_rec_gate_w)$", P("model")),
    (r"(scale|bias)$", P(None)),  # norms
]


def param_spec(path: str, ndim: int, stacked: bool) -> P:
    """PartitionSpec for a parameter at `path` (slash-joined), with `stacked`
    True when the leading axis is the scan-over-layers axis."""
    base = None
    for pat, spec in _PARAM_RULES:
        if re.search(pat, path):
            base = spec
            break
    if base is None:
        base = P()
    entries = list(base)
    if stacked:
        entries = [None] + entries
    # pad/trim to rank
    entries = (entries + [None] * ndim)[:ndim]
    return P(*entries)


def tree_param_specs(params, stacked_prefixes: Tuple[str, ...] = ("blocks",)):
    """Map a param pytree to PartitionSpecs by path."""

    def visit(path, leaf):
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        stacked = any(pstr.startswith(p) for p in stacked_prefixes)
        return param_spec(pstr, jnp.ndim(leaf), stacked)

    return jax.tree_util.tree_map_with_path(visit, params)


def tree_shardings(mesh: Mesh, specs):
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs)


def validate_divisibility(params, specs, mesh) -> list:
    """Returns a list of (path, shape, spec) where a sharded dim does not divide
    evenly - these fall back to replication (GSPMD would pad; we prefer
    explicitness)."""
    issues = []

    def visit(path, leaf, spec):
        for dim, entry in enumerate(spec):
            if entry is None:
                continue
            names = entry if isinstance(entry, tuple) else (entry,)
            size = int(np.prod([axis_size(mesh, n) for n in names]))
            if leaf.shape[dim] % size != 0:
                issues.append((jax.tree_util.keystr(path), leaf.shape, spec))
                return

    jax.tree_util.tree_map_with_path(visit, params, specs)
    return issues


def fallback_replicate(specs, issues_paths):
    """Replace specs at problematic paths with full replication."""

    def visit(path, spec):
        if jax.tree_util.keystr(path) in issues_paths:
            return P()
        return spec

    return jax.tree_util.tree_map_with_path(visit, specs)
