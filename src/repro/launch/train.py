"""End-to-end training driver (runs for real on CPU at reduced scale; the
production path is identical modulo mesh shape).

  PYTHONPATH=src python -m repro.launch.train --arch gemma2-9b --smoke \
      --steps 50 --batch 8 --seq 128

Wires together: config registry -> mesh -> sharded train step (launch/steps)
-> deterministic data pipeline (repro.data) -> fault-tolerant loop
(repro.runtime) -> atomic checkpoints (repro.checkpoint).
"""
from __future__ import annotations

import argparse
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs.shapes import ShapeSpec, input_specs
from repro.data import DataConfig, make_source
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_host_mesh
from repro.optim import adamw
from repro.runtime import FaultConfig, TrainLoopRunner

log = logging.getLogger("repro.train")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(configs.ARCH_NAMES))
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--corpus", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--imc-mode", default=None,
                    choices=[None, "fakequant", "imc_analytic"],
                    help="noise-aware training through the IMC layer")
    args = ap.parse_args(argv)

    logging.basicConfig(level=logging.INFO)
    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    if args.imc_mode:
        from repro.core.imc_linear import IMCConfig
        from repro.core.substrate import as_substrate

        # dynamic-policy substrate: per-batch quantizer stats keep STE
        # gradients tracking the live activation ranges (training parity)
        cfg = cfg.replace(
            imc=as_substrate(IMCConfig(mode=args.imc_mode, bx=7, bw=7)))

    mesh = make_host_mesh()
    shape = ShapeSpec("cli", args.seq, args.batch, "train")
    sds = input_specs(cfg, shape)
    opt_cfg = adamw.AdamWConfig(lr=args.lr)
    bundle = steps_lib.build_train_step(cfg, mesh, sds, opt_cfg,
                                        total_steps=args.steps)

    data_cfg = DataConfig(
        seed=0, vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, corpus_path=args.corpus,
    )
    source = make_source(data_cfg)

    def batch_fn(step: int):
        b = source.batch(step)
        out = {"tokens": jnp.asarray(b["tokens"])}
        if cfg.modality == "vlm":
            out["prefix_embeds"] = jnp.zeros(
                (args.batch, cfg.prefix_len, cfg.d_model), jnp.bfloat16
            )
        return out

    t_hist = []

    def step_fn(state, batch):
        t0 = time.perf_counter()
        state, metrics = bundle.step_fn(state, batch)
        metrics["loss"].block_until_ready()
        t_hist.append(time.perf_counter() - t0)
        step = int(state["step"])
        if step % args.log_every == 0:
            log.info(
                "step %d loss=%.4f lr=%.2e gnorm=%.3f %.0fms",
                step, float(metrics["loss"]), float(metrics["lr"]),
                float(metrics["grad_norm"]), 1000 * t_hist[-1],
            )
        return state, metrics

    runner = TrainLoopRunner(
        step_fn=step_fn,
        init_state_fn=lambda: bundle.init_state(jax.random.PRNGKey(0)),
        batch_fn=batch_fn,
        cfg=FaultConfig(ckpt_dir=args.ckpt_dir, save_every=args.save_every),
    )
    runner.install_preemption_handler()
    state, history = runner.run(args.steps)
    losses = history["loss"]
    log.info(
        "done: %d steps, loss %.4f -> %.4f, median step %.0fms, restarts=%d",
        len(losses), losses[0] if losses else float("nan"),
        losses[-1] if losses else float("nan"),
        1000 * float(np.median(t_hist)) if t_hist else -1,
        history["restarts"],
    )
    return state, history


if __name__ == "__main__":
    main()
