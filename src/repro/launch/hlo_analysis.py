"""Post-SPMD HLO analysis: correct per-device FLOPs / traffic / collectives.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, regardless of
trip count (verified experimentally - see EXPERIMENTS.md SSRoofline
methodology), which under-counts scan-over-layers models by ~n_layers.  This
module re-derives the quantities by walking the HLO computation graph:

  * per-computation symbol tables resolve operand shapes (operands print as
    bare %names in modern HLO),
  * ``while`` ops multiply body+condition costs by the trip count, taken from
    ``backend_config known_trip_count`` (fallback: max constant in the
    condition computation),
  * ``call``/``fusion``/``conditional`` recurse (conditional: max branch),
  * FLOPs: 2 * |out| * prod(contracting dims) per dot; convolutions via
    |out| * |kernel|,
  * dot_bytes: operand+output bytes of dots (MXU-stream traffic proxy),
  * collectives: output bytes + op counts per collective kind.

All quantities are per-device (the module is the post-partitioning program).
Validated in tests/test_hlo_analysis.py against hand-counted modules.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 0.5, "u4": 0.5, "c64": 8, "c128": 16,
}

_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_LINE = re.compile(r"^\s+(?:ROOT )?%?([\w.\-]+) = (.+?) ([a-z0-9\-]+)\((.*)$")
_OPERANDS = re.compile(r"%([\w.\-]+)")


def _shape_list(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _bytes_of(shapes) -> float:
    total = 0.0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _elems_of(dims: List[int]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    dot_bytes: float = 0.0
    dot_bytes_f32: float = 0.0  # f32 share (CPU-host bf16->f32 dot promotion)
    collective_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    collective_bytes_f32: float = 0.0
    collective_counts: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, other: "Costs", mult: float = 1.0):
        self.flops += mult * other.flops
        self.dot_bytes += mult * other.dot_bytes
        self.dot_bytes_f32 += mult * other.dot_bytes_f32
        self.collective_bytes_f32 += mult * other.collective_bytes_f32
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] = self.collective_bytes.get(k, 0.0) + mult * v
        for k, v in other.collective_counts.items():
            self.collective_counts[k] = (
                self.collective_counts.get(k, 0.0) + mult * v
            )


_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


@dataclasses.dataclass
class _Op:
    name: str
    result: str  # result shape text
    kind: str
    rest: str  # args + attributes text


class HloModule:
    def __init__(self, hlo_text: str):
        self.comps: Dict[str, List[_Op]] = {}
        self.entry: Optional[str] = None
        self._split(hlo_text)
        self._cost_cache: Dict[str, Costs] = {}

    def _split(self, text: str):
        cur_name: Optional[str] = None
        cur_ops: List[_Op] = []
        for line in text.splitlines():
            if line and not line[0].isspace() and "(" in line and "{" in line:
                m = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(", line)
                if m:
                    if cur_name:
                        self.comps[cur_name] = cur_ops
                    cur_name, cur_ops = m.group(2), []
                    if m.group(1):
                        self.entry = cur_name
                    continue
            if cur_name is None:
                continue
            if line.startswith("}"):
                self.comps[cur_name] = cur_ops
                cur_name, cur_ops = None, []
                continue
            m = _OP_LINE.match(line)
            if m:
                name, result, kind, rest = m.groups()
                cur_ops.append(_Op(name, result, kind, rest))
        if cur_name:
            self.comps[cur_name] = cur_ops
        if self.entry is None and self.comps:
            self.entry = max(self.comps, key=lambda k: len(self.comps[k]))

    # ------------------------------------------------------------------
    def _symtab(self, name: str) -> Dict[str, str]:
        return {op.name: op.result for op in self.comps.get(name, [])}

    @staticmethod
    def _trip_count_of(op: _Op, cond_lookup) -> float:
        m = re.search(r'known_trip_count[^0-9]*"n":"(\d+)"', op.rest)
        if m:
            return float(m.group(1))
        cm = re.search(r"condition=%?([\w.\-]+)", op.rest)
        if cm:
            consts = cond_lookup(cm.group(1))
            if consts:
                return float(max(consts))
        return 1.0

    def _cond_consts(self, cond_name: str) -> List[int]:
        out = []
        for op in self.comps.get(cond_name, []):
            for m in re.finditer(r"constant\((\d+)\)", op.rest):
                out.append(int(m.group(1)))
            if op.kind == "constant":
                m = re.search(r"\((\d+)\)", "(" + op.rest)
                if m:
                    out.append(int(m.group(1)))
        return out

    # ------------------------------------------------------------------
    def comp_cost(self, name: str) -> Costs:
        if name in self._cost_cache:
            return self._cost_cache[name]
        self._cost_cache[name] = Costs()  # cycle guard
        total = Costs()
        symtab = self._symtab(name)
        for op in self.comps.get(name, []):
            if op.kind == "dot":
                args = op.rest.split("), ")[0]
                opnames = _OPERANDS.findall(args)
                out_shapes = _shape_list(op.result)
                out_elems = sum(_elems_of(d) for _, d in out_shapes)
                k = 1
                cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
                if cm and opnames:
                    lhs_shape = _shape_list(symtab.get(opnames[0], ""))
                    if lhs_shape:
                        dims = lhs_shape[0][1]
                        for idx in cm.group(1).split(","):
                            if idx and int(idx) < len(dims):
                                k *= dims[int(idx)]
                total.flops += 2.0 * out_elems * k
                opb = sum(
                    _bytes_of(_shape_list(symtab.get(o, ""))) for o in opnames[:2]
                )
                b = _bytes_of(out_shapes) + opb
                total.dot_bytes += b
                f32b = _bytes_of([sh for sh in out_shapes if sh[0] == "f32"])
                for o in opnames[:2]:
                    f32b += _bytes_of(
                        [sh for sh in _shape_list(symtab.get(o, ""))
                         if sh[0] == "f32"]
                    )
                total.dot_bytes_f32 += f32b
            elif op.kind == "convolution":
                out_shapes = _shape_list(op.result)
                out_elems = sum(_elems_of(d) for _, d in out_shapes)
                opnames = _OPERANDS.findall(op.rest.split("), ")[0])
                kern = _shape_list(symtab.get(opnames[1], "")) if len(opnames) > 1 else []
                k_elems = _elems_of(kern[0][1]) if kern else 1
                total.flops += 2.0 * out_elems * k_elems
                total.dot_bytes += _bytes_of(out_shapes)
            elif op.kind in _COLLECTIVES or (
                op.kind.endswith("-start") and op.kind[:-6] in _COLLECTIVES
            ):
                key = op.kind[:-6] if op.kind.endswith("-start") else op.kind
                shapes = _shape_list(op.result)
                b = _bytes_of(shapes)
                total.collective_bytes[key] = (
                    total.collective_bytes.get(key, 0.0) + b
                )
                total.collective_bytes_f32 += _bytes_of(
                    [sh for sh in shapes if sh[0] == "f32"]
                )
                total.collective_counts[key] = (
                    total.collective_counts.get(key, 0.0) + 1
                )
            elif op.kind == "while":
                bm = re.search(r"body=%?([\w.\-]+)", op.rest)
                if bm:
                    trip = self._trip_count_of(op, self._cond_consts)
                    total.add(self.comp_cost(bm.group(1)), trip)
            elif op.kind in ("call", "fusion", "async-start"):
                tm = re.search(r"(?:to_apply|calls)=%?([\w.\-]+)", op.rest)
                if tm:
                    total.add(self.comp_cost(tm.group(1)))
            elif op.kind == "conditional":
                names = []
                bm = re.search(r"branch_computations=\{([^}]*)\}", op.rest)
                if bm:
                    names = [x.strip().lstrip("%") for x in bm.group(1).split(",")]
                else:
                    for key in ("true_computation", "false_computation"):
                        mm = re.search(key + r"=%?([\w.\-]+)", op.rest)
                        if mm:
                            names.append(mm.group(1))
                costs = [self.comp_cost(n) for n in names if n in self.comps]
                if costs:
                    total.add(max(costs, key=lambda c: c.flops + c.dot_bytes))
        self._cost_cache[name] = total
        return total

    def entry_cost(self) -> Costs:
        return self.comp_cost(self.entry)


def analyze(hlo_text: str) -> Dict:
    mod = HloModule(hlo_text)
    c = mod.entry_cost()
    total_coll = sum(c.collective_bytes.values())
    return {
        "flops": c.flops,
        "dot_bytes": c.dot_bytes,
        # CPU-host lowering promotes bf16 dot operands (and the collectives
        # on them) to f32; on the TPU target these tensors are bf16.  The
        # corrected figures halve the f32 share (exact for all-bf16 programs;
        # see EXPERIMENTS.md SSRoofline methodology).
        "dot_bytes_bf16c": c.dot_bytes - 0.5 * c.dot_bytes_f32,
        "collective_bytes": dict(c.collective_bytes),
        "collective_bytes_f32": c.collective_bytes_f32,
        "collective_bf16c_scale": (
            (total_coll - 0.5 * c.collective_bytes_f32) / total_coll
            if total_coll else 1.0
        ),
        "collective_counts": dict(c.collective_counts),
    }


def collective_wire_bytes(collective_bytes: Dict[str, float]) -> float:
    """Per-device wire traffic: ring all-reduce ~2x shard bytes; others ~1x."""
    factors = {
        "all-reduce": 2.0,
        "all-gather": 1.0,
        "reduce-scatter": 1.0,
        "all-to-all": 1.0,
        "collective-permute": 1.0,
    }
    return sum(factors.get(k, 1.0) * v for k, v in collective_bytes.items())
