"""Serve-path energy-delay metering: roll real serving traffic up to the
paper's energy-delay-accuracy metrics.

The serve engine (``launch.serve.Engine``) reports tok/s and KV bytes; the
paper's headline result is an energy-delay frontier over QS/QR/CM design
points.  This module is the missing link: a :class:`DPMeter` counts the
dot-product work the engine admits - per phase (prefill vs decode) and per
matmul site - and :func:`serve_energy_report` multiplies those counts by a
``core.design`` design point's ``energy_per_dp`` / ``delay_per_dp`` to report
J/token, J/request, EDP/token and compute-model tok/s.

Metering costs nothing on device: every count is a pure function of the
host-side call arguments the engine already computes (the admitted
``(R, bucket)`` of each batched prefill and the ``(active, T)`` of each fused
decode chunk), so the fused-scan and one-``(slots, T)``-block transfer
contracts are untouched.

Billing policy (pinned by ``tests/test_metering.py``; documented in ROADMAP):

  * prefill bucket padding IS billed: an admitted row executes the full
    ``(bucket,)``-token matmul sequence regardless of its true length - pad
    positions occupy real bank conversions;
  * dummy pow2-R pad rows are NOT billed: they exist only to stabilize the
    jit compile key and their outputs are dropped before any bank would be
    scheduled for them;
  * decode bills ACTIVE slots only: inactive rows in the fused scan are a
    batching artifact (their writes go to the garbage block), not work a
    deployed accelerator must schedule.

The per-site shapes walk is shared with ``benchmarks/model_energy`` and
``launch.breakdown`` (``core.mapping.per_token_matmul_shapes``), and the
per-token energy/delay math is shared with ``core.design.workload_metrics``
- one code path, so serve-side and training-side accounting cannot silently
double-count a site.

Billing is substrate-first: the engine records the
``core.substrate.Substrate`` it executes on the meter, and
:func:`serve_energy_report` accepts a substrate whose (possibly per-site)
design points price each matmul site - the design point billed is the one
the substrate object actually carries, not a parallel flag.  The legacy
``design=`` argument remains as the uniform-design special case.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence

from repro.core import design as design_lib
from repro.core.design import DesignPoint
from repro.core.mapping import MatmulShape, per_token_matmul_shapes
from repro.core.substrate import Substrate


class DPMeter:
    """Counts billed token-forwards (and thus dot-product evaluations) per
    phase and per matmul site for a served workload.

    The engine calls :meth:`note_prefill` once per batched ``(R, bucket)``
    prefill group and :meth:`note_decode` once per fused decode chunk; both
    are O(1) host-side integer updates.
    """

    def __init__(self, cfg=None, sites: Optional[Sequence[MatmulShape]] = None,
                 substrate: Optional[Substrate] = None):
        if sites is None:
            if cfg is None:
                raise ValueError("need a model config or an explicit site list")
            sites = per_token_matmul_shapes(cfg)
        self.sites: List[MatmulShape] = list(sites)
        # the substrate whose matmuls this meter counted: the serve engine
        # stamps its own substrate here at construction, so the rollup knows
        # what actually ran without any parallel flag plumbing
        self.substrate: Optional[Substrate] = substrate
        # prefill: billed = admitted rows x bucket (pad rows excluded)
        self.prefill_billed_tokens = 0
        self.prefill_true_tokens = 0
        self.prefill_groups = 0
        self.prefill_rows = 0
        # decode: billed = active rows x scan length
        self.decode_billed_tokens = 0
        self.decode_chunks = 0
        # robustness / online-calibration counters (engine hook points; all
        # O(1) host-side, same contract as the prefill/decode notes)
        self.shadow_samples = 0
        self.drift_checks = 0
        self.drift_events = 0
        self.calibration_swaps = 0
        self.failed_requests = 0
        self.drift_reports: List[dict] = []
        # overload-resilience counters (same O(1) host-side contract)
        self.shed_requests = 0
        self.preemptions = 0
        self.substrate_swaps = 0
        # prefix-sharing counters: a hit admission bills only its uncached
        # suffix; ``prefix_saved_billed_tokens`` is the billed prefill work a
        # cold admission of the same request WOULD have executed minus what
        # the warm one did - the tokens whose dot-product energy the cache
        # avoided outright (priced by serve_energy_report)
        self.prefix_lookups = 0
        self.prefix_hits = 0
        self.prefix_hit_tokens = 0
        self.prefix_saved_billed_tokens = 0
        self.cow_copies = 0
        # tensor-parallel provenance: the sharded engine stamps its mesh and
        # per-device KV pool capacity so energy/bench rollups can report the
        # per-device footprint next to the billed work
        self.mesh_shape: Optional[str] = None
        self.mesh_devices = 1
        self.kv_pool_bytes_per_device = 0

    # -- engine hook points ---------------------------------------------------
    def note_mesh(self, mesh_shape: Optional[str], devices: int,
                  kv_pool_bytes_per_device: int = 0):
        """The engine serves over a device mesh: record its ``RxC`` shape,
        device count, and structural per-device KV pool capacity (head-sharded
        pools carry 1/model_axis of the bytes on each device)."""
        self.mesh_shape = mesh_shape
        self.mesh_devices = devices
        self.kv_pool_bytes_per_device = kv_pool_bytes_per_device

    def note_shadow_sample(self):
        """One chunk / prefill group ran with shadow calibration recording."""
        self.shadow_samples += 1

    def note_drift_report(self, report: dict):
        """One drift-detector check ran; ``report`` is the structured
        ``runtime.drift.DriftReport.to_dict()`` payload."""
        self.drift_checks += 1
        if report.get("drifted"):
            self.drift_events += 1
        self.drift_reports.append(report)

    def note_swap(self):
        """The engine hot-swapped a refreshed calibration."""
        self.calibration_swaps += 1

    def note_request_failure(self):
        """One request retired with a per-request error status."""
        self.failed_requests += 1

    def note_shed(self):
        """The scheduler shed one request (typed per-request status)."""
        self.shed_requests += 1

    def note_preemption(self):
        """One mid-generation recompute-preemption (blocks freed, request
        re-queued with its generated tokens)."""
        self.preemptions += 1

    def note_substrate_swap(self, substrate: Optional[Substrate] = None):
        """The engine hot-swapped its execution substrate (frontier
        degradation step).  Energy rollups keep billing the substrate stamped
        on the meter - a mixed-level workload is billed at whichever level
        the report reads, which the serve_slo record states explicitly."""
        self.substrate_swaps += 1

    def drift_summary(self) -> Optional[dict]:
        """Structured rollup of the online-calibration activity this meter
        observed (None if the workload ran without a drift monitor)."""
        if not (self.shadow_samples or self.drift_checks
                or self.calibration_swaps):
            return None
        return {
            "shadow_samples": self.shadow_samples,
            "drift_checks": self.drift_checks,
            "drift_events": self.drift_events,
            "calibration_swaps": self.calibration_swaps,
            "failed_requests": self.failed_requests,
            "last_report": self.drift_reports[-1] if self.drift_reports
            else None,
        }
    def note_prefix_admission(self, suffix_billed: int, cold_bucket: int,
                              hit_tokens: int):
        """One prefix-HIT admission: ``suffix_billed`` token-forwards of
        suffix prefill actually ran (teacher-forced decode steps - no bucket
        padding, one row), against the ``cold_bucket`` a cold admission
        would have billed; ``hit_tokens`` prompt positions were served from
        cached blocks without any dot-product work."""
        self.prefill_billed_tokens += suffix_billed
        self.prefill_true_tokens += suffix_billed
        self.prefill_groups += 1
        self.prefill_rows += 1
        self.prefix_lookups += 1
        self.prefix_hits += 1
        self.prefix_hit_tokens += hit_tokens
        self.prefix_saved_billed_tokens += max(0, cold_bucket - suffix_billed)

    def note_prefix_miss(self):
        """One cold admission under an enabled prefix cache (its blocks are
        now indexed for future sharers)."""
        self.prefix_lookups += 1

    def note_cow_copy(self):
        """One copy-on-write block copy (a write landed in a shared block)."""
        self.cow_copies += 1

    def note_prefill(self, r_real: int, bucket: int,
                     true_lens: Optional[Sequence[int]] = None):
        """One admitted prefill group: ``r_real`` real rows (pow2 pad rows
        excluded), each billed for the full ``bucket`` positions."""
        self.prefill_billed_tokens += r_real * bucket
        if true_lens is not None:
            self.prefill_true_tokens += int(sum(true_lens))
        self.prefill_groups += 1
        self.prefill_rows += r_real

    def note_decode(self, n_active: int, n_steps: int):
        """One fused decode chunk: ``n_active`` live slots each execute
        ``n_steps`` token-forwards."""
        self.decode_billed_tokens += n_active * n_steps
        self.decode_chunks += 1

    # -- derived counts -------------------------------------------------------
    @property
    def billed_tokens(self) -> int:
        return self.prefill_billed_tokens + self.decode_billed_tokens

    @property
    def prefill_pad_tokens(self) -> int:
        """Billed-but-useless bucket-padding positions."""
        return self.prefill_billed_tokens - self.prefill_true_tokens

    def site_triples(self):
        """``(k, m, calls)`` triples (the ``core.design.workload_metrics``
        workload format)."""
        return [(s.k, s.m, s.calls) for s in self.sites]

    def dp_counts(self, phase: str = "total", rows: int = 512) -> Dict[str, float]:
        """Dot-product evaluations per matmul site for ``phase`` ("prefill" |
        "decode" | "total"), with DP dimensions tiled onto ``rows``-row banks
        (``ceil(k / rows)`` bank DPs per output column)."""
        tokens = {
            "prefill": self.prefill_billed_tokens,
            "decode": self.decode_billed_tokens,
            "total": self.billed_tokens,
        }[phase]
        return {
            s.name: tokens * s.calls * s.m * math.ceil(s.k / rows)
            for s in self.sites
        }


# ---------------------------------------------------------------------------
# rollup: meter counts x design point -> the paper's serving metrics
# ---------------------------------------------------------------------------


def energy_for_tokens(sites, design: DesignPoint, tokens: float) -> dict:
    """Energy/delay of ``tokens`` token-forwards over ``sites`` at ``design``.

    THE shared rollup helper: ``launch.breakdown`` (training/profiling side)
    and :func:`serve_energy_report` (serve side) both call it, so one full
    forward is costed identically no matter which path bills it.  ``sites``
    may be :class:`MatmulShape` objects or ``(k, m, calls)`` triples.
    """
    triples = [
        (s.k, s.m, s.calls) if isinstance(s, MatmulShape) else tuple(s)
        for s in sites
    ]
    per_tok = design_lib.workload_metrics(design, triples)
    return {
        "energy_j": tokens * per_tok["energy_per_token_j"],
        "energy_per_token_j": per_tok["energy_per_token_j"],
        "delay_per_token_s": per_tok["delay_per_token_s"],
        "edp_per_token": per_tok["edp_per_token"],
    }


def substrate_energy_for_tokens(sites: Sequence[MatmulShape],
                                substrate: Substrate, tokens: float) -> dict:
    """Like :func:`energy_for_tokens`, but each site is billed at the design
    point the SUBSTRATE assigns to it (``Substrate.design_for_site``), so
    MPC-style per-site overrides - e.g. the output head at a higher B_ADC -
    price exactly the hardware they describe.  With no per-site overrides
    this reduces to ``energy_for_tokens(sites, substrate.design, tokens)``
    exactly (same additions in the same site order)."""
    energy = 0.0
    delay = 0.0
    for s in sites:
        pt = substrate.design_for_site(s.name)
        if pt is None:
            raise ValueError(
                f"substrate {substrate.name!r} carries no design point for "
                f"site {s.name!r}; attach one with with_design()/overrides")
        per_tok = design_lib.workload_metrics(pt, [(s.k, s.m, s.calls)])
        energy += per_tok["energy_per_token_j"]
        delay += per_tok["delay_per_token_s"]
    return {
        "energy_j": tokens * energy,
        "energy_per_token_j": energy,
        "delay_per_token_s": delay,
        "edp_per_token": energy * delay,
    }


@dataclasses.dataclass(frozen=True)
class EnergyReport:
    """A served workload rolled up at one design point.

    ``j_per_token`` divides TOTAL billed energy (prefill + decode) by the
    tokens actually delivered to requests; ``edp_per_token`` multiplies it by
    the compute-model decode latency of one token-forward;
    ``tok_s_compute`` is the per-stream decode rate the compute model alone
    would allow (1 / delay_per_token - the serving analogue of the paper's
    delay axis, independent of the host wall clock).
    """

    design: DesignPoint
    prefill_tokens: int  # billed token-forwards (bucket padding included)
    decode_tokens: int  # billed token-forwards (active slots only)
    generated_tokens: int  # tokens delivered to requests
    requests: int
    prefill_j: float
    decode_j: float
    delay_per_token_s: float
    # the substrate whose (per-site) design points priced this workload;
    # None for legacy uniform-design rollups
    substrate: Optional[Substrate] = None
    # structured online-calibration rollup (DPMeter.drift_summary()); None
    # when the workload ran without a drift monitor
    drift: Optional[dict] = None
    # billed prefill energy the prefix cache avoided (the cold-admission
    # dot-products that never ran), priced through the same rollup as the
    # billed work; 0.0 for prefix-free workloads
    saved_prefill_j: float = 0.0

    @property
    def total_j(self) -> float:
        return self.prefill_j + self.decode_j

    @property
    def j_per_token_saved(self) -> float:
        """Avoided prefill energy per delivered token: the prefix cache's
        J/token discount (what j_per_token WOULD grow by without sharing)."""
        return self.saved_prefill_j / max(self.generated_tokens, 1)

    @property
    def j_per_token(self) -> float:
        return self.total_j / max(self.generated_tokens, 1)

    @property
    def j_per_request(self) -> float:
        return self.total_j / max(self.requests, 1)

    @property
    def edp_per_token(self) -> float:
        return self.j_per_token * self.delay_per_token_s

    @property
    def tok_s_compute(self) -> float:
        return 1.0 / self.delay_per_token_s if self.delay_per_token_s > 0 else float("inf")

    def summary(self) -> Dict[str, float]:
        out = {
            "substrate": (self.substrate.name if self.substrate is not None
                          else None),
            "arch_kind": self.design.arch_kind,
            "n": self.design.n,
            "n_banks": self.design.n_banks,
            "b_adc": self.design.b_adc,
            "knob": self.design.knob,
            "snr_t_db": round(self.design.snr_t_db, 2),
            "prefill_tokens": self.prefill_tokens,
            "decode_tokens": self.decode_tokens,
            "generated_tokens": self.generated_tokens,
            "requests": self.requests,
            "prefill_j": self.prefill_j,
            "decode_j": self.decode_j,
            "j_per_token": self.j_per_token,
            "j_per_request": self.j_per_request,
            "edp_per_token": self.edp_per_token,
            "delay_per_token_s": self.delay_per_token_s,
            "tok_s_compute": self.tok_s_compute,
        }
        # drift activity / prefix savings ride along only when they
        # happened: the legacy record shape is unchanged otherwise
        if self.drift is not None:
            out["drift"] = self.drift
        if self.saved_prefill_j:
            out["saved_prefill_j"] = self.saved_prefill_j
            out["j_per_token_saved"] = self.j_per_token_saved
        return out


def serve_energy_report(
    meter: DPMeter,
    design: Optional[DesignPoint] = None,
    generated_tokens: Optional[int] = None,
    requests: Optional[int] = None,
    substrate: Optional[Substrate] = None,
) -> EnergyReport:
    """Roll a metered serve workload up to J/token, J/request, EDP/token and
    compute-model tok/s (prefill/decode split preserved).

    Pass a ``substrate`` to bill the design points the substrate object
    carries - its base ``design`` plus any per-site overrides (the
    first-class path: no flag plumbing between the engine and the bill).
    Passing a bare ``design`` is the legacy uniform-design rollup.
    """
    sites = meter.sites
    if substrate is not None:
        if design is not None:
            raise ValueError("pass either design= or substrate=, not both")
        design = substrate.design
        if design is None:
            raise ValueError(
                f"substrate {substrate.name!r} carries no design point to "
                "bill; attach one with with_design()")
        pre = substrate_energy_for_tokens(sites, substrate,
                                          meter.prefill_billed_tokens)
        dec = substrate_energy_for_tokens(sites, substrate,
                                          meter.decode_billed_tokens)
        sav = substrate_energy_for_tokens(sites, substrate,
                                          meter.prefix_saved_billed_tokens)
    elif design is None:
        raise ValueError("need a design point or a substrate to bill")
    else:
        pre = energy_for_tokens(sites, design, meter.prefill_billed_tokens)
        dec = energy_for_tokens(sites, design, meter.decode_billed_tokens)
        sav = energy_for_tokens(sites, design,
                                meter.prefix_saved_billed_tokens)
    if generated_tokens is None:
        # best available proxy: every billed decode token is delivered, plus
        # one first token per prefill row
        generated_tokens = meter.decode_billed_tokens + meter.prefill_rows
    if requests is None:
        requests = meter.prefill_rows
    return EnergyReport(
        design=design,
        prefill_tokens=meter.prefill_billed_tokens,
        decode_tokens=meter.decode_billed_tokens,
        generated_tokens=generated_tokens,
        requests=requests,
        prefill_j=pre["energy_j"],
        decode_j=dec["energy_j"],
        delay_per_token_s=dec["delay_per_token_s"],
        substrate=substrate,
        drift=meter.drift_summary(),
        saved_prefill_j=sav["energy_j"],
    )


# ---------------------------------------------------------------------------
# SLO rollup: per-request timing -> p50/p99 TTFT & inter-token latency,
# deadline misses and goodput (virtual-clock serve loops; deterministic)
# ---------------------------------------------------------------------------


def percentile(values: Sequence[float], p: float) -> float:
    """Nearest-rank percentile (deterministic, no interpolation): the
    smallest element >= p percent of the sample.  NaN on empty input."""
    if not values:
        return float("nan")
    xs = sorted(values)
    rank = max(1, math.ceil(p / 100.0 * len(xs)))
    return float(xs[rank - 1])


def request_ttft(req) -> Optional[float]:
    """Arrival -> first token (falls back to submit time when the request
    carries no arrival timestamp)."""
    if req.t_first is None:
        return None
    start = req.arrive_at if req.arrive_at is not None else req.t_submit
    if start is None:
        return None
    return req.t_first - start


def request_itl_gaps(req) -> List[float]:
    """Gaps between consecutive generated tokens (virtual-clock runs only).
    Spans preemptions: a re-queued request's wait shows up as a large gap,
    which is exactly what its consumer would experience."""
    ts = req.token_times
    return [ts[i + 1] - ts[i] for i in range(len(ts) - 1)]


def slo_summary(requests, elapsed: float, policy: str = "",
                prefix_hits: int = 0, cow_copies: int = 0) -> dict:
    """Roll a finished SLO workload up to the scheduling scoreboard.

    A request MEETS its SLO iff it completed without error, its TTFT is
    within ``ttft_deadline`` and no inter-token gap exceeds
    ``itl_deadline`` (absent deadlines always pass).  ``goodput`` is
    SLO-met requests per virtual step and ``goodput_tokens`` their tokens
    per virtual step - the overload currency: shedding a hopeless request
    costs completed-count but buys goodput."""
    ttfts: List[float] = []
    gaps: List[float] = []
    completed = shed = errored = ttft_miss = itl_miss = slo_met = 0
    slo_tokens = 0
    preemptions = 0
    for r in requests:
        preemptions += r.preemptions
        if getattr(r, "shed", False):
            shed += 1
            continue
        if r.error is not None:
            errored += 1
            continue
        completed += 1
        ttft = request_ttft(r)
        if ttft is not None:
            ttfts.append(ttft)
        r_gaps = request_itl_gaps(r)
        gaps.extend(r_gaps)
        miss = False
        if r.ttft_deadline is not None and (ttft is None
                                            or ttft > r.ttft_deadline):
            ttft_miss += 1
            miss = True
        if r.itl_deadline is not None and any(g > r.itl_deadline
                                              for g in r_gaps):
            itl_miss += 1
            miss = True
        if not miss:
            slo_met += 1
            slo_tokens += len(r.out)
    if elapsed > 0:
        goodput = slo_met / elapsed
        goodput_tokens = slo_tokens / elapsed
    else:
        # empty / instantly-drained workload: a rate over zero elapsed time
        # is undefined - report 0.0 when nothing met its SLO and NaN when
        # something did (matching percentile() on empty input), instead of
        # the absurd ~1e9x inflation a clamped divisor produces
        goodput = 0.0 if slo_met == 0 else float("nan")
        goodput_tokens = 0.0 if slo_tokens == 0 else float("nan")
    return {
        "policy": policy,
        "requests": len(requests),
        "completed": completed,
        "shed": shed,
        "errored": errored,
        "ttft_miss": ttft_miss,
        "itl_miss": itl_miss,
        "slo_met": slo_met,
        "preemptions": preemptions,
        "elapsed_steps": round(elapsed, 3),
        "goodput": goodput,
        "goodput_tokens": goodput_tokens,
        "ttft_p50": percentile(ttfts, 50),
        "ttft_p99": percentile(ttfts, 99),
        "itl_p50": percentile(gaps, 50),
        "itl_p99": percentile(gaps, 99),
        # prefix-sharing under churn: hits that survived preemption pressure
        # and the CoW copies taken to keep shared blocks immutable
        "prefix_hits": prefix_hits,
        "cow_copies": cow_copies,
    }


def format_slo_summary(summary: dict) -> str:
    keys = ["requests", "completed", "shed", "errored", "ttft_miss",
            "itl_miss", "slo_met", "preemptions", "elapsed_steps",
            "goodput", "goodput_tokens", "ttft_p50", "ttft_p99", "itl_p50",
            "itl_p99", "prefix_hits", "cow_copies"]
    lines = []
    for k in keys:
        v = summary.get(k)
        lines.append(f"  {k:>16s} = "
                     + (f"{v:.4f}" if isinstance(v, float) else str(v)))
    for k, v in summary.items():
        if k in keys or k == "policy":
            continue
        lines.append(f"  {k:>16s} = "
                     + (f"{v:.4f}" if isinstance(v, float) else str(v)))
    return "\n".join(lines)


def format_report(reports: Sequence[EnergyReport]) -> str:
    """Human-readable table of one workload rolled up at several design
    points (one row per substrate/design point)."""
    hdr = (f"{'kind':>4s} {'N':>5s} {'banks':>5s} {'B_ADC':>5s} "
           f"{'SNR_T dB':>8s} {'J/token':>10s} {'J/request':>10s} "
           f"{'EDP/token':>10s} {'tok/s (compute)':>15s}")
    lines = [hdr]
    for r in reports:
        lines.append(
            f"{r.design.arch_kind:>4s} {r.design.n:>5d} "
            f"{r.design.n_banks:>5d} {r.design.b_adc:>5d} "
            f"{r.design.snr_t_db:>8.1f} {r.j_per_token:>10.3e} "
            f"{r.j_per_request:>10.3e} {r.edp_per_token:>10.3e} "
            f"{r.tok_s_compute:>15.3e}"
        )
    return "\n".join(lines)
