"""Collective/FLOP breakdown of one dry-run cell: per-while-loop costs with
trip counts, the heaviest collective ops and their op_name provenance.
The SSPerf profiling tool (the 'profile' of the hypothesis loop).

  PYTHONPATH=src python -m repro.launch.breakdown --arch granite-20b \
      --shape prefill_32k [--multi-pod]

Also the training/profiling-side home of the IMC energy rollup
(``forward_energy`` / ``--imc-energy``), sharing one code path with the
serve-path meter (``launch.metering``).
"""
import argparse
import collections
import os
import re

import jax

from repro import configs
from repro.configs.shapes import SHAPES, input_specs
from repro.launch import steps as steps_lib
from repro.launch.hlo_analysis import HloModule, _bytes_of, _shape_list
from repro.launch.mesh import make_production_mesh
from repro.models import init_cache, init_params


def lower_cell(arch, shape_name, multi_pod=False, fsdp=True, layout=""):
    from repro.launch.dryrun import make_layout_mesh

    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    mesh = (make_layout_mesh(layout) if layout
            else make_production_mesh(multi_pod=multi_pod))
    sds = input_specs(cfg, shape)
    if shape.kind == "train":
        bundle = steps_lib.build_train_step(cfg, mesh, sds, fsdp=fsdp)
        return bundle.step_fn.lower(bundle.state_shapes, sds)
    if shape.kind == "prefill":
        bundle = steps_lib.build_prefill_step(cfg, mesh, shape, sds, fsdp=fsdp)
        p_sds = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
        return bundle.step_fn.lower(p_sds, sds)
    bundle = steps_lib.build_decode_step(cfg, mesh, shape, sds, fsdp=fsdp)
    p_sds = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    c_sds = jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len))
    return bundle.step_fn.lower(p_sds, sds, c_sds)


def report(hlo_text: str, top: int = 12):
    mod = HloModule(hlo_text)
    print("== while loops by weighted collective bytes ==")
    entries = []
    for name, ops in mod.comps.items():
        for op in ops:
            if op.kind != "while":
                continue
            bm = re.search(r"body=%?([\w.\-]+)", op.rest)
            if not bm:
                continue
            trip = mod._trip_count_of(op, mod._cond_consts)
            c = mod.comp_cost(bm.group(1))
            cb = sum(c.collective_bytes.values())
            entries.append((cb * trip, name, bm.group(1), trip, c))
    for total, parent, body, trip, c in sorted(entries, reverse=True)[:6]:
        if total < 1e6:
            continue
        print(f"\n  while {body} (in {parent}) trip={trip:.0f} "
              f"total={total/2**30:.2f} GiB flops/iter={c.flops:.3g}")
        agg = collections.Counter()
        for op2 in mod.comps[body]:
            kind = op2.kind.replace("-start", "")
            if kind in ("all-to-all", "all-gather", "all-reduce",
                        "reduce-scatter", "collective-permute"):
                b = _bytes_of(_shape_list(op2.result))
                meta = re.search(r'op_name="([^"]+)"', op2.rest)
                prov = (meta.group(1).split("/")[-2:] if meta else ["?"])
                agg[(kind, op2.result[:48], "/".join(prov)[:70])] += b
        for (kind, res, prov), b in agg.most_common(top):
            print(f"    {kind:20s} {b/2**20:9.1f}MiB/iter {res}  <- {prov}")


def forward_energy(cfg, design, tokens: float = 1, sites=None) -> dict:
    """IMC energy/delay rollup of ``tokens`` token-forwards of ``cfg`` at a
    ``core.design`` design point OR a ``core.substrate.Substrate`` carrying
    one (per-site design overrides are honoured) - the training/profiling-
    side view of the same accounting the serve meter reports.

    Deliberately a thin veneer over the ``launch.metering`` rollup helpers
    with the shared ``core.mapping.per_token_matmul_shapes`` walk: a second
    independent shapes walk here would silently double-count (or drop)
    matmul sites relative to the serve-side rollup.  Pinned equal to the
    meter on a single full forward by ``tests/test_metering.py``.
    """
    from repro.core.mapping import per_token_matmul_shapes
    from repro.core.substrate import Substrate
    from repro.launch.metering import energy_for_tokens, substrate_energy_for_tokens

    if sites is None:
        sites = per_token_matmul_shapes(cfg)
    if isinstance(design, Substrate):
        return substrate_energy_for_tokens(sites, design, tokens)
    return energy_for_tokens(sites, design, tokens)


def imc_energy_report(arch: str, shape_name: str, snr_db: float):
    """Print the per-substrate IMC energy rollup for one dry-run cell shape
    (tokens = batch x seq for train/prefill, batch for decode)."""
    from repro.core.design import optimize

    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    tokens = shape.global_batch * (
        shape.seq_len if shape.kind in ("train", "prefill") else 1)
    print(f"== IMC energy rollup: {arch} {shape_name} "
          f"({tokens} token-forwards, SNR_T >= {snr_db} dB) ==")
    for kind in ("qs", "qr", "cm"):
        pt = optimize(n=512, snr_t_target_db=snr_db, kinds=(kind,))
        if pt is None:
            print(f"  {kind}: infeasible at {snr_db} dB")
            continue
        r = forward_energy(cfg, pt, tokens)
        print(f"  {kind}: {r['energy_j']:.3e} J total, "
              f"{r['energy_per_token_j']:.3e} J/token-forward, "
              f"{r['delay_per_token_s']:.3e} s/token (compute), "
              f"EDP/token {r['edp_per_token']:.3e}")


def main():
    # CLI-only: force the 512-device host platform for dry-run compiles.
    # Set here (NOT at import) so importing this module for forward_energy
    # cannot flip an in-process test session multi-device; jax initializes
    # its backend lazily, so this still precedes any device use below.
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=512 "
        + os.environ.get("XLA_FLAGS_EXTRA", "")
    )
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--layout", default="")
    ap.add_argument("--imc-energy", type=float, default=None, metavar="SNR_DB",
                    help="print the IMC energy rollup of this cell at the "
                         "given SNR_T target instead of compiling the HLO")
    args = ap.parse_args()
    if args.imc_energy is not None:
        imc_energy_report(args.arch, args.shape, args.imc_energy)
        return
    lowered = lower_cell(args.arch, args.shape, args.multi_pod,
                         fsdp=not args.no_fsdp, layout=args.layout)
    compiled = lowered.compile()
    report(compiled.as_text())


if __name__ == "__main__":
    main()
