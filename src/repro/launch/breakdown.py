import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS_EXTRA", "")
)

"""Collective/FLOP breakdown of one dry-run cell: per-while-loop costs with
trip counts, the heaviest collective ops and their op_name provenance.
The SSPerf profiling tool (the 'profile' of the hypothesis loop).

  PYTHONPATH=src python -m repro.launch.breakdown --arch granite-20b \
      --shape prefill_32k [--multi-pod]
"""
import argparse
import collections
import re

import jax

from repro import configs
from repro.configs.shapes import SHAPES, input_specs
from repro.launch import steps as steps_lib
from repro.launch.hlo_analysis import HloModule, _shape_list, _bytes_of
from repro.launch.mesh import make_production_mesh
from repro.models import init_cache, init_params


def lower_cell(arch, shape_name, multi_pod=False, fsdp=True, layout=""):
    from repro.launch.dryrun import make_layout_mesh

    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    mesh = (make_layout_mesh(layout) if layout
            else make_production_mesh(multi_pod=multi_pod))
    sds = input_specs(cfg, shape)
    if shape.kind == "train":
        bundle = steps_lib.build_train_step(cfg, mesh, sds, fsdp=fsdp)
        return bundle.step_fn.lower(bundle.state_shapes, sds)
    if shape.kind == "prefill":
        bundle = steps_lib.build_prefill_step(cfg, mesh, shape, sds, fsdp=fsdp)
        p_sds = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
        return bundle.step_fn.lower(p_sds, sds)
    bundle = steps_lib.build_decode_step(cfg, mesh, shape, sds, fsdp=fsdp)
    p_sds = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    c_sds = jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len))
    return bundle.step_fn.lower(p_sds, sds, c_sds)


def report(hlo_text: str, top: int = 12):
    mod = HloModule(hlo_text)
    print("== while loops by weighted collective bytes ==")
    entries = []
    for name, ops in mod.comps.items():
        for op in ops:
            if op.kind != "while":
                continue
            bm = re.search(r"body=%?([\w.\-]+)", op.rest)
            if not bm:
                continue
            trip = mod._trip_count_of(op, mod._cond_consts)
            c = mod.comp_cost(bm.group(1))
            cb = sum(c.collective_bytes.values())
            entries.append((cb * trip, name, bm.group(1), trip, c))
    for total, parent, body, trip, c in sorted(entries, reverse=True)[:6]:
        if total < 1e6:
            continue
        print(f"\n  while {body} (in {parent}) trip={trip:.0f} "
              f"total={total/2**30:.2f} GiB flops/iter={c.flops:.3g}")
        agg = collections.Counter()
        for op2 in mod.comps[body]:
            kind = op2.kind.replace("-start", "")
            if kind in ("all-to-all", "all-gather", "all-reduce",
                        "reduce-scatter", "collective-permute"):
                b = _bytes_of(_shape_list(op2.result))
                meta = re.search(r'op_name="([^"]+)"', op2.rest)
                prov = (meta.group(1).split("/")[-2:] if meta else ["?"])
                agg[(kind, op2.result[:48], "/".join(prov)[:70])] += b
        for (kind, res, prov), b in agg.most_common(top):
            print(f"    {kind:20s} {b/2**20:9.1f}MiB/iter {res}  <- {prov}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--layout", default="")
    args = ap.parse_args()
    lowered = lower_cell(args.arch, args.shape, args.multi_pod,
                         fsdp=not args.no_fsdp, layout=args.layout)
    compiled = lowered.compile()
    report(compiled.as_text())


if __name__ == "__main__":
    main()
