"""Optimizers and distributed-optimization tricks."""
from repro.optim.adamw import AdamWConfig, OptState, init, update, warmup_cosine, global_norm  # noqa: F401
from repro.optim.compression import compressed_psum, init_residual  # noqa: F401
