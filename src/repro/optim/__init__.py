"""Optimizers and distributed-optimization tricks."""
from repro.optim.adamw import (  # noqa: F401
    AdamWConfig,
    OptState,
    global_norm,
    init,
    update,
    warmup_cosine,
)
from repro.optim.compression import compressed_psum, init_residual  # noqa: F401
