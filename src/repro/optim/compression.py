"""Int8 error-feedback gradient compression for cross-pod all-reduce.

At multi-pod scale the ``pod`` axis rides DCN (much lower bandwidth than ICI),
so the cross-pod gradient all-reduce is the step-time tail.  This module
implements the standard 1-bit-Adam-family trick, int8 variant:

  1. add the local error-feedback residual to the gradient,
  2. quantize to int8 with a per-tensor max-abs scale,
  3. all-reduce (psum) the int8 payload in int32 (no overflow for <=2^23 pods),
  4. dequantize with the psum'd scale; keep the quantization residual locally.

Error feedback keeps the *accumulated* compression error bounded, so SGD-style
convergence is preserved (the residual re-enters next step).  8x traffic
reduction on the pod axis vs f32 (4x vs bf16).

Usable under shard_map (see repro.launch.steps.make_manual_dp_train_step) or
standalone for tests.  The residual state lives alongside the optimizer state.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def init_residual(grads_shape_tree) -> Any:
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_shape_tree
    )


def _quantize_int8(x) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum(
    grads,
    residual,
    axis_name: str,
):
    """psum(grads) over `axis_name` with int8 error-feedback compression.

    Must be called inside shard_map/pmap with `axis_name` bound.
    Returns (mean_grads, new_residual).
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q, scale = _quantize_int8(gf)
        local_dq = q.astype(jnp.float32) * scale
        new_r = gf - local_dq  # what this shard failed to transmit
        total = jax.lax.psum(q.astype(jnp.int32).astype(jnp.float32) * scale,
                             axis_name)
        return (total / n).astype(g.dtype), new_r

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (
        treedef.unflatten([o[0] for o in out]),
        treedef.unflatten([o[1] for o in out]),
    )


def compression_error_bound(x, bits: int = 8) -> float:
    """Per-step worst-case relative quantization error (for tests):
    max|x|/(2^(bits-1)-1) per element."""
    return float(jnp.max(jnp.abs(x)) / (2 ** (bits - 1) - 1))
