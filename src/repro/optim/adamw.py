"""Hand-rolled AdamW with decoupled weight decay, global-norm clipping, and
bf16-param / f32-state mixed precision.

State layout mirrors the param pytree (so parameter sharding specs apply
directly to m/v - combined with FSDP param sharding this is ZeRO-1/3).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # parameters whose path matches are excluded from weight decay
    no_decay_substrings: Tuple[str, ...] = ("scale", "bias", "norm", "A_log",
                                            "dt_bias", "rg_a")


class OptState(NamedTuple):
    m: Any
    v: Any
    count: jax.Array


def init(params) -> OptState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )
    return OptState(m=zeros, v=jax.tree_util.tree_map(jnp.copy, zeros),
                    count=jnp.zeros((), jnp.int32))


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


def update(
    grads,
    state: OptState,
    params,
    cfg: AdamWConfig,
    lr: jax.Array,
) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    count = state.count + 1
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    paths_decay = _decay_mask(params, cfg)

    def upd(path_decay, g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if path_decay:
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return new_p, m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [
        upd(d, g, m, v, p)
        for d, g, m, v, p in zip(paths_decay, flat_g, flat_m, flat_v, flat_p)
    ]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "clip_scale": scale}
    return new_p, OptState(new_m, new_v, count), metrics


def _decay_mask(params, cfg: AdamWConfig):
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    mask = []
    for path, _leaf in flat:
        pstr = jax.tree_util.keystr(path).lower()
        mask.append(not any(s in pstr for s in cfg.no_decay_substrings))
    return mask


# ---------------------------------------------------------------------------
# LR schedules
# ---------------------------------------------------------------------------


def warmup_cosine(base_lr: float, warmup: int, total: int, min_frac: float = 0.1):
    def sched(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)

    return sched
