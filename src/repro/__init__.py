"""repro: IMC-limits-aware training/inference framework in JAX.

Reproduces and extends "Fundamental Limits on Energy-Delay-Accuracy of
In-memory Architectures in Inference Applications" (Gonugondla et al., 2020).
"""
__version__ = "1.0.0"
