"""Sharded, atomic, async checkpointing."""
from repro.checkpoint.manager import AsyncSaver, cleanup, latest_step, restore, save  # noqa: F401
