"""Sharded checkpointing: atomic, manifest-verified, async-capable, and
restart/reshard-safe.

Layout (one directory per step):

  ckpt_dir/
    step_000100.tmp/        (written first)
      manifest.json          - tree structure, shapes, dtypes, shard digests
      arr_00000.npy ...      - one file per leaf (np.save, host-gathered)
    step_000100/             (atomic rename on completion - a crash never
                              leaves a half-valid checkpoint visible)

Restore is sharding-agnostic: leaves are loaded on host and device_put with
whatever shardings the *current* mesh prescribes, so a checkpoint written on
512 chips restores onto 8 (elastic restart).  Corrupt/partial checkpoints are
detected via the manifest digest and skipped by `latest_step`.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _leaf_paths(tree) -> Dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(p): v for p, v in flat}


def _digest(arr: np.ndarray) -> str:
    h = hashlib.sha256()
    h.update(np.ascontiguousarray(arr).tobytes()[: 1 << 20])  # first 1 MiB
    h.update(str(arr.shape).encode())
    h.update(str(arr.dtype).encode())
    return h.hexdigest()[:16]


def save(ckpt_dir: str, step: int, tree, extra: Optional[dict] = None) -> str:
    """Synchronous atomic save. Returns the final directory path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves = _leaf_paths(tree)
    manifest = {"step": step, "extra": extra or {}, "leaves": {}}
    for i, (path, leaf) in enumerate(sorted(leaves.items())):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"arr_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][path] = {
            "file": fname,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "digest": _digest(arr),
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


class AsyncSaver:
    """Fire-and-forget background saves (host-gather happens on the caller
    thread to snapshot consistent values; IO runs in the worker)."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None

    def save(self, ckpt_dir: str, step: int, tree, extra=None):
        host_tree = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), tree
        )
        self.wait()
        self._thread = threading.Thread(
            target=save, args=(ckpt_dir, step, host_tree, extra), daemon=True
        )
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Newest *valid* checkpoint step (validates manifest presence + files)."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if not name.startswith("step_") or name.endswith(".tmp"):
            continue
        d = os.path.join(ckpt_dir, name)
        if not os.path.isfile(os.path.join(d, "manifest.json")):
            continue
        try:
            with open(os.path.join(d, "manifest.json")) as f:
                man = json.load(f)
            ok = all(
                os.path.isfile(os.path.join(d, meta["file"]))
                for meta in man["leaves"].values()
            )
            if ok:
                steps.append(int(name.split("_")[1]))
        except (json.JSONDecodeError, KeyError, ValueError):
            continue
    return max(steps) if steps else None


def restore(
    ckpt_dir: str,
    step: int,
    like,
    shardings=None,
    verify: bool = True,
) -> Tuple[Any, dict]:
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs). Returns (tree, extra)."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        man = json.load(f)
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_flat = None
    if shardings is not None:
        shard_flat = treedef.flatten_up_to(shardings)
    out = []
    for i, (path, leaf) in enumerate(flat):
        key = jax.tree_util.keystr(path)
        meta = man["leaves"][key]
        arr = np.load(os.path.join(d, meta["file"]))
        if verify and _digest(arr) != meta["digest"]:
            raise IOError(f"checkpoint digest mismatch at {key}")
        if tuple(arr.shape) != tuple(leaf.shape):
            raise IOError(
                f"shape mismatch at {key}: ckpt {arr.shape} vs model {leaf.shape}"
            )
        if shard_flat is not None:
            out.append(jax.device_put(arr, shard_flat[i]))
        else:
            out.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return treedef.unflatten(out), man.get("extra", {})


def cleanup(ckpt_dir: str, keep: int = 3):
    """Delete all but the newest `keep` valid checkpoints (+ stray tmp dirs)."""
    if not os.path.isdir(ckpt_dir):
        return
    for name in os.listdir(ckpt_dir):
        if name.endswith(".tmp"):
            shutil.rmtree(os.path.join(ckpt_dir, name), ignore_errors=True)
    steps = sorted(
        int(n.split("_")[1])
        for n in os.listdir(ckpt_dir)
        if n.startswith("step_") and not n.endswith(".tmp")
    )
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)
