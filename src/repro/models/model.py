"""Top-level LM: init, forward/loss (train), prefill and decode (serve).

Scan-over-layers: parameters for each pattern position are stacked along a
leading ``n_full_cycles`` axis under ``params["blocks"]``; remainder layers
live unstacked under ``params["tail"]``.  Caches mirror this layout.

Modality stubs (DESIGN.md SS4): ``vlm`` consumes precomputed patch embeddings
(batch, prefix_len, d_model) scattered over the first positions; ``audio``
consumes EnCodec token ids directly (they are ordinary vocab tokens).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.imc_linear import layer_rng, linear
from repro.launch.sharding import ws
from repro.models import transformer as tf
from repro.models.layers import (
    apply_norm,
    dtype_of,
    embed_init,
    init_norm,
    sinusoidal_positions,
    softcap,
)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_params(key, cfg: ArchConfig) -> Dict[str, Any]:
    dtype = dtype_of(cfg.dtype)
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": embed_init(keys[0], cfg.padded_vocab, cfg.d_model, dtype),
        "final_norm": init_norm(cfg.norm_kind, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(keys[1], (cfg.d_model, cfg.padded_vocab)) * 0.02
        ).astype(dtype)
    if cfg.pos_kind == "learned":
        params["pos_table"] = (
            jax.random.normal(keys[2], (cfg.max_seq, cfg.d_model)) * 0.02
        ).astype(dtype)

    n_full = cfg.n_full_cycles
    blocks = {}
    for pi, kind in enumerate(cfg.pattern):
        ks = jax.random.split(jax.random.fold_in(keys[3], pi), n_full)
        stacked = [tf.init_block(k, cfg, kind, dtype) for k in ks]
        blocks[f"p{pi}"] = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *stacked
        )
    params["blocks"] = blocks
    tail = {}
    for ti, kind in enumerate(cfg.tail_kinds):
        tail[f"t{ti}"] = tf.init_block(
            jax.random.fold_in(keys[4], ti), cfg, kind, dtype
        )
    if tail:
        params["tail"] = tail
    return params


def init_cache(cfg: ArchConfig, batch: int, cache_len: int):
    dtype = dtype_of(cfg.dtype)
    n_full = cfg.n_full_cycles
    cache: Dict[str, Any] = {"blocks": {}, "pos": jnp.zeros((), jnp.int32)}
    for pi, kind in enumerate(cfg.pattern):
        one = tf.init_block_cache(cfg, kind, batch, cache_len, dtype)
        cache["blocks"][f"p{pi}"] = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (n_full,) + x.shape).copy(), one
        )
    for ti, kind in enumerate(cfg.tail_kinds):
        cache.setdefault("tail", {})[f"t{ti}"] = tf.init_block_cache(
            cfg, kind, batch, cache_len, dtype
        )
    return cache


def init_paged_cache(cfg: ArchConfig, batch: int, cache_len: int,
                     num_blocks: int, block_size: int):
    """Like :func:`init_cache` but global-attention layers get a paged block
    pool ({"pk","pv","bt"}) instead of a per-slot contiguous slice.  Every
    paged layer shares the same block-table CONTENTS (allocation is identical
    across layers); each carries its own copy so the cache tree stays
    self-contained under scan-over-layers."""
    dtype = dtype_of(cfg.dtype)
    n_full = cfg.n_full_cycles
    cache: Dict[str, Any] = {"blocks": {}, "pos": jnp.zeros((), jnp.int32)}
    for pi, kind in enumerate(cfg.pattern):
        one = tf.init_block_cache_paged(cfg, kind, batch, cache_len, dtype,
                                        num_blocks, block_size)
        cache["blocks"][f"p{pi}"] = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (n_full,) + x.shape).copy(), one
        )
    for ti, kind in enumerate(cfg.tail_kinds):
        cache.setdefault("tail", {})[f"t{ti}"] = tf.init_block_cache_paged(
            cfg, kind, batch, cache_len, dtype, num_blocks, block_size
        )
    return cache


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------


def _embed_inputs(params, cfg: ArchConfig, tokens, prefix_embeds, positions):
    x = params["embed"][tokens]  # (B, S, d)
    if cfg.emb_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    if cfg.modality == "vlm" and prefix_embeds is not None:
        p = prefix_embeds.shape[1]
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x[:, p:]], axis=1)
    if cfg.pos_kind == "learned":
        x = x + params["pos_table"][positions]
    elif cfg.pos_kind == "sinusoidal":
        x = x + sinusoidal_positions(positions, cfg.d_model).astype(x.dtype)
    return ws(x, "act_btd")


def _head(params, cfg: ArchConfig, x):
    x = apply_norm(params["final_norm"], x, cfg.norm_kind)
    if cfg.tie_embeddings:
        logits = jnp.einsum("...d,vd->...v", x, params["embed"])
    else:
        logits = linear(params["lm_head"], x, cfg.imc, site="lm_head")
    logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
    if cfg.padded_vocab != cfg.vocab_size:
        # mask padding rows out of the softmax
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad_mask, -1e9, logits)
    return ws(logits, "act_btv")


# ---------------------------------------------------------------------------
# full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------


def _scan_full(params, cfg: ArchConfig, x, positions, rng, want_cache,
               cache_len, true_len=None):
    n_full = cfg.n_full_cycles

    def cycle(x_aux, inp):
        x, aux = x_aux
        bp, li = inp
        caches = []
        for pi, kind in enumerate(cfg.pattern):
            r = None if rng is None else jax.random.fold_in(
                jax.random.fold_in(rng, pi), li
            )
            x, c, a = tf.apply_block_full(
                bp[f"p{pi}"], x, cfg, kind, positions, r, want_cache,
                cache_len, true_len=true_len,
            )
            aux = aux + a
            caches.append(c)
        out_caches = {f"p{pi}": c for pi, c in enumerate(caches)} if want_cache else 0
        return (x, aux), out_caches

    body = cycle
    if cfg.remat and not want_cache:
        body = jax.checkpoint(cycle, prevent_cse=False)

    (x, aux), caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)),
        (params["blocks"], jnp.arange(n_full)),
    )

    tail_caches = {}
    for ti, kind in enumerate(cfg.tail_kinds):
        r = None if rng is None else jax.random.fold_in(rng, 10_000 + ti)
        x, c, a = tf.apply_block_full(
            params["tail"][f"t{ti}"], x, cfg, kind, positions, r,
            want_cache, cache_len, true_len=true_len,
        )
        aux = aux + a
        tail_caches[f"t{ti}"] = c
    return x, aux, caches, tail_caches


def forward(
    params,
    cfg: ArchConfig,
    tokens,  # (B, S) int32
    prefix_embeds=None,  # (B, P, d) for vlm
    rng=None,
):
    """Full-sequence logits (B, S, V)."""
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = _embed_inputs(params, cfg, tokens, prefix_embeds, positions)
    x, aux, _, _ = _scan_full(params, cfg, x, positions, rng, False, 0)
    return _head(params, cfg, x), aux


def loss_fn(
    params,
    cfg: ArchConfig,
    batch: Dict[str, jax.Array],  # tokens (B,S), optional prefix_embeds
    rng=None,
    aux_coef: float = 0.01,
    z_coef: float = 1e-4,
):
    """Next-token cross entropy + MoE aux + z-loss. Returns (loss, metrics)."""
    tokens = batch["tokens"]
    logits, aux = forward(params, cfg, tokens, batch.get("prefix_embeds"), rng)
    targets = tokens[:, 1:]
    lg = logits[:, :-1].astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(lg, axis=-1)
    ll = jnp.take_along_axis(lg, targets[..., None], axis=-1)[..., 0] - logz
    ce = -jnp.mean(ll)
    z_loss = jnp.mean(logz**2)
    loss = ce + aux_coef * aux + z_coef * z_loss
    return loss, {"ce": ce, "moe_aux": aux, "z_loss": z_loss}


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------


def prefill(
    params,
    cfg: ArchConfig,
    tokens,  # (B, S)
    cache_len: int,
    prefix_embeds=None,
    rng=None,
    true_len=None,  # optional (B,) int32: true prompt lengths (S is padding)
):
    """Process a prompt; returns (last-position logits, cache).

    ``true_len`` enables bucketed prefill: ``tokens`` may be right-padded to a
    bucket length S, with each row's real prompt occupying the first
    ``true_len[i]`` positions.  Causality keeps the padded positions from
    contaminating real ones; the returned logits are gathered at each row's
    true last position, ``cache["pos"]`` becomes the (B,) vector ``true_len``,
    and sliding-window ring caches are packed per-row from the true tail.
    Attention KV-cache rows beyond ``true_len`` hold pad garbage but are
    masked during decode until they are overwritten position-by-position.
    """
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = _embed_inputs(params, cfg, tokens, prefix_embeds, positions)
    x, _, caches, tail_caches = _scan_full(
        params, cfg, x, positions, rng, True, cache_len, true_len=true_len
    )
    if true_len is None:
        x_last = x[:, -1:]
        pos = jnp.asarray(s, jnp.int32)
    else:
        pos = jnp.asarray(true_len, jnp.int32)
        x_last = x[jnp.arange(b), pos - 1][:, None, :]
    logits = _head(params, cfg, x_last)
    cache = {"blocks": caches, "pos": pos}
    if tail_caches:
        cache["tail"] = tail_caches
    return logits, cache


def decode_step(
    params,
    cfg: ArchConfig,
    token,  # (B,) int32 - the most recent token
    cache,
    rng=None,
    active=None,  # optional (B,) bool: rows allowed to write their KV slot
):
    """One decode step. Returns (logits (B, 1, V), new_cache).

    ``cache["pos"]`` may be a scalar (all slots synchronized) or a (B,)
    vector of per-slot positions (continuous batching); either way the
    returned cache carries ``pos + 1`` with the same shape.  ``active``
    matters only for paged caches: an inactive row's stale block table may
    reference physical blocks reassigned to another request, so its K/V
    write is routed to the garbage block.
    """
    b = token.shape[0]
    pos = cache["pos"]
    positions = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))[:, None]
    x = _embed_inputs(params, cfg, token[:, None], None, positions)

    def cycle(x, inp):
        bp, bc, li = inp
        new_cs = {}
        for pi, kind in enumerate(cfg.pattern):
            r = None if rng is None else jax.random.fold_in(
                jax.random.fold_in(rng, pi), li
            )
            x, nc = tf.apply_block_decode(bp[f"p{pi}"], x, cfg, kind,
                                          bc[f"p{pi}"], pos, r, active=active)
            new_cs[f"p{pi}"] = nc
        return x, new_cs

    x, new_caches = jax.lax.scan(
        cycle, x,
        (params["blocks"], cache["blocks"], jnp.arange(cfg.n_full_cycles)),
    )
    new_cache = {"blocks": new_caches, "pos": pos + 1}
    if "tail" in cache:
        new_tail = {}
        for ti, kind in enumerate(cfg.tail_kinds):
            r = None if rng is None else jax.random.fold_in(rng, 10_000 + ti)
            x, nc = tf.apply_block_decode(
                params["tail"][f"t{ti}"], x, cfg, kind, cache["tail"][f"t{ti}"],
                pos, r, active=active,
            )
            new_tail[f"t{ti}"] = nc
        new_cache["tail"] = new_tail
    logits = _head(params, cfg, x)
    return logits, new_cache


def param_count(params) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))
