"""Shared model-building blocks: norms, embeddings, positions, MLPs.

All layers are functional: ``init_*`` returns a param pytree, ``apply``-style
functions are pure.  Every matmul routes through repro.core.imc_linear so the
paper's IMC execution modes apply architecture-wide.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.imc_linear import DIGITAL, IMCConfig, linear
from repro.launch.sharding import ws


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[name]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def init_norm(kind: str, d: int, dtype):
    if kind == "rmsnorm":
        return {"scale": jnp.zeros((d,), dtype)}  # gemma-style (1 + scale)
    if kind == "layernorm":
        return {"scale": jnp.zeros((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    raise ValueError(kind)


def apply_norm(params, x, kind: str, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
        y = y * (1.0 + params["scale"].astype(jnp.float32))
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * (1.0 + params["scale"].astype(jnp.float32)) + params["bias"].astype(
            jnp.float32
        )
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# positions
# ---------------------------------------------------------------------------


def rope(x, positions, theta: float):
    """Rotary embedding. x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(ang)[..., :, None, :]  # (..., S, 1, half)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def sinusoidal_positions(positions, d: int):
    """(..., S) -> (..., S, d) classic sin/cos table, computed on the fly."""
    half = d // 2
    freq = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# softcap
# ---------------------------------------------------------------------------


def softcap(x, cap: Optional[float]):
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs (gated + plain), optionally through the IMC layer
# ---------------------------------------------------------------------------


def init_mlp(key, d: int, d_ff: int, kind: str, dtype):
    ks = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        return {
            "wi": dense_init(ks[0], d, d_ff, dtype),
            "wg": dense_init(ks[1], d, d_ff, dtype),
            "wo": dense_init(ks[2], d_ff, d, dtype),
        }
    if kind == "gelu":
        return {
            "wi": dense_init(ks[0], d, d_ff, dtype),
            "wo": dense_init(ks[2], d_ff, d, dtype),
        }
    raise ValueError(kind)


def apply_mlp(params, x, kind: str, imc: IMCConfig = DIGITAL, rng=None):
    # site names follow core.mapping.per_token_matmul_shapes (the gate proj
    # shares the "mlp.wi" site: same shape, same design-point assignment)
    if kind in ("swiglu", "geglu"):
        h = linear(params["wi"], x, imc, rng, site="mlp.wi")
        g = linear(params["wg"], x, imc, rng, site="mlp.wi")
        act = jax.nn.silu if kind == "swiglu" else jax.nn.gelu
        h = act(g.astype(jnp.float32)).astype(h.dtype) * h
        h = ws(h, "act_btf")
        return linear(params["wo"], h, imc, rng, site="mlp.wo")
    h = linear(params["wi"], x, imc, rng, site="mlp.wi")
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(h.dtype)
    h = ws(h, "act_btf")
    return linear(params["wo"], h, imc, rng, site="mlp.wo")
