"""Block assembly: pattern-cycled decoder layers with scan-over-layers.

Layers are grouped by the config's block ``pattern`` (e.g. gemma2 =
("local","attn"), recurrentgemma = ("rglru","rglru","local"), mamba2 =
("ssm",)).  Parameters for each pattern position are stacked across the
``n_full_cycles`` repetitions and applied under jax.lax.scan (small HLO,
fast multi-pod compiles); the remainder layers (n_layers % len(pattern)) are
applied as an explicit tail.

Three execution paths share the same block code:
  train/forward  - full sequence, no caches (optionally remat per cycle)
  prefill        - full sequence, additionally returns per-layer decode caches
  decode         - one token against caches
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.imc_linear import layer_rng
from repro.launch.sharding import ws
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import rglru as rg_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import apply_mlp, apply_norm, init_mlp, init_norm


# ---------------------------------------------------------------------------
# per-kind init
# ---------------------------------------------------------------------------


def attn_dims(cfg: ArchConfig, kind: str) -> attn_lib.AttnDims:
    hd = cfg.resolved_head_dim
    return attn_lib.AttnDims(
        n_heads=cfg.n_heads,
        n_kv=cfg.n_kv_heads,
        head_dim=hd,
        scale=cfg.attn_logit_scale or hd**-0.5,
        softcap_val=cfg.attn_softcap,
        window=cfg.window if kind == "local" else None,
        q_block=cfg.flash_q_block,
        kv_block=cfg.flash_kv_block,
        rope_theta=cfg.rope_theta,
        use_rope=cfg.pos_kind == "rope",
        paged_kernel=cfg.decode_attn != "gather",
    )


def init_block(key, cfg: ArchConfig, kind: str, dtype):
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p: Dict[str, Any] = {"norm1": init_norm(cfg.norm_kind, d, dtype)}
    if cfg.post_norm:
        p["norm1_post"] = init_norm(cfg.norm_kind, d, dtype)
    if kind in ("attn", "local"):
        p["mixer"] = attn_lib.init_attention(
            ks[0], d, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim, dtype
        )
    elif kind == "ssm":
        p["mixer"] = ssm_lib.init_ssm(
            ks[0], d, cfg.ssm_expand, cfg.ssm_head_dim, cfg.ssm_groups,
            cfg.ssm_state, cfg.conv_width, dtype,
        )
        return p  # mamba2 blocks have no separate MLP
    elif kind == "rglru":
        p["mixer"] = rg_lib.init_rglru(ks[0], d, cfg.rnn_width,
                                       cfg.rnn_conv_width, dtype)
    else:
        raise ValueError(kind)
    p["norm2"] = init_norm(cfg.norm_kind, d, dtype)
    if cfg.post_norm:
        p["norm2_post"] = init_norm(cfg.norm_kind, d, dtype)
    if cfg.n_experts > 0:
        p["moe"] = moe_lib.init_moe(ks[1], d, cfg.d_ff, cfg.n_experts,
                                    cfg.mlp_kind, dtype)
    else:
        p["mlp"] = init_mlp(ks[1], d, cfg.d_ff, cfg.mlp_kind, dtype)
    return p


def init_block_cache(cfg: ArchConfig, kind: str, batch: int, cache_len: int, dtype):
    if kind in ("attn", "local"):
        span = min(cfg.window, cache_len) if kind == "local" else cache_len
        return attn_lib.init_kv_cache(
            batch, span, cfg.n_kv_heads, cfg.resolved_head_dim, dtype
        )
    if kind == "ssm":
        return ssm_lib.init_ssm_cache(batch, cfg, dtype)
    if kind == "rglru":
        return rg_lib.init_rglru_cache(batch, cfg.rnn_width, cfg.rnn_conv_width, dtype)
    raise ValueError(kind)


def init_block_cache_paged(cfg: ArchConfig, kind: str, batch: int,
                           cache_len: int, dtype, num_blocks: int,
                           block_size: int):
    """Paged variant: global-attention KV caches become block pools + block
    tables; sliding-window rings (already bounded at the window span) and
    recurrent states (fixed-size per slot) keep their contiguous layout."""
    if kind == "attn":
        max_blocks = -(-cache_len // block_size)
        return attn_lib.init_paged_kv_cache(
            batch, num_blocks, block_size, max_blocks, cfg.n_kv_heads,
            cfg.resolved_head_dim, dtype,
        )
    return init_block_cache(cfg, kind, batch, cache_len, dtype)


# ---------------------------------------------------------------------------
# per-kind apply
# ---------------------------------------------------------------------------


def _mlp_half(p, x, cfg: ArchConfig, rng):
    h = apply_norm(p["norm2"], x, cfg.norm_kind)
    if cfg.n_experts > 0:
        out, aux = moe_lib.apply_moe(
            p["moe"], h, cfg.n_experts, cfg.top_k, cfg.capacity_factor,
            cfg.moe_group_size, cfg.mlp_kind, cfg.imc, rng,
        )
    else:
        out, aux = apply_mlp(p["mlp"], h, cfg.mlp_kind, cfg.imc, rng), 0.0
    if cfg.post_norm:
        out = apply_norm(p["norm2_post"], out, cfg.norm_kind)
    return ws(x + out, "act_btd"), aux


def apply_block_full(
    p,
    x,  # (B, S, d)
    cfg: ArchConfig,
    kind: str,
    positions,  # (B, S)
    rng,
    want_cache: bool,
    cache_len: int,
    true_len=None,  # optional (B,) true prompt lengths (bucketed prefill)
):
    """Full-sequence block. Returns (x, cache_or_None, moe_aux)."""
    h = apply_norm(p["norm1"], x, cfg.norm_kind)
    cache = None
    if kind in ("attn", "local"):
        dims = attn_dims(cfg, kind)
        q, k, v = attn_lib._project_qkv(p["mixer"], h, dims, positions,
                                        cfg.imc, rng, site_prefix=kind)
        if dims.window is not None and dims.window < h.shape[1]:
            ctx = attn_lib.banded_attention(q, k, v, dims)
        else:
            ctx = attn_lib.flash_attention(q, k, v, dims)
        b, s = h.shape[:2]
        ctx = ctx.reshape(b, s, dims.n_heads * dims.head_dim)
        out = attn_lib.linear(p["mixer"]["wo"], ctx, cfg.imc, rng,
                              site=f"{kind}.wo")
        if want_cache:
            cache = _pack_kv_cache(k, v, cache_len, dims.window, x.dtype,
                                   true_len)
    elif kind == "ssm":
        out, state = ssm_lib.ssm_forward(p["mixer"], h, cfg, cfg.imc, rng)
        if want_cache:
            if true_len is not None:
                # recurrent state integrates pad garbage; serve engines must
                # use exact-length prefill for recurrent patterns
                raise ValueError("bucketed (padded) prefill is not supported "
                                 "for ssm blocks")
            cache = _pack_ssm_cache(p, h, state, cfg, x.dtype)
        x = x + (apply_norm(p["norm1_post"], out, cfg.norm_kind)
                 if cfg.post_norm else out)
        return ws(x, "act_btd"), cache, 0.0  # mamba2: no MLP half
    elif kind == "rglru":
        out, h_last = rg_lib.rglru_forward(p["mixer"], h, cfg, cfg.imc, rng)
        if want_cache:
            if true_len is not None:
                raise ValueError("bucketed (padded) prefill is not supported "
                                 "for rglru blocks")
            cache = _pack_rglru_cache(p, h, h_last, cfg, x.dtype)
    else:
        raise ValueError(kind)
    if cfg.post_norm:
        out = apply_norm(p["norm1_post"], out, cfg.norm_kind)
    x = x + out
    x = ws(x, "act_btd")
    x, aux = _mlp_half(p, x, cfg, rng)
    return x, cache, aux


def apply_block_decode(p, x, cfg: ArchConfig, kind: str, cache, pos, rng,
                       active=None):
    """One-token block. Returns (x, new_cache)."""
    h = apply_norm(p["norm1"], x, cfg.norm_kind)
    if kind in ("attn", "local"):
        dims = attn_dims(cfg, kind)
        out, new_cache = attn_lib.attention_decode(
            p["mixer"], h, cache, pos, dims, cfg.imc, rng, active=active,
            site_prefix=kind,
        )
    elif kind == "ssm":
        out, new_cache = ssm_lib.ssm_decode(p["mixer"], h, cache, cfg, cfg.imc, rng)
        x = x + (apply_norm(p["norm1_post"], out, cfg.norm_kind)
                 if cfg.post_norm else out)
        return x, new_cache
    elif kind == "rglru":
        out, new_cache = rg_lib.rglru_decode(p["mixer"], h, cache, cfg, cfg.imc, rng)
    else:
        raise ValueError(kind)
    if cfg.post_norm:
        out = apply_norm(p["norm1_post"], out, cfg.norm_kind)
    x = x + out
    x, _ = _mlp_half(p, x, cfg, rng)
    return x, new_cache


# ---------------------------------------------------------------------------
# prefill cache packing
# ---------------------------------------------------------------------------


def _pack_kv_cache(k, v, cache_len: int, window: Optional[int], dtype,
                   true_len=None):
    """Arrange prefill K/V into the decode cache layout.

    With ``true_len`` (bucketed prefill: per-row true lengths, S is the padded
    bucket), the linear (global) layout needs no special casing - rows beyond
    ``true_len`` hold pad garbage that decode masks and then overwrites.  The
    sliding-window ring layout does: each row's ring must be packed from ITS
    true tail ``[true_len - w, true_len)`` at ring phase ``true_len % w``, or
    the pad tail would alias (and clobber) live in-window positions.
    """
    b, s = k.shape[:2]
    if window is None:
        pad = cache_len - s
        assert pad >= 0, (cache_len, s)
        kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(dtype)
        vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(dtype)
        return {"k": kc, "v": vc}
    w = min(window, cache_len)
    if s < w:
        # slot j = position j for every row, padded or not
        kc = jnp.pad(k, ((0, 0), (0, w - s), (0, 0), (0, 0)))
        vc = jnp.pad(v, ((0, 0), (0, w - s), (0, 0), (0, 0)))
        return {"k": kc.astype(dtype), "v": vc.astype(dtype)}
    if true_len is None:
        k_last, v_last = k[:, s - w :], v[:, s - w :]
        shift = s % w
        kc = jnp.roll(k_last, shift, axis=1)
        vc = jnp.roll(v_last, shift, axis=1)
        return {"k": kc.astype(dtype), "v": vc.astype(dtype)}

    tl = jnp.asarray(true_len, jnp.int32)

    def ring_row(k_row, v_row, tl_row):  # (S, H, hd), (S, H, hd), ()
        start = jnp.clip(tl_row - w, 0, s - w)
        ks = jax.lax.dynamic_slice_in_dim(k_row, start, w, axis=0)
        vs = jax.lax.dynamic_slice_in_dim(v_row, start, w, axis=0)
        # element j holds position start+j; ring slot of position p is p % w,
        # so roll by start % w (0 when the prompt hasn't filled the window:
        # start = 0 and slot j = position j already)
        shift = start % w
        return jnp.roll(ks, shift, axis=0), jnp.roll(vs, shift, axis=0)

    kc, vc = jax.vmap(ring_row)(k, v, tl)
    return {"k": kc.astype(dtype), "v": vc.astype(dtype)}


def _pack_ssm_cache(p, h_in, state, cfg: ArchConfig, dtype):
    """SSD decode cache from prefill: final state + last conv-window inputs."""
    from repro.core.imc_linear import linear as _linear

    proj = _linear(p["mixer"]["in_proj"], h_in[:, -(cfg.conv_width - 1):],
                   cfg.imc, site="ssm.in_proj")
    d_inner, n_heads, conv_ch = ssm_lib.ssm_dims(
        cfg.d_model, cfg.ssm_expand, cfg.ssm_head_dim, cfg.ssm_groups, cfg.ssm_state
    )
    gn = cfg.ssm_groups * cfg.ssm_state
    xbc = proj[..., d_inner : 2 * d_inner + 2 * gn]
    return {"conv": xbc.astype(dtype), "state": state}


def _pack_rglru_cache(p, h_in, h_last, cfg: ArchConfig, dtype):
    from repro.core.imc_linear import linear as _linear

    xb = _linear(p["mixer"]["rg_x"], h_in[:, -(cfg.rnn_conv_width - 1):],
                 cfg.imc, site="rg.x")
    return {"conv": xb.astype(dtype), "h": h_last}
