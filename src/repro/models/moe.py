"""Mixture-of-Experts with top-k routing, grouped capacity-based dispatch and
expert parallelism over the ``model`` mesh axis.

Dispatch uses the scatter/gather formulation (O(T*k*d) memory) rather than the
GShard one-hot einsum (O(T*E*C)): tokens are routed in groups of
``moe_group_size``; within a group each (token, choice) slot gets a position in
its expert's capacity buffer via a cumulative count, over-capacity slots drop
(controlled by capacity_factor), the (E, C, d) buffer is built by scatter,
experts run as a vmapped MLP over the expert axis (sharded on ``model``), and
results gather back to token order weighted by the router gates.

Returns an auxiliary load-balancing loss (Switch-style) for the train step.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.imc_linear import DIGITAL, IMCConfig, linear
from repro.launch.sharding import moe_vmap_axes, ws
from repro.models.layers import dense_init


def init_moe(key, d: int, d_ff: int, n_experts: int, mlp_kind: str, dtype):
    ks = jax.random.split(key, 4)

    def stack(k, d_in, d_out):
        kk = jax.random.split(k, n_experts)
        return jnp.stack([dense_init(ki, d_in, d_out, dtype) for ki in kk])

    params = {
        "router": dense_init(ks[0], d, n_experts, jnp.float32, scale=0.02),
        "experts": {
            "wi": stack(ks[1], d, d_ff),
            "wo": stack(ks[3], d_ff, d),
        },
    }
    if mlp_kind in ("swiglu", "geglu"):
        params["experts"]["wg"] = stack(ks[2], d, d_ff)
    return params


def _expert_mlp(ep, h, mlp_kind: str, imc: IMCConfig, rng):
    """h: (C, d) for a single expert's param slice ep."""
    hi = linear(ep["wi"], h, imc, rng, site="mlp.wi")
    if mlp_kind in ("swiglu", "geglu"):
        g = linear(ep["wg"], h, imc, rng, site="mlp.wi")
        act = jax.nn.silu if mlp_kind == "swiglu" else jax.nn.gelu
        hi = act(g.astype(jnp.float32)).astype(hi.dtype) * hi
    else:
        hi = jax.nn.gelu(hi.astype(jnp.float32)).astype(hi.dtype)
    return linear(ep["wo"], hi, imc, rng, site="mlp.wo")


def apply_moe(
    params,
    x,  # (B, S, d)
    n_experts: int,
    top_k: int,
    capacity_factor: float,
    group_size: int,
    mlp_kind: str,
    imc: IMCConfig = DIGITAL,
    rng=None,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y, aux_loss)."""
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    g_sz = min(group_size, t)
    n_groups = -(-t // g_sz)
    pad = n_groups * g_sz - t
    if pad:
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
    xg = ws(xt.reshape(n_groups, g_sz, d), "moe_gxd")

    cap = int(-(-top_k * g_sz * capacity_factor // n_experts))
    cap = max(cap, 1)

    def route_group(xg_i):
        # (g, d) -> (g, d), aux
        logits = jnp.einsum(
            "gd,de->ge", xg_i.astype(jnp.float32), params["router"]
        )
        probs = jax.nn.softmax(logits, axis=-1)  # (g, E)
        gate, idx = jax.lax.top_k(probs, top_k)  # (g, k)
        gate = gate / (jnp.sum(gate, axis=-1, keepdims=True) + 1e-9)

        # position in expert: flatten slots row-major (token-priority order)
        flat_e = idx.reshape(-1)  # (g*k,)
        onehot = ws(jax.nn.one_hot(flat_e, n_experts, dtype=jnp.int32),
                    "moe_ge")
        pos = jnp.cumsum(onehot, axis=0) - 1  # (g*k, E)
        pos_in_e = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
        keep = pos_in_e < cap
        slot = jnp.where(keep, flat_e * cap + pos_in_e, n_experts * cap)

        # scatter tokens into capacity buffers (+1 overflow row, dropped);
        # rows seq-sharded, buffer expert-sharded => lowers to the canonical
        # sequence->expert all-to-all
        x_rep = ws(jnp.repeat(xg_i, top_k, axis=0), "moe_td")  # (g*k, d)
        buf = jnp.zeros((n_experts * cap + 1, d), xg_i.dtype)
        buf = buf.at[slot].set(x_rep, mode="drop")
        buf = buf[:-1].reshape(n_experts, cap, d)
        buf = ws(buf, "moe_ecf")

        # expert computation, vmapped over the (model-sharded) expert axis
        h = jax.vmap(
            lambda ep, hb: _expert_mlp(ep, hb, mlp_kind, imc, rng)
        )(params["experts"], buf)  # (E, cap, d)
        h = ws(h, "moe_ecf")

        # gather back to slots, weight by gates, sum over k choices
        h_flat = jnp.concatenate(
            [h.reshape(n_experts * cap, d), jnp.zeros((1, d), h.dtype)], axis=0
        )
        y_slots = ws(h_flat[slot], "moe_td")  # (g*k, d) expert->seq a2a back
        y_slots = y_slots * (gate.reshape(-1)[:, None] * keep[:, None]).astype(
            y_slots.dtype
        )
        y = jnp.sum(y_slots.reshape(g_sz, top_k, d), axis=1)

        # Switch-style load-balance aux: E * sum_e f_e * p_e
        frac = jnp.mean(
            jax.nn.one_hot(idx, n_experts, dtype=jnp.float32), axis=(0, 1)
        )
        pmean = jnp.mean(probs, axis=0)
        aux = n_experts * jnp.sum(frac * pmean)
        return y, aux

    # vmap (NOT lax.map): batched routing keeps the groups dim sharded with
    # the batch and fuses per-group collectives into one wide all-to-all;
    # spmd_axis_name pins every internal buffer's group dim to the DP axes;
    # checkpoint: dispatch buffers are recomputed in backward, not saved
    y, aux = jax.vmap(
        jax.checkpoint(route_group, prevent_cse=False),
        spmd_axis_name=moe_vmap_axes(),
    )(xg)
    y = y.reshape(n_groups * g_sz, d)[:t].reshape(b, s, d)
    return y, jnp.mean(aux)
