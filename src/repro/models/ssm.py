"""Mamba-2 (SSD: state-space duality) mixer - chunked matmul-friendly form.

The SSD algorithm maps the selective state-space recurrence

    h[t] = exp(dt[t] A) h[t-1] + dt[t] B[t] (x) x[t];   y[t] = C[t] . h[t] + D x[t]

onto chunk-local matmuls (MXU-friendly: the intra-chunk term is an L x L
masked-decay attention-like matmul) plus a sequential inter-chunk state scan -
this is the TPU-native adaptation of the CUDA scan kernels (DESIGN.md SS3).

Decode is O(1): a single state update per token, so long_500k decode carries a
constant-size cache (no KV growth) - the reason mamba2 runs the 500k cell.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.imc_linear import DIGITAL, IMCConfig, linear
from repro.launch.sharding import ws
from repro.models.layers import dense_init


def ssm_dims(d_model: int, expand: int, head_dim: int, groups: int, state: int):
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    conv_ch = d_inner + 2 * groups * state
    return d_inner, n_heads, conv_ch


def init_ssm(key, d_model, expand, head_dim, groups, state, conv_width, dtype):
    d_inner, n_heads, conv_ch = ssm_dims(d_model, expand, head_dim, groups, state)
    ks = jax.random.split(key, 6)
    d_proj = 2 * d_inner + 2 * groups * state + n_heads
    # dt_bias: inverse-softplus of dt ~ U[1e-3, 1e-1]
    dt = jnp.exp(
        jax.random.uniform(ks[2], (n_heads,))
        * (math.log(0.1) - math.log(1e-3))
        + math.log(1e-3)
    )
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))
    return {
        "in_proj": dense_init(ks[0], d_model, d_proj, dtype),
        "conv_w": (jax.random.normal(ks[1], (conv_width, conv_ch)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(
            jax.random.uniform(ks[3], (n_heads,), minval=1.0, maxval=16.0)
        ),
        "D": jnp.ones((n_heads,)),
        "dt_bias": dt_bias,
        "norm_scale": jnp.zeros((d_inner,), dtype),
        "out_proj": dense_init(ks[4], d_inner, d_model, dtype),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: (B, S, C), w: (W, C)."""
    width = w.shape[0]
    out = jnp.zeros_like(x)
    for u in range(width):
        shift = width - 1 - u
        xs = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, : x.shape[1]]
        out = out + xs * w[u]
    return out + b


def _gated_norm(y, z, scale, eps=1e-6):
    """Mamba-2 RMSNormGated: rmsnorm(y * silu(z))."""
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    yf = y.astype(jnp.float32)
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))).astype(
        y.dtype
    )


def _split_proj(params, x, cfg, imc, rng):
    d_inner, n_heads, _ = ssm_dims(
        cfg.d_model, cfg.ssm_expand, cfg.ssm_head_dim, cfg.ssm_groups, cfg.ssm_state
    )
    gn = cfg.ssm_groups * cfg.ssm_state
    proj = linear(params["in_proj"], x, imc, rng, site="ssm.in_proj")
    z = proj[..., :d_inner]
    xbc = proj[..., d_inner : 2 * d_inner + 2 * gn]
    dt_raw = proj[..., 2 * d_inner + 2 * gn :]
    return z, xbc, dt_raw, d_inner, n_heads


def ssm_forward(params, x, cfg, imc: IMCConfig = DIGITAL, rng=None):
    """Full-sequence SSD. x: (B, S, d_model)."""
    b, s, _ = x.shape
    hd, g, n = cfg.ssm_head_dim, cfg.ssm_groups, cfg.ssm_state
    z, xbc, dt_raw, d_inner, n_heads = _split_proj(params, x, cfg, imc, rng)
    xbc = jax.nn.silu(
        _causal_conv(xbc, params["conv_w"], params["conv_b"]).astype(jnp.float32)
    ).astype(x.dtype)
    xs = xbc[..., :d_inner].reshape(b, s, n_heads, hd)
    bmat = xbc[..., d_inner : d_inner + g * n].reshape(b, s, g, n)
    cmat = xbc[..., d_inner + g * n :].reshape(b, s, g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)
    a = -jnp.exp(params["A_log"])  # (H,)

    l = min(cfg.ssm_chunk, s)
    nc = -(-s // l)
    pad = nc * l - s
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))

    heads_per_g = n_heads // g
    xs_c = xs.reshape(b, nc, l, n_heads, hd)
    b_c = bmat.reshape(b, nc, l, g, n)
    c_c = cmat.reshape(b, nc, l, g, n)
    dt_c = dt.reshape(b, nc, l, n_heads)
    causal = jnp.tril(jnp.ones((l, l), bool))

    def chunk_body(state, inp):
        """One chunk: intra-chunk L x L decay-masked matmul + inter-chunk
        state propagation.  All L x L intermediates live only inside this
        (checkpointed) body -> O(chunk) transient memory, flash-style."""
        x_ch, b_ch, c_ch, dt_ch = inp  # (B,L,H,P), (B,L,G,N), (B,L,G,N), (B,L,H)
        da = dt_ch * a  # (B,L,H)
        cum = jnp.cumsum(da, axis=1)
        xdt = x_ch.astype(jnp.float32) * dt_ch[..., None]  # (B,L,H,P)
        # intra: y[l1] += (C[l1].B[l2]) exp(cum[l1]-cum[l2]) dt[l2] x[l2]
        cb = jnp.einsum("blgn,bsgn->bgls", c_ch.astype(jnp.float32),
                        b_ch.astype(jnp.float32))  # (B,G,L,L)
        cb = jnp.repeat(cb, heads_per_g, axis=1)  # (B,H,L,L)
        decay = jnp.exp(
            cum.transpose(0, 2, 1)[..., :, None]
            - cum.transpose(0, 2, 1)[..., None, :]
        )  # (B,H,L,L)
        m = jnp.where(causal, cb * decay, 0.0)
        y = jnp.einsum("bhls,bshp->blhp", m, xdt)
        # inter: y[l] += C[l] . (exp(cum[l]) * state_in)
        ch = jnp.repeat(c_ch, heads_per_g, axis=2).astype(jnp.float32)
        y = y + jnp.einsum("blhn,bhnp->blhp", ch, state) * jnp.exp(cum)[..., None]
        # state update: S' = exp(cum[-1]) S + sum_l exp(cum[-1]-cum[l]) dt B (x) x
        tail = jnp.exp(cum[:, -1:, :] - cum)  # (B,L,H)
        bh = jnp.repeat(b_ch, heads_per_g, axis=2).astype(jnp.float32)
        s_c = jnp.einsum("blhn,blhp->bhnp", bh, xdt * tail[..., None])
        new_state = jnp.exp(cum[:, -1, :])[..., None, None] * state + s_c
        return new_state, y

    state0 = jnp.zeros((b, n_heads, n, hd), jnp.float32)
    xs_scan = (
        jnp.moveaxis(xs_c, 1, 0),
        jnp.moveaxis(b_c, 1, 0),
        jnp.moveaxis(c_c, 1, 0),
        jnp.moveaxis(dt_c, 1, 0),
    )
    final_state, y = jax.lax.scan(
        jax.checkpoint(chunk_body, prevent_cse=False), state0, xs_scan
    )
    y = jnp.moveaxis(y, 0, 1).reshape(b, nc * l, n_heads, hd)[:, :s]
    y = y + params["D"][None, None, :, None] * xs[:, :s].astype(jnp.float32)
    y = y.reshape(b, s, d_inner).astype(x.dtype)
    y = _gated_norm(y, z, params["norm_scale"])
    y = ws(y, "act_btf")
    return linear(params["out_proj"], y, imc, rng,
                  site="ssm.out_proj"), final_state


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def init_ssm_cache(batch, cfg, dtype):
    d_inner, n_heads, conv_ch = ssm_dims(
        cfg.d_model, cfg.ssm_expand, cfg.ssm_head_dim, cfg.ssm_groups, cfg.ssm_state
    )
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_ch), dtype),
        "state": jnp.zeros(
            (batch, n_heads, cfg.ssm_state, cfg.ssm_head_dim), jnp.float32
        ),
    }


def ssm_decode(params, x, cache, cfg, imc: IMCConfig = DIGITAL, rng=None):
    """One-token step. x: (B, 1, d_model). Returns (y, new_cache)."""
    b = x.shape[0]
    hd, g, n = cfg.ssm_head_dim, cfg.ssm_groups, cfg.ssm_state
    z, xbc, dt_raw, d_inner, n_heads = _split_proj(params, x, cfg, imc, rng)
    # conv with cached context
    hist = jnp.concatenate([cache["conv"], xbc], axis=1)  # (B, W, C)
    conv_out = (
        jnp.einsum("bwc,wc->bc", hist.astype(jnp.float32),
                   params["conv_w"].astype(jnp.float32))
        + params["conv_b"].astype(jnp.float32)
    )[:, None, :]
    xbc_a = jax.nn.silu(conv_out).astype(x.dtype)
    new_conv = hist[:, 1:]

    xs = xbc_a[..., :d_inner].reshape(b, n_heads, hd)
    bmat = xbc_a[..., d_inner : d_inner + g * n].reshape(b, g, n)
    cmat = xbc_a[..., d_inner + g * n :].reshape(b, g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)[:, 0] + params["dt_bias"])  # (B,H)
    a = -jnp.exp(params["A_log"])
    heads_per_g = n_heads // g

    decay = jnp.exp(dt * a)  # (B,H)
    bh = jnp.repeat(bmat, heads_per_g, axis=1).astype(jnp.float32)  # (B,H,N)
    ch = jnp.repeat(cmat, heads_per_g, axis=1).astype(jnp.float32)
    dbx = dt[..., None, None] * bh[..., :, None] * xs.astype(jnp.float32)[..., None, :]
    state = cache["state"] * decay[..., None, None] + dbx  # (B,H,N,P)
    y = jnp.einsum("bhn,bhnp->bhp", ch, state)
    y = y + params["D"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, 1, d_inner).astype(x.dtype)
    y = _gated_norm(y, z, params["norm_scale"])
    out = linear(params["out_proj"], y, imc, rng, site="ssm.out_proj")
    return out, {"conv": new_conv, "state": state}
