"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Block structure (Griffin "recurrent block"):
  x-branch: linear -> temporal conv1d -> RG-LRU
  y-branch: linear -> GeLU
  out = (x-branch * y-branch) -> linear

RG-LRU core (per channel):
  r_t = sigmoid(lam_a * x_t + b_a)          (recurrence gate; diagonal weights -
  i_t = sigmoid(lam_i * x_t + b_i)           see DESIGN.md SS7: Griffin uses
  a_t = exp(-c * softplus(A) * r_t)          block-diagonal; we use diagonal)
  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The linear recurrence runs as a jax.lax.associative_scan (log-depth, TPU
friendly) for train/prefill and as a single step for decode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.imc_linear import DIGITAL, IMCConfig, linear
from repro.launch.sharding import ws
from repro.models.layers import dense_init

RG_C = 8.0  # Griffin's fixed temperature


def init_rglru(key, d_model: int, width: int, conv_width: int, dtype):
    ks = jax.random.split(key, 7)
    return {
        "rg_x": dense_init(ks[0], d_model, width, dtype),
        "rg_gate": dense_init(ks[1], d_model, width, dtype),
        "rg_out": dense_init(ks[2], width, d_model, dtype),
        "conv_w": (jax.random.normal(ks[3], (conv_width, width)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((width,), dtype),
        # RG-LRU per-channel parameters
        "rg_a": jnp.log(jnp.expm1(  # softplus^-1 of A with a^c in [0.9, 0.999]
            -jnp.log(
                jax.random.uniform(ks[4], (width,), minval=0.9, maxval=0.999)
            ) / RG_C
        )),
        "rg_input_gate_w": (jax.random.normal(ks[5], (width,)) * 0.1),
        "rg_rec_gate_w": (jax.random.normal(ks[6], (width,)) * 0.1),
        "rg_input_gate_b": jnp.zeros((width,)),
        "rg_rec_gate_b": jnp.zeros((width,)),
    }


def _causal_conv(x, w, b):
    width = w.shape[0]
    out = jnp.zeros_like(x)
    for u in range(width):
        shift = width - 1 - u
        xs = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, : x.shape[1]]
        out = out + xs * w[u]
    return out + b


def _gates(params, xb):
    xf = xb.astype(jnp.float32)
    r = jax.nn.sigmoid(xf * params["rg_rec_gate_w"] + params["rg_rec_gate_b"])
    i = jax.nn.sigmoid(xf * params["rg_input_gate_w"] + params["rg_input_gate_b"])
    log_a = -RG_C * jax.nn.softplus(params["rg_a"]) * r  # (..., W), <= 0
    a = jnp.exp(log_a)
    gated_x = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * xf)
    return a, gated_x


def rglru_forward(params, x, cfg, imc: IMCConfig = DIGITAL, rng=None, h0=None):
    """Full-sequence RG block. x: (B, S, d_model). Returns (y, h_last)."""
    xb = linear(params["rg_x"], x, imc, rng, site="rg.x")  # (B, S, W)
    gate = jax.nn.gelu(
        linear(params["rg_gate"], x, imc, rng,
               site="rg.gate").astype(jnp.float32)
    )
    xb = _causal_conv(xb, params["conv_w"], params["conv_b"])
    xb = ws(xb, "act_btf")
    a, gx = _gates(params, xb)  # (B, S, W) f32

    if h0 is not None:
        # fold the initial state in as a virtual step at t=0
        gx = gx.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))
        # (exact: h_1 = a_1 h_0 + gx_1)
        a = a.at[:, 0].set(jnp.zeros_like(a[:, 0]))

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    a_s, h = jax.lax.associative_scan(combine, (a, gx), axis=1)
    y = (h * gate).astype(x.dtype)
    y = ws(y, "act_btf")
    out = linear(params["rg_out"], y, imc, rng, site="rg.out")
    return out, h[:, -1].astype(jnp.float32)


def init_rglru_cache(batch: int, width: int, conv_width: int, dtype):
    return {
        "conv": jnp.zeros((batch, conv_width - 1, width), dtype),
        "h": jnp.zeros((batch, width), jnp.float32),
    }


def rglru_decode(params, x, cache, cfg, imc: IMCConfig = DIGITAL, rng=None):
    """One-token step. x: (B, 1, d_model). Returns (y, new_cache)."""
    xb = linear(params["rg_x"], x, imc, rng, site="rg.x")  # (B, 1, W)
    gate = jax.nn.gelu(
        linear(params["rg_gate"], x, imc, rng,
               site="rg.gate").astype(jnp.float32)
    )
    hist = jnp.concatenate([cache["conv"], xb], axis=1)  # (B, W_conv, W)
    conv_out = (
        jnp.einsum("bwc,wc->bc", hist.astype(jnp.float32),
                   params["conv_w"].astype(jnp.float32))
        + params["conv_b"].astype(jnp.float32)
    )[:, None, :]
    a, gx = _gates(params, conv_out)  # (B, 1, W)
    h = a[:, 0] * cache["h"] + gx[:, 0]  # (B, W)
    y = (h[:, None, :] * gate).astype(x.dtype)
    out = linear(params["rg_out"], y, imc, rng, site="rg.out")
    return out, {"conv": hist[:, 1:].astype(cache["conv"].dtype), "h": h}
