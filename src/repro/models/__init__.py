"""Model zoo: composable decoder blocks covering all 10 assigned architectures."""
from repro.models.model import (  # noqa: F401
    decode_step,
    forward,
    init_cache,
    init_paged_cache,
    init_params,
    loss_fn,
    param_count,
    prefill,
)
