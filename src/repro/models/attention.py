"""GQA/MQA/MHA attention: flash-style blocked causal attention (pure JAX,
lax.scan online-softmax), an exact banded path for sliding windows, and a
single-token decode path designed for sequence-sharded KV caches.

Memory behaviour is the point: full S x S score matrices are never
materialized, so prefill_32k compiles within HBM at the production meshes
(deliverable (e)); the decode path's softmax over the sequence axis is sharded
over the ``model`` mesh axis (logical name "kv_bshd"), which XLA GSPMD turns
into the flash-decode partial-max/partial-sum collective pattern (DESIGN SS5).
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.imc_linear import DIGITAL, IMCConfig, linear
from repro.kernels.paged_attention import paged_attention_decode, write_routing
from repro.launch.sharding import attn_carry_pin, attn_expand_groups, attn_grad_spec, ws, ws_attn
from repro.models.layers import dense_init, rope, softcap

NEG_INF = -1e30


def init_attention(key, d_model: int, n_heads: int, n_kv: int, head_dim: int, dtype):
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d_model, n_heads * head_dim, dtype),
        "wk": dense_init(ks[1], d_model, n_kv * head_dim, dtype),
        "wv": dense_init(ks[2], d_model, n_kv * head_dim, dtype),
        "wo": dense_init(ks[3], n_heads * head_dim, d_model, dtype),
    }


class AttnDims(NamedTuple):
    n_heads: int
    n_kv: int
    head_dim: int
    scale: float
    softcap_val: Optional[float]
    window: Optional[int]
    q_block: int
    kv_block: int
    rope_theta: float
    use_rope: bool
    # paged decode attention: True streams KV blocks through the fused
    # online-softmax kernel (repro.kernels.paged_attention); False takes the
    # reference gather path that materializes pool[bt] (escape hatch,
    # cfg.decode_attn="gather")
    paged_kernel: bool = True


def _project_qkv(params, x, dims: AttnDims, positions, imc, rng,
                 site_prefix: str = "attn"):
    b, s, _ = x.shape
    q = linear(params["wq"], x, imc, rng,
               site=f"{site_prefix}.wq").reshape(b, s, dims.n_heads,
                                                 dims.head_dim)
    k = linear(params["wk"], x, imc, rng,
               site=f"{site_prefix}.wk").reshape(b, s, dims.n_kv,
                                                 dims.head_dim)
    v = linear(params["wv"], x, imc, rng,
               site=f"{site_prefix}.wv").reshape(b, s, dims.n_kv,
                                                 dims.head_dim)
    if dims.use_rope:
        q = rope(q, positions, dims.rope_theta)
        k = rope(k, positions, dims.rope_theta)
    q = ws(q, "act_bthd")
    return q, k, v


def _scores(q_blk, k_blk, dims: AttnDims):
    """q: (B, QB, Hkv, G, hd), k: (B, KB, Hkv, hd) -> (B, Hkv, G, QB, KB) f32."""
    s = jnp.einsum(
        "bqhgd,bkhd->bhgqk",
        q_blk.astype(jnp.float32),
        k_blk.astype(jnp.float32),
    )
    s = s * dims.scale
    if dims.softcap_val is not None:
        s = dims.softcap_val * jnp.tanh(s / dims.softcap_val)
    return s


def _block_mask(q_pos, k_pos, s_kv, window):
    mask = q_pos[:, None] >= k_pos[None, :]
    mask = jnp.logical_and(mask, (k_pos < s_kv)[None, :])
    if window is not None:
        mask = jnp.logical_and(mask, q_pos[:, None] - k_pos[None, :] < window)
    return mask


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash_core(q, k, v, dims: AttnDims, q_offset: int, s_kv_true: int):
    out, _lse = _flash_fwd_impl(q, k, v, dims, q_offset, s_kv_true)
    return out


def _flash_fwd_impl(q, k, v, dims: AttnDims, q_offset: int, s_kv_true: int):
    """q: (B, nQ*QB, Hkv, G, hd) padded; k, v: (B, nKV*KB, Hkv, hd) padded.
    Returns (out same shape as q, lse (B, Hkv, G, nQ*QB))."""
    b, s_qp, hkv, g, hd = q.shape
    qb, kb = dims.q_block, dims.kv_block
    qb, kb = min(qb, s_qp), min(kb, k.shape[1])
    n_q, n_kv = s_qp // qb, k.shape[1] // kb
    qg = q.reshape(b, n_q, qb, hkv, g, hd)
    kv_idx = jnp.arange(n_kv)
    pin = attn_carry_pin(hkv, g)

    def q_block_fn(q_blk, iq):
        q_pos = q_offset + iq * qb + jnp.arange(qb)

        def kv_step(carry, jk):
            m, l, acc = carry
            k_blk = jax.lax.dynamic_slice_in_dim(k, jk * kb, kb, axis=1)
            v_blk = jax.lax.dynamic_slice_in_dim(v, jk * kb, kb, axis=1)
            s = _scores(q_blk, k_blk, dims)  # (B, Hkv, G, QB, KB) f32
            k_pos = jk * kb + jnp.arange(kb)
            mask = _block_mask(q_pos, k_pos, s_kv_true, dims.window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = pin(jnp.maximum(m, jnp.max(s, axis=-1)))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = pin(l * corr + jnp.sum(p, axis=-1))
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p, v_blk.astype(jnp.float32))
            acc_new = pin(acc * corr[..., None] + pv)
            return (m_new, l_new, acc_new), None

        m0 = pin(jnp.full((b, hkv, g, qb), NEG_INF, jnp.float32))
        l0 = pin(jnp.zeros((b, hkv, g, qb), jnp.float32))
        a0 = pin(jnp.zeros((b, hkv, g, qb, hd), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), kv_idx)
        out = acc / jnp.maximum(l[..., None], 1e-30)  # (B, Hkv, G, QB, hd)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))  # (B, Hkv, G, QB)
        return jnp.transpose(out, (0, 3, 1, 2, 4)), lse

    def scan_body(_, inputs):
        q_blk, iq = inputs
        return None, q_block_fn(q_blk, iq)

    _, (out, lse) = jax.lax.scan(
        scan_body, None, (jnp.moveaxis(qg, 1, 0), jnp.arange(n_q))
    )
    out = jnp.moveaxis(out, 0, 1).reshape(b, s_qp, hkv, g, hd).astype(q.dtype)
    lse = jnp.moveaxis(lse, 0, 3).reshape(b, hkv, g, s_qp)
    return out, lse


def _flash_fwd(q, k, v, dims, q_offset, s_kv_true):
    out, lse = _flash_fwd_impl(q, k, v, dims, q_offset, s_kv_true)
    return out, (q, k, v, out, lse)


def _flash_bwd(dims: AttnDims, q_offset: int, s_kv_true: int, res, dout):
    """True flash backward: recompute score blocks; O(block) memory."""
    q, k, v, out, lse = res
    b, s_qp, hkv, g, hd = q.shape
    qb = min(dims.q_block, s_qp)
    kb = min(dims.kv_block, k.shape[1])
    n_q = s_qp // qb
    pin_c = attn_carry_pin(hkv, g)
    dout = dout.astype(jnp.float32)
    # D = rowsum(dout * out): (B, Hkv, G, Sq)
    dmat = jnp.einsum("bshgd,bshgd->bhgs", dout, out.astype(jnp.float32))
    qg = jnp.moveaxis(q.reshape(b, n_q, qb, hkv, g, hd), 1, 0)
    dog = jnp.moveaxis(dout.reshape(b, n_q, qb, hkv, g, hd), 1, 0)
    lse_g = jnp.moveaxis(lse.reshape(b, hkv, g, n_q, qb), 3, 0)
    d_g = jnp.moveaxis(dmat.reshape(b, hkv, g, n_q, qb), 3, 0)
    n_kv = k.shape[1] // kb
    kv_idx = jnp.arange(n_kv)

    def q_block_step(carry, inp):
        dk_full, dv_full = carry
        q_blk, do_blk, lse_blk, d_blk, iq = inp
        q_pos = q_offset + iq * qb + jnp.arange(qb)

        def kv_step(c, jk):
            dq_blk, dk_f, dv_f = c
            dk_f = _pin(dk_f)
            dv_f = _pin(dv_f)
            k_blk = jax.lax.dynamic_slice_in_dim(k, jk * kb, kb, axis=1)
            v_blk = jax.lax.dynamic_slice_in_dim(v, jk * kb, kb, axis=1)
            s = _scores(q_blk, k_blk, dims)
            k_pos = jk * kb + jnp.arange(kb)
            mask = _block_mask(q_pos, k_pos, s_kv_true, dims.window)
            s_masked = jnp.where(mask[None, None, None], s, NEG_INF)
            p = jnp.exp(s_masked - lse_blk[..., None])  # (B,Hkv,G,QB,KB)
            dv_b = jnp.einsum("bhgqk,bqhgd->bkhd", p, do_blk)
            dp = jnp.einsum("bqhgd,bkhd->bhgqk", do_blk,
                            v_blk.astype(jnp.float32))
            ds = p * (dp - d_blk[..., None])
            if dims.softcap_val is not None:
                # d/ds_raw of c*tanh(s_raw/c) = 1 - (s_capped/c)^2; use a
                # mask-safe s (masked lanes have p = 0 but 0 * inf = nan)
                s_safe = jnp.where(mask[None, None, None], s_masked, 0.0)
                ds = ds * (1.0 - (s_safe / dims.softcap_val) ** 2)
            ds = ds * dims.scale
            ds = jnp.where(mask[None, None, None], ds, 0.0)
            dq_b = jnp.einsum("bhgqk,bkhd->bqhgd", ds,
                              k_blk.astype(jnp.float32))
            dk_b = jnp.einsum("bhgqk,bqhgd->bkhd", ds, q_blk.astype(jnp.float32))
            dk_f = jax.lax.dynamic_update_slice_in_dim(
                dk_f, jax.lax.dynamic_slice_in_dim(dk_f, jk * kb, kb, 1) + dk_b,
                jk * kb, axis=1,
            )
            dv_f = jax.lax.dynamic_update_slice_in_dim(
                dv_f, jax.lax.dynamic_slice_in_dim(dv_f, jk * kb, kb, 1) + dv_b,
                jk * kb, axis=1,
            )
            return (dq_blk + dq_b, dk_f, dv_f), None

        dq0 = jnp.zeros((b, qb, hkv, g, hd), jnp.float32)
        (dq_blk, dk_full, dv_full), _ = jax.lax.scan(
            kv_step, (dq0, dk_full, dv_full), kv_idx
        )
        return (dk_full, dv_full), dq_blk  # dq layout (B, QB, Hkv, G, hd)

    # keep grad-accumulator carries in the same (heads-on-model / replicated
    # for MQA) layout as k/v: without the pin, GSPMD reshards them with
    # all-to-alls every block
    gspec = attn_grad_spec(hkv, g)

    def _pin(x):
        if gspec is None:
            return x
        mesh, spec = gspec
        try:
            from jax.sharding import NamedSharding
            return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
        except (ValueError, TypeError):
            return x

    dk0 = _pin(jnp.zeros(k.shape, jnp.float32))
    dv0 = _pin(jnp.zeros(v.shape, jnp.float32))
    (dk, dv), dq = jax.lax.scan(
        q_block_step, (dk0, dv0),
        (qg, dog, lse_g, d_g, jnp.arange(n_q)),
    )
    dq = jnp.moveaxis(dq, 0, 1).reshape(b, s_qp, hkv, g, hd)
    # softcap note: _scores applies softcap BEFORE masking; ds above already
    # includes the tanh jacobian, and dq/dk absorbed dims.scale.
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash_core.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q, k, v, dims: AttnDims, q_offset=0):
    """Blocked causal attention, O(S*S) compute, O(S*KB) memory, with a true
    flash (recompute-based, custom_vjp) backward.

    q: (B, S, Hq, hd); k, v: (B, Skv, Hkv, hd).  Returns (B, S, Hq, hd).
    ``q_offset``: absolute position of q[0] relative to k[0] (0 for self-attn).
    """
    b, s_q, hq, hd = q.shape
    _, s_kv, hkv, _ = k.shape
    g = hq // hkv
    if g > 1 and attn_expand_groups(hkv, g):
        # GQA -> MHA expansion for clean head sharding (dk/dv fold back
        # through the AD of the repeat)
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
        hkv, g = hq, 1
    qb, kb = min(dims.q_block, s_q), min(dims.kv_block, s_kv)
    n_q = -(-s_q // qb)
    n_kv = -(-s_kv // kb)
    pad_q = n_q * qb - s_q
    pad_kv = n_kv * kb - s_kv
    q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    qg, k, v = ws_attn(q.reshape(b, n_q * qb, hkv, g, hd), k, v)
    out = _flash_core(qg, k, v, dims, q_offset, s_kv)
    return out[:, :s_q].reshape(b, s_q, hq, hd)


def banded_attention(q, k, v, dims: AttnDims):  # noqa: C901
    """Exact sliding-window attention with O(S * W) compute: each q block only
    reads the [qo - W, qo + QB) slice of K/V (front-padded)."""
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    w = dims.window
    qb = min(dims.q_block, s)
    n_q = -(-s // qb)
    pad_q = n_q * qb - s
    q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    # front-pad K/V by W so every block slice is in range
    k_p = jnp.pad(k, ((0, 0), (w, pad_q), (0, 0), (0, 0)))
    v_p = jnp.pad(v, ((0, 0), (w, pad_q), (0, 0), (0, 0)))
    span = w + qb
    qg = q.reshape(b, n_q, qb, hkv, g, hd)

    def q_block_fn(q_blk, iq):
        start = iq * qb  # in padded coords this is qo - W + W
        k_blk = jax.lax.dynamic_slice_in_dim(k_p, start, span, axis=1)
        v_blk = jax.lax.dynamic_slice_in_dim(v_p, start, span, axis=1)
        s_mat = _scores(q_blk, k_blk, dims)  # (B, Hkv, G, QB, span)
        q_pos = iq * qb + jnp.arange(qb)
        k_pos = iq * qb - w + jnp.arange(span)  # absolute (may be < 0 = pad)
        mask = (
            (q_pos[:, None] >= k_pos[None, :])
            & (q_pos[:, None] - k_pos[None, :] < w)
            & (k_pos >= 0)[None, :]
            & (k_pos < s)[None, :]
        )
        s_mat = jnp.where(mask[None, None, None], s_mat, NEG_INF)
        p = jax.nn.softmax(s_mat, axis=-1)
        out = jnp.einsum("bhgqk,bkhd->bhgqd", p, v_blk.astype(jnp.float32))
        return jnp.transpose(out, (0, 3, 1, 2, 4))

    def scan_body(_, inputs):
        q_blk, iq = inputs
        return None, q_block_fn(q_blk, iq)

    # checkpoint: backward recomputes each banded score block
    _, out = jax.lax.scan(
        jax.checkpoint(scan_body, prevent_cse=False), None,
        (jnp.moveaxis(qg, 1, 0), jnp.arange(n_q)),
    )
    out = jnp.moveaxis(out, 0, 1).reshape(b, n_q * qb, hq, hd)
    return out[:, :s].astype(q.dtype)


def attention_forward(
    params,
    x,  # (B, S, d)
    dims: AttnDims,
    positions,  # (B, S) absolute positions
    imc: IMCConfig = DIGITAL,
    rng=None,
    site_prefix: str = "attn",
):
    q, k, v = _project_qkv(params, x, dims, positions, imc, rng, site_prefix)
    if dims.window is not None and dims.window < x.shape[1]:
        ctx = banded_attention(q, k, v, dims)
    else:
        # window >= S covers every causal pair: run flash with the window
        # mask dropped instead of relying on it being a causal no-op
        ctx = flash_attention(q, k, v, dims._replace(window=None))
    b, s = x.shape[:2]
    ctx = ctx.reshape(b, s, dims.n_heads * dims.head_dim)
    return linear(params["wo"], ctx, imc, rng, site=f"{site_prefix}.wo")


# ---------------------------------------------------------------------------
# decode (one new token against a cache)
# ---------------------------------------------------------------------------


def init_kv_cache(batch: int, cache_len: int, n_kv: int, head_dim: int, dtype):
    shape = (batch, cache_len, n_kv, head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def init_paged_kv_cache(batch: int, num_blocks: int, block_size: int,
                        max_blocks: int, n_kv: int, head_dim: int, dtype):
    """Paged KV cache: a shared block pool plus a per-slot block table.

    ``pk``/``pv`` are the physical pools (num_blocks, block_size, Hkv, hd);
    ``bt`` maps each slot's logical block j to a physical block id.  Physical
    block 0 is the GARBAGE block: it is never allocated to a request, block
    tables point to it for unallocated logical blocks, and inactive rows'
    decode writes are routed to it (see ``attention_decode``).
    """
    shape = (num_blocks, block_size, n_kv, head_dim)
    return {
        "pk": jnp.zeros(shape, dtype),
        "pv": jnp.zeros(shape, dtype),
        "bt": jnp.zeros((batch, max_blocks), jnp.int32),
    }


def _decode_attend(params, x, q, k, v, valid, dims: AttnDims, imc, rng,
                   site_prefix: str = "attn"):
    """Single-token attention over a (B, Skv, Hkv, hd) K/V view with a
    (B, Skv) validity mask; shared by the contiguous and paged cache paths."""
    b = x.shape[0]
    hq, hkv, hd = dims.n_heads, dims.n_kv, dims.head_dim
    g = hq // hkv
    qg = q.reshape(b, hkv, g, hd)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg.astype(jnp.float32), k.astype(jnp.float32))
    s = s * dims.scale
    if dims.softcap_val is not None:
        s = dims.softcap_val * jnp.tanh(s / dims.softcap_val)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    # softmax over the (possibly model-axis-sharded) sequence dim: GSPMD emits
    # the partial-max/sum + all-reduce flash-decode pattern automatically
    p = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhgk,bkhd->bhgd", p, v.astype(jnp.float32))
    ctx = ctx.reshape(b, 1, hq * hd).astype(x.dtype)
    return linear(params["wo"], ctx, imc, rng, site=f"{site_prefix}.wo")


def _attention_decode_paged(params, x, cache, pos_b, dims: AttnDims, imc, rng,
                            active, site_prefix: str = "attn"):
    """Paged decode: scatter the new K/V into the tail block and attend over
    the block table.

    Default path (``dims.paged_kernel``): the fused kernel in
    ``repro.kernels.paged_attention`` walks the block table in-kernel,
    streaming one physical block per step into an online-softmax accumulator
    and scattering the new token inside the same kernel - the gathered
    ``pool[bt]`` copy never exists.  Escape hatch (``cfg.decode_attn =
    "gather"``): scatter, then materialize the gathered view and run a
    full-row softmax (the reference math, kept selectable for debugging).

    Masked (invalid) lanes read garbage from unallocated blocks but contribute
    exactly zero probability, so both paths reproduce the contiguous layout
    token-for-token.  New-token writes follow the garbage-block-0 routing
    contract (``paged_attention.write_routing``): rows with ``active ==
    False`` (a retired slot's stale table may point at physical blocks the
    allocator already handed to another request) AND rows whose position
    overran the slot's capacity (clipping the logical block index would
    clobber the slot's last LIVE block) write to garbage block 0.
    """
    assert dims.window is None, "paged KV caches are global-attention only"
    b = x.shape[0]
    positions = pos_b[:, None]
    q, k_new, v_new = _project_qkv(params, x, dims, positions, imc, rng,
                                   site_prefix)
    pk, pv, bt = cache["pk"], cache["pv"], cache["bt"]
    block = pk.shape[1]
    max_blocks = bt.shape[1]
    hq, hkv, hd = dims.n_heads, dims.n_kv, dims.head_dim
    if dims.paged_kernel:
        g = hq // hkv
        qg = q.reshape(b, hkv, g, hd)
        ctx, pk, pv = paged_attention_decode(
            qg, k_new[:, 0], v_new[:, 0], pk, pv, bt, pos_b, active,
            scale=dims.scale, softcap=dims.softcap_val)
        ctx = ctx.reshape(b, 1, hq * hd).astype(x.dtype)
        y = linear(params["wo"], ctx, imc, rng, site=f"{site_prefix}.wo")
        return y, {"pk": pk, "pv": pv, "bt": bt}
    dest, off = write_routing(bt, pos_b, block, active)
    pk = pk.at[dest, off].set(k_new[:, 0].astype(pk.dtype))
    pv = pv.at[dest, off].set(v_new[:, 0].astype(pv.dtype))
    s_kv = max_blocks * block
    # head-sharded logical name: the pools themselves are head-sharded under
    # the tensor-parallel serve engine, so the gathered view must keep heads
    # on the model axis (sequence-sharding here would all-to-all every step)
    k = ws(pk[bt].reshape(b, s_kv, hkv, hd), "paged_kv_bshd")
    v = ws(pv[bt].reshape(b, s_kv, hkv, hd), "paged_kv_bshd")
    valid = jnp.arange(s_kv)[None, :] <= pos_b[:, None]
    y = _decode_attend(params, x, q, k, v, valid, dims, imc, rng, site_prefix)
    return y, {"pk": pk, "pv": pv, "bt": bt}


def attention_decode(
    params,
    x,  # (B, 1, d)
    cache,  # {"k","v"}: (B, Skv, Hkv, hd) (ring buffer when window), or a
    #         paged {"pk","pv","bt"} block pool (global attention only)
    pos,  # int32 scalar OR (B,) per-slot vector: tokens already in the cache
    dims: AttnDims,
    imc: IMCConfig = DIGITAL,
    rng=None,
    active=None,  # optional (B,) bool: rows allowed to write their K/V slot
    site_prefix: str = "attn",
):
    b = x.shape[0]
    # per-slot positions: a scalar broadcasts to the whole batch (wave-style
    # synchronized decode); a (B,) vector lets every slot sit at its own depth
    # (continuous batching with unequal prompt lengths)
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    if "pk" in cache:
        return _attention_decode_paged(params, x, cache, pos_b, dims, imc,
                                       rng, active, site_prefix)
    positions = pos_b[:, None]
    q, k_new, v_new = _project_qkv(params, x, dims, positions, imc, rng,
                                   site_prefix)
    s_kv = cache["k"].shape[1]
    # ring buffer for sliding windows; plain append for global attention
    if dims.window is not None:
        slot = pos_b % s_kv
    else:
        slot = jnp.minimum(pos_b, s_kv - 1)
    rows = jnp.arange(b)
    k = cache["k"].at[rows, slot].set(k_new[:, 0].astype(cache["k"].dtype))
    v = cache["v"].at[rows, slot].set(v_new[:, 0].astype(cache["v"].dtype))
    k = ws(k, "kv_bshd")
    v = ws(v, "kv_bshd")
    idx = jnp.arange(s_kv)
    if dims.window is not None:
        valid = jnp.where(
            (pos_b + 1 >= s_kv)[:, None],
            jnp.ones((b, s_kv), bool),
            idx[None, :] <= pos_b[:, None],
        )
    else:
        valid = idx[None, :] <= pos_b[:, None]
    y = _decode_attend(params, x, q, k, v, valid, dims, imc, rng, site_prefix)
    return y, {"k": k, "v": v}
