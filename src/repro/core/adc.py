"""Column ADC energy model (paper SSV-C, eq. 26, after Murmann [48]):

    E_ADC = k1 (B_ADC + log2(V_DD / V_c)) + k2 (V_DD / V_c)^2 4^B_ADC

with k1 = 100 fJ (per-bit/logic term) and k2 = 1 aJ (noise-limited comparator
term).  ``V_c`` is the voltage range being quantized: a small V_c forces the ADC
into the noise-limited regime and the second term explodes as 4^B_ADC.
"""
from __future__ import annotations

import math

from repro.core.compute_models import TECH_65NM, TechParams

K1 = 100e-15  # J
K2 = 1e-18  # J


def adc_energy(
    b_adc: int,
    vdd_over_vc: float,
    tech: TechParams = TECH_65NM,
    k1: float = K1,
    k2: float = K2,
) -> float:
    """Eq. (26). ``vdd_over_vc`` = V_DD / V_c >= 1 typically."""
    r = max(vdd_over_vc, 1.0)
    return k1 * (b_adc + math.log2(r)) + k2 * r * r * 4.0**b_adc


def adc_delay(b_adc: int, tech: TechParams = TECH_65NM) -> float:
    """SAR conversion time: B_ADC bit-cycles."""
    return b_adc * tech.t_adc_per_bit
