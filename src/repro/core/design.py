"""Design-space exploration: turn the paper's SSVI design guidelines into a solver.

Given a DP dimension N, a target SNR_T, a technology node, and signal statistics,
find the minimum-energy IMC design point:

  * compute model / architecture: QS-Arch (knob: V_WL), QR-Arch (knob: C_o),
    CM (knobs: V_WL, B_w),
  * banking: if no feasible single-bank point exists at N (SNR_a caps out -
    paper SSVI bullet 4: "multi-bank IMCs will be required for high-dimensional
    DPs"), split the DP across n_banks banks of N/n_banks rows each and reduce
    digitally; the analog SNR improves (smaller N per bank) at digital cost.
  * B_ADC: assigned by MPC (eq. 15) - never BGC.

The solver reproduces the paper's qualitative guideline "QS-based architectures
are preferred at low compute SNR, QR-based at high compute SNR" (tests assert it).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Optional

import numpy as np

from repro.core import precision as prec
from repro.core.archs import CMArch, IMCArch, QRArch, QSArch
from repro.core.compute_models import TechParams, TECH_65NM
from repro.core.quant import SignalStats, UNIFORM_STATS
from repro.core import snr as snr_lib

V_WL_GRID = tuple(np.round(np.arange(0.50, 0.86, 0.025), 3))
C_O_GRID = tuple(float(c) * 1e-15 for c in (0.5, 1, 1.5, 2, 3, 4.5, 6, 9, 12, 16))
BANK_SPLITS = (1, 2, 4, 8, 16, 32)


@dataclasses.dataclass(frozen=True)
class DesignPoint:
    """A fully-specified IMC design for one dot-product shape."""

    arch_kind: str  # "qs" | "qr" | "cm"
    n: int  # total DP dimension
    n_bank: int  # rows per bank
    n_banks: int  # digital reduction fan-in
    bx: int
    bw: int
    b_adc: int
    knob: float  # V_WL (qs/cm) or C_o (qr)
    tech: str
    # predicted metrics
    snr_a_db: float
    snr_A_db: float
    snr_t_db: float
    energy_per_dp: float  # J (analog + ADC + digital reduction)
    delay_per_dp: float  # s
    edp: float

    def arch(self, stats: SignalStats = UNIFORM_STATS) -> IMCArch:
        from repro.core import scaling

        tech = scaling.node(self.tech)
        if self.arch_kind == "qs":
            return QSArch(n=self.n_bank, bx=self.bx, bw=self.bw, stats=stats,
                          tech=tech, v_wl=self.knob)
        if self.arch_kind == "qr":
            return QRArch(n=self.n_bank, bx=self.bx, bw=self.bw, stats=stats,
                          tech=tech, c_o=self.knob)
        return CMArch(n=self.n_bank, bx=self.bx, bw=self.bw, stats=stats,
                      tech=tech, v_wl=self.knob)


def _mk_arch(kind: str, n_bank: int, bx: int, bw: int, stats, tech, knob) -> IMCArch:
    if kind == "qs":
        return QSArch(n=n_bank, bx=bx, bw=bw, stats=stats, tech=tech, v_wl=knob)
    if kind == "qr":
        return QRArch(n=n_bank, bx=bx, bw=bw, stats=stats, tech=tech, c_o=knob)
    if kind == "cm":
        return CMArch(n=n_bank, bx=bx, bw=bw, stats=stats, tech=tech, v_wl=knob)
    raise ValueError(kind)


def _bank_reduction_energy(n_banks: int, width_bits: int, tech: TechParams) -> float:
    """Digital adder-tree energy for combining n_banks partial DPs."""
    return max(n_banks - 1, 0) * width_bits * tech.e_add_per_bit


def evaluate_point(
    kind: str,
    n: int,
    n_banks: int,
    bx: int,
    bw: int,
    stats: SignalStats,
    tech: TechParams,
    knob: float,
    snr_t_target_db: float,
    gamma_db: float = 0.5,
    max_rows: int = 512,
) -> Optional[DesignPoint]:
    """Returns a DesignPoint if the configuration meets the SNR target, else None."""
    n_bank = int(math.ceil(n / n_banks))
    if n_bank > max_rows or n_bank < 2:
        return None
    arch = _mk_arch(kind, n_bank, bx, bw, stats, tech, knob)

    # banked composition: per-bank DP variance is sigma_yo^2/n_banks-ish; bank
    # noises are independent => bank SNRs compose as the same SNR (both signal
    # and noise scale with n_bank). SNR_a(total) = SNR_a(bank).
    snr_a_db = arch.snr_a_db()
    snr_A_db = arch.snr_A_db()
    b_adc = arch.b_adc_min(gamma_db)
    snr_t_db = arch.snr_T_db(b_adc)
    if not math.isfinite(snr_t_db) or snr_t_db < snr_t_target_db:
        return None

    e_bank = arch.energy_per_dp(b_adc)
    width = b_adc + int(math.ceil(math.log2(max(n_banks, 2))))
    energy = n_banks * e_bank + _bank_reduction_energy(n_banks, width, tech)
    # banks operate in parallel; reduction adds one tree of log2(n_banks) adds
    delay = arch.delay_per_dp(b_adc) + math.ceil(math.log2(max(n_banks, 1)) or 0) * 1e-10
    return DesignPoint(
        arch_kind=kind,
        n=n,
        n_bank=n_bank,
        n_banks=n_banks,
        bx=bx,
        bw=bw,
        b_adc=b_adc,
        knob=knob,
        tech=tech.name,
        snr_a_db=snr_a_db,
        snr_A_db=snr_A_db,
        snr_t_db=snr_t_db,
        energy_per_dp=energy,
        delay_per_dp=delay,
        edp=energy * delay,
    )


def optimize(
    n: int,
    snr_t_target_db: float,
    stats: SignalStats = UNIFORM_STATS,
    tech: TechParams = TECH_65NM,
    kinds: Iterable[str] = ("qs", "qr", "cm"),
    bx: Optional[int] = None,
    bw: Optional[int] = None,
    objective: str = "energy",  # "energy" | "edp" | "delay"
    max_rows: int = 512,
) -> Optional[DesignPoint]:
    """Exhaustive grid search over (kind x knob x banking), min-objective subject
    to SNR_T >= target.  B_x/B_w default to the SSIII-B assignment for the target."""
    if bx is None or bw is None:
        pa = prec.assign_precisions(snr_t_target_db + 3.0, n, stats)
        bx = bx or pa.bx
        bw = bw or pa.bw

    best: Optional[DesignPoint] = None
    for kind in kinds:
        knobs = C_O_GRID if kind == "qr" else V_WL_GRID
        for knob in knobs:
            for n_banks in BANK_SPLITS:
                pt = evaluate_point(
                    kind, n, n_banks, bx, bw, stats, tech, knob,
                    snr_t_target_db, max_rows=max_rows,
                )
                if pt is None:
                    continue
                key = {
                    "energy": pt.energy_per_dp,
                    "edp": pt.edp,
                    "delay": pt.delay_per_dp,
                }[objective]
                best_key = None if best is None else {
                    "energy": best.energy_per_dp,
                    "edp": best.edp,
                    "delay": best.delay_per_dp,
                }[objective]
                if best is None or key < best_key:
                    best = pt
    return best


def pareto_sweep(
    n: int,
    stats: SignalStats = UNIFORM_STATS,
    tech: TechParams = TECH_65NM,
    kinds: Iterable[str] = ("qs", "qr", "cm"),
    targets_db: Iterable[float] = tuple(range(8, 44, 2)),
):
    """Energy-vs-SNR_T pareto frontier (the Fig. 13-style trade-off curve)."""
    out = []
    for t in targets_db:
        pt = optimize(n, t, stats=stats, tech=tech, kinds=kinds)
        if pt is not None:
            out.append((t, pt))
    return out
