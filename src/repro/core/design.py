"""Design-space exploration: turn the paper's SSVI design guidelines into a solver.

Given a DP dimension N, a target SNR_T, a technology node, and signal statistics,
find the minimum-energy IMC design point:

  * compute model / architecture: QS-Arch (knob: V_WL), QR-Arch (knob: C_o),
    CM (knobs: V_WL, B_w),
  * banking: if no feasible single-bank point exists at N (SNR_a caps out -
    paper SSVI bullet 4: "multi-bank IMCs will be required for high-dimensional
    DPs"), split the DP across n_banks banks of N/n_banks rows each and reduce
    digitally; the analog SNR improves (smaller N per bank) at digital cost.
  * B_ADC: assigned by MPC (eq. 15) - never BGC.

The solver reproduces the paper's qualitative guideline "QS-based architectures
are preferred at low compute SNR, QR-based at high compute SNR" (tests assert it).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Iterable, List, Optional

import numpy as np

from repro.core import precision as prec
from repro.core import snr as snr_lib
from repro.core.archs import (
    CMArch,
    IMCArch,
    QRArch,
    QSArch,
    binomial_clip_second_moment,
    sigma_qiy_sq,
)
from repro.core.compute_models import TECH_65NM, TechParams
from repro.core.quant import QuantSpec, SignalStats, UNIFORM_STATS

# digital reduction-tree latency per level (banked composition and
# cross-tile workload rollups share it: one calibration site)
T_REDUCE_LEVEL = 1e-10  # s

V_WL_GRID = tuple(np.round(np.arange(0.50, 0.86, 0.025), 3))
C_O_GRID = tuple(float(c) * 1e-15 for c in (0.5, 1, 1.5, 2, 3, 4.5, 6, 9, 12, 16))
BANK_SPLITS = (1, 2, 4, 8, 16, 32)


@dataclasses.dataclass(frozen=True)
class DesignPoint:
    """A fully-specified IMC design for one dot-product shape."""

    arch_kind: str  # "qs" | "qr" | "cm"
    n: int  # total DP dimension
    n_bank: int  # rows per bank
    n_banks: int  # digital reduction fan-in
    bx: int
    bw: int
    b_adc: int
    knob: float  # V_WL (qs/cm) or C_o (qr)
    tech: str
    # predicted metrics
    snr_a_db: float
    snr_A_db: float
    snr_t_db: float
    energy_per_dp: float  # J (analog + ADC + digital reduction)
    delay_per_dp: float  # s
    edp: float

    def arch(self, stats: SignalStats = UNIFORM_STATS) -> IMCArch:
        from repro.core import scaling

        tech = scaling.node(self.tech)
        if self.arch_kind == "qs":
            return QSArch(n=self.n_bank, bx=self.bx, bw=self.bw, stats=stats,
                          tech=tech, v_wl=self.knob)
        if self.arch_kind == "qr":
            return QRArch(n=self.n_bank, bx=self.bx, bw=self.bw, stats=stats,
                          tech=tech, c_o=self.knob)
        return CMArch(n=self.n_bank, bx=self.bx, bw=self.bw, stats=stats,
                      tech=tech, v_wl=self.knob)


def _mk_arch(kind: str, n_bank: int, bx: int, bw: int, stats, tech, knob) -> IMCArch:
    if kind == "qs":
        return QSArch(n=n_bank, bx=bx, bw=bw, stats=stats, tech=tech, v_wl=knob)
    if kind == "qr":
        return QRArch(n=n_bank, bx=bx, bw=bw, stats=stats, tech=tech, c_o=knob)
    if kind == "cm":
        return CMArch(n=n_bank, bx=bx, bw=bw, stats=stats, tech=tech, v_wl=knob)
    raise ValueError(kind)


def _bank_reduction_energy(n_banks: int, width_bits: int, tech: TechParams) -> float:
    """Digital adder-tree energy for combining n_banks partial DPs."""
    return max(n_banks - 1, 0) * width_bits * tech.e_add_per_bit


def evaluate_point(
    kind: str,
    n: int,
    n_banks: int,
    bx: int,
    bw: int,
    stats: SignalStats,
    tech: TechParams,
    knob: float,
    snr_t_target_db: float,
    gamma_db: float = 0.5,
    max_rows: int = 512,
) -> Optional[DesignPoint]:
    """Returns a DesignPoint if the configuration meets the SNR target, else None."""
    n_bank = int(math.ceil(n / n_banks))
    if n_bank > max_rows or n_bank < 2:
        return None
    arch = _mk_arch(kind, n_bank, bx, bw, stats, tech, knob)

    # banked composition: per-bank DP variance is sigma_yo^2/n_banks-ish; bank
    # noises are independent => bank SNRs compose as the same SNR (both signal
    # and noise scale with n_bank). SNR_a(total) = SNR_a(bank).
    snr_a_db = arch.snr_a_db()
    snr_A_db = arch.snr_A_db()
    b_adc = arch.b_adc_min(gamma_db)
    snr_t_db = arch.snr_T_db(b_adc)
    if not math.isfinite(snr_t_db) or snr_t_db < snr_t_target_db:
        return None

    e_bank = arch.energy_per_dp(b_adc)
    width = b_adc + int(math.ceil(math.log2(max(n_banks, 2))))
    energy = n_banks * e_bank + _bank_reduction_energy(n_banks, width, tech)
    # banks operate in parallel; reduction adds one tree of log2(n_banks) adds
    delay = arch.delay_per_dp(b_adc) \
        + math.ceil(math.log2(max(n_banks, 1)) or 0) * T_REDUCE_LEVEL
    return DesignPoint(
        arch_kind=kind,
        n=n,
        n_bank=n_bank,
        n_banks=n_banks,
        bx=bx,
        bw=bw,
        b_adc=b_adc,
        knob=knob,
        tech=tech.name,
        snr_a_db=snr_a_db,
        snr_A_db=snr_A_db,
        snr_t_db=snr_t_db,
        energy_per_dp=energy,
        delay_per_dp=delay,
        edp=energy * delay,
    )


def with_b_adc(pt: DesignPoint, b_adc: int,
               stats: SignalStats = UNIFORM_STATS) -> DesignPoint:
    """The same analog design point re-assigned a different output-ADC
    precision (MPC-style per-site assignment, paper eq. 15): SNR_T, ADC
    energy and conversion delay move; the analog core (kind, knob, banking)
    stays.  Uses the same Table III closed forms as :func:`evaluate_point`,
    so ``with_b_adc(pt, pt.b_adc) == pt`` for any solver-produced point."""
    from repro.core import scaling

    tech = scaling.node(pt.tech)
    arch = pt.arch(stats)
    e_bank = arch.energy_per_dp(b_adc)
    width = b_adc + int(math.ceil(math.log2(max(pt.n_banks, 2))))
    energy = pt.n_banks * e_bank \
        + _bank_reduction_energy(pt.n_banks, width, tech)
    delay = arch.delay_per_dp(b_adc) \
        + math.ceil(math.log2(max(pt.n_banks, 1)) or 0) * T_REDUCE_LEVEL
    return dataclasses.replace(
        pt,
        b_adc=b_adc,
        snr_t_db=arch.snr_T_db(b_adc),
        energy_per_dp=energy,
        delay_per_dp=delay,
        edp=energy * delay,
    )


def frontier_ladder(pt: DesignPoint, steps: int = 2, min_b_adc: int = 2,
                    stats: SignalStats = UNIFORM_STATS) -> List[DesignPoint]:
    """Design points stepping DOWN the EDAP frontier from ``pt`` by lowering
    the output-ADC precision one bit at a time (:func:`with_b_adc`): each
    step trades SNR_T for lower energy AND delay per DP while the analog
    core (kind, knob, banking) stays put.  Index 0 is ``pt`` itself; the
    list is the load-shedding-by-accuracy axis the serve engine's
    ``PressureController`` walks under overload (the workload-matched ADC
    precision argument of arxiv 2507.09776 / 2408.06390)."""
    if steps < 0:
        raise ValueError("steps must be >= 0")
    ladder = [pt]
    b = pt.b_adc
    for _ in range(steps):
        b -= 1
        if b < min_b_adc:
            break
        ladder.append(with_b_adc(pt, b, stats))
    return ladder


# ---------------------------------------------------------------------------
# vectorized grid evaluation: all (knob, n_banks) points per kind in one
# numpy batch (same Table III math as evaluate_point; verified by tests)
# ---------------------------------------------------------------------------

_v_clip_stats = np.vectorize(prec.gaussian_clip_stats, otypes=[float, float])
_v_binom2 = np.vectorize(
    lambda nn, kk: binomial_clip_second_moment(int(nn), float(kk)),
    otypes=[float],
)


def _db_arr(x):
    return 10.0 * np.log10(np.maximum(x, 1e-300))


@functools.lru_cache(maxsize=256)
def _grid_metrics(kind: str, n: int, bx: int, bw: int, stats: SignalStats,
                  tech: TechParams, max_rows: int, gamma_db: float):
    """Table III metrics over the full (knob x n_banks) grid, as numpy arrays
    of shape (len(knobs), len(BANK_SPLITS)).  Row-major flat order matches the
    legacy scalar loop (knob outer, banking inner), so argmin tie-breaking is
    unchanged.  Cached: pareto_sweep re-uses the batch across SNR targets."""
    knobs = np.asarray(C_O_GRID if kind == "qr" else V_WL_GRID)[:, None]
    banks = np.asarray(BANK_SPLITS)[None, :]
    n_bank = np.ceil(n / banks).astype(int)
    valid = (n_bank <= max_rows) & (n_bank >= 2)
    n_bank = np.maximum(n_bank, 2)  # placeholder rows stay masked via `valid`

    dx = QuantSpec(bx, signed=False, max_val=stats.x_max).delta
    dw = QuantSpec(bw, signed=True, max_val=stats.w_max).delta
    sigma_yo_sq = n_bank * stats.var_w * stats.e_x2
    sigma_qiy = n_bank * sigma_qiy_sq(1, bx, bw, stats)  # linear in N

    if kind == "qs" or kind == "cm":
        t_pulse = tech.t0 if kind == "cm" else tech.t_pulse
        ov = np.maximum(knobs - tech.v_t, 1e-9)
        cell_i = tech.w_over_l * tech.k_prime * ov**tech.alpha
        sigma_d = tech.alpha * tech.sigma_vt / ov
        t_rf = tech.t_rise - ((knobs - tech.v_t) / knobs) * (
            (tech.t_rise + tech.t_fall) / (tech.alpha + 1.0)
        )
        t_eff = np.maximum(t_pulse - t_rf, 1e-12)
        dv_unit = cell_i * t_eff / tech.c_bl
        k_h = tech.dv_bl_max / dv_unit

    if kind == "qs":
        pws = (4.0 / 9.0) * (1 - 4.0**-bw) * (1 - 4.0**-bx)
        eta_h = pws * _v_binom2(n_bank + 0 * k_h, k_h + 0.0 * n_bank)
        eta_e = pws * n_bank * sigma_d**2 / 4.0
        v_c_counts = np.minimum(
            np.minimum(n_bank / 4.0 + np.sqrt(3.0 * n_bank), k_h), n_bank
        )
        v_c_norm = v_c_counts * dx * dw * (2.0**bx - 1) * (2.0**bw - 1) / 4.0
        adc_ratio = tech.v_dd / np.maximum(v_c_counts * dv_unit, 1e-6)
        conversions = bx * bw
        analog = bx * bw * (
            np.minimum(n_bank / 4.0, k_h) * dv_unit * tech.v_dd * tech.c_bl
            + n_bank * tech.e_switch
        )
    elif kind == "qr":
        c_o = knobs
        sigma_c_rel = tech.pelgrom_kappa / np.sqrt(c_o)
        sigma_th = np.sqrt(1.380649e-23 * tech.temp / c_o)
        sigma_inj_sq = (tech.inj_p * tech.wl_cox / c_o) ** 2
        per_cell = (
            stats.e_x2 * sigma_c_rel**2
            + 2.0 * (sigma_th / tech.v_dd) ** 2
            + sigma_inj_sq * stats.var_x
        )
        eta_h = np.zeros_like(per_cell + 0.0 * n_bank)
        eta_e = (2.0 / 3.0) * (1 - 4.0**-bw) * n_bank * per_cell
        v_c_volts = (
            2.0 * tech.v_dd
            * np.sqrt((stats.e_x2 + stats.var_x) / (stats.x_max**2 * n_bank))
        ) + 0.0 * c_o
        v_c_norm = 4.0 * np.sqrt(sigma_yo_sq) + 0.0 * c_o
        adc_ratio = tech.v_dd / np.maximum(v_c_volts, 1e-6)
        conversions = bw
        e_qr = n_bank * ((1.0 - stats.mu_x) * tech.v_dd) * tech.v_dd * c_o \
            + n_bank * tech.e_switch
        e_mult = stats.mu_x * 0.5 * c_o * tech.v_dd**2
        analog = bw * (e_qr + n_bank * e_mult)
    elif kind == "cm":
        t = np.maximum(1.0 - 2.0 * k_h * 2.0**-bw, 0.0)
        eta_h = (
            (1.0 / 12.0) * n_bank * stats.e_x2 * stats.var_w
            * k_h**-2 * 2.0 ** (2 * bw) * t * t
        )
        eta_e = (
            (2.0 / 3.0) * n_bank * stats.e_x2
            * (0.25 - 4.0**-bw) * sigma_d**2
        )
        sigma_y = np.sqrt(n_bank * stats.var_w * stats.e_x2)
        v_c_volts = 4.0 * 2.0 ** (bw - 1) * dv_unit * sigma_y / n_bank
        v_c_norm = 4.0 * np.sqrt(sigma_yo_sq) + 0.0 * k_h
        adc_ratio = tech.v_dd / np.maximum(v_c_volts, 1e-6)
        conversions = 1
        mean_counts = np.minimum(0.5 * (2.0**bw - 1), k_h * 2)
        mean_v = np.minimum(mean_counts * dv_unit, tech.dv_bl_max)
        e_qs_col = mean_v * tech.v_dd * tech.c_bl / n_bank + tech.e_switch
        qr_co = 3e-15
        e_qr = n_bank * ((1.0 - stats.mu_x) * tech.v_dd) * tech.v_dd * qr_co \
            + n_bank * tech.e_switch
        e_mult = stats.mu_x * 0.5 * qr_co * tech.v_dd**2
        analog = 2 * n_bank * e_qs_col + e_qr + n_bank * e_mult
    else:
        raise ValueError(kind)

    # -- SNR composition (eqs. 10, 11, 14, 15) --
    snr_a = sigma_yo_sq / np.maximum(eta_h + eta_e, 1e-300)
    snr_a_db = _db_arr(snr_a)
    snr_A = 1.0 / (1.0 / snr_a + sigma_qiy / sigma_yo_sq)
    snr_A_db = _db_arr(snr_A)
    mpc = np.ceil(
        (snr_A_db + 7.2 - gamma_db
         - 10.0 * math.log10(1.0 - 10.0 ** (-gamma_db / 10.0))) / 6.0
    )
    if kind == "qs":
        b_adc = np.ceil(np.minimum(
            np.minimum(mpc, np.log2(np.maximum(k_h, 2.0)) + 0.0 * n_bank),
            np.log2(n_bank),
        )).astype(int)
    elif kind == "qr":
        b_adc = np.ceil(np.minimum(mpc, bx + np.log2(n_bank))).astype(int)
    else:
        b_adc = mpc.astype(int)

    zeta = v_c_norm / np.maximum(np.sqrt(sigma_yo_sq), 1e-300)
    q_var = (2.0 * v_c_norm * 2.0**-b_adc.astype(float)) ** 2 / 12.0
    p_c, scc = _v_clip_stats(zeta)
    sigma_qy = q_var + p_c * scc * sigma_yo_sq
    snr_t = 1.0 / (1.0 / snr_A + sigma_qy / sigma_yo_sq)
    snr_t_db = _db_arr(snr_t)

    # -- energy & delay (eqs. 21, 25, 26 + banked composition) --
    r = np.maximum(adc_ratio, 1.0)
    e_adc = 100e-15 * (b_adc + np.log2(r)) + 1e-18 * r * r * 4.0**b_adc
    e_bank = analog + conversions * e_adc \
        + conversions * b_adc * tech.e_add_per_bit
    width = b_adc + np.ceil(np.log2(np.maximum(banks, 2))).astype(int)
    energy = banks * e_bank \
        + np.maximum(banks - 1, 0) * width * tech.e_add_per_bit
    if kind == "qs":
        delay_bank = bx * (tech.t_pulse + tech.t_setup
                           + b_adc * tech.t_adc_per_bit)
    elif kind == "qr":
        delay_bank = 2 * tech.t0 + tech.t_setup + b_adc * tech.t_adc_per_bit
    else:
        delay_bank = (2.0 ** (bw - 1) * tech.t0 + tech.t_setup
                      + 2 * tech.t0 + tech.t_setup
                      + b_adc * tech.t_adc_per_bit)
    delay = delay_bank + np.ceil(np.log2(np.maximum(banks, 1))) * T_REDUCE_LEVEL
    energy = np.broadcast_to(energy + 0.0 * snr_t_db, snr_t_db.shape)
    delay = np.broadcast_to(delay + 0.0 * snr_t_db, snr_t_db.shape)
    return {
        "knobs": np.asarray(C_O_GRID if kind == "qr" else V_WL_GRID),
        "banks": np.asarray(BANK_SPLITS),
        "valid": np.broadcast_to(valid, snr_t_db.shape),
        "snr_t_db": snr_t_db,
        "energy": energy,
        "delay": delay,
        "edp": energy * delay,
    }


def optimize(
    n: int,
    snr_t_target_db: float,
    stats: SignalStats = UNIFORM_STATS,
    tech: TechParams = TECH_65NM,
    kinds: Iterable[str] = ("qs", "qr", "cm"),
    bx: Optional[int] = None,
    bw: Optional[int] = None,
    objective: str = "energy",  # "energy" | "edp" | "delay"
    max_rows: int = 512,
) -> Optional[DesignPoint]:
    """Grid search over (kind x knob x banking), min-objective subject to
    SNR_T >= target.  B_x/B_w default to the SSIII-B assignment for the target.

    The whole (knob, n_banks) grid per kind is evaluated as one vectorized
    numpy batch (:func:`_grid_metrics`); only the winning cell goes through
    the scalar :func:`evaluate_point` to build the exact DesignPoint."""
    if bx is None or bw is None:
        pa = prec.assign_precisions(snr_t_target_db + 3.0, n, stats)
        bx = bx or pa.bx
        bw = bw or pa.bw

    obj_key = {"energy": "energy", "edp": "edp", "delay": "delay"}[objective]
    best: Optional[DesignPoint] = None
    for kind in kinds:
        g = _grid_metrics(kind, n, bx, bw, stats, tech, max_rows, 0.5)
        feasible = (
            g["valid"]
            & np.isfinite(g["snr_t_db"])
            & (g["snr_t_db"] >= snr_t_target_db)
        )
        if not feasible.any():
            continue
        obj = np.where(feasible, g[obj_key], np.inf)
        # ascending objective; stable sort keeps the legacy scalar-loop
        # tie-break (knob outer, banking inner, first strict improvement)
        for flat in np.argsort(obj, axis=None, kind="stable"):
            if not feasible.flat[flat]:
                break
            ki, bi = np.unravel_index(flat, obj.shape)
            pt = evaluate_point(
                kind, n, int(g["banks"][bi]), bx, bw, stats, tech,
                float(g["knobs"][ki]), snr_t_target_db, max_rows=max_rows,
            )
            if pt is not None:
                key = {"energy": pt.energy_per_dp, "edp": pt.edp,
                       "delay": pt.delay_per_dp}[objective]
                best_key = None if best is None else {
                    "energy": best.energy_per_dp, "edp": best.edp,
                    "delay": best.delay_per_dp}[objective]
                if best is None or key < best_key:
                    best = pt
                break
    return best


# ---------------------------------------------------------------------------
# workload-level rollup: one token-forward of a model costed at a design point
# ---------------------------------------------------------------------------


def workload_metrics(pt: DesignPoint, sites) -> dict:
    """Energy/delay of ONE token-forward over ``sites`` at design point ``pt``.

    ``sites`` is an iterable of ``(k, m, calls)`` matmul-site triples (see
    :func:`repro.core.mapping.per_token_matmul_shapes`): each call evaluates
    ``m`` output dot products of dimension ``k``.  A site whose DP dimension
    exceeds the design point's ``pt.n`` is tiled onto ``ceil(k / pt.n)``
    bank-row groups (the ``core.mapping`` bank tiling) whose partials reduce
    digitally, exactly like the in-design banking of ``evaluate_point``.
    Banks are column- and tile-parallel, so per-call delay is one DP
    conversion; sites within a token-forward are sequential (layer order).
    """
    from repro.core import scaling

    tech = scaling.node(pt.tech)
    energy = 0.0
    delay = 0.0
    for k, m, calls in sites:
        tiles = int(math.ceil(k / pt.n))
        width = pt.b_adc + int(math.ceil(math.log2(max(tiles * pt.n_banks, 2))))
        e_dp = tiles * pt.energy_per_dp + _bank_reduction_energy(tiles, width, tech)
        energy += calls * m * e_dp
        delay += calls * (pt.delay_per_dp
                          + math.ceil(math.log2(max(tiles, 1))) * T_REDUCE_LEVEL)
    return {
        "energy_per_token_j": energy,
        "delay_per_token_s": delay,
        "edp_per_token": energy * delay,
    }


def pareto_sweep(
    n: int,
    stats: SignalStats = UNIFORM_STATS,
    tech: TechParams = TECH_65NM,
    kinds: Iterable[str] = ("qs", "qr", "cm"),
    targets_db: Iterable[float] = tuple(range(8, 44, 2)),
    workload=None,
):
    """Energy-vs-SNR_T pareto frontier (the Fig. 13-style trade-off curve).

    With ``workload=`` (an iterable of ``(k, m, calls)`` matmul-site triples,
    e.g. from a :class:`repro.launch.metering.DPMeter`), each SNR target
    re-ranks the per-kind optima by SERVE-WORKLOAD EDP per token-forward
    (:func:`workload_metrics`) instead of per-DP energy - the rollup the
    paper's "QS at low / QR at high compute SNR" guideline is stated over.
    """
    out = []
    for t in targets_db:
        if workload is None:
            pt = optimize(n, t, stats=stats, tech=tech, kinds=kinds)
            if pt is not None:
                out.append((t, pt))
            continue
        best = None
        best_edp = math.inf
        for kind in kinds:
            pt = optimize(n, t, stats=stats, tech=tech, kinds=(kind,))
            if pt is None:
                continue
            edp = workload_metrics(pt, workload)["edp_per_token"]
            if edp < best_edp:
                best, best_edp = pt, edp
        if best is not None:
            out.append((t, best))
    return out
