"""First-class execution substrates: WHICH hardware a matmul runs on, HOW its
quantizers are calibrated, and WHAT design point gets billed for it.

The paper's central prescription is per-compute-site assignment: activation /
weight / ADC precision must be chosen so SNR_T -> SNR_a at minimal ADC cost
(MPC, eq. 15) *per site*, not globally.  Before this module the analog
substrate was selected with a string flag (``IMCConfig.mode``) threaded as a
kwarg through every layer, quantizer ranges were re-derived from whatever
batch happened to flow through ``imc_linear.linear``, and the serve-path
meter had to trust a side-channel shapes walk to know which design point
"ran" where.  A :class:`Substrate` object now carries all three concerns:

  execution      an :class:`~repro.core.imc_linear.IMCConfig` (the knobs the
                 kernels actually consume) selected by subclass -
                 :class:`DigitalSubstrate`, :class:`AnalyticIMC` (folded-noise
                 model), :class:`BitSerialIMC` (bit-exact QS-Arch kernel);
  calibration    a policy - ``"dynamic"`` (per-batch quantizer stats, the
                 historical behaviour, kept bit-exact for training parity) or
                 ``"frozen"`` (ranges captured once by a calibration pass and
                 stored in a :class:`Calibration` pytree).  Frozen substrates
                 make every forward pass batch-composition-invariant: the
                 batched serve engine is bit-identical to sequential
                 single-request execution (pinned by
                 ``tests/test_serve_paged.py``);
  accounting     an optional ``core.design.DesignPoint`` billed by
                 ``launch.metering`` for the work this substrate executes,
                 plus optional per-site overrides.

Per-site overrides are keyed by the site names of THE shared shapes walk
(``core.mapping.per_token_matmul_shapes``): ``"attn.wq"``, ``"mlp.wi"``,
``"lm_head"``, ...  An override key matches a site exactly, or by its group
prefix before the dot (``"attn"`` covers ``attn.wq`` .. ``attn.wo``), or
``"*"`` as the fallback; this is how MPC-style per-layer precision assignment
(e.g. the output head at a higher B_ADC than the FFN sites) is expressed.

Calibration semantics (pinned by hypothesis properties in
``tests/test_properties.py``): per-site stats are running maxima -
``x_max`` / ``w_max`` are max-|value| over everything observed, ``sigma_yo``
is the max per-row output std - so frozen ranges are invariant to batch
order and to zero-row padding, calibrating on a superset of batches never
shrinks a range, and a :class:`Calibration` round-trips losslessly through
its pytree and through JSON.

Hot-swap contract (online recalibration, ``runtime.drift`` +
``launch.serve.Engine.swap_calibration``): a frozen calibration may be
replaced at runtime, but ONLY at chunk boundaries - never inside a fused
decode scan or a batched prefill call - so within any one chunk every row
quantizes against one consistent set of ranges and the batched == sequential
bit-identity holds chunk by chunk.  The serve engine passes the Calibration
pytree as a TRACED argument to its jitted decode/prefill functions; the jit
cache is therefore keyed on the calibration's treedef (the sorted site-name
tuple is pytree aux data), and a refreshed calibration that preserves the
frozen site-name set (``runtime.drift.refreshed_calibration`` guarantees
this) swaps in as new leaf values on the SAME compiled executables - an
atomic host-side pointer update, no recompile storm.  Live traffic is
observed for drift through :func:`shadow_recording`, the passive counterpart
of :func:`recording`: the sampled forward executes its real substrate path
unchanged (outputs bit-identical) while running-maxima stats stream out
through debug callbacks.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import json
import threading
import warnings
from typing import Any, Dict, Iterable, List, Mapping, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.design import DesignPoint
from repro.core.imc_linear import IMCConfig

# ---------------------------------------------------------------------------
# calibration: frozen quantizer statistics, one entry per compute site
# ---------------------------------------------------------------------------

# stats are max-merged, so every field must be monotone under "observe more":
# x_max/w_max are running max |value|; sigma_yo is the max per-row output std
_STAT_FIELDS = ("x_max", "w_max", "sigma_yo")

# merged-over-all-sites fallback entry: sites unseen during calibration (and
# ``site=None`` callers) freeze against it instead of silently going dynamic,
# which would break the batch-invariance guarantee for exactly those sites
DEFAULT_SITE = "*"


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class SiteStats:
    """Frozen quantizer statistics of one matmul site (plain floats: they
    embed as compile-time constants, which is what makes frozen substrates
    batch-invariant and keeps the whole Substrate hashable/static)."""

    x_max: float
    w_max: float
    sigma_yo: float

    def merge(self, other: "SiteStats") -> "SiteStats":
        return SiteStats(*(max(getattr(self, f), getattr(other, f))
                           for f in _STAT_FIELDS))

    def tree_flatten(self):
        return tuple(getattr(self, f) for f in _STAT_FIELDS), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Calibration:
    """Per-site frozen ranges, sorted by site name (a canonical order makes
    equality/hashing independent of observation order)."""

    sites: Tuple[Tuple[str, SiteStats], ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "sites", tuple(sorted(self.sites)))

    def get(self, site: Optional[str]) -> Optional[SiteStats]:
        """Stats for ``site``, falling back to the ``"*"`` merged entry."""
        d = dict(self.sites)
        if site is not None and site in d:
            return d[site]
        return d.get(DEFAULT_SITE)

    def site_names(self) -> Tuple[str, ...]:
        return tuple(name for name, _ in self.sites)

    def merge(self, other: "Calibration") -> "Calibration":
        d: Dict[str, SiteStats] = dict(self.sites)
        for name, st in other.sites:
            d[name] = d[name].merge(st) if name in d else st
        return Calibration(tuple(d.items()))

    # -- lossless round trips ------------------------------------------------
    def tree_flatten(self):
        names = tuple(name for name, _ in self.sites)
        return tuple(st for _, st in self.sites), names

    @classmethod
    def tree_unflatten(cls, names, children):
        return cls(tuple(zip(names, children)))

    def to_dict(self) -> dict:
        return {name: {f: getattr(st, f) for f in _STAT_FIELDS}
                for name, st in self.sites}

    @classmethod
    def from_dict(cls, d: Mapping[str, Mapping[str, float]]) -> "Calibration":
        return cls(tuple(
            (name, SiteStats(**{f: float(v[f]) for f in _STAT_FIELDS}))
            for name, v in d.items()
        ))

    def save(self, path: str):
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=1, sort_keys=True)

    @classmethod
    def load(cls, path: str) -> "Calibration":
        with open(path) as f:
            return cls.from_dict(json.load(f))


class CalibrationRecorder:
    """Accumulates per-site running-max stats during an eager calibration
    pass (activate with :func:`recording`; ``imc_linear.linear`` feeds it)."""

    def __init__(self):
        self._acc: Dict[str, SiteStats] = {}

    def note(self, site: str, stats: SiteStats):
        prev = self._acc.get(site)
        self._acc[site] = stats if prev is None else prev.merge(stats)

    def observe(self, site: str, x, w, y=None):
        """Record one (x, w) observation of ``site``.  ``y`` defaults to the
        noiseless quantized-code product the dynamic path would quantize
        against; zero-padded rows of ``x`` cannot change any stat (max |x|
        and max per-row std both ignore all-zero rows).

        Works under tracing (scan-over-layers, jit): the concrete values are
        pulled out through ``jax.debug.callback``, which fires once per
        runtime execution of the site - layers that scan over one shared
        site name max-merge into a single entry, which is exactly the
        per-site (not per-layer-instance) granularity of the shapes walk.
        """
        x = jnp.asarray(x)
        w = jnp.asarray(w)
        if y is None:
            y = jnp.einsum("...k,km->...m", x, w)
        y = jnp.asarray(y)
        x_max = jnp.max(jnp.abs(x))
        w_max = jnp.max(jnp.abs(w))
        sigma = jnp.max(jnp.std(y.reshape(-1, y.shape[-1]), axis=-1))
        jax.debug.callback(functools.partial(self._note_concrete, site),
                           x_max, w_max, sigma)

    def _note_concrete(self, site: str, x_max, w_max, sigma):
        self.note(site, SiteStats(x_max=float(x_max) + 1e-9,
                                  w_max=float(w_max) + 1e-9,
                                  sigma_yo=float(sigma) + 1e-9))

    def finalize(self) -> Calibration:
        """Per-site entries plus the ``"*"`` merge of every site (the frozen
        fallback for sites the calibration batch never exercised)."""
        entries = dict(self._acc)
        if entries and DEFAULT_SITE not in entries:
            merged = None
            for st in entries.values():
                merged = st if merged is None else merged.merge(st)
            entries[DEFAULT_SITE] = merged
        return Calibration(tuple(entries.items()))

    def reset(self):
        """Drop the accumulated stats IN PLACE.  The instance identity is
        preserved on purpose: shadow-traced executables bind the recorder
        object at trace time, so replacing the instance (rather than
        resetting it) would orphan every compiled shadow function."""
        self._acc.clear()


_ACTIVE = threading.local()


def active_recorder() -> Optional[CalibrationRecorder]:
    return getattr(_ACTIVE, "recorder", None)


@contextlib.contextmanager
def recording(recorder: CalibrationRecorder):
    """Route every non-digital ``imc_linear.linear`` call to ``recorder``.
    The recording forward must EXECUTE inside the context (the recorder
    fills through debug callbacks at run time); call ``jax.effects_barrier``
    before finalizing if you dispatched asynchronously."""
    prev = active_recorder()
    _ACTIVE.recorder = recorder
    try:
        yield recorder
    finally:
        _ACTIVE.recorder = prev


def active_shadow_recorder() -> Optional[CalibrationRecorder]:
    return getattr(_ACTIVE, "shadow", None)


@contextlib.contextmanager
def shadow_recording(recorder: CalibrationRecorder):
    """Passively observe every non-digital ``imc_linear.linear`` call into
    ``recorder`` WITHOUT changing execution.

    Unlike :func:`recording` (which swaps the calibration-pass fakequant
    proxy in for the real substrate path), a shadow-observed forward runs
    its real substrate path unchanged - same ops, bit-identical outputs -
    and only streams running-maxima stats out through ``jax.debug.callback``.
    This is what lets the serve engine sample LIVE traffic for drift
    detection (``runtime.drift``) without breaking the frozen-policy
    batch-invariance contract.

    Trace-time semantics: a jitted function first traced inside this context
    bakes the observation callbacks (bound to THIS recorder instance) into
    its compiled executable; later calls feed the same recorder whether or
    not the context is active.  Callers therefore keep separate jit cache
    entries for shadow and non-shadow variants and a persistent recorder
    instance (see ``CalibrationRecorder.reset``).  Flush with
    ``jax.effects_barrier()`` before reading the accumulated stats.
    """
    prev = active_shadow_recorder()
    _ACTIVE.shadow = recorder
    try:
        yield recorder
    finally:
        _ACTIVE.shadow = prev


# ---------------------------------------------------------------------------
# per-site overrides
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SiteOverride:
    """Per-site deviation from a substrate's base assignment: IMCConfig field
    replacements (stored as a sorted tuple for hashability) and/or a
    different billed design point."""

    imc_fields: Tuple[Tuple[str, Any], ...] = ()
    design: Optional[DesignPoint] = None


def _normalize_overrides(overrides) -> Tuple[Tuple[str, SiteOverride], ...]:
    if overrides is None:
        return ()
    if isinstance(overrides, tuple):  # already normalized (dataclasses.replace)
        return overrides
    out: List[Tuple[str, SiteOverride]] = []
    for key, val in overrides.items():
        if isinstance(val, SiteOverride):
            out.append((key, val))
            continue
        if isinstance(val, DesignPoint):
            out.append((key, SiteOverride(design=val)))
            continue
        fields = dict(val)
        design = fields.pop("design", None)
        out.append((key, SiteOverride(tuple(sorted(fields.items())), design)))
    return tuple(sorted(out))


# ---------------------------------------------------------------------------
# the substrate hierarchy
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Substrate:
    """One fully-specified way to execute (and bill) the model's matmuls.

    Hashable and immutable: a Substrate is safe to close over in jitted
    functions and to use as a cache key.  Prefer the concrete subclasses
    (:class:`DigitalSubstrate`, :class:`AnalyticIMC`, :class:`BitSerialIMC`);
    the base class exists for exotic ``IMCConfig`` modes (e.g. fakequant).
    """

    imc: IMCConfig = IMCConfig()
    policy: str = "dynamic"  # "dynamic" | "frozen"
    calibration: Optional[Calibration] = None
    design: Optional[DesignPoint] = None
    overrides: Tuple[Tuple[str, SiteOverride], ...] = ()

    def __post_init__(self):
        if self.policy not in ("dynamic", "frozen"):
            raise ValueError(f"unknown calibration policy {self.policy!r}")
        if self.policy == "frozen" and self.calibration is None:
            raise ValueError("a frozen substrate needs a Calibration "
                             "(run substrate.calibrate(...) first)")
        object.__setattr__(self, "overrides",
                           _normalize_overrides(self.overrides))

    # -- identity ------------------------------------------------------------
    @property
    def name(self) -> str:
        """The execution-mode name (the string the old flag plumbing used)."""
        return self.imc.mode

    @property
    def trace_key(self):
        """Hashable identity of the TRACED computation this substrate builds:
        kernel knobs, calibration policy and per-site overrides.  The
        calibration VALUES are excluded - they enter jitted functions as a
        traced runtime argument (the hot-swap contract), so two frozen
        substrates differing only in calibration share every compiled
        executable.  The serve engine keys its prefill/decode jit caches on
        this, which is what makes frontier-ladder substrate swaps compile
        once per level instead of storming."""
        return (self.imc, self.policy, self.overrides)

    # -- per-site resolution -------------------------------------------------
    def _override_for(self, site: Optional[str]) -> Optional[SiteOverride]:
        if not self.overrides:
            return None
        d = dict(self.overrides)
        if site is not None:
            if site in d:
                return d[site]
            group = site.split(".", 1)[0]
            if group in d:
                return d[group]
        return d.get(DEFAULT_SITE)

    def site_config(self, site: Optional[str] = None) -> IMCConfig:
        """The effective execution knobs at ``site`` (base IMCConfig with any
        matching override fields applied)."""
        ov = self._override_for(site)
        if ov is None or not ov.imc_fields:
            return self.imc
        return dataclasses.replace(self.imc, **dict(ov.imc_fields))

    def site_stats(self, site: Optional[str] = None) -> Optional[SiteStats]:
        """Frozen quantizer stats for ``site`` (None under the dynamic
        policy: the caller derives per-batch stats as before)."""
        if self.policy != "frozen":
            return None
        stats = self.calibration.get(site)
        if stats is None:
            raise KeyError(
                f"frozen substrate has no calibration entry for site "
                f"{site!r} and no {DEFAULT_SITE!r} fallback")
        return stats

    def design_for_site(self, site: Optional[str] = None) -> Optional[DesignPoint]:
        """The design point billed for work at ``site`` (site override wins
        over the substrate-wide design point)."""
        ov = self._override_for(site)
        if ov is not None and ov.design is not None:
            return ov.design
        return self.design

    # -- functional updates --------------------------------------------------
    def frozen(self, calibration: Calibration) -> "Substrate":
        """This substrate with quantizer ranges frozen at ``calibration``."""
        return dataclasses.replace(self, policy="frozen",
                                   calibration=calibration)

    def dynamic(self) -> "Substrate":
        return dataclasses.replace(self, policy="dynamic", calibration=None)

    def with_design(self, design: DesignPoint) -> "Substrate":
        return dataclasses.replace(self, design=design)

    def with_overrides(self, overrides) -> "Substrate":
        return dataclasses.replace(self,
                                   overrides=_normalize_overrides(overrides))

    # -- calibration pass ----------------------------------------------------
    def calibrate(self, fn, batches: Iterable[Any]) -> "Substrate":
        """Run ``fn(batch)`` eagerly for each reference batch under a
        recorder and return the frozen substrate.  ``fn`` must execute the
        workload through ``imc_linear.linear`` with THIS substrate in
        dynamic mode (e.g. a closure over ``models.forward``)."""
        rec = CalibrationRecorder()
        with recording(rec):
            for batch in batches:
                fn(batch)
            jax.effects_barrier()  # flush pending recorder callbacks
        return self.frozen(rec.finalize())


class _ModalSubstrate(Substrate):
    """Shared constructor for the concrete substrates: accepts either a
    ready-made ``imc=IMCConfig`` (mode must match) or IMCConfig knobs as
    keywords (``bx=7, bw=7, v_wl=0.7, ...``)."""

    MODE = ""

    def __init__(self, *, imc: Optional[IMCConfig] = None,
                 policy: str = "dynamic",
                 calibration: Optional[Calibration] = None,
                 design: Optional[DesignPoint] = None,
                 overrides=(), **knobs):
        if imc is None:
            imc = IMCConfig(mode=self.MODE, **knobs)
        else:
            if knobs:
                imc = dataclasses.replace(imc, **knobs)
            if imc.mode != self.MODE:
                raise ValueError(
                    f"{type(self).__name__} wants mode {self.MODE!r}, "
                    f"got {imc.mode!r}")
        super().__init__(imc=imc, policy=policy, calibration=calibration,
                         design=design, overrides=overrides)


class DigitalSubstrate(_ModalSubstrate):
    """Plain matmuls - the baseline every IMC substrate is compared against.
    Carries no analog design point by default; attach one with
    ``with_design`` to bill a hypothetical deployment."""

    MODE = "digital"


class AnalyticIMC(_ModalSubstrate):
    """Folded-noise IMC model (paper eqs. 10-15): fakequant + Gaussian analog
    noise at the analytic SNR_a + MPC-clipped B_ADC output quantization.
    Differentiable, cheap, shardable - the training / dry-run substrate."""

    MODE = "imc_analytic"


class BitSerialIMC(_ModalSubstrate):
    """Bit-exact QS-Arch simulation through the Pallas kernel path
    (``repro.kernels``) - the silicon-fidelity substrate."""

    MODE = "imc_bitserial"


DIGITAL_SUBSTRATE = DigitalSubstrate()

_BY_MODE = {
    DigitalSubstrate.MODE: DigitalSubstrate,
    AnalyticIMC.MODE: AnalyticIMC,
    BitSerialIMC.MODE: BitSerialIMC,
}


def as_substrate(obj: Union[None, "Substrate", IMCConfig]) -> Substrate:
    """Normalize legacy execution configs to a Substrate.

    ``IMCConfig`` stays a supported low-level knob container (it IS part of
    every substrate), so wrapping one is silent and exactly reproduces the
    historical dynamic-calibration behaviour bit for bit.
    """
    if obj is None:
        return DIGITAL_SUBSTRATE
    if isinstance(obj, Substrate):
        return obj
    if isinstance(obj, IMCConfig):
        cls = _BY_MODE.get(obj.mode)
        if cls is None:
            return Substrate(imc=obj)
        return cls(imc=obj)
    raise TypeError(f"cannot interpret {type(obj).__name__} as a Substrate")


def substrate_from_flag(mode: str, **knobs) -> Substrate:
    """DEPRECATED shim for the old string-flag plumbing.

    Emits a :class:`DeprecationWarning`; construct :class:`DigitalSubstrate`
    / :class:`AnalyticIMC` / :class:`BitSerialIMC` directly instead.
    """
    warnings.warn(
        "substrate_from_flag() is a deprecation shim for the old string-flag "
        "API; construct DigitalSubstrate / AnalyticIMC / BitSerialIMC "
        "directly",
        DeprecationWarning,
        stacklevel=2,
    )
    cls = _BY_MODE.get(mode)
    if cls is None:
        return Substrate(imc=IMCConfig(mode=mode, **knobs))
    return cls(**knobs)


def substrate_for_design(pt: DesignPoint, **kw) -> Substrate:
    """The executable substrate a ``core.design`` design point implies: QS
    architectures run bit-serial planes (:class:`BitSerialIMC`); QR/CM
    convert a full DP per ADC read, which the folded-noise
    :class:`AnalyticIMC` models.  The design point rides along for billing
    (``launch.metering``)."""
    if pt.arch_kind == "qs":
        return BitSerialIMC(bx=pt.bx, bw=pt.bw, b_adc=pt.b_adc,
                            rows=pt.n_bank, v_wl=pt.knob, design=pt, **kw)
    return AnalyticIMC(bx=pt.bx, bw=pt.bw, b_adc=pt.b_adc,
                       snr_a_db=pt.snr_a_db, design=pt, **kw)


def substrate_ladder(pt: DesignPoint, steps: int = 2, min_b_adc: int = 2,
                     **kw) -> List[Substrate]:
    """Executable substrates stepping DOWN the EDAP frontier from ``pt``
    (``core.design.frontier_ladder``): index 0 is the committed design point,
    each later entry trades SNR_T for lower energy/delay per DP by dropping
    one bit of output-ADC precision.  This is the degradation axis the
    ``launch.scheduler.PressureController`` walks under overload; every
    entry carries its design point for billing."""
    from repro.core.design import frontier_ladder

    return [substrate_for_design(p, **kw)
            for p in frontier_ladder(pt, steps=steps, min_b_adc=min_b_adc)]


# ---------------------------------------------------------------------------
# model-level calibration convenience
# ---------------------------------------------------------------------------


def calibrate_model(cfg, params, token_batches, prefix_embeds=None):
    """Freeze ``cfg``'s substrate against reference ``token_batches``.

    Runs ``models.forward`` eagerly (the recorder needs concrete values) once
    per ``(B, S)`` int32 batch; during recording every non-digital site
    executes the noiseless fakequant proxy, which is cheap and has the same
    operand ranges as the real substrate.  Returns ``cfg`` with the frozen
    substrate installed (``cfg.imc`` becomes batch-composition-invariant).
    """
    from repro.models import forward  # local: core must not import models

    sub = as_substrate(cfg.imc).dynamic()
    run_cfg = cfg.replace(imc=sub)

    def one(batch):
        forward(params, run_cfg, jnp.asarray(batch, jnp.int32),
                prefix_embeds=prefix_embeds)

    frozen = sub.calibrate(one, token_batches)
    return cfg.replace(imc=frozen)
