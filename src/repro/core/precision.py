"""Output-precision assignment criteria: BGC, tBGC, and the paper's MPC
(paper SSIII-C/D, eqs. 12-15).

BGC (bit growth criterion):      B_y = B_x + B_w + log2(N)         (eq. 12)
tBGC:                            B_y set below BGC, LSBs truncated (eq. 9 applies)
MPC (minimum precision criterion): clip the output at y_c = zeta * sigma_yo
  (zeta = 4 maximizes SQNR for Gaussian outputs) and quantize the reduced range
  with B_y bits, trading quantization noise against a controlled clipping noise
  (eq. 14).  Lower bound on B_y: eq. (15).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import snr as snr_lib
from repro.core.quant import QuantSpec, SignalStats, db, undb


# ---------------------------------------------------------------------------
# Gaussian clipping statistics (used by MPC; paper SSIII-D)
# ---------------------------------------------------------------------------


def _phi(z):
    """Standard normal pdf."""
    return np.exp(-0.5 * z * z) / math.sqrt(2.0 * math.pi)


def _q(z):
    """Standard normal tail probability Q(z) = P(Z > z)."""
    return 0.5 * math.erfc(z / math.sqrt(2.0))


def gaussian_clip_stats(zeta: float) -> Tuple[float, float]:
    """For y ~ N(0, sigma^2) clipped at y_c = zeta*sigma, returns
    (p_c, sigma_cc^2 / sigma^2):

      p_c       = Pr{|y| > y_c} = 2 Q(zeta)
      sigma_cc^2 = E[(|y| - y_c)^2 | |y| > y_c]
                 = sigma^2 (1 + zeta^2 - zeta phi(zeta)/Q(zeta))
    """
    qz = _q(zeta)
    p_c = 2.0 * qz
    if qz <= 0.0:
        return 0.0, 0.0
    scc = 1.0 + zeta**2 - zeta * _phi(zeta) / qz
    return p_c, max(scc, 0.0)


# ---------------------------------------------------------------------------
# BGC / tBGC (eqs. 12, 9, 13)
# ---------------------------------------------------------------------------


def by_bgc(bx: int, bw: int, n: int) -> int:
    """Eq. (12): full bit growth (lossless integer accumulation width)."""
    return bx + bw + int(math.ceil(math.log2(n)))


def sqnr_qy_fullrange(by: int, n: int, stats: SignalStats):
    """Exact SQNR_qy when the full range [-y_m, y_m], y_m = N x_m w_m, is
    quantized with B_y bits (this is eq. (9); BGC/tBGC both use it)."""
    y_m = stats.dp_max(n)
    spec = QuantSpec(by, signed=True, max_val=y_m)
    return stats.dp_var(n) / spec.noise_var


def sqnr_qy_fullrange_db_approx(by: int, n: int, stats: SignalStats):
    """Paper eq. (9): 6 B_y + 4.8 - [zeta_x + zeta_w](dB) - 10log10(N)."""
    return (
        6.0206 * by
        + 4.7712
        - db(stats.zeta_x_sq)
        - db(stats.zeta_w_sq)
        - 10.0 * np.log10(n)
    )


def sqnr_qy_bgc_db(bx: int, bw: int, n: int, stats: SignalStats):
    """Paper eq. (13) (closed form with B_y = B_y^BGC)."""
    return (
        6.0206 * (bx + bw)
        + 4.7712
        - db(stats.zeta_x_sq)
        - db(stats.zeta_w_sq)
        + 10.0 * np.log10(n)
    )


# ---------------------------------------------------------------------------
# MPC (eqs. 14, 15)
# ---------------------------------------------------------------------------


def sqnr_qy_mpc(by: int, zeta: float = 4.0):
    """Paper eq. (14)/(30) for a Gaussian DP output, in linear units:

        SQNR = 3 * 2^(2 B_y) / (zeta^2 (1 + p_c sigma_cc^2/sigma_qy^2))

    with sigma_qy^2 = y_c^2 2^(-2 B_y) / 3 and y_c = zeta sigma_yo.
    Independent of N and of the signal scale (everything normalizes to sigma_yo).
    """
    p_c, scc_norm = gaussian_clip_stats(zeta)
    sigma_qy_norm = zeta**2 * 2.0 ** (-2 * by) / 3.0  # / sigma_yo^2
    return (3.0 * 2.0 ** (2 * by) / zeta**2) / (1.0 + p_c * scc_norm / sigma_qy_norm)


def sqnr_qy_mpc_db(by: int, zeta: float = 4.0):
    return db(sqnr_qy_mpc(by, zeta))


def optimal_zeta(by: int, grid=None) -> float:
    """Numerically maximize eq. (14) over the clip ratio zeta.

    The paper's MPC rule: the optimum is ~4 for Gaussian outputs (Fig. 4(b)).
    """
    if grid is None:
        grid = np.linspace(1.0, 8.0, 1401)
    vals = [float(sqnr_qy_mpc_db(by, z)) for z in grid]
    return float(grid[int(np.argmax(vals))])


def by_mpc_lower_bound(snr_a_db: float, gamma_db: float = 0.5) -> int:
    """Paper eq. (15): minimum B_y so that SNR_A - SNR_T <= gamma, assuming
    Gaussian outputs clipped at 4 sigma with p_c = 0.001:

        B_y >= 1/6 [ SNR_A(dB) + 7.2 - gamma - 10 log10(1 - 10^(-gamma/10)) ]

    For gamma = 0.5 dB this is B_y >= (SNR_A(dB) + 16.3)/6.
    """
    val = (
        snr_a_db
        + 7.2
        - gamma_db
        - 10.0 * math.log10(1.0 - 10.0 ** (-gamma_db / 10.0))
    ) / 6.0
    return int(math.ceil(val))


def clip_level_mpc(sigma_yo, zeta: float = 4.0):
    """The MPC-based SQNR maximizing rule: y_c = 4 sigma_yo for Gaussian DPs."""
    return zeta * sigma_yo


# ---------------------------------------------------------------------------
# Empirical MPC for arbitrary output distributions (beyond-paper utility)
# ---------------------------------------------------------------------------


def sqnr_qy_mpc_empirical(y_samples, by: int, zeta: float = 4.0):
    """Monte-Carlo SQNR_qy of a zeta*sigma-clipped B_y-bit quantizer applied to
    actual DP output samples (no Gaussian assumption). Used to validate eq. (14)
    and to extend MPC to non-Gaussian layer output distributions."""
    y = jnp.asarray(y_samples)
    sigma = jnp.std(y)
    c = zeta * sigma
    spec = QuantSpec(by, signed=True, max_val=c)
    yq = jnp.clip(jnp.round(y / spec.delta), spec.code_min, spec.code_max) * spec.delta
    err = yq - y
    return float(jnp.var(y) / jnp.mean((err - jnp.mean(err)) ** 2))


# ---------------------------------------------------------------------------
# Full precision assignment (paper SSIII-B procedure)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PrecisionAssignment:
    bx: int
    bw: int
    by: int
    criterion: str
    # predicted SNRs (dB)
    sqnr_qiy_db: float
    sqnr_qy_db: float
    snr_a_db: float
    snr_A_db: float
    snr_t_db: float


def assign_precisions(
    snr_a_db: float,
    n: int,
    stats: SignalStats,
    gamma_db: float = 0.5,
    criterion: str = "mpc",
    max_bits: int = 16,
) -> PrecisionAssignment:
    """The paper's SSIII-B recipe, automated:

      1. smallest B_x = B_w such that SQNR_qiy >= SNR_a + margin(gamma/2)
         (so SNR_A -> SNR_a within gamma/2),
      2. B_y via MPC eq. (15) (or BGC eq. (12)) so SNR_T -> SNR_A within gamma/2.
    """
    from repro.core.quant import sqnr_qiy  # local import to avoid cycle

    margin = float(snr_lib.margin_for_degradation(gamma_db / 2.0))
    bx = bw = None
    for b in range(2, max_bits + 1):
        if float(db(sqnr_qiy(n, b, b, stats))) >= snr_a_db + margin:
            bx = bw = b
            break
    if bx is None:
        bx = bw = max_bits

    snr_A_db = float(
        snr_lib.compose_snr_db(snr_a_db, db(sqnr_qiy(n, bx, bw, stats)))
    )

    if criterion == "bgc":
        by = by_bgc(bx, bw, n)
        qy_db = float(sqnr_qy_bgc_db(bx, bw, n, stats))
    else:
        by = by_mpc_lower_bound(snr_A_db, gamma_db / 2.0)
        qy_db = float(sqnr_qy_mpc_db(by))

    snr_t_db = float(snr_lib.compose_snr_db(snr_A_db, qy_db))
    return PrecisionAssignment(
        bx=bx,
        bw=bw,
        by=by,
        criterion=criterion,
        sqnr_qiy_db=float(db(sqnr_qiy(n, bx, bw, stats))),
        sqnr_qy_db=qy_db,
        snr_a_db=snr_a_db,
        snr_A_db=snr_A_db,
        snr_t_db=snr_t_db,
    )
