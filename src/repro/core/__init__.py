"""repro.core - the paper's primary contribution as a composable library.

Fundamental limits on energy-delay-accuracy of in-memory computing (IMC)
architectures (Gonugondla et al., 2020):

  quant           additive quantization noise model, uniform quantizers, PARs
  snr             compute SNR metrics + composition rules (eqs. 6-11)
  precision       BGC / tBGC / MPC output-precision criteria (eqs. 12-15)
  compute_models  QS / IS / QR physical compute models (eqs. 16-25, Table II)
  archs           QS-Arch / QR-Arch / CM architecture analytics (Table III)
  adc             column-ADC energy model (eq. 26)
  scaling         technology-node parameter tables (SSV-D)
  mc              sample-accurate Monte Carlo validators (SSV-A, Fig. 8)
  design          min-energy design-point solver (SSVI guidelines as a solver)
  mapping         matmul -> bank tiling + whole-model energy rollups
  imc_linear      the executable IMC linear layer (digital/fakequant/analytic/bitserial)
  substrate       first-class execution substrates: per-site design points,
                  frozen-vs-dynamic calibration, batch-invariant IMC serving
"""
from repro.core.adc import adc_energy  # noqa: F401
from repro.core.archs import CMArch, IMCArch, QRArch, QSArch  # noqa: F401
from repro.core.compute_models import (  # noqa: F401
    ISModel,
    QRModel,
    QSModel,
    TECH_65NM,
    TechParams,
)
from repro.core.design import DesignPoint, optimize, pareto_sweep  # noqa: F401
from repro.core.mapping import (  # noqa: F401
    BankSpec,
    MatmulShape,
    ModelReport,
    map_matmul,
    map_model,
)
from repro.core.precision import (  # noqa: F401
    PrecisionAssignment,
    assign_precisions,
    by_bgc,
    by_mpc_lower_bound,
    gaussian_clip_stats,
    optimal_zeta,
    sqnr_qy_bgc_db,
    sqnr_qy_fullrange,
    sqnr_qy_mpc,
    sqnr_qy_mpc_db,
)
from repro.core.quant import (  # noqa: F401
    QuantSpec,
    SignalStats,
    UNIFORM_STATS,
    bit_planes,
    combine_bit_planes,
    db,
    dequantize,
    fakequant,
    quantize,
    sqnr_qiy,
    sqnr_qiy_db_approx,
    undb,
)
from repro.core.snr import compose_snr, compose_snr_db, empirical_snr_db  # noqa: F401
from repro.core.substrate import (  # noqa: F401
    AnalyticIMC,
    BitSerialIMC,
    Calibration,
    CalibrationRecorder,
    DigitalSubstrate,
    SiteStats,
    Substrate,
    as_substrate,
    calibrate_model,
    substrate_for_design,
    substrate_from_flag,
)
