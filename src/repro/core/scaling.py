"""Technology-node parameter tables for the scaling study (paper SSV-D, Fig. 13).

The paper scales the Table II parameters "per the ITRS roadmap [52]" (FDSOI at
22/11/7 nm) without printing the table; we encode a roadmap-shaped table whose
qualitative anchors are asserted in tests:

  * max achievable SNR_A of QS-Arch/CM *decreases* from 65 nm to 7 nm
    (lower V_dd => more headroom clipping; worse sigma_Vt/(V_WL - V_t)),
  * at fixed SNR_A, energy at 11/7 nm is *higher* than at 22 nm for QS-Arch/CM,
  * QR-Arch keeps approaching the quantization limit (no clipping) and gets
    ~4x energy per 6 dB cheaper with scaling.

Trends encoded: V_dd and C scale down; V_t roughly flat (leakage floor);
sigma_Vt *increases* mildly (smaller devices, AVt/sqrt(WL) with W,L shrinking
faster than AVt improves); wiring/BL cap per row shrinks.
"""
from __future__ import annotations

import dataclasses

from repro.core.compute_models import TechParams

# name -> TechParams
NODES: dict[str, TechParams] = {}


def _mk(name, **kw) -> TechParams:
    p = dataclasses.replace(TechParams(), name=name, **kw)
    NODES[name] = p
    return p


TECH_65 = _mk("65nm")  # Table II values (defaults)

TECH_45 = _mk(
    "45nm",
    v_dd=0.95,
    v_t=0.38,
    sigma_vt=26e-3,
    c_bl=210e-15,
    dv_bl_max=0.80,
    k_prime=260e-6,
    t0=85e-12,
    wl_cox=0.26e-15,
    pelgrom_kappa=0.072 * 1e-15**0.5,
    e_switch=0.08e-15,
    e_add_per_bit=0.7e-15,
)

TECH_28 = _mk(
    "28nm",
    v_dd=0.90,
    v_t=0.36,
    sigma_vt=28e-3,
    c_bl=160e-15,
    dv_bl_max=0.75,
    k_prime=300e-6,
    t0=70e-12,
    wl_cox=0.20e-15,
    pelgrom_kappa=0.065 * 1e-15**0.5,
    e_switch=0.06e-15,
    e_add_per_bit=0.5e-15,
)

TECH_22 = _mk(
    "22nm",
    v_dd=0.85,
    v_t=0.35,
    sigma_vt=30e-3,
    c_bl=130e-15,
    dv_bl_max=0.70,
    k_prime=330e-6,
    t0=60e-12,
    wl_cox=0.16e-15,
    pelgrom_kappa=0.060 * 1e-15**0.5,
    e_switch=0.045e-15,
    e_add_per_bit=0.4e-15,
)

TECH_11 = _mk(
    "11nm",
    v_dd=0.75,
    v_t=0.33,
    sigma_vt=34e-3,
    c_bl=90e-15,
    dv_bl_max=0.60,
    k_prime=380e-6,
    t0=45e-12,
    wl_cox=0.10e-15,
    pelgrom_kappa=0.052 * 1e-15**0.5,
    e_switch=0.03e-15,
    e_add_per_bit=0.25e-15,
)

TECH_7 = _mk(
    "7nm",
    v_dd=0.70,
    v_t=0.32,
    sigma_vt=38e-3,
    c_bl=65e-15,
    dv_bl_max=0.55,
    k_prime=420e-6,
    t0=35e-12,
    wl_cox=0.07e-15,
    pelgrom_kappa=0.046 * 1e-15**0.5,
    e_switch=0.02e-15,
    e_add_per_bit=0.18e-15,
)

SCALING_SEQUENCE = ["65nm", "45nm", "28nm", "22nm", "11nm", "7nm"]
PAPER_SEQUENCE = ["65nm", "22nm", "11nm", "7nm"]  # nodes shown in Fig. 13


def node(name: str) -> TechParams:
    return NODES[name]
