"""IMC architecture-level analytical models: QS-Arch, QR-Arch, CM (paper Table III,
SSIV-B2/C2/D, Appendix B).

All noise variances are in *normalized algorithmic units* (x_m = w_m = 1), i.e.
directly comparable with sigma_yo^2 = N sigma_w^2 E[x^2].  Voltage-domain
quantities (V_c, Delta-V_BL) convert through dv_unit (QS/CM) or V_dd (QR).

Each architecture exposes:
  sigma_qiy_sq / sigma_eta_h_sq / sigma_eta_e_sq / sigma_eta_a_sq
  snr_a / snr_A / snr_T(b_adc)              (linear; *_db helpers)
  b_adc_min(gamma)                          (Table III row "B_ADC")
  v_c_*                                     (ADC input clip level / range)
  energy_per_dp(b_adc) / delay_per_dp       (Table III row "Energy cost per DP")
"""
from __future__ import annotations

import dataclasses
import math
from functools import lru_cache

import numpy as np

from repro.core import precision as prec
from repro.core.adc import adc_energy
from repro.core.compute_models import QRModel, QSModel, TECH_65NM, TechParams
from repro.core.quant import QuantSpec, SignalStats, UNIFORM_STATS


def _db(x):
    return 10.0 * math.log10(max(float(x), 1e-300))


# ---------------------------------------------------------------------------
# Binomial clipping moment (QS-Arch Appendix B)
# ---------------------------------------------------------------------------


@lru_cache(maxsize=4096)
def binomial_clip_second_moment(n: int, k_h: float, p: float = 0.25) -> float:
    """E[(K - k_h)^2 ; K > k_h] for K ~ Binomial(n, p).

    Exact iterative pmf for n <= 20000; Gaussian tail approximation beyond.
    """
    if k_h >= n:
        return 0.0
    if n <= 20000:
        pmf = (1.0 - p) ** n
        total = 0.0
        k0 = int(math.floor(k_h)) + 1
        for k in range(0, n + 1):
            if k >= k0:
                total += (k - k_h) ** 2 * pmf
            pmf *= (n - k) / (k + 1.0) * (p / (1.0 - p))
        return total
    # Gaussian approximation: K ~ N(np, np(1-p))
    mu = n * p
    sig = math.sqrt(n * p * (1 - p))
    z = (k_h - mu) / sig
    pc, scc = prec.gaussian_clip_stats(abs(z)) if z > 0 else (1.0, 1.0 + z * z)
    return 0.5 * pc * scc * sig * sig if z > 0 else sig * sig


# ---------------------------------------------------------------------------
# Shared input-quantization noise (identical for all three architectures)
# ---------------------------------------------------------------------------


def sigma_qiy_sq(n: int, bx: int, bw: int, stats: SignalStats):
    dx = QuantSpec(bx, signed=False, max_val=stats.x_max).delta
    dw = QuantSpec(bw, signed=True, max_val=stats.w_max).delta
    return (n / 12.0) * (dx**2 * stats.var_w + dw**2 * stats.e_x2)


# ---------------------------------------------------------------------------
# Base class
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class IMCArch:
    """Common analytic scaffolding; subclasses fill in the Table III rows."""

    n: int = 512  # DP dimension (rows used per bank)
    bx: int = 6
    bw: int = 6
    stats: SignalStats = UNIFORM_STATS
    tech: TechParams = TECH_65NM

    # ---- Table III rows (subclass responsibility) ----
    def sigma_eta_h_sq(self) -> float:
        raise NotImplementedError

    def sigma_eta_e_sq(self) -> float:
        raise NotImplementedError

    def v_c_norm(self) -> float:
        """ADC clip level in normalized output units (used by MPC math)."""
        raise NotImplementedError

    def analog_energy_per_dp(self) -> float:
        raise NotImplementedError

    def adc_conversions_per_dp(self) -> int:
        raise NotImplementedError

    def adc_range_ratio(self) -> float:
        """V_DD / V_c for the ADC energy model (eq. 26)."""
        raise NotImplementedError

    def delay_per_dp(self, b_adc: int) -> float:
        raise NotImplementedError

    # ---- derived SNRs ----
    def sigma_yo_sq(self) -> float:
        return self.stats.dp_var(self.n)

    def sigma_qiy_sq(self) -> float:
        return sigma_qiy_sq(self.n, self.bx, self.bw, self.stats)

    def sigma_eta_a_sq(self) -> float:
        return self.sigma_eta_h_sq() + self.sigma_eta_e_sq()

    def snr_a(self) -> float:
        return self.sigma_yo_sq() / max(self.sigma_eta_a_sq(), 1e-300)

    def snr_a_db(self) -> float:
        return _db(self.snr_a())

    def sqnr_qiy(self) -> float:
        return self.sigma_yo_sq() / self.sigma_qiy_sq()

    def snr_A(self) -> float:
        """Eq. (10)."""
        return 1.0 / (1.0 / self.snr_a() + 1.0 / self.sqnr_qiy())

    def snr_A_db(self) -> float:
        return _db(self.snr_A())

    def sigma_qy_sq(self, b_adc: int) -> float:
        """Output (ADC) quantization + clip noise at the final DP output, for an
        MPC-clipped ADC with range +-v_c_norm: variance of quantization over the
        clipped range plus conditional clipping noise of the DP output."""
        y_c = self.v_c_norm()
        sigma_yo = math.sqrt(self.sigma_yo_sq())
        zeta = y_c / max(sigma_yo, 1e-300)
        delta = y_c * 2.0 ** (1 - b_adc) / 2.0  # step/2... step = 2 y_c / 2^B
        q_var = (2.0 * y_c * 2.0**-b_adc) ** 2 / 12.0
        p_c, scc = prec.gaussian_clip_stats(zeta)
        return q_var + p_c * scc * sigma_yo**2

    def sqnr_qy(self, b_adc: int) -> float:
        return self.sigma_yo_sq() / self.sigma_qy_sq(b_adc)

    def snr_T(self, b_adc: int) -> float:
        """Eq. (11)."""
        return 1.0 / (1.0 / self.snr_A() + 1.0 / self.sqnr_qy(b_adc))

    def snr_T_db(self, b_adc: int) -> float:
        return _db(self.snr_T(b_adc))

    # ---- precision assignment ----
    def b_adc_mpc(self, gamma_db: float = 0.5) -> int:
        """The MPC term of the Table III B_ADC bound (eq. 15)."""
        return prec.by_mpc_lower_bound(self.snr_A_db(), gamma_db)

    def b_adc_min(self, gamma_db: float = 0.5) -> int:
        raise NotImplementedError

    def b_adc_bgc(self) -> int:
        return prec.by_bgc(self.bx, self.bw, self.n)

    # ---- energy ----
    def adc_energy_per_conversion(self, b_adc: int) -> float:
        return adc_energy(b_adc, self.adc_range_ratio(), self.tech)

    def energy_per_dp(self, b_adc: int | None = None) -> float:
        if b_adc is None:
            b_adc = self.b_adc_min()
        return (
            self.analog_energy_per_dp()
            + self.adc_conversions_per_dp() * self.adc_energy_per_conversion(b_adc)
            + self.misc_energy_per_dp(b_adc)
        )

    def misc_energy_per_dp(self, b_adc: int) -> float:
        """Digital recombination / reduction energy (E_misc)."""
        return self.adc_conversions_per_dp() * b_adc * self.tech.e_add_per_bit

    def edp_per_dp(self, b_adc: int | None = None) -> float:
        if b_adc is None:
            b_adc = self.b_adc_min()
        return self.energy_per_dp(b_adc) * self.delay_per_dp(b_adc)


# ---------------------------------------------------------------------------
# QS-Arch: fully binarized bit-serial DPs (paper SSIV-B2)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class QSArch(IMCArch):
    v_wl: float = 0.8

    @property
    def qs(self) -> QSModel:
        return QSModel(tech=self.tech, v_wl=self.v_wl)

    @property
    def k_h(self) -> float:
        return self.qs.k_h

    # -- Table III noise rows --
    def _plane_weight_sum(self) -> float:
        """sum_{i,j} 4^(1-i-j) = (4/9)(1-4^-Bw)(1-4^-Bx)."""
        return (4.0 / 9.0) * (1 - 4.0**-self.bw) * (1 - 4.0**-self.bx)

    def sigma_eta_h_sq(self) -> float:
        lam2 = binomial_clip_second_moment(self.n, self.k_h)
        return self._plane_weight_sum() * lam2

    def sigma_eta_e_sq(self, include_secondary: bool = False) -> float:
        """Table III: N sigma_D^2 (1-4^-Bw)(1-4^-Bx) / 9 (current mismatch).

        ``include_secondary`` adds pulse-width + thermal terms (the paper's MC
        includes them; Table III neglects them as sub-dominant).
        """
        qs = self.qs
        var_delta = qs.sigma_d**2 / 4.0
        if include_secondary:
            # pulse-width: relative (sigma_T/T)^2 per active cell
            var_delta += (qs.sigma_t() / qs.t_pulse_max) ** 2 / 4.0
            # thermal: in counts^2 per plane, spread over N cells
            v_th_counts = qs.sigma_theta_volts(self.n) / qs.dv_unit
            var_delta += v_th_counts**2 / self.n
        return self._plane_weight_sum() * self.n * var_delta

    # -- ADC --
    def v_c_counts(self) -> float:
        """Per-plane ADC clip level in unit-discharge counts: cover the binomial
        plane-DP up to mean + 4 sigma, bounded by headroom k_h and by N.
        (Table III convention note: DESIGN.md SS7.)"""
        mu = self.n / 4.0
        sig = math.sqrt(3.0 * self.n) / 4.0
        return min(mu + 4.0 * sig, self.k_h, float(self.n))

    def v_c_norm(self) -> float:
        """Clip level referred to the *final* DP output (normalized units):
        plane clip c_plane recombines like the planes themselves."""
        dx = QuantSpec(self.bx, signed=False, max_val=self.stats.x_max).delta
        dw = QuantSpec(self.bw, signed=True, max_val=self.stats.w_max).delta
        # sum of plane weights: (2^Bx - 1)(2^Bw - 1) ~ full-scale recombination
        return self.v_c_counts() * dx * dw * (2.0**self.bx - 1) * (2.0**self.bw - 1) / 4.0

    def adc_range_ratio(self) -> float:
        v_c_volts = self.v_c_counts() * self.qs.dv_unit
        return self.tech.v_dd / max(v_c_volts, 1e-6)

    def b_adc_min(self, gamma_db: float = 0.5) -> int:
        """Table III: >= min((SNR_A + 16.2)/6, log2 k_h, log2 N)."""
        return int(
            math.ceil(
                min(
                    self.b_adc_mpc(gamma_db),
                    math.log2(max(self.k_h, 2.0)),
                    math.log2(self.n),
                )
            )
        )

    # -- energy & delay: E = Bw Bx (E_QS + E_ADC) + E_misc --
    def analog_energy_per_dp(self) -> float:
        mean_counts = min(self.n / 4.0, self.k_h)
        mean_v_a = mean_counts * self.qs.dv_unit
        return self.bx * self.bw * self.qs.energy(mean_v_a, self.n)

    def adc_conversions_per_dp(self) -> int:
        return self.bx * self.bw

    def delay_per_dp(self, b_adc: int) -> float:
        # Bx serial input cycles; Bw columns converted in parallel per cycle.
        t_adc = b_adc * self.tech.t_adc_per_bit
        return self.bx * (self.qs.delay + t_adc)


# ---------------------------------------------------------------------------
# QR-Arch: binary-weighted DPs via charge redistribution (paper SSIV-C2)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class QRArch(IMCArch):
    c_o: float = 3e-15

    @property
    def qr(self) -> QRModel:
        return QRModel(tech=self.tech, c_o=self.c_o)

    def _w_plane_weight_sum(self) -> float:
        """sum_i 4^(1-i), i = 1..Bw -> (4/3)(1 - 4^-Bw); the x input is analog
        (multi-bit DAC) so only weight planes recombine."""
        return (4.0 / 3.0) * (1 - 4.0**-self.bw)

    def sigma_eta_h_sq(self) -> float:
        return 0.0  # QR does not clip (charge conservation; paper SSIV-C)

    def sigma_eta_e_sq(self) -> float:
        """Table III: (2/3)(1-4^-Bw) N (E[x^2] sigma_Co^2/C_o^2 + 2 sigma_th^2/V_dd^2
        + sigma_inj^2)."""
        qr = self.qr
        per_cell = (
            self.stats.e_x2 * qr.sigma_c_rel**2
            + 2.0 * (qr.sigma_theta_volts / self.tech.v_dd) ** 2
            + qr.sigma_inj_norm_sq * self.stats.var_x
        )
        return (2.0 / 3.0) * (1 - 4.0**-self.bw) * self.n * per_cell

    def v_c_volts(self) -> float:
        """Clip level (4 sigma) of the charge-shared plane output
        V = (V_dd/N) sum x^_j w^_ij: sigma_V = (V_dd/2) sqrt((E[x^2]+Var x)/N)
        (paper App. B; Table III's '8 V_dd sqrt(.)' is the full 8-sigma span -
        we standardize on the 4-sigma clip level, DESIGN.md SS7)."""
        s = self.stats
        return (
            2.0
            * self.tech.v_dd
            * math.sqrt((s.e_x2 + s.var_x) / (s.x_max**2 * self.n))
        )

    def v_c_norm(self) -> float:
        """Final-output clip level: planes are not clipped, the ADC clip is MPC
        at 4 sigma of the recombined output."""
        return 4.0 * math.sqrt(self.sigma_yo_sq())

    def adc_range_ratio(self) -> float:
        return self.tech.v_dd / max(self.v_c_volts(), 1e-6)

    def b_adc_min(self, gamma_db: float = 0.5) -> int:
        """Table III: >= min((SNR_A+16.2)/6, Bx + log2 N)."""
        return int(
            math.ceil(min(self.b_adc_mpc(gamma_db), self.bx + math.log2(self.n)))
        )

    # -- energy & delay: E = Bw (E_QR + N E_mult + E_ADC) + E_misc --
    def analog_energy_per_dp(self) -> float:
        qr = self.qr
        e_qr = qr.energy(1.0 - self.stats.mu_x, self.n)
        e_mult = self.stats.mu_x * 0.5 * self.c_o * self.tech.v_dd**2
        return self.bw * (e_qr + self.n * e_mult)

    def adc_conversions_per_dp(self) -> int:
        return self.bw

    def delay_per_dp(self, b_adc: int) -> float:
        t_adc = b_adc * self.tech.t_adc_per_bit
        return self.qr.delay + t_adc  # Bw rows in parallel


# ---------------------------------------------------------------------------
# CM: multi-bit analog DP (QS + QR composed; paper SSIV-D)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CMArch(IMCArch):
    v_wl: float = 0.8

    @property
    def qs(self) -> QSModel:
        # CM uses the smallest pulse T0 as the LSB pulse
        return QSModel(
            tech=dataclasses.replace(self.tech, t_pulse=self.tech.t0),
            v_wl=self.v_wl,
        )

    @property
    def k_h(self) -> float:
        return self.qs.k_h

    def sigma_eta_h_sq(self) -> float:
        """Table III: (1/12) N E[x^2] sigma_w^2 k_h^-2 2^(2Bw) (1 - 2 k_h 2^-Bw)_+^2."""
        s = self.stats
        t = 1.0 - 2.0 * self.k_h * 2.0**-self.bw
        t = max(t, 0.0)
        return (
            (1.0 / 12.0)
            * self.n
            * s.e_x2
            * s.var_w
            * self.k_h**-2
            * 2.0 ** (2 * self.bw)
            * t * t
        )

    def sigma_eta_e_sq(self) -> float:
        """Table III: (2/3) N E[x^2] (1/4 - 4^-Bw) sigma_D^2."""
        return (
            (2.0 / 3.0)
            * self.n
            * self.stats.e_x2
            * (0.25 - 4.0**-self.bw)
            * self.qs.sigma_d**2
        )

    def v_c_volts(self) -> float:
        """Table III (App. B): 4 sigma of Delta-V_o = 2^(Bw-1) dV_unit/N sum w_i x_i."""
        s = self.stats
        sigma_y = math.sqrt(self.n * s.var_w * s.e_x2)
        return 4.0 * 2.0 ** (self.bw - 1) * self.qs.dv_unit * sigma_y / self.n

    def v_c_norm(self) -> float:
        return 4.0 * math.sqrt(self.sigma_yo_sq())

    def adc_range_ratio(self) -> float:
        return self.tech.v_dd / max(self.v_c_volts(), 1e-6)

    def b_adc_min(self, gamma_db: float = 0.5) -> int:
        """Table III: >= (SNR_A + 16.2)/6 (MPC only)."""
        return int(math.ceil(self.b_adc_mpc(gamma_db)))

    # -- energy & delay: E = 2N E_QS + E_QR + E_mult + E_ADC + E_misc --
    def analog_energy_per_dp(self) -> float:
        s = self.stats
        # per-column BL discharge ~ E[|w|] of full scale; E[|w|] for U[-1,1] = 1/2
        mean_counts = min(0.5 * (2.0**self.bw - 1), self.k_h * 2)
        mean_v = min(mean_counts * self.qs.dv_unit, self.tech.dv_bl_max)
        e_qs_col = mean_v * self.tech.v_dd * self.tech.c_bl / self.n + self.tech.e_switch
        qr = QRModel(tech=self.tech, c_o=3e-15)
        e_qr = qr.energy(1.0 - s.mu_x, self.n)
        e_mult = s.mu_x * 0.5 * qr.c_o * self.tech.v_dd**2
        return 2 * self.n * e_qs_col + e_qr + self.n * e_mult

    def adc_conversions_per_dp(self) -> int:
        return 1

    def delay_per_dp(self, b_adc: int) -> float:
        t_max = 2.0 ** (self.bw - 1) * self.tech.t0
        qr = QRModel(tech=self.tech, c_o=3e-15)
        return t_max + self.tech.t_setup + qr.delay + b_adc * self.tech.t_adc_per_bit
