"""In-memory compute models: charge summing (QS), current summing (IS), charge
redistribution (QR) - paper SSIV, Fig. 5, Table II.

Each model maps algorithmic variables of the DP  y_o = sum_j w_j x_j  to physical
quantities:

  QS: (y_o -> V_o,  w_j -> I_j,  x_j -> T_j):  V_o = (1/C) sum_j I_j T_j   (eq. 16)
  QR: (w_j x_j -> V_j):  V_o = sum_j C_j V_j / sum_j C_j                   (eq. 22)
  IS: (w_j -> I_j, x_j -> switch): output current summed over a fixed window
      (the paper defers IS details; we model it as QS with a fixed pulse - the
      same mismatch/thermal machinery applies, no pulse-width noise).

Noise parameter expressions implemented here: eqs. (18)-(20) for QS, eq. (24)
for QR.  Energy: eqs. (21), (25).  Delay: T_QS = T_max + T_su, T_QR = T_share + T_su.

All voltages in volts, capacitances in farads, currents in amperes, times in
seconds, energies in joules.  "Normalized" noise values are referred to the
algorithmic DP with x_m = w_m = 1.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

K_BOLTZMANN = 1.380649e-23  # J/K


# ---------------------------------------------------------------------------
# Technology parameters (Table II; 65 nm CMOS representative process)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TechParams:
    """Process + circuit parameters (Table II plus calibration constants).

    Calibration constants not printed in the paper (w_over_l, t_pulse,
    c_sw, E_su per-cell, ADC timing) are chosen to reproduce the paper's
    quantitative anchors (sigma_I/I in 8-25% over V_WL = 0.55-0.8 V;
    QS-Arch N_max ~ 125 at V_WL = 0.8 V with SNR_A ~ 19.6 dB; see DESIGN.md SS7).
    """

    name: str = "65nm"
    # --- QS / transistor ---
    k_prime: float = 220e-6  # A/V^2 (alpha-law prefactor k')
    alpha: float = 1.8  # alpha-law exponent
    v_t: float = 0.40  # V, threshold voltage
    sigma_vt: float = 23.8e-3  # V, threshold-voltage mismatch std
    v_dd: float = 1.0  # V
    sigma_t0: float = 2.3e-12  # s, unit WL-driver delay std
    t0: float = 100e-12  # s, unit WL-driver delay
    dv_bl_max: float = 0.85  # V, max BL discharge (0.8-0.9 V in Table II)
    c_bl: float = 270e-15  # F, bit-line capacitance (512-row array, SSV)
    g_m: float = 66e-6  # A/V, access transistor transconductance
    temp: float = 300.0  # K
    # calibration (see docstring)
    w_over_l: float = 1.0  # access transistor W/L
    t_pulse: float = 130e-12  # s, LSB word-line pulse width
    t_rise: float = 30e-12  # s, WL pulse rise time
    t_fall: float = 30e-12  # s, WL pulse fall time
    t_setup: float = 200e-12  # s, precharge/setup time T_su
    e_switch: float = 0.1e-15  # J, per-cell switch-toggle energy (E_su component)
    # --- QR ---
    wl_cox: float = 0.31e-15  # F, W*L*C_ox of the QR switch (Table II)
    pelgrom_kappa: float = 0.08 * math.sqrt(1e-15)  # F^0.5 (kappa = 0.08 fF^0.5)
    inj_p: float = 0.5  # charge-injection layout constant p
    # --- misc/digital ---
    e_add_per_bit: float = 1.0e-15  # J, digital add energy per bit (reduction tree)
    t_adc_per_bit: float = 250e-12  # s, SAR ADC time per bit


TECH_65NM = TechParams()


# ---------------------------------------------------------------------------
# QS model (paper SSIV-B)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class QSModel:
    """Charge-summing compute model at an operating point.

    The operating point is (V_WL, pulse width T, capacitor C).  The binary cell
    discharges the BL cap by dv_unit = I T / C per active (x=1, w=1) cell.
    """

    tech: TechParams = TECH_65NM
    v_wl: float = 0.8

    # --- device quantities -------------------------------------------------
    @property
    def cell_current(self) -> float:
        """alpha-law cell current, eq. (31): I = (W/L) k' (V_WL - V_t)^alpha."""
        ov = max(self.v_wl - self.tech.v_t, 1e-9)
        return self.tech.w_over_l * self.tech.k_prime * ov**self.tech.alpha

    @property
    def sigma_d(self) -> float:
        """Normalized current mismatch sigma_I/I, eq. (18):
        sigma_D = alpha sigma_Vt / (V_WL - V_t)."""
        ov = max(self.v_wl - self.tech.v_t, 1e-9)
        return self.tech.alpha * self.tech.sigma_vt / ov

    @property
    def t_rf(self) -> float:
        """Effective pulse-width loss from finite rise/fall times, eq. (19)."""
        t = self.tech
        return t.t_rise - ((self.v_wl - t.v_t) / self.v_wl) * (
            (t.t_rise + t.t_fall) / (t.alpha + 1.0)
        )

    def sigma_t(self, h_stages: float = 1.0) -> float:
        """Pulse-width mismatch std, eq. (20): sigma_Tj = sqrt(h_j) sigma_T0."""
        return math.sqrt(h_stages) * self.tech.sigma_t0

    def sigma_theta_volts(self, n: int, t_max: float | None = None) -> float:
        """Integrated BL thermal noise voltage std, eq. (20):
        sigma_theta = (1/C) sqrt(N T_max g_m k T / 3)."""
        t = self.tech
        t_max = self.t_pulse_max if t_max is None else t_max
        return (1.0 / t.c_bl) * math.sqrt(n * t_max * t.g_m * K_BOLTZMANN * t.temp / 3.0)

    # --- derived array quantities ------------------------------------------
    @property
    def t_pulse_max(self) -> float:
        return self.tech.t_pulse

    @property
    def t_eff(self) -> float:
        """Effective integration window: nominal pulse minus the deterministic
        rise/fall-time loss t_rf (eq. 19/36)."""
        return max(self.tech.t_pulse - self.t_rf, 1e-12)

    @property
    def dv_unit(self) -> float:
        """Actual BL discharge per active cell: Delta V_BL,unit = I T_eff / C
        (the deterministic rise/fall loss is part of the unit discharge; it is
        known and compensated digitally at reconstruction)."""
        return self.cell_current * self.t_eff / self.tech.c_bl

    @property
    def k_h(self) -> float:
        """Headroom in unit discharges: k_h = Delta V_BL,max / Delta V_BL,unit
        (Table III footnote) - the number of simultaneously-active cells the BL
        can absorb before clipping."""
        return self.tech.dv_bl_max / self.dv_unit

    # --- energy & delay (eq. 21) --------------------------------------------
    def energy(self, mean_v_a: float, n: int) -> float:
        """E_QS = E[V_a] V_dd C + E_su (eq. 21). mean_v_a in volts."""
        t = self.tech
        return mean_v_a * t.v_dd * t.c_bl + n * t.e_switch

    @property
    def delay(self) -> float:
        """T_QS = T_max + T_su."""
        return self.tech.t_pulse + self.tech.t_setup


# ---------------------------------------------------------------------------
# IS model (current summing; modeled as fixed-window QS - see module docstring)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ISModel:
    tech: TechParams = TECH_65NM
    v_wl: float = 0.8

    @property
    def _qs(self) -> QSModel:
        return QSModel(tech=self.tech, v_wl=self.v_wl)

    @property
    def sigma_d(self) -> float:
        return self._qs.sigma_d

    def sigma_theta_volts(self, n: int) -> float:
        return self._qs.sigma_theta_volts(n)

    @property
    def dv_unit(self) -> float:
        return self._qs.dv_unit

    @property
    def k_h(self) -> float:
        return self._qs.k_h

    def energy(self, mean_v_a: float, n: int) -> float:
        return self._qs.energy(mean_v_a, n)

    @property
    def delay(self) -> float:
        # no per-row pulse modulation: single fixed integration window
        return self.tech.t_pulse + self.tech.t_setup


# ---------------------------------------------------------------------------
# QR model (paper SSIV-C)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class QRModel:
    """Charge-redistribution compute model with unit capacitors C_o."""

    tech: TechParams = TECH_65NM
    c_o: float = 3e-15  # F (1-10 fF MOM caps)

    @property
    def sigma_c(self) -> float:
        """Capacitor mismatch std, eq. (24): sigma_C = kappa sqrt(C)."""
        return self.tech.pelgrom_kappa * math.sqrt(self.c_o)

    @property
    def sigma_c_rel(self) -> float:
        """sigma_C / C = kappa / sqrt(C)."""
        return self.sigma_c / self.c_o

    @property
    def sigma_theta_volts(self) -> float:
        """Per-capacitor kT/C thermal noise voltage std, eq. (24)."""
        return math.sqrt(K_BOLTZMANN * self.tech.temp / self.c_o)

    def charge_injection_volts(self, v_j: float) -> float:
        """Deterministic-per-voltage charge injection, eq. (24):
        v_inj = p W L C_ox (V_dd - V_t - V_j) / C_j."""
        t = self.tech
        return t.inj_p * t.wl_cox * (t.v_dd - t.v_t - v_j) / self.c_o

    @property
    def sigma_inj_norm_sq(self) -> float:
        """Normalized (V/V_dd) charge-injection *noise* variance.

        v_inj depends linearly on the signal voltage V_j = x V_dd; the
        signal-dependent part acts as noise (the constant part is an offset,
        calibrated out).  Var(v_inj/V_dd) = (p WLCox / C_o)^2 Var(x).
        See DESIGN.md SS7 deviation (2) - the paper's footnote is dimensionally
        loose; the Monte Carlo uses eq. (24) directly and validates this.
        """
        t = self.tech
        g = t.inj_p * t.wl_cox / self.c_o
        return g * g  # multiply by Var(x) at the architecture level

    # --- energy & delay (eq. 25) --------------------------------------------
    def energy(self, mean_one_minus_v_norm: float, n: int) -> float:
        """E_QR = sum_j E[(V_dd - V_j)] V_dd C_j + E_su (eq. 25).

        ``mean_one_minus_v_norm`` = E[1 - V_j/V_dd] = E[1 - x] for V_j = x V_dd.
        """
        t = self.tech
        return n * (mean_one_minus_v_norm * t.v_dd) * t.v_dd * self.c_o + n * t.e_switch

    @property
    def delay(self) -> float:
        """T_QR = T_share + T_su; charge sharing settles in a few RC constants -
        we use a fixed 2 T_0 for T_share (sub-ns for fF caps)."""
        return 2 * self.tech.t0 + self.tech.t_setup
