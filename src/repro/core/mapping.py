"""Mapping matmuls onto IMC bit-cell arrays: bank tiling, and whole-model
energy/delay/SNR rollups (beyond-paper extension of SSV-C to full architectures).

A (K x M) weight matrix deployed on R-row x C-col SRAM banks occupies
ceil(K/R) x ceil(M*B_w/C) banks (QS-Arch stores B_w columns per output).  A
T-token forward pass executes T dot products per output column; banks operate in
parallel, K-direction partials reduce digitally.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

from repro.core.compute_models import TECH_65NM, TechParams
from repro.core.design import DesignPoint, optimize
from repro.core.quant import SignalStats, UNIFORM_STATS


@dataclasses.dataclass(frozen=True)
class BankSpec:
    rows: int = 512  # paper SSV: 512-row SRAM array
    cols: int = 512


@dataclasses.dataclass(frozen=True)
class MatmulShape:
    """One linear layer: y[M] = W[K, M]^T x[K], executed for `calls` tokens."""

    name: str
    k: int
    m: int
    calls: int = 1


def per_token_matmul_shapes(cfg) -> List[MatmulShape]:
    """All weight-stationary matmul sites one token-forward of ``cfg``
    executes (attention score/value products are activation-activation and
    stay digital).  ``calls`` counts layer repetitions per token.

    This is THE shapes walk: model-scale energy rollups
    (``benchmarks/model_energy``), the serve-path meter
    (``launch.metering.DPMeter``) and the profiling-side rollup
    (``launch.breakdown``) all share it, so a site can never be counted
    twice (or with diverging ``calls``) between the accounting paths.
    """
    d, hd = cfg.d_model, cfg.resolved_head_dim
    shapes: List[MatmulShape] = []
    counts: Dict[str, int] = {}
    for kind in cfg.pattern:
        counts[kind] = counts.get(kind, 0) + cfg.n_full_cycles
    for kind in cfg.tail_kinds:
        counts[kind] = counts.get(kind, 0) + 1
    for kind, cnt in counts.items():
        if kind in ("attn", "local"):
            shapes += [
                MatmulShape(f"{kind}.wq", d, cfg.n_heads * hd, cnt),
                MatmulShape(f"{kind}.wk", d, cfg.n_kv_heads * hd, cnt),
                MatmulShape(f"{kind}.wv", d, cfg.n_kv_heads * hd, cnt),
                MatmulShape(f"{kind}.wo", cfg.n_heads * hd, d, cnt),
            ]
        elif kind == "ssm":
            d_in = cfg.ssm_expand * d
            proj = (2 * d_in + 2 * cfg.ssm_groups * cfg.ssm_state
                    + d_in // cfg.ssm_head_dim)
            shapes += [
                MatmulShape("ssm.in_proj", d, proj, cnt),
                MatmulShape("ssm.out_proj", d_in, d, cnt),
            ]
        elif kind == "rglru":
            w = cfg.rnn_width
            shapes += [
                MatmulShape("rg.x", d, w, cnt),
                MatmulShape("rg.gate", d, w, cnt),
                MatmulShape("rg.out", w, d, cnt),
            ]
        if kind != "ssm" and cfg.d_ff > 0:
            mults = 3 if cfg.mlp_kind in ("swiglu", "geglu") else 2
            e = cfg.top_k if cfg.n_experts else 1  # active experts per token
            shapes += [
                MatmulShape("mlp.wi", d, cfg.d_ff, cnt * e * (mults - 1)),
                MatmulShape("mlp.wo", cfg.d_ff, d, cnt * e),
            ]
    shapes.append(MatmulShape("lm_head", d, cfg.vocab_size, 1))
    return shapes


@dataclasses.dataclass(frozen=True)
class LayerReport:
    name: str
    k: int
    m: int
    calls: int
    n_banks_k: int
    n_banks_m: int
    design: DesignPoint
    energy_j: float
    delay_s: float
    snr_t_db: float

    @property
    def energy_per_mac_j(self) -> float:
        return self.energy_j / (self.k * self.m * self.calls)


def map_matmul(
    shape: MatmulShape,
    snr_t_target_db: float,
    bank: BankSpec = BankSpec(),
    stats: SignalStats = UNIFORM_STATS,
    tech: TechParams = TECH_65NM,
    kinds=("qs", "qr", "cm"),
    design: Optional[DesignPoint] = None,
) -> Optional[LayerReport]:
    """Tile one matmul onto banks and cost it at the optimal design point.

    The DP dimension per bank is min(K, rows); K-direction tiling reduces
    digitally (handled inside `optimize` via its banking dimension when
    K <= rows*max_banks, otherwise we tile explicitly here).
    """
    n_banks_k = int(math.ceil(shape.k / bank.rows))
    n_bank_rows = int(math.ceil(shape.k / n_banks_k))
    if design is None:
        design = optimize(
            n=shape.k,
            snr_t_target_db=snr_t_target_db,
            stats=stats,
            tech=tech,
            kinds=kinds,
            max_rows=bank.rows,
        )
    if design is None:
        return None
    arch = design.arch(stats)
    bw = design.bw
    cols_per_out = bw if design.arch_kind == "qs" else 1
    n_banks_m = int(math.ceil(shape.m * cols_per_out / bank.cols))

    # per-DP energy already includes the K-direction bank reduction (design.n_banks)
    e_dp = design.energy_per_dp
    energy = e_dp * shape.m * shape.calls
    # all M columns within a bank convert in column-parallel; bank-tiles in M are
    # independent banks (parallel); K-direction reduction is in the design point.
    delay = design.delay_per_dp * shape.calls
    return LayerReport(
        name=shape.name,
        k=shape.k,
        m=shape.m,
        calls=shape.calls,
        n_banks_k=design.n_banks,
        n_banks_m=n_banks_m,
        design=design,
        energy_j=energy,
        delay_s=delay,
        snr_t_db=design.snr_t_db,
    )


@dataclasses.dataclass
class ModelReport:
    layers: List[LayerReport]

    @property
    def total_energy_j(self) -> float:
        return sum(l.energy_j for l in self.layers)

    @property
    def total_macs(self) -> int:
        return sum(l.k * l.m * l.calls for l in self.layers)

    @property
    def energy_per_mac_j(self) -> float:
        return self.total_energy_j / max(self.total_macs, 1)

    @property
    def tops_per_watt(self) -> float:
        """2 ops per MAC."""
        return 2.0 / self.energy_per_mac_j / 1e12

    @property
    def min_snr_t_db(self) -> float:
        return min(l.snr_t_db for l in self.layers)

    def summary(self) -> Dict[str, float]:
        return {
            "layers": len(self.layers),
            "total_energy_j": self.total_energy_j,
            "energy_per_mac_fj": self.energy_per_mac_j * 1e15,
            "tops_per_watt": self.tops_per_watt,
            "min_snr_t_db": self.min_snr_t_db,
        }


def map_model(
    shapes: List[MatmulShape],
    snr_t_target_db: float,
    bank: BankSpec = BankSpec(),
    stats: SignalStats = UNIFORM_STATS,
    tech: TechParams = TECH_65NM,
    kinds=("qs", "qr", "cm"),
) -> ModelReport:
    """Cost a whole model (list of matmul shapes) on IMC hardware.

    Design points are cached per distinct K (the optimizer only depends on the
    DP dimension), so 60-layer models cost ~3 optimizer calls.
    """
    cache: Dict[int, Optional[DesignPoint]] = {}
    reports = []
    for s in shapes:
        if s.k not in cache:
            cache[s.k] = optimize(
                n=s.k, snr_t_target_db=snr_t_target_db, stats=stats, tech=tech,
                kinds=kinds, max_rows=bank.rows,
            )
        d = cache[s.k]
        r = map_matmul(s, snr_t_target_db, bank, stats, tech, kinds, design=d)
        if r is not None:
            reports.append(r)
    return ModelReport(layers=reports)
