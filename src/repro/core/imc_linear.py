"""IMCLinear: the paper's technique as an executable layer.

Every matmul in the model zoo routes through :func:`linear`, which executes in
one of four modes (ExecMode):

  digital        plain matmul (the baseline; used for training + dry-run).
  fakequant      B_x/B_w input quantization only (digital FX arithmetic, STE
                 gradients) - isolates SQNR_qiy (paper eq. 8).
  imc_analytic   folded-noise IMC model: fakequant matmul + Gaussian analog
                 noise at the analytic SNR_a (repro.core.archs) + MPC-clipped
                 B_ADC output quantization (paper eqs. 10-15). Differentiable
                 (STE) => usable for noise-aware training; cheap => usable at
                 dry-run scale; pure-jnp => shards under pjit.
  imc_bitserial  bit-exact QS-Arch simulation via the Pallas kernel
                 (repro.kernels) - for silicon-fidelity studies at layer scale.
                 Per-plane analog noise is generated inside the kernel from a
                 scalar seed derived from the layer key: no noise tensor is
                 materialized at any point in this path.

The mode and design knobs live in IMCConfig, threaded through model configs.
Per-layer RNG is derived with jax.random.fold_in over a static layer id.

First-class substrates (repro.core.substrate) wrap an IMCConfig with a
calibration policy (dynamic per-batch stats vs frozen calibrated ranges) and
per-site overrides; :func:`linear` accepts either and resolves the effective
IMCConfig per compute site.  A bare IMCConfig is exactly the dynamic-policy
substrate - bit-for-bit the historical behaviour.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.archs import QSArch
from repro.core.quant import QuantSpec


@dataclasses.dataclass(frozen=True)
class IMCConfig:
    """Static IMC execution configuration (hashable; safe as a jit static arg)."""

    mode: str = "digital"  # digital|fakequant|imc_analytic|imc_bitserial
    bx: int = 6
    bw: int = 6
    b_adc: Optional[int] = None  # None -> MPC assignment from SNR_A
    rows: int = 512  # SRAM bank height (DP dim per bank)
    x_signed: bool = True
    # analog design point (QS-Arch knobs; used to derive SNR_a when
    # snr_a_db is None)
    v_wl: float = 0.7
    snr_a_db: Optional[float] = None
    y_clip_sigmas: float = 4.0
    use_kernel: bool = False  # Pallas path for bitserial (layer-scale studies)
    # assumed operand PARs (max/sigma) for static ADC assignment on the
    # bit-serial path; 4.0 ~ Gaussian tensors clipped at 4 sigma
    par_x: float = 4.0
    par_w: float = 4.0
    adc_margin_db: float = 9.0  # SQNR_qy >= SNR_A + margin (paper SSIII-B)

    def bank_rows(self, n: Optional[int] = None) -> int:
        """Auto-banking (paper SSVI bullet 4): the DP dimension per bank is
        limited to N_max of the design point - choose the largest power-of-two
        bank height within 1 dB of the peak analytic SNR_A."""
        return _bank_rows_cached(
            min(n or self.rows, self.rows), self.bx, self.bw, self.v_wl
        )

    def resolved_snr_a_db(self, n: Optional[int] = None) -> float:
        if self.snr_a_db is not None:
            return self.snr_a_db
        arch = self.qs_arch(n)
        return float(arch.snr_a_db())

    def qs_arch(self, n: Optional[int] = None) -> QSArch:
        return QSArch(n=self.bank_rows(n), bx=self.bx, bw=self.bw,
                      v_wl=self.v_wl)

    def resolved_b_adc(self, n: Optional[int] = None) -> int:
        """MPC assignment (paper eq. 15) - used for *final-output* ADCs
        (imc_analytic mode, CM/QR-style architectures)."""
        if self.b_adc is not None:
            return self.b_adc
        from repro.core.precision import by_mpc_lower_bound

        return by_mpc_lower_bound(self.resolved_snr_a_db(n))

    def resolved_b_adc_bitserial(self, n: int) -> int:
        """Per-plane ADC precision for the bit-serial QS-Arch path.

        The paper's eq. (15) targets a single final-output ADC.  In QS-Arch the
        ADC digitizes each (i, j) binary plane DP, and plane errors recombine
        with 4^(i+j) weights, so the requirement must be placed on the
        *recombined* ADC noise:

          n_banks * S_x * S_w * Delta^2/12 <= sigma_yo,code^2 * 10^-(SNR_A+m)/10

        with S_b = (4^B - 1)/3 the sum of squared plane weights and
        sigma_yo,code estimated from the assumed operand PARs.  For the paper's
        low-PAR uniform operands this reduces to ~eq. (15); for high-PAR
        Gaussian LM tensors it assigns 2-4 more bits (DESIGN.md SS7).
        """
        if self.b_adc is not None:
            return self.b_adc
        import math

        arch = self.qs_arch(n)
        nb = arch.n
        n_banks = max(1, -(-n // nb))
        sx = 2.0 ** (self.bx - 1) / self.par_x if self.x_signed else (
            2.0**self.bx * 0.5 / self.par_x
        )
        sw = 2.0 ** (self.bw - 1) / self.par_w
        sigma_yo_sq = n * sx**2 * sw**2
        budget = sigma_yo_sq * 10.0 ** (
            -(arch.snr_A_db() + self.adc_margin_db) / 10.0
        )
        s_x = (4.0**self.bx - 1) / 3.0
        s_w = (4.0**self.bw - 1) / 3.0
        delta = math.sqrt(12.0 * budget / (n_banks * s_x * s_w))
        v_c = arch.v_c_counts()
        b = int(math.ceil(math.log2(max(v_c / max(delta, 1e-6), 2.0))))
        return max(2, min(b, 14))


DIGITAL = IMCConfig(mode="digital")


import functools as _functools


@_functools.lru_cache(maxsize=1024)
def _bank_rows_cached(size: int, bx: int, bw: int, v_wl: float) -> int:
    cands = []
    c = size
    while c >= 32:
        cands.append(c)
        c //= 2
    if not cands:
        return max(size, 1)
    snrs = [QSArch(n=nb, bx=bx, bw=bw, v_wl=v_wl).snr_A_db() for nb in cands]
    peak = max(snrs)
    for nb, s in zip(cands, snrs):  # cands sorted large -> small
        if s >= peak - 1.0:
            return nb
    return cands[-1]


# ---------------------------------------------------------------------------
# quantizer helpers (dynamic per-tensor scales, STE gradients)
# ---------------------------------------------------------------------------


def _fq_ste(v, bits: int, signed: bool, max_val):
    """fake-quant with straight-through gradient."""
    if signed:
        delta = max_val * 2.0 ** (1 - bits)
        lo, hi = -(2.0 ** (bits - 1)), 2.0 ** (bits - 1) - 1
    else:
        delta = max_val * 2.0 ** (-bits)
        lo, hi = 0.0, 2.0**bits - 1
    q = jnp.clip(jnp.round(v / delta), lo, hi) * delta
    return v + jax.lax.stop_gradient(q - v)


def _dynamic_max(v):
    return jax.lax.stop_gradient(jnp.max(jnp.abs(v)) + 1e-9)


# ---------------------------------------------------------------------------
# the layer
# ---------------------------------------------------------------------------


def linear(
    w: jax.Array,  # (d_in, d_out)
    x: jax.Array,  # (..., d_in)
    cfg=DIGITAL,  # IMCConfig | core.substrate.Substrate
    rng: Optional[jax.Array] = None,
    bias: Optional[jax.Array] = None,
    dot_general=None,
    site: Optional[str] = None,
) -> jax.Array:
    """y = x @ w (+ bias) on the configured execution substrate.

    ``cfg`` is the substrate the matmul executes on: either a first-class
    :class:`repro.core.substrate.Substrate` (``DigitalSubstrate`` /
    ``AnalyticIMC`` / ``BitSerialIMC``) or, for backward compatibility, a
    bare :class:`IMCConfig` - which behaves exactly like the equivalent
    dynamic-policy substrate (bit-for-bit: same ops, same per-batch
    quantizer statistics).

    ``site`` names the compute site this call implements, using the site
    vocabulary of the ONE shared shapes walk
    (``core.mapping.per_token_matmul_shapes``: ``"attn.wq"``, ``"mlp.wi"``,
    ``"lm_head"``, ...).  It selects any per-site substrate override (e.g. a
    higher B_ADC on the output head) and, under a ``frozen`` calibration
    policy, the frozen quantizer ranges - which replace the per-batch
    ``max|x|`` / ``std(y)`` statistics and make the call
    batch-composition-invariant.  ``site=None`` uses the substrate's base
    config and the calibration's ``"*"`` fallback entry.
    """
    from repro.core import substrate as substrate_lib

    sub = substrate_lib.as_substrate(cfg)
    cfg = sub.site_config(site)
    if cfg.mode == "digital":
        if dot_general is not None:
            y = dot_general(x, w)
        else:
            y = jnp.einsum("...k,km->...m", x, w)
        return y if bias is None else y + bias

    rec = substrate_lib.active_recorder()
    if rec is not None:
        # calibration pass (eager): record this site's operand ranges, then
        # execute the cheap noiseless fakequant proxy - same ranges as the
        # real substrate without paying for noise draws / bit-serial planes
        x_max = _dynamic_max(x)
        w_max = _dynamic_max(w)
        xq = _fq_ste(x, cfg.bx, cfg.x_signed, x_max)
        wq = _fq_ste(w, cfg.bw, True, w_max)
        y = jnp.einsum("...k,km->...m", xq, wq)
        rec.observe(site or substrate_lib.DEFAULT_SITE, x, w, y=y)
        return y if bias is None else y + bias

    # passive shadow observation (online drift monitoring): the sampled
    # forward executes its real substrate path below UNCHANGED - the shadow
    # recorder only taps operand/output stats through debug callbacks.  The
    # output fed to the recorder is the closest available pre-ADC proxy:
    # the fakequant product for fakequant/analytic, the kernel output for
    # bit-serial (post-ADC, a conservative sigma_yo proxy - drift detection
    # is driven by the one-sided x_max/w_max tests either way).
    shadow = substrate_lib.active_shadow_recorder()

    def _shadow_note(y_obs):
        if shadow is not None:
            shadow.observe(site or substrate_lib.DEFAULT_SITE, x, w, y=y_obs)

    stats = sub.site_stats(site)  # None => dynamic per-batch statistics
    if stats is None:
        x_max = _dynamic_max(x)
        w_max = _dynamic_max(w)
    else:
        x_max = stats.x_max
        w_max = stats.w_max

    if cfg.mode == "fakequant":
        xq = _fq_ste(x, cfg.bx, cfg.x_signed, x_max)
        wq = _fq_ste(w, cfg.bw, True, w_max)
        y = jnp.einsum("...k,km->...m", xq, wq)
        _shadow_note(y)
        return y if bias is None else y + bias

    if cfg.mode == "imc_analytic":
        n = x.shape[-1]
        xq = _fq_ste(x, cfg.bx, cfg.x_signed, x_max)
        wq = _fq_ste(w, cfg.bw, True, w_max)
        y = jnp.einsum("...k,km->...m", xq, wq)
        _shadow_note(y)
        if stats is None:
            sigma_yo = jax.lax.stop_gradient(jnp.std(y) + 1e-9)
        else:
            sigma_yo = stats.sigma_yo
        snr_a_db = cfg.resolved_snr_a_db(n)
        sigma_a = sigma_yo * 10.0 ** (-snr_a_db / 20.0)
        if rng is not None:
            y = y + sigma_a * jax.random.normal(rng, y.shape, dtype=y.dtype)
        # MPC output ADC: clip at zeta*sigma, quantize with B_ADC bits (STE)
        b_adc = cfg.resolved_b_adc(n)
        y_c = cfg.y_clip_sigmas * sigma_yo
        y = _fq_ste(jnp.clip(y, -y_c, y_c), b_adc, True, y_c)
        return y if bias is None else y + bias

    if cfg.mode == "imc_bitserial":
        from repro.kernels import ops as kops

        n = x.shape[-1]
        mcfg = kops.matmul_config_from_imc(cfg, n)
        lead = x.shape[:-1]
        x2 = x.reshape((-1, x.shape[-1]))
        y = kops.imc_matmul(x2, w, mcfg, key=rng, x_max=x_max, w_max=w_max)
        y = y.reshape(lead + (w.shape[-1],)).astype(x.dtype)
        _shadow_note(y)
        return y if bias is None else y + bias

    raise ValueError(f"unknown IMC mode {cfg.mode!r}")


def layer_rng(base: Optional[jax.Array], layer_id: int) -> Optional[jax.Array]:
    """Derive a per-layer noise key (None passes through)."""
    if base is None:
        return None
    return jax.random.fold_in(base, layer_id)
