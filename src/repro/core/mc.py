"""Sample-accurate Monte Carlo simulation of the IMC architectures (paper SSV-A,
Fig. 8): the 'S' curves that validate the Table III 'E' expressions.

Each simulator draws an ensemble of circuit instances (spatial mismatch is fixed
per instance, temporal noise redrawn per evaluation), pushes real operand vectors
through the *physical* signal chain of eqs. (17) / (23) - including the
nonlinear clipping and the ADC - and returns BOTH the post-ADC and the pre-ADC
reconstructed DP outputs from the SAME analog pass (same noise draws): one
simulation yields the full-chain SNR_T and the chain-without-ADC SNR_A, so MC
validation runs each circuit once instead of twice.

Everything is jax.vmap-vectorized over ensemble instances and jit-compatible.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.archs import CMArch, QRArch, QSArch
from repro.core.compute_models import K_BOLTZMANN
from repro.core.quant import QuantSpec, bit_planes


# ---------------------------------------------------------------------------
# ADC helper: clipped uniform quantizer in physical units
# ---------------------------------------------------------------------------


def adc_quantize(v, b_adc: int, v_lo: float, v_hi: float):
    """B_ADC-bit uniform ADC over [v_lo, v_hi] (values beyond the range clip)."""
    span = v_hi - v_lo
    delta = span / (2.0**b_adc)
    code = jnp.clip(jnp.round((v - v_lo) / delta), 0, 2.0**b_adc - 1)
    return v_lo + code * delta


# ---------------------------------------------------------------------------
# QS-Arch sample-accurate simulator (eq. 17 per bit plane)
# ---------------------------------------------------------------------------


def mc_qs_arch(
    key: jax.Array,
    x: jax.Array,  # (ens, N) unsigned in [0, x_max]
    w: jax.Array,  # (ens, N) signed in [-w_max, w_max]
    arch: QSArch,
    b_adc: Optional[int] = None,
):
    """Returns (y_post, y_pre, y_ideal) per instance: the IMC-computed DP with
    and without the ADC (same analog noise draws) and the FL DP.

    Physical chain per (weight-bit i, input-bit j) plane:
      per-cell discharge dv_unit * (1 + i_k/I) * (1 + t_k/T) for active cells
      + thermal noise, clipped at dv_bl_max, ADC-quantized, then power-of-two
      recombined and rescaled to algorithmic units.
    """
    ens, n = x.shape
    qs = arch.qs
    tech = arch.tech
    if b_adc is None:
        b_adc = arch.b_adc_min()

    xspec = QuantSpec(arch.bx, signed=False, max_val=arch.stats.x_max)
    wspec = QuantSpec(arch.bw, signed=True, max_val=arch.stats.w_max)
    xc = jnp.clip(jnp.round(x / xspec.delta), xspec.code_min, xspec.code_max)
    wc = jnp.clip(jnp.round(w / wspec.delta), wspec.code_min, wspec.code_max)

    xb, xw_weights = bit_planes(xc, arch.bx, signed=False)  # (Bx, ens, N)
    wb, ww_weights = bit_planes(wc, arch.bw, signed=True)  # (Bw, ens, N)

    k_cur, k_pw, k_th = jax.random.split(key, 3)
    # spatial current mismatch: fixed per instance per cell
    cur_mis = 1.0 + qs.sigma_d * jax.random.normal(k_cur, (ens, n))
    # temporal pulse-width mismatch: per cell per plane-evaluation
    pw_mis = 1.0 + (qs.sigma_t() / qs.t_eff) * jax.random.normal(
        k_pw, (arch.bx, ens, n)
    )
    # NOTE: the deterministic rise/fall-time loss (eq. 19) is folded into
    # dv_unit = I*T_eff/C (known, compensated digitally; paper: "can be
    # mitigated by carefully designing the WL pulse generators").
    dv_unit = qs.dv_unit
    dv_max = tech.dv_bl_max

    # active-cell discharge per plane (i, j): sum_k wb_i xb_j * per-cell gain
    # (ens, N) contributions -> (Bw, Bx, ens)
    def plane_discharge(wbi, xbj, pwj):
        contrib = wbi * xbj * cur_mis * pwj  # (ens, N)
        v = dv_unit * jnp.sum(contrib, axis=-1)  # (ens,)
        return v

    v_planes = jax.vmap(
        lambda wbi: jax.vmap(lambda xbj, pwj: plane_discharge(wbi, xbj, pwj))(
            xb, pw_mis
        )
    )(wb)  # (Bw, Bx, ens)

    # integrated thermal noise per plane evaluation
    sigma_th = qs.sigma_theta_volts(n)
    v_planes = v_planes + sigma_th * jax.random.normal(k_th, v_planes.shape)

    # headroom clipping (eq. 17): v_a = min(V_o, V_o,max)
    v_planes = jnp.minimum(v_planes, dv_max)

    v_c = arch.v_c_counts() * dv_unit
    v_adc = adc_quantize(v_planes, b_adc, 0.0, v_c)

    def recombine(v):
        counts = v / dv_unit  # back to unit-discharge counts
        # digital POT recombination: y_code = sum_{i,j} ww_i xw_j counts_ij
        y_code = jnp.einsum("i,j,ije->e", ww_weights, xw_weights, counts)
        return y_code * xspec.delta * wspec.delta

    y_ideal = jnp.sum(w * x, axis=-1)
    return recombine(v_adc), recombine(v_planes), y_ideal


# ---------------------------------------------------------------------------
# QR-Arch sample-accurate simulator (eq. 23 per weight-bit plane)
# ---------------------------------------------------------------------------


def mc_qr_arch(
    key: jax.Array,
    x: jax.Array,  # (ens, N)
    w: jax.Array,  # (ens, N)
    arch: QRArch,
    b_adc: Optional[int] = None,
):
    """Charge redistribution across N caps per weight-bit plane:
    V = sum_j (C + c_j)(V_j + v_th,j + v_inj,j) / sum_j (C + c_j), V_j = x_j w^_i V_dd.

    Returns (y_post, y_pre, y_ideal); post/pre-ADC share one analog pass.
    """
    ens, n = x.shape
    qr = arch.qr
    tech = arch.tech
    if b_adc is None:
        b_adc = arch.b_adc_min()

    xspec = QuantSpec(arch.bx, signed=False, max_val=arch.stats.x_max)
    wspec = QuantSpec(arch.bw, signed=True, max_val=arch.stats.w_max)
    xq = jnp.clip(jnp.round(x / xspec.delta), xspec.code_min, xspec.code_max) * xspec.delta
    wc = jnp.clip(jnp.round(w / wspec.delta), wspec.code_min, wspec.code_max)
    wb, ww_weights = bit_planes(wc, arch.bw, signed=True)  # (Bw, ens, N)

    k_cap, k_th, k_inj = jax.random.split(key, 3)
    caps = qr.c_o + qr.sigma_c * jax.random.normal(k_cap, (ens, n))  # spatial
    caps = jnp.maximum(caps, 0.1 * qr.c_o)

    v_dd = tech.v_dd

    def plane_voltage(wbi, kth):
        v_j = (xq / arch.stats.x_max) * wbi * v_dd  # (ens, N) in volts
        v_th = qr.sigma_theta_volts * jax.random.normal(kth, (ens, n))
        v_inj = tech.inj_p * tech.wl_cox * (v_dd - tech.v_t - v_j) / caps
        v_inj = v_inj * wbi  # switch only toggles for active cells
        num = jnp.sum(caps * (v_j + v_th + v_inj), axis=-1)
        den = jnp.sum(caps, axis=-1)
        return num / den  # (ens,)

    keys = jax.random.split(k_th, arch.bw)
    v_planes = jax.vmap(plane_voltage)(wb, keys)  # (Bw, ens)

    v_c = arch.v_c_volts()
    mu = float(arch.stats.mu_x) * v_dd / 2.0  # plane mean (w-bit ~ Bern(1/2))
    v_adc = adc_quantize(v_planes, b_adc, mu - v_c, mu + v_c)

    def recombine(v):
        # normalize: plane DP estimate = V * N / V_dd (x-normalized counts)
        plane_dp = v * n / v_dd * arch.stats.x_max
        y_code = jnp.einsum("i,ie->e", ww_weights, plane_dp)
        return y_code * wspec.delta

    y_ideal = jnp.sum(w * x, axis=-1)
    return recombine(v_adc), recombine(v_planes), y_ideal


# ---------------------------------------------------------------------------
# CM sample-accurate simulator (QS multi-bit column + QR aggregation)
# ---------------------------------------------------------------------------


def mc_cm(
    key: jax.Array,
    x: jax.Array,  # (ens, N)
    w: jax.Array,  # (ens, N)
    arch: CMArch,
    b_adc: Optional[int] = None,
):
    """CM: per-column POT-weighted QS discharge encodes |w_j| on BL / BLB
    (sign via differential), clipped at dv_bl_max; per-column mixed-signal
    multiply by x_j; QR aggregation across N columns; single ADC conversion.

    Returns (y_post, y_pre, y_ideal); post/pre-ADC share one analog pass.
    """
    ens, n = x.shape
    qs = arch.qs
    tech = arch.tech
    if b_adc is None:
        b_adc = arch.b_adc_min()

    xspec = QuantSpec(arch.bx, signed=False, max_val=arch.stats.x_max)
    wspec = QuantSpec(arch.bw, signed=True, max_val=arch.stats.w_max)
    xq = jnp.clip(jnp.round(x / xspec.delta), xspec.code_min, xspec.code_max) * xspec.delta
    wc = jnp.clip(jnp.round(w / wspec.delta), wspec.code_min, wspec.code_max)

    # weight magnitude bit planes (sign handled differentially: noise identical)
    wmag = jnp.abs(wc)
    wsign = jnp.sign(wc) + (wc == 0)
    wb, wmag_weights = bit_planes(wmag, arch.bw, signed=False)  # (Bw, ens, N)

    k_cur, k_th, k_cap = jax.random.split(key, 3)
    cur_mis = 1.0 + qs.sigma_d * jax.random.normal(k_cur, (ens, arch.bw, n))

    dv_unit = qs.dv_unit
    # POT pulse widths: bit i uses 2^i T0 => discharge 2^i dv_unit per active bit
    pot = jnp.asarray(wmag_weights).reshape(1, arch.bw, 1)
    dv_col = dv_unit * jnp.sum(jnp.transpose(wb, (1, 0, 2)) * pot * cur_mis, axis=1)
    # (ens, N) column discharges encoding |w| in dv_unit counts
    dv_col = jnp.minimum(dv_col, tech.dv_bl_max)  # headroom clip (eq. 17)

    # mixed-signal multiply by x (charge-domain scaling) + QR aggregation
    qr_c = 3e-15
    sig_c = tech.pelgrom_kappa * np.sqrt(qr_c)
    caps = qr_c + sig_c * jax.random.normal(k_cap, (ens, n))
    caps = jnp.maximum(caps, 0.1 * qr_c)
    v_mult = dv_col * (xq / arch.stats.x_max) * wsign
    v_th = np.sqrt(K_BOLTZMANN * tech.temp / qr_c) * jax.random.normal(k_th, (ens, n))
    v_o = jnp.sum(caps * (v_mult + v_th), axis=-1) / jnp.sum(caps, axis=-1)

    v_c = arch.v_c_volts()
    v_adc = adc_quantize(v_o, b_adc, -v_c, v_c)

    def rescale(v):
        # V_o = dv_unit/(N x_max) sum_k wc_k x_k  =>  y = Delta_w sum wc x
        return v * n * arch.stats.x_max / dv_unit * wspec.delta

    y_ideal = jnp.sum(w * x, axis=-1)
    return rescale(v_adc), rescale(v_o), y_ideal


# ---------------------------------------------------------------------------
# Ensemble drivers
# ---------------------------------------------------------------------------


def sample_operands(key, ens: int, n: int, stats, dist: str = "uniform"):
    """Draw operand ensembles matching a SignalStats description."""
    kx, kw = jax.random.split(key)
    if dist == "uniform":
        x = jax.random.uniform(kx, (ens, n), minval=0.0, maxval=stats.x_max)
        w = jax.random.uniform(kw, (ens, n), minval=-stats.w_max, maxval=stats.w_max)
    elif dist == "gaussian":
        sig_w = float(np.sqrt(stats.var_w))
        x = jnp.clip(
            jnp.abs(jax.random.normal(kx, (ens, n))) * stats.x_max / 4.0,
            0.0,
            stats.x_max,
        )
        w = jnp.clip(
            jax.random.normal(kw, (ens, n)) * sig_w, -stats.w_max, stats.w_max
        )
    else:
        raise ValueError(dist)
    return x, w


def empirical_snrs(key, arch, simulate, ens: int = 1000, b_adc=None, dist="uniform"):
    """Run a simulator ONCE and report empirical pre/post-ADC SNRs in dB.

    Returns dict with snr_T (full chain) and snr_A (chain without ADC); both
    come from the same simulator pass (identical noise draws), halving the MC
    wall time vs running the circuit twice.
    """
    k_ops, k_sim = jax.random.split(key)
    x, w = sample_operands(k_ops, ens, arch.n, arch.stats, dist)
    y_full, y_pre, y_ideal = simulate(k_sim, x, w, arch, b_adc=b_adc)

    def snr_db(y_hat):
        err = y_hat - y_ideal
        err = err - jnp.mean(err)
        sig = y_ideal - jnp.mean(y_ideal)
        return 10.0 * jnp.log10(jnp.mean(sig**2) / jnp.mean(err**2))

    return {
        "snr_T_db": float(snr_db(y_full)),
        "snr_A_db": float(snr_db(y_pre)),
    }
