"""Uniform quantizers and the additive quantization noise model (paper SSII-B, II-C).

Conventions (kept consistent across analytics, Monte Carlo, the IMC layer and the
Pallas kernel):

* Unsigned signal ``x in [0, x_max]`` quantized to ``B`` bits:
  step ``Delta_x = x_max * 2**-B`` (paper's convention), integer codes
  ``k = clip(round(x / Delta), 0, 2**B - 1)``, dequant ``x_hat = k * Delta``.
  Quantization error ~ U[-Delta/2, Delta/2] (unbiased), variance Delta^2/12.
  Codes are exactly representable as ``B`` bit planes: ``k = sum_j 2**j b_j``.

* Signed signal ``w in [-w_max, w_max]`` quantized to ``B`` bits (two's complement):
  step ``Delta_w = w_max * 2**(1-B)``, codes ``k in [-2**(B-1), 2**(B-1)-1]``,
  dequant ``w_hat = k * Delta``. Bit planes: ``k = -2**(B-1) b_{B-1} + sum 2**j b_j``.

* Clipped (MPC) signed quantizer: range ``[-c, c]``, step ``Delta = c * 2**(1-B)``;
  values beyond +-c clip. This is the ADC model under the minimum precision
  criterion (paper SSIII-D).

All functions are jnp-traceable (usable inside jit / grad / vmap) and also accept
numpy arrays.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# dB helpers
# ---------------------------------------------------------------------------


def db(x):
    """10*log10(x) (power ratio -> dB)."""
    return 10.0 * jnp.log10(x)


def undb(x_db):
    """dB -> linear power ratio."""
    return 10.0 ** (jnp.asarray(x_db) / 10.0)


# ---------------------------------------------------------------------------
# Quantizer specs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """A uniform quantizer description.

    Attributes:
      bits:   number of bits ``B``.
      signed: two's-complement signed (True) or unsigned (False).
      max_val: full-scale value (``x_max`` / ``w_max`` / clip level ``c``).
    """

    bits: int
    signed: bool
    max_val: float = 1.0

    @property
    def delta(self) -> float:
        """Quantization step size (paper: Delta_x = x_m 2^-Bx, Delta_w = w_m 2^(1-Bw))."""
        if self.signed:
            return self.max_val * 2.0 ** (1 - self.bits)
        return self.max_val * 2.0 ** (-self.bits)

    @property
    def code_min(self) -> int:
        return -(2 ** (self.bits - 1)) if self.signed else 0

    @property
    def code_max(self) -> int:
        return (2 ** (self.bits - 1)) - 1 if self.signed else (2**self.bits) - 1

    @property
    def noise_var(self) -> float:
        """Additive-model quantization noise variance Delta^2 / 12."""
        return self.delta**2 / 12.0


# ---------------------------------------------------------------------------
# Core quantize / dequantize
# ---------------------------------------------------------------------------


def quantize(x, spec: QuantSpec):
    """Quantize to integer codes (rounded, clipped). Returns float-typed codes.

    Float codes keep everything differentiable-friendly (with STE below) and are
    exact integers in value, so bit-plane extraction is exact.
    """
    k = jnp.round(x / spec.delta)
    return jnp.clip(k, spec.code_min, spec.code_max)


def dequantize(codes, spec: QuantSpec):
    return codes * spec.delta


def fakequant(x, spec: QuantSpec):
    """quantize -> dequantize (the FX signal x_q = x + q_x of the additive model)."""
    return dequantize(quantize(x, spec), spec)


def fakequant_ste(x, spec: QuantSpec):
    """Fake-quant with a straight-through estimator gradient (for QAT / noise-aware
    training, paper SSIII-B references in-training quantization [32][33])."""
    y = fakequant(x, spec)
    zero = x - jax.lax.stop_gradient(x)
    return zero + jax.lax.stop_gradient(y)


# ---------------------------------------------------------------------------
# Bit planes (for the bit-serial QS-Arch path; paper SSIV-B2)
# ---------------------------------------------------------------------------


def bit_planes(codes, bits: int, signed: bool):
    """Decompose integer-valued (float dtype) codes into bit planes.

    Returns:
      planes: float array, shape ``(bits,) + codes.shape`` with entries in {0, 1}.
              planes[j] is the 2^j plane; for signed, planes[bits-1] is the sign
              plane.
      weights: float array (bits,) such that ``codes == sum_j weights[j]*planes[j]``.
               Unsigned: ``weights[j] = 2^j``. Signed: MSB weight ``-2^(bits-1)``.
    """
    codes = jnp.asarray(codes)
    if signed:
        # offset-binary representative: u = k + 2^(B-1) in [0, 2^B - 1]
        u = codes + 2.0 ** (bits - 1)
    else:
        u = codes
    planes = []
    for j in range(bits):
        b = jnp.mod(jnp.floor(u / (2.0**j)), 2.0)
        planes.append(b)
    if signed:
        # two's complement sign bit a_{B-1} = 1 - (offset-binary MSB), so that
        # k = -2^(B-1) a_{B-1} + sum_{j<B-1} 2^j a_j  holds exactly.
        planes[bits - 1] = 1.0 - planes[bits - 1]
    planes = jnp.stack(planes, axis=0)
    weights = np.array([2.0**j for j in range(bits)])
    if signed:
        weights = weights.copy()
        weights[bits - 1] = -(2.0 ** (bits - 1))
    return planes, jnp.asarray(weights)


def combine_bit_planes(planes, weights):
    """Inverse of :func:`bit_planes` (digital power-of-two recombination)."""
    w = jnp.asarray(weights).reshape((-1,) + (1,) * (planes.ndim - 1))
    return jnp.sum(planes * w, axis=0)


# ---------------------------------------------------------------------------
# Peak-to-average ratios (paper's zeta definitions)
# ---------------------------------------------------------------------------


def par_signed(w_max, var_w):
    """PAR of a signed signal: zeta_w^2 = w_max^2 / sigma_w^2  (paper SSII-B)."""
    return w_max**2 / var_w


def par_unsigned(x_max, e_x2):
    """PAR of an unsigned signal per the paper's convention:
    zeta_x^2 = x_max^2 / (4 E[x^2])   (paper eq. (8) footnote)."""
    return x_max**2 / (4.0 * e_x2)


def par_signed_db(w_max, var_w):
    return db(par_signed(w_max, var_w))


def par_unsigned_db(x_max, e_x2):
    return db(par_unsigned(x_max, e_x2))


# ---------------------------------------------------------------------------
# SQNR: exact (linear domain) and the paper's dB approximation (eq. 1)
# ---------------------------------------------------------------------------


def sqnr_exact(signal_var, spec: QuantSpec):
    """SQNR = sigma_x^2 / (Delta^2/12)."""
    return signal_var / spec.noise_var


def sqnr_db_rule_of_thumb(bits, par_db_val):
    """Paper eq. (1): SQNR(dB) = 6.02 B + 4.77 - zeta(dB).

    (The paper rounds to 6 B + 4.78; we keep the exact constants
    20log10(2) = 6.0206, 10log10(3) = 4.7712.)
    """
    return 6.0206 * bits + 4.7712 - par_db_val


# ---------------------------------------------------------------------------
# Signal statistics container used throughout the analytics
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SignalStats:
    """Moments of the DP operands (paper SSII-C):

      activations x: unsigned, in [0, x_max], second moment e_x2 = E[x^2],
                     mean mu_x, variance var_x.
      weights w:     signed, zero-mean in [-w_max, w_max], variance var_w.
    """

    x_max: float = 1.0
    w_max: float = 1.0
    e_x2: float = 1.0 / 3.0  # uniform[0,1]
    mu_x: float = 0.5
    var_w: float = 1.0 / 3.0  # uniform[-1,1]

    @property
    def var_x(self) -> float:
        return self.e_x2 - self.mu_x**2

    @property
    def zeta_x_sq(self) -> float:
        return par_unsigned(self.x_max, self.e_x2)

    @property
    def zeta_w_sq(self) -> float:
        return par_signed(self.w_max, self.var_w)

    def dp_var(self, n: int) -> float:
        """sigma_yo^2 = N sigma_w^2 E[x^2]  (paper eq. (5))."""
        return n * self.var_w * self.e_x2

    def dp_max(self, n: int) -> float:
        """y_m = N x_max w_max (no clipping; paper App. A)."""
        return n * self.x_max * self.w_max


UNIFORM_STATS = SignalStats()
"""x ~ U[0,1], w ~ U[-1,1]: the paper's SSV default (zeta_x = -1.3 dB unsigned-PAR
... actually for U[0,1]: x_m^2/(4 E[x^2]) = 1/(4/3) = 0.75 -> -1.25 dB, the paper's
-1.3 dB; zeta_w: 1/(1/3) = 3 -> 4.77 dB, the paper's 4.8 dB)."""


def gaussian_relu_stats(sigma: float = 1.0, x_clip_sigmas: float = 4.0) -> SignalStats:
    """Stats for ReLU(Gaussian) activations and Gaussian weights clipped at 4 sigma,
    a DNN-realistic alternative used in benchmarks.

    For x = max(g, 0), g ~ N(0, sigma^2): E[x^2] = sigma^2/2, E[x] = sigma/sqrt(2 pi).
    """
    e_x2 = sigma**2 / 2.0
    mu_x = sigma / np.sqrt(2.0 * np.pi)
    return SignalStats(
        x_max=x_clip_sigmas * sigma,
        w_max=x_clip_sigmas * sigma,
        e_x2=e_x2,
        mu_x=mu_x,
        var_w=sigma**2,
    )


# ---------------------------------------------------------------------------
# DP input-referred quantization noise (paper eq. (5) / (27))
# ---------------------------------------------------------------------------


def sigma_qiy_sq(n: int, bx: int, bw: int, stats: SignalStats):
    """sigma_qiy^2 = N/12 (Delta_w^2 E[x^2] + Delta_x^2 sigma_w^2)."""
    dx = QuantSpec(bx, signed=False, max_val=stats.x_max).delta
    dw = QuantSpec(bw, signed=True, max_val=stats.w_max).delta
    return (n / 12.0) * (dw**2 * stats.e_x2 + dx**2 * stats.var_w)


def sqnr_qiy(n: int, bx: int, bw: int, stats: SignalStats):
    """Exact linear-domain SQNR_qiy (paper eq. (7)/(28))."""
    return stats.dp_var(n) / sigma_qiy_sq(n, bx, bw, stats)


def sqnr_qiy_db_approx(bx: int, bw: int, stats: SignalStats):
    """Paper eq. (8) closed form (independent of N)."""
    zx2 = stats.zeta_x_sq
    zw2 = stats.zeta_w_sq
    val = 3.0 * 2.0 ** (2 * (bx + bw)) / (
        zx2 * zw2 * (2.0 ** (2 * bx) / zx2 + 2.0 ** (2 * bw) / zw2)
    )
    return db(val)
