"""Compute SNR metrics for IMCs and their composition rules (paper SSIII, eqs. 6-11).

The IMC noise model is

    y = y_o + q_iy + eta_a + q_y,      eta_a = eta_e + eta_h        (eq. 6)

with the fundamental metrics

    SQNR_qiy = sigma_yo^2 / sigma_qiy^2        (input quantization)
    SNR_a    = sigma_yo^2 / sigma_eta_a^2      (analog core)
    SQNR_qy  = sigma_yo^2 / sigma_qy^2         (ADC / output quantization)

and the harmonic composition rules

    SNR_A = (1/SNR_a + 1/SQNR_qiy)^-1          (eq. 10, pre-ADC SNR)
    SNR_T = (1/SNR_A + 1/SQNR_qy)^-1           (eq. 11, total SNR)

so SNR_T <= SNR_a always: the analog core is the fundamental limit.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.quant import db, undb


# ---------------------------------------------------------------------------
# Composition
# ---------------------------------------------------------------------------


def compose_snr(*snrs):
    """Harmonic composition of independent noise sources sharing one signal:
    SNR_tot = (sum_i 1/SNR_i)^-1.  (Generalizes eqs. (10)-(11).)"""
    inv = sum(1.0 / jnp.asarray(s) for s in snrs)
    return 1.0 / inv


def compose_snr_db(*snr_dbs):
    return db(compose_snr(*[undb(s) for s in snr_dbs]))


def snr_a_required_for_target(snr_t_target_db: float, margin_db: float = 1.0):
    """Minimum SNR_a(dB) such that SNR_T(dB) >= target is attainable with
    appropriately assigned precisions (SNR_T -> SNR_a; paper SSIII-B)."""
    return snr_t_target_db + margin_db


def degradation_db(snr_limit_db, sqnr_extra_db):
    """By how much an extra noise source with SQNR ``sqnr_extra`` degrades an
    existing SNR ``snr_limit``: returns SNR_limit(dB) - SNR_combined(dB).

    Paper SSIII-B anchor: if SQNR_extra = SNR + 9 dB, degradation <= 0.5 dB.
    """
    combined = compose_snr_db(snr_limit_db, sqnr_extra_db)
    return jnp.asarray(snr_limit_db) - combined


def margin_for_degradation(gamma_db):
    """Inverse of :func:`degradation_db`: required (SQNR_extra - SNR)(dB) so that
    the degradation is exactly ``gamma_db``.

    1/SNR_c = 1/SNR + 1/SQNR ; SNR/SNR_c = 1 + SNR/SQNR = 10^(gamma/10)
    => SQNR/SNR = 1/(10^(gamma/10) - 1).
    """
    g = undb(gamma_db)
    return db(1.0 / (g - 1.0))


# ---------------------------------------------------------------------------
# Empirical estimators (ensemble / Monte Carlo; paper SSV-A)
# ---------------------------------------------------------------------------


def empirical_snr(y_ideal, y_noisy, axis=None):
    """SNR estimate var(y_o) / var(y_noisy - y_o) over an ensemble.

    The error is mean-removed per the paper's convention (fixed offsets are
    calibrated out in real IMCs).
    """
    err = y_noisy - y_ideal
    err = err - jnp.mean(err, axis=axis, keepdims=axis is not None)
    sig = y_ideal - jnp.mean(y_ideal, axis=axis, keepdims=axis is not None)
    return jnp.mean(sig**2, axis=axis) / jnp.mean(err**2, axis=axis)


def empirical_snr_db(y_ideal, y_noisy, axis=None):
    return db(empirical_snr(y_ideal, y_noisy, axis=axis))
