"""recurrentgemma-2b [hybrid]: RG-LRU + local attention, 1:2 ratio.

26L d_model=2560 10H (MQA kv=1) d_ff=7680 vocab=256000 [arXiv:2402.19427; hf].
Pattern (rglru, rglru, local) x 8 + (rglru, rglru) tail; window 2048;
GeGLU MLP; gemma-style sqrt(d) embedding scaling.  Sub-quadratic (bounded
attention range) => runs the long_500k cell.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256_000,
    pattern=("rglru", "rglru", "local"),
    window=2048,
    rnn_width=2560,
    mlp_kind="geglu",
    pos_kind="rope",
    rope_theta=10_000.0,
    norm_kind="rmsnorm",
    emb_scale=True,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    n_layers=5, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16, d_ff=128,
    rnn_width=64, vocab_size=512, window=16, max_seq=128, flash_q_block=16,
    flash_kv_block=16, dtype="float32",
)
