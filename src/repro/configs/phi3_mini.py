"""phi3-mini-3.8b [dense]: RoPE SwiGLU MHA (kv=32).

32L d_model=3072 32H d_ff=8192 vocab=32064 [arXiv:2404.14219; unverified].
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi3-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    mlp_kind="swiglu",
    pos_kind="rope",
    rope_theta=10_000.0,
    norm_kind="rmsnorm",
    tie_embeddings=False,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
    vocab_size=512, max_seq=128, flash_q_block=16, flash_kv_block=16,
    dtype="float32",
)
