"""mamba2-2.7b [ssm]: SSD (state-space duality), attention-free.

64L d_model=2560 ssm_state=128 expand=2 (d_inner=5120, 80 heads x 64)
vocab=50280 [arXiv:2405.21060; unverified].  O(1) decode state => runs the
long_500k cell.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=1,  # attention-free; SSD heads derive from expand*d/head_dim
    n_kv_heads=1,
    d_ff=0,
    vocab_size=50280,
    pattern=("ssm",),
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=128,
    ssm_groups=1,
    conv_width=4,
    pos_kind="none",
    norm_kind="rmsnorm",
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, vocab_size=512, ssm_state=16, ssm_head_dim=16,
    ssm_chunk=16, max_seq=128, dtype="float32",
)
