"""Architecture config registry: ``get(name)`` / ``get_smoke(name)`` /
``ARCH_NAMES``; plus the paper's own IMC design-point config."""
from repro.configs import (  # noqa: F401
    dbrx_132b,
    deepseek_coder_33b,
    gemma2_9b,
    granite_20b,
    granite_moe_1b,
    internvl2_2b,
    mamba2_2p7b,
    musicgen_medium,
    phi3_mini,
    recurrentgemma_2b,
)
from repro.configs.base import ArchConfig  # noqa: F401
from repro.configs.shapes import (  # noqa: F401
    SHAPES,
    ShapeSpec,
    input_specs,
    shape_applicable,
)

_MODULES = {
    "internvl2-2b": internvl2_2b,
    "recurrentgemma-2b": recurrentgemma_2b,
    "granite-moe-1b-a400m": granite_moe_1b,
    "dbrx-132b": dbrx_132b,
    "deepseek-coder-33b": deepseek_coder_33b,
    "granite-20b": granite_20b,
    "phi3-mini-3.8b": phi3_mini,
    "gemma2-9b": gemma2_9b,
    "musicgen-medium": musicgen_medium,
    "mamba2-2.7b": mamba2_2p7b,
}

ARCH_NAMES = tuple(_MODULES.keys())


def get(name: str) -> ArchConfig:
    return _MODULES[name].CONFIG


def get_smoke(name: str) -> ArchConfig:
    return _MODULES[name].SMOKE
