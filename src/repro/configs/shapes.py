"""Input-shape registry (the assignment's per-arch shape set) and
``input_specs()``: ShapeDtypeStruct stand-ins for every model input - weak-type
correct, shardable, no device allocation (dry-run pattern).

  train_4k      seq_len=4096    global_batch=256   lowers train_step
  prefill_32k   seq_len=32768   global_batch=32    lowers serve prefill
  decode_32k    seq_len=32768   global_batch=128   lowers serve decode_step
  long_500k     seq_len=524288  global_batch=1     lowers decode_step;
                sub-quadratic archs only (mamba2, recurrentgemma) - skips are
                recorded, not silently dropped.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeSpec) -> Optional[str]:
    """None if runnable; otherwise a skip reason (recorded in EXPERIMENTS.md)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return (
            "long_500k requires sub-quadratic attention; "
            f"{cfg.name} has unbounded-range attention layers"
        )
    return None


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs for the step inputs (excluding params/cache/state)."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if cfg.modality == "vlm":
            specs["prefix_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.prefix_len, cfg.d_model), jnp.bfloat16
            )
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if cfg.modality == "vlm":
            specs["prefix_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.prefix_len, cfg.d_model), jnp.bfloat16
            )
        return specs
    if shape.kind == "decode":
        return {"token": jax.ShapeDtypeStruct((b,), jnp.int32)}
    raise ValueError(shape.kind)


def cache_specs(cfg: ArchConfig, shape: ShapeSpec):
    """ShapeDtypeStructs for the decode cache (eval_shape over init_cache)."""
    from repro.models import init_cache

    return jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len)
    )


def param_specs(cfg: ArchConfig):
    from repro.models import init_params

    return jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg)
    )
