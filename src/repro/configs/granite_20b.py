"""granite-20b [dense]: gpt_bigcode-style code model.

52L d_model=6144 48H (MQA kv=1) d_ff=24576 vocab=49152 [arXiv:2405.04324; hf].
Learned absolute positions, LayerNorm, non-gated GELU MLP.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    mlp_kind="gelu",
    pos_kind="learned",
    max_seq=32768,
    norm_kind="layernorm",
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=1, head_dim=8, d_ff=256,
    vocab_size=512, max_seq=128, flash_q_block=16, flash_kv_block=16,
    dtype="float32",
)
