"""dbrx-132b [moe]: 16 experts top-4, fine-grained.

40L d_model=6144 48H (GQA kv=8) d_ff=10752/expert vocab=100352
[hf:databricks/dbrx-base; unverified].
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    n_experts=16,
    top_k=4,
    capacity_factor=1.25,
    moe_group_size=4096,  # = seq-aligned groups (SSPerf dbrx iter 1: bigger
    # pools REFUTED - they break the token-sharding alignment, 2.7x worse)
    mlp_kind="swiglu",
    pos_kind="rope",
    rope_theta=500_000.0,
    norm_kind="layernorm",
    tie_embeddings=False,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8, d_ff=48,
    vocab_size=512, n_experts=4, top_k=2, moe_group_size=64, max_seq=128,
    flash_q_block=16, flash_kv_block=16, dtype="float32",
)
