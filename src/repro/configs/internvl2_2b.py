"""internvl2-2b [vlm]: InternViT frontend (stub) + InternLM2-1.8B decoder.

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553 [arXiv:2404.16821; hf].
The ViT is a modality STUB per the assignment: input_specs() provides
precomputed patch embeddings (batch, 256, d_model) scattered over the first
positions of the sequence.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92553,
    pattern=("attn",),
    mlp_kind="swiglu",
    pos_kind="rope",
    rope_theta=1_000_000.0,
    norm_kind="rmsnorm",
    tie_embeddings=False,
    modality="vlm",
    prefix_len=256,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
    vocab_size=512, prefix_len=8, max_seq=128, flash_q_block=16,
    flash_kv_block=16, dtype="float32",
)
