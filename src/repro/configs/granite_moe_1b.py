"""granite-moe-1b-a400m [moe]: 32 experts top-8, fine-grained experts.

24L d_model=1024 16H (GQA kv=8) d_ff=512/expert vocab=49155
[hf:ibm-granite/granite-3.0-1b-a400m-base].
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    n_experts=32,
    top_k=8,
    capacity_factor=1.25,
    moe_group_size=4096,
    mlp_kind="swiglu",
    pos_kind="rope",
    rope_theta=10_000.0,
    norm_kind="rmsnorm",
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=32,
    vocab_size=512, n_experts=8, top_k=2, moe_group_size=64, max_seq=128,
    flash_q_block=16, flash_kv_block=16, dtype="float32",
)
