"""gemma2-9b [dense]: alternating local(4096)/global attention, logit softcaps.

42L d_model=3584 16H (GQA kv=8) head_dim=256 d_ff=14336 vocab=256000
[arXiv:2408.00118; hf].  Attention softcap 50, final softcap 30, sandwich
norms, sqrt(d) embedding scale, query scale (d_model/n_heads)^-0.5.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256_000,
    pattern=("local", "attn"),
    window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    attn_logit_scale=(3584 / 16) ** -0.5,
    mlp_kind="geglu",
    pos_kind="rope",
    rope_theta=10_000.0,
    norm_kind="rmsnorm",
    post_norm=True,
    emb_scale=True,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
    vocab_size=512, window=16, attn_logit_scale=None, max_seq=128,
    flash_q_block=16, flash_kv_block=16, dtype="float32",
)
