"""musicgen-medium [audio]: decoder-only over EnCodec tokens.

48L d_model=1536 24H (MHA kv=24) d_ff=6144 vocab=2048 [arXiv:2306.05284; hf].
The EnCodec frontend is a STUB per the assignment: EnCodec tokens ARE the
vocabulary (2048 codes); sinusoidal positions, LayerNorm, GELU MLP.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    mlp_kind="gelu",
    pos_kind="sinusoidal",
    norm_kind="layernorm",
    tie_embeddings=False,
    modality="audio",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
    vocab_size=256, max_seq=128, flash_q_block=16, flash_kv_block=16,
    dtype="float32",
)
