"""Architecture configuration schema.

One ArchConfig fully describes a model in the zoo: layer pattern (attention /
sliding-window attention / Mamba-2 SSD / RG-LRU blocks), head layout, MLP/MoE
shape, positions, norms, modality frontend stubs, and the IMC execution config
(the paper's technique threaded through every matmul).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.imc_linear import DIGITAL, IMCConfig


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // n_heads

    # --- block pattern (cycled over layers) ---
    # kinds: "attn" (global), "local" (sliding window), "ssm", "rglru"
    pattern: Tuple[str, ...] = ("attn",)
    window: Optional[int] = None  # sliding-window size for "local"
    attn_softcap: Optional[float] = None  # gemma2 attention logit softcap
    final_softcap: Optional[float] = None  # gemma2 final logit softcap

    # --- mlp ---
    mlp_kind: str = "swiglu"  # swiglu | geglu | gelu
    # --- positions ---
    pos_kind: str = "rope"  # rope | learned | sinusoidal | none
    rope_theta: float = 10000.0
    max_seq: int = 32768  # learned-position table size / default cache bound

    # --- moe ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_group_size: int = 4096

    # --- ssm (mamba2 SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_groups: int = 1
    conv_width: int = 4

    # --- rglru (recurrentgemma) ---
    rnn_width: int = 0
    rnn_conv_width: int = 4

    # --- norms / embeddings ---
    norm_kind: str = "rmsnorm"  # rmsnorm | layernorm
    post_norm: bool = False  # gemma2 sandwich (pre+post) norms
    tie_embeddings: bool = True
    emb_scale: bool = False  # gemma-style sqrt(d_model) embedding scale
    attn_logit_scale: Optional[float] = None  # override 1/sqrt(head_dim)

    # --- modality frontend stubs ---
    modality: str = "text"  # text | vlm | audio
    prefix_len: int = 0  # precomputed patch/frame embeddings length (vlm)

    # --- execution ---
    dtype: str = "bfloat16"
    # the execution substrate every matmul routes through: either a
    # first-class repro.core.substrate.Substrate (DigitalSubstrate /
    # AnalyticIMC / BitSerialIMC - carrying calibration policy, per-site
    # overrides and the billed design point) or, for backward compatibility,
    # a bare IMCConfig (== the equivalent dynamic-policy substrate)
    imc: "IMCConfig" = DIGITAL  # IMCConfig | repro.core.substrate.Substrate
    remat: bool = True  # rematerialize each block in train step
    flash_q_block: int = 512
    flash_kv_block: int = 1024
    # decode attention over the paged KV pool: "kernel" streams blocks
    # through the fused online-softmax kernel (repro.kernels.paged_attention,
    # Pallas on TPU / the identical-math pure-JAX walk elsewhere); "gather"
    # is the reference escape hatch that materializes pool[bt] each step.
    # Static per engine (baked into AttnDims at trace time), so flipping it
    # can't key-thrash the serve jit caches.
    decode_attn: str = "kernel"

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Embedding-table rows padded to a multiple of 256 so the vocab dim
        shards evenly on any mesh axis (standard framework practice; padded
        logits are masked to -inf in the head). E.g. 92553 -> 92672."""
        return -(-self.vocab_size // 256) * 256

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def q_per_kv(self) -> int:
        assert self.n_heads % max(self.n_kv_heads, 1) == 0
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def n_full_cycles(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def tail_kinds(self) -> Tuple[str, ...]:
        return self.pattern[: self.n_layers % len(self.pattern)]

    @property
    def is_attention_free(self) -> bool:
        return all(k == "ssm" for k in self.pattern)

    @property
    def sub_quadratic(self) -> bool:
        """True if no block attends over an unbounded range (long_500k eligible)."""
        return all(k in ("ssm", "rglru", "local") for k in self.pattern)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # parameter count (for MODEL_FLOPS = 6 N D roofline bookkeeping)
    def param_count(self, active_only: bool = False) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        n_q, n_kv = self.n_heads, self.n_kv_heads
        emb = self.vocab_size * d  # true rows (padding excluded from N)
        total = emb if self.tie_embeddings else 2 * emb
        if self.pos_kind == "learned":
            total += self.max_seq * d
        counts = {}
        for kind in self.pattern:
            counts[kind] = counts.get(kind, 0) + self.n_full_cycles
        for kind in self.tail_kinds:
            counts[kind] += 1
        for kind, cnt in counts.items():
            if kind in ("attn", "local"):
                blk = d * (n_q * hd) + 2 * d * (n_kv * hd) + (n_q * hd) * d
            elif kind == "ssm":
                d_in = self.ssm_expand * d
                n_h = d_in // self.ssm_head_dim
                blk = (
                    d * (2 * d_in + 2 * self.ssm_groups * self.ssm_state + n_h)
                    + d_in * d
                    + self.conv_width * (d_in + 2 * self.ssm_groups * self.ssm_state)
                )
            elif kind == "rglru":
                w = self.rnn_width
                blk = d * w * 2 + w * d + 3 * w + self.rnn_conv_width * w
            else:
                raise ValueError(kind)
            # mlp
            if self.n_experts > 0:
                e = self.top_k if active_only else self.n_experts
                if kind != "ssm":
                    mults = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
                    blk += e * mults * d * self.d_ff + d * self.n_experts
            elif self.d_ff > 0 and kind != "ssm":
                mults = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
                blk += mults * d * self.d_ff
            total += cnt * blk
        return total
