"""deepseek-coder-33b [dense]: llama-arch code model.

62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256 [arXiv:2401.14196; hf].
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-coder-33b",
    family="dense",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=19200,
    vocab_size=32256,
    mlp_kind="swiglu",
    pos_kind="rope",
    rope_theta=100_000.0,
    norm_kind="rmsnorm",
    tie_embeddings=False,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8, d_ff=160,
    vocab_size=512, max_seq=128, flash_q_block=16, flash_kv_block=16,
    dtype="float32",
)
