"""Fault-tolerant execution loop: step retry, straggler deadline, checkpoint
restart, preemption-safe save, and elastic re-mesh.

On a real multi-pod deployment the failure modes are: host crash (process
exits -> restart from checkpoint), device error (XlaRuntimeError -> retry the
step, then restart), straggler (step exceeds deadline -> raise, coordinator
reschedules), and preemption (SIGTERM -> synchronous final save).  On CPU we
exercise the same code paths with injected failures (tests/test_fault.py).

The loop is deliberately framework-level (pure-Python around a jit'd step):
that is what survives 1000-node reality - in-graph error handling does not.
"""
from __future__ import annotations

import dataclasses
import logging
import signal
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax

from repro.checkpoint import manager as ckpt

log = logging.getLogger("repro.fault")

try:  # the jaxlib runtime's catch-all for device-side faults
    from jaxlib.xla_extension import XlaRuntimeError as _XlaRuntimeError
except Exception:  # pragma: no cover - jaxlib layout drift

    class _XlaRuntimeError(Exception):
        pass


#: exception types a retry is worth attempting for: device-side runtime
#: errors (OOM blips, transient fabric faults), not Python-level bugs
TRANSIENT_ERROR_TYPES = (_XlaRuntimeError,)


def is_transient_device_error(e: BaseException) -> bool:
    """True for device-runtime errors worth a retry (``XlaRuntimeError`` and
    subclasses).  Python-level exceptions - shape errors, assertion failures,
    programming bugs - are NOT transient: retrying them only hides the bug."""
    return isinstance(e, TRANSIENT_ERROR_TYPES)


def call_with_retries(fn: Callable[[], Any], max_retries: int, *,
                      retryable: Optional[Callable[[BaseException], bool]] = None,
                      describe: str = "step",
                      logger: Optional[logging.Logger] = None):
    """THE retry idiom: run ``fn()``, re-running it up to ``max_retries``
    times when it raises an exception ``retryable`` accepts (default: any
    ``Exception``); the final failure propagates to the caller.

    Shared by the training loop (:class:`TrainLoopRunner`, which retries
    everything except :class:`StepTimeout`) and the serve engine
    (``launch.serve.Engine``, which retries only
    :func:`is_transient_device_error` and then fails just the affected
    requests) - one code path, so the two cannot drift apart.
    """
    lg = logger or log
    for attempt in range(max_retries + 1):
        try:
            return fn()
        except Exception as e:  # noqa: BLE001 - device errors are dynamic
            if (retryable is not None and not retryable(e)) \
                    or attempt >= max_retries:
                raise
            lg.warning("%s attempt %d failed: %r; retrying", describe,
                       attempt, e)
    raise AssertionError("unreachable")


@dataclasses.dataclass
class FaultConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    save_every: int = 50
    keep: int = 3
    max_step_retries: int = 2
    step_deadline_s: Optional[float] = None  # straggler mitigation
    max_restarts: int = 3
    async_save: bool = True


class StepTimeout(RuntimeError):
    pass


class TrainLoopRunner:
    """Runs `step_fn(state, batch) -> (state, metrics)` fault-tolerantly."""

    def __init__(
        self,
        step_fn: Callable,
        init_state_fn: Callable[[], Any],
        batch_fn: Callable[[int], Dict],
        cfg: FaultConfig,
        failure_injector: Optional[Callable[[int], None]] = None,
    ):
        self.step_fn = step_fn
        self.init_state_fn = init_state_fn
        self.batch_fn = batch_fn
        self.cfg = cfg
        self.failure_injector = failure_injector
        self.saver = ckpt.AsyncSaver()
        self._preempted = False

    # -- preemption handling -------------------------------------------------
    def install_preemption_handler(self):
        def _handler(signum, frame):
            log.warning("preemption signal received; will save and exit")
            self._preempted = True

        signal.signal(signal.SIGTERM, _handler)

    # -- state restore --------------------------------------------------------
    def _restore_or_init(self) -> Tuple[Any, int]:
        state = self.init_state_fn()
        last = ckpt.latest_step(self.cfg.ckpt_dir)
        if last is None:
            return state, 0
        shapes = jax.tree_util.tree_map(lambda x: x, state)
        restored, extra = ckpt.restore(self.cfg.ckpt_dir, last, shapes)
        log.info("restored checkpoint at step %d", last)
        return restored, int(extra.get("next_step", last))

    # -- one guarded step ------------------------------------------------------
    def _guarded_step(self, state, batch, step: int):
        deadline = self.cfg.step_deadline_s

        def attempt():
            t0 = time.monotonic()
            if self.failure_injector is not None:
                self.failure_injector(step)
            new_state, metrics = self.step_fn(state, batch)
            # block so stragglers/timeouts are observable
            jax.block_until_ready(
                jax.tree_util.tree_leaves(metrics)[0]
                if jax.tree_util.tree_leaves(metrics)
                else jax.tree_util.tree_leaves(new_state)[0]
            )
            dt = time.monotonic() - t0
            if deadline is not None and dt > deadline:
                raise StepTimeout(
                    f"step {step} took {dt:.1f}s > deadline {deadline}s"
                )
            return new_state, metrics

        # stragglers (StepTimeout) escalate to restart/reschedule, anything
        # else is retried in place - the shared serve/train retry idiom
        return call_with_retries(
            attempt, self.cfg.max_step_retries,
            retryable=lambda e: not isinstance(e, StepTimeout),
            describe=f"step {step}",
        )

    # -- the loop ---------------------------------------------------------------
    def run(self, total_steps: int) -> Tuple[Any, Dict]:
        restarts = 0
        history: Dict[str, list] = {"loss": [], "restarts": 0, "retried": 0}
        while True:
            try:
                state, step = self._restore_or_init()
                while step < total_steps and not self._preempted:
                    batch = self.batch_fn(step)
                    state, metrics = self._guarded_step(state, batch, step)
                    if "loss" in metrics:
                        history["loss"].append(float(metrics["loss"]))
                    step += 1
                    if step % self.cfg.save_every == 0 or step == total_steps:
                        extra = {"next_step": step}
                        # serialize wait -> cleanup -> save: cleanup removes
                        # stray .tmp dirs and must never run while an async
                        # save is mid-write (it would delete the in-flight
                        # .tmp; caught by test_train_driver_resume as a lost
                        # checkpoint)
                        self.saver.wait()
                        ckpt.cleanup(self.cfg.ckpt_dir, self.cfg.keep)
                        if self.cfg.async_save:
                            self.saver.save(self.cfg.ckpt_dir, step, state, extra)
                        else:
                            ckpt.save(self.cfg.ckpt_dir, step, state, extra)
                if self._preempted:
                    self.saver.wait()
                    ckpt.save(self.cfg.ckpt_dir, step, state, {"next_step": step})
                self.saver.wait()
                history["restarts"] = restarts
                return state, history
            except Exception as e:  # noqa: BLE001
                restarts += 1
                log.warning("run failed (%r); restart %d", e, restarts)
                self.saver.wait()
                if restarts > self.cfg.max_restarts:
                    raise


def elastic_remesh(model_axis: Optional[int] = None):
    """Rebuild a mesh from the devices that are currently alive.

    After losing hosts, callers rebuild the step functions against this mesh;
    checkpoint restore is sharding-agnostic (repro.checkpoint) and the data
    pipeline is counter-based (repro.data), so training resumes bit-identically
    modulo batch layout.
    """
    from repro.launch.mesh import make_host_mesh

    return make_host_mesh(model_axis)
