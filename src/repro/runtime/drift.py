"""Online calibration: drift detection + background recalibration for
frozen-calibration substrates.

A ``frozen`` substrate (``core.substrate``) makes IMC serving
batch-composition-invariant by baking quantizer ranges captured once from a
reference batch.  Under live traffic the activation statistics drift: when
``|x|`` grows past the frozen ``x_max`` the activation quantizer clips, and
per-site SNR_T silently degrades below the paper's SNR_T -> SNR_a criterion.
This module closes the loop:

  shadow observation   the serve engine runs ``CalibrationRecorder``'s
                       running-maxima capture on a sampled fraction of live
                       chunks (``core.substrate.shadow_recording`` - passive:
                       execution is NOT replaced, outputs are untouched);
  drift detection      :func:`detect_drift` exploits the Calibration pytree's
                       superset monotonicity - stats are running maxima, so
                       "observed > frozen" per site is a ONE-SIDED test.
                       ``observed <= frozen`` never flags (traffic that does
                       not exercise the calibrated range is not drift); an
                       excess is scored by relative range excess and by a
                       clip-rate proxy (Gaussian tail mass past the frozen
                       range at the site's assumed PAR);
  refresh              :func:`refreshed_calibration` max-merges the frozen
                       and observed stats, PRESERVING the frozen site-name
                       set (same pytree treedef), so the engine's hot-swap
                       (``Engine.swap_calibration``) re-uses every compiled
                       decode/prefill executable - no recompile storm;
  recovery accounting  :func:`effective_snr_t_db` is the analytic SNR_T proxy
                       of a B_x-bit quantizer whose full-scale range mismatches
                       the live traffic (quantization noise + clip noise from
                       ``core.precision.gaussian_clip_stats``), used to report
                       per-site degradation and post-swap recovery.

:class:`DriftMonitor` packages the recorder + cadence + thresholds for the
engine: sample every Nth chunk, check every Nth sample, auto-swap on a
drifted report.  Detection latency is therefore bounded by
``sample_every * check_every`` chunks of the drift onset.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import jax

from repro.core.precision import gaussian_clip_stats
from repro.core.substrate import (
    DEFAULT_SITE,
    _STAT_FIELDS,
    Calibration,
    CalibrationRecorder,
    SiteStats,
)

# ---------------------------------------------------------------------------
# detection
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DriftThresholds:
    """Per-site drift thresholds (both tests strictly greater-than: a site
    sitting exactly at a threshold has not drifted).

    ``rel_excess``: observed/frozen - 1 past which a stat field counts as
    drifted (5% default - comfortably above shadow-sampling jitter).
    ``clip_rate``: estimated probability mass the frozen activation range
    clips off the observed traffic, past which ``x_max`` drift is flagged
    even under ``rel_excess`` (a heavy-tailed shift can hurt SNR_T before
    the 5% range excess trips).
    """

    rel_excess: float = 0.05
    clip_rate: float = 1e-3


@dataclasses.dataclass(frozen=True)
class SiteDrift:
    """One (site, stat-field) comparison of observed traffic vs the frozen
    range.  ``rel_excess`` is one-sided (clamped at 0: frozen ranges are
    running maxima, so an observation below the range carries no evidence)."""

    site: str
    field: str
    frozen: float
    observed: float
    rel_excess: float
    clip_rate: float  # estimated clip probability (x_max entries; else 0)
    drifted: bool

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class DriftReport:
    """Structured result of one drift check (surfaced through
    ``launch.metering``)."""

    entries: Tuple[SiteDrift, ...]
    checked_sites: int

    @property
    def drifted(self) -> bool:
        return any(e.drifted for e in self.entries)

    @property
    def drifted_sites(self) -> Tuple[str, ...]:
        return tuple(sorted({e.site for e in self.entries if e.drifted}))

    def worst(self) -> Optional[SiteDrift]:
        """The entry with the largest relative excess (None if no entries)."""
        if not self.entries:
            return None
        return max(self.entries, key=lambda e: e.rel_excess)

    def to_dict(self) -> dict:
        worst = self.worst()
        return {
            "drifted": self.drifted,
            "checked_sites": self.checked_sites,
            "drifted_sites": list(self.drifted_sites),
            "max_rel_excess": worst.rel_excess if worst else 0.0,
            "max_clip_rate": max((e.clip_rate for e in self.entries),
                                 default=0.0),
            "entries": [e.to_dict() for e in self.entries if e.drifted],
        }

    def summary_line(self) -> str:
        if not self.drifted:
            return (f"no drift across {self.checked_sites} sites "
                    f"(max rel excess "
                    f"{self.worst().rel_excess if self.entries else 0.0:.3f})")
        w = self.worst()
        return (f"DRIFT at {len(self.drifted_sites)}/{self.checked_sites} "
                f"sites {list(self.drifted_sites)}: worst {w.site}.{w.field} "
                f"observed {w.observed:.4g} vs frozen {w.frozen:.4g} "
                f"(+{100 * w.rel_excess:.1f}%, clip~{w.clip_rate:.2e})")


def estimated_clip_rate(frozen_max: float, observed_max: float,
                        par: float = 4.0) -> float:
    """Probability mass a quantizer clipping at ``frozen_max`` cuts off the
    observed traffic, modelling the operand as Gaussian with
    ``sigma = observed_max / par`` (the substrate's PAR assumption).  The
    effective clip factor is ``zeta = par * frozen_max / observed_max``; the
    tail mass is ``p_c = 2 Q(zeta)`` (``core.precision.gaussian_clip_stats``).
    Monotone one-sided: observed <= frozen gives zeta >= par ~ 4 and a
    negligible rate."""
    if observed_max <= 0.0 or frozen_max <= 0.0:
        return 0.0
    zeta = par * frozen_max / observed_max
    p_c, _ = gaussian_clip_stats(zeta)
    return float(p_c)


def detect_drift(frozen: Calibration, observed: Calibration,
                 thresholds: DriftThresholds = DriftThresholds(),
                 par_x: float = 4.0) -> DriftReport:
    """One-sided per-site drift test of ``observed`` shadow stats against the
    ``frozen`` calibration.

    Superset monotonicity makes this sound: frozen stats are running maxima,
    so any genuine distribution shift that matters to the quantizers shows up
    as ``observed > frozen`` on some field; ``observed <= frozen`` is always
    consistent with the calibrated distribution and never flags.  Each
    observed site is compared against the stats the frozen engine actually
    uses for it (exact entry or the ``"*"`` fallback).  The aggregate
    ``"*"`` entry itself is skipped: it merges every site and would only
    duplicate the per-site verdicts.
    """
    entries: List[SiteDrift] = []
    checked = 0
    for name, obs in observed.sites:
        if name == DEFAULT_SITE:
            continue
        frz = frozen.get(name)
        if frz is None:
            continue
        checked += 1
        for field in _STAT_FIELDS:
            f_val = float(getattr(frz, field))
            o_val = float(getattr(obs, field))
            rel = max(0.0, o_val / f_val - 1.0) if f_val > 0 else (
                float("inf") if o_val > 0 else 0.0)
            clip = (estimated_clip_rate(f_val, o_val, par_x)
                    if field == "x_max" else 0.0)
            drifted = (rel > thresholds.rel_excess
                       or clip > thresholds.clip_rate)
            entries.append(SiteDrift(site=name, field=field, frozen=f_val,
                                     observed=o_val, rel_excess=rel,
                                     clip_rate=clip, drifted=drifted))
    return DriftReport(entries=tuple(entries), checked_sites=checked)


# ---------------------------------------------------------------------------
# refresh: the hot-swappable calibration
# ---------------------------------------------------------------------------


def refreshed_calibration(frozen: Calibration,
                          observed: Calibration) -> Calibration:
    """Max-merge ``observed`` shadow stats into ``frozen``, PRESERVING the
    frozen site-name set.

    The engine's hot-swap requires the refreshed calibration to flatten to
    the same pytree treedef as the frozen one (same site names in the same
    order): that is what lets the jitted decode/prefill executables - traced
    with the calibration as a runtime argument - be re-used verbatim.
    Observed sites the frozen calibration does not name are folded into its
    ``"*"`` fallback entry (the entry the frozen engine serves them from).
    Monotone: no refreshed range is ever below its frozen value.
    """
    names = set(frozen.site_names())
    merged: Dict[str, SiteStats] = dict(frozen.sites)
    extra: Optional[SiteStats] = None
    for name, st in observed.sites:
        if name in names:
            merged[name] = merged[name].merge(st)
        elif name != DEFAULT_SITE:
            extra = st if extra is None else extra.merge(st)
    if extra is not None and DEFAULT_SITE in merged:
        merged[DEFAULT_SITE] = merged[DEFAULT_SITE].merge(extra)
    return Calibration(tuple(merged.items()))


# ---------------------------------------------------------------------------
# analytic per-site SNR_T proxy (degradation / recovery accounting)
# ---------------------------------------------------------------------------


def effective_snr_t_db(range_max: float, observed_max: float, bx: int,
                       par: float = 4.0) -> float:
    """SNR_T of a signed ``bx``-bit quantizer with full-scale ``range_max``
    against traffic whose observed max-|x| is ``observed_max`` (Gaussian at
    the PAR assumption, ``sigma = observed_max / par``).

    Two regimes, both priced (paper eq. 8 + the MPC clip analysis):
    quantization noise ``Delta^2/12`` with ``Delta = range_max * 2^(1-bx)``
    grows when the range over-provisions (range >> traffic), and clip noise
    ``p_c * sigma_cc^2`` (``gaussian_clip_stats``) takes over when the range
    under-provisions (drifted traffic) - so a drifted site's SNR_T drops and
    a freshly-matched range (``range_max == observed_max``) is the
    reference the hot-swap recovery is measured against.
    """
    if observed_max <= 0.0 or range_max <= 0.0:
        return float("-inf")
    sigma = observed_max / par
    zeta = range_max / sigma
    delta = range_max * 2.0 ** (1 - bx)
    q_noise = delta * delta / 12.0
    p_c, scc = gaussian_clip_stats(zeta)
    clip_noise = float(p_c) * float(scc) * sigma * sigma
    return 10.0 * math.log10(sigma * sigma / (q_noise + clip_noise))


def site_snr_table(frozen: Calibration, refreshed: Calibration,
                   observed: Calibration, bx: int,
                   par_x: float = 4.0) -> List[dict]:
    """Per-site SNR_T accounting rows: the stale frozen range vs the
    refreshed (post-swap) range vs a fresh-frozen reference whose range
    exactly matches the observed traffic."""
    rows = []
    for name, obs in observed.sites:
        if name == DEFAULT_SITE:
            continue
        frz = frozen.get(name)
        if frz is None:
            continue
        ref = refreshed.get(name)
        fresh = effective_snr_t_db(obs.x_max, obs.x_max, bx, par_x)
        stale = effective_snr_t_db(frz.x_max, obs.x_max, bx, par_x)
        after = effective_snr_t_db(ref.x_max, obs.x_max, bx, par_x)
        rows.append({
            "site": name,
            "x_max_frozen": float(frz.x_max),
            "x_max_observed": float(obs.x_max),
            "snr_t_stale_db": stale,
            "snr_t_refreshed_db": after,
            "snr_t_fresh_db": fresh,
            "recovery_gap_db": fresh - after,
            "degradation_db": fresh - stale,
        })
    return rows


def format_snr_table(rows: List[dict]) -> str:
    hdr = (f"{'site':>10s} {'x_max frz':>10s} {'x_max obs':>10s} "
           f"{'SNR_T stale':>11s} {'SNR_T swap':>11s} {'SNR_T fresh':>11s} "
           f"{'gap dB':>7s}")
    lines = [hdr]
    for r in rows:
        lines.append(
            f"{r['site']:>10s} {r['x_max_frozen']:>10.4g} "
            f"{r['x_max_observed']:>10.4g} {r['snr_t_stale_db']:>11.2f} "
            f"{r['snr_t_refreshed_db']:>11.2f} {r['snr_t_fresh_db']:>11.2f} "
            f"{r['recovery_gap_db']:>7.3f}")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# the monitor the serve engine drives
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DriftConfig:
    """Cadence + policy of online drift monitoring.

    ``sample_every``: shadow-record every Nth decode chunk / prefill group
    (1 = every chunk).  ``check_every``: run the detector every Nth shadow
    sample.  Detection latency of a drift onset is therefore bounded by
    ``sample_every * check_every`` chunks.  ``auto_swap``: hot-swap the
    refreshed calibration at the next chunk boundary when a check drifts.
    """

    sample_every: int = 4
    check_every: int = 2
    thresholds: DriftThresholds = DriftThresholds()
    auto_swap: bool = True
    par_x: float = 4.0

    def __post_init__(self):
        if self.sample_every < 1 or self.check_every < 1:
            raise ValueError("sample_every and check_every must be >= 1")


class DriftMonitor:
    """Shadow recorder + drift bookkeeping for one serve engine.

    The engine asks :meth:`take_sample` before each decode chunk (and prefill
    group) and runs the sampled call under
    ``core.substrate.shadow_recording(monitor.recorder)``; after a sampled
    chunk it calls :meth:`check`.  The recorder instance is persistent for
    the monitor's lifetime: shadow-traced executables bind it at trace time,
    so replacing it would silently orphan every compiled shadow function.
    """

    def __init__(self, cfg: DriftConfig = DriftConfig()):
        self.cfg = cfg
        self.recorder = CalibrationRecorder()
        self.chunks_seen = 0
        self.prefills_seen = 0
        self.samples = 0
        self.checks = 0
        self.drift_events = 0
        self.swaps = 0
        self.last_report: Optional[DriftReport] = None
        self.last_observed: Optional[Calibration] = None
        self.first_drift_chunk: Optional[int] = None

    # -- cadence --------------------------------------------------------------
    def take_sample(self) -> bool:
        """True if the upcoming decode chunk should be shadow-recorded."""
        take = self.chunks_seen % self.cfg.sample_every == 0
        self.chunks_seen += 1
        return take

    def take_prefill_sample(self) -> bool:
        """True if the upcoming prefill group should be shadow-recorded."""
        take = self.prefills_seen % self.cfg.sample_every == 0
        self.prefills_seen += 1
        return take

    # -- detection ------------------------------------------------------------
    def check(self, frozen: Calibration) -> Optional[DriftReport]:
        """Account one shadow sample; every ``check_every`` samples flush the
        pending observation callbacks and run the detector.  Returns the
        report when a check ran, else None."""
        self.samples += 1
        if self.samples % self.cfg.check_every != 0:
            return None
        jax.effects_barrier()  # shadow stats arrive via jax.debug.callback
        observed = self.recorder.finalize()
        if not observed.sites:
            return None
        self.checks += 1
        report = detect_drift(frozen, observed, self.cfg.thresholds,
                              par_x=self.cfg.par_x)
        self.last_report = report
        self.last_observed = observed
        if report.drifted:
            self.drift_events += 1
            if self.first_drift_chunk is None:
                self.first_drift_chunk = self.chunks_seen
        return report

    def refreshed(self, frozen: Calibration) -> Calibration:
        """The hot-swappable calibration: frozen max-merged with everything
        observed so far (treedef-preserving).  After a swap the observed
        stats are by construction <= the new frozen stats, so stale
        accumulator state cannot re-flag the same drift."""
        return refreshed_calibration(frozen, self.recorder.finalize())

    def note_swap(self):
        self.swaps += 1

    def counters(self) -> dict:
        return {
            "chunks_seen": self.chunks_seen,
            "shadow_samples": self.samples,
            "drift_checks": self.checks,
            "drift_events": self.drift_events,
            "calibration_swaps": self.swaps,
            "first_drift_chunk": self.first_drift_chunk,
        }
