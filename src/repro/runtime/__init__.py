"""Fault-tolerant runtime: retries, deadlines, elastic re-mesh."""
from repro.runtime.fault import FaultConfig, StepTimeout, TrainLoopRunner, elastic_remesh  # noqa: F401
