"""Fault-tolerant runtime: retries, deadlines, elastic re-mesh."""
from repro.runtime.fault import (  # noqa: F401
    FaultConfig,
    StepTimeout,
    TrainLoopRunner,
    elastic_remesh,
)
