"""Fault-tolerant runtime: retries, deadlines, elastic re-mesh, and online
calibration-drift monitoring for frozen substrates."""
from repro.runtime.drift import (  # noqa: F401
    DriftConfig,
    DriftMonitor,
    DriftReport,
    DriftThresholds,
    detect_drift,
    effective_snr_t_db,
    refreshed_calibration,
    site_snr_table,
)
from repro.runtime.fault import (  # noqa: F401
    FaultConfig,
    StepTimeout,
    TrainLoopRunner,
    call_with_retries,
    elastic_remesh,
    is_transient_device_error,
)
