"""Seeded SLO-aware workload generation for the serve engine.

The ROADMAP's production scenarios need traffic that looks like traffic:
requests ARRIVE over time (Poisson or bursty), prompt and output lengths are
heavy-tailed (lognormal, clipped), and requests belong to multi-tenant
classes with per-class TTFT / inter-token deadlines.  This module generates
such workloads **deterministically from a seed** - every draw comes from one
`numpy.random.default_rng(seed)` stream, so a `serve_slo` bench record is
reproducible draw-for-draw with no wall clock anywhere.

Time is VIRTUAL, measured in decode-step units (:class:`VirtualClock`): one
fused decode step at the baseline substrate costs 1.0, a prefill token costs
``prefill_token_cost`` (prefill rows run batched, so a bucket costs
``bucket * prefill_token_cost`` regardless of R), and a degraded substrate
scales the decode step by its frontier delay ratio (``clock.time_scale``).
Arrival times and deadlines live on the same axis, which makes TTFT,
inter-token latency and goodput pure functions of the seed.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

import numpy as np


class VirtualClock:
    """Deterministic serve-loop time in decode-step units.

    The engine advances it: ``n_steps * time_scale`` per fused decode chunk
    and ``bucket * prefill_token_cost`` per batched prefill group.  The
    ``PressureController`` writes ``time_scale`` when it moves the engine
    along the EDAP frontier (a degraded design point has a smaller
    delay-per-DP, so its steps cost less virtual time).
    """

    def __init__(self, prefill_token_cost: float = 0.125,
                 time_scale: float = 1.0):
        self.now = 0.0
        self.prefill_token_cost = prefill_token_cost
        self.time_scale = time_scale

    def advance(self, dt: float):
        if dt < 0:
            raise ValueError(f"time cannot run backwards (dt={dt})")
        self.now += dt


@dataclasses.dataclass(frozen=True)
class RequestClass:
    """A tenant class: how much of the traffic it is and what it expects.

    Deadlines are in virtual steps: ``ttft_deadline`` bounds arrival ->
    first token, ``itl_deadline`` bounds the gap between consecutive
    generated tokens (both checked post-hoc by ``metering.slo_summary``;
    the deadline scheduler additionally sheds requests that can no longer
    meet their TTFT deadline)."""

    name: str
    weight: float
    ttft_deadline: float
    itl_deadline: float


DEFAULT_CLASSES: Tuple[RequestClass, ...] = (
    RequestClass("interactive", weight=0.7, ttft_deadline=48.0,
                 itl_deadline=6.0),
    RequestClass("batch", weight=0.3, ttft_deadline=192.0,
                 itl_deadline=24.0),
)


@dataclasses.dataclass(frozen=True)
class WorkloadConfig:
    """Everything a workload draw depends on (hash it, commit it, replay it).

    ``arrival`` is "poisson" (exponential inter-arrival gaps with mean
    ``mean_interarrival``) or "bursty" (groups of ``burst_size`` arrivals
    separated by ``burst_size * mean_interarrival`` quiet gaps - same mean
    rate, much worse peaks).  Prompt lengths and true generation lengths
    (``stop_at`` - the EOS the engine cannot know at admission) are
    lognormal, clipped to the given bounds; ``max_new`` is the per-request
    generation CAP, so ``stop_at < max_new`` requests are the early-stopping
    mix that worst-case block reservation over-provisions for."""

    n_requests: int = 32
    seed: int = 0
    arrival: str = "poisson"  # "poisson" | "bursty"
    mean_interarrival: float = 4.0  # virtual steps between arrivals
    burst_size: int = 4
    prompt_median: float = 8.0
    prompt_sigma: float = 0.6
    prompt_min: int = 1
    prompt_max: int = 32
    max_new: int = 8
    gen_median: float = 6.0
    gen_sigma: float = 0.5
    classes: Tuple[RequestClass, ...] = DEFAULT_CLASSES
    # shared-system-prompt traffic: every request's prompt starts with a
    # ``prefix_len``-token prefix drawn from its CLASS's pool of distinct
    # prefixes (pool size ~ class share of n_requests / prefix_dup, so
    # ``prefix_dup`` requests share each system prompt on average - the
    # high-duplication regime prefix-sharing KV caches exist for).  0
    # disables (every prompt fully unique, the legacy draw, stream-identical
    # to pre-prefix workloads).
    prefix_len: int = 0
    prefix_dup: int = 4

    def __post_init__(self):
        if self.arrival not in ("poisson", "bursty"):
            raise ValueError(f"unknown arrival process {self.arrival!r}")
        if not self.classes:
            raise ValueError("need at least one request class")
        if self.prefix_len < 0 or self.prefix_dup < 1:
            raise ValueError("prefix_len must be >= 0 and prefix_dup >= 1")


def make_overload_config(n_requests: int = 32, seed: int = 0,
                         overload: float = 2.0, slots: int = 4,
                         max_new: int = 8, arrival: str = "bursty",
                         prefill_token_cost: float = 0.125,
                         **kw) -> WorkloadConfig:
    """A workload offered at ``overload`` times the engine's service rate.

    Capacity model (virtual steps): ``slots`` streams each deliver one token
    per step, so a request costing roughly ``prompt * prefill_token_cost +
    E[stop_at]`` steps of single-stream work is served at rate
    ``slots / cost``.  Setting the mean inter-arrival to ``cost / (slots *
    overload)`` offers ``overload``x that - at 2x, half the offered SLO-load
    is physically unservable and the scheduler has to choose."""
    probe = WorkloadConfig(n_requests=1, seed=0, max_new=max_new, **kw)
    mean_prompt = probe.prompt_median * math.exp(probe.prompt_sigma ** 2 / 2)
    mean_gen = min(probe.gen_median * math.exp(probe.gen_sigma ** 2 / 2),
                   float(max_new))
    # shared prefixes are costed COLD here: the capacity model prices what a
    # cache-less engine must serve, so a prefix cache shows up as headroom
    cost = (mean_prompt + probe.prefix_len) * prefill_token_cost + mean_gen
    return WorkloadConfig(
        n_requests=n_requests, seed=seed, arrival=arrival, max_new=max_new,
        mean_interarrival=cost / (max(slots, 1) * overload), **kw)


def _lognormal_int(rng: np.random.Generator, median: float, sigma: float,
                   lo: int, hi: int) -> int:
    draw = rng.lognormal(mean=math.log(median), sigma=sigma)
    return int(np.clip(round(draw), lo, hi))


def _arrival_times(rng: np.random.Generator, wcfg: WorkloadConfig) -> List[float]:
    times: List[float] = []
    t = 0.0
    if wcfg.arrival == "poisson":
        for _ in range(wcfg.n_requests):
            t += rng.exponential(wcfg.mean_interarrival)
            times.append(t)
        return times
    # bursty: burst_size near-simultaneous arrivals, then a quiet gap that
    # restores the overall mean rate (peak rate ~ burst_size x the mean)
    intra = wcfg.mean_interarrival / max(wcfg.burst_size, 1)
    quiet = wcfg.mean_interarrival * wcfg.burst_size
    i = 0
    while i < wcfg.n_requests:
        t += rng.exponential(quiet)
        for _ in range(min(wcfg.burst_size, wcfg.n_requests - i)):
            t += rng.exponential(intra)
            times.append(t)
            i += 1
    return times


def generate(wcfg: WorkloadConfig, vocab_size: int) -> List["Request"]:
    """Draw the workload: a list of ``launch.serve.Request`` (sorted by
    ``arrive_at``, rid = arrival order) with prompts, generation caps, true
    stop lengths, class tags and per-class deadlines all seeded."""
    # lazy import: runtime must stay importable without the launch layer
    from repro.launch.serve import Request

    rng = np.random.default_rng(wcfg.seed)
    times = _arrival_times(rng, wcfg)
    weights = np.array([c.weight for c in wcfg.classes], float)
    weights = weights / weights.sum()
    # per-class shared-system-prompt pools: each class holds roughly
    # (its share of n_requests) / prefix_dup distinct prefixes, all drawn
    # from the SAME seeded stream (prefix_len == 0 adds no draws, so legacy
    # workloads replay identically).  Pool draws happen up front, in class
    # order, so the stream layout is independent of per-request choices.
    pools: dict = {}
    if wcfg.prefix_len > 0:
        for c, w in zip(wcfg.classes, weights):
            n_pool = max(1, round(w * wcfg.n_requests / wcfg.prefix_dup))
            pools[c.name] = [rng.integers(0, vocab_size, wcfg.prefix_len)
                             for _ in range(n_pool)]
    reqs: List[Request] = []
    for rid, t in enumerate(times):
        cls = wcfg.classes[int(rng.choice(len(wcfg.classes), p=weights))]
        plen = _lognormal_int(rng, wcfg.prompt_median, wcfg.prompt_sigma,
                              wcfg.prompt_min, wcfg.prompt_max)
        stop = _lognormal_int(rng, wcfg.gen_median, wcfg.gen_sigma,
                              1, wcfg.max_new)
        prompt = rng.integers(0, vocab_size, plen)
        if wcfg.prefix_len > 0:
            pool = pools[cls.name]
            prefix = pool[int(rng.integers(0, len(pool)))]
            prompt = np.concatenate([prefix, prompt])
        reqs.append(Request(
            rid=rid,
            prompt=prompt,
            max_new=wcfg.max_new,
            stop_at=stop,
            arrive_at=float(t),
            rclass=cls.name,
            ttft_deadline=cls.ttft_deadline,
            itl_deadline=cls.itl_deadline,
        ))
    return reqs
