"""Radix prefix index over token-id block chains.

Host-side trie mapping full ``block_size``-token chunks of a prompt to the
physical KV-pool blocks that already hold their K/V, so a new request that
shares a prefix with earlier traffic can *link* those blocks into its block
table instead of re-running prefill dot-products over them.

Division of labour (mirrors the BlockAllocator contract in
``launch/serve.py``):

- this module owns the *index*: which token chains are cached and which
  physical block backs each chunk, plus LRU recency for eviction ordering;
- the ``BlockAllocator`` owns *lifetime*: refcounts, the idle set, and the
  free list.  The engine is the only coordinator — it retains blocks on a
  hit, registers new chains after admission, and evicts leaf-first when the
  pool is under pressure.

Everything here is plain host Python (no jax): under tensor-parallel
serving the allocator is whole per shard group, so a single host-side index
serves every shard without sharding-aware changes.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple


class PrefixNode:
    """One cached block: a full ``block_size``-token chunk plus the physical
    pool block that holds its K/V."""

    __slots__ = ("key", "block", "parent", "children", "last_use")

    def __init__(self, key: Tuple[int, ...], block: int,
                 parent: Optional["PrefixNode"]):
        self.key = key
        self.block = block
        self.parent = parent
        self.children: Dict[Tuple[int, ...], "PrefixNode"] = {}
        self.last_use = 0

    def __repr__(self):  # pragma: no cover - debugging aid
        return f"PrefixNode(block={self.block}, children={len(self.children)})"


class PrefixCache:
    """Radix/trie index at block granularity.

    Only *full* chunks are ever indexed: a chain for an L-token prompt has
    ``L // block_size`` nodes.  Partial tail blocks are still being written
    by their owning slot and are never shared.
    """

    def __init__(self, block_size: int = 8):
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        self.block_size = int(block_size)
        self._root = PrefixNode((), -1, None)
        self._tick = 0
        self.n_nodes = 0

    # -- chunking ----------------------------------------------------------

    def _chunks(self, tokens: Sequence[int]) -> List[Tuple[int, ...]]:
        bs = self.block_size
        n_full = len(tokens) // bs
        return [tuple(int(t) for t in tokens[i * bs:(i + 1) * bs])
                for i in range(n_full)]

    # -- queries -----------------------------------------------------------

    def match(self, tokens: Sequence[int]) -> List[PrefixNode]:
        """Longest chain of cached full chunks prefixing ``tokens``.

        Pure query: no recency stamping, no counters — the engine stamps via
        :meth:`insert` only when an admission actually goes through, so a
        deferred (capacity-blocked) head request cannot skew LRU order.
        """
        node = self._root
        out: List[PrefixNode] = []
        for chunk in self._chunks(tokens):
            child = node.children.get(chunk)
            if child is None:
                break
            out.append(child)
            node = child
        return out

    # -- updates -----------------------------------------------------------

    def insert(self, tokens: Sequence[int], blocks: Sequence[int]
               ) -> List[int]:
        """Index the full-chunk chain of ``tokens`` backed by ``blocks``.

        ``blocks[i]`` is the physical block holding chunk ``i``'s K/V.
        Existing nodes are kept (first writer wins — a duplicate physical
        copy admitted concurrently simply stays request-private) and the
        whole chain's recency is stamped.  Returns the physical blocks of
        *newly created* nodes; the caller must ``register_cached`` exactly
        those with the allocator.
        """
        chunks = self._chunks(tokens)
        if len(blocks) < len(chunks):
            raise ValueError(
                f"chain needs {len(chunks)} blocks, got {len(blocks)}")
        self._tick += 1
        node = self._root
        new_blocks: List[int] = []
        for i, chunk in enumerate(chunks):
            child = node.children.get(chunk)
            if child is None:
                child = PrefixNode(chunk, int(blocks[i]), node)
                node.children[chunk] = child
                self.n_nodes += 1
                new_blocks.append(child.block)
            child.last_use = self._tick
            node = child
        return new_blocks

    def remove(self, node: PrefixNode) -> None:
        """Drop a leaf node from the index (its block is being evicted)."""
        if node.children:
            raise ValueError("only leaf nodes can be removed (leaf-first LRU)")
        if node.parent is None:
            raise ValueError("cannot remove the root")
        del node.parent.children[node.key]
        node.parent = None
        self.n_nodes -= 1

    # -- eviction ordering -------------------------------------------------

    def leaves_lru(self) -> List[PrefixNode]:
        """All leaf nodes, least-recently-used first.

        Leaf-first keeps every cached chain reachable: an interior block is
        only ever evicted after all its descendants have gone.
        """
        leaves: List[PrefixNode] = []
        stack = list(self._root.children.values())
        while stack:
            n = stack.pop()
            if n.children:
                stack.extend(n.children.values())
            else:
                leaves.append(n)
        leaves.sort(key=lambda n: n.last_use)
        return leaves

    def __len__(self) -> int:
        return self.n_nodes
