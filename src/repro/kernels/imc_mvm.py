"""Pallas TPU kernels for IMC-simulated matrix multiplies.

Two kernels:

  imc_bitserial_matmul - bit-exact QS-Arch simulation (paper SSIV-B2): per
      (weight-bit x input-bit) plane binary matmuls on the MXU, per-plane
      headroom clipping, additive analog noise, per-plane ADC transfer, and
      signed power-of-two digital recombination, fused over SRAM banks.

  imc_analytic_matmul - the fast path: quantized-code matmul with the *folded*
      Gaussian analog-noise model (variance from repro.core.archs analytics)
      and an MPC-clipped output ADC; one MXU matmul per (K-tile) plus VPU
      epilogue.

TPU mapping notes (hardware adaptation, DESIGN.md SS3):
  * K is tiled at the SRAM bank height (rows=512, a multiple of the 128-wide
    MXU); M/B tiles default to 128.
  * bit planes are extracted in-register (VPU) from integer-valued f32 codes;
    each plane matmul is an MXU op with f32 accumulation. (On real TPU an int8
    path would halve VMEM traffic; kept f32 for bit-exact CPU validation -
    see EXPERIMENTS.md SSPerf for the int8 variant discussion.)
  * the per-plane nonlinearities (clip, noise add, ADC) are VPU elementwise ops
    on the (B_t, M_t) accumulator tile between MXU calls - they never leave
    VMEM.
  * grid = (B_tiles, M_tiles, n_banks) with the bank dimension innermost:
    output tiles are revisited consecutively and accumulated in place (digital
    cross-bank reduction).

Validated in interpret mode against repro.kernels.ref oracles.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.ref import AnalyticSpec, BitSerialSpec

DEFAULT_TILE_B = 128
DEFAULT_TILE_M = 128


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# bit-serial kernel
# ---------------------------------------------------------------------------


def _bitserial_kernel(
    x_ref,  # (B_t, rows) f32 integer codes
    w_ref,  # (rows, M_t) f32 integer codes
    g_ref,  # (rows, M_t) f32 per-cell current gain, or dummy
    n_ref,  # (1, Bw*Bx, B_t, M_t) f32 per-plane temporal noise (counts), or dummy
    o_ref,  # (B_t, M_t) f32 accumulator (code units)
    *,
    spec: BitSerialSpec,
    has_gain: bool,
    has_noise: bool,
):
    bank = pl.program_id(2)

    @pl.when(bank == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    ww, xw = spec.plane_weights()
    x = x_ref[...]
    w = w_ref[...]

    # offset-binary representatives for plane extraction
    w_u = w + 2.0 ** (spec.bw - 1)
    x_u = x + 2.0 ** (spec.bx - 1) if spec.x_signed else x

    acc = jnp.zeros_like(o_ref)
    for i in range(spec.bw):
        wplane = jnp.mod(jnp.floor(w_u / (2.0**i)), 2.0)
        if i == spec.bw - 1:
            wplane = 1.0 - wplane  # two's complement sign plane
        if has_gain:
            # spatial bit-cell current mismatch (eq. 18): fixed per cell, so
            # it multiplies the plane operand (correlated across planes)
            wplane = wplane * g_ref[...]
        for j in range(spec.bx):
            xplane = jnp.mod(jnp.floor(x_u / (2.0**j)), 2.0)
            if spec.x_signed and j == spec.bx - 1:
                xplane = 1.0 - xplane
            # MXU: (B_t, rows) @ (rows, M_t) binary-plane DP in counts
            dp = jnp.dot(xplane, wplane, preferred_element_type=jnp.float32)
            # VPU epilogue: headroom clip -> analog noise -> ADC transfer
            dp = jnp.minimum(dp, spec.k_h)
            if has_noise:
                dp = dp + n_ref[0, i * spec.bx + j]
                dp = jnp.maximum(dp, 0.0)
            if spec.apply_adc:
                delta = spec.v_c / (2.0**spec.b_adc)
                code = jnp.clip(
                    jnp.round(dp / delta - 0.5), 0.0, 2.0**spec.b_adc - 1
                )
                dp = (code + 0.5) * delta
            acc = acc + (ww[i] * xw[j]) * dp
    o_ref[...] += acc


def imc_bitserial_matmul(
    x_codes: jax.Array,  # (B, K) f32 integer codes
    w_codes: jax.Array,  # (K, M) f32 integer codes
    w_gain: Optional[jax.Array],  # (K, M) per-cell gain (1+eps) or None
    noise: Optional[jax.Array],  # (n_banks, Bw*Bx, B, M) f32 or None
    spec: BitSerialSpec,
    tile_b: int = DEFAULT_TILE_B,
    tile_m: int = DEFAULT_TILE_M,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Fused bit-serial IMC matmul; returns (B, M) in code units.

    B, M, K are padded to tile multiples internally; K pads with zero codes
    (inactive rows - physically, unused bank rows).
    """
    if interpret is None:
        interpret = _interpret_default()
    b_sz, k = x_codes.shape
    _, m = w_codes.shape
    n_banks = -(-k // spec.rows)
    bp = -(-b_sz // tile_b) * tile_b
    mp = -(-m // tile_m) * tile_m
    kp = n_banks * spec.rows
    x_p = jnp.pad(x_codes.astype(jnp.float32), ((0, bp - b_sz), (0, kp - k)))
    w_p = jnp.pad(w_codes.astype(jnp.float32), ((0, kp - k), (0, mp - m)))
    has_gain = w_gain is not None
    has_noise = noise is not None
    operands = [x_p, w_p]
    in_specs = [
        pl.BlockSpec((tile_b, spec.rows), lambda b, mm, kk: (b, kk)),
        pl.BlockSpec((spec.rows, tile_m), lambda b, mm, kk: (kk, mm)),
    ]
    if has_gain:
        g_p = jnp.pad(
            w_gain.astype(jnp.float32),
            ((0, kp - k), (0, mp - m)),
            constant_values=1.0,
        )
        operands.append(g_p)
        in_specs.append(
            pl.BlockSpec((spec.rows, tile_m), lambda b, mm, kk: (kk, mm))
        )
    else:
        operands.append(jnp.ones((1, 1), jnp.float32))
        in_specs.append(pl.BlockSpec((1, 1), lambda b, mm, kk: (0, 0)))
    if has_noise:
        n_p = jnp.pad(
            noise.astype(jnp.float32),
            ((0, 0), (0, 0), (0, bp - b_sz), (0, mp - m)),
        )
        operands.append(n_p)
        in_specs.append(
            pl.BlockSpec(
                (1, spec.bw * spec.bx, tile_b, tile_m),
                lambda b, mm, kk: (kk, 0, b, mm),
            )
        )
    else:
        operands.append(jnp.zeros((1, 1, 1, 1), jnp.float32))
        in_specs.append(pl.BlockSpec((1, 1, 1, 1), lambda b, mm, kk: (0, 0, 0, 0)))

    grid = (bp // tile_b, mp // tile_m, n_banks)
    out = pl.pallas_call(
        functools.partial(
            _bitserial_kernel, spec=spec, has_gain=has_gain, has_noise=has_noise
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((tile_b, tile_m), lambda b, mm, kk: (b, mm)),
        out_shape=jax.ShapeDtypeStruct((bp, mp), jnp.float32),
        interpret=interpret,
    )(*operands)
    return out[:b_sz, :m]


# ---------------------------------------------------------------------------
# analytic-mode kernel
# ---------------------------------------------------------------------------


def _analytic_kernel(
    x_ref,  # (B_t, K_t)
    w_ref,  # (K_t, M_t)
    n_ref,  # (B_t, M_t) standard-normal draws
    o_ref,  # (B_t, M_t)
    *,
    spec: AnalyticSpec,
    n_k: int,
    has_noise: bool,
):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(kk == n_k - 1)
    def _epilogue():
        y = o_ref[...]
        if has_noise and spec.sigma_out > 0.0:
            y = y + spec.sigma_out * n_ref[...]
        if spec.apply_adc:
            c = spec.y_clip
            delta = 2.0 * c / (2.0**spec.b_adc)
            code = jnp.clip(
                jnp.round(y / delta),
                -(2.0 ** (spec.b_adc - 1)),
                2.0 ** (spec.b_adc - 1) - 1,
            )
            y = code * delta
        o_ref[...] = y


def imc_analytic_matmul(
    x_codes: jax.Array,  # (B, K)
    w_codes: jax.Array,  # (K, M)
    noise: Optional[jax.Array],  # (B, M) standard normal or None
    spec: AnalyticSpec,
    tile_b: int = DEFAULT_TILE_B,
    tile_m: int = DEFAULT_TILE_M,
    tile_k: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    if interpret is None:
        interpret = _interpret_default()
    b_sz, k = x_codes.shape
    _, m = w_codes.shape
    bp = -(-b_sz // tile_b) * tile_b
    mp = -(-m // tile_m) * tile_m
    kp = -(-k // tile_k) * tile_k
    x_p = jnp.pad(x_codes.astype(jnp.float32), ((0, bp - b_sz), (0, kp - k)))
    w_p = jnp.pad(w_codes.astype(jnp.float32), ((0, kp - k), (0, mp - m)))
    has_noise = noise is not None
    if has_noise:
        n_p = jnp.pad(noise.astype(jnp.float32), ((0, bp - b_sz), (0, mp - m)))
    else:
        n_p = jnp.zeros((bp, mp), jnp.float32)
    n_k = kp // tile_k
    out = pl.pallas_call(
        functools.partial(
            _analytic_kernel, spec=spec, n_k=n_k, has_noise=has_noise
        ),
        grid=(bp // tile_b, mp // tile_m, n_k),
        in_specs=[
            pl.BlockSpec((tile_b, tile_k), lambda b, mm, kk: (b, kk)),
            pl.BlockSpec((tile_k, tile_m), lambda b, mm, kk: (kk, mm)),
            pl.BlockSpec((tile_b, tile_m), lambda b, mm, kk: (b, mm)),
        ],
        out_specs=pl.BlockSpec((tile_b, tile_m), lambda b, mm, kk: (b, mm)),
        out_shape=jax.ShapeDtypeStruct((bp, mp), jnp.float32),
        interpret=interpret,
    )(x_p, w_p, n_p)
    return out[:b_sz, :m]
