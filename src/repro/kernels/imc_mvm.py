"""Pallas TPU kernels for IMC-simulated matrix multiplies.

Two kernels:

  imc_bitserial_matmul - bit-exact QS-Arch simulation (paper SSIV-B2): all
      (weight-bit x input-bit) plane binary matmuls fused into ONE stacked MXU
      call per (B, M, bank) tile, per-plane headroom clipping, additive analog
      noise generated in-kernel, per-plane ADC transfer, and signed
      power-of-two digital recombination, fused over SRAM banks.

  imc_analytic_matmul - the fast path: quantized-code matmul with the *folded*
      Gaussian analog-noise model (variance from repro.core.archs analytics)
      and an MPC-clipped output ADC; one MXU matmul per (K-tile) plus VPU
      epilogue with in-kernel output-noise generation.

TPU mapping notes (hardware adaptation, DESIGN.md SS3):
  * K is tiled at the SRAM bank height (rows=512, a multiple of the 128-wide
    MXU); M/B tiles default to 128.
  * weight bit planes are extracted ONCE per call on the host side of the
    pallas_call (weights are static across the batch and across B/M tiles) and
    handed to the kernel packed as a (K, Bw, M) operand with the two's
    complement sign-plane flip and the per-cell current gain (eq. 18) already
    folded in.  The kernel never runs floor/mod on weights, and the gain
    multiply happens once per weight plane instead of Bx times.
  * input bit planes are extracted in-register (VPU) once per grid step -
    Bx extractions, hoisted out of the weight-plane loop - and stacked into a
    (Bx*B_t, rows) operand so that ALL Bw*Bx plane dot products issue as a
    single (Bx*B_t, rows) @ (rows, Bw*M_t) MXU matmul.  This cuts MXU call
    count per tile from Bw*Bx to 1 and amortizes per-op overhead (the
    dominant cost in interpret mode, and scheduling overhead on TPU).
  * the per-plane nonlinearities (clip, noise add, ADC) are VPU elementwise
    ops applied to the whole stacked (Bx*B_t, Bw*M_t) accumulator at once;
    recombination walks the 36 sub-tiles in the oracle's i-outer/j-inner
    order within each bank (cross-bank f32 accumulation order differs:
    per-bank local sum, then in-place add - single-bank shapes match ref.py's
    rounding exactly, multi-bank shapes to allclose tolerance).
  * analog noise never touches HBM: the kernel draws it in-register, either
    from the TPU hardware PRNG (pltpu.prng_seed / prng_random_bits, seeded
    per (b, m, bank) grid step) or - in interpret/CPU mode - from the
    deterministic counter-based hash in repro.kernels.prng, whose draws are a
    pure function of global (bank, plane, b, m) indices and therefore
    bit-reproducible by the ref.py oracles.  The seed crosses the pallas_call
    boundary as a single (1, 1) int32 operand: O(1) bytes where the seed
    design streamed an O(n_banks*Bw*Bx*B*M) noise tensor (36x the output
    size per bank at the paper's 6x6-bit design point).
  * grid = (B_tiles, M_tiles, n_banks) with the bank dimension innermost:
    output tiles are revisited consecutively and accumulated in place (digital
    cross-bank reduction), and the packed weight-plane operand for a bank is
    reused across all B tiles before moving on.

Validated in interpret mode against repro.kernels.ref oracles (bit-exact on
the noiseless integer path; draw-for-draw on the fallback-PRNG noise path,
up to rare last-ulp ADC knife edges; statistical SNR-level equivalence on
the TPU hardware-PRNG path).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-only primitives (hardware PRNG); absent on some CPU-only builds
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None

from repro.kernels import prng
from repro.kernels.ref import (
    AnalyticSpec,
    BitSerialSpec,
    adc_transfer,
    mpc_adc,
    unpack_plane,
)

DEFAULT_TILE_B = 128
DEFAULT_TILE_M = 128


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _hw_prng_available(interpret: bool) -> bool:
    """Use the TPU hardware PRNG only when actually compiling for TPU."""
    return (not interpret) and pltpu is not None and (
        jax.default_backend() == "tpu"
    )


def _tpu_normal(shape):  # pragma: no cover - requires real TPU
    """Standard-normal draws from the TPU hardware PRNG (post prng_seed)."""
    b1 = pltpu.bitcast(pltpu.prng_random_bits(shape), jnp.uint32)
    b2 = pltpu.bitcast(pltpu.prng_random_bits(shape), jnp.uint32)
    return prng.normal_from_bits(b1, b2)


def _fold_seed(seed, *ids):
    """Mix grid ids into the base seed for per-tile hardware-PRNG seeding.

    Uses the full splitmix avalanche from repro.kernels.prng: a plain XOR of
    per-position constants is degenerate (constants that are power-of-two
    multiples of each other collide across grid steps, handing different
    tiles bit-identical hardware-PRNG streams).
    """
    return jax.lax.bitcast_convert_type(
        prng.hash_u32(seed, *ids), jnp.int32
    )


# ---------------------------------------------------------------------------
# bit-serial kernel
# ---------------------------------------------------------------------------


def pack_weight_planes(
    w_codes: jax.Array,  # (K, M) f32 integer codes
    w_gain: Optional[jax.Array],  # (K, M) per-cell gain (1 + eps) or None
    bw: int,
) -> jax.Array:
    """Extract the Bw two's-complement weight bit planes once per call.

    Returns a (K, Bw, M) f32 operand with the sign-plane flip and the spatial
    per-cell current gain (paper eq. 18; correlated across planes because
    mismatch is fixed per physical cell) already folded in, so the kernel's
    weight-plane work is a pure block load.
    """
    w = w_codes.astype(jnp.float32)
    wp = jnp.stack(
        [unpack_plane(w, i, bw, signed=True) for i in range(bw)], axis=1
    )  # (K, Bw, M)
    if w_gain is not None:
        wp = wp * w_gain.astype(jnp.float32)[:, None, :]
    return wp


def _bitserial_kernel(
    seed_ref,  # (1, 1) i32 base noise seed (dummy when not has_noise)
    x_ref,  # (B_t, rows) f32 integer codes
    wp_ref,  # (rows, Bw, M_t) f32 packed weight planes (gain folded in)
    o_ref,  # (B_t, M_t) f32 accumulator (code units)
    *,
    spec: BitSerialSpec,
    has_noise: bool,
    hw_prng: bool,
    tile_b: int,
    tile_m: int,
):
    bank = pl.program_id(2)

    @pl.when(bank == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    bx, bw = spec.bx, spec.bw
    ww, xw = spec.plane_weights()

    # input planes: Bx in-register extractions, hoisted out of the w loop
    x = x_ref[...]
    xs = jnp.concatenate(
        [unpack_plane(x, j, bx, signed=spec.x_signed) for j in range(bx)],
        axis=0,
    )  # (Bx*B_t, rows)

    wp = wp_ref[...].reshape(spec.rows, bw * tile_m)  # (rows, Bw*M_t)

    # ONE MXU call for all Bw*Bx plane dot products (counts)
    dp = jnp.dot(xs, wp, preferred_element_type=jnp.float32)

    # VPU epilogue on the whole stacked tile: headroom clip -> noise -> ADC
    dp = jnp.minimum(dp, spec.k_h)
    if has_noise:
        if hw_prng:  # pragma: no cover - requires real TPU
            pltpu.prng_seed(
                _fold_seed(seed_ref[0, 0], pl.program_id(0),
                           pl.program_id(1), bank)
            )
            z = _tpu_normal(dp.shape)
        else:
            # deterministic counter PRNG over GLOBAL (bank, plane, b, m)
            # indices: tile-layout independent, bit-exact vs ref.py
            row = jax.lax.broadcasted_iota(jnp.int32, dp.shape, 0)
            col = jax.lax.broadcasted_iota(jnp.int32, dp.shape, 1)
            b_g = pl.program_id(0) * tile_b + row % tile_b
            m_g = pl.program_id(1) * tile_m + col % tile_m
            plane = (col // tile_m) * bx + row // tile_b  # p = i*Bx + j
            z = prng.counter_normal(
                seed_ref[0, 0], prng.TAG_BITSERIAL, bank, plane, b_g, m_g
            )
        dp = jnp.maximum(dp + spec.sigma_noise * z, 0.0)
    if spec.apply_adc:
        dp = adc_transfer(dp, spec.b_adc, spec.v_c)

    # signed power-of-two recombination, walking sub-tiles in the oracle's
    # i-outer/j-inner order (within a bank; the cross-bank accumulation order
    # differs - per-bank local sum, then in-place add to o_ref)
    acc = jnp.zeros((tile_b, tile_m), jnp.float32)
    for i in range(bw):
        for j in range(bx):
            blk = dp[j * tile_b:(j + 1) * tile_b,
                     i * tile_m:(i + 1) * tile_m]
            acc = acc + (ww[i] * xw[j]) * blk
    o_ref[...] += acc


def imc_bitserial_matmul(
    x_codes: jax.Array,  # (B, K) f32 integer codes
    w_codes: jax.Array,  # (K, M) f32 integer codes
    w_gain: Optional[jax.Array],  # (K, M) per-cell gain (1+eps) or None
    spec: BitSerialSpec,
    seed: Optional[jax.Array] = None,  # scalar int32 noise seed, or None
    tile_b: int = DEFAULT_TILE_B,
    tile_m: int = DEFAULT_TILE_M,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Fused bit-serial IMC matmul; returns (B, M) in code units.

    Per-plane temporal analog noise (std ``spec.sigma_noise`` counts) is
    generated *inside* the kernel when ``seed`` is given - no noise tensor is
    ever materialized.  ``seed=None`` (or ``sigma_noise == 0``) disables it.

    B, M, K are padded to tile multiples internally; K pads with zero codes
    (inactive rows - physically, unused bank rows).
    """
    if interpret is None:
        interpret = _interpret_default()
    b_sz, k = x_codes.shape
    _, m = w_codes.shape
    n_banks = -(-k // spec.rows)
    bp = -(-b_sz // tile_b) * tile_b
    mp = -(-m // tile_m) * tile_m
    kp = n_banks * spec.rows
    x_p = jnp.pad(x_codes.astype(jnp.float32), ((0, bp - b_sz), (0, kp - k)))
    w_p = jnp.pad(w_codes.astype(jnp.float32), ((0, kp - k), (0, mp - m)))
    g_p = None
    if w_gain is not None:
        g_p = jnp.pad(
            w_gain.astype(jnp.float32),
            ((0, kp - k), (0, mp - m)),
            constant_values=1.0,
        )
    # hoisted plane extraction: once per call, not once per grid step
    wp = pack_weight_planes(w_p, g_p, spec.bw)  # (Kp, Bw, Mp)

    has_noise = seed is not None and spec.sigma_noise > 0.0
    if has_noise:
        seed_arr = jnp.asarray(seed, jnp.int32).reshape(1, 1)
    else:
        seed_arr = jnp.zeros((1, 1), jnp.int32)

    grid = (bp // tile_b, mp // tile_m, n_banks)
    out = pl.pallas_call(
        functools.partial(
            _bitserial_kernel,
            spec=spec,
            has_noise=has_noise,
            hw_prng=_hw_prng_available(interpret),
            tile_b=tile_b,
            tile_m=tile_m,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, mm, kk: (0, 0)),
            pl.BlockSpec((tile_b, spec.rows), lambda b, mm, kk: (b, kk)),
            pl.BlockSpec(
                (spec.rows, spec.bw, tile_m), lambda b, mm, kk: (kk, 0, mm)
            ),
        ],
        out_specs=pl.BlockSpec((tile_b, tile_m), lambda b, mm, kk: (b, mm)),
        out_shape=jax.ShapeDtypeStruct((bp, mp), jnp.float32),
        interpret=interpret,
    )(seed_arr, x_p, wp)
    return out[:b_sz, :m]


# ---------------------------------------------------------------------------
# analytic-mode kernel
# ---------------------------------------------------------------------------


def _analytic_kernel(
    seed_ref,  # (1, 1) i32 noise seed (dummy when not has_noise)
    x_ref,  # (B_t, K_t)
    w_ref,  # (K_t, M_t)
    o_ref,  # (B_t, M_t)
    *,
    spec: AnalyticSpec,
    n_k: int,
    has_noise: bool,
    hw_prng: bool,
    tile_b: int,
    tile_m: int,
):
    kk = pl.program_id(2)
    # grid ids are read outside the pl.when closure: interpret mode lowers
    # program_id only at the top level of the kernel trace
    pid_b, pid_m = pl.program_id(0), pl.program_id(1)

    @pl.when(kk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(kk == n_k - 1)
    def _epilogue():
        y = o_ref[...]
        if has_noise:
            if hw_prng:  # pragma: no cover - requires real TPU
                pltpu.prng_seed(_fold_seed(seed_ref[0, 0], pid_b, pid_m))
                z = _tpu_normal(y.shape)
            else:
                row = jax.lax.broadcasted_iota(jnp.int32, y.shape, 0)
                col = jax.lax.broadcasted_iota(jnp.int32, y.shape, 1)
                b_g = pid_b * tile_b + row
                m_g = pid_m * tile_m + col
                z = prng.counter_normal(
                    seed_ref[0, 0], prng.TAG_ANALYTIC, b_g, m_g
                )
            y = y + spec.sigma_out * z
        if spec.apply_adc:
            y = mpc_adc(y, spec.b_adc, spec.y_clip)
        o_ref[...] = y


def imc_analytic_matmul(
    x_codes: jax.Array,  # (B, K)
    w_codes: jax.Array,  # (K, M)
    spec: AnalyticSpec,
    seed: Optional[jax.Array] = None,  # scalar int32 noise seed, or None
    tile_b: int = DEFAULT_TILE_B,
    tile_m: int = DEFAULT_TILE_M,
    tile_k: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Analytic-mode IMC matmul with in-kernel folded output noise.

    ``seed=None`` (or ``spec.sigma_out == 0``) disables the noise; the (B, M)
    normal draw of the seed design no longer exists as an operand.
    """
    if interpret is None:
        interpret = _interpret_default()
    b_sz, k = x_codes.shape
    _, m = w_codes.shape
    bp = -(-b_sz // tile_b) * tile_b
    mp = -(-m // tile_m) * tile_m
    kp = -(-k // tile_k) * tile_k
    x_p = jnp.pad(x_codes.astype(jnp.float32), ((0, bp - b_sz), (0, kp - k)))
    w_p = jnp.pad(w_codes.astype(jnp.float32), ((0, kp - k), (0, mp - m)))
    has_noise = seed is not None and spec.sigma_out > 0.0
    if has_noise:
        seed_arr = jnp.asarray(seed, jnp.int32).reshape(1, 1)
    else:
        seed_arr = jnp.zeros((1, 1), jnp.int32)
    n_k = kp // tile_k
    out = pl.pallas_call(
        functools.partial(
            _analytic_kernel,
            spec=spec,
            n_k=n_k,
            has_noise=has_noise,
            hw_prng=_hw_prng_available(interpret),
            tile_b=tile_b,
            tile_m=tile_m,
        ),
        grid=(bp // tile_b, mp // tile_m, n_k),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, mm, kk: (0, 0)),
            pl.BlockSpec((tile_b, tile_k), lambda b, mm, kk: (b, kk)),
            pl.BlockSpec((tile_k, tile_m), lambda b, mm, kk: (kk, mm)),
        ],
        out_specs=pl.BlockSpec((tile_b, tile_m), lambda b, mm, kk: (b, mm)),
        out_shape=jax.ShapeDtypeStruct((bp, mp), jnp.float32),
        interpret=interpret,
    )(seed_arr, x_p, w_p)
    return out[:b_sz, :m]
