"""Public jit'd wrappers around the IMC matmul kernels.

These take real-valued activations/weights, perform the input quantization
(paper SSII), derive per-plane noise sigmas from the core analytics, and
dispatch to either the Pallas kernel or the pure-jnp oracle (ref.py).

Noise plumbing: analog noise is generated *inside* the kernels (or lazily,
plane-by-plane, inside the oracle) from a scalar int32 seed derived from the
caller's PRNG key.  No per-plane noise tensor is drawn or materialized here -
the seed design streamed an O(n_banks*Bw*Bx*B*M) noise operand through HBM;
this wrapper now ships 4 bytes.  The only remaining weight-shaped draw is the
optional (K, M) spatial per-cell mismatch gain (paper eq. 18), which is a
fixed per-die quantity, not per-call noise traffic.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import imc_mvm, ref
from repro.kernels.ref import AnalyticSpec, BitSerialSpec, quantize_codes


@dataclasses.dataclass(frozen=True)
class IMCMatmulConfig:
    """Runtime configuration of an IMC-simulated matmul (static under jit)."""

    mode: str = "imc_bitserial"  # imc_bitserial | imc_analytic | fakequant
    bx: int = 6
    bw: int = 6
    b_adc: int = 8
    rows: int = 512
    x_signed: bool = True  # LM activations are signed; paper mode uses False
    # analog noise (normalized units; from repro.core.archs analytics)
    sigma_d: float = 0.0  # per-cell relative current mismatch (eq. 18, spatial)
    sigma_thermal_counts: float = 0.0  # per-plane thermal noise std (eq. 20)
    k_h_counts: float = 1e9  # headroom clip in counts (bitserial)
    v_c_counts: float = 1e9  # per-plane ADC range in counts (bitserial)
    snr_a_db: Optional[float] = None  # analytic mode: folded analog SNR
    y_clip_sigmas: float = 4.0  # MPC clip ratio (analytic mode)
    use_kernel: bool = True
    interpret: Optional[bool] = None


def derive_config_from_arch(arch, x_signed: bool = True, use_kernel: bool = True):
    """Build an IMCMatmulConfig from a core QSArch analytic design point."""
    qs = arch.qs
    return IMCMatmulConfig(
        mode="imc_bitserial",
        bx=arch.bx,
        bw=arch.bw,
        b_adc=arch.b_adc_min(),
        rows=arch.n,
        x_signed=x_signed,
        sigma_d=float(qs.sigma_d),
        sigma_thermal_counts=float(qs.sigma_theta_volts(arch.n) / qs.dv_unit),
        k_h_counts=float(arch.k_h),
        v_c_counts=float(arch.v_c_counts()),
        snr_a_db=float(arch.snr_a_db()),
        use_kernel=use_kernel,
    )


def matmul_config_from_imc(cfg, n: int) -> IMCMatmulConfig:
    """Resolve the layer-level execution knobs (an
    ``repro.core.imc_linear.IMCConfig``, i.e. one site of a
    ``core.substrate.Substrate``) into the kernel-level
    :class:`IMCMatmulConfig` for a DP dimension ``n``: auto-banked rows,
    per-plane ADC precision, and the QS-Arch noise constants in counts."""
    arch = cfg.qs_arch(n)
    return IMCMatmulConfig(
        mode="imc_bitserial",
        bx=cfg.bx,
        bw=cfg.bw,
        b_adc=cfg.resolved_b_adc_bitserial(n),
        rows=cfg.bank_rows(n),
        x_signed=cfg.x_signed,
        sigma_d=float(arch.qs.sigma_d),
        sigma_thermal_counts=float(
            arch.qs.sigma_theta_volts(arch.n) / arch.qs.dv_unit
        ),
        k_h_counts=float(arch.k_h),
        v_c_counts=float(arch.v_c_counts()),
        use_kernel=cfg.use_kernel,
    )


def _quantize_operands(x, w, cfg: IMCMatmulConfig, x_max=None, w_max=None):
    if x_max is None:
        x_max = jax.lax.stop_gradient(jnp.max(jnp.abs(x)) + 1e-9)
    if w_max is None:
        w_max = jax.lax.stop_gradient(jnp.max(jnp.abs(w)) + 1e-9)
    xc, dx = quantize_codes(x, cfg.bx, cfg.x_signed, x_max)
    wc, dw = quantize_codes(w, cfg.bw, True, w_max)
    return xc, wc, dx, dw


def _seed_from_key(key: jax.Array) -> jax.Array:
    """Derive the scalar int32 kernel noise seed from a jax PRNG key."""
    bits = jax.random.bits(key, (), jnp.uint32)
    return jax.lax.bitcast_convert_type(bits, jnp.int32)


@functools.partial(jax.jit, static_argnames=("cfg",))
def imc_matmul(
    x: jax.Array,  # (B, K) real
    w: jax.Array,  # (K, M) real
    cfg: IMCMatmulConfig,
    key: Optional[jax.Array] = None,
    x_max: Optional[jax.Array] = None,
    w_max: Optional[jax.Array] = None,
    sigma_yo: Optional[jax.Array] = None,
) -> jax.Array:
    """IMC-simulated ``y = x @ w`` in real units.

    The quantizer/clip operands make the call batch-composition-invariant
    when supplied (the ``frozen`` calibration policy of
    ``core.substrate.Substrate``) and reproduce the historical per-batch
    behaviour when left ``None``:

      ``x_max`` / ``w_max``  operand quantizer ranges; default: dynamic
                             ``max|.|`` over the full operand;
      ``sigma_yo``           (analytic mode) output std in CODE units that
                             scales the folded analog noise and the MPC clip;
                             default: the std of the first <= 8 rows' ideal
                             code product - a per-batch statistic;
      ``key=None``           disables analog noise (quantization, clipping
                             and the output ADC still apply).
    """
    b_sz, k = x.shape
    _, m = w.shape
    xc, wc, dx, dw = _quantize_operands(x, w, cfg, x_max, w_max)

    if cfg.mode == "fakequant":
        return jnp.dot(xc, wc, preferred_element_type=jnp.float32) * (dx * dw)

    if cfg.mode == "imc_analytic":
        if sigma_yo is None:
            sigma_yo_codes = jax.lax.stop_gradient(
                jnp.std(jnp.dot(xc[: min(b_sz, 8)], wc)) + 1e-9
            )
        else:
            sigma_yo_codes = sigma_yo
        # folded analog noise: SNR_a = sigma_yo^2 / sigma_a^2
        if cfg.snr_a_db is not None:
            sigma_out = float(10.0 ** (-cfg.snr_a_db / 20.0))
        else:
            sigma_out = 0.0
        spec = AnalyticSpec(
            b_adc=cfg.b_adc,
            sigma_out=sigma_out,  # in sigma_yo units; operands scaled below
            y_clip=cfg.y_clip_sigmas,  # in sigma_yo units, scaled below
            apply_adc=True,
        )
        seed = None
        if key is not None and sigma_out > 0.0:
            seed = _seed_from_key(key)
        # spec constants (sigma_out, y_clip) are in sigma_yo units; scale the
        # operands by 1/sigma_yo so they apply exactly while staying static.
        xs = xc / sigma_yo_codes
        if cfg.use_kernel:
            y = imc_mvm.imc_analytic_matmul(xs, wc, spec, seed=seed,
                                            interpret=cfg.interpret)
        else:
            y = ref.imc_analytic_ref(xs, wc, spec, seed=seed)
        return y * sigma_yo_codes * (dx * dw)

    if cfg.mode == "imc_bitserial":
        spec = BitSerialSpec(
            bx=cfg.bx,
            bw=cfg.bw,
            b_adc=cfg.b_adc,
            rows=cfg.rows,
            k_h=cfg.k_h_counts,
            v_c=cfg.v_c_counts,
            x_signed=cfg.x_signed,
            apply_adc=True,
            sigma_noise=cfg.sigma_thermal_counts,
        )
        w_gain = None
        seed = None
        if key is not None:
            k_sp, k_th = jax.random.split(key)
            if cfg.sigma_d > 0.0:
                # spatial per-cell current mismatch (fixed per chip instance -
                # pass a persistent "chip key" for a fixed die)
                w_gain = 1.0 + cfg.sigma_d * jax.random.normal(
                    k_sp, (k, m), dtype=jnp.float32
                )
            if cfg.sigma_thermal_counts > 0.0:
                seed = _seed_from_key(k_th)
        if cfg.use_kernel:
            y = imc_mvm.imc_bitserial_matmul(xc, wc, w_gain, spec, seed=seed,
                                             interpret=cfg.interpret)
        else:
            y = ref.imc_bitserial_ref(xc, wc, w_gain, spec, seed=seed)
        return y * (dx * dw)

    raise ValueError(f"unknown mode {cfg.mode!r}")
