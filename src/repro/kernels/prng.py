"""Counter-based PRNG shared by the Pallas kernels and the jnp oracles.

The bit-serial and analytic IMC kernels generate their analog-noise draws
*inside* the kernel instead of streaming a pre-drawn HBM noise tensor.  The
draw for a given noise site is a pure function of ``(seed, counter fields)``:

  bit-serial  z[bank, plane, b, m] = N(seed; TAG_BITSERIAL, bank, plane, b, m)
  analytic    z[b, m]              = N(seed; TAG_ANALYTIC, b, m)

where the counter fields are *global* indices (not tile-local ones), so the
same value is produced regardless of how the kernel tiles B/M/K.  That makes
the fallback path reproducible by the pure-jnp oracles in ``ref.py``:
interpret-mode kernel output with a given seed matches the oracle output
with the same seed draw-for-draw (up to last-ulp FMA-contraction differences
between the two XLA graphs - the integer hash itself is exact).

On a real TPU the kernels instead use the hardware PRNG
(``pltpu.prng_seed`` / ``pltpu.prng_random_bits``) seeded per grid step -
faster, but only *statistically* equivalent to the oracle (same N(0,1)
marginals, different bits).  Tests therefore assert bit-exactness in
interpret mode and statistical (SNR-level) equivalence otherwise.

The hash is a splitmix32-style finalizer chained over the counter fields.
All arithmetic is uint32 with wraparound, which lowers to plain VPU integer
ops inside Pallas and to XLA integer ops in the oracles.
"""
from __future__ import annotations

import jax.numpy as jnp

# domain-separation tags (first counter field) so the two kernels never share
# a counter stream even under the same seed
TAG_BITSERIAL = 0x51
TAG_ANALYTIC = 0xA7

_GOLDEN = 0x9E3779B9  # 2^32 / phi; Weyl increment for field absorption


def _mix32(h):
    """splitmix32 finalizer: full avalanche on a uint32."""
    h = (h ^ (h >> 16)) * jnp.uint32(0x7FEB352D)
    h = (h ^ (h >> 15)) * jnp.uint32(0x846CA68B)
    return h ^ (h >> 16)


def hash_u32(seed, *fields):
    """Hash ``seed`` and integer counter ``fields`` to uint32 noise bits.

    Fields may be scalars or broadcastable integer arrays; the result has the
    broadcast shape.  Every field is absorbed with a Weyl-sequence offset and
    re-avalanched, so low-entropy counters (small ints, iotas) still produce
    independent-looking streams.
    """
    h = _mix32(jnp.asarray(seed).astype(jnp.uint32) ^ jnp.uint32(_GOLDEN))
    for f in fields:
        f = jnp.asarray(f).astype(jnp.uint32)
        h = _mix32(h ^ (f * jnp.uint32(_GOLDEN) + jnp.uint32(0x85EBCA6B)))
    return h


def uniform_from_bits(bits, open_zero: bool = False):
    """uint32 bits -> f32 uniform using the top 24 bits.

    ``open_zero=True`` maps to (0, 1] (safe under log); otherwise [0, 1).
    """
    u = (bits >> jnp.uint32(8)).astype(jnp.float32)
    if open_zero:
        u = u + 1.0
    return u * jnp.float32(2.0**-24)


def normal_from_bits(bits_a, bits_b):
    """Two independent uint32 bit arrays -> standard-normal f32 (Box-Muller)."""
    u1 = uniform_from_bits(bits_a, open_zero=True)
    u2 = uniform_from_bits(bits_b)
    r = jnp.sqrt(-2.0 * jnp.log(u1))
    return r * jnp.cos(jnp.float32(2.0 * 3.141592653589793) * u2)


def counter_normal(seed, *fields):
    """Standard-normal draw at the given counter site(s).

    Deterministic in ``(seed, fields)`` and tile-layout independent; this is
    the fallback noise generator used by the interpret/CPU kernel path and by
    the ``ref.py`` oracles (which makes the two bit-exact against each other).
    """
    return normal_from_bits(
        hash_u32(seed, *fields, 1), hash_u32(seed, *fields, 2)
    )
