"""Pallas paged-attention decode kernel: online softmax over the block table.

One decode step of attention for a batch of slots whose K/V live in a shared
paged block pool (``repro.models.attention.init_paged_kv_cache`` layout:
pools (num_blocks, block_size, Hkv, hd), per-slot block table (B, max_blocks),
physical block 0 reserved as the GARBAGE block).  Instead of materializing the
gathered ``pool[bt]`` copy (O(B * max_blocks * block) KV bytes per step) and
running a dense softmax over it, the kernel walks the block table in-kernel:
each grid step streams ONE physical block from the pool and folds it into an
online-softmax accumulator, so the gathered copy never exists and the resident
KV working set per step is O(1) in the context length.

Grid / accumulator layout (TPU mapping notes, in the style of
``imc_mvm.py``):

  * grid = (B, max_blocks) with the logical-block axis j innermost: each
    (b, j) step DMAs pool block ``bt[b, j]`` - the physical block id comes
    from the scalar-prefetched block table via the BlockSpec index_map
    (``pltpu.PrefetchScalarGridSpec``), the canonical paged-attention idiom.
    The walk order is LOGICAL block order, so the output is invariant to the
    physical block ids the allocator happened to hand out (preemption/resume
    and defragmentation cannot perturb tokens).
  * VMEM scratch carries the online-softmax state across the j steps of one
    slot: running row-max ``m`` (Hkv, G), row-sum ``l`` (Hkv, G), weighted
    accumulator ``acc`` (Hkv, G, hd) - the same m/l/corr recurrence as
    ``_flash_fwd_impl`` (models/attention.py).  State is (re)initialized at
    j == 0 and the normalized context ``acc / max(l, 1e-30)`` is flushed to
    the output block at j == max_blocks - 1 (the output BlockSpec revisits
    the same (1, Hkv, G, hd) block for every j, so only the final flush
    survives).
  * the new token's K/V is scattered into the tail block INSIDE the kernel:
    the tail (b, j == pos[b] // bs) step overlays k_new/v_new onto row
    ``pos[b] % bs`` of the streamed block in-register, and the pools are
    aliased in-out (``input_output_aliases``) so each step writes its
    (possibly overlaid) block back to a scalar-prefetched write destination.

Garbage-block-0 write contract: the per-step write destination ``wdest[b, j]``
is the slot's physical tail block ONLY for the tail step of an active,
in-range row; every other step - non-tail j, rows with ``active == False``
(a retired slot's stale table may point at blocks the allocator already
reused), and OVERRUN rows (``pos >= max_blocks * bs``, which previously
clobbered the slot's last live block) - is routed to physical block 0, whose
content is garbage by pool contract.  ``write_routing`` below is the single
source of truth for this routing; the gather escape-hatch path in
``models/attention.py`` and the ``ref.py`` oracle share it.

CPU / interpret story (the ``kernels/prng.py`` precedent): on non-TPU
backends ``paged_attention_decode`` dispatches to a pure-JAX fallback
(`lax.scan` over logical blocks) implementing the identical streamed
recurrence - bit-reproducible math, no Pallas interpreter overhead inside the
serve decode scan.  The Pallas kernel itself runs under ``interpret=True``
only in the dedicated equivalence tests (tests/test_paged_attention.py),
which check it against the fallback and against the gather-path oracle
``ref.paged_attention_ref``.  On real TPU the aliased in-out pool revisits
physical block 0 from multiple grid steps; the only step whose write targets
a block read by a LATER step is the tail step of the owning slot itself
(slots own disjoint blocks), which reads and writes within the same step, so
the sequential grid semantics are preserved.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-only grid spec (scalar prefetch); absent on some CPU-only builds
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None

NEG_INF = -1e30


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# shared write routing (kernel, fallback, gather path and oracle all use this)
# ---------------------------------------------------------------------------


def write_routing(bt: jax.Array, pos_b: jax.Array, block_size: int,
                  active: Optional[jax.Array]
                  ) -> Tuple[jax.Array, jax.Array]:
    """(dest, off): physical block and in-block row for each slot's new K/V.

    ``dest`` follows the garbage-block-0 contract (module docstring): the
    slot's tail block for active in-range rows, block 0 for inactive or
    overrun rows.
    """
    b, max_blocks = bt.shape
    rows = jnp.arange(b)
    tail = pos_b // block_size
    dest = bt[rows, jnp.clip(tail, 0, max_blocks - 1)]
    dest = jnp.where(tail >= max_blocks, 0, dest)
    if active is not None:
        dest = jnp.where(active, dest, 0)
    return dest, pos_b % block_size


# ---------------------------------------------------------------------------
# pure-JAX fallback: the same streamed recurrence, lax.scan over blocks
# ---------------------------------------------------------------------------


def _decode_jax(q, k_new, v_new, pk, pv, bt, pos_b, dest, off,
                scale: float, softcap: Optional[float]):
    """Streamed online-softmax walk over logical blocks (CPU serving path).

    Scatters the new K/V first (same pool state as the kernel's in-kernel
    overlay + aliased write-back), then folds one (B, bs, Hkv, hd) block per
    scan step into the m/l/acc recurrence.  The gathered ``pool[bt]`` copy is
    never materialized.
    """
    b, max_blocks = bt.shape
    bs = pk.shape[1]
    pk = pk.at[dest, off].set(k_new)
    pv = pv.at[dest, off].set(v_new)
    qf = q.astype(jnp.float32)
    hkv, g, hd = q.shape[1], q.shape[2], q.shape[3]

    def blk_step(carry, j):
        m, l, acc = carry
        phys = bt[:, j]
        k_blk = pk[phys].astype(jnp.float32)  # (B, bs, Hkv, hd)
        v_blk = pv[phys].astype(jnp.float32)
        s = jnp.einsum("bhgd,bkhd->bhgk", qf, k_blk) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = j * bs + jnp.arange(bs)
        valid = k_pos[None, :] <= pos_b[:, None]
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv_blk = jnp.einsum("bhgk,bkhd->bhgd", p, v_blk)
        acc_new = acc * corr[..., None] + pv_blk
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(blk_step, (m0, l0, a0),
                                  jnp.arange(max_blocks))
    ctx = acc / jnp.maximum(l[..., None], 1e-30)
    return ctx, pk, pv


# ---------------------------------------------------------------------------
# Pallas kernel
# ---------------------------------------------------------------------------


def _paged_kernel(bt_ref, wdest_ref, pos_ref, act_ref, q_ref, kn_ref, vn_ref,
                  pk_ref, pv_ref, ctx_ref, opk_ref, opv_ref,
                  m_scr, l_scr, acc_scr, *, bs: int, scale: float,
                  softcap: Optional[float]):
    b = pl.program_id(0)
    j = pl.program_id(1)
    n_blocks = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    pos = pos_ref[b]
    k_blk = pk_ref[0]  # (bs, Hkv, hd) - the streamed physical block
    v_blk = pv_ref[0]
    # in-register overlay of the new token onto the tail block's row -
    # gated on the write mask: an inactive row's write goes to garbage, so
    # its tail lane must keep attending the STALE pool value (gather-path
    # semantics; the row's output is discarded by the engine anyway)
    row = jax.lax.broadcasted_iota(jnp.int32, (bs,), 0)
    sel = (row == pos % bs) & (j == pos // bs) & (act_ref[b] != 0)
    k_blk = jnp.where(sel[:, None, None], kn_ref[0][None], k_blk)
    v_blk = jnp.where(sel[:, None, None], vn_ref[0][None], v_blk)
    # aliased write-back: the tail step persists the overlay into the slot's
    # tail block; every other step's destination is garbage block 0
    opk_ref[0] = k_blk
    opv_ref[0] = v_blk

    qf = q_ref[0].astype(jnp.float32)  # (Hkv, G, hd)
    s = jnp.einsum("hgd,khd->hgk", qf, k_blk.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    valid = (j * bs + row) <= pos
    s = jnp.where(valid[None, None, :], s, NEG_INF)
    m = m_scr[...]
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * corr[..., None] + jnp.einsum(
        "hgk,khd->hgd", p, v_blk.astype(jnp.float32))
    m_scr[...] = m_new

    @pl.when(j == n_blocks - 1)
    def _flush():
        ctx_ref[0] = acc_scr[...] / jnp.maximum(l_scr[...][..., None], 1e-30)


def _decode_pallas(q, k_new, v_new, pk, pv, bt, pos_b, dest, off, act,
                   scale: float, softcap: Optional[float], interpret: bool):
    """pallas_call wrapper: scalar-prefetched block table + write routing."""
    if pltpu is None:  # pragma: no cover - CPU builds without pallas.tpu
        return _decode_jax(q, k_new, v_new, pk, pv, bt, pos_b, dest, off,
                           scale, softcap)
    b, max_blocks = bt.shape
    bs, hkv, hd = pk.shape[1], pk.shape[2], pk.shape[3]
    g = q.shape[2]
    # per-(b, j) write destination: garbage block 0 everywhere except the
    # (in-range, active) tail step, which gets the slot's real tail block
    wdest = jnp.zeros((b, max_blocks), jnp.int32).at[
        jnp.arange(b), jnp.clip(pos_b // bs, 0, max_blocks - 1)].set(dest)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,  # bt, wdest, pos, active
        grid=(b, max_blocks),
        in_specs=[
            pl.BlockSpec((1, hkv, g, hd),
                         lambda bb, jj, bt_, wd, ps, ac: (bb, 0, 0, 0)),
            pl.BlockSpec((1, hkv, hd),
                         lambda bb, jj, bt_, wd, ps, ac: (bb, 0, 0)),
            pl.BlockSpec((1, hkv, hd),
                         lambda bb, jj, bt_, wd, ps, ac: (bb, 0, 0)),
            pl.BlockSpec((1, bs, hkv, hd),
                         lambda bb, jj, bt_, wd, ps, ac: (bt_[bb, jj], 0, 0, 0)),
            pl.BlockSpec((1, bs, hkv, hd),
                         lambda bb, jj, bt_, wd, ps, ac: (bt_[bb, jj], 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, hkv, g, hd),
                         lambda bb, jj, bt_, wd, ps, ac: (bb, 0, 0, 0)),
            pl.BlockSpec((1, bs, hkv, hd),
                         lambda bb, jj, bt_, wd, ps, ac: (wd[bb, jj], 0, 0, 0)),
            pl.BlockSpec((1, bs, hkv, hd),
                         lambda bb, jj, bt_, wd, ps, ac: (wd[bb, jj], 0, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((hkv, g), jnp.float32),
            pltpu.VMEM((hkv, g), jnp.float32),
            pltpu.VMEM((hkv, g, hd), jnp.float32),
        ],
    )
    ctx, opk, opv = pl.pallas_call(
        functools.partial(_paged_kernel, bs=bs, scale=scale, softcap=softcap),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, hkv, g, hd), jnp.float32),
            jax.ShapeDtypeStruct(pk.shape, pk.dtype),
            jax.ShapeDtypeStruct(pv.shape, pv.dtype),
        ],
        # operand indices count the 4 scalar-prefetch args: pk = 7, pv = 8
        input_output_aliases={7: 1, 8: 2},
        interpret=interpret,
    )(bt, wdest, pos_b, act, q, k_new, v_new, pk, pv)
    return ctx, opk, opv


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------


def paged_attention_decode(
    q: jax.Array,       # (B, Hkv, G, hd) grouped queries (rope applied)
    k_new: jax.Array,   # (B, Hkv, hd) new token K (any float dtype)
    v_new: jax.Array,   # (B, Hkv, hd) new token V
    pk: jax.Array,      # (num_blocks, bs, Hkv, hd) key pool
    pv: jax.Array,      # (num_blocks, bs, Hkv, hd) value pool
    bt: jax.Array,      # (B, max_blocks) int32 block table
    pos_b: jax.Array,   # (B,) int32: tokens already in the cache per slot
    active: Optional[jax.Array] = None,  # (B,) bool write-permission mask
    *,
    scale: float,
    softcap: Optional[float] = None,
    use_pallas: Optional[bool] = None,
    interpret: Optional[bool] = None,
):
    """Fused scatter + block-table walk + online-softmax decode attention.

    Returns ``(ctx (B, Hkv, G, hd) f32, pk, pv)`` with the new token's K/V
    scattered into the pools per the garbage-block-0 contract.  Dispatch
    mirrors ``kernels/prng.py``: the Pallas kernel on TPU, the pure-JAX
    streamed fallback (identical math) elsewhere; ``use_pallas``/``interpret``
    force either path for the interpret-mode equivalence tests.
    """
    pos_b = pos_b.astype(jnp.int32)
    # cast ONCE to the pool dtype before both the scatter and the overlay so
    # the kernel attends over exactly the value the pool ends up holding
    # (bit-compat with the gather path, which scatters then re-reads)
    k_new = k_new.astype(pk.dtype)
    v_new = v_new.astype(pv.dtype)
    dest, off = write_routing(bt, pos_b, pk.shape[1], active)
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas:
        return _decode_jax(q, k_new, v_new, pk, pv, bt, pos_b, dest, off,
                           scale, softcap)
    if interpret is None:
        interpret = _interpret_default()
    act = (jnp.ones(pos_b.shape, jnp.int32) if active is None
           else active.astype(jnp.int32))
    return _decode_pallas(q, k_new, v_new, pk, pv, bt, pos_b, dest, off, act,
                          scale, softcap, interpret)
