"""Pure-jnp oracles for the IMC matrix-multiply kernels (and the paged-
attention decode kernel - see :func:`paged_attention_ref` at the bottom).

These implement exactly the same math as the Pallas kernels in imc_mvm.py and
are the ground truth for the interpret-mode allclose sweeps in
tests/test_kernels.py.  They are also usable directly (vmap/grad-able) when the
kernel path is disabled.

Shared semantics (QS-Arch bit-serial simulation, paper SSIV-B2):

  y[b, m] = Delta_x Delta_w *
      sum_banks  sum_{i<Bw, j<Bx}  s_i s_j 2^(i+j) *
          ADC( min( xplane_j[b, :] . wplane_i[:, m], k_h ) + noise )

with two's-complement bit planes (s = -1 for sign planes), per-plane headroom
clipping at k_h counts, additive per-plane analog noise, and a B_adc-bit ADC
over [0, v_c] counts ([-v_c, v_c] when planes can be negative - they cannot:
plane DPs are counts >= 0).

Noise oracle mode: the kernels generate their per-plane temporal noise
in-kernel from the counter-based PRNG in :mod:`repro.kernels.prng`, keyed by
global ``(bank, plane, b, m)`` indices.  The oracles here reproduce the same
draws from the same ``seed`` - materializing at most one bank's planes at a
time - so interpret-mode kernel output matches the oracle draw-for-draw.
The only permitted divergence is last-ulp FMA-contraction differences
between the two XLA graphs, which can flip a single ADC code on rounding
knife edges (tests bound this below 0.1% of elements).  On real TPU the
kernel uses the hardware PRNG instead and equivalence is statistical: same
N(0, sigma_noise) marginals.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import prng


@dataclasses.dataclass(frozen=True)
class BitSerialSpec:
    """Static configuration of the bit-serial IMC matmul."""

    bx: int = 6
    bw: int = 6
    b_adc: int = 8
    rows: int = 512  # bank height (DP dimension per bank)
    k_h: float = 1e9  # headroom clip in unit-discharge counts (inf = no clip)
    v_c: float = 1e9  # ADC full-scale in counts (>= k_h typically)
    x_signed: bool = False  # unsigned (ReLU) vs signed activations
    apply_adc: bool = True
    sigma_noise: float = 0.0  # per-plane temporal noise std in counts (eq. 20)

    @property
    def n_x_planes(self) -> int:
        return self.bx

    @property
    def n_w_planes(self) -> int:
        return self.bw

    def plane_weights(self):
        """(w_weights[Bw], x_weights[Bx]) signed power-of-two recombination."""
        ww = np.array([2.0**i for i in range(self.bw)])
        ww[self.bw - 1] = -(2.0 ** (self.bw - 1))  # w always signed
        xw = np.array([2.0**j for j in range(self.bx)])
        if self.x_signed:
            xw[self.bx - 1] = -(2.0 ** (self.bx - 1))
        return ww, xw


# ---------------------------------------------------------------------------
# quantization helpers shared by ops.py (codes in float32, exact small ints)
# ---------------------------------------------------------------------------


def quantize_codes(v, bits: int, signed: bool, max_val):
    """Uniform quantization to integer codes (float dtype)."""
    if signed:
        delta = max_val * 2.0 ** (1 - bits)
        lo, hi = -(2.0 ** (bits - 1)), 2.0 ** (bits - 1) - 1
    else:
        delta = max_val * 2.0 ** (-bits)
        lo, hi = 0.0, 2.0**bits - 1
    return jnp.clip(jnp.round(v / delta), lo, hi), delta


def unpack_plane(codes, j: int, bits: int, signed: bool):
    """Extract bit plane j from integer codes; two's complement sign plane for
    j == bits-1 when signed."""
    u = codes + 2.0 ** (bits - 1) if signed else codes
    b = jnp.mod(jnp.floor(u / (2.0**j)), 2.0)
    if signed and j == bits - 1:
        b = 1.0 - b
    return b


def adc_transfer(v, b_adc: int, v_c: float):
    """B_adc-bit ADC over [0, v_c] counts."""
    delta = v_c / (2.0**b_adc)
    code = jnp.clip(jnp.round(v / delta - 0.5), 0.0, 2.0**b_adc - 1)
    return (code + 0.5) * delta


def mpc_adc(v, b_adc: int, y_clip: float):
    """Signed B_adc-bit MPC output ADC over [-y_clip, y_clip]."""
    delta = 2.0 * y_clip / (2.0**b_adc)
    code = jnp.clip(
        jnp.round(v / delta),
        -(2.0 ** (b_adc - 1)),
        2.0 ** (b_adc - 1) - 1,
    )
    return code * delta


def bitserial_bank_noise(seed, bank: int, n_planes: int, b_sz: int, m: int):
    """The (n_planes, B, M) standard-normal draws the kernel generates for
    ``bank`` - same counter sites as the in-kernel fallback PRNG (plane index
    p = i*Bx + j).  One vectorized hash call per bank: issuing a separate
    hash chain per plane makes the traced XLA graph pathologically slow to
    compile (~100 chains at Bw=Bx=7), while the per-bank peak memory stays a
    factor n_banks below the seed design's full noise tensor."""
    p_idx = jnp.arange(n_planes, dtype=jnp.int32)[:, None, None]
    b_idx = jnp.arange(b_sz, dtype=jnp.int32)[None, :, None]
    m_idx = jnp.arange(m, dtype=jnp.int32)[None, None, :]
    return prng.counter_normal(
        seed, prng.TAG_BITSERIAL, bank, p_idx, b_idx, m_idx
    )


# ---------------------------------------------------------------------------
# bit-serial oracle
# ---------------------------------------------------------------------------


def imc_bitserial_ref(
    x_codes: jax.Array,  # (B, K) float32 integer codes
    w_codes: jax.Array,  # (K, M) float32 integer codes
    w_gain: Optional[jax.Array],  # (K, M) per-cell current gain (1 + eps) or None
    spec: BitSerialSpec,
    seed: Optional[jax.Array] = None,  # scalar int32 noise seed, or None
) -> jax.Array:
    """Returns the recombined integer-code DP (B, M) in *code units*
    (caller multiplies by Delta_x*Delta_w to get real units).

    ``w_gain`` models *spatial* bit-cell current mismatch (paper eq. 18): the
    same cell gain multiplies that cell's contribution in every bit plane
    (mismatch is fixed per physical cell), which is what makes the mismatch
    noise recombine like the signal (Table III: sigma_eta_e^2 ~ N sigma_D^2/9).
    ``seed`` enables per-plane *temporal* noise (thermal, eq. 20) with std
    ``spec.sigma_noise`` counts - independent draws per plane evaluation,
    generated from the shared counter PRNG (the same draws the
    interpret-mode kernel produces under the same seed).
    """
    b_sz, k = x_codes.shape
    k2, m = w_codes.shape
    assert k == k2, (k, k2)
    n_banks = (k + spec.rows - 1) // spec.rows
    pad = n_banks * spec.rows - k
    if pad:
        x_codes = jnp.pad(x_codes, ((0, 0), (0, pad)))
        w_codes = jnp.pad(w_codes, ((0, pad), (0, 0)))
        if w_gain is not None:
            w_gain = jnp.pad(w_gain, ((0, pad), (0, 0)), constant_values=1.0)
    ww, xw = spec.plane_weights()
    has_noise = seed is not None and spec.sigma_noise > 0.0

    acc = jnp.zeros((b_sz, m), dtype=jnp.float32)
    for bank in range(n_banks):
        sl = slice(bank * spec.rows, (bank + 1) * spec.rows)
        xb = x_codes[:, sl]
        wb = w_codes[sl, :]
        gb = None if w_gain is None else w_gain[sl, :]
        z_bank = None
        if has_noise:
            z_bank = bitserial_bank_noise(
                seed, bank, spec.bw * spec.bx, b_sz, m
            )
        for i in range(spec.bw):
            wplane = unpack_plane(wb, i, spec.bw, signed=True)
            if gb is not None:
                wplane = wplane * gb
            for j in range(spec.bx):
                xplane = unpack_plane(xb, j, spec.bx, signed=spec.x_signed)
                dp = jnp.dot(xplane, wplane, preferred_element_type=jnp.float32)
                dp = jnp.minimum(dp, spec.k_h)
                if has_noise:
                    z = z_bank[i * spec.bx + j]
                    dp = jnp.maximum(dp + spec.sigma_noise * z, 0.0)
                if spec.apply_adc:
                    dp = adc_transfer(dp, spec.b_adc, spec.v_c)
                acc = acc + (ww[i] * xw[j]) * dp
    return acc


# ---------------------------------------------------------------------------
# analytic-mode oracle: fakequant matmul + folded Gaussian noise + MPC ADC
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AnalyticSpec:
    """Static config for the analytic (folded-noise) IMC matmul.

    sigma_out: std of the folded analog noise in *code units* (x_code.w_code
    space); y_clip: MPC clip level in code units (4 sigma_yo typically);
    b_adc: output ADC precision.
    """

    b_adc: int = 8
    sigma_out: float = 0.0
    y_clip: float = 1e9
    apply_adc: bool = True


def analytic_output_noise(seed, b_sz: int, m: int):
    """The (B, M) standard-normal draw the analytic kernel generates in its
    epilogue - same counter sites as the in-kernel fallback PRNG."""
    b_idx = jnp.arange(b_sz, dtype=jnp.int32)[:, None]
    m_idx = jnp.arange(m, dtype=jnp.int32)[None, :]
    return prng.counter_normal(seed, prng.TAG_ANALYTIC, b_idx, m_idx)


def imc_analytic_ref(
    x_codes: jax.Array,  # (B, K)
    w_codes: jax.Array,  # (K, M)
    spec: AnalyticSpec,
    seed: Optional[jax.Array] = None,  # scalar int32 noise seed, or None
) -> jax.Array:
    """y_code = ADC_MPC( x_codes @ w_codes + sigma_out * N(seed) )."""
    y = jnp.dot(x_codes, w_codes, preferred_element_type=jnp.float32)
    if seed is not None and spec.sigma_out > 0.0:
        b_sz, m = y.shape
        y = y + spec.sigma_out * analytic_output_noise(seed, b_sz, m)
    if spec.apply_adc:
        y = mpc_adc(y, spec.b_adc, spec.y_clip)
    return y


# ---------------------------------------------------------------------------
# paged-attention decode oracle: scatter, gather pool[bt], full softmax
# ---------------------------------------------------------------------------


def paged_attention_ref(
    q: jax.Array,       # (B, Hkv, G, hd) grouped queries
    k_new: jax.Array,   # (B, Hkv, hd) new token K
    v_new: jax.Array,   # (B, Hkv, hd) new token V
    pk: jax.Array,      # (num_blocks, bs, Hkv, hd) key pool
    pv: jax.Array,      # (num_blocks, bs, Hkv, hd) value pool
    bt: jax.Array,      # (B, max_blocks) int32 block table
    pos_b: jax.Array,   # (B,) int32 per-slot depth
    active: Optional[jax.Array] = None,  # (B,) bool write mask
    *,
    scale: float,
    softcap: Optional[float] = None,
):
    """Gather-path oracle for ``paged_attention.paged_attention_decode``.

    Scatters the new token into the pool (same garbage-block-0 routing as the
    kernel - ``paged_attention.write_routing`` is the shared source of truth),
    materializes the gathered ``pool[bt]`` view and runs a FULL-row softmax
    over it - exactly the reference math of the serve engine's gather escape
    hatch.  The kernel's online softmax matches it to allclose tolerance (the
    streamed m/l/corr recurrence rounds differently in the last ulps); the
    updated pools match bit-exactly.  Returns ``(ctx, pk, pv)``.
    """
    from repro.kernels.paged_attention import NEG_INF, write_routing

    b, max_blocks = bt.shape
    bs, hkv, hd = pk.shape[1], pk.shape[2], pk.shape[3]
    pos_b = pos_b.astype(jnp.int32)
    dest, off = write_routing(bt, pos_b, bs, active)
    pk = pk.at[dest, off].set(k_new.astype(pk.dtype))
    pv = pv.at[dest, off].set(v_new.astype(pv.dtype))
    s_kv = max_blocks * bs
    k = pk[bt].reshape(b, s_kv, hkv, hd).astype(jnp.float32)
    v = pv[bt].reshape(b, s_kv, hkv, hd).astype(jnp.float32)
    s = jnp.einsum("bhgd,bkhd->bhgk", q.astype(jnp.float32), k) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    valid = jnp.arange(s_kv)[None, :] <= pos_b[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhgk,bkhd->bhgd", p, v)
    return ctx, pk, pv
