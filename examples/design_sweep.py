"""Design-space exploration (paper SSV-D + SSVI): energy-vs-SNR pareto
frontiers per technology node, whole-model IMC deployment costs for the
assigned architectures, and an MPC-style per-site precision assignment
through the first-class Substrate API.

Run:  PYTHONPATH=src python examples/design_sweep.py
"""
import os
import sys

# make `python examples/design_sweep.py` work from anywhere (repo root on
# sys.path for the benchmarks package, as in benchmarks/run.py)
sys.path.insert(
    0, os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
)

from benchmarks.model_energy import model_matmul_shapes  # noqa: E402
from repro.core import optimize, pareto_sweep, scaling
from repro.core.design import with_b_adc
from repro.core.mapping import map_model
from repro.core.substrate import substrate_for_design
from repro.launch.metering import energy_for_tokens, substrate_energy_for_tokens

print("== energy-vs-SNR_T pareto (N=256 DP) per node ==")
for node_name in ("65nm", "22nm", "7nm"):
    tech = scaling.node(node_name)
    pts = pareto_sweep(n=256, tech=tech, targets_db=range(10, 32, 4))
    line = ", ".join(
        f"{t}dB:{pt.energy_per_dp*1e12:.1f}pJ({pt.arch_kind})" for t, pt in pts
    )
    print(f"{node_name}: {line}")

print("\n== whole-model IMC deployment (24 dB SNR_T target) ==")
for arch in ("phi3-mini-3.8b", "gemma2-9b", "granite-moe-1b-a400m",
             "mamba2-2.7b"):
    rep = map_model(model_matmul_shapes(arch), snr_t_target_db=24.0)
    s = rep.summary()
    print(f"{arch:24s} {s['total_energy_j']*1e6:8.2f} uJ/token  "
          f"{s['tops_per_watt']:6.1f} TOPS/W  "
          f"{s['energy_per_mac_fj']:6.1f} fJ/MAC")

print("\n== MPC-style per-site assignment (Substrate API) ==")
# uniform min-energy design point at 14 dB vs the same substrate with the
# output head and attention projections reassigned a finer output ADC
pt = optimize(n=512, snr_t_target_db=14.0)
uniform = substrate_for_design(pt)
boosted = uniform.with_overrides({
    "lm_head": {"b_adc": pt.b_adc + 2, "design": with_b_adc(pt, pt.b_adc + 2)},
    "attn": {"b_adc": pt.b_adc + 1, "design": with_b_adc(pt, pt.b_adc + 1)},
})
shapes = model_matmul_shapes("musicgen-medium")
e_u = energy_for_tokens(shapes, pt, 1)["energy_per_token_j"]
e_b = substrate_energy_for_tokens(shapes, boosted, 1)["energy_per_token_j"]
head = boosted.design_for_site("lm_head")
print(f"uniform {uniform.name}: B_ADC={pt.b_adc} SNR_T={pt.snr_t_db:.1f} dB "
      f"everywhere, {e_u*1e6:.2f} uJ/token")
print(f"per-site overrides: lm_head B_ADC={head.b_adc} "
      f"SNR_T={head.snr_t_db:.1f} dB, FFN stays at {pt.b_adc}; "
      f"{e_b*1e6:.2f} uJ/token (+{100*(e_b/e_u-1):.1f}%)")
