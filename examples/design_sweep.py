"""Design-space exploration (paper SSV-D + SSVI): energy-vs-SNR pareto
frontiers per technology node, and whole-model IMC deployment costs for the
assigned architectures.

Run:  PYTHONPATH=src python examples/design_sweep.py
"""
from repro.core import pareto_sweep, scaling
from benchmarks.model_energy import model_matmul_shapes
from repro.core.mapping import map_model

print("== energy-vs-SNR_T pareto (N=256 DP) per node ==")
for node_name in ("65nm", "22nm", "7nm"):
    tech = scaling.node(node_name)
    pts = pareto_sweep(n=256, tech=tech, targets_db=range(10, 32, 4))
    line = ", ".join(
        f"{t}dB:{pt.energy_per_dp*1e12:.1f}pJ({pt.arch_kind})" for t, pt in pts
    )
    print(f"{node_name}: {line}")

print("\n== whole-model IMC deployment (24 dB SNR_T target) ==")
for arch in ("phi3-mini-3.8b", "gemma2-9b", "granite-moe-1b-a400m",
             "mamba2-2.7b"):
    rep = map_model(model_matmul_shapes(arch), snr_t_target_db=24.0)
    s = rep.summary()
    print(f"{arch:24s} {s['total_energy_j']*1e6:8.2f} uJ/token  "
          f"{s['tops_per_watt']:6.1f} TOPS/W  "
          f"{s['energy_per_mac_fj']:6.1f} fJ/MAC")
