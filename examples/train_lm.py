"""End-to-end driver (deliverable (b)): train a ~100M-param LM for a few
hundred steps on CPU with the full production stack (sharded step, fault
tolerant loop, checkpoints, deterministic data).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_example_ckpt")
    args = ap.parse_args()

    # ~100M params: gemma2-family block at d_model=512, 8 layers, vocab 32k
    import repro.configs as configs

    base = configs.get("gemma2-9b")
    cfg = base.replace(
        n_layers=8, d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
        d_ff=2048, vocab_size=32_000, window=256, attn_logit_scale=None,
        max_seq=1024, flash_q_block=128, flash_kv_block=128,
        dtype="float32",
    )
    print(f"model: {cfg.param_count()/1e6:.1f}M params")
    configs._MODULES["gemma2-9b"].SMOKE_100M = cfg  # register for train CLI

    # drive through the standard trainer by monkey-patching the smoke config
    import repro.launch.train as t

    orig = configs.get_smoke
    configs.get_smoke = lambda name: cfg if name == "gemma2-9b" else orig(name)
    try:
        state, hist = t.main([
            "--arch", "gemma2-9b", "--smoke",
            "--steps", str(args.steps), "--batch", "8", "--seq", "256",
            "--lr", "1e-3", "--ckpt-dir", args.ckpt_dir,
            "--save-every", "50", "--log-every", "10",
        ])
    finally:
        configs.get_smoke = orig
    losses = hist["loss"]
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} over {len(losses)} steps")
    assert losses[-1] < losses[0], "loss must decrease"


if __name__ == "__main__":
    main()
