"""Serve a small model with batched requests through IMC-simulated matmuls
(deliverable (b), serving flavor): the same weights served digitally and at
two analog design points, reporting output agreement vs the digital baseline.

The prompt set is deliberately MIXED short/long: the paged KV cache admits a
4-token and a 48-token request into the same batch while only holding blocks
for the tokens each actually keeps (a contiguous layout would size all four
slots for the 48-token worst case).

The digital pass also meters the served traffic (launch.metering.DPMeter)
and prints the serve-path energy report: J/token, J/request and EDP/token at
the min-energy QS/QR/CM 512-row design points.

Run:  PYTHONPATH=src python examples/serve_imc.py

Add ``--drift-demo`` to run the online-calibration scenario instead: a
frozen-calibration analytic-IMC engine serves live traffic while shadow
recording activation ranges; a weight-scale shift injected mid-workload is
detected by the drift monitor, the refreshed calibration is hot-swapped
between chunks (no pause, no recompile), and the final drift report plus the
per-site SNR_T recovery table (stale frozen vs post-swap vs a fresh-frozen
reference) is printed.

Add ``--prefix-demo`` to run the prefix-sharing scenario instead: every
request carries the same 16-token system prompt, the radix prefix cache
links the already-written KV blocks into each later slot (suffix-only warm
prefill, copy-on-write where needed), and the run prints the hit rate, the
prefill tokens skipped, and the J/token reduction the energy report bills
for the avoided prefill dot-products.

Add ``--overload-demo`` to run the overload-resilience scenario instead: a
seeded bursty workload arrives at 2x the engine's service capacity while the
KV block pool is deliberately undersized; the deadline scheduler reorders and
sheds hopeless requests, the lazy paged allocator grows blocks on demand and
recompute-preempts the newest slot when the pool runs dry (bit-exact resume
under frozen calibration), and the PressureController walks the engine down
the EDAP frontier ladder under sustained pressure - the printed scoreboard
shows goodput, TTFT/ITL percentiles, and shed/preempt/degrade counters with
zero engine deaths.
"""
import sys

import numpy as np

from repro.launch import serve as serve_mod

MIXED_PROMPT_LENS = "4,24,48,6,8,40,5,16"


def run(imc_mode=None, v_wl=0.7, energy_report=False):
    args = ["--arch", "musicgen-medium", "--smoke", "--batch", "4",
            "--requests", "8", "--prompt-lens", MIXED_PROMPT_LENS,
            "--gen", "12"]
    if imc_mode:
        args += ["--imc-mode", imc_mode, "--imc-vwl", str(v_wl)]
    if energy_report:
        # meter the served traffic and print J/token, J/request, EDP/token
        # at the min-energy QS/QR/CM 512-row design points (the serve-path
        # rollup of the paper's energy-delay-accuracy frontier)
        args += ["--energy-report"]
    return serve_mod.main(args)


def run_drift_demo(scale=2.5, after=4):
    """Drift-resilient serving end to end: frozen analytic-IMC engine,
    shadow calibration on every chunk, a ``x{scale}`` mlp.wi weight shift
    after ``after`` requests, detection + atomic hot-swap, and the SNR_T
    recovery table printed by ``serve.main`` at the end of the run."""
    return serve_mod.main([
        "--arch", "musicgen-medium", "--smoke", "--batch", "4",
        "--requests", "8", "--prompt-lens", MIXED_PROMPT_LENS,
        "--gen", "12", "--imc-mode", "imc_analytic",
        "--imc-policy", "frozen", "--recalibrate",
        "--drift-sample-every", "1", "--drift-check-every", "1",
        "--inject-drift", f"{scale}@{after}",
    ])


def run_prefix_demo(prefix_len=16, imc_mode="imc_analytic"):
    """Prefix-sharing paged KV end to end: a shared system prompt across the
    mixed prompt set, served through the radix prefix cache under a frozen
    IMC substrate with metering on - ``serve.main`` prints the hit-rate /
    tokens-skipped scoreboard and the energy report's J/token saving from
    the prefill dot-products that were never issued."""
    return serve_mod.main([
        "--arch", "musicgen-medium", "--smoke", "--batch", "4",
        "--requests", "8", "--prompt-lens", MIXED_PROMPT_LENS,
        "--gen", "8", "--prefix-cache",
        "--shared-prefix-len", str(prefix_len),
        "--imc-mode", imc_mode, "--imc-policy", "frozen",
        "--energy-report",
    ])


def run_overload_demo(overload=2.0, requests=16, seed=0):
    """Overload-resilient serving end to end: seeded bursty arrivals at
    ``overload``x capacity, deadline-EDF scheduling with load shedding, lazy
    paged KV with recompute-preemption on pool exhaustion, and load-adaptive
    EDAP-frontier degradation; ``serve.main`` prints the SLO scoreboard."""
    return serve_mod.main([
        "--arch", "musicgen-medium", "--smoke", "--batch", "4",
        "--requests", str(requests), "--gen", "8", "--chunk", "4",
        "--kv-blocks", "11", "--workload", "bursty",
        "--workload-seed", str(seed), "--overload", str(overload),
        "--slo-policy", "deadline", "--alloc", "lazy", "--degrade",
        "--imc-mode", "imc_analytic", "--imc-policy", "frozen",
    ])


def agreement(a, b):
    match = sum(
        np.mean(np.array(ra.out) == np.array(rb.out))
        for ra, rb in zip(a, b)
    )
    return match / len(a)


if __name__ == "__main__":
    if "--overload-demo" in sys.argv[1:]:
        served = run_overload_demo()
        shed = [r for r in served if getattr(r, "shed", False)]
        errored = [r for r in served
                   if r.error is not None and not getattr(r, "shed", False)]
        print(f"overload demo: {len(served)} requests accounted for "
              f"({len(shed)} shed, {len(errored)} errored) under 2x bursty "
              f"overload; see the SLO scoreboard above")
        sys.exit(0)
    if "--prefix-demo" in sys.argv[1:]:
        served = run_prefix_demo()
        failed = [r for r in served if r.error is not None]
        print(f"prefix demo: served {len(served)} requests "
              f"({len(failed)} failed) off a shared 16-token system prompt; "
              f"see the prefix-cache scoreboard and the J/token saving in "
              f"the energy report above")
        sys.exit(0)
    if "--drift-demo" in sys.argv[1:]:
        served = run_drift_demo()
        failed = [r for r in served if r.error is not None]
        print(f"drift demo: served {len(served)} requests "
              f"({len(failed)} failed) across an injected mid-workload "
              f"weight-scale shift; see the drift report and SNR_T "
              f"recovery table above")
        sys.exit(0)
    digital = run(None, energy_report=True)
    print(f"digital: served {len(digital)} requests")
    for mode, v_wl in [("imc_analytic", 0.8), ("imc_analytic", 0.6)]:
        noisy = run(mode, v_wl)
        agr = agreement(digital, noisy)
        print(f"{mode}@V_WL={v_wl}: token agreement vs digital = {agr:.2%} "
              f"(higher V_WL => higher SNR_a => higher agreement)")
