"""Quickstart: the paper's core workflow in 60 lines.

1. Ask what SNR_T a workload needs; 2. find the min-energy IMC design point
that delivers it (compute model, V_WL / C_o, banking, MPC ADC bits);
3. execute a real matmul through the resulting noisy hardware simulation and
verify the delivered SNR.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import optimize
from repro.core.imc_linear import linear
from repro.core.precision import assign_precisions
from repro.core.quant import UNIFORM_STATS
from repro.core.substrate import BitSerialIMC

# -- 1. the requirement: a 1024-dim DP layer needs ~22 dB (4-b-equivalent
#       accuracy, paper SSIII-B) ------------------------------------------------
N, TARGET_DB = 1024, 22.0
pa = assign_precisions(snr_a_db=TARGET_DB + 3, n=N, stats=UNIFORM_STATS)
print(f"precision assignment: B_x={pa.bx} B_w={pa.bw} "
      f"B_y={pa.by} (BGC would use {pa.bx+pa.bw+10})")

# -- 2. min-energy design point -------------------------------------------------
pt = optimize(n=N, snr_t_target_db=TARGET_DB)
print(f"design point: {pt.arch_kind}-Arch, knob={pt.knob:.3g}, "
      f"{pt.n_banks} banks x {pt.n_bank} rows, B_ADC={pt.b_adc}")
print(f"  predicted SNR_T={pt.snr_t_db:.1f} dB, "
      f"energy={pt.energy_per_dp*1e12:.2f} pJ/DP, "
      f"delay={pt.delay_per_dp*1e9:.1f} ns/DP")

# -- 3. execute a matmul through the simulated hardware -------------------------
k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
x = jax.random.normal(k1, (64, N))
w = jax.random.normal(k2, (N, 128)) / np.sqrt(N)
y_exact = x @ w

# a first-class substrate: the bit-serial QS-Arch simulation, carrying the
# design point it bills (repro.core.substrate; string mode flags are retired)
substrate = BitSerialIMC(bx=pa.bx, bw=pa.bw, v_wl=0.7, design=pt)
y_imc = linear(w, x, substrate, rng=k3)
err = y_imc - y_exact
snr = 10 * np.log10(float(jnp.var(y_exact)) /
                    float(jnp.mean((err - jnp.mean(err)) ** 2)))
snr_a = substrate.imc.resolved_snr_a_db(N)
print(f"bit-serial QS-Arch execution: delivered SNR = {snr:.1f} dB "
      f"(analytic SNR_a = {snr_a:.1f} dB)")

# the fundamental limit (paper's headline): SNR_T <= SNR_a, always
assert snr <= snr_a + 1.5
print("OK: SNR_T is bounded by the analog core's SNR_a - the paper's limit.")
