"""Roofline report (deliverable (g)): reads dry-run artifacts and emits the
per-(arch x shape x mesh) three-term roofline table + dominant bottleneck.

  t_compute    = HLO_FLOPs / peak_FLOPs          (197 TFLOP/s bf16 per chip)
  t_memory     = HLO dot-stream bytes / HBM_bw   (819 GB/s per chip)
  t_collective = wire bytes / ICI_bw             (50 GB/s per link)

All quantities are per-device from the post-SPMD module, with the while-loop
trip-count correction and the bf16 host-promotion correction (see
launch/hlo_analysis.py).  roofline_fraction = t_compute / max(all terms): the
fraction of peak the step would achieve if perfectly overlapped - the SSPerf
score.  MODEL_FLOPS ratio flags remat/redundancy waste.
"""
from __future__ import annotations

import glob
import json
import os
from typing import List, Tuple

Row = Tuple[str, float, str]

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "results/dryrun")


def load_records(dryrun_dir: str = DRYRUN_DIR):
    recs = []
    for f in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def roofline_fraction(rec) -> float:
    rf = rec["roofline"]
    bound = max(rf["t_compute_s"], rf["t_memory_s"], rf["t_collective_s"])
    return rf["t_compute_s"] / bound if bound > 0 else 0.0


def dominant(rec) -> str:
    rf = rec["roofline"]
    terms = {
        "compute": rf["t_compute_s"],
        "memory": rf["t_memory_s"],
        "collective": rf["t_collective_s"],
    }
    return max(terms, key=terms.get)


def markdown_table(recs, mesh_filter=None) -> str:
    lines = [
        "| arch | shape | mesh | t_comp (s) | t_mem (s) | t_coll (s) | "
        "bottleneck | roofline frac | useful FLOPs | temp GiB |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | - | - | "
                f"skipped | - | - | - |"
            )
            continue
        if r["status"] != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | - | - | "
                f"ERROR | - | - | - |"
            )
            continue
        if mesh_filter and r["mesh"] != mesh_filter:
            continue
        rf = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {rf['t_compute_s']:.4g} | {rf['t_memory_s']:.4g} "
            f"| {rf['t_collective_s']:.4g} | {dominant(r)} "
            f"| {roofline_fraction(r):.3f} | {r['useful_flops_ratio']:.2f} "
            f"| {r['memory']['temp_bytes']/2**30:.1f} |"
        )
    return "\n".join(lines)


def run() -> List[Row]:
    recs = load_records()
    rows: List[Row] = []
    ok = [r for r in recs if r["status"] == "ok"]
    skipped = [r for r in recs if r["status"] == "skipped"]
    rows.append(("roofline/cells_ok", len(ok), "compiled cells"))
    rows.append(("roofline/cells_skipped", len(skipped),
                 "long_500k on full-attention archs"))
    rows.append(("roofline/cells_error",
                 len(recs) - len(ok) - len(skipped), "must be 0"))
    for r in ok:
        if r["mesh"] != "16x16":
            continue
        key = f"roofline/{r['arch']}/{r['shape']}"
        rows.append((key + "/frac", round(roofline_fraction(r), 4),
                     dominant(r)))
    return rows


if __name__ == "__main__":
    recs = load_records()
    print(markdown_table(recs))
