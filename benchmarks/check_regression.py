"""Bench-regression gate: compare a CI-produced bench JSON against the
committed baseline and fail (exit 1) on regression.

  PYTHONPATH=src python benchmarks/check_regression.py \
      --pair BENCH_kernels.json:bench_kernels_ci.json \
      --pair BENCH_serve.json:bench_serve_ci.json \
      --pair BENCH_energy.json:bench_energy_ci.json

Records are matched across files by an identity key (the stable descriptor
fields: bench/config/arch/shape dims/targets), then compared metric by
metric under per-metric tolerance rules:

  * structural counters (MXU calls, operand bytes, prefill calls, decode
    steps, billed tokens) are DETERMINISTIC functions of the code -> exact;
  * deterministic floats (KV bytes/active token, J/token, EDP/token) get a
    small relative tolerance (numeric jitter across BLAS/XLA builds);
  * measured wall-clock RATIOS (paged-vs-contiguous tok/s, kernel speedups)
    compare the same two implementations on the same box, so they transfer
    across machines - but noisily: they only gate with generous floors;
  * absolute wall times (tok_s, wall_us, ttft_ms) never gate.

A baseline record missing from the current run is a failure (a silently
dropped bench is exactly the "stale artifact" failure mode this gate
exists for); extra current records are allowed (new benches land first).

Bench schema v2.6: serve-suite records must carry a ``substrate`` field
naming the Substrate they ran on / billed (since v2.1), ``serve_drift``
records must carry the full drift-report surface (detection, swap and
recovery fields - since v2.2), ``serve_slo`` records must carry the
overload scoreboard (goodput, latency percentiles, shed/preempt/degrade
counters, engine_deaths, conservation - since v2.3), engine-comparison
``serve`` records must carry a ``decode_attn`` field naming the decode
attention path they ran ("kernel" / "gather" for the paged engine, "dense"
for the contiguous/wave baselines - since v2.4, alongside the
``paged_attention`` kernel bench whose ``gathered_kv_bytes_*`` counters pin
the gathered-KV copy eliminated), and ``serve_sharded`` records must pin
the tensor-parallel engine (new in v2.5): ``mesh_shape``/``devices`` are
identity fields, ``kv_bytes_per_device`` / ``kv_bytes_total`` /
``kv_shard_ways`` are structural (shape-derived) and gate exactly,
``token_match`` (sharded greedy tokens == single-device) gates exactly,
and ``scaling_tok_s_ratio`` gates on a generous absolute floor
(host-simulated devices share one physical CPU), and ``serve_prefix``
records must pin the prefix-sharing paged KV cache (new in v2.6): the
hit/CoW/eviction counters and billed-token tallies are deterministic
functions of the seeded shared-system-prompt schedule and gate exactly,
``token_match`` (warm greedy tokens == cold-cache run) gates exactly, and
the billed-prefill-energy saving (``saved_prefill_j`` /
``j_per_token_saved`` at the committed QR design point) gates with the
same relative tolerance as the other deterministic energy rollups;
:func:`validate_schema` fails either side of a pair with a clear message
when any of it is missing.

``--suites`` restricts a comparison to a comma list of suites on BOTH
sides - e.g. the distributed CI job produces only the ``serve_sharded``
suite and gates it against the full committed ``BENCH_serve.json``.
"""
from __future__ import annotations

import argparse
import json
import math
import sys
from typing import Dict, List, Tuple

# fields that IDENTIFY a record (never compared as metrics)
ID_FIELDS = (
    "bench", "config", "arch", "mode", "kind", "name", "substrate",
    "slots", "requests", "gen", "prompt_len", "prompt_lens",
    "B", "K", "M", "bx", "bw", "rows", "bank_rows", "n", "n_banks",
    "snr_t_target_db", "snr_low_db", "snr_high_db", "inject_scale",
    "policy", "alloc", "degrade", "workload_seed", "overload", "arrival",
    "kv_blocks",
    "blocks", "block_size", "heads", "kv_heads", "head_dim", "decode_attn",
    "mesh_shape", "devices",
    "prefix_len", "prefix_dup",
)

# bench schema v2.1: every serve-suite record must name the execution
# substrate it ran on / billed (the Substrate object's mode name) - a record
# without it can't be attributed to a design point, which is exactly the
# old side-channel ambiguity the Substrate API removed
SUBSTRATE_REQUIRED_PREFIXES = ("serve", "site_snr")

# metric -> (rule, tolerance); rules:
#   exact      current == baseline
#   rel        |cur - base| <= tol * max(|base|, 1e-30)
#   min_ratio  cur >= tol * base   (higher is better, deterministic metrics)
#   max_ratio  cur <= tol * base   (lower is better, deterministic metrics)
#   min_abs    cur >= tol          (wall-clock ratios: committed baselines on
#   max_abs    cur <= tol           a shared box swing run-to-run, so gating
#                                   relative to them fails on pure variance -
#                                   an absolute floor/ceiling encodes the
#                                   invariant that actually transfers, e.g.
#                                   "the rewrite is not slower than seed")
#   exact_str  string/bool equality
RULES: Dict[str, Tuple[str, float]] = {
    # kernel bench structural counters
    "mxu_calls": ("exact", 0.0),
    "noise_bytes": ("exact", 0.0),
    "w_bytes": ("exact", 0.0),
    "x_bytes": ("exact", 0.0),
    "plane_flops_mf": ("exact", 0.0),
    "noise_bytes_before": ("exact", 0.0),
    "noise_bytes_after": ("exact", 0.0),
    "noise_bytes_reduction": ("exact", 0.0),
    "mxu_calls_before": ("exact", 0.0),
    "mxu_calls_after": ("exact", 0.0),
    # kernel summary speedups (same-box ratio of rewrite vs frozen seed;
    # observed run-to-run spread 1.6-4.9 / 0.7-2.5 on an idle box, so the
    # absolute floor asserts "not slower than seed beyond noise")
    "speedup_vs_seed": ("min_abs", 0.8),
    "speedup_vs_seed_noise": ("min_abs", 0.5),
    # paged-attention decode step (schema v2.4): the gathered-KV working set
    # is a deterministic function of the shape -> exact; the before/after
    # reduction IS the acceptance invariant (gather copy -> O(1) block).
    # wall ratio gets only a generous same-box floor
    "gathered_kv_bytes_per_step": ("exact", 0.0),
    "gathered_kv_bytes_before": ("exact", 0.0),
    "gathered_kv_bytes_after": ("exact", 0.0),
    "gathered_kv_reduction": ("exact", 0.0),
    "speedup_vs_gather": ("min_abs", 0.2),
    # serve bench structural counters
    "prefill_calls": ("exact", 0.0),
    "prefill_rows": ("exact", 0.0),
    "decode_chunks": ("exact", 0.0),
    "decode_steps": ("exact", 0.0),
    "tokens": ("exact", 0.0),
    "host_syncs_per_token": ("rel", 0.01),
    "sync_bytes_per_token": ("rel", 0.01),
    "jit_out_bytes_per_tick": ("rel", 0.01),
    "kv_bytes_per_active_token": ("rel", 0.05),
    "kv_bytes_per_active_token_before": ("rel", 0.05),
    "kv_bytes_per_active_token_after": ("rel", 0.05),
    "prefill_calls_before": ("exact", 0.0),
    "prefill_calls_after": ("exact", 0.0),
    "kv_reduction": ("min_ratio", 0.9),
    # paged vs frozen-contiguous wall ratios (observed 1.0-4.6 / 0.2-1.2):
    # absolute bounds assert "paged not materially slower than contiguous"
    "speedup_tok_s": ("min_abs", 0.7),
    "ttft_ratio": ("max_abs", 3.0),
    # serve-path energy accounting (deterministic rollup)
    "b_adc": ("exact", 0.0),
    "knob": ("rel", 1e-9),
    "snr_t_db": ("rel", 0.01),
    "prefill_tokens": ("exact", 0.0),
    "decode_tokens": ("exact", 0.0),
    "generated_tokens": ("exact", 0.0),
    "prefill_j": ("rel", 0.02),
    "decode_j": ("rel", 0.02),
    "j_per_token": ("rel", 0.02),
    "j_per_request": ("rel", 0.02),
    "edp_per_token": ("rel", 0.02),
    "delay_per_token_s": ("rel", 0.02),
    "tok_s_compute": ("rel", 0.02),
    "j_per_token_best": ("rel", 0.02),
    "edp_per_token_best": ("rel", 0.02),
    # per-site SNR_T map (MPC-style overrides; deterministic closed forms)
    "b_adc_uniform": ("exact", 0.0),
    "b_adc_override": ("exact", 0.0),
    "snr_t_uniform_db": ("rel", 0.01),
    "snr_t_override_db": ("rel", 0.01),
    "snr_t_boosted_min_db": ("rel", 0.01),
    "sites": ("exact", 0.0),
    "sites_boosted": ("exact", 0.0),
    "j_per_token_uniform": ("rel", 0.02),
    "j_per_token_override": ("rel", 0.02),
    "j_per_token_ratio": ("rel", 0.02),
    # frontier/crossover shape (the acceptance invariant itself)
    "best_kind_energy": ("exact_str", 0.0),
    "best_kind_edp": ("exact_str", 0.0),
    "best_kind_high": ("exact_str", 0.0),
    "kinds_feasible": ("exact_str", 0.0),
    "qs_feasible_low": ("exact_str", 0.0),
    "qs_feasible_high": ("exact_str", 0.0),
    "crossover": ("exact_str", 0.0),
    # drift-injection serve scenario (schema v2.2): the shadow-calibration
    # loop is a deterministic function of the request schedule and the
    # injected scale, so the detection/swap counters gate exactly; the
    # absolute 1 dB ceiling on the post-swap gap IS the acceptance
    # invariant ("SNR_T recovers to within 1 dB of a fresh-frozen
    # reference"), not a diff against the baseline
    "drift_detected": ("exact_str", 0.0),
    "false_positives_clean": ("exact", 0.0),
    "chunks_to_detect": ("exact", 0.0),
    "detection_bound_chunks": ("exact", 0.0),
    "swaps": ("exact", 0.0),
    "shadow_samples": ("exact", 0.0),
    "sites_drifted": ("exact", 0.0),
    "degradation_db_max": ("rel", 0.05),
    "recovery_gap_db_max": ("max_abs", 1.0),
    "failed_requests": ("exact", 0.0),
    # SLO overload scenario (schema v2.3): virtual-clocked, so every metric
    # is a deterministic function of the committed workload seed - counters
    # gate exactly, latency/goodput floats get numeric-jitter tolerance.
    # The absolute rules ARE the acceptance invariants: the resilient stack
    # beats the FIFO+reserve baseline on goodput (ratio floor > 1), lazy
    # allocation raises pool utilization (gain floor), and overload NEVER
    # kills the engine (deaths ceiling 0)
    "completed": ("exact", 0.0),
    "shed": ("exact", 0.0),
    "errored": ("exact", 0.0),
    "ttft_miss": ("exact", 0.0),
    "itl_miss": ("exact", 0.0),
    "slo_met": ("exact", 0.0),
    "preemptions": ("exact", 0.0),
    "preempt_count": ("exact", 0.0),
    "substrate_swaps": ("exact", 0.0),
    "degrade_steps": ("exact", 0.0),
    "upgrade_steps": ("exact", 0.0),
    "shed_total": ("exact", 0.0),
    "elapsed_steps": ("rel", 0.01),
    "goodput": ("rel", 0.01),
    "goodput_tokens": ("rel", 0.01),
    "goodput_baseline": ("rel", 0.01),
    "goodput_resilient": ("rel", 0.01),
    "ttft_p50": ("rel", 0.01),
    "ttft_p99": ("rel", 0.01),
    "itl_p50": ("rel", 0.01),
    "itl_p99": ("rel", 0.01),
    "pool_utilization": ("rel", 0.01),
    "goodput_ratio": ("min_abs", 1.001),
    "pool_util_gain": ("min_abs", 0.01),
    "engine_deaths": ("max_abs", 0.0),
    "conserved": ("exact_str", 0.0),
    # tensor-parallel sharded serve (schema v2.5): per-device KV bytes and
    # the head-shard arity are deterministic functions of the shapes ->
    # exact; the greedy-token match with the single-device engine IS the
    # correctness invariant; the tok/s scaling ratio vs 1 device only gets
    # an absolute floor (host-simulated mesh devices share one physical
    # CPU, so "sharding didn't collapse throughput" is all that transfers)
    "kv_bytes_per_device": ("exact", 0.0),
    "kv_bytes_total": ("exact", 0.0),
    "kv_shard_ways": ("exact", 0.0),
    "token_match": ("exact_str", 0.0),
    "scaling_tok_s_ratio": ("min_abs", 0.05),
    # prefix-sharing paged KV (schema v2.6): every counter is a pure
    # function of the seeded shared-system-prompt schedule -> exact (incl.
    # hit_rate, a rounded ratio of exact counters); the energy-side fields
    # are deterministic rollups and share the 2% numeric-jitter tolerance
    # (the ">0 hits / >0 J saved" acceptance floors are pinned against the
    # committed artifact by tests/test_bench_schema.py)
    "prefix_lookups": ("exact", 0.0),
    "prefix_hits": ("exact", 0.0),
    "hit_rate": ("exact", 0.0),
    "prefix_hit_tokens": ("exact", 0.0),
    "saved_billed_tokens": ("exact", 0.0),
    "cow_copies": ("exact", 0.0),
    "prefix_evictions": ("exact", 0.0),
    "cached_blocks": ("exact", 0.0),
    "prefill_rows_cold": ("exact", 0.0),
    "prefill_tokens_cold": ("exact", 0.0),
    "kv_bytes_per_active_token_cold": ("rel", 0.05),
    "prefill_j_cold": ("rel", 0.02),
    "j_per_token_cold": ("rel", 0.02),
    "saved_prefill_j": ("rel", 0.02),
    "j_per_token_saved": ("rel", 0.02),
}

# drift records must carry the full report surface: a record that says
# "serve_drift" but lacks these can't express the acceptance invariant
DRIFT_REQUIRED_FIELDS = (
    "substrate", "drift_detected", "chunks_to_detect",
    "detection_bound_chunks", "swaps", "sites_drifted",
    "recovery_gap_db_max", "failed_requests",
)

# serve_slo records must carry the overload scoreboard (schema v2.3): a
# record without these cannot express the overload acceptance invariants
SLO_REQUIRED_FIELDS = (
    "substrate", "policy", "alloc", "workload_seed", "overload", "goodput",
    "slo_met", "shed", "preempt_count", "pool_utilization", "engine_deaths",
    "conserved",
)
SLO_SUMMARY_REQUIRED_FIELDS = (
    "substrate", "workload_seed", "goodput_ratio", "pool_util_gain",
    "preempt_count", "engine_deaths", "conserved",
)

# serve_sharded records must pin the tensor-parallel engine (schema v2.5):
# the mesh identity, the structural per-device KV bytes, the greedy-token
# match with the single-device engine, and the tok/s scaling ratio
SHARDED_REQUIRED_FIELDS = (
    "substrate", "mesh_shape", "devices", "decode_attn",
    "scaling_tok_s_ratio", "kv_bytes_per_device", "kv_bytes_total",
    "kv_shard_ways", "token_match",
)

# serve_prefix records must pin the prefix-sharing cache (schema v2.6):
# the workload identity, the hit/CoW/eviction counters, the warm-vs-cold
# greedy-token match, and the billed-prefill-energy saving
PREFIX_REQUIRED_FIELDS = (
    "substrate", "prefix_len", "prefix_dup", "workload_seed",
    "prefix_lookups", "prefix_hits", "hit_rate", "prefix_hit_tokens",
    "saved_billed_tokens", "cow_copies", "prefix_evictions",
    "cached_blocks", "token_match", "kv_bytes_per_active_token",
    "j_per_token", "j_per_token_cold", "saved_prefill_j",
    "j_per_token_saved",
)


def record_key(suite: str, rec: dict) -> str:
    ident = {k: rec[k] for k in ID_FIELDS if k in rec}
    return suite + "::" + json.dumps(ident, sort_keys=True)


def _records(payload: dict) -> Dict[str, dict]:
    out: Dict[str, dict] = {}
    for suite, body in payload.get("suites", {}).items():
        for rec in body.get("records", []):
            key = record_key(suite, rec)
            # duplicate keys (e.g. repeated shapes) disambiguate by encounter
            # order; NOTE this pairing is order-dependent, so a bench that
            # emits identical-identity records must keep their relative order
            # stable (no committed baseline has duplicates today)
            base, i = key, 0
            while key in out:
                i += 1
                key = f"{base}#{i}"
            out[key] = rec
    return out


def compare_metric(name: str, base, cur) -> str:
    """Empty string if OK, else a failure description."""
    rule, tol = RULES[name]
    if rule == "exact_str":
        return "" if cur == base else f"{name}: {base!r} -> {cur!r}"
    try:
        b, c = float(base), float(cur)
    except (TypeError, ValueError):
        return "" if cur == base else f"{name}: {base!r} -> {cur!r}"
    if math.isnan(b) or math.isnan(c):
        return ""  # a NaN baseline can't gate
    if rule == "exact":
        return "" if b == c else f"{name}: {b:g} -> {c:g} (exact)"
    if rule == "rel":
        if abs(c - b) <= tol * max(abs(b), 1e-30):
            return ""
        return f"{name}: {b:g} -> {c:g} (|d| > {tol:.0%})"
    if rule == "min_ratio":
        if c >= tol * b:
            return ""
        return f"{name}: {b:g} -> {c:g} (< {tol:g}x baseline)"
    if rule == "max_ratio":
        if c <= tol * b:
            return ""
        return f"{name}: {b:g} -> {c:g} (> {tol:g}x baseline)"
    if rule == "min_abs":
        return "" if c >= tol else f"{name}: {c:g} (< floor {tol:g})"
    if rule == "max_abs":
        return "" if c <= tol else f"{name}: {c:g} (> ceiling {tol:g})"
    raise ValueError(rule)


def validate_schema(payload: dict, label: str) -> List[str]:
    """Bench-schema v2.4 structural checks (run on BOTH sides of a pair: a
    stale committed baseline must fail just as loudly as a bad CI run)."""
    failures: List[str] = []
    for suite, body in payload.get("suites", {}).items():
        if "error" in body:
            continue
        for rec in body.get("records", []):
            bench = rec.get("bench", "")
            ident = {k: rec[k] for k in ("bench", "config", "kind",
                                         "name") if k in rec}
            if bench.startswith(SUBSTRATE_REQUIRED_PREFIXES) \
                    and "substrate" not in rec:
                failures.append(
                    f"{label}: record {ident} is missing its 'substrate' "
                    f"field (required since bench schema v2.1: every serve "
                    f"record must name the Substrate it ran on/billed - "
                    f"regenerate the artifact with benchmarks/run.py)")
            if bench == "serve" and "decode_attn" not in rec:
                failures.append(
                    f"{label}: serve record {ident} is missing its "
                    f"'decode_attn' field (required since bench schema "
                    f"v2.4: every engine-comparison record must name the "
                    f"decode attention path it ran - kernel/gather/dense - "
                    f"regenerate the artifact with benchmarks/run.py)")
            if bench == "serve_drift":
                missing = [f for f in DRIFT_REQUIRED_FIELDS if f not in rec]
                if missing:
                    failures.append(
                        f"{label}: serve_drift record {ident} is missing "
                        f"{missing} (required since bench schema v2.2: a "
                        f"drift record must carry the full detection/swap/"
                        f"recovery report surface)")
            required = {"serve_slo": SLO_REQUIRED_FIELDS,
                        "serve_slo_summary": SLO_SUMMARY_REQUIRED_FIELDS}
            if bench in required:
                missing = [f for f in required[bench] if f not in rec]
                if missing:
                    failures.append(
                        f"{label}: {bench} record {ident} is missing "
                        f"{missing} (required since bench schema v2.3: an "
                        f"SLO record must carry the full overload "
                        f"scoreboard)")
            if bench == "serve_sharded":
                missing = [f for f in SHARDED_REQUIRED_FIELDS if f not in rec]
                if missing:
                    failures.append(
                        f"{label}: serve_sharded record {ident} is missing "
                        f"{missing} (required since bench schema v2.5: a "
                        f"sharded-serve record must pin the mesh identity, "
                        f"per-device KV bytes, token match and tok/s "
                        f"scaling - regenerate the artifact with "
                        f"benchmarks/run.py)")
            if bench == "serve_prefix":
                missing = [f for f in PREFIX_REQUIRED_FIELDS if f not in rec]
                if missing:
                    failures.append(
                        f"{label}: serve_prefix record {ident} is missing "
                        f"{missing} (required since bench schema v2.6: a "
                        f"prefix-sharing record must pin the workload "
                        f"identity, hit/CoW/eviction counters, warm-vs-cold "
                        f"token match and the billed-prefill-energy saving "
                        f"- regenerate the artifact with benchmarks/run.py)")
    return failures


def compare_payloads(baseline: dict, current: dict) -> List[str]:
    """All regressions of ``current`` vs ``baseline`` (empty list = pass)."""
    failures: List[str] = []
    failures.extend(validate_schema(baseline, "baseline"))
    failures.extend(validate_schema(current, "current"))
    for suite, body in baseline.get("suites", {}).items():
        if "error" in body:
            continue  # an errored baseline suite can't gate
        cur_body = current.get("suites", {}).get(suite)
        if cur_body is None:
            failures.append(f"{suite}: suite missing from current run")
            continue
        if "error" in cur_body:
            failures.append(f"{suite}: current run errored: {cur_body['error']}")
            continue
    base_recs = _records(baseline)
    cur_recs = _records(current)
    for key, brec in base_recs.items():
        crec = cur_recs.get(key)
        if crec is None:
            suite = key.split("::", 1)[0]
            if suite in current.get("suites", {}) \
                    and "error" not in current["suites"][suite]:
                failures.append(f"missing record: {key}")
            continue
        for metric, bval in brec.items():
            if metric in ID_FIELDS or metric not in RULES:
                continue
            if metric not in crec:
                failures.append(f"{key}: metric {metric} missing")
                continue
            msg = compare_metric(metric, bval, crec[metric])
            if msg:
                failures.append(f"{key}: {msg}")
    return failures


def filter_suites(payload: dict, suites) -> dict:
    """A shallow copy of ``payload`` keeping only the named suites (applied
    to BOTH sides of a pair: a job that produces one suite can gate it
    against a baseline that carries several)."""
    keep = set(suites)
    out = dict(payload)
    out["suites"] = {name: body
                     for name, body in payload.get("suites", {}).items()
                     if name in keep}
    return out


def check_pair(baseline_path: str, current_path: str,
               suites=None) -> List[str]:
    with open(baseline_path) as f:
        baseline = json.load(f)
    with open(current_path) as f:
        current = json.load(f)
    if suites is not None:
        baseline = filter_suites(baseline, suites)
        current = filter_suites(current, suites)
    return [f"[{baseline_path} vs {current_path}] {m}"
            for m in compare_payloads(baseline, current)]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", action="append", required=True,
                    metavar="BASELINE:CURRENT",
                    help="baseline JSON : CI-produced JSON (repeatable)")
    ap.add_argument("--suites", default=None, metavar="A,B",
                    help="restrict every pair to this comma list of suites "
                         "(both sides; a partial CI run gates only what it "
                         "produced)")
    args = ap.parse_args(argv)
    suites = set(args.suites.split(",")) if args.suites else None
    failures: List[str] = []
    for pair in args.pair:
        baseline_path, _, current_path = pair.partition(":")
        if not current_path:
            ap.error(f"--pair wants BASELINE:CURRENT, got {pair!r}")
        failures.extend(check_pair(baseline_path, current_path,
                                   suites=suites))
    if failures:
        print(f"BENCH REGRESSION: {len(failures)} failure(s)")
        for f in failures:
            print(f"  {f}")
        return 1
    print("bench regression gate: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
