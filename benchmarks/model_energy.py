"""Beyond-paper: whole-model IMC energy/delay rollups (SSV-C extended from
single DPs to the assigned architectures).

Maps every matmul of each assigned architecture onto 512-row IMC banks at the
min-energy design point meeting a target SNR_T, and reports energy/token and
TOPS/W - the numbers an IMC accelerator architect would quote.
"""
from __future__ import annotations

from typing import List, Tuple

from repro import configs
from repro.core.mapping import MatmulShape, map_model

Row = Tuple[str, float, str]


def model_matmul_shapes(name: str):
    """All per-token matmul shapes of an arch (weights only; attention
    score/value products are activation-activation and stay digital)."""
    cfg = configs.get(name)
    d, hd = cfg.d_model, cfg.resolved_head_dim
    shapes = []
    counts = {}
    for kind in cfg.pattern:
        counts[kind] = counts.get(kind, 0) + cfg.n_full_cycles
    for i, kind in enumerate(cfg.tail_kinds):
        counts[kind] = counts.get(kind, 0) + 1
    for kind, cnt in counts.items():
        if kind in ("attn", "local"):
            shapes += [
                MatmulShape(f"{kind}.wq", d, cfg.n_heads * hd, cnt),
                MatmulShape(f"{kind}.wk", d, cfg.n_kv_heads * hd, cnt),
                MatmulShape(f"{kind}.wv", d, cfg.n_kv_heads * hd, cnt),
                MatmulShape(f"{kind}.wo", cfg.n_heads * hd, d, cnt),
            ]
        elif kind == "ssm":
            d_in = cfg.ssm_expand * d
            proj = 2 * d_in + 2 * cfg.ssm_groups * cfg.ssm_state + d_in // cfg.ssm_head_dim
            shapes += [
                MatmulShape("ssm.in_proj", d, proj, cnt),
                MatmulShape("ssm.out_proj", d_in, d, cnt),
            ]
        elif kind == "rglru":
            w = cfg.rnn_width
            shapes += [
                MatmulShape("rg.x", d, w, cnt),
                MatmulShape("rg.gate", d, w, cnt),
                MatmulShape("rg.out", w, d, cnt),
            ]
        if kind != "ssm" and cfg.d_ff > 0:
            mults = 3 if cfg.mlp_kind in ("swiglu", "geglu") else 2
            e = cfg.top_k if cfg.n_experts else 1  # active experts per token
            shapes += [
                MatmulShape("mlp.wi", d, cfg.d_ff, cnt * e * (mults - 1)),
                MatmulShape("mlp.wo", cfg.d_ff, d, cnt * e),
            ]
    shapes.append(MatmulShape("lm_head", d, cfg.vocab_size, 1))
    return shapes


def run(archs=("phi3-mini-3.8b", "gemma2-9b", "mamba2-2.7b",
               "granite-moe-1b-a400m"), snr_t_db: float = 24.0) -> List[Row]:
    rows: List[Row] = []
    for name in archs:
        shapes = model_matmul_shapes(name)
        rep = map_model(shapes, snr_t_target_db=snr_t_db)
        s = rep.summary()
        rows.append((f"imc_energy/{name}/uJ_per_token",
                     round(s["total_energy_j"] * 1e6, 3),
                     f"@SNR_T>={snr_t_db}dB, 512-row banks"))
        rows.append((f"imc_energy/{name}/TOPS_per_W",
                     round(s["tops_per_watt"], 2),
                     f"min layer SNR_T={s['min_snr_t_db']:.1f}dB"))
        rows.append((f"imc_energy/{name}/fJ_per_MAC",
                     round(s["energy_per_mac_fj"], 2),
                     f"{int(s['layers'])} matmul groups"))
    return rows
