"""Beyond-paper: whole-model IMC energy/delay rollups (SSV-C extended from
single DPs to the assigned architectures).

Maps every matmul of each assigned architecture onto 512-row IMC banks at the
min-energy design point meeting a target SNR_T, and reports energy/token and
TOPS/W - the numbers an IMC accelerator architect would quote.
"""
from __future__ import annotations

from typing import List, Tuple

from repro import configs
from repro.core.mapping import map_model, per_token_matmul_shapes

Row = Tuple[str, float, str]


def model_matmul_shapes(name: str):
    """All per-token matmul shapes of an arch (weights only; attention
    score/value products are activation-activation and stay digital).

    Thin name-based wrapper over the ONE shared shapes walk
    (``core.mapping.per_token_matmul_shapes``) also used by the serve-path
    meter and the profiling rollup - keeping a private copy here is how
    sites silently double-count between accounting paths."""
    return per_token_matmul_shapes(configs.get(name))


def run(archs=("phi3-mini-3.8b", "gemma2-9b", "mamba2-2.7b",
               "granite-moe-1b-a400m"), snr_t_db: float = 24.0) -> List[Row]:
    rows: List[Row] = []
    for name in archs:
        shapes = model_matmul_shapes(name)
        rep = map_model(shapes, snr_t_target_db=snr_t_db)
        s = rep.summary()
        rows.append((f"imc_energy/{name}/uJ_per_token",
                     round(s["total_energy_j"] * 1e6, 3),
                     f"@SNR_T>={snr_t_db}dB, 512-row banks"))
        rows.append((f"imc_energy/{name}/TOPS_per_W",
                     round(s["tops_per_watt"], 2),
                     f"min layer SNR_T={s['min_snr_t_db']:.1f}dB"))
        rows.append((f"imc_energy/{name}/fJ_per_MAC",
                     round(s["energy_per_mac_fj"], 2),
                     f"{int(s['layers'])} matmul groups"))
    return rows
