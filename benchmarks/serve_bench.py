"""Serving micro-bench: tok/s, time-to-first-token and host-transfer traffic
for the continuous-batching engine vs a FROZEN copy of the seed wave server.

The frozen ``WaveServer`` below preserves the pre-rewrite serving design (kept
ONLY as the perf reference): one decode step per Python tick with a host sync
(`np.array` of the argmax) every token, a host-side `tree_map` loop scattering
each prefill cache into its slot, and a single scalar cache position that
forces equal-prompt-length admission waves.  The engine
(`repro.launch.serve.Engine`) replaces all three: per-slot position vectors,
a fused `lax.scan` decode chunk (one (slots, T) int32 host transfer per
chunk), and bucketed prefill with a jitted slot insert.

Structural counters reported per configuration:

  sync_bytes_per_token   int32 token traffic actually copied to the host,
                         amortized per generated token
  jit_out_bytes_per_tick bytes leaving the jitted decode computation per tick
                         (wave: the full (slots, 1, vocab) f32 logits cross
                         the jit boundary every token; engine: logits never
                         leave the scan - only the (slots, T) token block)
  host_syncs_per_token   blocking device->host round trips per token

CPU wall times are indicative; the structural counters transfer to TPU.
``bench_records()`` returns machine-readable dicts (consumed by
``benchmarks/run.py --json``); ``run()`` formats them as CSV rows.  The
committed ``BENCH_serve.json`` baseline is produced with::

    PYTHONPATH=src python benchmarks/run.py --only serve --json BENCH_serve.json
"""
from __future__ import annotations

import time
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch.serve import Engine, Request, serve
from repro.models import decode_step, init_cache, init_params, prefill

Row = Tuple[str, float, str]

ARCH = "musicgen-medium"
BATCH = 4
REQUESTS = 8
PROMPT_LEN = 12
GEN = 8
# measured request count per mode (bitserial is ~30x slower per token on the
# CPU reference path; fewer requests keep the suite inside the CI budget)
MODES = {None: REQUESTS, "imc_analytic": REQUESTS, "imc_bitserial": 4}
WARMUP_REQUESTS = 2  # enough to compile prefill bucket + all chunk sizes


# ---------------------------------------------------------------------------
# frozen seed wave server (pre-rewrite design, perf reference only)
# ---------------------------------------------------------------------------


class WaveServer:
    """Fixed-slot wave server: scalar cache position (slots stay
    position-synchronized), per-tick host sync, host-side cache scatter."""

    def __init__(self, cfg, params, batch_slots: int, cache_len: int,
                 rng: Optional[jax.Array] = None):
        self.cfg = cfg
        self.params = params
        self.slots: List[Optional[Request]] = [None] * batch_slots
        self.cache = init_cache(cfg, batch_slots, cache_len)
        self.cache_len = cache_len
        self.slot_pos = np.zeros(batch_slots, np.int32)
        self.last_token = np.zeros(batch_slots, np.int32)
        self.rng = rng
        self.ticks = 0
        self.sync_bytes = 0
        self._decode = jax.jit(
            lambda p, t, c, key: decode_step(p, cfg, t, c, rng=key)
        )

    def admit(self, req: Request) -> bool:
        for i, s in enumerate(self.slots):
            if s is None:
                if req.t_submit is None:
                    req.t_submit = time.perf_counter()
                self.slots[i] = req
                self._prefill_slot(i, req)
                return True
        return False

    def _prefill_slot(self, i: int, req: Request):
        toks = jnp.asarray(req.prompt)[None, :]
        logits, cache1 = prefill(self.params, self.cfg, toks,
                                 cache_len=self.cache_len, rng=self.rng)

        # scatter the single-request cache into slot i of the batched cache
        def put(batched, single):
            if batched.ndim == 0 or batched.shape == single.shape == ():
                return batched
            for axis in range(batched.ndim):
                if (batched.shape[axis] == len(self.slots)
                        and single.shape[axis] == 1):
                    idx = [slice(None)] * batched.ndim
                    idx[axis] = i
                    sidx = [slice(None)] * single.ndim
                    sidx[axis] = 0
                    return batched.at[tuple(idx)].set(single[tuple(sidx)])
            return batched

        self.cache = jax.tree_util.tree_map(
            lambda b, s: put(b, s) if hasattr(b, "at") else b,
            {k: v for k, v in self.cache.items() if k != "pos"},
            {k: v for k, v in cache1.items() if k != "pos"},
        )
        self.cache["pos"] = jnp.asarray(int(cache1["pos"]), jnp.int32)
        self.slot_pos[i] = len(req.prompt)
        self.last_token[i] = int(jnp.argmax(logits[0, -1]))
        req.out.append(int(self.last_token[i]))
        req.t_first = time.perf_counter()

    def tick(self):
        toks = jnp.asarray(self.last_token)
        key = None
        if self.rng is not None:
            self.rng, key = jax.random.split(self.rng)
        logits, self.cache = self._decode(self.params, toks, self.cache, key)
        # np.array (copy): the per-token host sync the engine eliminates
        nxt = np.array(jnp.argmax(logits[:, 0], axis=-1), np.int32)
        self.ticks += 1
        self.sync_bytes += nxt.nbytes
        for i, req in enumerate(self.slots):
            if req is None or req.done:
                continue
            req.out.append(int(nxt[i]))
            self.slot_pos[i] += 1
            if len(req.out) >= req.max_new:
                req.done = True
                self.slots[i] = None
        self.last_token = nxt

    @property
    def active(self) -> int:
        return sum(1 for s in self.slots if s is not None)


def _serve_wave(server: WaveServer, requests: List[Request]) -> List[Request]:
    pending = list(requests)
    finished: List[Request] = []
    while pending or server.active:
        while pending and server.admit(pending[0]):
            pending.pop(0)
        before = [s for s in server.slots if s is not None]
        server.tick()
        finished.extend(r for r in before if r.done)
    return finished


# ---------------------------------------------------------------------------
# harness
# ---------------------------------------------------------------------------


def _mk_cfg(mode: Optional[str]):
    cfg = configs.get_smoke(ARCH)
    if mode:
        from repro.core.imc_linear import IMCConfig

        cfg = cfg.replace(imc=IMCConfig(mode=mode, bx=7, bw=7, v_wl=0.7))
    return cfg


def _mk_requests(cfg, lens, n_requests) -> List[Request]:
    rnp = np.random.default_rng(0)
    return [
        Request(rid=i, prompt=rnp.integers(0, cfg.vocab_size, lens[i % len(lens)]),
                max_new=GEN)
        for i in range(n_requests)
    ]


def _ttft_ms(reqs) -> float:
    vals = [r.ttft for r in reqs if r.ttft is not None]
    return 1e3 * float(np.mean(vals)) if vals else float("nan")


def _run_wave(cfg, rng, cache_len, n_requests):
    server = WaveServer(cfg, init_params(jax.random.PRNGKey(0), cfg),
                        BATCH, cache_len, rng=rng)
    reqs = _mk_requests(cfg, [PROMPT_LEN], n_requests)
    t0 = time.perf_counter()
    out = _serve_wave(server, reqs)
    dt = time.perf_counter() - t0
    tokens = sum(len(r.out) for r in out)
    return {
        "wall_s": round(dt, 3),
        "tok_s": round(tokens / dt, 1) if dt > 0 else float("nan"),
        "ttft_ms": round(_ttft_ms(out), 1),
        "tokens": tokens,
        "host_syncs_per_token": 1.0,
        "sync_bytes_per_token": round(server.sync_bytes / max(tokens, 1), 1),
        # the (slots, 1, vocab) f32 logits leave the jitted step every tick
        "jit_out_bytes_per_tick": BATCH * cfg.padded_vocab * 4,
    }


def _run_engine(cfg, rng, cache_len, lens, n_requests):
    engine = Engine(cfg, init_params(jax.random.PRNGKey(0), cfg),
                    BATCH, cache_len, rng=rng, max_chunk=GEN)
    reqs = _mk_requests(cfg, lens, n_requests)
    t0 = time.perf_counter()
    out = serve(engine, reqs)
    dt = time.perf_counter() - t0
    tokens = sum(len(r.out) for r in out)
    steps = max(engine.decode_steps, 1)
    return {
        "wall_s": round(dt, 3),
        "tok_s": round(tokens / dt, 1) if dt > 0 else float("nan"),
        "ttft_ms": round(_ttft_ms(out), 1),
        "tokens": tokens,
        "host_syncs_per_token": round(engine.decode_calls / steps, 3),
        "sync_bytes_per_token": round(
            engine.host_transfer_bytes / max(tokens, 1), 1),
        # only the (slots, T) int32 token block leaves the fused scan
        "jit_out_bytes_per_tick": round(
            engine.host_transfer_bytes / max(engine.decode_steps, 1), 1),
        "decode_chunks": engine.decode_calls,
        "decode_steps": engine.decode_steps,
    }


def bench_records() -> List[dict]:
    records: List[dict] = []
    cache_len = 2 * PROMPT_LEN + GEN + 8  # covers the pow2 bucket (16)
    for mode, n_requests in MODES.items():
        cfg = _mk_cfg(mode)
        rng = jax.random.PRNGKey(7) if mode else None
        meta = {"bench": "serve", "arch": ARCH, "mode": mode or "digital",
                "slots": BATCH, "requests": n_requests,
                "prompt_len": PROMPT_LEN, "gen": GEN}
        # warmup both paths (compile time excluded, as in kernel_bench)
        _run_wave(cfg, rng, cache_len, WARMUP_REQUESTS)
        _run_engine(cfg, rng, cache_len, [PROMPT_LEN], WARMUP_REQUESTS)
        wave = _run_wave(cfg, rng, cache_len, n_requests)
        eng = _run_engine(cfg, rng, cache_len, [PROMPT_LEN], n_requests)
        records.append({**meta, "config": "wave_baseline", **wave})
        records.append({**meta, "config": "engine", **eng})
        records.append({
            **meta, "bench": "serve_summary",
            "speedup_tok_s": round(eng["tok_s"] / wave["tok_s"], 2)
            if wave["tok_s"] else float("nan"),
            "ttft_ratio": round(eng["ttft_ms"] / wave["ttft_ms"], 2)
            if wave["ttft_ms"] else float("nan"),
            "jit_out_bytes_per_tick_before": wave["jit_out_bytes_per_tick"],
            "jit_out_bytes_per_tick_after": eng["jit_out_bytes_per_tick"],
            "host_syncs_per_token_before": wave["host_syncs_per_token"],
            "host_syncs_per_token_after": eng["host_syncs_per_token"],
        })
    # unequal prompt lengths in one batch: the wave server cannot run this
    # shape at all (scalar cache position => admission waves)
    cfg = _mk_cfg(None)
    lens = [5, 9, 12, 17]
    cache_len = 32 + GEN + 8
    _run_engine(cfg, None, cache_len, lens, len(lens))  # warm every bucket
    eng = _run_engine(cfg, None, cache_len, lens, REQUESTS)
    records.append({"bench": "serve", "arch": ARCH, "mode": "digital",
                    "config": "engine_unequal_prompts", "slots": BATCH,
                    "requests": REQUESTS, "prompt_lens": lens, "gen": GEN,
                    **eng})
    return records


def rows_from_records(records: List[dict]) -> List[Row]:
    rows: List[Row] = []
    for r in records:
        tag = f"{r['mode']}_b{r['slots']}"
        if r["bench"] == "serve_summary":
            rows.append((
                f"serve/summary_{tag}",
                r["speedup_tok_s"],
                f"tok/s speedup; jit_out_B/tick "
                f"{r['jit_out_bytes_per_tick_before']}->"
                f"{r['jit_out_bytes_per_tick_after']} "
                f"syncs/tok {r['host_syncs_per_token_before']}->"
                f"{r['host_syncs_per_token_after']}",
            ))
        else:
            rows.append((
                f"serve/{r['config']}_{tag}",
                r["tok_s"],
                f"tok/s; ttft={r['ttft_ms']}ms "
                f"sync_B/tok={r['sync_bytes_per_token']} "
                f"jit_out_B/tick={r['jit_out_bytes_per_tick']}",
            ))
    return rows


def run() -> List[Row]:
    return rows_from_records(bench_records())
