"""Serving micro-bench: tok/s, time-to-first-token, host-transfer traffic and
KV memory per active token for the PAGED engine vs a FROZEN copy of the PR-2
contiguous-cache engine (and the seed wave server, kept as a reference).

Two frozen baselines live here (kept ONLY as perf references):

  ``WaveServer``        the seed design: one decode step per Python tick with
                        a host sync every token, host-side cache scatter, and
                        a single scalar cache position (equal-prompt waves).
  ``ContiguousEngine``  the PR-2 design: per-slot position vectors, fused
                        decode scan, bucketed prefill - but every slot owns a
                        contiguous cache_len KV slice sized for the LONGEST
                        request, and prefill admits one request per call.

The live engine (`repro.launch.serve.Engine`) replaces the contiguous cache
with a paged block pool + per-slot block tables (KV memory proportional to
tokens actually held) and admits the FIFO prefix of same-bucket pending
requests as one batched (R, bucket) prefill call.

Structural counters reported per configuration:

  sync_bytes_per_token      int32 token traffic copied to the host / token
  jit_out_bytes_per_tick    bytes leaving the jitted decode per tick
  host_syncs_per_token      blocking device->host round trips per token
  kv_bytes_per_active_token KV cache bytes held per token resident in an
                            active slot, sampled after every decode chunk
                            (contiguous: the full slots x cache_len
                            allocation; paged: allocated blocks only)
  prefill_calls             prefill dispatches (paged batches same-bucket
                            admissions; contiguous pays one per request)

CPU wall times are indicative; the structural counters transfer to TPU.
``bench_records()`` returns machine-readable dicts (consumed by
``benchmarks/run.py --json``); ``run()`` formats them as CSV rows.  The
committed ``BENCH_serve.json`` baseline is produced with::

    PYTHONPATH=src python benchmarks/run.py --only serve --json BENCH_serve.json
"""
from __future__ import annotations

import copy
import json
import os
import subprocess
import sys
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.design import optimize
from repro.core.mapping import per_token_matmul_shapes
from repro.core.substrate import AnalyticIMC, BitSerialIMC, substrate_for_design
from repro.launch.metering import DPMeter, serve_energy_report
from repro.launch.serve import Engine, Request, needs_exact_prefill, prefill_bucket
from repro.models import decode_step, init_cache, init_params, prefill

Row = Tuple[str, float, str]

ARCH = "musicgen-medium"
BATCH = 4
REQUESTS = 8
PROMPT_LEN = 12
GEN = 8
# the mixed short/long workload: mostly short prompts with occasional long
# ones - the contiguous engine must size EVERY slot for the longest
MIXED_LENS = [4, 6, 48, 5, 8, 44, 6, 7]
# measured request count per mode (bitserial is ~30x slower per token on the
# CPU reference path; fewer requests keep the suite inside the CI budget)
MODES = {None: REQUESTS, "imc_analytic": REQUESTS, "imc_bitserial": 4}
# warmup replays the FULL measured workload once: the paged engine compiles
# one prefill per (R-pad, bucket) group shape, and the group composition is a
# deterministic function of the request schedule, so an identical warmup pass
# is the only way to cover every shape (a short warmup leaves compiles inside
# the measured window and understates steady-state tok/s)
WARMUP_REQUESTS = 2  # wave-server warmup only (exact-length prefill)
REPEATS = 3  # measured runs per engine; best wall time is reported


# ---------------------------------------------------------------------------
# frozen seed wave server (pre-PR-2 design, perf reference only)
# ---------------------------------------------------------------------------


class WaveServer:
    """Fixed-slot wave server: scalar cache position (slots stay
    position-synchronized), per-tick host sync, host-side cache scatter."""

    def __init__(self, cfg, params, batch_slots: int, cache_len: int,
                 rng: Optional[jax.Array] = None):
        self.cfg = cfg
        self.params = params
        self.slots: List[Optional[Request]] = [None] * batch_slots
        self.cache = init_cache(cfg, batch_slots, cache_len)
        self.cache_len = cache_len
        self.slot_pos = np.zeros(batch_slots, np.int32)
        self.last_token = np.zeros(batch_slots, np.int32)
        self.rng = rng
        self.ticks = 0
        self.sync_bytes = 0
        self._decode = jax.jit(
            lambda p, t, c, key: decode_step(p, cfg, t, c, rng=key)
        )

    def admit(self, req: Request) -> bool:
        for i, s in enumerate(self.slots):
            if s is None:
                if req.t_submit is None:
                    req.t_submit = time.perf_counter()
                self.slots[i] = req
                self._prefill_slot(i, req)
                return True
        return False

    def _prefill_slot(self, i: int, req: Request):
        toks = jnp.asarray(req.prompt)[None, :]
        logits, cache1 = prefill(self.params, self.cfg, toks,
                                 cache_len=self.cache_len, rng=self.rng)

        # scatter the single-request cache into slot i of the batched cache
        def put(batched, single):
            if batched.ndim == 0 or batched.shape == single.shape == ():
                return batched
            for axis in range(batched.ndim):
                if (batched.shape[axis] == len(self.slots)
                        and single.shape[axis] == 1):
                    idx = [slice(None)] * batched.ndim
                    idx[axis] = i
                    sidx = [slice(None)] * single.ndim
                    sidx[axis] = 0
                    return batched.at[tuple(idx)].set(single[tuple(sidx)])
            return batched

        self.cache = jax.tree_util.tree_map(
            lambda b, s: put(b, s) if hasattr(b, "at") else b,
            {k: v for k, v in self.cache.items() if k != "pos"},
            {k: v for k, v in cache1.items() if k != "pos"},
        )
        self.cache["pos"] = jnp.asarray(int(cache1["pos"]), jnp.int32)
        self.slot_pos[i] = len(req.prompt)
        self.last_token[i] = int(jnp.argmax(logits[0, -1]))
        req.out.append(int(self.last_token[i]))
        req.t_first = time.perf_counter()

    def tick(self):
        toks = jnp.asarray(self.last_token)
        key = None
        if self.rng is not None:
            self.rng, key = jax.random.split(self.rng)
        logits, self.cache = self._decode(self.params, toks, self.cache, key)
        # np.array (copy): the per-token host sync the engine eliminates
        nxt = np.array(jnp.argmax(logits[:, 0], axis=-1), np.int32)
        self.ticks += 1
        self.sync_bytes += nxt.nbytes
        for i, req in enumerate(self.slots):
            if req is None or req.done:
                continue
            req.out.append(int(nxt[i]))
            self.slot_pos[i] += 1
            if len(req.out) >= req.max_new:
                req.done = True
                self.slots[i] = None
        self.last_token = nxt

    @property
    def active(self) -> int:
        return sum(1 for s in self.slots if s is not None)


def _serve_wave(server: WaveServer, requests: List[Request]) -> List[Request]:
    pending = list(requests)
    finished: List[Request] = []
    while pending or server.active:
        while pending and server.admit(pending[0]):
            pending.pop(0)
        before = [s for s in server.slots if s is not None]
        server.tick()
        finished.extend(r for r in before if r.done)
    return finished


# ---------------------------------------------------------------------------
# frozen PR-2 contiguous-cache engine (pre-paging design, perf reference only)
# ---------------------------------------------------------------------------


class ContiguousEngine:
    """FROZEN copy of the PR-2 engine: per-slot positions and a fused decode
    scan, but each slot owns a contiguous (cache_len, ...) KV slice and
    prefill admits exactly one request per call."""

    def __init__(self, cfg, params, batch_slots: int, cache_len: int,
                 rng: Optional[jax.Array] = None, max_chunk: int = 8):
        self.cfg = cfg
        self.params = params
        self.batch_slots = batch_slots
        self.cache_len = cache_len
        self.max_chunk = max_chunk
        self.rng = rng
        self.bucketable = not needs_exact_prefill(cfg)

        self.slots: List[Optional[Request]] = [None] * batch_slots
        cache = init_cache(cfg, batch_slots, cache_len)
        cache.pop("pos")
        self.cache = cache
        self.pos = jnp.zeros((batch_slots,), jnp.int32)
        self.last_token = jnp.zeros((batch_slots,), jnp.int32)
        self.finished: List[Request] = []

        self.decode_calls = 0
        self.decode_steps = 0
        self.host_transfer_bytes = 0
        self.prefill_calls = 0
        self.prefill_rows = 0

        self._prefill_fns: Dict[int, object] = {}
        self._decode_fns: Dict[int, object] = {}
        self._insert_fn = jax.jit(self._insert_impl)
        self._kv_bytes = sum(
            leaf.size * leaf.dtype.itemsize
            for key, leaf in _kv_leaves(self.cache)
        )

    def kv_bytes_in_use(self) -> int:
        """The whole slots x cache_len allocation backs every admission."""
        return self._kv_bytes

    def live_tokens(self) -> int:
        return sum(len(r.prompt) + len(r.out) for r in self.slots
                   if r is not None)

    def _next_key(self):
        if self.rng is None:
            return None
        self.rng, key = jax.random.split(self.rng)
        return key

    @property
    def active(self) -> int:
        return sum(1 for s in self.slots if s is not None)

    def admit_pending(self, pending: List[Request]) -> List[Request]:
        admitted = []
        while pending and self.admit(pending[0]):
            admitted.append(pending.pop(0))
        return admitted

    def admit(self, req: Request) -> bool:
        free = next((i for i, s in enumerate(self.slots) if s is None), None)
        if free is None:
            return False
        if req.t_submit is None:
            req.t_submit = time.perf_counter()
        length = len(req.prompt)
        if length + req.max_new - 1 > self.cache_len:
            raise ValueError(
                f"prompt ({length}) + max_new ({req.max_new}) exceeds "
                f"cache_len ({self.cache_len})")
        bucket = prefill_bucket(length, self.bucketable, self.cache_len)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :length] = req.prompt
        pf = self._prefill_fns.get(bucket)
        if pf is None:
            pf = self._prefill_fns[bucket] = self._make_prefill()
        tok0, cache1 = pf(self.params, jnp.asarray(toks),
                          jnp.asarray([length], jnp.int32), self._next_key())
        self.cache, self.last_token, self.pos = self._insert_fn(
            self.cache, {k: v for k, v in cache1.items() if k != "pos"},
            jnp.asarray(free, jnp.int32), tok0[0],
            jnp.asarray(length, jnp.int32), self.last_token, self.pos,
        )
        self.prefill_calls += 1
        self.prefill_rows += 1
        self.slots[free] = req
        req.out.append(int(tok0[0]))  # 4-byte sync, once per request (TTFT)
        req.t_first = time.perf_counter()
        if len(req.out) >= req.max_new:
            self._retire(free)
        return True

    def _make_prefill(self):
        cfg, cache_len, bucketable = self.cfg, self.cache_len, self.bucketable

        def pf(params, toks, true_len, key):
            logits, cache1 = prefill(
                params, cfg, toks, cache_len=cache_len, rng=key,
                true_len=true_len if bucketable else None,
            )
            tok0 = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            return tok0, cache1

        return jax.jit(pf)

    def _insert_impl(self, cache, cache1, slot, tok0, length, last_token, pos):
        n_slots = self.batch_slots

        def put(batched, single):
            if getattr(batched, "ndim", 0) == 0:
                return batched
            for axis in range(batched.ndim):
                if (batched.shape[axis] == n_slots
                        and single.shape[axis] == 1):
                    starts = [0] * batched.ndim
                    starts[axis] = slot
                    return jax.lax.dynamic_update_slice(
                        batched, single.astype(batched.dtype), tuple(starts)
                    )
            return batched

        new_cache = jax.tree_util.tree_map(put, cache, cache1)
        return (new_cache, last_token.at[slot].set(tok0),
                pos.at[slot].set(length))

    def _retire(self, i: int):
        req = self.slots[i]
        req.done = True
        self.slots[i] = None
        self.finished.append(req)

    def next_chunk(self) -> int:
        rem = [r.max_new - len(r.out) for r in self.slots if r is not None]
        if not rem:
            return 0
        cap = min(min(rem), self.max_chunk)
        t = 1
        while t * 2 <= cap:
            t *= 2
        return t

    def _make_decode(self, n_steps: int):
        cfg = self.cfg

        def chunk(params, cache, last_tok, pos, active, key):
            def step(carry, t):
                cache, tok, pos = carry
                k = None if key is None else jax.random.fold_in(key, t)
                logits, new_cache = decode_step(
                    params, cfg, tok, dict(cache, pos=pos), rng=k
                )
                nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
                nxt = jnp.where(active, nxt, tok)
                new_pos = jnp.where(active, pos + 1, pos)
                new_cache.pop("pos")
                return (new_cache, nxt, new_pos), nxt

            (cache, tok, pos), toks = jax.lax.scan(
                step, (cache, last_tok, pos), jnp.arange(n_steps)
            )
            return cache, tok, pos, toks.T  # (slots, T)

        return jax.jit(chunk)

    def decode_chunk(self, n_steps: Optional[int] = None) -> np.ndarray:
        if n_steps is None:
            n_steps = self.next_chunk()
        if n_steps <= 0:
            return np.zeros((self.batch_slots, 0), np.int32)
        fn = self._decode_fns.get(n_steps)
        if fn is None:
            fn = self._decode_fns[n_steps] = self._make_decode(n_steps)
        active = jnp.asarray(
            np.array([s is not None for s in self.slots]))
        self.cache, self.last_token, self.pos, toks = fn(
            self.params, self.cache, self.last_token, self.pos, active,
            self._next_key(),
        )
        block = np.asarray(toks)
        self.decode_calls += 1
        self.decode_steps += n_steps
        self.host_transfer_bytes += block.nbytes
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            take = min(n_steps, req.max_new - len(req.out))
            req.out.extend(int(t) for t in block[i, :take])
            if len(req.out) >= req.max_new:
                self._retire(i)
        return block


def _kv_leaves(tree, prefix=""):
    """Yield (name, leaf) for attention KV leaves ("k"/"v") in a cache tree."""
    if isinstance(tree, dict):
        for key, sub in tree.items():
            if key in ("k", "v", "pk", "pv") and hasattr(sub, "size"):
                yield f"{prefix}{key}", sub
            elif isinstance(sub, dict):
                yield from _kv_leaves(sub, f"{prefix}{key}.")


def drive_engine(engine, requests: List[Request], sample=None) -> List[Request]:
    """Bench drive loop shared by both engines (same admit_pending /
    decode_chunk / finished interface); ``sample`` observes the engine after
    every decode chunk (KV utilization)."""
    pending = list(requests)
    while pending or engine.active:
        engine.admit_pending(pending)
        engine.decode_chunk()
        if sample is not None:
            sample(engine)
    return engine.finished


# ---------------------------------------------------------------------------
# harness
# ---------------------------------------------------------------------------


# the first-class substrates the bench executes on (string flags retired)
_SUBSTRATES = {"imc_analytic": AnalyticIMC, "imc_bitserial": BitSerialIMC}


def _mk_cfg(mode: Optional[str]):
    cfg = configs.get_smoke(ARCH)
    if mode:
        cfg = cfg.replace(imc=_SUBSTRATES[mode](bx=7, bw=7, v_wl=0.7))
    return cfg


def _mk_requests(cfg, lens, n_requests) -> List[Request]:
    rnp = np.random.default_rng(0)
    return [
        Request(rid=i, prompt=rnp.integers(0, cfg.vocab_size, lens[i % len(lens)]),
                max_new=GEN)
        for i in range(n_requests)
    ]


def _ttft_ms(reqs) -> float:
    vals = [r.ttft for r in reqs if r.ttft is not None]
    return 1e3 * float(np.mean(vals)) if vals else float("nan")


class _KVSampler:
    """Samples KV bytes per token resident in an active slot after every
    decode chunk (the utilization signal paging is supposed to fix)."""

    def __init__(self):
        self.samples: List[float] = []

    def __call__(self, engine):
        live = engine.live_tokens()
        if live > 0:
            self.samples.append(engine.kv_bytes_in_use() / live)

    @property
    def mean(self) -> float:
        return float(np.mean(self.samples)) if self.samples else float("nan")


def _run_engine(engine, cfg, lens, n_requests):
    # the engine object is reused across warmup + measurement so its jit
    # caches stay warm; the perf counters and the finished list restart per
    # run (serve_* return engine.finished - a stale list would count prior
    # runs' tokens against this run's wall time)
    engine.decode_calls = engine.decode_steps = 0
    engine.host_transfer_bytes = 0
    engine.prefill_calls = engine.prefill_rows = 0
    engine.finished = []
    reqs = _mk_requests(cfg, lens, n_requests)
    sampler = _KVSampler()
    t0 = time.perf_counter()
    out = drive_engine(engine, reqs, sample=sampler)
    dt = time.perf_counter() - t0
    tokens = sum(len(r.out) for r in out)
    steps = max(engine.decode_steps, 1)
    return {
        "wall_s": round(dt, 3),
        "tok_s": round(tokens / dt, 1) if dt > 0 else float("nan"),
        "ttft_ms": round(_ttft_ms(out), 1),
        "tokens": tokens,
        "host_syncs_per_token": round(engine.decode_calls / steps, 3),
        "sync_bytes_per_token": round(
            engine.host_transfer_bytes / max(tokens, 1), 1),
        # only the (slots, T) int32 token block leaves the fused scan
        "jit_out_bytes_per_tick": round(
            engine.host_transfer_bytes / max(engine.decode_steps, 1), 1),
        "decode_chunks": engine.decode_calls,
        "decode_steps": engine.decode_steps,
        "prefill_calls": engine.prefill_calls,
        "prefill_rows": engine.prefill_rows,
        "kv_bytes_per_active_token": round(sampler.mean, 1),
    }


def _run_wave(cfg, rng, cache_len, n_requests):
    server = WaveServer(cfg, init_params(jax.random.PRNGKey(0), cfg),
                        BATCH, cache_len, rng=rng)
    reqs = _mk_requests(cfg, [PROMPT_LEN], n_requests)
    t0 = time.perf_counter()
    out = _serve_wave(server, reqs)
    dt = time.perf_counter() - t0
    tokens = sum(len(r.out) for r in out)
    return {
        "wall_s": round(dt, 3),
        "tok_s": round(tokens / dt, 1) if dt > 0 else float("nan"),
        "ttft_ms": round(_ttft_ms(out), 1),
        "tokens": tokens,
        "host_syncs_per_token": 1.0,
        "sync_bytes_per_token": round(server.sync_bytes / max(tokens, 1), 1),
        # the (slots, 1, vocab) f32 logits leave the jitted step every tick
        "jit_out_bytes_per_tick": BATCH * cfg.padded_vocab * 4,
    }


def _engines_for(cfg, rng, cache_len):
    params = init_params(jax.random.PRNGKey(0), cfg)
    cont = ContiguousEngine(cfg, params, BATCH, cache_len, rng=rng,
                            max_chunk=GEN)
    paged = Engine(cfg, params, BATCH, cache_len, rng=rng, max_chunk=GEN)
    return cont, paged


def bench_records() -> List[dict]:
    records: List[dict] = []
    # mixed workload: contiguous must size every slot for the longest prompt
    max_bucket = max(prefill_bucket(l, True, 10**9) for l in MIXED_LENS)
    cache_len = max_bucket + GEN + 8
    for mode, n_requests in MODES.items():
        cfg = _mk_cfg(mode)
        rng = jax.random.PRNGKey(7) if mode else None
        meta = {"bench": "serve", "arch": ARCH, "mode": mode or "digital",
                "substrate": mode or "digital",
                "slots": BATCH, "requests": n_requests,
                "prompt_lens": MIXED_LENS[:n_requests], "gen": GEN}
        # warmup both engines (compile time excluded, as in kernel_bench)
        cont, paged = _engines_for(cfg, rng, cache_len)
        _run_engine(cont, cfg, MIXED_LENS, n_requests)
        _run_engine(paged, cfg, MIXED_LENS, n_requests)
        # best-of-REPEATS per engine: CPU wall times on shared boxes swing
        # ~2x run to run; the structural counters are identical across runs
        cont_rec = max(
            (_run_engine(cont, cfg, MIXED_LENS, n_requests)
             for _ in range(REPEATS)), key=lambda r: r["tok_s"])
        paged_rec = max(
            (_run_engine(paged, cfg, MIXED_LENS, n_requests)
             for _ in range(REPEATS)), key=lambda r: r["tok_s"])
        # schema v2.4: every serve record names its decode-attention path -
        # "dense" for the contiguous/wave baselines (full-cache attention),
        # cfg.decode_attn for the paged engine (fused kernel vs gather)
        records.append({**meta, "config": "contiguous_engine",
                        "decode_attn": "dense", **cont_rec})
        records.append({**meta, "config": "paged_engine",
                        "decode_attn": cfg.decode_attn, **paged_rec})
        records.append({
            **meta, "bench": "serve_summary",
            "speedup_tok_s": round(paged_rec["tok_s"] / cont_rec["tok_s"], 2)
            if cont_rec["tok_s"] else float("nan"),
            "ttft_ratio": round(paged_rec["ttft_ms"] / cont_rec["ttft_ms"], 2)
            if cont_rec["ttft_ms"] else float("nan"),
            "kv_reduction": round(
                cont_rec["kv_bytes_per_active_token"]
                / paged_rec["kv_bytes_per_active_token"], 2),
            "kv_bytes_per_active_token_before":
                cont_rec["kv_bytes_per_active_token"],
            "kv_bytes_per_active_token_after":
                paged_rec["kv_bytes_per_active_token"],
            "prefill_calls_before": cont_rec["prefill_calls"],
            "prefill_calls_after": paged_rec["prefill_calls"],
        })
    # seed wave server reference (equal prompts - it cannot run mixed lengths)
    cfg = _mk_cfg(None)
    wave_cache_len = 2 * PROMPT_LEN + GEN + 8
    _run_wave(cfg, None, wave_cache_len, WARMUP_REQUESTS)
    wave = _run_wave(cfg, None, wave_cache_len, REQUESTS)
    records.append({"bench": "serve", "arch": ARCH, "mode": "digital",
                    "substrate": "digital",
                    "config": "wave_baseline", "slots": BATCH,
                    "requests": REQUESTS, "prompt_len": PROMPT_LEN,
                    "gen": GEN, "decode_attn": "dense", **wave})
    records.extend(drift_records())
    records.extend(slo_records())
    return records


# ---------------------------------------------------------------------------
# SLO overload scenario (scheduling + preemption + frontier degradation)
# ---------------------------------------------------------------------------

# the committed overload scenario: a seeded bursty workload offered at 2x the
# engine's service rate, on the frozen imc_analytic substrate at the QR
# high-SNR frontier point (ladder level 0 for the PressureController).  Every
# gated field is a deterministic function of (seed, overload, kv_blocks):
# time is virtual (runtime.workload.VirtualClock), so no wall clock leaks
# into the record.
SLO_SEED = 0
SLO_REQUESTS = 32
SLO_OVERLOAD = 2.0
SLO_ARRIVAL = "bursty"
# 10 usable blocks for 4 slots: tight enough that lazy growth must preempt
# under the burst, ample enough that worst-case reservation still admits
SLO_KV_BLOCKS = 11
SLO_RUNS = (
    # (config id, policy, alloc, degrade): A = status-quo baseline,
    # B = the full overload-resilience stack, C = isolates the lazy-alloc
    # utilization win from scheduling effects
    ("fifo_reserve", "fifo", "reserve", False),
    ("deadline_lazy_degrade", "deadline", "lazy", True),
    ("fifo_lazy", "fifo", "lazy", False),
)


def _slo_frozen():
    """Frozen imc_analytic smoke engine config at the committed QR frontier
    point (same freeze recipe as drift_records: rng(1) reference batch)."""
    from repro.core.substrate import calibrate_model

    pt = optimize(n=ENERGY_N, snr_t_target_db=ENERGY_SNR_HIGH, kinds=("qr",))
    cfg = configs.get_smoke(ARCH).replace(imc=substrate_for_design(pt))
    params = init_params(jax.random.PRNGKey(0), cfg)
    ref = np.random.default_rng(1).integers(0, cfg.vocab_size, (2, 24))
    return pt, calibrate_model(cfg, params, [ref]), params


def slo_records(seed: Optional[int] = None) -> List[dict]:
    """Goodput / latency / shed / preempt / degrade scoreboard for the three
    committed runs on identical seeded 2x-overload bursty traffic.

    The acceptance invariants (gated in ``check_regression`` and pinned by
    ``test_bench_schema``): the full stack (B) achieves strictly higher
    goodput than the FIFO+reserve baseline (A) with zero engine deaths and
    exact request conservation; lazy allocation alone (C) raises pool
    utilization over worst-case reservation (A)."""
    from repro.core.substrate import substrate_ladder
    from repro.launch.metering import slo_summary
    from repro.launch.scheduler import PressureController, make_policy
    from repro.launch.serve import serve_slo
    from repro.runtime.workload import (
        VirtualClock,
        generate,
        make_overload_config,
    )

    if seed is None:
        seed = SLO_SEED  # resolved late: run.py --workload-seed overrides
    pt, cfg, params = _slo_frozen()
    wcfg = make_overload_config(
        n_requests=SLO_REQUESTS, seed=seed, overload=SLO_OVERLOAD,
        slots=BATCH, max_new=GEN, arrival=SLO_ARRIVAL)
    records: List[dict] = []
    by_config: Dict[str, dict] = {}
    for config, policy_name, alloc, degrade in SLO_RUNS:
        reqs = generate(wcfg, cfg.vocab_size)
        engine = Engine(cfg, params, BATCH, 32 + GEN + 8, max_chunk=4,
                        kv_blocks=SLO_KV_BLOCKS, alloc_policy=alloc,
                        clock=VirtualClock())
        controller = (PressureController(engine,
                                         substrate_ladder(pt, steps=2))
                      if degrade else None)
        policy = make_policy(policy_name)
        deaths = 0
        try:
            finished = serve_slo(engine, reqs, policy=policy,
                                 controller=controller)
        except Exception:  # an engine death is a GATED failure, not a crash
            deaths = 1
            finished = engine.finished
        conserved = (len(finished) == SLO_REQUESTS and sorted(
            r.rid for r in finished) == list(range(SLO_REQUESTS)))
        summary = slo_summary(finished, elapsed=engine.clock.now,
                              policy=policy.name)
        rec = {
            "bench": "serve_slo", "arch": ARCH, "mode": "imc_analytic",
            "substrate": "imc_analytic", "config": config,
            "policy": policy.name, "alloc": alloc, "degrade": degrade,
            "workload_seed": seed, "overload": SLO_OVERLOAD,
            "arrival": SLO_ARRIVAL, "slots": BATCH,
            "requests": SLO_REQUESTS, "gen": GEN,
            "kv_blocks": SLO_KV_BLOCKS,
            **{k: (round(v, 4) if isinstance(v, float) else v)
               for k, v in summary.items() if k != "policy"},
            "preempt_count": engine.preempt_count,
            "substrate_swaps": engine.substrate_swaps,
            "degrade_steps": (controller.degrade_steps if controller else 0),
            "upgrade_steps": (controller.upgrade_steps if controller else 0),
            "pool_utilization": round(engine.pool_utilization(), 4),
            "engine_deaths": deaths,
            "conserved": conserved,
        }
        records.append(rec)
        by_config[config] = rec
    a = by_config["fifo_reserve"]
    b = by_config["deadline_lazy_degrade"]
    c = by_config["fifo_lazy"]
    records.append({
        "bench": "serve_slo_summary", "arch": ARCH, "mode": "imc_analytic",
        "substrate": "imc_analytic", "config": "overload_2x",
        "workload_seed": seed, "overload": SLO_OVERLOAD,
        "requests": SLO_REQUESTS, "slots": BATCH,
        "goodput_ratio": round(b["goodput"] / a["goodput"], 4)
        if a["goodput"] else float("nan"),
        "goodput_baseline": a["goodput"],
        "goodput_resilient": b["goodput"],
        "pool_util_gain": round(
            c["pool_utilization"] - a["pool_utilization"], 4),
        "preempt_count": b["preempt_count"],
        "degrade_steps": b["degrade_steps"],
        "shed_total": a["shed"] + b["shed"] + c["shed"],
        "engine_deaths": (a["engine_deaths"] + b["engine_deaths"]
                          + c["engine_deaths"]),
        "conserved": bool(a["conserved"] and b["conserved"]
                          and c["conserved"]),
    })
    return records


# ---------------------------------------------------------------------------
# drift-injection serve scenario (shadow calibration -> detect -> hot-swap)
# ---------------------------------------------------------------------------

DRIFT_SCALE = 2.5  # injected weight-scale shift on every mlp.wi
DRIFT_REQUESTS = 6  # half served clean, half after the injected shift


def drift_records() -> List[dict]:
    """Serve calibrated traffic, inject an ``mlp.wi`` weight-scale shift
    mid-stream, and record how the shadow-calibration loop behaves: chunks
    to detection (vs the cadence bound), hot-swap count, and the worst
    post-swap SNR_T gap to a fresh-frozen reference (schema v2.2: the
    acceptance invariant is ``recovery_gap_db_max <= 1``).  Every recorded
    field is a deterministic function of the request schedule and the
    injected scale - no wall clock.

    The shift is injected into the weights (not the embedding): the model
    is pre-norm, so an embedding-scale shift would be normalized away
    before every matmul site and no drift would ever reach the quantizers.
    """
    from repro.core.substrate import as_substrate, calibrate_model
    from repro.runtime import drift as drift_lib

    cfg_dyn = _mk_cfg("imc_analytic")
    params = init_params(jax.random.PRNGKey(0), cfg_dyn)
    ref = np.random.default_rng(1).integers(0, cfg_dyn.vocab_size, (4, 24))
    cfg = calibrate_model(cfg_dyn, params, [ref])
    sub = as_substrate(cfg.imc)
    # rel_excess bounds the post-swap gap to a fresh-frozen reference:
    # residual excess below the re-flag threshold never swaps again, so the
    # drifted-site gap is at most 20*log10(1 + rel_excess) = 0.83 dB here -
    # the structural guarantee behind the 1 dB acceptance ceiling
    mon = drift_lib.DriftMonitor(drift_lib.DriftConfig(
        sample_every=1, check_every=1,
        thresholds=drift_lib.DriftThresholds(rel_excess=0.1, clip_rate=0.05)))
    max_bucket = max(prefill_bucket(l, True, 10**9) for l in MIXED_LENS)
    engine = Engine(cfg, params, BATCH, max_bucket + GEN + 8, max_chunk=GEN,
                    drift_monitor=mon)
    reqs = _mk_requests(cfg, MIXED_LENS, DRIFT_REQUESTS)
    half = DRIFT_REQUESTS // 2
    drive_engine(engine, reqs[:half])
    clean_events = mon.drift_events
    chunks_clean = mon.chunks_seen

    def scale_wi(p):
        if isinstance(p, dict):
            return {k: (v * DRIFT_SCALE if k == "wi" else scale_wi(v))
                    for k, v in p.items()}
        return p

    engine.params = scale_wi(engine.params)
    drive_engine(engine, reqs[half:])

    rows = drift_lib.site_snr_table(sub.calibration, engine._calib,
                                    mon.last_observed, bx=sub.imc.bx)
    # drifted = observed range EXCEEDED the frozen one (the one-sided test's
    # direction); sites whose frozen range merely over-provisions live
    # traffic carry a static q-noise gap the monotone merge can never shrink
    # - that's calibration conservatism, not drift, and is not gated here
    drifted = [r for r in rows if r["x_max_observed"] > r["x_max_frozen"]]
    detected = mon.first_drift_chunk is not None
    return [{
        "bench": "serve_drift", "arch": ARCH, "mode": "imc_analytic",
        "substrate": "imc_analytic", "config": "paged_engine_drift",
        "slots": BATCH, "requests": DRIFT_REQUESTS, "gen": GEN,
        "inject_scale": DRIFT_SCALE,
        "drift_detected": detected,
        "false_positives_clean": clean_events,
        "chunks_to_detect": (mon.first_drift_chunk - chunks_clean
                             if detected else -1),
        "detection_bound_chunks": (mon.cfg.sample_every
                                   * mon.cfg.check_every + 1),
        "swaps": engine.swap_count,
        "shadow_samples": mon.samples,
        "sites_drifted": len(drifted),
        "degradation_db_max": round(max(
            (r["degradation_db"] for r in drifted), default=0.0), 3),
        "recovery_gap_db_max": round(max(
            (abs(r["recovery_gap_db"]) for r in drifted), default=0.0), 3),
        "failed_requests": engine.failed_requests,
    }]


# ---------------------------------------------------------------------------
# serve-path energy-delay accounting (J/token per design point)
# ---------------------------------------------------------------------------

# two SNR_T targets bracketing the serving EDAP frontier: at ENERGY_SNR_LOW
# every substrate (QS/QR/CM) still meets the target, at ENERGY_SNR_HIGH only
# QR remains feasible - the serve-workload form of the paper's "QS-based at
# low compute SNR, QR-based at high" guideline (QS's 512-row points cap out
# near 18-19 dB SNR_T; see core.design)
ENERGY_SNR_LOW = 14.0
ENERGY_SNR_HIGH = 26.0
ENERGY_N = 512  # the paper's 512-row SRAM bank


def _meter_workload() -> Tuple[DPMeter, int, int]:
    """Serve the standard mixed 4..48-token workload once (digital smoke
    model - the billed schedule is a pure function of the request stream) with
    a DPMeter attached, billing the FULL ``musicgen-medium`` matmul sites so
    the rollup reports deployment-scale energy on the real traffic pattern."""
    cfg = _mk_cfg(None)
    sites = per_token_matmul_shapes(configs.get(ARCH))
    meter = DPMeter(sites=sites)
    max_bucket = max(prefill_bucket(l, True, 10**9) for l in MIXED_LENS)
    cache_len = max_bucket + GEN + 8
    params = init_params(jax.random.PRNGKey(0), cfg)
    engine = Engine(cfg, params, BATCH, cache_len, max_chunk=GEN, meter=meter)
    drive_engine(engine, _mk_requests(cfg, MIXED_LENS, REQUESTS))
    generated = sum(len(r.out) for r in engine.finished)
    return meter, generated, len(engine.finished)


_ENERGY_CACHE: List[dict] = []


def energy_records() -> List[dict]:
    """J/token, J/request, EDP/token per substrate x design point on the
    metered serve workload, plus per-target frontier summaries and the
    QS-vs-QR crossover record (deterministic - no wall-clock timing).

    Single home: these records exist ONLY under the ``serve_energy`` suite
    (``run.py`` expands ``--only serve`` to include it, so the serve bench
    surface still reports energy).  Memoized per process.  NOTE the suite is
    committed in both ``BENCH_serve.json`` (via the expansion) and
    ``BENCH_energy.json`` - regenerate the two baselines together after any
    rollup change, or the regression gate will flag the stale one."""
    if _ENERGY_CACHE:
        return copy.deepcopy(_ENERGY_CACHE)
    meter, generated, n_requests = _meter_workload()
    meta = {"bench": "serve_energy", "arch": ARCH, "slots": BATCH,
            "requests": n_requests, "gen": GEN,
            "prompt_lens": MIXED_LENS, "bank_rows": ENERGY_N}
    records: List[dict] = []
    frontier: Dict[float, Dict[str, dict]] = {}
    for snr_db in (ENERGY_SNR_LOW, ENERGY_SNR_HIGH):
        per_kind: Dict[str, dict] = {}
        for kind in ("qs", "qr", "cm"):
            pt = optimize(n=ENERGY_N, snr_t_target_db=snr_db, kinds=(kind,))
            if pt is None:
                continue
            # bill through the executable substrate the design point
            # implies: the rollup reads the billed design (and any per-site
            # overrides) from the substrate object itself (schema v2.1:
            # every serve record names its substrate)
            rep = serve_energy_report(meter,
                                      substrate=substrate_for_design(pt),
                                      generated_tokens=generated,
                                      requests=n_requests)
            rec = {**meta, "snr_t_target_db": snr_db, "kind": kind,
                   **{k: v for k, v in rep.summary().items()
                      if k != "arch_kind"}}
            per_kind[kind] = rec
            records.append(rec)
        frontier[snr_db] = per_kind
        if per_kind:
            best_e = min(per_kind, key=lambda k: per_kind[k]["j_per_token"])
            best_edp = min(per_kind, key=lambda k: per_kind[k]["edp_per_token"])
            records.append({
                **meta, "bench": "serve_energy_summary",
                "substrate": "mixed",  # aggregates across substrates
                "snr_t_target_db": snr_db,
                "kinds_feasible": sorted(per_kind),
                "best_kind_energy": best_e,
                "best_kind_edp": best_edp,
                "j_per_token_best": per_kind[best_e]["j_per_token"],
                "edp_per_token_best": per_kind[best_edp]["edp_per_token"],
            })
    lo, hi = frontier[ENERGY_SNR_LOW], frontier[ENERGY_SNR_HIGH]
    records.append({
        **meta, "bench": "serve_energy_crossover",
        "substrate": "mixed",  # aggregates across substrates
        "snr_low_db": ENERGY_SNR_LOW, "snr_high_db": ENERGY_SNR_HIGH,
        # the crossover as it manifests in this calibration: QS serves the
        # low-SNR side of the frontier only (feasible at the low target,
        # absent at the high one); QR alone spans the high-SNR side
        "qs_feasible_low": "qs" in lo,
        "qs_feasible_high": "qs" in hi,
        "best_kind_high": min(hi, key=lambda k: hi[k]["j_per_token"]) if hi
        else None,
        "crossover": ("qs" in lo) and ("qs" not in hi)
        and bool(hi) and min(hi, key=lambda k: hi[k]["j_per_token"]) == "qr",
    })
    # per-site SNR_T map of the MPC-style override substrate vs the uniform
    # design point (deterministic closed forms; see benchmarks/layer_snr.py)
    from benchmarks.layer_snr import site_snr_records

    records.extend(site_snr_records(arch=ARCH, snr_t_db=ENERGY_SNR_LOW,
                                    n=ENERGY_N))
    _ENERGY_CACHE.extend(copy.deepcopy(records))
    return records


# ---------------------------------------------------------------------------
# prefix-sharing paged KV suite (shared-system-prompt traffic, warm vs cold)
# ---------------------------------------------------------------------------

PREFIX_LEN = 16  # shared system-prompt tokens: two full 8-token KV blocks
PREFIX_DUP = 4  # requests sharing each system prompt on average
PREFIX_REQUESTS = 12
PREFIX_SEED = 0
# digital + frozen imc_analytic: bit-identity across all three substrates
# (incl. the ~30x-slower bitserial path) is pinned by
# tests/test_prefix_cache.py; the bench keeps inside the CI budget
PREFIX_MODES = (None, "imc_analytic")


def _prefix_requests(cfg) -> List[Request]:
    """The committed shared-system-prompt draw: ``runtime.workload`` builds
    prompts, stop lengths and the per-class prefix pools from ONE seeded
    stream; arrival times are dropped (``drive_engine`` serves open loop) and
    rid order is kept, so the warm and cold engines see the identical
    schedule and greedy outputs compare token for token."""
    from repro.runtime.workload import WorkloadConfig, generate

    wcfg = WorkloadConfig(n_requests=PREFIX_REQUESTS, seed=PREFIX_SEED,
                          max_new=GEN, prefix_len=PREFIX_LEN,
                          prefix_dup=PREFIX_DUP)
    return [Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new,
                    stop_at=r.stop_at)
            for r in generate(wcfg, cfg.vocab_size)]


def _prefix_run(cfg, params, cache_len, enable):
    """One serve pass over the shared-prefix workload: fresh engine + meter
    (billing the full ``musicgen-medium`` sites), KV utilization sampled
    after every decode chunk; returns (engine, meter, kv_mean, out-by-rid)."""
    meter = DPMeter(sites=per_token_matmul_shapes(configs.get(ARCH)))
    engine = Engine(cfg, params, BATCH, cache_len, max_chunk=GEN,
                    meter=meter, prefix_cache=enable)
    sampler = _KVSampler()
    done = drive_engine(engine, _prefix_requests(cfg), sample=sampler)
    return engine, meter, sampler.mean, {r.rid: list(r.out) for r in done}


def prefix_records() -> List[dict]:
    """Prefix-sharing warm engine vs cold-cache engine on identical seeded
    shared-system-prompt traffic, per substrate.

    The acceptance invariants (gated in ``check_regression`` and pinned by
    ``test_bench_schema``): greedy outputs bit-identical to the cold run
    (``token_match``), a strictly positive hit rate, and a strictly positive
    billed-prefill-energy saving (``j_per_token_saved`` - the J/token the
    cache's skipped prefill dot-products would have cost at the committed
    low-SNR QR design point).  The prefix counters are structural (pure
    functions of the seeded schedule) and gate exactly."""
    from repro.core.substrate import calibrate_model

    pt = optimize(n=ENERGY_N, snr_t_target_db=ENERGY_SNR_LOW, kinds=("qr",))
    records: List[dict] = []
    for mode in PREFIX_MODES:
        cfg = _mk_cfg(mode)
        params = init_params(jax.random.PRNGKey(0), cfg)
        if mode:
            # freeze calibration (drift_records' rng(1) reference batch):
            # warm-vs-cold identity needs one fixed quantization map
            ref = np.random.default_rng(1).integers(0, cfg.vocab_size,
                                                    (2, 24))
            cfg = calibrate_model(cfg, params, [ref])
        lens = [len(r.prompt) for r in _prefix_requests(cfg)]
        cache_len = max(prefill_bucket(l, True, 10**9)
                        for l in lens) + GEN + 8
        cold, meter_c, kv_cold, toks_cold = _prefix_run(
            cfg, params, cache_len, enable=False)
        warm, meter_w, kv_warm, toks_warm = _prefix_run(
            cfg, params, cache_len, enable=True)
        stats = warm.prefix_stats()
        sub = substrate_for_design(pt)
        rep_w = serve_energy_report(
            meter_w, substrate=sub,
            generated_tokens=sum(len(t) for t in toks_warm.values()),
            requests=len(toks_warm))
        rep_c = serve_energy_report(
            meter_c, substrate=sub,
            generated_tokens=sum(len(t) for t in toks_cold.values()),
            requests=len(toks_cold))
        records.append({
            "bench": "serve_prefix", "arch": ARCH,
            "mode": mode or "digital", "substrate": mode or "digital",
            "config": "prefix_engine", "slots": BATCH,
            "requests": PREFIX_REQUESTS, "gen": GEN,
            "prefix_len": PREFIX_LEN, "prefix_dup": PREFIX_DUP,
            "workload_seed": PREFIX_SEED,
            "snr_t_target_db": ENERGY_SNR_LOW, "kind": "qr",
            "token_match": toks_warm == toks_cold,
            "prefix_lookups": stats["lookups"],
            "prefix_hits": stats["hits"],
            "hit_rate": stats["hit_rate"],
            "prefix_hit_tokens": stats["hit_tokens"],
            "saved_billed_tokens": stats["saved_billed_tokens"],
            "cow_copies": stats["cow_copies"],
            "prefix_evictions": stats["evictions"],
            "cached_blocks": stats["cached_blocks"],
            "prefill_calls": warm.prefill_calls,
            "prefill_rows": warm.prefill_rows,
            "prefill_rows_cold": cold.prefill_rows,
            "prefill_tokens": rep_w.prefill_tokens,
            "prefill_tokens_cold": rep_c.prefill_tokens,
            "kv_bytes_per_active_token": round(kv_warm, 1),
            "kv_bytes_per_active_token_cold": round(kv_cold, 1),
            "prefill_j": rep_w.prefill_j,
            "prefill_j_cold": rep_c.prefill_j,
            "j_per_token": rep_w.j_per_token,
            "j_per_token_cold": rep_c.j_per_token,
            "saved_prefill_j": rep_w.saved_prefill_j,
            "j_per_token_saved": rep_w.j_per_token_saved,
        })
    return records


# ---------------------------------------------------------------------------
# tensor-parallel sharded serve (multi-device scaling suite)
# ---------------------------------------------------------------------------

# the committed sharded scenario: the mixed 4..48 workload served by the
# single-device engine and by a (1, 4) tensor-parallel engine (musicgen's 4 KV
# heads head-shard 4 ways: one head per device) inside a child process that
# pins 8 host-simulated devices - so ANY parent (the single-device tier-1 CI
# job included) can produce the suite.  Structural fields (per-device KV
# bytes, greedy-token match) gate exactly; tok/s scaling gates on a generous
# absolute floor because host-simulated CPU "devices" share one physical
# socket (all-reduce overhead without any real parallel silicon).
SHARDED_MESH = "1x4"
SHARDED_DEVICES = 8
# digital + frozen imc_analytic: equivalence across all three substrates
# (incl. the ~30x-slower bitserial path) is pinned by the slow lane in
# tests/test_serve_sharded.py; the bench keeps inside the CI budget
SHARDED_MODES = (None, "imc_analytic")

_SHARDED_CHILD = r"""
import json
import os
import time

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                           + os.environ["REPRO_SHARDED_DEVICES"])
import jax
import numpy as np

from repro import configs
from repro.core import substrate as substrate_lib
from repro.core.imc_linear import IMCConfig
from repro.launch.mesh import make_serve_mesh, parse_mesh_shape
from repro.launch.serve import Engine, Request, prefill_bucket, serve
from repro.models import init_params

ARCH = os.environ["REPRO_SHARDED_ARCH"]
MESH = os.environ["REPRO_SHARDED_MESH"]
DEVICES = int(os.environ["REPRO_SHARDED_DEVICES"])
LENS = [int(x) for x in os.environ["REPRO_SHARDED_LENS"].split(",")]
GEN = int(os.environ["REPRO_SHARDED_GEN"])
BATCH = int(os.environ["REPRO_SHARDED_BATCH"])
REPEATS = int(os.environ["REPRO_SHARDED_REPEATS"])
MODES = os.environ["REPRO_SHARDED_MODES"].split(",")


def mk_requests(cfg, n):
    rnp = np.random.default_rng(0)
    return [Request(rid=i,
                    prompt=rnp.integers(0, cfg.vocab_size,
                                        LENS[i % len(LENS)]),
                    max_new=GEN) for i in range(n)]


def run_once(engine, cfg, n):
    engine.decode_calls = engine.decode_steps = 0
    engine.host_transfer_bytes = 0
    engine.prefill_calls = engine.prefill_rows = 0
    engine.finished = []
    t0 = time.perf_counter()
    out = serve(engine, mk_requests(cfg, n))
    dt = time.perf_counter() - t0
    tokens = sum(len(r.out) for r in out)
    return (tokens / dt if dt > 0 else float("nan"),
            {r.rid: list(r.out) for r in out})


records = []
max_bucket = max(prefill_bucket(l, True, 10 ** 9) for l in LENS)
cache_len = max_bucket + GEN + 8
data_ax, model_ax = parse_mesh_shape(MESH)
for mode in MODES:
    mode = mode or None
    n = len(LENS)
    cfg = configs.get_smoke(ARCH)
    if mode:
        cfg = cfg.replace(imc=substrate_lib.as_substrate(
            IMCConfig(mode=mode, bx=7, bw=7, v_wl=0.7)))
    params = init_params(jax.random.PRNGKey(0), cfg)
    if mode:
        # frozen calibration: batch-composition-invariant IMC forwards (the
        # precondition for sharded == single-device token identity)
        ref = np.random.default_rng(1).integers(
            0, cfg.vocab_size, (2, max(LENS)))
        cfg = substrate_lib.calibrate_model(cfg, params, [ref])
    single = Engine(cfg, params, BATCH, cache_len, max_chunk=GEN)
    run_once(single, cfg, n)  # warmup: compiles excluded from timing
    tok_s_single, toks_single = max(
        (run_once(single, cfg, n) for _ in range(REPEATS)),
        key=lambda t: t[0])
    mesh = make_serve_mesh(data_ax, model_ax)
    sharded = Engine(cfg, params, BATCH, cache_len, max_chunk=GEN, mesh=mesh)
    run_once(sharded, cfg, n)
    tok_s_sharded, toks_sharded = max(
        (run_once(sharded, cfg, n) for _ in range(REPEATS)),
        key=lambda t: t[0])
    records.append({
        "bench": "serve_sharded", "arch": ARCH, "config": "tp_engine",
        "mode": mode or "digital", "substrate": mode or "digital",
        "decode_attn": sharded.cfg.decode_attn,
        "mesh_shape": MESH, "devices": DEVICES,
        "slots": BATCH, "requests": n, "prompt_lens": LENS[:n], "gen": GEN,
        "tok_s_single": round(tok_s_single, 1),
        "tok_s_sharded": round(tok_s_sharded, 1),
        "scaling_tok_s_ratio": round(tok_s_sharded / tok_s_single, 3),
        "kv_shard_ways": sharded.tp if sharded.kv_shard else 1,
        "kv_bytes_per_device": sharded.kv_pool_bytes_per_device(),
        "kv_bytes_total": sharded.kv_pool_bytes(),
        "token_match": toks_sharded == toks_single,
    })
print("SHARDED_JSON " + json.dumps(records))
"""


def sharded_records() -> List[dict]:
    """Run the sharded-vs-single-device comparison in a child process that
    forces ``SHARDED_DEVICES`` host devices (XLA pins the device count at
    backend init, so the parent's count - 1 in tier-1 CI - cannot be
    changed in-process)."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)  # the child pins its own device count
    env["PYTHONPATH"] = os.path.join(root, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env.update(
        REPRO_SHARDED_ARCH=ARCH,
        REPRO_SHARDED_MESH=SHARDED_MESH,
        REPRO_SHARDED_DEVICES=str(SHARDED_DEVICES),
        REPRO_SHARDED_LENS=",".join(str(l) for l in MIXED_LENS),
        REPRO_SHARDED_GEN=str(GEN),
        REPRO_SHARDED_BATCH=str(BATCH),
        REPRO_SHARDED_REPEATS=str(REPEATS),
        REPRO_SHARDED_MODES=",".join(m or "" for m in SHARDED_MODES),
    )
    proc = subprocess.run([sys.executable, "-c", _SHARDED_CHILD],
                          capture_output=True, text=True, env=env, cwd=root,
                          timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError(
            "sharded serve child failed:\n--- stdout ---\n"
            f"{proc.stdout[-2000:]}\n--- stderr ---\n{proc.stderr[-2000:]}")
    lines = [ln for ln in proc.stdout.splitlines()
             if ln.startswith("SHARDED_JSON ")]
    return json.loads(lines[-1][len("SHARDED_JSON "):])


def sharded_rows(records: List[dict]) -> List[Row]:
    rows: List[Row] = []
    for r in records:
        if r["bench"] != "serve_sharded":
            continue
        rows.append((
            f"serve_sharded/{r['substrate']}_mesh{r['mesh_shape']}",
            r["scaling_tok_s_ratio"],
            f"tok/s vs 1-device ({r['tok_s_single']}->{r['tok_s_sharded']}); "
            f"kv_B/dev={r['kv_bytes_per_device']} of {r['kv_bytes_total']} "
            f"({r['kv_shard_ways']}-way heads) "
            f"token_match={r['token_match']}",
        ))
    return rows


def energy_rows(records: List[dict]) -> List[Row]:
    rows: List[Row] = []
    for r in records:
        if r["bench"] == "serve_energy":
            rows.append((
                f"serve_energy/{r['kind']}_snr{int(r['snr_t_target_db'])}",
                r["j_per_token"],
                f"J/token; J/req={r['j_per_request']:.3e} "
                f"EDP/tok={r['edp_per_token']:.3e} "
                f"tok/s(compute)={r['tok_s_compute']:.3e} "
                f"b_adc={r['b_adc']} n_banks={r['n_banks']}",
            ))
        elif r["bench"] == "serve_energy_summary":
            rows.append((
                f"serve_energy/summary_snr{int(r['snr_t_target_db'])}",
                r["j_per_token_best"],
                f"best J/token ({r['best_kind_energy']}); "
                f"best EDP kind={r['best_kind_edp']} "
                f"feasible={'/'.join(r['kinds_feasible'])}",
            ))
        elif r["bench"] == "serve_energy_crossover":
            rows.append((
                "serve_energy/qs_qr_crossover",
                1.0 if r["crossover"] else 0.0,
                f"qs@low={r['qs_feasible_low']} qs@high={r['qs_feasible_high']} "
                f"best@high={r['best_kind_high']}",
            ))
        elif r["bench"] == "site_snr":
            rows.append((
                f"site_snr/{r['arch']}/{r['name']}",
                r["snr_t_override_db"],
                f"SNR_T dB w/ per-site override (uniform "
                f"{r['snr_t_uniform_db']} dB, B_ADC "
                f"{r['b_adc_uniform']}->{r['b_adc_override']})",
            ))
        elif r["bench"] == "site_snr_summary":
            rows.append((
                f"site_snr/{r['arch']}/summary",
                r["j_per_token_ratio"],
                f"J/token cost of boosting {r['sites_boosted']}/{r['sites']} "
                f"sites; min boosted SNR_T {r['snr_t_boosted_min_db']} dB "
                f"vs uniform {r['snr_t_uniform_db']} dB",
            ))
    return rows


def rows_from_records(records: List[dict]) -> List[Row]:
    rows: List[Row] = []
    energy = [r for r in records if r["bench"].startswith("serve_energy")]
    for r in records:
        if r["bench"].startswith("serve_energy"):
            continue
        tag = f"{r['mode']}_b{r['slots']}"
        if r["bench"] == "serve_summary":
            rows.append((
                f"serve/summary_{tag}",
                r["kv_reduction"],
                f"kv B/active-tok reduction "
                f"{r['kv_bytes_per_active_token_before']}->"
                f"{r['kv_bytes_per_active_token_after']}; "
                f"tok/s ratio {r['speedup_tok_s']} "
                f"prefill calls {r['prefill_calls_before']}->"
                f"{r['prefill_calls_after']}",
            ))
        elif r["bench"] == "serve_drift":
            rows.append((
                f"serve/drift_{tag}",
                r["recovery_gap_db_max"],
                f"dB worst post-swap gap to fresh-frozen; "
                f"detected={r['drift_detected']} in "
                f"{r['chunks_to_detect']} chunks "
                f"(bound {r['detection_bound_chunks']}) "
                f"swaps={r['swaps']} sites_drifted={r['sites_drifted']} "
                f"degradation={r['degradation_db_max']}dB",
            ))
        elif r["bench"] == "serve_slo":
            rows.append((
                f"serve/slo_{r['config']}_{tag}",
                r["goodput"],
                f"SLO-met req/step @{r['overload']}x {r['arrival']} "
                f"seed={r['workload_seed']}; met={r['slo_met']}/"
                f"{r['requests']} shed={r['shed']} "
                f"preempt={r['preempt_count']} "
                f"degrade={r['degrade_steps']} "
                f"ttft_p99={r['ttft_p99']} pool_util="
                f"{r['pool_utilization']} deaths={r['engine_deaths']}",
            ))
        elif r["bench"] == "serve_slo_summary":
            rows.append((
                f"serve/slo_summary_{tag}",
                r["goodput_ratio"],
                f"goodput ratio (deadline+lazy+degrade / fifo+reserve) "
                f"@{r['overload']}x overload; pool_util_gain="
                f"{r['pool_util_gain']} preempt={r['preempt_count']} "
                f"deaths={r['engine_deaths']} conserved={r['conserved']}",
            ))
        elif r["bench"] == "serve_prefix":
            rows.append((
                f"serve/prefix_{tag}",
                r["hit_rate"],
                f"prefix hit rate ({r['prefix_hits']}/{r['prefix_lookups']} "
                f"admissions); saved {r['saved_billed_tokens']} billed "
                f"prefill tokens = {r['j_per_token_saved']:.3e} J/token "
                f"({r['j_per_token_cold']:.3e}->{r['j_per_token']:.3e}) "
                f"cow={r['cow_copies']} evict={r['prefix_evictions']} "
                f"token_match={r['token_match']}",
            ))
        else:
            kv = r.get("kv_bytes_per_active_token")
            rows.append((
                f"serve/{r['config']}_{tag}",
                r["tok_s"],
                f"tok/s; ttft={r['ttft_ms']}ms "
                f"sync_B/tok={r['sync_bytes_per_token']} "
                + (f"kv_B/active_tok={kv}" if kv is not None else
                   f"jit_out_B/tick={r['jit_out_bytes_per_tick']}"),
            ))
    rows.extend(energy_rows(energy))
    return rows


def run() -> List[Row]:
    return rows_from_records(bench_records())
