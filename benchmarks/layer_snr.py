"""Fig. 2 analogue on modern LMs: per-layer SNR_T requirements.

The paper's Fig. 2 plots the SNR_T each DP layer of VGG-16 needs for <1%
accuracy loss.  Here we measure the LM equivalent: inject analog noise at a
given SNR_T into ONE layer group at a time of an assigned-architecture (smoke
config) and record the cross-entropy degradation; the smallest SNR_T whose
degradation is below threshold is that layer's requirement.

Also sweeps whole-model IMC execution (all layers noisy) across SNR levels -
the deployment question the paper's framework answers - and emits the
per-site SNR_T map of an MPC-style per-site override substrate vs the
uniform design point (:func:`site_snr_records`, committed in
``BENCH_energy.json`` under the ``serve_energy`` suite).
"""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.design import optimize, with_b_adc
from repro.core.mapping import per_token_matmul_shapes
from repro.core.substrate import AnalyticIMC, substrate_for_design
from repro.models import init_params, loss_fn

Row = Tuple[str, float, str]


def _loss(cfg, params, batch, rng=None):
    l, _ = loss_fn(params, cfg, batch, rng=rng)
    return float(l)


def whole_model_snr_sweep(arch: str = "gemma2-9b", b: int = 4, s: int = 128,
                          levels=(10.0, 16.0, 22.0, 28.0, 34.0, 40.0)) -> List[Row]:
    cfg = configs.get_smoke(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.modality == "vlm":
        batch["prefix_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (b, cfg.prefix_len, cfg.d_model))
    base = _loss(cfg, params, batch)
    rows: List[Row] = [(f"layer_snr/{arch}/fp_ce", round(base, 4), "baseline")]
    rng = jax.random.PRNGKey(3)
    for snr in levels:
        noisy_cfg = cfg.replace(
            imc=AnalyticIMC(bx=8, bw=8, snr_a_db=snr)
        )
        ce = np.mean([
            _loss(noisy_cfg, params, batch, rng=jax.random.fold_in(rng, i))
            for i in range(3)
        ])
        rows.append((
            f"layer_snr/{arch}/ce_at_{snr:.0f}dB",
            round(float(ce), 4),
            f"dCE={ce-base:+.4f} (req: small at >=24 dB, paper SSIII-B)",
        ))
    return rows


# ---------------------------------------------------------------------------
# per-site SNR_T under an MPC-style override map (substrate API demo)
# ---------------------------------------------------------------------------

# extra output-ADC bits per site group vs the uniform design point: the
# embedding-adjacent sites (output head, attention projections feeding the
# residual stream) get a finer ADC than the FFN sites - the per-site
# precision assignment the paper's MPC criterion (eq. 15) prices per layer
OVERRIDE_EXTRA_BITS = {"lm_head": 2, "attn": 1}


def site_snr_records(arch: str = "musicgen-medium", snr_t_db: float = 14.0,
                     n: int = 512) -> List[dict]:
    """Per-site SNR_T of every matmul site of ``arch`` at (a) the uniform
    min-energy design point for ``snr_t_db`` and (b) a substrate with
    MPC-style per-site B_ADC overrides (``OVERRIDE_EXTRA_BITS``), plus a
    summary record with the J/token cost of the reassignment.  Deterministic
    closed forms - no model execution."""
    from repro.launch.metering import energy_for_tokens, substrate_energy_for_tokens

    cfg = configs.get(arch)
    shapes = per_token_matmul_shapes(cfg)
    pt = optimize(n=n, snr_t_target_db=snr_t_db)
    uniform = substrate_for_design(pt)
    overrides = {}
    for group, extra in OVERRIDE_EXTRA_BITS.items():
        pt_g = with_b_adc(pt, pt.b_adc + extra)
        overrides[group] = {"b_adc": pt_g.b_adc, "design": pt_g}
    boosted = uniform.with_overrides(overrides)

    meta = {"bench": "site_snr", "arch": arch, "substrate": boosted.name,
            "kind": pt.arch_kind, "bank_rows": n, "snr_t_target_db": snr_t_db}
    records: List[dict] = []
    for s in shapes:
        pu = uniform.design_for_site(s.name)
        po = boosted.design_for_site(s.name)
        records.append({
            **meta, "name": s.name, "K": s.k, "M": s.m,
            "b_adc_uniform": pu.b_adc, "b_adc_override": po.b_adc,
            "snr_t_uniform_db": round(pu.snr_t_db, 3),
            "snr_t_override_db": round(po.snr_t_db, 3),
        })
    e_uniform = energy_for_tokens(shapes, pt, 1)["energy_per_token_j"]
    e_boosted = substrate_energy_for_tokens(shapes, boosted,
                                            1)["energy_per_token_j"]
    boosted_sites = [r for r in records
                     if r["b_adc_override"] > r["b_adc_uniform"]]
    records.append({
        **meta, "bench": "site_snr_summary",
        "sites": len(shapes), "sites_boosted": len(boosted_sites),
        "snr_t_uniform_db": round(pt.snr_t_db, 3),
        "snr_t_boosted_min_db": round(
            min(r["snr_t_override_db"] for r in boosted_sites), 3),
        "j_per_token_uniform": e_uniform,
        "j_per_token_override": e_boosted,
        "j_per_token_ratio": round(e_boosted / e_uniform, 4),
    })
    return records


def run() -> List[Row]:
    rows: List[Row] = []
    for arch in ("gemma2-9b", "mamba2-2.7b"):
        rows += whole_model_snr_sweep(arch)
    return rows
