"""Fig. 2 analogue on modern LMs: per-layer SNR_T requirements.

The paper's Fig. 2 plots the SNR_T each DP layer of VGG-16 needs for <1%
accuracy loss.  Here we measure the LM equivalent: inject analog noise at a
given SNR_T into ONE layer group at a time of an assigned-architecture (smoke
config) and record the cross-entropy degradation; the smallest SNR_T whose
degradation is below threshold is that layer's requirement.

Also sweeps whole-model IMC execution (all layers noisy) across SNR levels -
the deployment question the paper's framework answers.
"""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.imc_linear import IMCConfig
from repro.models import init_params, loss_fn

Row = Tuple[str, float, str]


def _loss(cfg, params, batch, rng=None):
    l, _ = loss_fn(params, cfg, batch, rng=rng)
    return float(l)


def whole_model_snr_sweep(arch: str = "gemma2-9b", b: int = 4, s: int = 128,
                          levels=(10.0, 16.0, 22.0, 28.0, 34.0, 40.0)) -> List[Row]:
    cfg = configs.get_smoke(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.modality == "vlm":
        batch["prefix_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (b, cfg.prefix_len, cfg.d_model))
    base = _loss(cfg, params, batch)
    rows: List[Row] = [(f"layer_snr/{arch}/fp_ce", round(base, 4), "baseline")]
    rng = jax.random.PRNGKey(3)
    for snr in levels:
        noisy_cfg = cfg.replace(
            imc=IMCConfig(mode="imc_analytic", bx=8, bw=8, snr_a_db=snr)
        )
        ce = np.mean([
            _loss(noisy_cfg, params, batch, rng=jax.random.fold_in(rng, i))
            for i in range(3)
        ])
        rows.append((
            f"layer_snr/{arch}/ce_at_{snr:.0f}dB",
            round(float(ce), 4),
            f"dCE={ce-base:+.4f} (req: small at >=24 dB, paper SSIII-B)",
        ))
    return rows


def run() -> List[Row]:
    rows: List[Row] = []
    for arch in ("gemma2-9b", "mamba2-2.7b"):
        rows += whole_model_snr_sweep(arch)
    return rows
