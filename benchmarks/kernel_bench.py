"""Kernel micro-bench: per-shape op counts and wall time for the IMC matmul
kernels (interpret mode on CPU: wall time is indicative only; the derived
column reports the structural quantities that transfer to TPU - MXU matmul
count, VMEM working set, arithmetic intensity)."""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import imc_mvm, ref
from repro.kernels.ref import BitSerialSpec, quantize_codes

Row = Tuple[str, float, str]


def _bench(fn, *args, iters=3):
    fn(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> List[Row]:
    rows: List[Row] = []
    key = jax.random.PRNGKey(0)
    for (b, k, m, bx, bw) in [(64, 512, 128, 6, 6), (128, 1024, 256, 7, 7),
                              (32, 2048, 128, 4, 4)]:
        k1, k2 = jax.random.split(jax.random.fold_in(key, k + m))
        x = jax.random.normal(k1, (b, k))
        w = jax.random.normal(k2, (k, m))
        xc, _ = quantize_codes(x, bx, True, jnp.max(jnp.abs(x)))
        wc, _ = quantize_codes(w, bw, True, jnp.max(jnp.abs(w)))
        rows_bank = min(512, k)
        spec = BitSerialSpec(bx=bx, bw=bw, b_adc=8, rows=rows_bank, k_h=60.0,
                             v_c=55.0, x_signed=True)
        us = _bench(
            lambda: imc_mvm.imc_bitserial_matmul(xc, wc, None, None, spec,
                                                 interpret=True)
        )
        n_banks = -(-k // rows_bank)
        mxu_calls = bx * bw * n_banks * (-(-b // 128)) * (-(-m // 128))
        vmem_kb = (128 * rows_bank + rows_bank * 128 + 128 * 128) * 4 / 1024
        rows.append((
            f"kernel/bitserial_B{b}_K{k}_M{m}_b{bx}x{bw}",
            round(us, 1),
            f"MXU_tiles={mxu_calls} vmem_tile={vmem_kb:.0f}KiB "
            f"plane_flops={2*b*k*m*bx*bw/1e6:.0f}MF",
        ))
        us_ref = _bench(lambda: ref.imc_bitserial_ref(xc, wc, None, None, spec))
        rows.append((f"kernel/ref_B{b}_K{k}_M{m}_b{bx}x{bw}",
                     round(us_ref, 1), "pure-jnp oracle"))
    return rows
