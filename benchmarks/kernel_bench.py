"""Kernel micro-bench: per-shape wall time and structural counters for the
IMC matmul kernels (interpret mode on CPU: wall time is indicative only; the
structural counters are the quantities that transfer to TPU - MXU matmul
count, HBM bytes per operand class, arithmetic intensity).

Benches both the CURRENT kernel (packed weight planes, one stacked MXU call
per tile, in-kernel noise) and a frozen copy of the SEED kernel (per-plane
floor/mod extraction in every grid step, per-plane noise streamed from an
HBM-materialized ``(n_banks, Bw*Bx, B, M)`` tensor), so every run reports the
before/after trajectory this PR's rewrite established - in particular the
noise-operand HBM bytes, the structural quantity the rewrite eliminates.

Also benches the paged-attention decode step (``bench: paged_attention``):
the gather path materializes every resident slot's KV out of the block pool
(``pool[bt]``) before attending - O(slots * blocks) HBM traffic per decoded
token - while the fused kernel streams one physical block at a time through
the online-softmax accumulator, so the materialized copy is a single
block-sized working set, O(1) in sequence length.  The structural counter
``gathered_kv_bytes_per_step`` records exactly that quantity; the summary's
``gathered_kv_reduction`` is the deterministic before/after ratio the
regression gate pins.

``bench_records()`` returns machine-readable dicts (consumed by
``benchmarks/run.py --json``); ``run()`` formats them as the usual CSV rows.
"""
from __future__ import annotations

import functools
import time
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import imc_mvm, ref
from repro.kernels.paged_attention import paged_attention_decode
from repro.kernels.ref import (
    BitSerialSpec,
    paged_attention_ref,
    quantize_codes,
)

Row = Tuple[str, float, str]

SHAPES = [
    # (B, K, M, bx, bw)
    (64, 512, 128, 6, 6),
    (128, 1024, 256, 7, 7),
    (32, 2048, 128, 4, 4),
]

PAGED_SHAPES = [
    # (slots, blocks per slot, block_size, kv heads, q groups, head_dim)
    (4, 8, 8, 2, 2, 64),
    (8, 16, 8, 4, 2, 64),
]


# ---------------------------------------------------------------------------
# frozen seed-kernel baseline (pre-rewrite design, kept ONLY as the perf
# reference: per-grid-step plane extraction + HBM noise operand)
# ---------------------------------------------------------------------------


def _seed_bitserial_kernel(x_ref, w_ref, n_ref, o_ref, *, spec, has_noise):
    bank = pl.program_id(2)

    @pl.when(bank == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    ww, xw = spec.plane_weights()
    x = x_ref[...]
    w = w_ref[...]
    w_u = w + 2.0 ** (spec.bw - 1)
    x_u = x + 2.0 ** (spec.bx - 1) if spec.x_signed else x

    acc = jnp.zeros_like(o_ref)
    for i in range(spec.bw):
        wplane = jnp.mod(jnp.floor(w_u / (2.0**i)), 2.0)
        if i == spec.bw - 1:
            wplane = 1.0 - wplane
        for j in range(spec.bx):
            xplane = jnp.mod(jnp.floor(x_u / (2.0**j)), 2.0)
            if spec.x_signed and j == spec.bx - 1:
                xplane = 1.0 - xplane
            dp = jnp.dot(xplane, wplane, preferred_element_type=jnp.float32)
            dp = jnp.minimum(dp, spec.k_h)
            if has_noise:
                dp = dp + n_ref[0, i * spec.bx + j]
                dp = jnp.maximum(dp, 0.0)
            if spec.apply_adc:
                delta = spec.v_c / (2.0**spec.b_adc)
                code = jnp.clip(
                    jnp.round(dp / delta - 0.5), 0.0, 2.0**spec.b_adc - 1
                )
                dp = (code + 0.5) * delta
            acc = acc + (ww[i] * xw[j]) * dp
    o_ref[...] += acc


def _seed_bitserial_matmul(x_codes, w_codes, noise, spec,
                           tile_b=128, tile_m=128):
    b_sz, k = x_codes.shape
    _, m = w_codes.shape
    n_banks = -(-k // spec.rows)
    bp = -(-b_sz // tile_b) * tile_b
    mp = -(-m // tile_m) * tile_m
    kp = n_banks * spec.rows
    x_p = jnp.pad(x_codes.astype(jnp.float32), ((0, bp - b_sz), (0, kp - k)))
    w_p = jnp.pad(w_codes.astype(jnp.float32), ((0, kp - k), (0, mp - m)))
    has_noise = noise is not None
    operands = [x_p, w_p]
    in_specs = [
        pl.BlockSpec((tile_b, spec.rows), lambda b, mm, kk: (b, kk)),
        pl.BlockSpec((spec.rows, tile_m), lambda b, mm, kk: (kk, mm)),
    ]
    if has_noise:
        n_p = jnp.pad(
            noise.astype(jnp.float32),
            ((0, 0), (0, 0), (0, bp - b_sz), (0, mp - m)),
        )
        operands.append(n_p)
        in_specs.append(
            pl.BlockSpec(
                (1, spec.bw * spec.bx, tile_b, tile_m),
                lambda b, mm, kk: (kk, 0, b, mm),
            )
        )
    else:
        operands.append(jnp.zeros((1, 1, 1, 1), jnp.float32))
        in_specs.append(
            pl.BlockSpec((1, 1, 1, 1), lambda b, mm, kk: (0, 0, 0, 0))
        )
    out = pl.pallas_call(
        functools.partial(
            _seed_bitserial_kernel, spec=spec, has_noise=has_noise
        ),
        grid=(bp // tile_b, mp // tile_m, n_banks),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((tile_b, tile_m), lambda b, mm, kk: (b, mm)),
        out_shape=jax.ShapeDtypeStruct((bp, mp), jnp.float32),
        interpret=True,
    )(*operands)
    return out[:b_sz, :m]


# ---------------------------------------------------------------------------
# harness
# ---------------------------------------------------------------------------


def _bench(fn, iters=3):
    fn()  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / iters * 1e6


def _structure(b, k, m, bx, bw, rows, design: str, noisy: bool):
    """Structural counters: what each configuration moves through HBM and
    issues on the MXU, per call (f32 operands; B/M padded to 128 tiles)."""
    n_banks = -(-k // rows)
    bt, mt = -(-b // 128), -(-m // 128)
    bp, mp = bt * 128, mt * 128
    kp = n_banks * rows
    counters = {
        "n_banks": n_banks,
        "x_bytes": bp * kp * 4,
        "plane_flops_mf": round(2 * b * k * m * bx * bw / 1e6),
    }
    if design == "seed":
        counters["mxu_calls"] = bx * bw * n_banks * bt * mt
        counters["w_bytes"] = kp * mp * 4
        counters["noise_bytes"] = n_banks * bw * bx * bp * mp * 4 if noisy else 0
    else:
        counters["mxu_calls"] = n_banks * bt * mt
        counters["w_bytes"] = kp * bw * mp * 4  # packed (K, Bw, M) planes
        counters["noise_bytes"] = 4 if noisy else 0  # scalar int32 seed
    return counters


def _paged_structure(slots, blocks, bs, hkv, hd, design: str):
    """KV bytes materialized OUTSIDE the block pool per decode step (f32
    K + V).  The gather path copies every resident slot's whole table
    (``pool[bt]``); the fused kernel's only materialized KV is the single
    block streamed through VMEM at each grid step - O(1) in both slot count
    and sequence length."""
    kv_elem = 4 * 2  # f32, K and V
    if design == "gather":
        gathered = slots * blocks * bs * hkv * hd * kv_elem
    else:
        gathered = bs * hkv * hd * kv_elem
    return {"gathered_kv_bytes_per_step": gathered}


def paged_attention_records(iters: int = 3) -> List[dict]:
    """Decode-step records: gather path vs fused streaming kernel (the
    pure-JAX block-walk the serve engine runs on CPU; on TPU the same walk
    is the Pallas grid)."""
    records: List[dict] = []
    key = jax.random.PRNGKey(1)
    for (slots, blocks, bs, hkv, g, hd) in PAGED_SHAPES:
        ks = jax.random.split(jax.random.fold_in(key, slots * blocks), 5)
        n_pool = slots * blocks + 1  # + reserved garbage block 0
        q = jax.random.normal(ks[0], (slots, hkv, g, hd))
        kn = jax.random.normal(ks[1], (slots, hkv, hd))
        vn = jax.random.normal(ks[2], (slots, hkv, hd))
        pk = jax.random.normal(ks[3], (n_pool, bs, hkv, hd))
        pv = jax.random.normal(ks[4], (n_pool, bs, hkv, hd))
        bt = 1 + jnp.arange(slots * blocks, dtype=jnp.int32).reshape(
            slots, blocks)
        # mid-block tail positions, staggered so the causal mask varies
        pos_b = (blocks // 2) * bs + 3 + jnp.arange(slots, dtype=jnp.int32)
        scale = hd ** -0.5

        shape_meta = {"slots": slots, "blocks": blocks, "block_size": bs,
                      "heads": hkv * g, "kv_heads": hkv, "head_dim": hd}
        configs = {
            "gather": (
                jax.jit(lambda q_, kn_, vn_: paged_attention_ref(
                    q_, kn_, vn_, pk, pv, bt, pos_b, scale=scale)),
                "gather",
            ),
            "kernel": (
                jax.jit(lambda q_, kn_, vn_: paged_attention_decode(
                    q_, kn_, vn_, pk, pv, bt, pos_b, scale=scale,
                    use_pallas=False)),
                "kernel",
            ),
        }
        for cname, (fn, design) in configs.items():
            # block inside the callable: these ops are microsecond-scale, so
            # an async (unblocked) warmup would bleed compile time into the
            # first timed iteration and swamp the measurement
            call = (lambda fn=fn: jax.block_until_ready(fn(q, kn, vn)))
            rec = {"bench": "paged_attention", "config": cname, **shape_meta,
                   "wall_us": round(_bench(call, iters=iters), 1),
                   **_paged_structure(slots, blocks, bs, hkv, hd, design)}
            records.append(rec)
        by_cfg = {r["config"]: r for r in records
                  if r.get("bench") == "paged_attention"
                  and (r["slots"], r["blocks"]) == (slots, blocks)}
        records.append({
            "bench": "paged_attention_summary", **shape_meta,
            "speedup_vs_gather": round(
                by_cfg["gather"]["wall_us"] / by_cfg["kernel"]["wall_us"], 2),
            "gathered_kv_bytes_before":
                by_cfg["gather"]["gathered_kv_bytes_per_step"],
            "gathered_kv_bytes_after":
                by_cfg["kernel"]["gathered_kv_bytes_per_step"],
            "gathered_kv_reduction": round(
                by_cfg["gather"]["gathered_kv_bytes_per_step"]
                / by_cfg["kernel"]["gathered_kv_bytes_per_step"], 1),
        })
    return records


def bench_records(iters: int = 3) -> List[dict]:
    """Machine-readable per-(shape, config) records for run.py --json."""
    records: List[dict] = []
    key = jax.random.PRNGKey(0)
    for (b, k, m, bx, bw) in SHAPES:
        k1, k2, k3 = jax.random.split(jax.random.fold_in(key, k + m), 3)
        x = jax.random.normal(k1, (b, k))
        w = jax.random.normal(k2, (k, m))
        xc, _ = quantize_codes(x, bx, True, jnp.max(jnp.abs(x)))
        wc, _ = quantize_codes(w, bw, True, jnp.max(jnp.abs(w)))
        rows_bank = min(512, k)
        n_banks = -(-k // rows_bank)
        sigma = 0.3
        spec = BitSerialSpec(bx=bx, bw=bw, b_adc=8, rows=rows_bank, k_h=60.0,
                             v_c=55.0, x_signed=True)
        spec_noisy = BitSerialSpec(bx=bx, bw=bw, b_adc=8, rows=rows_bank,
                                   k_h=60.0, v_c=55.0, x_signed=True,
                                   sigma_noise=sigma)
        # pre-drawn HBM noise tensor: the operand class the rewrite removed
        noise = sigma * jax.random.normal(
            k3, (n_banks, bw * bx, b, m), dtype=jnp.float32
        )

        shape_meta = {"B": b, "K": k, "M": m, "bx": bx, "bw": bw,
                      "rows": rows_bank}
        configs = {
            "seed_baseline": (
                lambda: _seed_bitserial_matmul(xc, wc, None, spec),
                "seed", False,
            ),
            "seed_baseline_noise": (
                lambda: _seed_bitserial_matmul(xc, wc, noise, spec_noisy),
                "seed", True,
            ),
            "kernel": (
                lambda: imc_mvm.imc_bitserial_matmul(xc, wc, None, spec,
                                                     interpret=True),
                "new", False,
            ),
            "kernel_noise": (
                lambda: imc_mvm.imc_bitserial_matmul(
                    xc, wc, None, spec_noisy, seed=17, interpret=True
                ),
                "new", True,
            ),
            "oracle": (
                lambda: ref.imc_bitserial_ref(xc, wc, None, spec),
                None, False,
            ),
        }
        for cname, (fn, design, noisy) in configs.items():
            rec = {"bench": "bitserial", "config": cname, **shape_meta,
                   "wall_us": round(_bench(fn, iters=iters), 1)}
            if design is not None:
                rec.update(_structure(b, k, m, bx, bw, rows_bank, design,
                                      noisy))
            records.append(rec)

        by_cfg = {r["config"]: r for r in records
                  if r.get("bench") == "bitserial"
                  and (r["B"], r["K"], r["M"]) == (b, k, m)}
        records.append({
            "bench": "bitserial_summary", **shape_meta,
            "speedup_vs_seed": round(
                by_cfg["seed_baseline"]["wall_us"] / by_cfg["kernel"]["wall_us"],
                2),
            "speedup_vs_seed_noise": round(
                by_cfg["seed_baseline_noise"]["wall_us"]
                / by_cfg["kernel_noise"]["wall_us"], 2),
            "noise_bytes_before": by_cfg["seed_baseline_noise"]["noise_bytes"],
            "noise_bytes_after": by_cfg["kernel_noise"]["noise_bytes"],
            "noise_bytes_reduction": round(
                by_cfg["seed_baseline_noise"]["noise_bytes"]
                / max(by_cfg["kernel_noise"]["noise_bytes"], 1), 1),
            "mxu_calls_before": by_cfg["seed_baseline"]["mxu_calls"],
            "mxu_calls_after": by_cfg["kernel"]["mxu_calls"],
        })
    records.extend(paged_attention_records(iters=iters))
    return records


def rows_from_records(records: List[dict]) -> List[Row]:
    rows: List[Row] = []
    for r in records:
        if r["bench"].startswith("paged_attention"):
            tag = (f"S{r['slots']}_N{r['blocks']}x{r['block_size']}"
                   f"_H{r['heads']}_D{r['head_dim']}")
            if r["bench"] == "paged_attention_summary":
                rows.append((
                    f"kernel/paged_summary_{tag}",
                    r["speedup_vs_gather"],
                    f"gathered_kv_B {r['gathered_kv_bytes_before']}->"
                    f"{r['gathered_kv_bytes_after']} "
                    f"({r['gathered_kv_reduction']}x)",
                ))
            else:
                rows.append((
                    f"kernel/paged_{r['config']}_{tag}", r["wall_us"],
                    f"gathered_kv_B={r['gathered_kv_bytes_per_step']}",
                ))
            continue
        tag = f"B{r['B']}_K{r['K']}_M{r['M']}_b{r['bx']}x{r['bw']}"
        if r["bench"] == "bitserial_summary":
            rows.append((
                f"kernel/summary_{tag}",
                r["speedup_vs_seed"],
                f"speedup_noise={r['speedup_vs_seed_noise']} "
                f"noise_bytes {r['noise_bytes_before']}->"
                f"{r['noise_bytes_after']} "
                f"mxu {r['mxu_calls_before']}->{r['mxu_calls_after']}",
            ))
        else:
            derived = (
                f"MXU_tiles={r['mxu_calls']} noise_B={r['noise_bytes']} "
                f"w_B={r['w_bytes']} plane_flops={r['plane_flops_mf']}MF"
                if "mxu_calls" in r else "pure-jnp oracle"
            )
            rows.append((
                f"kernel/{r['config']}_{tag}", r["wall_us"], derived
            ))
    return rows


def run() -> List[Row]:
    return rows_from_records(bench_records())
