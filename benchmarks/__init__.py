"""Benchmarks: one per paper figure/table + roofline + beyond-paper rollups."""
