"""One benchmark per paper table/figure (deliverable (d)).

Each ``fig*`` function regenerates the quantitative content of the paper's
figure from this implementation (analytic curves + Monte Carlo overlays) and
returns rows of (name, value, derived) that benchmarks.run prints as CSV.
Numbers are cross-checked against the paper's stated anchors inline.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import numpy as np

from repro.core import mc, precision as prec, scaling
from repro.core.archs import CMArch, QRArch, QSArch
from repro.core.design import optimize, pareto_sweep
from repro.core.quant import UNIFORM_STATS, db, sqnr_qiy_db_approx

Row = Tuple[str, float, str]
KEY = jax.random.PRNGKey(0)


def _timeit(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, (time.perf_counter() - t0) * 1e6


# ---------------------------------------------------------------------------
# Fig. 2 analogue: per-layer SNR_T requirement on an LM (see bench_layer_snr)
# Fig. 4: MPC vs BGC vs tBGC
# ---------------------------------------------------------------------------


def fig4_mpc_vs_bgc() -> List[Row]:
    rows: List[Row] = []
    stats = UNIFORM_STATS
    rows.append(("fig4/sqnr_qiy_7b_dB", float(sqnr_qiy_db_approx(7, 7, stats)),
                 "paper: 41 dB"))
    for n in (16, 64, 256, 1024):
        rows.append((f"fig4a/bgc_by_N{n}", prec.by_bgc(7, 7, n),
                     "B_y under BGC (16-20 over sweep)"))
        rows.append((
            f"fig4a/tbgc8_sqnr_N{n}",
            round(float(prec.sqnr_qy_fullrange_db_approx(8, n, stats)), 2),
            "tBGC B_y=8 fails 40 dB at large N",
        ))
    rows.append(("fig4a/mpc8_sqnr_dB", round(float(prec.sqnr_qy_mpc_db(8)), 2),
                 "MPC B_y=8, N-independent (>=40)"))
    # Fig 4(b): SQNR vs clip ratio, maximum at zeta ~ 4
    for z in (2.0, 3.0, 4.0, 5.0, 6.0):
        rows.append((f"fig4b/mpc8_zeta{z:.0f}",
                     round(float(prec.sqnr_qy_mpc_db(8, z)), 2), ""))
    rows.append(("fig4b/optimal_zeta", prec.optimal_zeta(8), "paper: 4"))
    # LM comparison note (paper: LM only 0.5 dB above MPC at B_y=8)
    return rows


# ---------------------------------------------------------------------------
# Fig. 9: QS-Arch SNR trade-offs (+ MC overlay)
# ---------------------------------------------------------------------------


def fig9_qs_arch(mc_ens: int = 400) -> List[Row]:
    rows: List[Row] = []
    for v_wl in (0.6, 0.7, 0.8):
        for n in (32, 64, 125, 256, 512):
            a = QSArch(n=n, bx=6, bw=6, v_wl=v_wl)
            rows.append((f"fig9a/E_snrA_V{v_wl}_N{n}",
                         round(a.snr_A_db(), 2), f"k_h={a.k_h:.0f}"))
    # MC overlay at the paper's anchor point
    a = QSArch(n=125, bx=6, bw=6, v_wl=0.8)
    r = mc.empirical_snrs(KEY, a, mc.mc_qs_arch, ens=mc_ens)
    rows.append(("fig9a/S_snrA_V0.8_N125", round(r["snr_A_db"], 2),
                 f"E={a.snr_A_db():.2f} (paper ~19.6)"))
    # Fig 9(b): SNR_T vs B_ADC - minimum B_ADC prediction
    for b_adc in (3, 4, 5, 6, 8):
        rows.append((f"fig9b/snrT_V0.7_N128_B{b_adc}",
                     round(QSArch(n=128, bx=6, bw=6, v_wl=0.7).snr_T_db(b_adc), 2),
                     ""))
    rows.append(("fig9b/b_adc_min_V0.7_N128",
                 QSArch(n=128, bx=6, bw=6, v_wl=0.7).b_adc_min(), "circled pt"))
    return rows


# ---------------------------------------------------------------------------
# Fig. 10: QR-Arch
# ---------------------------------------------------------------------------


def fig10_qr_arch(mc_ens: int = 400) -> List[Row]:
    rows: List[Row] = []
    base = QRArch(n=128, bx=6, bw=7, c_o=1e-15).snr_a_db()
    for co in (1e-15, 3e-15, 9e-15):
        a = QRArch(n=128, bx=6, bw=7, c_o=co)
        rows.append((f"fig10a/E_snrA_Co{co*1e15:.0f}fF",
                     round(a.snr_A_db(), 2),
                     f"delta={a.snr_a_db()-base:+.1f} (paper +8/+12)"))
        rows.append((f"fig10b/b_adc_Co{co*1e15:.0f}fF", a.b_adc_min(),
                     "6-8 per paper; BGC=12"))
    a = QRArch(n=128, bx=6, bw=7, c_o=3e-15)
    r = mc.empirical_snrs(KEY, a, mc.mc_qr_arch, ens=mc_ens)
    rows.append(("fig10a/S_snrA_Co3fF", round(r["snr_A_db"], 2),
                 f"E={a.snr_A_db():.2f}"))
    rows.append(("fig10/bgc_by", a.b_adc_bgc(), "vs MPC above"))
    return rows


# ---------------------------------------------------------------------------
# Fig. 11: CM
# ---------------------------------------------------------------------------


def fig11_cm(mc_ens: int = 400) -> List[Row]:
    rows: List[Row] = []
    for v_wl in (0.7, 0.8):
        vals = {bw: CMArch(n=64, bx=6, bw=bw, v_wl=v_wl).snr_A_db()
                for bw in range(3, 10)}
        best = max(vals, key=vals.get)
        for bw, v in vals.items():
            rows.append((f"fig11a/E_snrA_V{v_wl}_Bw{bw}", round(v, 2), ""))
        rows.append((f"fig11a/opt_bw_V{v_wl}", best,
                     "paper: 6 @0.8V, 7 @0.7V"))
    a = CMArch(n=64, bx=6, bw=6, v_wl=0.8)
    r = mc.empirical_snrs(KEY, a, mc.mc_cm, ens=mc_ens)
    rows.append(("fig11a/S_snrA_V0.8_Bw6", round(r["snr_A_db"], 2),
                 f"E={a.snr_A_db():.2f}"))
    rows.append(("fig11b/b_adc_mpc", a.b_adc_min(), "paper: <=8 (BGC 19)"))
    rows.append(("fig11b/b_adc_bgc", a.b_adc_bgc(), ""))
    return rows


# ---------------------------------------------------------------------------
# Fig. 12: ADC energy vs N under BGC vs MPC
# ---------------------------------------------------------------------------


def fig12_adc_energy() -> List[Row]:
    rows: List[Row] = []
    for n in (32, 64, 128, 256, 512):
        qs = QSArch(n=n, bx=6, bw=6, v_wl=0.7)
        qr = QRArch(n=n, bx=6, bw=6, c_o=3e-15)
        cm = CMArch(n=n, bx=6, bw=6, v_wl=0.8)
        rows.append((f"fig12a/qs_mpc_fJ_N{n}",
                     round(qs.adc_energy_per_conversion(qs.b_adc_min()) * 1e15, 2),
                     "decreases with N"))
        rows.append((f"fig12b/qr_mpc_fJ_N{n}",
                     round(qr.adc_energy_per_conversion(qr.b_adc_min()) * 1e15, 2),
                     "~N under MPC"))
        rows.append((f"fig12b/qr_bgc_fJ_N{n}",
                     round(qr.adc_energy_per_conversion(qr.b_adc_bgc()) * 1e15, 2),
                     "~N^2 under BGC"))
        rows.append((f"fig12c/cm_mpc_fJ_N{n}",
                     round(cm.adc_energy_per_conversion(cm.b_adc_min()) * 1e15, 2),
                     ""))
    return rows


# ---------------------------------------------------------------------------
# Fig. 13: technology scaling
# ---------------------------------------------------------------------------


def fig13_scaling() -> List[Row]:
    rows: List[Row] = []
    for name in scaling.PAPER_SEQUENCE:
        tech = scaling.node(name)
        best_qs = max(
            QSArch(n=100, bx=3, bw=4, tech=tech, v_wl=float(v)).snr_A_db()
            for v in np.arange(0.5, tech.v_dd - 0.05, 0.025)
        )
        rows.append((f"fig13a/qs_max_snrA_{name}", round(best_qs, 2),
                     "declines with scaling"))
        qr = QRArch(n=100, bx=3, bw=4, tech=tech, c_o=3e-15)
        rows.append((f"fig13b/qr_snrA_{name}", round(qr.snr_A_db(), 2),
                     "QR keeps its SNR"))
        rows.append((f"fig13b/qr_energy_fJ_{name}",
                     round((qr.analog_energy_per_dp()
                            + qr.adc_energy_per_conversion(6)) * 1e15, 2),
                     "drops with scaling"))
    return rows


# ---------------------------------------------------------------------------
# SSVI guidelines as data: energy-vs-SNR pareto (design solver)
# ---------------------------------------------------------------------------


def table_design_pareto() -> List[Row]:
    rows: List[Row] = []
    for target, pt in pareto_sweep(n=256, targets_db=range(10, 34, 4)):
        rows.append((
            f"pareto/target{target}dB",
            round(pt.energy_per_dp * 1e12, 4),
            f"pJ/DP via {pt.arch_kind} knob={pt.knob:.3g} "
            f"banks={pt.n_banks} B_ADC={pt.b_adc}",
        ))
    return rows


ALL = {
    "fig4": fig4_mpc_vs_bgc,
    "fig9": fig9_qs_arch,
    "fig10": fig10_qr_arch,
    "fig11": fig11_cm,
    "fig12": fig12_adc_energy,
    "fig13": fig13_scaling,
    "pareto": table_design_pareto,
}
