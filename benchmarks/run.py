"""Benchmark orchestrator (deliverable (d)): one entry per paper table/figure
plus the roofline + beyond-paper extensions.  Prints ``name,value,derived``
CSV rows (value is dB / fJ / seconds / count as per the name)."""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig4,fig9,fig10,fig11,fig12,fig13,"
                         "pareto,layer_snr,model_energy,kernel,roofline")
    args = ap.parse_args()

    from benchmarks import kernel_bench, layer_snr, model_energy, roofline
    from benchmarks.paper_figures import ALL as FIG_BENCHES

    suites = {}
    suites.update(FIG_BENCHES)
    suites["layer_snr"] = layer_snr.run
    suites["model_energy"] = model_energy.run
    suites["kernel"] = kernel_bench.run
    suites["roofline"] = roofline.run

    only = set(args.only.split(",")) if args.only else None
    print("name,value,derived")
    for name, fn in suites.items():
        if only and name not in only:
            continue
        t0 = time.perf_counter()
        try:
            rows = fn()
        except Exception as e:  # noqa: BLE001
            print(f"{name}/ERROR,0,{type(e).__name__}: {e}")
            continue
        dt = time.perf_counter() - t0
        for rname, val, derived in rows:
            print(f'{rname},{val},"{derived}"')
        print(f'{name}/_suite_s,{dt:.2f},"suite wall time"')
        sys.stdout.flush()


if __name__ == "__main__":
    main()
