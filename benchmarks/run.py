"""Benchmark orchestrator (deliverable (d)): one entry per paper table/figure
plus the roofline + beyond-paper extensions.  Prints ``name,value,derived``
CSV rows (value is dB / fJ / seconds / count as per the name).

``--json PATH`` additionally writes a machine-readable report.  Suites that
expose ``bench_records()`` (currently the kernel micro-bench) contribute
structured per-shape records - wall time plus structural counters (MXU
calls, HBM bytes per operand class, noise-operand bytes before/after the
in-kernel-RNG rewrite); other suites contribute their CSV rows as dicts.
The committed ``BENCH_kernels.json`` baseline is produced with::

    PYTHONPATH=src python benchmarks/run.py --only kernel --json BENCH_kernels.json
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import time

# make `python benchmarks/run.py` work from anywhere (repo root on sys.path)
sys.path.insert(
    0, os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig4,fig9,fig10,fig11,fig12,fig13,"
                         "pareto,layer_snr,model_energy,kernel,serve,"
                         "serve_energy,serve_sharded,serve_prefix,roofline")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write a machine-readable JSON report")
    ap.add_argument("--workload-seed", type=int, default=None,
                    help="override the serve_slo overload-workload seed "
                         "(default: the committed baseline seed; every "
                         "serve_slo field is a deterministic draw-for-draw "
                         "function of this seed - no wall clock)")
    ap.add_argument("--mesh", default=None, metavar="RxC",
                    help="override the serve_sharded suite's device mesh "
                         "(default: the committed baseline mesh, 1x4; the "
                         "suite runs in a child process that pins 8 "
                         "host-simulated devices regardless of the parent)")
    args = ap.parse_args()
    if args.json:
        json_dir = os.path.dirname(os.path.abspath(args.json)) or "."
        if not os.path.isdir(json_dir):
            ap.error(f"--json: directory does not exist: {json_dir}")

    import jax

    from benchmarks import kernel_bench, layer_snr, model_energy, roofline, serve_bench
    from benchmarks.paper_figures import ALL as FIG_BENCHES

    if args.workload_seed is not None:
        serve_bench.SLO_SEED = args.workload_seed
    if args.mesh is not None:
        serve_bench.SHARDED_MESH = args.mesh

    suites = {}
    suites.update(FIG_BENCHES)
    suites["layer_snr"] = layer_snr.run
    suites["model_energy"] = model_energy.run
    suites["kernel"] = kernel_bench.run
    suites["serve"] = serve_bench.run
    # deterministic serve-path energy accounting alone (fast; no wall-clock
    # repeats) - the committed BENCH_energy.json baseline is produced with
    #   PYTHONPATH=src python benchmarks/run.py --only serve_energy \
    #       --json BENCH_energy.json
    suites["serve_energy"] = lambda: serve_bench.energy_rows(
        serve_bench.energy_records())
    # multi-device scaling suite: runs in a child process that pins 8 host
    # devices, so it works (and gates) under any parent device count
    suites["serve_sharded"] = lambda: serve_bench.sharded_rows(
        serve_bench.sharded_records())
    # prefix-sharing paged KV suite: warm (radix prefix cache) vs cold engine
    # on identical seeded shared-system-prompt traffic; deterministic
    # structural counters + billed-prefill-energy saving
    suites["serve_prefix"] = lambda: serve_bench.rows_from_records(
        serve_bench.prefix_records())
    suites["roofline"] = roofline.run
    # suites with structured records: run once, derive the CSV rows from them
    record_fns = {"kernel": (kernel_bench.bench_records,
                             kernel_bench.rows_from_records),
                  "serve": (serve_bench.bench_records,
                            serve_bench.rows_from_records),
                  "serve_energy": (serve_bench.energy_records,
                                   serve_bench.energy_rows),
                  "serve_sharded": (serve_bench.sharded_records,
                                    serve_bench.sharded_rows),
                  "serve_prefix": (serve_bench.prefix_records,
                                   serve_bench.rows_from_records)}

    only = set(args.only.split(",")) if args.only else None
    if only and "serve" in only:
        # the serve bench surface reports energy + multi-device scaling +
        # prefix sharing too: selecting the serve suite pulls in the
        # (deterministic) serve_energy rollup, the subprocess-isolated
        # serve_sharded comparison, and the serve_prefix warm-vs-cold
        # comparison, so the committed BENCH_serve.json carries all four
        only.add("serve_energy")
        only.add("serve_sharded")
        only.add("serve_prefix")
    # schema v2.6: serve-suite records name the execution substrate they
    # ran/billed (since v2.1), serve_drift records carry the full
    # detection/swap/recovery report surface (since v2.2), serve_slo
    # records carry the overload scoreboard - goodput, TTFT/ITL percentiles,
    # shed/preempt/degrade counters, engine_deaths, conservation - for the
    # committed seeded 2x-overload scenario (since v2.3), engine
    # "serve" records name their decode-attention path (kernel/gather/
    # dense) alongside the paged_attention kernel bench records (since
    # v2.4), serve_sharded records pin the tensor-parallel engine:
    # mesh_shape/devices identity, per-device KV bytes (structural-exact),
    # greedy-token match with the single-device engine, and a tok/s scaling
    # floor (since v2.5), and serve_prefix records pin the prefix-sharing
    # paged KV cache: exact hit/CoW/eviction counters, greedy-token identity
    # with a cold-cache run, and the billed-prefill-energy saving at the
    # committed QR design point (new in v2.6; all enforced by
    # check_regression.py)
    payload = {
        "schema": "repro-imc-bench/v2.6",
        "schema_version": 2.6,
        "backend": jax.default_backend(),
        # machine/XLA provenance: lets the regression gate (and humans) tell
        # a real perf change from a toolchain change, and the schema test
        # reject stale/truncated committed artifacts
        "meta": {
            "backend": jax.default_backend(),
            "jax": jax.__version__,
            "python": platform.python_version(),
            "platform": platform.platform(),
            "machine": platform.machine(),
            "xla_flags": os.environ.get("XLA_FLAGS", ""),
        },
        "suites": {},
    }
    print("name,value,derived")
    for name, fn in suites.items():
        if only and name not in only:
            continue
        t0 = time.perf_counter()
        try:
            if args.json and name in record_fns:
                records_fn, rows_fn = record_fns[name]
                records = records_fn()
                rows = rows_fn(records)
            else:
                records = None
                rows = fn()
        except Exception as e:  # noqa: BLE001
            print(f"{name}/ERROR,0,{type(e).__name__}: {e}")
            payload["suites"][name] = {"error": f"{type(e).__name__}: {e}"}
            continue
        dt = time.perf_counter() - t0
        for rname, val, derived in rows:
            print(f'{rname},{val},"{derived}"')
        print(f'{name}/_suite_s,{dt:.2f},"suite wall time"')
        sys.stdout.flush()
        if records is None:
            records = [
                {"name": rname, "value": val, "derived": derived}
                for rname, val, derived in rows
            ]
        payload["suites"][name] = {"wall_s": round(dt, 2), "records": records}

    if args.json:
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"# wrote {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
