"""Overload-resilience policy layer: scheduler policies, the pressure
controller's frontier-degradation hysteresis, the seeded SLO workload
generator, the SLO rollup, and the drift-pause-under-saturation contract.

Policy/controller/workload/rollup tests are pure host-side (fake engines,
no jit); the drift-pause test runs the real frozen imc_analytic engine.
"""
import jax
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core.design import frontier_ladder, optimize
from repro.core.imc_linear import IMCConfig
from repro.core.substrate import calibrate_model, substrate_ladder
from repro.launch.metering import percentile, slo_summary
from repro.launch.scheduler import (
    DeadlineSLOPolicy,
    FIFOPolicy,
    PressureController,
    ShortestPromptFirst,
    make_policy,
)
from repro.launch.serve import Engine, Request, serve_slo
from repro.models import init_params
from repro.runtime.drift import DriftConfig, DriftMonitor
from repro.runtime.workload import (
    RequestClass,
    VirtualClock,
    WorkloadConfig,
    generate,
    make_overload_config,
)

TINY = dict(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
    max_seq=128, flash_q_block=16, flash_kv_block=16, dtype="float32",
)
DENSE = ArchConfig(name="t", family="dense", **TINY)

_PARAMS = {}


def jax_params(cfg):
    key = id(cfg)
    if key not in _PARAMS:
        _PARAMS[key] = init_params(jax.random.PRNGKey(0), cfg)
    return _PARAMS[key]


def _frozen_cfg(mode="imc_analytic", seed=1):
    cfg_dyn = DENSE.replace(imc=IMCConfig(mode=mode, bx=7, bw=7, v_wl=0.7))
    params = jax_params(DENSE)
    ref = np.random.default_rng(seed).integers(0, DENSE.vocab_size, (4, 24))
    cfg = calibrate_model(cfg_dyn, params, [ref])
    _PARAMS[id(cfg)] = params
    return cfg, params


def _req(rid, plen=4, out=0, arrive=None, ttft=None, itl=None, max_new=8):
    r = Request(rid=rid, prompt=np.arange(plen, dtype=np.int32),
                max_new=max_new, arrive_at=arrive, ttft_deadline=ttft,
                itl_deadline=itl)
    r.out = list(range(out))
    return r


# ---------------------------------------------------------------------------
# scheduler policies (pure host-side)
# ---------------------------------------------------------------------------


def test_make_policy_and_unknown():
    assert isinstance(make_policy("fifo"), FIFOPolicy)
    assert isinstance(make_policy("sjf"), ShortestPromptFirst)
    assert isinstance(make_policy("deadline"), DeadlineSLOPolicy)
    with pytest.raises(ValueError, match="unknown scheduler policy"):
        make_policy("lifo")


def test_fifo_is_identity_and_never_sheds():
    q = [_req(0, 9, arrive=0.0, ttft=1.0), _req(1, 2, arrive=0.0, ttft=1.0)]
    p = FIFOPolicy()
    assert p.shed(q, now=99.0) == []
    p.order(q, now=99.0)
    assert [r.rid for r in q] == [0, 1]


def test_sjf_orders_by_effective_prompt_stably():
    # rid 2 is mid-flight (prompt 2 + out 3 = 5); rids 0/1 tie at 4 and must
    # keep arrival order; rid 3 is longest
    q = [_req(0, 4), _req(1, 4), _req(2, 2, out=3), _req(3, 9)]
    ShortestPromptFirst().order(q, now=0.0)
    assert [r.rid for r in q] == [0, 1, 2, 3]


def test_deadline_orders_resumed_first_then_edf():
    q = [_req(0, arrive=0.0, ttft=50.0), _req(1, arrive=2.0, ttft=10.0),
         _req(2, arrive=1.0, out=2, ttft=5.0), _req(3)]  # rid 3: no deadline
    DeadlineSLOPolicy().order(q, now=0.0)
    # resumed (rid 2) first, then EDF (12 < 50), no-deadline last
    assert [r.rid for r in q] == [2, 1, 0, 3]


def test_deadline_sheds_only_hopeless_fresh_requests():
    p = DeadlineSLOPolicy(slack=1.0)
    q = [
        _req(0, arrive=0.0, ttft=5.0),          # overdue: 0+5+1 < 10
        _req(1, arrive=8.0, ttft=5.0),          # still feasible
        _req(2, arrive=0.0, out=3, ttft=5.0),   # resumed: never shed
        _req(3),                                # no deadline: never shed
    ]
    doomed = p.shed(q, now=10.0)
    assert [r.rid for r in doomed] == [0]
    assert [r.rid for r in q] == [1, 2, 3]
    assert p.shed_count == 1
    # exactly at deadline + slack: not shed (strictly-greater-than)
    q2 = [_req(4, arrive=4.0, ttft=5.0)]
    assert p.shed(q2, now=10.0) == []


# ---------------------------------------------------------------------------
# pressure controller hysteresis (fake engine, no jit)
# ---------------------------------------------------------------------------


class _FakeDesign:
    def __init__(self, delay):
        self.delay_per_dp = delay
        self.b_adc = 8


class _FakeSub:
    def __init__(self, delay):
        self.design = _FakeDesign(delay)


class _FakeAlloc:
    def __init__(self, num_blocks=9, used=0):
        self.num_blocks = num_blocks
        self.used_count = used


class _FakeEngine:
    def __init__(self):
        self.queue_depth = 0
        self.batch_slots = 4
        self.alloc = _FakeAlloc()
        self.swaps = []

    def swap_substrate(self, sub, time_scale=1.0):
        self.swaps.append((sub, time_scale))


def test_pressure_is_max_of_queue_and_pool():
    eng = _FakeEngine()
    pc = PressureController(eng, [_FakeSub(1.0)])
    assert pc.pressure() == 0.0
    eng.queue_depth = 2
    assert pc.pressure() == pytest.approx(0.5)
    eng.alloc.used_count = 6  # 6/8 > 2/4
    assert pc.pressure() == pytest.approx(0.75)


def test_controller_hysteresis_and_time_scales():
    eng = _FakeEngine()
    ladder = [_FakeSub(1.0), _FakeSub(0.5), _FakeSub(0.25)]
    pc = PressureController(eng, ladder, high=1.0, low=0.25, hold=2)
    assert pc.time_scales == [1.0, 0.5, 0.25]

    eng.queue_depth = 8  # pressure 2.0
    assert pc.update() == 0          # 1 hot tick: not yet
    assert pc.update() == 1          # 2nd hot tick: degrade
    assert eng.swaps[-1] == (ladder[1], 0.5)
    assert pc.update() == 1          # counter reset on step
    assert pc.update() == 2          # bottoms out next pair of ticks
    assert pc.update() == 2          # already at last level: stays
    assert pc.degrade_steps == 2

    eng.queue_depth = 2              # mid-band pressure 0.5: counters reset
    for _ in range(5):
        assert pc.update() == 2
    eng.queue_depth = 0              # cool
    assert pc.update() == 2
    assert pc.update() == 1          # upgrade after `hold` cool ticks
    assert eng.swaps[-1] == (ladder[1], 0.5)
    assert pc.update() == 1
    assert pc.update() == 0
    assert eng.swaps[-1] == (ladder[0], 1.0)
    assert pc.counters() == {
        "level": 0, "degrade_steps": 2, "upgrade_steps": 2}


def test_controller_input_validation():
    with pytest.raises(ValueError, match="non-empty"):
        PressureController(_FakeEngine(), [])
    with pytest.raises(ValueError, match="high > low"):
        PressureController(_FakeEngine(), [_FakeSub(1.0)], high=0.2, low=0.5)


def test_frontier_and_substrate_ladder():
    pt = optimize(n=512, snr_t_target_db=26.0, kinds=("qr",))
    ladder = frontier_ladder(pt, steps=2)
    assert len(ladder) == 3
    assert ladder[0] is pt
    b = [d.b_adc for d in ladder]
    assert b[0] > b[1] > b[2]
    # stepping down the frontier must get cheaper per DP (the whole point)
    delays = [d.delay_per_dp for d in ladder]
    assert delays[0] > delays[1] > delays[2]
    subs = substrate_ladder(pt, steps=2)
    assert [s.design.b_adc for s in subs] == b
    # ladder levels are distinct trace keys -> each compiles exactly once
    assert len({s.trace_key for s in subs}) == 3


# ---------------------------------------------------------------------------
# workload generator (seeded, deterministic)
# ---------------------------------------------------------------------------


def test_workload_seed_reproducible_draw_for_draw():
    wcfg = make_overload_config(n_requests=24, seed=7, overload=2.0, slots=4)
    a = generate(wcfg, vocab_size=256)
    b = generate(wcfg, vocab_size=256)
    assert len(a) == len(b) == 24
    for ra, rb in zip(a, b):
        assert ra.rid == rb.rid
        np.testing.assert_array_equal(ra.prompt, rb.prompt)
        assert ra.arrive_at == rb.arrive_at
        assert ra.stop_at == rb.stop_at
        assert ra.rclass == rb.rclass
        assert ra.ttft_deadline == rb.ttft_deadline
    c = generate(make_overload_config(n_requests=24, seed=8), vocab_size=256)
    assert any(na.arrive_at != nc.arrive_at for na, nc in zip(a, c))


@pytest.mark.parametrize("arrival", ["poisson", "bursty"])
def test_workload_bounds_and_monotone_arrivals(arrival):
    wcfg = WorkloadConfig(n_requests=40, seed=3, arrival=arrival,
                          prompt_min=2, prompt_max=16, max_new=6)
    reqs = generate(wcfg, vocab_size=256)
    classes = {c.name: c for c in wcfg.classes}
    last = 0.0
    for r in reqs:
        assert 2 <= len(r.prompt) <= 16
        assert r.prompt.min() >= 0 and r.prompt.max() < 256
        assert 1 <= r.stop_at <= r.max_new == 6
        assert r.arrive_at >= last
        last = r.arrive_at
        cls = classes[r.rclass]
        assert r.ttft_deadline == cls.ttft_deadline
        assert r.itl_deadline == cls.itl_deadline


def test_overload_config_scales_interarrival():
    """2x overload means arrivals land twice as fast as service capacity."""
    one = make_overload_config(n_requests=8, seed=0, overload=1.0, slots=4)
    two = make_overload_config(n_requests=8, seed=0, overload=2.0, slots=4)
    assert two.mean_interarrival == pytest.approx(one.mean_interarrival / 2)


def test_workload_config_validation():
    with pytest.raises(ValueError, match="arrival"):
        WorkloadConfig(arrival="uniform")
    with pytest.raises(ValueError, match="class"):
        WorkloadConfig(classes=())


def test_virtual_clock():
    # advance() adds raw dt; the ENGINE pre-multiplies decode chunks by
    # time_scale (clock.advance(n_steps * clock.time_scale))
    clk = VirtualClock()
    clk.advance(2.0)
    clk.time_scale = 0.5
    clk.advance(4 * clk.time_scale)
    assert clk.now == pytest.approx(4.0)
    with pytest.raises(ValueError):
        clk.advance(-1.0)


# ---------------------------------------------------------------------------
# SLO rollup accounting
# ---------------------------------------------------------------------------


def test_percentile_nearest_rank():
    xs = [1.0, 2.0, 3.0, 4.0]
    assert percentile(xs, 50) == 2.0
    assert percentile(xs, 99) == 4.0
    assert percentile([5.0], 50) == 5.0
    assert np.isnan(percentile([], 50))


def _finished_fixture():
    ok = _req(0, arrive=0.0, ttft=5.0, itl=2.0)
    ok.t_first = 3.0
    ok.token_times = [3.0, 4.0, 5.0]
    late = _req(1, arrive=0.0, ttft=2.0, itl=10.0)
    late.t_first = 6.0  # TTFT 6 > 2
    late.token_times = [6.0, 7.0]
    gappy = _req(2, arrive=0.0, ttft=50.0, itl=1.5)
    gappy.t_first = 1.0
    gappy.token_times = [1.0, 2.0, 6.0]  # gap 4 > 1.5
    shed = _req(3, arrive=0.0, ttft=2.0)
    shed.error = RuntimeError("shed by deadline policy")
    shed.error_kind = "shed"
    dead = _req(4)
    dead.error = RuntimeError("decode failed")
    dead.error_kind = "decode"
    return [ok, late, gappy, shed, dead]


def test_slo_summary_accounting():
    s = slo_summary(_finished_fixture(), elapsed=10.0, policy="deadline")
    assert s["policy"] == "deadline"
    assert s["requests"] == 5
    assert s["completed"] == 3
    assert s["shed"] == 1 and s["errored"] == 1
    assert s["ttft_miss"] == 1 and s["itl_miss"] == 1
    assert s["slo_met"] == 1
    assert s["goodput"] == pytest.approx(0.1)
    # ok carries 3 tokens (len(out) == 0 in fixture -> tokens from out list)
    assert s["ttft_p50"] == pytest.approx(3.0)
    assert s["itl_p50"] == pytest.approx(1.0)
    assert s["itl_p99"] == pytest.approx(4.0)


# ---------------------------------------------------------------------------
# satellite: drift shadow sampling pauses while saturated
# ---------------------------------------------------------------------------


def test_drift_sampling_pauses_under_saturation():
    """While queue_depth exceeds ``drift_pause_depth`` the monitor's cadence
    counter is not consulted (no shadow samples, phase frozen); when pressure
    clears, sampling resumes exactly where it left off."""
    cfg, params = _frozen_cfg("imc_analytic")
    mon = DriftMonitor(DriftConfig(sample_every=1, check_every=100,
                                   auto_swap=False))
    eng = Engine(cfg, params, batch_slots=2, cache_len=32, max_chunk=2,
                 drift_monitor=mon, drift_pause_depth=0)
    reqs = [Request(rid=i,
                    prompt=np.random.default_rng(i).integers(0, 256, 5),
                    max_new=6) for i in range(2)]
    eng.admit_pending(reqs)
    assert not reqs

    eng.queue_depth = 3  # saturated: above pause depth
    eng.decode_chunk()
    eng.decode_chunk()
    assert mon.chunks_seen == 0 and mon.samples == 0  # cadence frozen

    eng.queue_depth = 0  # pressure cleared: cadence resumes
    eng.decode_chunk()
    assert mon.chunks_seen == 1 and mon.samples == 1

    # no pause configured -> always samples, regardless of queue depth
    eng2 = Engine(cfg, params, batch_slots=2, cache_len=32, max_chunk=2,
                  drift_monitor=DriftMonitor(
                      DriftConfig(sample_every=1, check_every=100,
                                  auto_swap=False)))
    reqs2 = [Request(rid=9, prompt=np.arange(4, dtype=np.int32), max_new=4)]
    eng2.admit_pending(reqs2)
    eng2.queue_depth = 99
    eng2.decode_chunk()
    assert eng2._drift.chunks_seen == 1
