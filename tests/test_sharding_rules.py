"""Sharding-rule unit tests (pure logic, no devices)."""
import os
import subprocess
import sys

import pytest
from jax.sharding import PartitionSpec as P

from repro.launch import sharding as shd


def test_param_spec_rules():
    assert shd.param_spec("embed", 2, False) == P("model", None)
    assert shd.param_spec("blocks/p0/mixer/wq", 3, True) == P(None, None, "model")
    assert shd.param_spec("blocks/p0/mixer/wo", 3, True) == P(None, "model", None)
    assert shd.param_spec("blocks/p0/moe/experts/wi", 4, True) == P(
        None, "model", None, None)
    assert shd.param_spec("final_norm/scale", 1, False) == P(None)
    assert shd.param_spec("blocks/p0/mixer/in_proj", 3, True) == P(
        None, None, "model")


def test_ws_noop_outside_context():
    import jax.numpy as jnp

    x = jnp.zeros((4, 4))
    assert shd.ws(x, "act_btd") is x
    qg = jnp.zeros((1, 2, 2, 2, 2))
    q2, k2, v2 = shd.ws_attn(qg, jnp.zeros((1, 2, 2, 2)), jnp.zeros((1, 2, 2, 2)))
    assert q2 is qg
    assert shd.attn_carry_pin(8, 6)(x) is x
    assert shd.moe_vmap_axes() is None
    assert not shd.attn_expand_groups(8, 6)


SPEC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.launch import steps, sharding as shd
from repro.launch.mesh import make_mesh

mesh = make_mesh((4, 4), ("data", "model"))

# _fix_spec relocates model off a non-divisible dim
spec = steps._fix_spec(P("model", None), (92553, 2048), mesh)
assert spec == P(None, "model"), spec
# drops when nothing fits
spec2 = steps._fix_spec(P("model", None), (7, 13), mesh)
assert spec2 == P(None, None), spec2
# fsdp adds the dp axes to the largest free dim of big params
spec3 = steps._add_fsdp(P(None, "model"), (4096, 4096), mesh)
assert spec3 == P("data", "model"), spec3
# small params untouched
spec4 = steps._add_fsdp(P(), (16, 16), mesh)
assert spec4 == P(), spec4

# MQA/GQA-aware helpers under an active rules context
with shd.axis_rules(mesh, steps.train_rules(mesh)):
    assert shd.attn_expand_groups(2, 6)       # hkv=2 %4!=0, g=6 %4!=0, 12%4==0
    assert not shd.attn_expand_groups(4, 3)   # hkv divides
    assert shd.moe_vmap_axes() == "data"
with shd.axis_rules(mesh, steps.train_rules(mesh, backward=False)):
    assert not shd.attn_expand_groups(2, 6)   # gated off for prefill
print("SPEC_OK")
"""


@pytest.mark.slow
def test_spec_helpers_with_mesh():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SPEC_SCRIPT],
                       capture_output=True, text=True,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))), env=env, timeout=600)
    assert "SPEC_OK" in r.stdout, r.stdout + r.stderr[-2000:]
