"""Committed bench artifacts: schema validation (stale/truncated files can't
land) and the serve-energy frontier invariants the CI gate pins."""
import glob
import json
import os

import pytest

from benchmarks.check_regression import (
    DRIFT_REQUIRED_FIELDS,
    PREFIX_REQUIRED_FIELDS,
    SHARDED_REQUIRED_FIELDS,
    SLO_REQUIRED_FIELDS,
    SLO_SUMMARY_REQUIRED_FIELDS,
    SUBSTRATE_REQUIRED_PREFIXES,
)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_FILES = sorted(glob.glob(os.path.join(ROOT, "BENCH_*.json")))

REQUIRED_META = ("backend", "jax", "python", "platform", "machine")


def _load(path):
    with open(path) as f:
        return json.load(f)


def test_committed_bench_files_exist():
    names = {os.path.basename(p) for p in BENCH_FILES}
    assert {"BENCH_kernels.json", "BENCH_serve.json",
            "BENCH_energy.json"} <= names


@pytest.mark.parametrize("path", BENCH_FILES,
                         ids=[os.path.basename(p) for p in BENCH_FILES])
def test_bench_schema(path):
    payload = _load(path)
    assert payload["schema_version"] == 2.6
    assert payload["schema"] == "repro-imc-bench/v2.6"
    meta = payload["meta"]
    for key in REQUIRED_META:
        assert meta.get(key), f"meta.{key} missing/empty"
    assert payload["suites"], "no suites"
    for suite, body in payload["suites"].items():
        assert "error" not in body, f"{suite}: committed artifact has error"
        assert body.get("records"), f"{suite}: empty records"
        assert body.get("wall_s") is not None
        for rec in body["records"]:
            # schema v2.1: serve-suite records name the Substrate they
            # ran on / billed (also enforced by check_regression.py)
            if rec.get("bench", "").startswith(SUBSTRATE_REQUIRED_PREFIXES):
                assert rec.get("substrate"), \
                    f"{suite}: record missing 'substrate' (schema v2.1)"
            # schema v2.2: drift records carry the full detection/swap/
            # recovery report surface (also enforced by check_regression.py)
            if rec.get("bench") == "serve_drift":
                for field in DRIFT_REQUIRED_FIELDS:
                    assert field in rec, \
                        f"{suite}: serve_drift record missing {field!r} " \
                        f"(schema v2.2)"
            # schema v2.3: serve_slo records carry the overload scoreboard
            # (also enforced by check_regression.py)
            slo_required = {"serve_slo": SLO_REQUIRED_FIELDS,
                            "serve_slo_summary": SLO_SUMMARY_REQUIRED_FIELDS}
            for field in slo_required.get(rec.get("bench", ""), ()):
                assert field in rec, \
                    f"{suite}: {rec['bench']} record missing {field!r} " \
                    f"(schema v2.3)"
            # schema v2.4: engine-comparison serve records name their
            # decode-attention path (also enforced by check_regression.py)
            if rec.get("bench") == "serve":
                assert rec.get("decode_attn"), \
                    f"{suite}: serve record missing 'decode_attn' " \
                    f"(schema v2.4)"
            # schema v2.5: tensor-parallel serve records pin the mesh
            # identity, the per-device KV footprint and the greedy-token
            # match (also enforced by check_regression.py)
            if rec.get("bench") == "serve_sharded":
                for field in SHARDED_REQUIRED_FIELDS:
                    assert field in rec, \
                        f"{suite}: serve_sharded record missing {field!r} " \
                        f"(schema v2.5)"
            # schema v2.6: prefix-sharing serve records pin the workload
            # identity, hit/CoW/eviction counters, warm-vs-cold token match
            # and the billed-prefill-energy saving (also enforced by
            # check_regression.py)
            if rec.get("bench") == "serve_prefix":
                for field in PREFIX_REQUIRED_FIELDS:
                    assert field in rec, \
                        f"{suite}: serve_prefix record missing {field!r} " \
                        f"(schema v2.6)"


def test_paged_attention_records_committed():
    """The paged-attention decode bench is part of the committed kernel
    baseline: the fused kernel's materialized KV working set is ONE block
    (O(1) - independent of slot count and sequence length) while the gather
    path copies the whole resident table, and the committed reduction ratio
    equals slots * blocks exactly."""
    payload = _load(os.path.join(ROOT, "BENCH_kernels.json"))
    records = payload["suites"]["kernel"]["records"]
    runs = [r for r in records if r["bench"] == "paged_attention"]
    summaries = [r for r in records
                 if r["bench"] == "paged_attention_summary"]
    assert runs and summaries, "BENCH_kernels.json missing paged_attention"
    for r in runs:
        one_block = r["block_size"] * r["kv_heads"] * r["head_dim"] * 8
        if r["config"] == "kernel":
            assert r["gathered_kv_bytes_per_step"] == one_block
        else:
            assert r["gathered_kv_bytes_per_step"] == \
                r["slots"] * r["blocks"] * one_block
    for s in summaries:
        assert s["gathered_kv_reduction"] == s["slots"] * s["blocks"]
        assert s["gathered_kv_bytes_after"] == \
            s["block_size"] * s["kv_heads"] * s["head_dim"] * 8


def test_serve_drift_record_committed():
    """The drift-injection scenario is part of the committed serve baseline:
    detection happened inside the cadence bound, the hot-swap ran, and the
    post-swap SNR_T gap to a fresh-frozen reference is inside the 1 dB
    acceptance ceiling."""
    payload = _load(os.path.join(ROOT, "BENCH_serve.json"))
    recs = [r for r in payload["suites"]["serve"]["records"]
            if r["bench"] == "serve_drift"]
    assert recs, "BENCH_serve.json has no serve_drift record"
    for r in recs:
        assert r["drift_detected"] is True
        assert r["false_positives_clean"] == 0
        assert 0 <= r["chunks_to_detect"] <= r["detection_bound_chunks"]
        assert r["swaps"] >= 1
        assert r["sites_drifted"] >= 1
        assert r["recovery_gap_db_max"] <= 1.0
        assert r["failed_requests"] == 0


def test_serve_slo_records_committed():
    """The seeded 2x-overload bursty scenario is part of the committed serve
    baseline: the deadline+lazy+degrade policy strictly beats the FIFO/reserve
    baseline on goodput, lazy allocation raises pool utilization, at least one
    recompute-preemption happened, no engine died, and every run conserved
    its requests."""
    payload = _load(os.path.join(ROOT, "BENCH_serve.json"))
    records = payload["suites"]["serve"]["records"]
    runs = [r for r in records if r["bench"] == "serve_slo"]
    assert len(runs) >= 3, "BENCH_serve.json is missing serve_slo runs"
    for r in runs:
        assert r["engine_deaths"] == 0
        assert r["conserved"] is True
        assert r["errored"] == 0
    (summary,) = [r for r in records if r["bench"] == "serve_slo_summary"]
    assert summary["goodput_ratio"] > 1.0
    assert summary["pool_util_gain"] > 0.0
    assert summary["preempt_count"] >= 1
    assert summary["engine_deaths"] == 0
    assert summary["conserved"] is True


def test_serve_sharded_records_committed():
    """The tensor-parallel engine comparison is part of the committed serve
    baseline: the 1x4 mesh head-shards the smoke model's 4 KV heads (one per
    device, so per-device pool bytes are exactly total/4), the kernel decode
    path fell back to gather, and the sharded engine produced greedy tokens
    identical to the single-device engine on every substrate."""
    payload = _load(os.path.join(ROOT, "BENCH_serve.json"))
    recs = [r for r in payload["suites"]["serve_sharded"]["records"]
            if r["bench"] == "serve_sharded"]
    assert len(recs) >= 2, "BENCH_serve.json is missing serve_sharded runs"
    substrates = {r["substrate"] for r in recs}
    assert "digital" in substrates
    assert any(s.startswith("imc") for s in substrates)
    for r in recs:
        assert r["mesh_shape"] == "1x4"
        assert r["devices"] == 8
        assert r["decode_attn"] == "gather"
        assert r["token_match"] is True
        assert r["scaling_tok_s_ratio"] >= 0.05
        assert r["kv_shard_ways"] == 4
        # musicgen smoke is fully paged (no contiguous rings): the pool
        # bytes split exactly over the shard groups
        assert r["kv_bytes_per_device"] * r["kv_shard_ways"] == \
            r["kv_bytes_total"]


def test_serve_prefix_records_committed():
    """The prefix-sharing paged KV comparison is part of the committed serve
    baseline: on the seeded shared-system-prompt workload the warm engine
    hits the radix cache (>0 hit rate), produces greedy tokens bit-identical
    to the cold-cache engine, and the energy rollup bills a strictly
    positive prefill-dot-product saving (J/token) at the committed QR design
    point."""
    payload = _load(os.path.join(ROOT, "BENCH_serve.json"))
    recs = [r for r in payload["suites"]["serve_prefix"]["records"]
            if r["bench"] == "serve_prefix"]
    assert len(recs) >= 2, "BENCH_serve.json is missing serve_prefix runs"
    substrates = {r["substrate"] for r in recs}
    assert "digital" in substrates
    assert any(s.startswith("imc") for s in substrates)
    for r in recs:
        assert r["token_match"] is True
        assert r["prefix_hits"] >= 1
        assert 0.0 < r["hit_rate"] <= 1.0
        assert r["prefix_hit_tokens"] >= r["prefix_hits"]
        assert r["saved_billed_tokens"] > 0
        # the acceptance invariant: the cache measurably reduces the billed
        # prefill dot-product energy vs the cold run at the same design point
        assert r["saved_prefill_j"] > 0
        assert r["j_per_token_saved"] > 0
        assert r["j_per_token"] < r["j_per_token_cold"]
        assert r["prefill_tokens"] < r["prefill_tokens_cold"]
        # warm billed + avoided == the cold bill (token bookkeeping closes)
        assert r["prefill_tokens"] + r["saved_billed_tokens"] == \
            r["prefill_tokens_cold"]


def _energy_records():
    payload = _load(os.path.join(ROOT, "BENCH_energy.json"))
    return payload["suites"]["serve_energy"]["records"]


def test_energy_bench_per_design_point_metrics():
    """--only serve_energy emits J/token, J/request, EDP/token per substrate
    x design point, split prefill/decode."""
    recs = [r for r in _energy_records() if r["bench"] == "serve_energy"]
    assert len(recs) >= 4  # 3 kinds at the low target + >=1 at the high
    for r in recs:
        assert r["kind"] in ("qs", "qr", "cm")
        for key in ("j_per_token", "j_per_request", "edp_per_token",
                    "prefill_j", "decode_j", "tok_s_compute", "b_adc",
                    "prefill_tokens", "decode_tokens"):
            assert key in r, key
        assert r["j_per_token"] > 0
        assert r["prefill_j"] + r["decode_j"] == pytest.approx(
            r["j_per_token"] * r["generated_tokens"], rel=1e-6)


def test_energy_bench_reproduces_qs_qr_crossover():
    """The committed baseline pins the QS-vs-QR serve-workload crossover:
    QS on the frontier at the low SNR target only, QR best at the high."""
    (xr,) = [r for r in _energy_records()
             if r["bench"] == "serve_energy_crossover"]
    assert xr["qs_feasible_low"] is True
    assert xr["qs_feasible_high"] is False
    assert xr["best_kind_high"] == "qr"
    assert xr["crossover"] is True
