"""BGC / tBGC / MPC output-precision criteria (paper SSIII-C/D, Fig. 4)."""
import numpy as np
import pytest

from repro.core import precision as prec
from repro.core import snr as snr_lib
from repro.core.quant import SignalStats, UNIFORM_STATS, db


def test_bgc_formula():
    assert prec.by_bgc(7, 7, 1024) == 24
    assert prec.by_bgc(7, 7, 16) == 18
    assert prec.by_bgc(8, 1, 256) == 17


def test_gaussian_clip_stats():
    p_c, scc = prec.gaussian_clip_stats(4.0)
    assert p_c < 1e-3  # paper: p_c < 0.001 at 4 sigma
    assert p_c > 1e-6
    # MC check
    rng = np.random.default_rng(0)
    y = rng.normal(size=5_000_000)
    emp_pc = np.mean(np.abs(y) > 4.0)
    assert abs(emp_pc - p_c) / p_c < 0.3


def test_mpc_optimal_zeta_is_four():
    """Fig. 4(b): SQNR_qy^MPC maximized at clip = 4 sigma for Gaussian."""
    for by in (6, 8, 10):
        z = prec.optimal_zeta(by)
        assert 3.0 < z < 5.2, (by, z)
    # and specifically ~4 at B_y = 8 (the paper's example)
    assert abs(prec.optimal_zeta(8) - 4.0) < 0.3


def test_mpc_sqnr_against_empirical():
    """Eq. (14) vs actually clip-quantizing Gaussian samples."""
    rng = np.random.default_rng(1)
    y = rng.normal(size=400_000)
    for by in (6, 8):
        ana = float(prec.sqnr_qy_mpc_db(by, 4.0))
        emp = 10 * np.log10(prec.sqnr_qy_mpc_empirical(y, by, 4.0))
        assert abs(ana - emp) < 0.6, (by, ana, emp)


def test_mpc_meets_40db_with_8_bits_bgc_needs_growth():
    """Fig. 4(a) anchors: MPC B_y = 8 achieves ~40 dB independent of N;
    BGC assigns 16-20 bits over the N sweep; tBGC at B_y = 8 fails for large N."""
    stats = UNIFORM_STATS
    assert float(prec.sqnr_qy_mpc_db(8, 4.0)) >= 40.0
    for n, lo, hi in [(16, 16, 20), (1024, 20, 26)]:
        assert lo <= prec.by_bgc(7, 7, n) <= hi
    # tBGC (full range, B_y = 8): degrades with N (eq. 9)
    t16 = float(prec.sqnr_qy_fullrange_db_approx(8, 16, stats))
    t1024 = float(prec.sqnr_qy_fullrange_db_approx(8, 1024, stats))
    assert t1024 < t16 - 15
    assert t1024 < 40.0  # fails the requirement


def test_mpc_by_lower_bound():
    """Eq. (15): gamma = 0.5 -> B_y >= (SNR_A + 16.3)/6."""
    for snr_a in (20.0, 30.0, 40.0):
        by = prec.by_mpc_lower_bound(snr_a, 0.5)
        assert by == int(np.ceil((snr_a + 16.3) / 6.0))


def test_snr_composition_margin():
    """SSIII-B: SQNR 9 dB above SNR -> <= 0.5 dB degradation."""
    deg = float(snr_lib.degradation_db(30.0, 39.0))
    assert deg <= 0.52
    m = float(snr_lib.margin_for_degradation(0.5))
    assert 8.5 < m < 9.7


def test_snr_t_bounded_by_snr_a():
    """The fundamental limit: SNR_T <= SNR_a regardless of precisions."""
    rng = np.random.default_rng(2)
    for _ in range(50):
        snr_a = rng.uniform(5, 45)
        qiy = rng.uniform(0, 60)
        qy = rng.uniform(0, 60)
        t = float(snr_lib.compose_snr_db(snr_a, qiy, qy))
        assert t <= snr_a + 1e-6


def test_assign_precisions_end_to_end():
    pa = prec.assign_precisions(snr_a_db=25.0, n=256, stats=UNIFORM_STATS)
    assert pa.snr_t_db > 24.0  # within ~1 dB of SNR_a
    assert pa.by <= prec.by_bgc(pa.bx, pa.bw, 256) - 4  # far fewer bits than BGC
    pa_bgc = prec.assign_precisions(
        snr_a_db=25.0, n=256, stats=UNIFORM_STATS, criterion="bgc"
    )
    assert pa_bgc.by > pa.by
