"""Substrate tests: data pipeline, checkpointing, optimizer, fault tolerance."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import manager as ckpt
from repro.data import DataConfig, MemmapCorpus, Prefetcher, SyntheticLM, host_slice
from repro.optim import AdamWConfig, adamw
from repro.runtime import FaultConfig, StepTimeout, TrainLoopRunner


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------


def test_synthetic_deterministic():
    cfg = DataConfig(seed=3, vocab_size=1000, seq_len=32, global_batch=4)
    s1, s2 = SyntheticLM(cfg), SyntheticLM(cfg)
    b1, b2 = s1.batch(17), s2.batch(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = s1.batch(18)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    assert b1["tokens"].min() >= 0 and b1["tokens"].max() < 1000


def test_host_slice_disjoint_cover():
    cfg = DataConfig(seed=0, vocab_size=100, seq_len=8, global_batch=8)
    b = SyntheticLM(cfg).batch(0)
    slices = [host_slice(b, i, 4)["tokens"] for i in range(4)]
    assert all(s.shape[0] == 2 for s in slices)
    np.testing.assert_array_equal(np.concatenate(slices), b["tokens"])


def test_memmap_corpus(tmp_path):
    data = np.arange(10_000, dtype=np.uint16) % 500
    path = str(tmp_path / "corpus.bin")
    data.tofile(path)
    cfg = DataConfig(seed=1, vocab_size=500, seq_len=64, global_batch=3,
                     corpus_path=path)
    src = MemmapCorpus(cfg)
    b = src.batch(5)
    assert b["tokens"].shape == (3, 64)
    np.testing.assert_array_equal(b["tokens"], src.batch(5)["tokens"])


def test_prefetcher():
    cfg = DataConfig(seed=0, vocab_size=50, seq_len=4, global_batch=2)
    pf = Prefetcher(SyntheticLM(cfg), start_step=10)
    steps = [next(pf)[0] for _ in range(3)]
    pf.close()
    assert steps == [10, 11, 12]


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(k, (16, 8)),
        "nested": {"b": jnp.arange(5, dtype=jnp.int32),
                   "c": jnp.float32(3.5)},
    }


def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path)
    tree = _tree()
    ckpt.save(d, 7, tree, extra={"next_step": 7})
    assert ckpt.latest_step(d) == 7
    restored, extra = ckpt.restore(d, 7, tree)
    assert extra["next_step"] == 7
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity_and_corruption(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 1, _tree(1))
    ckpt.save(d, 2, _tree(2))
    # corrupt step 2: remove an array file
    step2 = os.path.join(d, "step_00000002")
    victim = [f for f in os.listdir(step2) if f.endswith(".npy")][0]
    os.remove(os.path.join(step2, victim))
    assert ckpt.latest_step(d) == 1  # falls back to the last valid one
    # a stray .tmp dir must not count either
    os.makedirs(os.path.join(d, "step_00000009.tmp"), exist_ok=True)
    assert ckpt.latest_step(d) == 1


def test_checkpoint_digest_detects_bitrot(tmp_path):
    d = str(tmp_path)
    tree = _tree()
    ckpt.save(d, 3, tree)
    step = os.path.join(d, "step_00000003")
    f = sorted(os.listdir(step))[0]
    if f == "manifest.json":
        f = sorted(os.listdir(step))[1]
    arr = np.load(os.path.join(step, f))
    arr_fl = arr.reshape(-1)
    arr_fl[0] = arr_fl[0] + 1 if arr.dtype != np.float32 else arr_fl[0] + 1.0
    np.save(os.path.join(step, f), arr)
    with pytest.raises(IOError):
        ckpt.restore(d, 3, tree)


def test_checkpoint_cleanup(tmp_path):
    d = str(tmp_path)
    for s in range(6):
        ckpt.save(d, s, {"x": jnp.zeros(3)})
    ckpt.cleanup(d, keep=2)
    assert ckpt.latest_step(d) == 5
    remaining = [n for n in os.listdir(d) if n.startswith("step_")]
    assert len(remaining) == 2


def test_async_saver(tmp_path):
    d = str(tmp_path)
    s = ckpt.AsyncSaver()
    s.save(d, 4, _tree())
    s.wait()
    assert ckpt.latest_step(d) == 4


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_adamw_converges_quadratic():
    w = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    opt = adamw.init(w)
    cfg = AdamWConfig(lr=0.3, weight_decay=0.0)
    for _ in range(150):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(w)
        w, opt, _ = adamw.update(g, opt, w, cfg, jnp.float32(0.3))
    assert float(jnp.max(jnp.abs(w["w"]))) < 0.05


def test_adamw_grad_clip():
    w = {"w": jnp.ones(4)}
    opt = adamw.init(w)
    cfg = AdamWConfig(lr=0.1, grad_clip=1.0)
    g = {"w": jnp.full((4,), 100.0)}
    _, _, m = adamw.update(g, opt, w, cfg, jnp.float32(0.1))
    assert float(m["grad_norm"]) == pytest.approx(200.0)
    assert float(m["clip_scale"]) < 0.01


def test_adamw_no_decay_mask():
    params = {"mlp": {"wi": jnp.ones((2, 2))}, "norm1": {"scale": jnp.ones(2)}}
    cfg = AdamWConfig()
    mask = adamw._decay_mask(params, cfg)
    assert mask == [True, False]


def test_warmup_cosine_shape():
    sched = adamw.warmup_cosine(1e-3, warmup=10, total=100)
    assert float(sched(jnp.int32(0))) == pytest.approx(0.0)
    assert float(sched(jnp.int32(10))) == pytest.approx(1e-3, rel=1e-3)
    assert float(sched(jnp.int32(100))) == pytest.approx(1e-4, rel=1e-2)
    assert float(sched(jnp.int32(55))) < 1e-3


# ---------------------------------------------------------------------------
# gradient compression (error feedback)
# ---------------------------------------------------------------------------


def test_compression_error_feedback_single_device():
    """Under a 1-member axis, compressed_psum must reproduce the gradient up
    to int8 quantization, and error feedback must keep the *running sum*
    accurate (residual carries over)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.optim import compressed_psum, init_residual

    mesh = jax.make_mesh((1,), ("pod",))
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64,)),
                          jnp.float32)}
    r = init_residual(g)

    def f(g, r):
        return compressed_psum(g, r, "pod")

    fm = shard_map(f, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()))
    acc = jnp.zeros(64)
    acc_true = jnp.zeros(64)
    for i in range(20):
        gi = {"w": g["w"] * (1 + 0.1 * i)}
        out, r = fm(gi, r)
        acc = acc + out["w"]
        acc_true = acc_true + gi["w"]
    # error feedback: accumulated transmitted sum tracks the true sum to
    # within one quantization step (not 20 steps' worth)
    step = float(jnp.max(jnp.abs(acc_true)) / 127.0) * 3
    assert float(jnp.max(jnp.abs(acc - acc_true))) < step


# ---------------------------------------------------------------------------
# fault-tolerant loop
# ---------------------------------------------------------------------------


def _counter_runner(tmp_path, injector=None, deadline=None):
    def step_fn(state, batch):
        new = {"x": state["x"] + batch["inc"]}
        return new, {"loss": float(state["x"][0])}

    return TrainLoopRunner(
        step_fn=step_fn,
        init_state_fn=lambda: {"x": jnp.zeros(2)},
        batch_fn=lambda step: {"inc": jnp.ones(2)},
        cfg=FaultConfig(ckpt_dir=str(tmp_path), save_every=5,
                        max_step_retries=1, step_deadline_s=deadline,
                        max_restarts=5, async_save=False),
        failure_injector=injector,
    )


def test_fault_loop_clean_run(tmp_path):
    runner = _counter_runner(tmp_path)
    state, hist = runner.run(12)
    assert float(state["x"][0]) == 12.0
    assert hist["restarts"] == 0
    assert ckpt.latest_step(str(tmp_path)) == 12


def test_fault_loop_recovers_from_crash(tmp_path):
    crashed = {"done": False}

    def injector(step):
        if step == 7 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("injected device failure")

    runner = _counter_runner(tmp_path, injector=injector)
    state, hist = runner.run(12)
    # retry path absorbs it (max_step_retries=1) without a full restart
    assert float(state["x"][0]) == 12.0


def test_fault_loop_restart_from_checkpoint(tmp_path):
    boom = {"count": 0}

    def injector(step):
        if step == 8 and boom["count"] < 2:
            boom["count"] += 1
            raise RuntimeError("persistent failure")

    runner = _counter_runner(tmp_path, injector=injector)
    state, hist = runner.run(12)
    assert float(state["x"][0]) == 12.0
    assert hist["restarts"] >= 1  # exhausted retries once -> restarted


def test_straggler_deadline(tmp_path):
    import time

    def step_fn(state, batch):
        time.sleep(0.3)
        return state, {"loss": 0.0}

    runner = TrainLoopRunner(
        step_fn=step_fn,
        init_state_fn=lambda: {"x": jnp.zeros(1)},
        batch_fn=lambda s: {},
        cfg=FaultConfig(ckpt_dir=str(tmp_path), save_every=100,
                        step_deadline_s=0.05, max_restarts=0,
                        async_save=False),
    )
    with pytest.raises(StepTimeout):
        runner.run(2)


def test_elastic_remesh():
    from repro.runtime import elastic_remesh

    mesh = elastic_remesh()
    assert "data" in mesh.axis_names and "model" in mesh.axis_names
