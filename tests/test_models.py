"""Per-arch smoke tests (reduced configs, deliverable (f)) + decode/forward
consistency + block-level invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import ArchConfig
from repro.models import (
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
    prefill,
)

TINY = dict(
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
    max_seq=128, flash_q_block=16, flash_kv_block=16, dtype="float32",
)


def _batch(cfg, b=2, s=48, seed=1):
    toks = jax.random.randint(jax.random.PRNGKey(seed), (b, s), 0, cfg.vocab_size)
    out = {"tokens": toks}
    if cfg.modality == "vlm":
        out["prefix_embeds"] = jax.random.normal(
            jax.random.PRNGKey(seed + 1), (b, cfg.prefix_len, cfg.d_model)
        )
    return out


# ---------------------------------------------------------------------------
# (f) one smoke test per assigned architecture: forward/train step on CPU,
#     asserting output shapes + no NaNs
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", configs.ARCH_NAMES)
def test_arch_smoke_train_step(name):
    cfg = configs.get_smoke(name)
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: loss_fn(p, cfg, batch), has_aux=True
    )(params)
    assert np.isfinite(float(loss))
    assert 3.0 < float(loss) < 12.0  # ~ln(vocab) at init
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in leaves)
    logits, _ = forward(params, cfg, batch["tokens"],
                        batch.get("prefix_embeds"))
    assert logits.shape == (*batch["tokens"].shape, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("name", configs.ARCH_NAMES)
def test_arch_smoke_decode(name):
    cfg = configs.get_smoke(name)
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    toks = batch["tokens"]
    _, cache = prefill(params, cfg, toks[:, :-1], cache_len=toks.shape[1] + 8,
                       prefix_embeds=batch.get("prefix_embeds"))
    logits, cache2 = decode_step(params, cfg, toks[:, -1], cache)
    assert logits.shape == (toks.shape[0], 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


# ---------------------------------------------------------------------------
# decode == full forward (teacher-forced) for every mixer family
# ---------------------------------------------------------------------------

DECODE_CASES = {
    "dense-gqa": ArchConfig(name="t", family="dense", **TINY),
    "gemma2ish": ArchConfig(
        name="t", family="dense", **TINY, pattern=("local", "attn"), window=16,
        attn_softcap=50.0, final_softcap=30.0, post_norm=True, emb_scale=True,
    ),
    "mqa-learned": ArchConfig(
        name="t", family="dense", **{**TINY, "n_kv_heads": 1}, mlp_kind="gelu",
        pos_kind="learned", norm_kind="layernorm",
    ),
    "moe-dropless": ArchConfig(
        name="t", family="moe", **TINY, n_experts=8, top_k=2,
        moe_group_size=32, capacity_factor=8.0,
    ),
    "mamba2": ArchConfig(
        name="t", family="ssm", **{**TINY, "n_heads": 1, "n_kv_heads": 1,
                                   "d_ff": 0},
        pattern=("ssm",), ssm_state=16, ssm_expand=2, ssm_head_dim=16,
        ssm_chunk=16,
    ),
    "rg-hybrid": ArchConfig(
        name="t", family="hybrid", **{**TINY, "n_layers": 5, "n_kv_heads": 1},
        pattern=("rglru", "rglru", "local"), window=16, rnn_width=64,
        mlp_kind="geglu",
    ),
}


@pytest.mark.parametrize("case", list(DECODE_CASES))
def test_decode_matches_forward(case):
    cfg = DECODE_CASES[case]
    params = init_params(jax.random.PRNGKey(0), cfg)
    b, s, n_dec = 2, 33, 4
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)
    full, _ = forward(params, cfg, toks)
    _, cache = prefill(params, cfg, toks[:, : s - n_dec], cache_len=s + 4)
    for t in range(s - n_dec, s):
        lg, cache = decode_step(params, cfg, toks[:, t], cache)
        np.testing.assert_allclose(
            np.asarray(lg[:, 0]), np.asarray(full[:, t]), rtol=2e-4, atol=2e-4
        )


def test_decode_beyond_window_ring_buffer():
    """Sliding-window decode must stay consistent after the ring buffer wraps."""
    cfg = DECODE_CASES["gemma2ish"]
    params = init_params(jax.random.PRNGKey(0), cfg)
    b, s = 2, 56  # window is 16; decode through >2 wraps
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)
    full, _ = forward(params, cfg, toks)
    _, cache = prefill(params, cfg, toks[:, :8], cache_len=s + 4)
    for t in range(8, s):
        lg, cache = decode_step(params, cfg, toks[:, t], cache)
    np.testing.assert_allclose(
        np.asarray(lg[:, 0]), np.asarray(full[:, -1]), rtol=2e-4, atol=2e-4
    )


def test_ssd_chunk_size_invariance():
    """SSD output must be invariant to the chunk size (algorithmic identity)."""
    base = DECODE_CASES["mamba2"]
    params = init_params(jax.random.PRNGKey(0), base)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 40), 0, base.vocab_size)
    outs = []
    for chunk in (8, 16, 40):
        cfg = base.replace(ssm_chunk=chunk)
        logits, _ = forward(params, cfg, toks)
        outs.append(np.asarray(logits))
    np.testing.assert_allclose(outs[0], outs[1], rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(outs[0], outs[2], rtol=2e-4, atol=2e-4)


def test_rglru_associative_scan_vs_sequential():
    """The associative-scan recurrence equals the sequential definition."""
    from repro.models import rglru

    cfg = DECODE_CASES["rg-hybrid"]
    params = rglru.init_rglru(jax.random.PRNGKey(3), cfg.d_model, cfg.rnn_width,
                              cfg.rnn_conv_width, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 24, cfg.d_model))
    y_fast, h_fast = rglru.rglru_forward(params, x, cfg)
    # sequential reference via repeated decode steps
    cache = rglru.init_rglru_cache(2, cfg.rnn_width, cfg.rnn_conv_width,
                                   jnp.float32)
    ys = []
    for t in range(24):
        y_t, cache = rglru.rglru_decode(params, x[:, t : t + 1], cache, cfg)
        ys.append(y_t)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_fast), np.asarray(y_seq),
                               rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(h_fast), np.asarray(cache["h"]),
                               rtol=5e-4, atol=5e-4)


def test_flash_vs_banded_window_equivalence():
    """Window attention: masked-flash path == banded path."""
    from repro.models.attention import AttnDims, banded_attention, flash_attention

    dims = AttnDims(4, 2, 16, 16**-0.5, None, 24, 16, 16, 1e4, False)
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(k1, (2, 64, 4, 16))
    k = jax.random.normal(k2, (2, 64, 2, 16))
    v = jax.random.normal(k3, (2, 64, 2, 16))
    np.testing.assert_allclose(
        np.asarray(flash_attention(q, k, v, dims)),
        np.asarray(banded_attention(q, k, v, dims)),
        rtol=1e-5, atol=1e-5,
    )


def test_param_count_matches_init():
    """ArchConfig.param_count (used for MODEL_FLOPS) vs actual init sizes."""
    from repro.models.model import param_count

    for name in ("gemma2-9b", "mamba2-2.7b", "granite-moe-1b-a400m"):
        cfg = configs.get_smoke(name)
        params = init_params(jax.random.PRNGKey(0), cfg)
        actual = param_count(params)
        predicted = cfg.param_count()
        assert abs(actual - predicted) / actual < 0.05, (name, actual, predicted)
