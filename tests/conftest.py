"""Test config: tests run on the single real CPU device (the 512-device
dry-run is exercised only via subprocesses in test_distributed/test_dryrun)."""
import os

# make sure no leaked XLA_FLAGS turn tests multi-device
os.environ.pop("XLA_FLAGS", None)

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
