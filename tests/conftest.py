"""Test config: tests run on the single real CPU device (the 512-device
dry-run is exercised only via subprocesses in test_distributed/test_dryrun)."""
import os

# make sure no leaked XLA_FLAGS turn tests multi-device
os.environ.pop("XLA_FLAGS", None)

import numpy as np
import pytest

try:
    from hypothesis import settings as _hyp_settings
except ImportError:  # pragma: no cover - dev extra always carries hypothesis
    _hyp_settings = None

if _hyp_settings is not None:
    # pinned deterministic CI profile: derandomized example generation (no
    # fresh-entropy flakes across the python matrix) and a fixed disabled
    # deadline (shared CI boxes blow any wall-clock deadline spuriously).
    # CI selects it via HYPOTHESIS_PROFILE=ci; local runs keep the default
    # randomized search (better bug-finding) minus the deadline.
    _hyp_settings.register_profile(
        "ci", derandomize=True, deadline=None, max_examples=100,
        print_blob=True)
    _hyp_settings.register_profile("dev", deadline=None)
    _hyp_settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))


@pytest.fixture(scope="module", autouse=True)
def _bounded_xla_executable_accumulation():
    """Drop compiled-executable caches at every module boundary.  A full
    tier-1 run compiles hundreds of engine scans into ONE process; past a
    few hundred live executables the CPU XLA client has been observed to
    segfault inside backend_compile (deterministically, on the next scan
    compile).  Per-module clearing bounds the live set; tests never share
    compiled functions across modules, so this only costs recompiles."""
    import jax

    jax.clear_caches()
    yield


@pytest.fixture
def rng():
    return np.random.default_rng(0)
