"""Test config: tests run on the single real CPU device (the 512-device
dry-run is exercised only via subprocesses in test_distributed/test_dryrun)."""
import os

# make sure no leaked XLA_FLAGS turn tests multi-device
os.environ.pop("XLA_FLAGS", None)

import numpy as np
import pytest


@pytest.fixture(scope="module", autouse=True)
def _bounded_xla_executable_accumulation():
    """Drop compiled-executable caches at every module boundary.  A full
    tier-1 run compiles hundreds of engine scans into ONE process; past a
    few hundred live executables the CPU XLA client has been observed to
    segfault inside backend_compile (deterministically, on the next scan
    compile).  Per-module clearing bounds the live set; tests never share
    compiled functions across modules, so this only costs recompiles."""
    import jax

    jax.clear_caches()
    yield


@pytest.fixture
def rng():
    return np.random.default_rng(0)
