"""IMCLinear execution modes: SNR ordering, analytics tracking, gradients."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.imc_linear import DIGITAL, IMCConfig, layer_rng, linear

K1, K2, K3 = jax.random.split(jax.random.PRNGKey(0), 3)
X = jax.random.normal(K1, (32, 1024))
W = jax.random.normal(K2, (1024, 256)) / 32
Y0 = X @ W


def _snr_db(y):
    err = y - Y0
    err = err - jnp.mean(err)
    return 10 * np.log10(float(jnp.var(Y0)) / float(jnp.mean(err**2)))


def test_digital_exact():
    np.testing.assert_allclose(np.asarray(linear(W, X)), np.asarray(Y0),
                               rtol=1e-6)


def test_mode_snr_ordering():
    fq = _snr_db(linear(W, X, IMCConfig(mode="fakequant", bx=7, bw=7), rng=K3))
    an = _snr_db(linear(W, X, IMCConfig(mode="imc_analytic", bx=7, bw=7),
                        rng=K3))
    bs = _snr_db(linear(W, X, IMCConfig(mode="imc_bitserial", bx=7, bw=7),
                        rng=K3))
    assert fq > an  # analog noise on top of quantization
    assert fq > bs
    assert an > 10 and bs > 10  # still usable per paper SSIII-B requirement


def test_analytic_mode_tracks_snr_a():
    for snr_a in (15.0, 25.0, 35.0):
        cfg = IMCConfig(mode="imc_analytic", bx=8, bw=8, snr_a_db=snr_a)
        got = _snr_db(linear(W, X, cfg, rng=K3))
        assert abs(got - snr_a) < 2.5, (snr_a, got)


def test_bitserial_tracks_design_point():
    for v_wl in (0.6, 0.7, 0.8):
        cfg = IMCConfig(mode="imc_bitserial", bx=7, bw=7, v_wl=v_wl)
        pred = cfg.resolved_snr_a_db(1024)
        got = _snr_db(linear(W, X, cfg, rng=K3))
        assert abs(got - pred) < 2.5, (v_wl, pred, got)


def test_auto_banking_respects_nmax():
    cfg = IMCConfig(mode="imc_bitserial", bx=6, bw=6, v_wl=0.8)
    assert cfg.bank_rows(1024) <= 256  # N_max ~ 125 at 0.8 V -> 128 banks
    cfg2 = IMCConfig(mode="imc_bitserial", bx=6, bw=6, v_wl=0.6)
    assert cfg2.bank_rows(1024) >= cfg.bank_rows(1024)


def test_grads_through_fakequant_and_analytic():
    for mode in ("fakequant", "imc_analytic"):
        cfg = IMCConfig(mode=mode, bx=6, bw=6, snr_a_db=25.0)
        g = jax.grad(lambda w: jnp.mean(linear(w, X, cfg, rng=K3) ** 2))(W)
        assert bool(jnp.all(jnp.isfinite(g)))
        assert float(jnp.max(jnp.abs(g))) > 0


def test_noise_reproducible_and_keyed():
    cfg = IMCConfig(mode="imc_analytic", bx=7, bw=7, snr_a_db=20.0)
    y1 = linear(W, X, cfg, rng=K3)
    y2 = linear(W, X, cfg, rng=K3)
    y3 = linear(W, X, cfg, rng=jax.random.PRNGKey(42))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2))
    assert not np.allclose(np.asarray(y1), np.asarray(y3))


def test_layer_rng():
    assert layer_rng(None, 3) is None
    a, b = layer_rng(K1, 1), layer_rng(K1, 2)
    assert not np.array_equal(np.asarray(a), np.asarray(b))


def test_bias_and_leading_dims():
    cfg = IMCConfig(mode="fakequant", bx=6, bw=6)
    x3 = X.reshape(4, 8, 1024)
    bias = jnp.ones((256,))
    y = linear(W, x3, cfg, rng=K3, bias=bias)
    assert y.shape == (4, 8, 256)


def test_noise_aware_training_reduces_loss():
    """QAT-style sanity: a few SGD steps through imc_analytic reduce loss."""
    cfg = IMCConfig(mode="imc_analytic", bx=6, bw=6, snr_a_db=22.0)
    target = jax.random.normal(jax.random.PRNGKey(9), (32, 16))
    w = jax.random.normal(jax.random.PRNGKey(10), (1024, 16)) * 0.01

    def loss(w, key):
        return jnp.mean((linear(w, X, cfg, rng=key) - target) ** 2)

    l0 = float(loss(w, K3))
    for i in range(30):
        g = jax.grad(loss)(w, jax.random.fold_in(K3, i))
        w = w - 0.05 * g
    l1 = float(loss(w, K3))
    assert l1 < 0.7 * l0
