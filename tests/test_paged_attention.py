"""Paged-attention decode kernel equivalence suite.

Unit level: the streamed online-softmax kernel (pure-JAX fallback AND the
Pallas kernel under interpret mode) against the gather-path oracle
``ref.paged_attention_ref`` - per-step ctx allclose, pool updates bit-exact
outside the garbage block, the garbage-block-0 write-routing contract
(inactive rows, OVERRUN rows), and block-boundary crossing.

Serve level: greedy token streams from the fused-kernel engine
(``decode_attn="kernel"``, the default) are bit-identical to the gather
escape hatch (``decode_attn="gather"``) across digital / imc_analytic /
imc_bitserial under FROZEN calibration on the committed mixed 4..48-token
workload - including multi-block slots and recompute-preemption/resume under
a tight physical pool.

Plus the two satellite pins that ride along this PR: the
``attention_forward`` window >= S dispatch equivalence and the
``slo_summary`` zero-elapsed goodput guard.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.imc_linear import IMCConfig
from repro.core.substrate import as_substrate, calibrate_model
from repro.kernels.paged_attention import paged_attention_decode, write_routing
from repro.kernels.ref import paged_attention_ref
from repro.launch.serve import Engine, Request, serve
from repro.models import init_params
from repro.models.attention import AttnDims, attention_forward, init_attention

SCALE = 0.25

# the committed serve-bench mixed short/long workload (serve_bench.MIXED_LENS)
MIXED_LENS = [4, 6, 48, 5, 8, 44, 6, 7]


# ---------------------------------------------------------------------------
# fixtures
# ---------------------------------------------------------------------------


def _paged_state(seed=0, b=4, mb=6, bs=8, nb=24, hkv=2, g=2, hd=16,
                 pos=(3, 11, 29, 47)):
    """Random pools + a disjoint block table (block 0 = garbage)."""
    rng = np.random.default_rng(seed)
    f32 = jnp.float32
    q = jnp.asarray(rng.normal(size=(b, hkv, g, hd)), f32)
    kn = jnp.asarray(rng.normal(size=(b, hkv, hd)), f32)
    vn = jnp.asarray(rng.normal(size=(b, hkv, hd)), f32)
    pk = jnp.asarray(rng.normal(size=(nb, bs, hkv, hd)), f32)
    pv = jnp.asarray(rng.normal(size=(nb, bs, hkv, hd)), f32)
    bt = np.zeros((b, mb), np.int32)
    ids = iter(range(1, nb))
    for row, p in enumerate(pos):
        for j in range(min(p // bs + 1, mb)):
            bt[row, j] = next(ids)
    return q, kn, vn, pk, pv, jnp.asarray(bt), jnp.asarray(pos, jnp.int32)


def _all_paths(state, active=None, softcap=None):
    q, kn, vn, pk, pv, bt, pos_b = state
    ref = paged_attention_ref(q, kn, vn, pk, pv, bt, pos_b, active,
                              scale=SCALE, softcap=softcap)
    fb = paged_attention_decode(q, kn, vn, pk, pv, bt, pos_b, active,
                                scale=SCALE, softcap=softcap,
                                use_pallas=False)
    pal = paged_attention_decode(q, kn, vn, pk, pv, bt, pos_b, active,
                                 scale=SCALE, softcap=softcap,
                                 use_pallas=True, interpret=True)
    return ref, fb, pal


# ---------------------------------------------------------------------------
# kernel vs gather-path oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("softcap", [None, 30.0])
def test_kernel_matches_gather_oracle(softcap):
    """Fallback and Pallas-interpret kernel vs the full-softmax gather
    oracle: ctx to tight allclose (online vs full softmax round differently
    in the last ulps), pools bit-exact outside garbage block 0."""
    state = _paged_state()
    active = jnp.asarray([True, True, False, True])
    (ctx_r, pk_r, pv_r), (ctx_f, pk_f, pv_f), (ctx_p, pk_p, pv_p) = \
        _all_paths(state, active, softcap)
    np.testing.assert_allclose(ctx_f, ctx_r, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(ctx_p, ctx_r, atol=1e-5, rtol=1e-5)
    # the streamed recurrence is the same math in both implementations
    np.testing.assert_allclose(ctx_p, ctx_f, atol=1e-6, rtol=1e-6)
    for got_k, got_v in ((pk_f, pv_f), (pk_p, pv_p)):
        assert jnp.array_equal(got_k[1:], pk_r[1:])
        assert jnp.array_equal(got_v[1:], pv_r[1:])


def test_kernel_matches_oracle_per_step_across_block_boundary():
    """Walk a slot's position across a block boundary one token at a time
    (bs-2 .. bs+2): the kernel must match the oracle at EVERY step, with the
    pool state threaded through (tail block fills up, then a fresh block)."""
    bs, mb, nb, hkv, g, hd = 4, 4, 10, 2, 1, 8
    rng = np.random.default_rng(3)
    f32 = jnp.float32
    pk = jnp.asarray(rng.normal(size=(nb, bs, hkv, hd)), f32)
    pv = jnp.asarray(rng.normal(size=(nb, bs, hkv, hd)), f32)
    bt = jnp.asarray([[1, 2, 3, 0]], jnp.int32)
    pk_k, pv_k = pk, pv  # kernel-path pool state
    pk_o, pv_o = pk, pv  # oracle-path pool state
    for pos in range(bs - 2, bs + 3):
        q = jnp.asarray(rng.normal(size=(1, hkv, g, hd)), f32)
        kn = jnp.asarray(rng.normal(size=(1, hkv, hd)), f32)
        vn = jnp.asarray(rng.normal(size=(1, hkv, hd)), f32)
        pos_b = jnp.asarray([pos], jnp.int32)
        ctx_o, pk_o, pv_o = paged_attention_ref(
            q, kn, vn, pk_o, pv_o, bt, pos_b, None, scale=SCALE)
        ctx_k, pk_k, pv_k = paged_attention_decode(
            q, kn, vn, pk_k, pv_k, bt, pos_b, None, scale=SCALE,
            use_pallas=True, interpret=True)
        np.testing.assert_allclose(ctx_k, ctx_o, atol=1e-5, rtol=1e-5)
        assert jnp.array_equal(pk_k[1:], pk_o[1:]), pos
        assert jnp.array_equal(pv_k[1:], pv_o[1:]), pos


# ---------------------------------------------------------------------------
# garbage-block-0 write routing (the tail-clobber bugfix)
# ---------------------------------------------------------------------------


def test_write_routing_contract():
    bt = jnp.asarray([[3, 4, 0], [5, 6, 7]], jnp.int32)
    # in-range: tail block; overrun (pos // bs >= max_blocks): garbage 0
    dest, off = write_routing(bt, jnp.asarray([9, 27], jnp.int32), 8, None)
    assert dest.tolist() == [4, 0]  # row 1 overran 3 blocks * 8
    assert off.tolist() == [1, 3]
    # inactive rows always route to garbage 0
    dest, _ = write_routing(bt, jnp.asarray([9, 9], jnp.int32), 8,
                            jnp.asarray([False, True]))
    assert dest.tolist() == [0, 6]


@pytest.mark.parametrize("path", ["gather", "fallback", "pallas"])
def test_overrun_write_does_not_clobber_tail_block(path):
    """The satellite bugfix pin: a position past the slot's capacity used to
    clip into the LAST logical block, overwriting a live token.  All three
    implementations must route the overrun write to garbage block 0 and
    leave every allocated block untouched."""
    bs, mb = 4, 3
    state = _paged_state(seed=5, b=2, mb=mb, bs=bs, nb=8, hkv=2, g=1, hd=8,
                         pos=(mb * bs, 5))  # row 0 exactly one past capacity
    q, kn, vn, pk, pv, bt, pos_b = state
    if path == "gather":
        _, pk2, pv2 = paged_attention_ref(q, kn, vn, pk, pv, bt, pos_b, None,
                                          scale=SCALE)
    else:
        _, pk2, pv2 = paged_attention_decode(
            q, kn, vn, pk, pv, bt, pos_b, None, scale=SCALE,
            use_pallas=path == "pallas", interpret=True)
    # row 0's allocated blocks (all of bt[0]) keep their pre-step contents
    for blk in np.asarray(bt[0]):
        if blk == 0:
            continue
        assert jnp.array_equal(pk2[blk], pk[blk]), blk
        assert jnp.array_equal(pv2[blk], pv[blk]), blk
    # row 1 (in range) still landed its write at its tail block
    tail = int(bt[1, pos_b[1] // bs])
    assert jnp.array_equal(pk2[tail, pos_b[1] % bs], kn[1].astype(pk.dtype))


def test_inactive_row_writes_garbage_and_attends_stale():
    """An inactive row's write must land in garbage block 0, and its ctx must
    equal the gather path's (which attends the STALE tail value, since the
    new K/V never reached the row's tail block)."""
    state = _paged_state(seed=6, b=2, mb=3, bs=4, nb=8, hkv=2, g=1, hd=8,
                         pos=(5, 6))
    active = jnp.asarray([True, False])
    (ctx_r, pk_r, _), (ctx_f, pk_f, _), (ctx_p, pk_p, _) = \
        _all_paths(state, active)
    q, kn, vn, pk, pv, bt, pos_b = state
    tail1 = int(bt[1, pos_b[1] // 4])
    for got in (pk_r, pk_f, pk_p):
        assert jnp.array_equal(got[tail1], pk[tail1])  # stale tail kept
    np.testing.assert_allclose(ctx_f, ctx_r, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(ctx_p, ctx_r, atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# serve level: kernel vs gather escape hatch, three substrates, frozen calib
# ---------------------------------------------------------------------------


def _frozen_cfg(substrate):
    base = configs.get_smoke("musicgen-medium")
    if substrate == "digital":
        return base, init_params(jax.random.PRNGKey(0), base)
    cfg_dyn = base.replace(
        imc=IMCConfig(mode=substrate, bx=7, bw=7, v_wl=0.7))
    params = init_params(jax.random.PRNGKey(0), cfg_dyn)
    ref_batch = np.random.default_rng(1).integers(
        0, base.vocab_size, (2, 24))
    cfg = calibrate_model(cfg_dyn, params, [ref_batch])
    assert as_substrate(cfg.imc).policy == "frozen"
    return cfg, params


def _serve_tokens(cfg, params, lens, max_new, kv_blocks=None, block=8):
    rnp = np.random.default_rng(11)
    reqs = [Request(rid=i, prompt=rnp.integers(0, cfg.vocab_size, l),
                    max_new=max_new)
            for i, l in enumerate(lens)]
    cache_len = 48 + max_new + 8
    engine = Engine(cfg, params, batch_slots=4, cache_len=cache_len,
                    max_chunk=4, block_size=block, kv_blocks=kv_blocks)
    done = serve(engine, reqs)
    assert all(r.error is None for r in done)
    return {r.rid: r.out for r in done}, engine


@pytest.mark.parametrize("substrate",
                         ["digital", "imc_analytic", "imc_bitserial"])
def test_serve_kernel_vs_gather_bit_identical(substrate):
    """The acceptance pin: on the committed mixed 4..48-token workload the
    fused-kernel engine emits bit-identical greedy token streams to the
    gather escape hatch on every substrate (frozen calibration: batch
    composition cannot leak in).  The 44/48-token prompts make multi-block
    slots (6 blocks of 8) and generation crosses block boundaries."""
    cfg, params = _frozen_cfg(substrate)
    lens = MIXED_LENS if substrate != "imc_bitserial" else MIXED_LENS[:4]
    max_new = 6 if substrate != "imc_bitserial" else 4
    out_k, _ = _serve_tokens(cfg.replace(decode_attn="kernel"), params,
                             lens, max_new)
    out_g, _ = _serve_tokens(cfg.replace(decode_attn="gather"), params,
                             lens, max_new)
    assert out_k == out_g, (substrate, out_k, out_g)


def test_serve_kernel_preemption_resume_bit_identical():
    """Recompute-preemption under a tight pool (lazy alloc) with the kernel
    enabled: the preempted-and-resumed run must reproduce the ample-pool
    kernel run AND the gather-path run token for token."""
    cfg, params = _frozen_cfg("imc_analytic")
    lens, max_new = [4, 6, 48, 5], 6
    cfg_k = cfg.replace(decode_attn="kernel")
    out_ample, _ = _serve_tokens(cfg_k, params, lens, max_new)
    out_tight, eng = _serve_tokens(cfg_k, params, lens, max_new, kv_blocks=12)
    assert eng.preempt_count >= 1, "tight pool never preempted"
    out_gather, _ = _serve_tokens(cfg.replace(decode_attn="gather"), params,
                                  lens, max_new, kv_blocks=12)
    assert out_tight == out_ample
    assert out_tight == out_gather


# ---------------------------------------------------------------------------
# satellite pins: window >= S dispatch, slo_summary zero-elapsed guard
# ---------------------------------------------------------------------------


def test_attention_forward_window_ge_seq_matches_no_window():
    """window >= S must take the flash path with the window mask DROPPED and
    reproduce the window=None result bit-exactly (a window covering every
    causal pair is a no-op) - the old dispatch kept the window in dims and
    silently relied on the flash mask being a causal no-op."""
    b, s, hq, hkv, hd = 2, 12, 4, 2, 8
    params = init_attention(jax.random.PRNGKey(2), 32, hq, hkv, hd,
                            jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (b, s, 32), jnp.float32)
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    base = dict(n_heads=hq, n_kv=hkv, head_dim=hd, scale=hd**-0.5,
                softcap_val=None, q_block=8, kv_block=8, rope_theta=1e4,
                use_rope=True)
    y_nowin = attention_forward(params, x, AttnDims(**base, window=None), positions)
    for window in (s, s + 5, 10**6):
        y_win = attention_forward(params, x, AttnDims(**base, window=window),
                                  positions)
        assert jnp.array_equal(y_win, y_nowin), window


def test_slo_summary_zero_elapsed():
    """elapsed == 0 (empty or instantly-drained workload) must not raise and
    must not fabricate a ~1e9x goodput: 0.0 when nothing met its SLO, NaN
    (undefined rate, like percentile() on empty input) when something did."""
    from repro.launch.metering import slo_summary

    s = slo_summary([], elapsed=0.0)
    assert s["goodput"] == 0.0 and s["goodput_tokens"] == 0.0
    assert s["requests"] == 0

    class _Req:
        preemptions = 0
        shed = False
        error = None
        ttft_deadline = None
        itl_deadline = None
        out = [1, 2, 3]
        token_times = []
        arrive_at = 0.0
        t_submit = 0.0
        t_first = 0.0

    s = slo_summary([_Req()], elapsed=0.0)
    assert s["slo_met"] == 1
    assert np.isnan(s["goodput"]) and np.isnan(s["goodput_tokens"])
    # sane elapsed still divides
    s = slo_summary([_Req()], elapsed=2.0)
    assert s["goodput"] == 0.5 and s["goodput_tokens"] == 1.5
