"""Dry-run machinery unit tests (no 512-device compile: pure helpers +
shape/skip logic; full-scale compiles are exercised by the sweep itself and
results are validated from artifacts when present)."""
import glob
import json
import os

import pytest

from repro import configs
from repro.configs.shapes import SHAPES, input_specs, shape_applicable


def test_skip_matrix():
    """Exactly 8 archs skip long_500k; no other (arch, shape) skips."""
    skips = []
    for name in configs.ARCH_NAMES:
        cfg = configs.get(name)
        for sname, shape in SHAPES.items():
            if shape_applicable(cfg, shape):
                skips.append((name, sname))
    assert all(s == "long_500k" for _, s in skips)
    assert len(skips) == 8
    assert ("mamba2-2.7b", "long_500k") not in skips
    assert ("recurrentgemma-2b", "long_500k") not in skips


def test_input_specs_shapes():
    for name in configs.ARCH_NAMES:
        cfg = configs.get(name)
        for sname, shape in SHAPES.items():
            sds = input_specs(cfg, shape)
            if shape.kind in ("train", "prefill"):
                assert sds["tokens"].shape == (shape.global_batch, shape.seq_len)
                if cfg.modality == "vlm":
                    assert sds["prefix_embeds"].shape == (
                        shape.global_batch, cfg.prefix_len, cfg.d_model)
            else:
                assert sds["token"].shape == (shape.global_batch,)


def test_collective_wire_model():
    from repro.launch.hlo_analysis import collective_wire_bytes

    assert collective_wire_bytes({"all-reduce": 100.0}) == 200.0
    assert collective_wire_bytes({"all-gather": 100.0, "all-to-all": 50.0}) == 150.0


def test_layout_mesh_parse():
    import subprocess
    import sys

    script = (
        "import os; os.environ['XLA_FLAGS']="
        "'--xla_force_host_platform_device_count=16';"
        "import sys; sys.path.insert(0,'src');"
        "from repro.launch.dryrun import make_layout_mesh;"
        "m=make_layout_mesh('4x4'); assert m.axis_names==('data','model');"
        "m2=make_layout_mesh('2x4x2'); assert m2.axis_names==('pod','data','model');"
        "print('LAYOUT_OK')"
    )
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))), env=env, timeout=600)
    assert "LAYOUT_OK" in r.stdout, r.stderr[-1000:]


ARTIFACTS = sorted(glob.glob(os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "results", "dryrun", "*.json")))


@pytest.mark.skipif(not ARTIFACTS, reason="no dry-run artifacts present")
def test_dryrun_artifacts_valid():
    """Every present artifact is ok/skipped with coherent roofline fields."""
    n_ok = n_skip = 0
    for f in ARTIFACTS:
        with open(f) as fh:
            r = json.load(fh)
        assert r["status"] in ("ok", "skipped"), (f, r.get("error"))
        if r["status"] == "skipped":
            n_skip += 1
            assert "sub-quadratic" in r["reason"]
            continue
        n_ok += 1
        rf = r["roofline"]
        assert rf["t_compute_s"] >= 0 and rf["t_memory_s"] >= 0
        assert r["cost"]["flops"] > 0
        assert r["memory"]["temp_bytes"] >= 0
        assert 0 < r["useful_flops_ratio"] < 3.0, f
    assert n_ok >= 1
