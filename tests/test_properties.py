"""Hypothesis property sweeps for the quantizer, the bit-serial oracle and
the paged-KV block allocator.

Kept in their own module, guarded with ``pytest.importorskip``: the tier-1
suite collects and passes without hypothesis installed (this file skips
wholesale), and the property tests run whenever the ``dev`` extra is present.
"""
import pytest

pytest.importorskip("hypothesis")

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.quant import (
    QuantSpec,
    bit_planes,
    combine_bit_planes,
    fakequant,
)
from repro.kernels import ref
from repro.kernels.ref import BitSerialSpec, quantize_codes
from repro.launch.serve import BlockAllocator


# ---------------------------------------------------------------------------
# quantizer invariants (from test_quant.py)
# ---------------------------------------------------------------------------


@given(
    bits=st.integers(2, 10),
    signed=st.booleans(),
    max_val=st.floats(0.1, 100.0),
    seed=st.integers(0, 2**16),
)
@settings(max_examples=30, deadline=None)
def test_quantizer_error_bounded(bits, signed, max_val, seed):
    spec = QuantSpec(bits, signed, max_val)
    rng = np.random.default_rng(seed)
    lo = -max_val if signed else 0.0
    x = rng.uniform(lo, max_val, size=(256,))
    xq = np.asarray(fakequant(jnp.asarray(x), spec))
    # in-range values: error <= Delta/2 (+ Delta at the top clip edge)
    assert np.all(np.abs(xq - x) <= spec.delta * 1.001 + 1e-7)


@given(bits=st.integers(2, 10), signed=st.booleans(), seed=st.integers(0, 2**16))
@settings(max_examples=30, deadline=None)
def test_quantize_idempotent(bits, signed, seed):
    spec = QuantSpec(bits, signed, 1.0)
    rng = np.random.default_rng(seed)
    x = rng.uniform(-1 if signed else 0, 1, size=(128,))
    once = fakequant(jnp.asarray(x), spec)
    twice = fakequant(once, spec)
    assert np.allclose(np.asarray(once), np.asarray(twice))


@given(bits=st.integers(2, 9), signed=st.booleans(), seed=st.integers(0, 2**16))
@settings(max_examples=30, deadline=None)
def test_bit_plane_roundtrip(bits, signed, seed):
    rng = np.random.default_rng(seed)
    lo = -(2 ** (bits - 1)) if signed else 0
    hi = (2 ** (bits - 1)) if signed else 2**bits
    codes = jnp.asarray(rng.integers(lo, hi, size=(64,)), jnp.float32)
    planes, weights = bit_planes(codes, bits, signed)
    assert np.all((np.asarray(planes) == 0) | (np.asarray(planes) == 1))
    rec = combine_bit_planes(planes, weights)
    assert np.allclose(np.asarray(rec), np.asarray(codes))


# ---------------------------------------------------------------------------
# bit-serial oracle invariant (from test_kernels.py)
# ---------------------------------------------------------------------------


def _codes(key, b, k, m, bx, bw, x_signed):
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (b, k))
    if not x_signed:
        x = jnp.abs(x)
    w = jax.random.normal(k2, (k, m))
    xc, _ = quantize_codes(x, bx, x_signed, jnp.max(jnp.abs(x)))
    wc, _ = quantize_codes(w, bw, True, jnp.max(jnp.abs(w)))
    return xc, wc


@given(
    b=st.integers(1, 40),
    k=st.integers(8, 600),
    m=st.integers(1, 90),
    bx=st.integers(2, 8),
    bw=st.integers(2, 8),
    xs=st.booleans(),
)
@settings(max_examples=12, deadline=None)
def test_bitserial_ref_wide_open_property(b, k, m, bx, bw, xs):
    """Hypothesis sweep of the oracle itself: exactness invariant."""
    key = jax.random.PRNGKey(b * 1000 + k + m)
    xc, wc = _codes(key, b, k, m, bx, bw, xs)
    spec = BitSerialSpec(bx=bx, bw=bw, b_adc=16, rows=min(512, k), k_h=1e9,
                         v_c=1e9, x_signed=xs, apply_adc=False)
    yr = ref.imc_bitserial_ref(xc, wc, None, spec)
    np.testing.assert_allclose(np.asarray(yr), np.asarray(xc @ wc), rtol=1e-6)


# ---------------------------------------------------------------------------
# paged-KV block allocator invariants (serve engine)
# ---------------------------------------------------------------------------


@given(
    num_blocks=st.integers(2, 64),
    ops=st.lists(
        st.tuples(st.booleans(), st.integers(0, 12)), min_size=1, max_size=60
    ),
)
@settings(max_examples=60, deadline=None)
def test_block_allocator_never_double_allocates(num_blocks, ops):
    """Arbitrary admit/finish interleavings: every live allocation is
    disjoint, block 0 is never handed out, the free count is conserved, and
    a released request's blocks are immediately reusable."""
    alloc = BlockAllocator(num_blocks)
    capacity = num_blocks - 1  # block 0 reserved
    live = []  # list of allocated block-lists (simulated active requests)

    def check_invariants():
        held = [b for blocks in live for b in blocks]
        assert 0 not in held
        assert len(held) == len(set(held))  # no double allocation
        assert all(1 <= b < num_blocks for b in held)
        assert alloc.free_count + len(held) == capacity  # conservation
        assert alloc.used_count == len(held)

    for is_admit, n in ops:
        if is_admit:
            free_before = alloc.free_count
            got = alloc.alloc(n)
            if n > free_before:
                assert got is None  # all-or-nothing: no partial allocation
                assert alloc.free_count == free_before  # nothing leaked
            else:
                assert got is not None and len(got) == n
                live.append(got)
        elif live:
            freed = live.pop(n % len(live))  # finish an arbitrary request
            alloc.free(freed)
            if freed:
                # released blocks are reusable right away
                again = alloc.alloc(len(freed))
                assert again is not None and set(again) <= set(
                    range(1, num_blocks))
                live.append(again)
        check_invariants()

    for blocks in live:
        alloc.free(blocks)
    assert alloc.free_count == capacity and alloc.used_count == 0


@given(
    num_blocks=st.integers(2, 32),
    ops=st.lists(
        st.tuples(st.integers(0, 4), st.integers(0, 12)),
        min_size=1, max_size=80,
    ),
)
@settings(max_examples=60, deadline=None)
def test_block_allocator_refcount_sharing_invariants(num_blocks, ops):
    """The prefix-sharing extension under arbitrary interleavings of admit /
    share (retain) / release / register-cached / evict: the REFINED
    conservation law ``free + referenced + idle_cached == capacity`` holds,
    allocator refcounts agree with a model count, referenced blocks are
    never evictable, and a fresh allocation never aliases a block that is
    still referenced or cached (the invariant CoW relies on)."""
    alloc = BlockAllocator(num_blocks)
    capacity = num_blocks - 1
    live = []      # per-sharer block lists (each entry holds one reference)
    cached = set()  # blocks handed to the prefix index

    def check():
        refs = {}
        for blocks in live:
            for b in blocks:
                refs[b] = refs.get(b, 0) + 1
        held = set(refs) | cached
        assert 0 not in held
        for b, n in refs.items():
            assert alloc.refcount(b) == n
        # refined conservation: referenced + idle cached + free == capacity
        assert alloc.free_count + len(held) == capacity
        assert alloc.used_count == len(held)
        assert alloc.evictable_count == len(cached - set(refs))
        for b in cached:
            assert alloc.is_evictable(b) == (b not in refs)

    for op, n in ops:
        if op == 0:  # admit: fresh allocation, one reference per block
            got = alloc.alloc(max(1, n % 4))
            if got is not None:
                # CoW-safety: fresh blocks never alias referenced/cached ones
                in_use = {b for blocks in live for b in blocks} | cached
                assert not (set(got) & in_use)
                live.append(got)
        elif op == 1 and live:  # share: a prefix hit retains the same blocks
            src = live[n % len(live)]
            alloc.retain(src)
            live.append(list(src))
        elif op == 2 and live:  # release one sharer (retire / preempt)
            alloc.free(live.pop(n % len(live)))
        elif op == 3 and live:  # index a block with the prefix cache
            blocks = live[n % len(live)]
            b = blocks[n % len(blocks)]
            cached.add(b)
            alloc.register_cached(b)
        elif op == 4:  # evict one idle cached block (LRU order irrelevant)
            refs = {b for blocks in live for b in blocks}
            idle = sorted(cached - refs)
            if idle:
                b = idle[n % len(idle)]
                alloc.evict(b)
                cached.remove(b)
            elif cached:  # every cached block is referenced: evict must raise
                b = sorted(cached)[n % len(cached)]
                with pytest.raises(ValueError):
                    alloc.evict(b)
        check()

    for blocks in live:  # drain: release every sharer, evict every idle block
        alloc.free(blocks)
    for b in sorted(cached):
        alloc.evict(b)
    assert alloc.free_count == capacity and alloc.used_count == 0
    assert alloc.evictable_count == 0


@given(num_blocks=st.integers(2, 32), n=st.integers(0, 40))
@settings(max_examples=40, deadline=None)
def test_block_allocator_all_or_nothing(num_blocks, n):
    alloc = BlockAllocator(num_blocks)
    got = alloc.alloc(n)
    if n <= num_blocks - 1:
        assert got is not None and len(got) == n
        assert alloc.free_count == num_blocks - 1 - n
    else:
        assert got is None
        assert alloc.free_count == num_blocks - 1  # nothing leaked


# ---------------------------------------------------------------------------
# substrate Calibration invariants (core.substrate)
# ---------------------------------------------------------------------------

import json

from repro.core.substrate import Calibration, CalibrationRecorder, SiteStats


def _batches_strategy():
    """Small float batches: lists of (rows, k) activation blocks."""
    return st.lists(
        st.integers(0, 2**16),  # per-batch seed
        min_size=1, max_size=4,
    )


def _mk_batch(seed, rows=5, k=8, m=4):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(rows, k)) * rng.uniform(0.1, 3.0)
    w = rng.normal(size=(k, m))
    return x, w


def _observe_all(rec, seeds, site="mlp.wi"):
    for s in seeds:
        x, w = _mk_batch(s)
        rec.observe(site, jnp.asarray(x), jnp.asarray(w))
    jax.effects_barrier()


@given(seeds=_batches_strategy(), order_seed=st.integers(0, 2**16))
@settings(max_examples=25, deadline=None)
def test_calibration_batch_order_invariant(seeds, order_seed):
    """Frozen ranges are running maxima: observation order cannot matter."""
    shuffled = list(seeds)
    np.random.default_rng(order_seed).shuffle(shuffled)
    a, b = CalibrationRecorder(), CalibrationRecorder()
    _observe_all(a, seeds)
    _observe_all(b, shuffled)
    assert a.finalize() == b.finalize()


@given(seeds=_batches_strategy(), pad_rows=st.integers(1, 6))
@settings(max_examples=25, deadline=None)
def test_calibration_padding_invariant(seeds, pad_rows):
    """Zero-row padding (the engine's bucket-pad artifact at the stat level)
    cannot move any frozen range: |0| never raises a max and an all-zero row
    contributes zero output std."""
    a, b = CalibrationRecorder(), CalibrationRecorder()
    for s in seeds:
        x, w = _mk_batch(s)
        a.observe("mlp.wi", jnp.asarray(x), jnp.asarray(w))
        xp = np.concatenate([x, np.zeros((pad_rows, x.shape[1]))], axis=0)
        b.observe("mlp.wi", jnp.asarray(xp), jnp.asarray(w))
    jax.effects_barrier()
    assert a.finalize() == b.finalize()


@given(seeds=_batches_strategy(), extra=_batches_strategy())
@settings(max_examples=25, deadline=None)
def test_calibration_superset_never_shrinks(seeds, extra):
    """Calibrating on a superset of batches never shrinks any range."""
    small, big = CalibrationRecorder(), CalibrationRecorder()
    _observe_all(small, seeds)
    _observe_all(big, seeds + extra)
    cs, cb = small.finalize(), big.finalize()
    for name, st_small in cs.sites:
        st_big = cb.get(name)
        assert st_big.x_max >= st_small.x_max
        assert st_big.w_max >= st_small.w_max
        assert st_big.sigma_yo >= st_small.sigma_yo


@given(
    entries=st.dictionaries(
        st.sampled_from(["attn.wq", "attn.wo", "mlp.wi", "mlp.wo",
                         "lm_head", "*"]),
        st.tuples(*(st.floats(1e-9, 1e9, allow_nan=False) for _ in range(3))),
        min_size=1, max_size=6,
    )
)
@settings(max_examples=40, deadline=None)
def test_calibration_pytree_and_json_roundtrip_lossless(entries):
    cal = Calibration(tuple(
        (name, SiteStats(*vals)) for name, vals in entries.items()))
    leaves, treedef = jax.tree_util.tree_flatten(cal)
    assert jax.tree_util.tree_unflatten(treedef, leaves) == cal
    assert Calibration.from_dict(json.loads(json.dumps(cal.to_dict()))) == cal


# ---------------------------------------------------------------------------
# shadow refresh (runtime.drift): the hot-swap algebra
# ---------------------------------------------------------------------------

from repro.runtime.drift import DriftThresholds, detect_drift, \
    refreshed_calibration  # noqa: E402

_DRIFT_SITES = ["attn.wq", "attn.wo", "mlp.wi", "mlp.wo", "lm_head", "*"]
_stats_strategy = st.tuples(
    *(st.floats(1e-6, 1e6, allow_nan=False) for _ in range(3)))


def _cal_of(entries):
    return Calibration(tuple(
        (name, SiteStats(*vals)) for name, vals in entries.items()))


@given(seeds=_batches_strategy(), order_seed=st.integers(0, 2**16))
@settings(max_examples=25, deadline=None)
def test_shadow_refresh_batch_order_invariant(seeds, order_seed):
    """The hot-swappable refreshed calibration is independent of the order
    in which live chunks were shadow-sampled (running maxima all the way
    down), so serving schedule cannot leak into the swapped ranges."""
    frozen = _cal_of({"mlp.wi": (0.5, 0.5, 0.5), "*": (1.0, 1.0, 1.0)})
    shuffled = list(seeds)
    np.random.default_rng(order_seed).shuffle(shuffled)
    a, b = CalibrationRecorder(), CalibrationRecorder()
    _observe_all(a, seeds)
    _observe_all(b, shuffled)
    assert refreshed_calibration(frozen, a.finalize()) == \
        refreshed_calibration(frozen, b.finalize())


@given(
    frozen=st.dictionaries(st.sampled_from(_DRIFT_SITES), _stats_strategy,
                           min_size=1, max_size=6),
    observed=st.dictionaries(st.sampled_from(_DRIFT_SITES), _stats_strategy,
                             min_size=1, max_size=6),
)
@settings(max_examples=50, deadline=None)
def test_refreshed_calibration_treedef_preserving_and_monotone(
        frozen, observed):
    """Swap-safety invariants for ANY frozen/observed pair: the refreshed
    calibration carries exactly the frozen site names (same pytree treedef,
    so compiled executables are re-used) and never shrinks a range."""
    f, o = _cal_of(frozen), _cal_of(observed)
    r = refreshed_calibration(f, o)
    assert r.site_names() == f.site_names()
    _, td_f = jax.tree_util.tree_flatten(f)
    _, td_r = jax.tree_util.tree_flatten(r)
    assert td_f == td_r
    for name, st_f in f.sites:
        st_r = r.get(name)
        assert st_r.x_max >= st_f.x_max
        assert st_r.w_max >= st_f.w_max
        assert st_r.sigma_yo >= st_f.sigma_yo


@given(
    frozen=st.dictionaries(st.sampled_from(_DRIFT_SITES), _stats_strategy,
                           min_size=1, max_size=6),
    observed=st.dictionaries(st.sampled_from(_DRIFT_SITES), _stats_strategy,
                             min_size=1, max_size=6),
)
@settings(max_examples=50, deadline=None)
def test_swap_then_recheck_never_reflags(frozen, observed):
    """Convergence of the detect->swap loop: after refreshing with the very
    observations that flagged drift, re-running the detector on those same
    observations finds NO range excess even at a zero threshold (the
    one-sided test is consistent with the merge)."""
    f, o = _cal_of(frozen), _cal_of(observed)
    r = refreshed_calibration(f, o)
    rep = detect_drift(r, o, DriftThresholds(rel_excess=0.0, clip_rate=1.0))
    assert not rep.drifted
    assert all(e.rel_excess == 0.0 for e in rep.entries)


# ---------------------------------------------------------------------------
# overload resilience: allocator under lazy-grow + preempt/resume, and
# request conservation through the SLO serve loop (launch.serve.serve_slo)
# ---------------------------------------------------------------------------

from repro.launch.scheduler import DeadlineSLOPolicy, FIFOPolicy  # noqa: E402
from repro.launch.serve import Request, serve_slo  # noqa: E402
from repro.runtime.workload import VirtualClock  # noqa: E402


@given(
    num_blocks=st.integers(3, 48),
    ops=st.lists(
        st.tuples(st.integers(0, 3), st.integers(0, 8)),
        min_size=1, max_size=80,
    ),
)
@settings(max_examples=60, deadline=None)
def test_block_allocator_conserved_under_lazy_grow_and_preemption(
        num_blocks, ops):
    """The lazy-allocation usage pattern: admit with a small initial grant,
    GROW a live request mid-decode (block-boundary crossing), PREEMPT (free
    everything it holds, remember it), RESUME (fresh grant).  Under every
    interleaving: disjoint live sets, block 0 stays reserved, and
    free + held == capacity exactly."""
    alloc = BlockAllocator(num_blocks)
    capacity = num_blocks - 1
    live = []       # block-lists of admitted/resumed requests
    preempted = 0   # resumable requests (hold nothing while preempted)

    def check():
        held = [b for blocks in live for b in blocks]
        assert 0 not in held
        assert len(held) == len(set(held))
        assert alloc.free_count + len(held) == capacity
        assert alloc.used_count == len(held)

    for op, n in ops:
        if op == 0:  # admit: initial lazy grant (>=1 block)
            got = alloc.alloc(max(1, n % 4))
            if got is not None:
                live.append(got)
        elif op == 1 and live:  # grow a live request by n blocks
            idx = n % len(live)
            before = alloc.free_count
            got = alloc.alloc(n)
            if got is None:
                assert n > before  # all-or-nothing even mid-grow
            else:
                live[idx].extend(got)
        elif op == 2 and live:  # preempt: victim frees EVERYTHING it holds
            victim = live.pop(n % len(live))
            alloc.free(victim)
            preempted += 1
        elif op == 3 and preempted:  # resume: fresh grant like admission
            got = alloc.alloc(max(1, n % 4))
            if got is not None:
                preempted -= 1
                live.append(got)
        check()

    for blocks in live:
        alloc.free(blocks)
    assert alloc.free_count == capacity and alloc.used_count == 0


class _FakeSLOEngine:
    """Model-free engine exposing exactly the serve_slo duck-type surface.

    Each running request progresses one token per chunk; a hypothesis-drawn
    per-rid budget makes it preempt (re-queue with its tokens) a bounded
    number of times - the adversarial schedule the conservation property
    must survive."""

    def __init__(self, slots, chunks_needed, preempt_budget):
        self.clock = VirtualClock()
        self.queue_depth = 0
        self.preempted = []
        self.finished = []
        self.running = []
        self.slots = slots
        self.chunks_needed = chunks_needed
        self.preempt_budget = dict(preempt_budget)

    @property
    def active(self):
        return len(self.running)

    def admit_pending(self, queue):
        admitted = []
        while queue and len(self.running) < self.slots:
            r = queue.pop(0)
            if r.t_first is None:
                r.t_first = self.clock.now
            self.running.append(r)
            admitted.append(r)
        return admitted

    def decode_chunk(self):
        self.clock.advance(1.0)
        still = []
        for r in self.running:
            if self.preempt_budget.get(r.rid, 0) > 0:
                self.preempt_budget[r.rid] -= 1
                r.preemptions += 1
                self.preempted.append(r)
                continue
            r.out.append(0)
            if len(r.out) >= self.chunks_needed.get(r.rid, 1):
                self.finished.append(r)
            else:
                still.append(r)
        self.running = still

    def fail_request(self, req, error, kind="admission"):
        req.error = RuntimeError(error)
        req.error_kind = kind
        self.finished.append(req)


@given(
    n=st.integers(1, 12),
    slots=st.integers(1, 3),
    seed=st.integers(0, 2**16),
    deadline_policy=st.booleans(),
)
@settings(max_examples=50, deadline=None)
def test_serve_slo_request_conservation(n, slots, seed, deadline_policy):
    """Every submitted request leaves the loop exactly once - completed,
    errored, or shed - under arbitrary arrival times, tight TTFT deadlines
    and adversarial bounded preemption, with either policy; and no request
    that survives shedding starves (the loop terminates with all work
    retired)."""
    rng = np.random.default_rng(seed)
    reqs = [
        Request(rid=i, prompt=np.zeros(4, np.int64), max_new=8,
                arrive_at=float(rng.uniform(0, 10)),
                ttft_deadline=(float(rng.uniform(0.1, 6))
                               if rng.random() < 0.5 else None))
        for i in range(n)
    ]
    chunks_needed = {i: int(rng.integers(1, 5)) for i in range(n)}
    budget = {i: int(rng.integers(0, 3)) for i in range(n)}
    eng = _FakeSLOEngine(slots, chunks_needed, budget)
    policy = DeadlineSLOPolicy() if deadline_policy else FIFOPolicy()

    finished = serve_slo(eng, list(reqs), policy=policy)

    assert len(finished) == n  # conservation: exactly once each
    assert sorted(r.rid for r in finished) == sorted(r.rid for r in reqs)
    assert len({id(r) for r in finished}) == n
    assert eng.running == [] and eng.preempted == []
    for r in finished:
        if r.error is None:
            assert len(r.out) >= chunks_needed[r.rid]  # really finished
        elif r.error_kind == "shed":
            assert deadline_policy  # only the deadline policy sheds
            assert r.out == []  # mid-flight requests are never shed
    if not deadline_policy:
        assert all(r.error is None for r in finished)  # FIFO: all complete
