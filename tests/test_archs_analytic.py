"""Architecture-level analytics vs the paper's quantitative anchors
(Table III, Figs. 9-13)."""
import numpy as np
import pytest

from repro.core import scaling
from repro.core.archs import CMArch, QRArch, QSArch
from repro.core.design import optimize


# ---------------------------------------------------------------------------
# QS-Arch (Fig. 9)
# ---------------------------------------------------------------------------


def test_qs_arch_snr_plateau_and_collapse():
    """Fig. 9(a): SNR_A ~ 19.6 dB for N <= 125 at V_WL = 0.8, then collapses."""
    a64 = QSArch(n=64, bx=6, bw=6, v_wl=0.8)
    a125 = QSArch(n=125, bx=6, bw=6, v_wl=0.8)
    a256 = QSArch(n=256, bx=6, bw=6, v_wl=0.8)
    assert abs(a64.snr_A_db() - 19.6) < 1.0
    assert abs(a125.snr_A_db() - a64.snr_A_db()) < 0.5
    assert a256.snr_A_db() < 0.0  # catastrophic clipping


def test_qs_arch_vwl_tradeoff():
    """Higher V_WL -> higher max SNR but smaller N_max (SSV-B1)."""
    lo = QSArch(n=64, bx=6, bw=6, v_wl=0.6)
    hi = QSArch(n=64, bx=6, bw=6, v_wl=0.8)
    assert hi.snr_A_db() > lo.snr_A_db()
    assert lo.k_h > 2.5 * hi.k_h  # headroom in counts grows as V_WL drops


def test_qs_arch_nmax_doubles_per_3db():
    """SSV-B1: N_max increases ~2x for every ~3 dB drop in SNR_A."""

    def n_max(v_wl):
        n = 32
        while n < 4096:
            if QSArch(n=2 * n, bx=6, bw=6, v_wl=v_wl).snr_A_db() < 5.0:
                break
            n *= 2
        return n

    def snr(v_wl, n):
        return QSArch(n=n, bx=6, bw=6, v_wl=v_wl).snr_A_db()

    n8, n7 = n_max(0.8), n_max(0.7)
    assert n7 >= 2 * n8 * 0.5  # at least roughly doubles
    drop = snr(0.8, 64) - snr(0.7, 64)
    assert 1.5 < drop < 5.0  # ~3 dB


def test_qs_arch_b_adc_small():
    a = QSArch(n=128, bx=6, bw=6, v_wl=0.7)
    assert 4 <= a.b_adc_min() <= 8  # Fig. 9(b) range
    assert a.b_adc_min() < a.b_adc_bgc() - 6


# ---------------------------------------------------------------------------
# QR-Arch (Fig. 10)
# ---------------------------------------------------------------------------


def test_qr_arch_co_sweep():
    """Fig. 10: ~+8 dB at 3 fF, ~+12 dB at 9 fF vs 1 fF (ours: +6.5/+12,
    DESIGN.md SS7 deviation 2)."""
    base = QRArch(n=128, bx=6, bw=7, c_o=1e-15).snr_a_db()
    d3 = QRArch(n=128, bx=6, bw=7, c_o=3e-15).snr_a_db() - base
    d9 = QRArch(n=128, bx=6, bw=7, c_o=9e-15).snr_a_db() - base
    assert 5.0 < d3 < 9.0
    assert 10.0 < d9 < 14.0


def test_qr_arch_no_clipping():
    assert QRArch(n=512, bx=6, bw=7).sigma_eta_h_sq() == 0.0


def test_qr_arch_b_adc_range():
    """Fig. 10(b): 6-8 bits suffice (MPC); BGC would assign ~12."""
    for co in (1e-15, 3e-15, 9e-15):
        a = QRArch(n=128, bx=6, bw=7, c_o=co)
        assert 5 <= a.b_adc_min() <= 8
    assert QRArch(n=128, bx=6, bw=7).b_adc_bgc() >= 12


# ---------------------------------------------------------------------------
# CM (Fig. 11)
# ---------------------------------------------------------------------------


def test_cm_optimal_bw():
    """Fig. 11(a): SNR_A peaks at B_w = 6 (V_WL = 0.8) and B_w = 7 (0.7)."""
    for v_wl, expect in [(0.8, 6), (0.7, 7)]:
        vals = {bw: CMArch(n=64, bx=6, bw=bw, v_wl=v_wl).snr_A_db()
                for bw in range(3, 10)}
        best = max(vals, key=vals.get)
        assert abs(best - expect) <= 1, (v_wl, vals)


def test_cm_noise_balance():
    """Fig. 11: clipping dominates at high V_WL/B_w, electrical at low."""
    hi = CMArch(n=64, bx=6, bw=8, v_wl=0.8)
    lo = CMArch(n=64, bx=6, bw=6, v_wl=0.6)
    assert hi.sigma_eta_h_sq() > hi.sigma_eta_e_sq()
    assert lo.sigma_eta_e_sq() > lo.sigma_eta_h_sq()


def test_cm_b_adc_much_smaller_than_bgc():
    """SSV-B3: MPC assigns <= 8 bits where BGC would assign ~19."""
    a = CMArch(n=128, bx=6, bw=6, v_wl=0.8)
    assert a.b_adc_min() <= 8
    assert a.b_adc_bgc() >= 18


# ---------------------------------------------------------------------------
# ADC energy trends (Fig. 12) and technology scaling (Fig. 13)
# ---------------------------------------------------------------------------


def test_adc_energy_trends_with_n():
    """Fig. 12: with MPC, E_ADC decreases with N for QS-Arch (V_c grows with
    N) and increases with N for QR-Arch/CM (V_c shrinks as 1/sqrt(N))."""
    e_qs = [
        QSArch(n=n, bx=6, bw=6, v_wl=0.7).adc_energy_per_conversion(6)
        for n in (32, 64, 128, 256)
    ]
    assert e_qs[-1] < e_qs[0]
    e_qr = [
        QRArch(n=n, bx=6, bw=6).adc_energy_per_conversion(7)
        for n in (32, 64, 128, 256)
    ]
    assert e_qr[-1] > e_qr[0]
    e_cm = [
        CMArch(n=n, bx=6, bw=6, v_wl=0.8).adc_energy_per_conversion(7)
        for n in (32, 64, 128, 256)
    ]
    assert e_cm[-1] > e_cm[0]


def test_mpc_vs_bgc_adc_energy_scaling():
    """Fig. 12: for QR-Arch, E_ADC ~ N^2 under BGC vs ~ N under MPC."""
    n1, n2 = 64, 256
    a1, a2 = QRArch(n=n1, bx=6, bw=6), QRArch(n=n2, bx=6, bw=6)
    e_mpc = a2.adc_energy_per_conversion(a2.b_adc_min()) / a1.adc_energy_per_conversion(a1.b_adc_min())
    e_bgc = a2.adc_energy_per_conversion(a2.b_adc_bgc()) / a1.adc_energy_per_conversion(a1.b_adc_bgc())
    assert e_bgc > 2.5 * e_mpc


def test_scaling_qs_max_snr_declines():
    """SSV-D/Fig. 13: max achievable SNR_A of QS-Arch declines 65 nm -> 7 nm."""

    def max_snr(tech):
        best = -1e9
        for v_wl in np.arange(0.5, tech.v_dd - 0.05, 0.025):
            best = max(best, QSArch(n=100, bx=3, bw=4, tech=tech,
                                    v_wl=float(v_wl)).snr_A_db())
        return best

    snrs = [max_snr(scaling.node(n)) for n in scaling.PAPER_SEQUENCE]
    assert snrs[0] > snrs[-1] + 2.0  # 65nm clearly better than 7nm
    assert snrs[1] > snrs[-1]  # 22nm better than 7nm


def test_scaling_qr_keeps_improving_energy():
    """Fig. 13(b): QR-Arch analog energy (same C_o, same B_ADC) drops with
    scaling (V_dd^2 C); and its achievable SNR does NOT collapse (unlike QS)."""
    e, s = {}, {}
    for name in ("65nm", "22nm", "7nm"):
        tech = scaling.node(name)
        a = QRArch(n=100, bx=3, bw=4, tech=tech, c_o=3e-15)
        e[name] = a.analog_energy_per_dp() + a.adc_energy_per_conversion(6)
        s[name] = a.snr_a_db()
    assert e["7nm"] < e["22nm"] < e["65nm"]
    assert s["7nm"] > s["65nm"] - 3.0  # no QS-style collapse


# ---------------------------------------------------------------------------
# design solver (SSVI guidelines)
# ---------------------------------------------------------------------------


def test_design_solver_qs_low_qr_high():
    """SSVI: QS-based preferred at low compute SNR, QR-based at high."""
    lo = optimize(n=256, snr_t_target_db=12.0, kinds=("qs", "qr"))
    hi = optimize(n=256, snr_t_target_db=26.0, kinds=("qs", "qr"))
    assert lo is not None and hi is not None
    assert lo.energy_per_dp < hi.energy_per_dp
    assert hi.arch_kind == "qr"  # QS can't reach 26 dB cheaply (or at all)


def test_design_solver_banks_large_n():
    """SSVI bullet 4: high-dimensional DPs require multi-bank."""
    pt = optimize(n=2048, snr_t_target_db=18.0)
    assert pt is not None
    assert pt.n_banks >= 4 or pt.arch_kind == "qr"


def test_design_solver_infeasible_returns_none():
    assert optimize(n=256, snr_t_target_db=60.0) is None
