"""Tensor-parallel serve engine: fast unit coverage in-process (mesh
parsing, the KV-head partition contract, the ``make_host_mesh`` clamp) and
slow subprocess equivalence runs under 8 host-simulated devices (the main
test process keeps its single real device; see tests/test_distributed.py
for the pattern)."""
import os
import subprocess
import sys

import pytest

try:
    from hypothesis import given, strategies as st
except ImportError:  # pragma: no cover - CI's dev extra carries hypothesis
    given = st = None

from repro.launch.mesh import make_host_mesh, make_serve_mesh, parse_mesh_shape
from repro.launch.sharding import kv_head_partition

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# kv_head_partition: the head-sharding contract
# ---------------------------------------------------------------------------

def _check_partition(hkv, n):
    """Every Hkv/N combination either rejects (Hkv % N != 0) or yields
    exactly N disjoint contiguous ranges covering every head once."""
    if hkv % n != 0:
        with pytest.raises(ValueError, match="do not partition"):
            kv_head_partition(hkv, n)
        return
    ranges = kv_head_partition(hkv, n)
    assert len(ranges) == n
    per = hkv // n
    covered = []
    for lo, hi in ranges:
        assert hi - lo == per  # equal shares: no shard group starves
        covered.extend(range(lo, hi))
    # conservation + no overlap: each head appears exactly once, in order
    assert covered == list(range(hkv))


def test_kv_head_partition_grid():
    # always-on exhaustive sweep (hypothesis may be absent outside the dev
    # extra; the property below widens the range when it is present)
    for hkv in range(1, 17):
        for n in range(1, 9):
            _check_partition(hkv, n)


if given is not None:
    @given(hkv=st.integers(1, 64), n=st.integers(1, 16))
    def test_kv_head_partition_conserves_heads(hkv, n):
        _check_partition(hkv, n)


@pytest.mark.parametrize("hkv,n", [(0, 1), (4, 0), (-1, 2), (4, -2)])
def test_kv_head_partition_rejects_degenerate(hkv, n):
    with pytest.raises(ValueError, match="need hkv >= 1 and n >= 1"):
        kv_head_partition(hkv, n)


# ---------------------------------------------------------------------------
# mesh construction helpers
# ---------------------------------------------------------------------------

def test_make_host_mesh_clamps_oversized_model_axis():
    # single-device test process: an explicit model_axis=8 used to build a
    # (0, 8) mesh (integer division to zero); it must clamp to a divisor of
    # the device count instead
    mesh = make_host_mesh(model_axis=8)
    assert mesh.devices.size >= 1
    assert mesh.shape["model"] >= 1
    assert mesh.devices.size % mesh.shape["model"] == 0
    assert mesh.shape["data"] >= 1


def test_make_host_mesh_rejects_nonpositive_model_axis():
    with pytest.raises(ValueError):
        make_host_mesh(model_axis=0)


def test_parse_mesh_shape():
    assert parse_mesh_shape("1x8") == (1, 8)
    assert parse_mesh_shape("2x4") == (2, 4)
    for bad in ("", "8", "1x", "x8", "ax2", "1x2x3", "0x4", "1x-2"):
        with pytest.raises(ValueError):
            parse_mesh_shape(bad)


def test_make_serve_mesh_rejects_when_short_on_devices():
    import jax
    need = len(jax.devices()) + 1
    with pytest.raises(ValueError, match="device"):
        make_serve_mesh(1, need)


def test_make_serve_mesh_single_device():
    mesh = make_serve_mesh(1, 1)
    assert mesh.shape == {"data": 1, "model": 1}


# ---------------------------------------------------------------------------
# slow subprocess runs: sharded vs single-device greedy-token equivalence
# ---------------------------------------------------------------------------

def _run(script: str, timeout=1200) -> str:
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, cwd=ROOT, env=env, timeout=timeout)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-3000:]
    return r.stdout


# Frozen calibration everywhere: the dynamic IMC policy is bit-exactness-
# pinned elsewhere, but tensor-parallel matmuls reassociate the output-dim
# all-reduce, so the sharded contract is GREEDY-TOKEN identity, not bitwise
# logits.  Mixed 4..48 prompts cross the prefill bucket ladder.
EQUIVALENCE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import numpy as np
from repro import configs
from repro.core import substrate as substrate_lib
from repro.core.imc_linear import IMCConfig
from repro.launch.mesh import make_serve_mesh
from repro.launch.serve import Engine, Request, serve
from repro.models import init_params

MIXED = [4, 6, 48, 5, 8, 44, 6, 7]
GEN = 8


def mk_requests(cfg, n):
    rnp = np.random.default_rng(0)
    return [Request(rid=i,
                    prompt=rnp.integers(0, cfg.vocab_size,
                                        MIXED[i % len(MIXED)]),
                    max_new=GEN) for i in range(n)]


def build(mode):
    cfg = configs.get_smoke("musicgen-medium")
    if mode is not None:
        cfg = cfg.replace(imc=substrate_lib.as_substrate(
            IMCConfig(mode=mode, bx=7, bw=7, v_wl=0.7)))
    params = init_params(jax.random.PRNGKey(0), cfg)
    if mode is not None:
        ref = np.random.default_rng(1).integers(0, cfg.vocab_size, (2, 48))
        cfg = substrate_lib.calibrate_model(cfg, params, [ref])
    return cfg, params


for mode, n_req in ((None, 8), ("imc_analytic", 8), ("imc_bitserial", 4)):
    cfg, params = build(mode)
    cache_len = 64 + GEN + 8
    single = Engine(cfg, params, 4, cache_len, max_chunk=GEN)
    toks_single = {r.rid: list(r.out)
                   for r in serve(single, mk_requests(cfg, n_req))}
    mesh = make_serve_mesh(1, 4)
    sharded = Engine(cfg, params, 4, cache_len, max_chunk=GEN, mesh=mesh)
    assert sharded.kv_shard, "Hkv=4 must head-shard over a 4-way model axis"
    assert sharded.cfg.decode_attn == "gather", sharded.cfg.decode_attn
    assert sharded.kv_pool_bytes_per_device() * 4 == sharded.kv_pool_bytes()
    toks_sharded = {r.rid: list(r.out)
                    for r in serve(sharded, mk_requests(cfg, n_req))}
    assert toks_sharded == toks_single, (mode, toks_single, toks_sharded)
    print("MATCH", mode or "digital", len(toks_single))
print("EQUIV_OK")
"""


PREEMPT_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import numpy as np
from repro import configs
from repro.core import substrate as substrate_lib
from repro.core.imc_linear import IMCConfig
from repro.launch.mesh import make_serve_mesh
from repro.launch.serve import Engine, Request, serve
from repro.models import init_params

MIXED = [4, 6, 48, 5, 8, 44, 6, 7]
GEN = 8


def mk_requests(cfg, n):
    rnp = np.random.default_rng(0)
    return [Request(rid=i,
                    prompt=rnp.integers(0, cfg.vocab_size,
                                        MIXED[i % len(MIXED)]),
                    max_new=GEN) for i in range(n)]


cfg = configs.get_smoke("musicgen-medium")
cfg = cfg.replace(imc=substrate_lib.as_substrate(
    IMCConfig(mode="imc_analytic", bx=7, bw=7, v_wl=0.7)))
params = init_params(jax.random.PRNGKey(0), cfg)
ref = np.random.default_rng(1).integers(0, cfg.vocab_size, (2, 48))
cfg = substrate_lib.calibrate_model(cfg, params, [ref])
cache_len = 64 + GEN + 8

# tight pool: lazy allocation must preempt mid-decode and resume, and the
# sharded engine must walk the exact same preempt/resume schedule (the
# allocator and block table are whole per shard group, so scheduling is
# device-count-independent)
toks = {}
preempts = {}
for name, mesh in (("single", None), ("sharded", make_serve_mesh(1, 4))):
    eng = Engine(cfg, params, 4, cache_len, max_chunk=GEN, kv_blocks=11,
                 alloc_policy="lazy", mesh=mesh)
    toks[name] = {r.rid: list(r.out) for r in serve(eng, mk_requests(cfg, 8))}
    preempts[name] = eng.preempt_count

assert preempts["single"] >= 1, preempts
assert preempts["sharded"] == preempts["single"], preempts
assert toks["sharded"] == toks["single"]
print("PREEMPT_OK", preempts["single"])
"""


@pytest.mark.slow
def test_sharded_equivalence_three_substrates():
    out = _run(EQUIVALENCE_SCRIPT)
    assert "EQUIV_OK" in out
    assert "MATCH digital" in out
    assert "MATCH imc_analytic" in out
    assert "MATCH imc_bitserial" in out


@pytest.mark.slow
def test_sharded_preemption_resume_parity():
    out = _run(PREEMPT_SCRIPT)
    assert "PREEMPT_OK" in out
