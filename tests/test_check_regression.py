"""Unit tests for the CI bench-regression gate: the tier-1 job must fail on
a synthetic regression and pass on the committed baselines."""
import copy
import glob
import json
import os

import pytest

from benchmarks.check_regression import (
    check_pair,
    compare_payloads,
    filter_suites,
    main,
)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _payload():
    return {
        "schema_version": 2.5,
        "suites": {
            "serve_sharded": {
                "wall_s": 30.0,
                "records": [
                    {"bench": "serve_sharded", "config": "tp_engine",
                     "mode": "digital", "substrate": "digital",
                     "decode_attn": "gather", "mesh_shape": "1x4",
                     "devices": 8, "slots": 4, "requests": 8,
                     "gen": 8, "tok_s_single": 90.0, "tok_s_sharded": 40.0,
                     "scaling_tok_s_ratio": 0.44, "kv_shard_ways": 4,
                     "kv_bytes_per_device": 83968, "kv_bytes_total": 335872,
                     "token_match": True},
                ],
            },
            "serve": {
                "wall_s": 1.0,
                "records": [
                    {"bench": "serve", "config": "paged_engine",
                     "mode": "digital", "substrate": "digital", "slots": 4,
                     "decode_attn": "kernel",
                     "tok_s": 2700.0, "wall_s": 0.02,
                     "kv_bytes_per_active_token": 1212.8,
                     "prefill_calls": 6, "decode_steps": 14},
                    {"bench": "serve_summary", "mode": "digital",
                     "substrate": "digital", "slots": 4,
                     "speedup_tok_s": 1.37, "ttft_ratio": 1.0,
                     "kv_reduction": 3.08},
                    {"bench": "serve_energy", "kind": "qs",
                     "substrate": "imc_bitserial",
                     "snr_t_target_db": 14.0,
                     "j_per_token": 5.7e-4, "edp_per_token": 1.9e-9,
                     "b_adc": 6},
                    {"bench": "serve_energy_crossover",
                     "substrate": "mixed",
                     "snr_low_db": 14.0, "snr_high_db": 26.0,
                     "qs_feasible_low": True, "qs_feasible_high": False,
                     "best_kind_high": "qr", "crossover": True},
                ],
            },
        },
    }


def test_identical_payloads_pass():
    assert compare_payloads(_payload(), _payload()) == []


def test_wall_clock_changes_do_not_gate():
    cur = _payload()
    cur["suites"]["serve"]["records"][0]["tok_s"] = 1.0  # 2700x slower
    cur["suites"]["serve"]["records"][0]["wall_s"] = 99.0
    assert compare_payloads(_payload(), cur) == []


def test_small_jitter_within_tolerance_passes():
    cur = _payload()
    cur["suites"]["serve"]["records"][0]["kv_bytes_per_active_token"] *= 1.01
    cur["suites"]["serve"]["records"][2]["j_per_token"] *= 1.005
    assert compare_payloads(_payload(), cur) == []


def test_kv_bytes_regression_fails():
    cur = _payload()
    cur["suites"]["serve"]["records"][0]["kv_bytes_per_active_token"] *= 2
    fails = compare_payloads(_payload(), cur)
    assert len(fails) == 1 and "kv_bytes_per_active_token" in fails[0]


def test_structural_counter_change_fails():
    cur = _payload()
    cur["suites"]["serve"]["records"][0]["prefill_calls"] = 8
    assert any("prefill_calls" in f for f in compare_payloads(_payload(), cur))


def test_speedup_collapse_fails_but_noise_passes():
    # wall-clock ratios gate on ABSOLUTE bounds (committed same-box ratios
    # swing run-to-run), so a noisy-but-healthy ratio passes even far from
    # the baseline value, and a genuine collapse below parity fails
    cur = _payload()
    cur["suites"]["serve"]["records"][1]["speedup_tok_s"] = 1.0  # >= 0.7: ok
    assert compare_payloads(_payload(), cur) == []
    cur["suites"]["serve"]["records"][1]["speedup_tok_s"] = 0.6  # < 0.7
    assert any("speedup_tok_s" in f for f in compare_payloads(_payload(), cur))
    cur["suites"]["serve"]["records"][1]["speedup_tok_s"] = 1.37
    cur["suites"]["serve"]["records"][1]["ttft_ratio"] = 3.5  # > ceiling 3.0
    assert any("ttft_ratio" in f for f in compare_payloads(_payload(), cur))


def test_energy_regression_fails():
    cur = _payload()
    cur["suites"]["serve"]["records"][2]["j_per_token"] *= 1.10
    assert any("j_per_token" in f for f in compare_payloads(_payload(), cur))


def test_crossover_flip_fails():
    cur = _payload()
    cur["suites"]["serve"]["records"][3]["crossover"] = False
    cur["suites"]["serve"]["records"][3]["best_kind_high"] = "cm"
    fails = compare_payloads(_payload(), cur)
    assert any("crossover" in f for f in fails)
    assert any("best_kind_high" in f for f in fails)


def test_missing_record_fails():
    cur = _payload()
    del cur["suites"]["serve"]["records"][0]
    assert any("missing record" in f for f in compare_payloads(_payload(), cur))


def test_missing_suite_fails():
    cur = copy.deepcopy(_payload())
    del cur["suites"]["serve"]
    assert any("suite missing" in f for f in compare_payloads(_payload(), cur))


def test_errored_baseline_suite_does_not_gate():
    base = _payload()
    base["suites"]["broken"] = {"error": "ValueError: boom"}
    assert compare_payloads(base, _payload()) == []


def test_new_current_records_allowed():
    cur = _payload()
    cur["suites"]["serve"]["records"].append(
        {"bench": "serve", "config": "new_engine", "mode": "digital",
         "substrate": "digital", "slots": 4, "decode_attn": "dense",
         "kv_bytes_per_active_token": 1.0})
    assert compare_payloads(_payload(), cur) == []


def test_missing_substrate_field_fails_with_clear_message():
    """Bench schema v2.1: a serve record without its 'substrate' field must
    fail the gate with an actionable message - on either side of the pair."""
    cur = _payload()
    del cur["suites"]["serve"]["records"][2]["substrate"]
    fails = compare_payloads(_payload(), cur)
    assert any("missing its 'substrate' field" in f and "v2.1" in f
               and "regenerate" in f for f in fails), fails
    base = _payload()
    del base["suites"]["serve"]["records"][0]["substrate"]
    fails = compare_payloads(base, _payload())
    assert any(f.startswith("baseline:") for f in fails), fails


def test_missing_decode_attn_field_fails_with_clear_message():
    """Bench schema v2.4: an engine-comparison 'serve' record without its
    'decode_attn' field must fail the gate with an actionable message."""
    cur = _payload()
    del cur["suites"]["serve"]["records"][0]["decode_attn"]
    fails = compare_payloads(_payload(), cur)
    assert any("missing its 'decode_attn' field" in f and "v2.4" in f
               and "regenerate" in f for f in fails), fails
    # summary/energy records are exempt: only bench == "serve" carries it
    cur = _payload()
    fails = compare_payloads(_payload(), cur)
    assert fails == []


def test_substrate_value_change_is_identity_change():
    """'substrate' is an ID field: flipping it reads as a dropped baseline
    record (the bench no longer reports that substrate), not metric drift."""
    cur = _payload()
    cur["suites"]["serve"]["records"][2]["substrate"] = "imc_analytic"
    fails = compare_payloads(_payload(), cur)
    assert any("missing record" in f for f in fails)


def _sharded(payload):
    return payload["suites"]["serve_sharded"]["records"][0]


def test_missing_sharded_field_fails_with_clear_message():
    """Bench schema v2.5: a serve_sharded record without its mesh/KV/token
    pinning fields must fail the gate with an actionable message."""
    for field in ("mesh_shape", "kv_bytes_per_device", "token_match"):
        cur = _payload()
        del _sharded(cur)[field]
        fails = compare_payloads(_payload(), cur)
        assert any(f"'{field}'" in f or f"['{field}']" in f
                   for f in fails), (field, fails)
        assert any("v2.5" in f and "regenerate" in f for f in fails), fails


def test_mesh_shape_change_is_identity_change():
    """'mesh_shape' (and 'devices') are ID fields: changing the mesh reads
    as a dropped baseline record, not metric drift on the same record."""
    cur = _payload()
    _sharded(cur)["mesh_shape"] = "1x8"
    fails = compare_payloads(_payload(), cur)
    assert any("missing record" in f for f in fails), fails


def test_sharded_structural_kv_bytes_gate_exactly():
    cur = _payload()
    _sharded(cur)["kv_bytes_per_device"] += 8
    fails = compare_payloads(_payload(), cur)
    assert any("kv_bytes_per_device" in f and "exact" in f
               for f in fails), fails


def test_sharded_token_match_flip_fails():
    cur = _payload()
    _sharded(cur)["token_match"] = False
    fails = compare_payloads(_payload(), cur)
    assert any("token_match" in f for f in fails), fails


def test_sharded_scaling_gates_on_absolute_floor():
    # host-simulated devices share one CPU: the ratio only has to clear the
    # collapse floor, not track the committed value
    cur = _payload()
    _sharded(cur)["scaling_tok_s_ratio"] = 0.06  # noisy but >= 0.05: ok
    assert compare_payloads(_payload(), cur) == []
    _sharded(cur)["scaling_tok_s_ratio"] = 0.01  # < floor
    fails = compare_payloads(_payload(), cur)
    assert any("scaling_tok_s_ratio" in f and "floor" in f
               for f in fails), fails


def test_filter_suites_gates_only_named_suites():
    """--suites lets a job that produced ONE suite gate it against a
    baseline artifact that carries several (the distributed-smoke job
    checks serve_sharded alone against the full BENCH_serve.json)."""
    cur = _payload()
    del cur["suites"]["serve"]  # job only produced serve_sharded
    # unfiltered: the missing serve suite fails the pair
    assert any("suite missing" in f for f in compare_payloads(_payload(), cur))
    # filtered to serve_sharded on both sides: passes
    assert compare_payloads(filter_suites(_payload(), ["serve_sharded"]),
                            filter_suites(cur, ["serve_sharded"])) == []
    # and a real regression inside the kept suite still gates
    _sharded(cur)["token_match"] = False
    fails = compare_payloads(filter_suites(_payload(), ["serve_sharded"]),
                             filter_suites(cur, ["serve_sharded"]))
    assert any("token_match" in f for f in fails), fails


def test_cli_suites_flag(tmp_path):
    base = tmp_path / "base.json"
    cur = tmp_path / "cur.json"
    base.write_text(json.dumps(_payload()))
    partial = _payload()
    del partial["suites"]["serve"]
    cur.write_text(json.dumps(partial))
    assert main(["--pair", f"{base}:{cur}"]) == 1
    assert main(["--suites", "serve_sharded",
                 "--pair", f"{base}:{cur}"]) == 0


@pytest.mark.parametrize("path", sorted(glob.glob(
    os.path.join(ROOT, "BENCH_*.json"))),
    ids=lambda p: os.path.basename(p))
def test_committed_baselines_self_compare_pass(path):
    assert check_pair(path, path) == []


def test_cli_exit_codes(tmp_path):
    base = tmp_path / "base.json"
    good = tmp_path / "good.json"
    bad = tmp_path / "bad.json"
    base.write_text(json.dumps(_payload()))
    good.write_text(json.dumps(_payload()))
    regressed = _payload()
    regressed["suites"]["serve"]["records"][0]["kv_bytes_per_active_token"] *= 3
    bad.write_text(json.dumps(regressed))
    assert main(["--pair", f"{base}:{good}"]) == 0
    assert main(["--pair", f"{base}:{bad}"]) == 1
    assert main(["--pair", f"{base}:{good}", "--pair", f"{base}:{bad}"]) == 1
