"""Serve-path energy-delay metering: DP counts, billing policy, rollup
closed forms, and the breakdown==metering shared-code-path pin."""
import math

import jax
import numpy as np
import pytest

from repro import configs
from repro.core import scaling
from repro.core.design import T_REDUCE_LEVEL, optimize, pareto_sweep, workload_metrics
from repro.core.mapping import MatmulShape, per_token_matmul_shapes
from repro.launch import breakdown
from repro.launch.metering import DPMeter, energy_for_tokens, serve_energy_report
from repro.launch.serve import Engine, Request, serve
from repro.models import init_params

SITES_512 = [MatmulShape("site", 512, 4, 1)]


def _qs_512():
    pt = optimize(n=512, snr_t_target_db=14.0, kinds=("qs",))
    assert pt is not None and pt.arch_kind == "qs"
    return pt


# ---------------------------------------------------------------------------
# billing policy: bucket padding billed, dummy pow2 rows excluded
# ---------------------------------------------------------------------------


def test_meter_counts_hand_computed():
    m = DPMeter(sites=SITES_512)
    # one admitted group: 3 real rows in a bucket of 8 (pow2 pad row NOT
    # billed), true lengths 5+5+6
    m.note_prefill(3, 8, true_lens=[5, 5, 6])
    assert m.prefill_billed_tokens == 24  # padding IS billed
    assert m.prefill_true_tokens == 16
    assert m.prefill_pad_tokens == 8
    assert m.prefill_rows == 3 and m.prefill_groups == 1
    # two fused chunks: 3 active x 4 steps, then 1 active x 2 steps
    m.note_decode(3, 4)
    m.note_decode(1, 2)
    assert m.decode_billed_tokens == 14
    assert m.decode_chunks == 2
    assert m.billed_tokens == 38


def test_dp_counts_per_site_and_tiling():
    sites = [MatmulShape("a", 512, 4, 2), MatmulShape("b", 1280, 8, 1)]
    m = DPMeter(sites=sites)
    m.note_prefill(1, 8)
    m.note_decode(1, 2)
    dps = m.dp_counts("total", rows=512)
    # a: 10 tokens x 2 calls x 4 outputs x ceil(512/512)=1 bank DP
    assert dps["a"] == 10 * 2 * 4 * 1
    # b: ceil(1280/512) = 3 bank DPs per output
    assert dps["b"] == 10 * 1 * 8 * 3
    pre = m.dp_counts("prefill", rows=512)
    dec = m.dp_counts("decode", rows=512)
    assert pre["a"] + dec["a"] == dps["a"]


# ---------------------------------------------------------------------------
# engine integration: counts are pure functions of the admission schedule
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def smoke_setup():
    cfg = configs.get_smoke("musicgen-medium")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _mk_reqs(cfg, lens, gen):
    rnp = np.random.default_rng(0)
    return [Request(rid=i, prompt=rnp.integers(0, cfg.vocab_size, l),
                    max_new=gen)
            for i, l in enumerate(lens)]


def _served_meter(cfg, params, lens, gen=8, slots=4):
    meter = DPMeter(cfg)
    engine = Engine(cfg, params, slots, 64, max_chunk=gen, meter=meter)
    serve(engine, _mk_reqs(cfg, lens, gen))
    return meter, engine


def test_engine_meter_equal_prompts_hand_computed(smoke_setup):
    cfg, params = smoke_setup
    # 3 equal-length prompts -> ONE (R=3, bucket=8) group (pow2 pad row 4
    # excluded from billing); each request then decodes 7 more tokens in
    # lockstep chunks of 4+2+1
    meter, engine = _served_meter(cfg, params, [5, 5, 5], gen=8)
    assert engine.prefill_calls == 1 and engine.prefill_rows == 3
    assert meter.prefill_groups == 1 and meter.prefill_rows == 3
    assert meter.prefill_billed_tokens == 3 * 8
    assert meter.prefill_true_tokens == 15
    assert meter.decode_billed_tokens == 3 * 7
    assert meter.decode_chunks == 3  # scan lengths 4, 2, 1


def test_engine_meter_additive_across_workloads(smoke_setup):
    cfg, params = smoke_setup
    lens_a, lens_b = [5, 9, 4], [17, 6]
    m_a, _ = _served_meter(cfg, params, lens_a)
    m_b, _ = _served_meter(cfg, params, lens_b)
    # one engine serving A then B accumulates exactly meter(A) + meter(B)
    meter = DPMeter(cfg)
    engine = Engine(cfg, params, 4, 64, max_chunk=8, meter=meter)
    serve(engine, _mk_reqs(cfg, lens_a, 8))
    serve(engine, _mk_reqs(cfg, lens_b, 8))
    for field in ("prefill_billed_tokens", "prefill_true_tokens",
                  "prefill_rows", "prefill_groups",
                  "decode_billed_tokens", "decode_chunks"):
        assert getattr(meter, field) == \
            getattr(m_a, field) + getattr(m_b, field), field


# ---------------------------------------------------------------------------
# rollup closed forms
# ---------------------------------------------------------------------------


def test_j_per_token_qs512_closed_form():
    """J/token at the 512-row QS design point == the hand rollup."""
    pt = _qs_512()
    meter = DPMeter(sites=SITES_512)
    meter.note_prefill(1, 8, true_lens=[5])  # 8 billed prefill tokens
    meter.note_decode(1, 5)  # 5 billed decode tokens
    rep = serve_energy_report(meter, pt, generated_tokens=6, requests=1)
    # one site: k=512 -> 1 bank DP per output at pt.n=512 (no tiling, no
    # extra reduction), m=4 outputs, calls=1
    e_tok = 4 * pt.energy_per_dp
    assert rep.prefill_j == pytest.approx(8 * e_tok, rel=1e-12)
    assert rep.decode_j == pytest.approx(5 * e_tok, rel=1e-12)
    assert rep.j_per_token == pytest.approx(13 * e_tok / 6, rel=1e-12)
    assert rep.j_per_request == pytest.approx(13 * e_tok, rel=1e-12)
    assert rep.delay_per_token_s == pytest.approx(pt.delay_per_dp, rel=1e-12)
    assert rep.edp_per_token == pytest.approx(
        rep.j_per_token * pt.delay_per_dp, rel=1e-12)
    assert rep.tok_s_compute == pytest.approx(1.0 / pt.delay_per_dp, rel=1e-12)


def test_workload_metrics_tiling_closed_form():
    pt = _qs_512()
    tech = scaling.node(pt.tech)
    wm = workload_metrics(pt, [(1280, 8, 2)])
    tiles = math.ceil(1280 / pt.n)  # 3
    width = pt.b_adc + math.ceil(math.log2(max(tiles * pt.n_banks, 2)))
    e_dp = tiles * pt.energy_per_dp + (tiles - 1) * width * tech.e_add_per_bit
    assert wm["energy_per_token_j"] == pytest.approx(2 * 8 * e_dp, rel=1e-12)
    assert wm["delay_per_token_s"] == pytest.approx(
        2 * (pt.delay_per_dp + math.ceil(math.log2(tiles)) * T_REDUCE_LEVEL),
        rel=1e-12)
    assert wm["edp_per_token"] == pytest.approx(
        wm["energy_per_token_j"] * wm["delay_per_token_s"], rel=1e-12)


# ---------------------------------------------------------------------------
# breakdown == metering: one shared rollup code path
# ---------------------------------------------------------------------------


def test_breakdown_equals_metering_single_forward():
    """The profiling-side rollup and the serve meter bill ONE full forward
    identically (the shared-helper fix for the silent double-count risk)."""
    cfg = configs.get("musicgen-medium")
    pt = _qs_512()
    fwd = breakdown.forward_energy(cfg, pt, tokens=1)
    meter = DPMeter(cfg)
    meter.note_prefill(1, 1, true_lens=[1])  # exactly one billed token
    rep = serve_energy_report(meter, pt, generated_tokens=1, requests=1)
    assert rep.prefill_j == pytest.approx(fwd["energy_j"], rel=1e-12)
    assert rep.decode_j == 0.0
    assert rep.delay_per_token_s == pytest.approx(
        fwd["delay_per_token_s"], rel=1e-12)
    # and both agree with the low-level shared helper on the same sites
    direct = energy_for_tokens(per_token_matmul_shapes(cfg), pt, 1)
    assert fwd["energy_j"] == direct["energy_j"]


def test_model_energy_shapes_walk_is_shared():
    """benchmarks.model_energy delegates to the one shapes walk."""
    from benchmarks.model_energy import model_matmul_shapes

    assert model_matmul_shapes("musicgen-medium") == \
        per_token_matmul_shapes(configs.get("musicgen-medium"))


# ---------------------------------------------------------------------------
# workload mode of the pareto sweep
# ---------------------------------------------------------------------------


def test_pareto_sweep_workload_reranks_by_edp():
    sites = [(s.k, s.m, s.calls)
             for s in per_token_matmul_shapes(configs.get("musicgen-medium"))]
    targets = (14.0, 26.0)
    swept = pareto_sweep(512, targets_db=targets, workload=sites)
    assert [t for t, _ in swept] == list(targets)
    for t, pt in swept:
        # the chosen point is the min-workload-EDP one among per-kind optima
        edps = {}
        for kind in ("qs", "qr", "cm"):
            cand = optimize(512, t, kinds=(kind,))
            if cand is not None:
                edps[kind] = workload_metrics(cand, sites)["edp_per_token"]
        assert pt.arch_kind == min(edps, key=edps.get)
        assert pt.snr_t_db >= t


def test_serve_frontier_qs_low_qr_high():
    """The serve-workload frontier restates the paper's guideline: QS is
    feasible only on the low-SNR side; QR alone spans the high side."""
    lo_qs = optimize(512, 14.0, kinds=("qs",))
    hi_qs = optimize(512, 26.0, kinds=("qs",))
    hi_qr = optimize(512, 26.0, kinds=("qr",))
    assert lo_qs is not None
    assert hi_qs is None
    assert hi_qr is not None
