"""Quantizer + SQNR fundamentals (paper SSII).  The hypothesis property
sweeps live in test_properties.py, guarded by pytest.importorskip."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.core.quant import (
    QuantSpec,
    SignalStats,
    UNIFORM_STATS,
    db,
    dequantize,
    fakequant,
    quantize,
    sqnr_qiy,
    sqnr_qiy_db_approx,
)


# ---------------------------------------------------------------------------
# SQNR: 6 dB per bit (eq. 1)
# ---------------------------------------------------------------------------


def test_six_db_per_bit():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.uniform(-1, 1, size=(200_000,)))
    prev = None
    for bits in range(4, 10):
        spec = QuantSpec(bits, True, 1.0)
        err = np.asarray(fakequant(x, spec) - x)
        snr_db = 10 * np.log10(np.var(np.asarray(x)) / np.mean(err**2))
        if prev is not None:
            assert 5.7 < snr_db - prev < 6.4, (bits, snr_db - prev)
        prev = snr_db


def test_sqnr_matches_rule_of_thumb():
    """For U[-1,1]: SQNR(dB) = 6.02B + 4.77 - 4.77 = 6.02B."""
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.uniform(-1, 1, size=(400_000,)))
    spec = QuantSpec(8, True, 1.0)
    err = np.asarray(fakequant(x, spec) - x)
    snr_db = 10 * np.log10(np.var(np.asarray(x)) / np.mean(err**2))
    assert abs(snr_db - 6.0206 * 8) < 0.2


# ---------------------------------------------------------------------------
# PAR values (paper SSIII-E anchors)
# ---------------------------------------------------------------------------


def test_paper_par_anchors():
    s = UNIFORM_STATS
    assert abs(float(db(s.zeta_x_sq)) - (-1.3)) < 0.1  # paper: -1.3 dB
    assert abs(float(db(s.zeta_w_sq)) - 4.8) < 0.1  # paper: 4.8 dB


def test_sqnr_qiy_paper_anchor():
    """Bx = Bw = 7 with uniform stats -> 41 dB (paper SSIII-E)."""
    val = float(sqnr_qiy_db_approx(7, 7, UNIFORM_STATS))
    assert abs(val - 41.0) < 0.5


def test_sqnr_qiy_exact_vs_monte_carlo():
    """Eq. (5)/(8) against an actual quantized DP ensemble."""
    n, bx, bw = 256, 6, 6
    rng = np.random.default_rng(3)
    x = rng.uniform(0, 1, size=(2000, n))
    w = rng.uniform(-1, 1, size=(2000, n))
    xs = QuantSpec(bx, False, 1.0)
    ws_ = QuantSpec(bw, True, 1.0)
    xq = np.asarray(fakequant(jnp.asarray(x), xs))
    wq = np.asarray(fakequant(jnp.asarray(w), ws_))
    y = np.sum(w * x, -1)
    yq = np.sum(wq * xq, -1)
    emp_db = 10 * np.log10(np.var(y) / np.var(yq - y))
    ana_db = float(db(sqnr_qiy(n, bx, bw, UNIFORM_STATS)))
    assert abs(emp_db - ana_db) < 0.7, (emp_db, ana_db)


def test_sqnr_qiy_independent_of_n():
    for n in (16, 128, 1024):
        assert abs(
            float(db(sqnr_qiy(n, 6, 6, UNIFORM_STATS)))
            - float(sqnr_qiy_db_approx(6, 6, UNIFORM_STATS))
        ) < 1e-3


def test_fakequant_ste_gradient():
    spec = QuantSpec(4, True, 1.0)
    g = jax.grad(lambda x: jnp.sum(quant.fakequant_ste(x, spec) ** 2))(
        jnp.asarray([0.3, -0.7])
    )
    assert np.all(np.isfinite(np.asarray(g)))
    assert not np.allclose(np.asarray(g), 0.0)
