"""Multi-device distribution tests (subprocess: 8 host devices so the main
test process keeps its single real device)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str, timeout=1200) -> str:
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, cwd=ROOT, env=env, timeout=timeout)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-3000:]
    return r.stdout


TRAIN_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.configs.shapes import ShapeSpec, input_specs
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_mesh

cfg = configs.get_smoke("gemma2-9b")
mesh = make_mesh((2, 4), ("data", "model"))
shape = ShapeSpec("t", 64, 8, "train")
bundle = steps_lib.build_train_step(cfg, mesh, input_specs(cfg, shape))
state = bundle.init_state(jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
# one FIXED batch: repeated steps must strictly reduce its loss (fresh random
# token batches every step make the drop marginal and flaky at 8 steps)
batch = {"tokens": jnp.asarray(
    rng.integers(0, cfg.vocab_size, (8, 64)), jnp.int32)}
losses = []
for i in range(8):
    state, metrics = bundle.step_fn(state, batch)
    losses.append(float(metrics["loss"]))
assert all(np.isfinite(losses)), losses
assert losses[-1] < losses[0], losses  # fixed batch: loss drops
print("TRAIN_OK", losses[0], losses[-1])
"""


DECODE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.configs.shapes import ShapeSpec, input_specs
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_mesh
from repro.models import decode_step, init_params, prefill

cfg = configs.get_smoke("deepseek-coder-33b")
params = init_params(jax.random.PRNGKey(0), cfg)
toks = jax.random.randint(jax.random.PRNGKey(1), (8, 33), 0, cfg.vocab_size)

# single-device reference
_, cache = prefill(params, cfg, toks[:, :-1], cache_len=40)
ref_logits, _ = decode_step(params, cfg, toks[:, -1], cache)

# sharded decode (model axis shards the KV sequence)
mesh = make_mesh((2, 4), ("data", "model"))
shape = ShapeSpec("d", 40, 8, "decode")
bundle = steps_lib.build_decode_step(cfg, mesh, shape, input_specs(cfg, shape))
with mesh:
    p_sh = jax.device_put(params, bundle.param_shardings)
    c_sh = jax.device_put(cache, bundle.in_shardings[2])
    out, _ = bundle.step_fn(p_sh, {"token": toks[:, -1]}, c_sh)
err = float(jnp.max(jnp.abs(out - ref_logits)))
assert err < 2e-2, err  # f32-vs-sharded-reduction tolerance
print("DECODE_OK", err)
"""


COMPRESSION_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.optim import compressed_psum, init_residual

mesh = jax.make_mesh((8,), ("pod",))
rng = np.random.default_rng(0)
g_all = jnp.asarray(rng.normal(size=(8, 128)), jnp.float32)
res = jnp.zeros((8, 128), jnp.float32)

def f(g, r):
    out, new_r = compressed_psum({"g": g[0]}, {"g": r[0]}, "pod")
    return out["g"][None], new_r["g"][None]

fm = shard_map(f, mesh=mesh, in_specs=(P("pod"), P("pod")), out_specs=(P("pod"), P("pod")))
out, new_res = fm(g_all, res)
true_mean = jnp.mean(g_all, axis=0)
err = float(jnp.max(jnp.abs(out[0] - true_mean)))
q_step = float(jnp.max(jnp.abs(g_all)) / 127.0)
assert err <= q_step * 1.5, (err, q_step)
# all shards agree
assert float(jnp.max(jnp.abs(out - out[0:1]))) < 1e-6
print("COMPRESSION_OK", err)
"""


ELASTIC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np, tempfile
from repro import configs
from repro.checkpoint import manager as ckpt
from repro.configs.shapes import ShapeSpec, input_specs
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_mesh

cfg = configs.get_smoke("phi3-mini-3.8b")
shape = ShapeSpec("t", 32, 8, "train")
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)), jnp.int32)}

# train 3 steps on a 2x4 mesh, checkpoint, restore onto a 4x2 mesh (elastic
# reshard), continue - loss trajectory must continue smoothly
d = tempfile.mkdtemp()
b1 = steps_lib.build_train_step(cfg, make_mesh((2, 4), ("data", "model")),
                                input_specs(cfg, shape))
state = b1.init_state(jax.random.PRNGKey(0))
for _ in range(3):
    state, m1 = b1.step_fn(state, batch)
ckpt.save(d, 3, state)
l3 = float(m1["loss"])

b2 = steps_lib.build_train_step(cfg, make_mesh((4, 2), ("data", "model")),
                                input_specs(cfg, shape))
restored, _ = ckpt.restore(d, 3, b2.state_shapes, shardings=b2.state_shardings)
state2, m2 = b2.step_fn(restored, batch)
l4 = float(m2["loss"])
assert np.isfinite(l4) and l4 < l3 + 0.5, (l3, l4)
print("ELASTIC_OK", l3, l4)
"""


@pytest.mark.slow
def test_distributed_train_step():
    out = _run(TRAIN_SCRIPT)
    assert "TRAIN_OK" in out


@pytest.mark.slow
def test_distributed_decode_matches_single_device():
    out = _run(DECODE_SCRIPT)
    assert "DECODE_OK" in out


@pytest.mark.slow
def test_compressed_psum_multi_device():
    out = _run(COMPRESSION_SCRIPT)
    assert "COMPRESSION_OK" in out


@pytest.mark.slow
def test_elastic_reshard_restore():
    out = _run(ELASTIC_SCRIPT)
    assert "ELASTIC_OK" in out
