"""Vectorized design-space grid (design._grid_metrics) must agree with the
scalar Table III reference (design.evaluate_point) across the whole grid."""
import numpy as np
import pytest

from repro.core import design
from repro.core.compute_models import TECH_65NM
from repro.core.quant import UNIFORM_STATS


@pytest.mark.parametrize("kind", ["qs", "qr", "cm"])
def test_grid_matches_evaluate_point(kind):
    n, bx, bw, max_rows = 512, 6, 6, 512
    g = design._grid_metrics(kind, n, bx, bw, UNIFORM_STATS, TECH_65NM,
                             max_rows, 0.5)
    checked = 0
    for ki, knob in enumerate(g["knobs"]):
        for bi, n_banks in enumerate(g["banks"]):
            # scalar reference with an unreachable target => always a point
            pt = design.evaluate_point(
                kind, n, int(n_banks), bx, bw, UNIFORM_STATS, TECH_65NM,
                float(knob), snr_t_target_db=-1e9, max_rows=max_rows,
            )
            if pt is None:  # invalid banking (rows out of range)
                assert not g["valid"][ki, bi]
                continue
            assert g["valid"][ki, bi]
            np.testing.assert_allclose(g["snr_t_db"][ki, bi], pt.snr_t_db,
                                       rtol=1e-9, atol=1e-9)
            np.testing.assert_allclose(g["energy"][ki, bi], pt.energy_per_dp,
                                       rtol=1e-9)
            np.testing.assert_allclose(g["delay"][ki, bi], pt.delay_per_dp,
                                       rtol=1e-9)
            checked += 1
    assert checked > 20


def test_optimize_matches_scalar_exhaustive():
    """The batched optimize must return the same design point as the legacy
    exhaustive scalar loop."""
    for n, target in [(256, 12.0), (256, 26.0), (2048, 18.0), (512, 20.0)]:
        fast = design.optimize(n=n, snr_t_target_db=target)
        # scalar exhaustive reference
        best = None
        for kind in ("qs", "qr", "cm"):
            from repro.core import precision as prec
            pa = prec.assign_precisions(target + 3.0, n, UNIFORM_STATS)
            knobs = design.C_O_GRID if kind == "qr" else design.V_WL_GRID
            for knob in knobs:
                for n_banks in design.BANK_SPLITS:
                    pt = design.evaluate_point(
                        kind, n, n_banks, pa.bx, pa.bw, UNIFORM_STATS,
                        TECH_65NM, knob, target)
                    if pt is None:
                        continue
                    if best is None or pt.energy_per_dp < best.energy_per_dp:
                        best = pt
        assert (fast is None) == (best is None)
        if best is not None:
            assert fast.arch_kind == best.arch_kind
            assert fast.n_banks == best.n_banks
            np.testing.assert_allclose(fast.energy_per_dp, best.energy_per_dp,
                                       rtol=1e-12)
            np.testing.assert_allclose(fast.knob, best.knob)
