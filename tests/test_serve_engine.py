"""Continuous-batching engine correctness: greedy-token equivalence against
per-request sequential decode (and against the frozen wave server), including
the unequal-prompt-length admission the wave server could not run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import ArchConfig
from repro.launch.serve import Engine, Request, needs_exact_prefill, prefill_bucket, serve
from repro.models import decode_step, init_params, prefill

TINY = dict(
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
    max_seq=128, flash_q_block=16, flash_kv_block=16, dtype="float32",
)

CASES = {
    "dense-rope": ArchConfig(name="t", family="dense", **TINY),
    "windowed": ArchConfig(
        name="t", family="dense", **TINY, pattern=("local", "attn"), window=16,
        attn_softcap=50.0, final_softcap=30.0, post_norm=True, emb_scale=True,
    ),
    "musicgen-smoke": configs.get_smoke("musicgen-medium"),
}


def _greedy_sequential(cfg, prompt: np.ndarray, max_new: int):
    """Reference: one request alone, exact-length prefill + per-token decode."""
    cache_len = len(prompt) + max_new + 8
    logits, cache = prefill(jax_params(cfg), cfg, jnp.asarray(prompt)[None, :],
                            cache_len=cache_len)
    out = [int(jnp.argmax(logits[0, -1]))]
    while len(out) < max_new:
        tok = jnp.asarray([out[-1]], jnp.int32)
        logits, cache = decode_step(jax_params(cfg), cfg, tok, cache)
        out.append(int(jnp.argmax(logits[0, 0])))
    return out


_PARAMS = {}


def jax_params(cfg):
    key = id(cfg)
    if key not in _PARAMS:
        _PARAMS[key] = init_params(jax.random.PRNGKey(0), cfg)
    return _PARAMS[key]


@pytest.mark.parametrize("case", list(CASES))
def test_engine_matches_sequential_unequal_prompts(case):
    """Unequal prompt lengths admitted into ONE batch (per-slot positions +
    bucketed prefill) must reproduce each request's solo greedy decode."""
    cfg = CASES[case]
    lens = [5, 9, 12, 17]
    max_new = 6
    rnp = np.random.default_rng(3)
    prompts = [rnp.integers(0, cfg.vocab_size, l) for l in lens]
    cache_len = 32 + max_new + 8
    engine = Engine(cfg, jax_params(cfg), batch_slots=4, cache_len=cache_len,
                    max_chunk=4)
    reqs = [Request(rid=i, prompt=p, max_new=max_new)
            for i, p in enumerate(prompts)]
    out = serve(engine, reqs)
    assert len(out) == len(prompts)
    # all four slots genuinely decoded together at different depths
    assert engine.decode_calls < sum(max_new for _ in prompts)
    for r in out:
        ref = _greedy_sequential(cfg, r.prompt, max_new)
        assert r.out == ref, (r.rid, r.out, ref)


def test_engine_beyond_window_unequal():
    """Ring-buffer decode with per-slot phases: generate far past the window
    from bucket-padded prefills of different true lengths."""
    cfg = CASES["windowed"]  # window 16
    lens = [6, 13, 20, 27]
    max_new = 24  # every slot wraps the ring at its own phase
    rnp = np.random.default_rng(4)
    prompts = [rnp.integers(0, cfg.vocab_size, l) for l in lens]
    engine = Engine(cfg, jax_params(cfg), batch_slots=4,
                    cache_len=32 + max_new + 8, max_chunk=8)
    out = serve(engine, [Request(rid=i, prompt=p, max_new=max_new)
                         for i, p in enumerate(prompts)])
    for r in out:
        ref = _greedy_sequential(cfg, r.prompt, max_new)
        assert r.out == ref, (r.rid, r.out, ref)


def test_engine_matches_wave_server_digital():
    """Equal-length digital serving: frozen wave server and the new engine
    must produce identical greedy tokens."""
    from benchmarks.serve_bench import WaveServer, _serve_wave

    cfg = CASES["musicgen-smoke"]
    max_new = 6
    rnp = np.random.default_rng(0)
    prompts = [rnp.integers(0, cfg.vocab_size, 12) for _ in range(6)]

    wave = WaveServer(cfg, jax_params(cfg), 2, 12 + max_new + 8)
    wave_out = _serve_wave(wave, [Request(rid=i, prompt=p, max_new=max_new)
                                  for i, p in enumerate(prompts)])
    engine = Engine(cfg, jax_params(cfg), 2, 16 + max_new + 8, max_chunk=4)
    eng_out = serve(engine, [Request(rid=i, prompt=p, max_new=max_new)
                             for i, p in enumerate(prompts)])
    wave_by_rid = {r.rid: r.out for r in wave_out}
    for r in eng_out:
        assert r.out == wave_by_rid[r.rid], (r.rid, r.out, wave_by_rid[r.rid])


def test_continuous_admission_refills_freed_slots():
    """A short request finishing mid-stream frees its slot for a later,
    longer request while the other slot keeps decoding (no wave barrier)."""
    cfg = CASES["dense-rope"]
    rnp = np.random.default_rng(5)
    reqs = [
        Request(rid=0, prompt=rnp.integers(0, cfg.vocab_size, 4), max_new=2),
        Request(rid=1, prompt=rnp.integers(0, cfg.vocab_size, 11), max_new=9),
        Request(rid=2, prompt=rnp.integers(0, cfg.vocab_size, 7), max_new=5),
    ]
    engine = Engine(cfg, jax_params(cfg), batch_slots=2, cache_len=40,
                    max_chunk=4)
    out = serve(engine, list(reqs))
    assert sorted(r.rid for r in out) == [0, 1, 2]
    for r in out:
        ref = _greedy_sequential(cfg, r.prompt, r.max_new)
        assert r.out == ref, (r.rid, r.out, ref)


def test_bucketing_policy():
    cfg = CASES["dense-rope"]
    assert not needs_exact_prefill(cfg)
    assert prefill_bucket(5, True, 64) == 8
    assert prefill_bucket(12, True, 64) == 16
    assert prefill_bucket(17, True, 64) == 32
    assert prefill_bucket(17, False, 64) == 17  # recurrent/moe: exact
    ssm_cfg = configs.get_smoke("mamba2-2.7b")
    assert needs_exact_prefill(ssm_cfg)


def test_engine_exact_prefill_recurrent():
    """Recurrent patterns fall back to exact-length prefill but still admit
    unequal lengths in one batch (decode is position-free there)."""
    cfg = configs.get_smoke("mamba2-2.7b")
    max_new = 4
    rnp = np.random.default_rng(6)
    prompts = [rnp.integers(0, cfg.vocab_size, l) for l in (5, 11)]
    engine = Engine(cfg, jax_params(cfg), batch_slots=2, cache_len=32,
                    max_chunk=4)
    out = serve(engine, [Request(rid=i, prompt=p, max_new=max_new)
                         for i, p in enumerate(prompts)])
    for r in out:
        ref = _greedy_sequential(cfg, r.prompt, max_new)
        assert r.out == ref, (r.rid, r.out, ref)
