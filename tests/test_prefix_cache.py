"""Prefix-sharing paged KV correctness.

The subsystem's acceptance anchor: an engine serving shared-prefix traffic
through the radix prefix cache (linked blocks + suffix-only warm prefill,
copy-on-write on fully-cached prompts, LRU eviction under pool pressure)
must emit greedy tokens BIT-IDENTICAL to a cold-cache engine under frozen
calibration on digital / imc_analytic / imc_bitserial - including across
recompute-preemption and resume of a prefix-sharing slot.

Plus the radix index unit contract (match / insert / remove / leaves_lru,
first-writer-wins) and the allocator's refcount error paths.
"""
import jax
import numpy as np
import pytest

from repro import configs
from repro.configs.base import ArchConfig
from repro.core.imc_linear import IMCConfig
from repro.core.substrate import as_substrate, calibrate_model
from repro.launch.serve import BlockAllocator, Engine, Request, serve
from repro.models import init_params
from repro.runtime.prefix_cache import PrefixCache

TINY = dict(
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
    max_seq=128, flash_q_block=16, flash_kv_block=16, dtype="float32",
)
DENSE = ArchConfig(name="t", family="dense", **TINY)
WINDOWED = ArchConfig(name="t", family="dense", **TINY,
                      pattern=("local", "attn"), window=16)

SUBSTRATES = ["digital", "imc_analytic", "imc_bitserial"]

_PARAMS = {}


def jax_params(cfg):
    key = id(cfg)
    if key not in _PARAMS:
        _PARAMS[key] = init_params(jax.random.PRNGKey(0), cfg)
    return _PARAMS[key]


def _frozen_smoke(substrate):
    """Frozen-calibration smoke config: batch-invariant IMC forwards, the
    precondition for warm==cold bit-identity (same contract the recompute-
    preemption suite pins)."""
    base = configs.get_smoke("musicgen-medium")
    if substrate == "digital":
        return base
    cfg_dyn = base.replace(
        imc=IMCConfig(mode=substrate, bx=7, bw=7, v_wl=0.7))
    params = jax_params(cfg_dyn)
    ref_batch = np.random.default_rng(1).integers(0, base.vocab_size, (2, 24))
    cfg = calibrate_model(cfg_dyn, params, [ref_batch])
    _PARAMS[id(cfg)] = params
    assert as_substrate(cfg.imc).policy == "frozen"
    return cfg


def _shared_requests(cfg, prefix_len, tail_lens, max_new, seed=3):
    rnp = np.random.default_rng(seed)
    prefix = rnp.integers(0, cfg.vocab_size, prefix_len)
    return [Request(rid=i,
                    prompt=np.concatenate(
                        [prefix, rnp.integers(0, cfg.vocab_size, l)]),
                    max_new=max_new)
            for i, l in enumerate(tail_lens)]


# ---------------------------------------------------------------------------
# radix index unit contract
# ---------------------------------------------------------------------------


def test_prefix_cache_match_insert_roundtrip():
    pc = PrefixCache(block_size=4)
    toks = list(range(11))  # 2 full chunks + a 3-token partial tail
    assert pc.match(toks) == []
    new = pc.insert(toks, [5, 7, 9])  # extra blocks beyond chunks ignored
    assert new == [5, 7] and len(pc) == 2  # partial tail never indexed
    chain = pc.match(toks)
    assert [n.block for n in chain] == [5, 7]
    # a shorter shared prefix matches the shorter chain
    assert [n.block for n in pc.match(list(range(6)))] == [5]
    # divergence after the first chunk
    assert [n.block for n in pc.match([0, 1, 2, 3, 99, 98, 97, 96])] == [5]
    assert pc.match([99, 98, 97, 96]) == []
    with pytest.raises(ValueError, match="needs 2 blocks"):
        pc.insert(list(range(8)), [1])


def test_prefix_cache_first_writer_wins():
    pc = PrefixCache(block_size=4)
    pc.insert(list(range(8)), [3, 4])
    # a concurrent duplicate admission re-inserts the same chain backed by
    # DIFFERENT physical blocks: existing nodes win, nothing new to cache
    assert pc.insert(list(range(8)), [8, 9]) == []
    assert [n.block for n in pc.match(list(range(8)))] == [3, 4]
    # extending the chain caches only the new suffix node
    assert pc.insert(list(range(12)), [8, 9, 11]) == [11]


def test_prefix_cache_remove_and_lru_order():
    pc = PrefixCache(block_size=4)
    pc.insert(list(range(8)), [1, 2])        # chain A (leaf block 2)
    pc.insert([9, 9, 9, 9], [3])             # chain B (leaf block 3)
    # interior nodes are never eviction candidates
    interior = pc.match(list(range(8)))[0]
    with pytest.raises(ValueError, match="leaf"):
        pc.remove(interior)
    # stamping A's recency (a later insert touches the whole chain) makes
    # B the LRU leaf
    pc.insert(list(range(8)), [1, 2])
    leaves = pc.leaves_lru()
    assert [n.block for n in leaves] == [3, 2]
    pc.remove(leaves[0])
    assert pc.match([9, 9, 9, 9]) == [] and len(pc) == 2
    # removing A's leaf exposes its parent as the next leaf
    pc.remove(pc.leaves_lru()[0])
    assert [n.block for n in pc.leaves_lru()] == [1]


# ---------------------------------------------------------------------------
# allocator refcount / cache error paths (directed; property sweep lives in
# test_properties.py)
# ---------------------------------------------------------------------------


def test_allocator_refcount_sharing_and_eviction():
    a = BlockAllocator(6)
    got = a.alloc(2)
    a.retain(got)  # a second sharer links the same blocks
    a.free(got)    # first sharer retires: still referenced, still held
    assert a.used_count == 2 and a.free_count == 3
    a.register_cached(got[0])
    assert a.evictable_count == 0  # referenced blocks are not evictable
    with pytest.raises(ValueError, match="not evictable"):
        a.evict(got[0])
    a.free(got)    # last reference drops
    # the cached block parks idle; the uncached one returns to the pool
    assert a.free_count == 4 and a.evictable_count == 1
    assert a.is_evictable(got[0]) and a.used_count == 1
    with pytest.raises(ValueError, match="double free"):
        a.free([got[0]])  # zero-ref cached block: no reference left to drop
    a.evict(got[0])
    assert a.free_count == 5 and a.used_count == 0
    with pytest.raises(ValueError, match="retain of unallocated"):
        a.retain([got[0]])
    with pytest.raises(ValueError, match="cannot cache unallocated"):
        a.register_cached(3)


# ---------------------------------------------------------------------------
# warm == cold greedy bit-identity (the correctness anchor)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("substrate", SUBSTRATES)
def test_prefix_hits_bit_identical_to_cold(substrate):
    """Shared 16-token system prompt over three requests: the first admission
    is cold and indexes its blocks; both later ones link the cached chain and
    prefill only their suffix - with tokens bit-identical to a cold-cache
    engine on every substrate (IMC modes frozen)."""
    cfg = _frozen_smoke(substrate)
    max_new = 4 if substrate == "imc_bitserial" else 5
    tails = [5, 9, 3] if substrate != "imc_bitserial" else [5, 3]
    reqs = lambda: _shared_requests(cfg, 16, tails, max_new)  # noqa: E731

    cold = Engine(cfg, jax_params(cfg), batch_slots=4, cache_len=48,
                  max_chunk=4)
    cold_out = {r.rid: r.out for r in serve(cold, reqs())}

    warm = Engine(cfg, jax_params(cfg), batch_slots=4, cache_len=48,
                  max_chunk=4, prefix_cache=True)
    rq = reqs()
    done = serve(warm, [rq[0]])  # seeds the index (a miss)
    done += serve(warm, rq[1:])
    warm_out = {r.rid: r.out for r in done}

    assert warm.prefix_hits == len(tails) - 1
    assert warm.prefix_hit_tokens == 16 * (len(tails) - 1)
    assert warm.cow_copies == 0  # prompts extend past the cached chain
    for rid, out in cold_out.items():
        assert warm_out[rid] == out, (substrate, rid, warm_out[rid], out)
    # retired sharers released their refs; only idle cached blocks remain
    assert warm.alloc.used_count == warm.alloc.evictable_count > 0


@pytest.mark.parametrize("substrate", SUBSTRATES)
def test_cow_on_fully_cached_prompt_bit_identical(substrate):
    """A duplicate prompt whose length is an exact block multiple: the whole
    prompt is cached, so the mandatory final-token re-feed would write INTO
    the last shared block - copy-on-write must give the new slot a private
    copy, leave the shared block byte-identical for its peers, and keep
    greedy tokens equal to the cold run."""
    cfg = _frozen_smoke(substrate)
    max_new = 4 if substrate == "imc_bitserial" else 5
    dup = np.random.default_rng(5).integers(0, cfg.vocab_size, 16)
    mk = lambda rid: Request(rid=rid, prompt=dup.copy(),  # noqa: E731
                             max_new=max_new)

    cold = Engine(cfg, jax_params(cfg), batch_slots=2, cache_len=32,
                  max_chunk=4)
    cold_out = [serve(cold, [mk(i)])[0].out for i in range(3)]

    warm = Engine(cfg, jax_params(cfg), batch_slots=2, cache_len=32,
                  max_chunk=4, prefix_cache=True)
    warm_out = [serve(warm, [mk(i)])[0].out for i in range(3)]

    assert warm.prefix_hits == 2 and warm.cow_copies == 2
    # the third request still matched the ORIGINAL chain (CoW copies stay
    # request-private; first writer wins keeps one canonical chain)
    assert len(warm.prefix) == 2
    assert warm_out == cold_out, (substrate, warm_out, cold_out)


@pytest.mark.parametrize("substrate", SUBSTRATES)
def test_preempt_resume_of_prefix_sharing_slot_bit_exact(substrate):
    """A pool too small for both sharers' generation tails: lazy growth fails
    mid-decode, a prefix-sharing victim is recompute-preempted (its refs
    release; the shared block must NOT be pulled out from under its peer),
    and the resume re-admission itself takes the warm path off the still-
    cached prefix - tokens bit-identical to an ample-pool run."""
    cfg = _frozen_smoke(substrate)
    max_new = 5
    tails = [5, 5, 6]

    def _run(kv_blocks):
        eng = Engine(cfg, jax_params(cfg), batch_slots=2, cache_len=32,
                     max_chunk=4, kv_blocks=kv_blocks, prefix_cache=True)
        done = serve(eng, [_shared_requests(cfg, 8, tails, max_new)[0]])
        # two sharers resident at once: their growth contends for the pool
        done += serve(eng, _shared_requests(cfg, 8, tails, max_new)[1:])
        return eng, {r.rid: r.out for r in done}

    ample_eng, ample = _run(kv_blocks=16)
    assert ample_eng.preempt_count == 0
    assert ample_eng.prefix_hits == 2
    tight_eng, tight = _run(kv_blocks=5)
    assert tight_eng.preempt_count >= 1
    # resume re-admissions rode the cached prefix too
    assert tight_eng.prefix_hits > ample_eng.prefix_hits
    assert tight == ample, (substrate, tight, ample)
    assert tight_eng.alloc.used_count == tight_eng.alloc.evictable_count


def test_eviction_under_pool_pressure_keeps_serving():
    """Distinct-prefix requests through a pool with room for roughly one
    request: every admission must reclaim idle cached blocks (LRU leaf-first)
    and outputs stay exact - the cache degrades, the engine never deadlocks."""
    cfg = DENSE
    rnp = np.random.default_rng(7)
    prompts = [rnp.integers(0, cfg.vocab_size, 16) for _ in range(3)]

    cold = Engine(cfg, jax_params(cfg), batch_slots=1, cache_len=32,
                  max_chunk=4)
    cold_out = [serve(cold, [Request(rid=i, prompt=p.copy(), max_new=4)])[0].out
                for i, p in enumerate(prompts)]

    eng = Engine(cfg, jax_params(cfg), batch_slots=1, cache_len=32,
                 max_chunk=4, kv_blocks=4, prefix_cache=True)
    outs = [serve(eng, [Request(rid=i, prompt=p.copy(), max_new=4)])[0].out
            for i, p in enumerate(prompts)]

    assert eng.prefix_evictions >= 1
    assert outs == cold_out
    assert eng.alloc.free_count + eng.alloc.used_count == 3
    stats = eng.prefix_stats()
    assert stats["evictions"] == eng.prefix_evictions
    assert stats["cached_blocks"] == eng.alloc.evictable_count


def test_prefix_cache_gated_off_for_non_paged_and_windowed():
    """Eligibility gate: recurrent families (nothing paged) and windowed
    patterns (per-slot rings are position-aliased, not shareable) silently
    disable the cache instead of serving wrong tokens."""
    for cfg in (configs.get_smoke("mamba2-2.7b"), WINDOWED):
        eng = Engine(cfg, jax_params(cfg), batch_slots=2, cache_len=32,
                     max_chunk=4, prefix_cache=True)
        assert eng.prefix is None
        assert eng.prefix_stats()["enabled"] is False
        rnp = np.random.default_rng(8)
        req = Request(rid=0, prompt=rnp.integers(0, cfg.vocab_size, 9),
                      max_new=3)
        out = serve(eng, [req])
        assert out[0].error is None and len(out[0].out) == 3
