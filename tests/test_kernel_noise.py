"""In-kernel noise path validation (kernel rewrite PR).

Three layers of guarantees:

  1. bit-exact: the packed-plane kernel on the noiseless/no-ADC path equals
     the plain quantized matmul, and the fallback-PRNG noisy path equals the
     ref.py oracle draw-for-draw (same seed -> same bits).
  2. statistical: the in-kernel-RNG bit-serial output matches the oracle's
     *empirical* SNR within 1 dB at the paper's 512-row design point, and
     both match the closed-form recombined thermal-noise variance.
  3. distributional: the counter PRNG itself produces N(0,1) marginals.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.archs import QSArch
from repro.kernels import imc_mvm, ops, prng, ref
from repro.kernels.ref import BitSerialSpec, quantize_codes

KEY = jax.random.PRNGKey(21)

# the paper's 6x6-bit, 512-row QS-Arch design point
B, K, M = 64, 512, 128
BX = BW = 6
ROWS = 512


def _design_point_codes(key):
    k1, k2 = jax.random.split(key)
    x = jnp.abs(jax.random.normal(k1, (B, K)))
    w = jax.random.uniform(k2, (K, M), minval=-1, maxval=1)
    xc, _ = quantize_codes(x, BX, False, jnp.max(jnp.abs(x)))
    wc, _ = quantize_codes(w, BW, True, jnp.max(jnp.abs(w)))
    return xc, wc


def _snr_db(y_noisy, y_clean):
    err = y_noisy - y_clean
    err = err - jnp.mean(err)
    return 10.0 * np.log10(float(jnp.var(y_clean)) / float(jnp.mean(err**2)))


def test_counter_prng_is_standard_normal():
    b_idx = jnp.arange(400, dtype=jnp.int32)[:, None]
    m_idx = jnp.arange(500, dtype=jnp.int32)[None, :]
    z = np.asarray(
        prng.counter_normal(1234, prng.TAG_BITSERIAL, 0, 7, b_idx, m_idx)
    ).ravel()
    assert abs(z.mean()) < 0.01
    assert abs(z.std() - 1.0) < 0.01
    # tail mass sane (not uniform, not clipped)
    assert 0.02 < (np.abs(z) > 2.0).mean() < 0.07
    assert np.abs(z).max() < 6.5


def test_counter_prng_streams_are_independent():
    """Different planes/banks/seeds decorrelate (counter hash avalanche)."""
    b_idx = jnp.arange(256, dtype=jnp.int32)[:, None]
    m_idx = jnp.arange(256, dtype=jnp.int32)[None, :]

    def draw(seed, bank, plane):
        return np.asarray(
            prng.counter_normal(
                seed, prng.TAG_BITSERIAL, bank, plane, b_idx, m_idx
            )
        ).ravel()

    base = draw(0, 0, 0)
    for other in (draw(0, 0, 1), draw(0, 1, 0), draw(1, 0, 0)):
        r = np.corrcoef(base, other)[0, 1]
        assert abs(r) < 0.02, r


def test_packed_plane_kernel_bitexact_noiseless():
    """Satellite criterion: noiseless, no-ADC packed-plane kernel == plain
    quantized matmul, exactly (integer plane DPs are exact in f32)."""
    xc, wc = _design_point_codes(jax.random.fold_in(KEY, 0))
    spec = BitSerialSpec(bx=BX, bw=BW, b_adc=16, rows=ROWS, k_h=1e9, v_c=1e9,
                         x_signed=False, apply_adc=False)
    yk = imc_mvm.imc_bitserial_matmul(xc, wc, None, spec, interpret=True)
    assert np.array_equal(np.asarray(yk), np.asarray(xc @ wc))


def test_inkernel_noise_reproduces_oracle_draws():
    """Fallback counter PRNG: same seed -> kernel and oracle generate the
    same noise, so outputs agree to float tolerance pre-ADC (the only
    permitted difference is last-ulp FMA contraction between the two XLA
    graphs) and to rare one-step code flips with the ADC on."""
    xc, wc = _design_point_codes(jax.random.fold_in(KEY, 1))
    spec = BitSerialSpec(bx=BX, bw=BW, b_adc=8, rows=ROWS, k_h=60.0, v_c=55.0,
                         x_signed=False, apply_adc=False, sigma_noise=0.5)
    yk = imc_mvm.imc_bitserial_matmul(xc, wc, None, spec, seed=777,
                                      interpret=True)
    yr = ref.imc_bitserial_ref(xc, wc, None, spec, seed=777)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), rtol=1e-5,
                               atol=1e-2)

    spec_adc = BitSerialSpec(bx=BX, bw=BW, b_adc=8, rows=ROWS, k_h=60.0,
                             v_c=55.0, x_signed=False, sigma_noise=0.5)
    yk = imc_mvm.imc_bitserial_matmul(xc, wc, None, spec_adc, seed=777,
                                      interpret=True)
    yr = ref.imc_bitserial_ref(xc, wc, None, spec_adc, seed=777)
    frac = float(jnp.mean(jnp.abs(yk - yr) > 0))
    assert frac < 1e-3, frac


def test_bitserial_snr_within_1db_of_oracle():
    """Satellite criterion: empirical SNR of the in-kernel-RNG kernel within
    1 dB of the ref.py oracle's empirical SNR at the 512-row design point
    (independent seeds - this is the statistical equivalence guarantee that
    holds on the TPU hardware-PRNG path too)."""
    xc, wc = _design_point_codes(jax.random.fold_in(KEY, 2))
    sigma = 1.5
    spec_clean = BitSerialSpec(bx=BX, bw=BW, b_adc=8, rows=ROWS, k_h=1e9,
                               v_c=1e9, x_signed=False, apply_adc=False)
    spec_noisy = BitSerialSpec(bx=BX, bw=BW, b_adc=8, rows=ROWS, k_h=1e9,
                               v_c=1e9, x_signed=False, apply_adc=False,
                               sigma_noise=sigma)
    y_clean = ref.imc_bitserial_ref(xc, wc, None, spec_clean)
    snr_kernel = _snr_db(
        imc_mvm.imc_bitserial_matmul(xc, wc, None, spec_noisy, seed=101,
                                     interpret=True),
        y_clean,
    )
    snr_oracle = _snr_db(
        ref.imc_bitserial_ref(xc, wc, None, spec_noisy, seed=202), y_clean
    )
    assert abs(snr_kernel - snr_oracle) < 1.0, (snr_kernel, snr_oracle)

    # both must also sit within 1 dB of the closed-form recombined thermal
    # noise: var = n_banks * S_w * S_x * sigma^2 (repro.core.archs algebra)
    s_w = (4.0**BW - 1) / 3.0
    s_x = (4.0**BX - 1) / 3.0
    var_pred = s_w * s_x * sigma**2  # n_banks == 1 at this design point
    snr_pred = 10.0 * np.log10(float(jnp.var(y_clean)) / var_pred)
    assert abs(snr_kernel - snr_pred) < 1.0, (snr_kernel, snr_pred)
    assert abs(snr_oracle - snr_pred) < 1.0, (snr_oracle, snr_pred)


def test_ops_bitserial_noise_seed_reproducible():
    """Same key -> identical output; different key -> different noise (the
    seed now rides inside the kernel instead of an HBM tensor).  Uses the
    n=256 design point: at overloaded points (e.g. 512 rows at 0.7 V) the
    headroom clip saturates every plane DP and noise cannot flip any ADC
    code, so seeds become unobservable."""
    arch = QSArch(n=256, bx=BX, bw=BW, v_wl=0.7)
    cfg = ops.derive_config_from_arch(arch, x_signed=False, use_kernel=True)
    k1, k2, k3 = jax.random.split(jax.random.fold_in(KEY, 3), 3)
    x = jnp.abs(jax.random.normal(k1, (16, 256)))
    w = jax.random.uniform(k2, (256, 32), minval=-1, maxval=1)
    y1 = ops.imc_matmul(x, w, cfg, key=k3)
    y2 = ops.imc_matmul(x, w, cfg, key=k3)
    y3 = ops.imc_matmul(x, w, cfg, key=jax.random.fold_in(k3, 1))
    assert np.array_equal(np.asarray(y1), np.asarray(y2))
    assert not np.array_equal(np.asarray(y1), np.asarray(y3))


def test_analytic_inkernel_noise_statistics():
    """The analytic kernel's in-kernel epilogue noise has the configured
    sigma_out (measured against the noiseless kernel output)."""
    key = jax.random.fold_in(KEY, 4)
    k1, k2 = jax.random.split(key)
    xc = jnp.round(jax.random.normal(k1, (128, 256)) * 8)
    wc = jnp.round(jax.random.normal(k2, (256, 128)) * 8)
    sig = float(jnp.std(xc @ wc)) + 1e-6
    sigma_out = 0.1
    spec_noisy = ref.AnalyticSpec(b_adc=8, sigma_out=sigma_out, y_clip=4.0,
                                  apply_adc=False)
    y_clean = imc_mvm.imc_analytic_matmul(xc / sig, wc, spec_noisy,
                                          interpret=True)
    y_noisy = imc_mvm.imc_analytic_matmul(xc / sig, wc, spec_noisy, seed=5150,
                                          interpret=True)
    emp = float(jnp.std(y_noisy - y_clean))
    assert abs(emp - sigma_out) / sigma_out < 0.05, emp
