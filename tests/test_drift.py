"""Online calibration (runtime.drift) + the hardened serve failure path.

Detector unit tests pin the one-sided superset test and its threshold
edges; engine tests pin the robustness contracts of ISSUE 6: bit-exact
chunk outputs across an atomic calibration hot-swap with zero recompiles,
passive shadow recording (outputs untouched), drift-injection detection +
SNR_T recovery within 1 dB of a fresh-frozen reference, and per-request
failure isolation (poison prefill, transient/persistent decode errors).
"""
import jax
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core.imc_linear import IMCConfig
from repro.core.substrate import (
    Calibration,
    SiteStats,
    as_substrate,
    calibrate_model,
)
from repro.launch.serve import Engine, Request, serve
from repro.models import init_params
from repro.runtime import fault as fault_lib
from repro.runtime.drift import (
    DriftConfig,
    DriftMonitor,
    DriftThresholds,
    detect_drift,
    effective_snr_t_db,
    estimated_clip_rate,
    refreshed_calibration,
    site_snr_table,
)

# ---------------------------------------------------------------------------
# detector unit tests (pure host-side, no jit)
# ---------------------------------------------------------------------------


def _cal(**sites):
    return Calibration(tuple(
        (name, SiteStats(*vals)) for name, vals in sites.items()))


FROZEN = _cal(**{"mlp.wi": (1.0, 2.0, 3.0), "attn.wq": (0.5, 1.0, 1.5),
                 "*": (1.0, 2.0, 3.0)})


def test_one_sided_superset_test():
    """observed <= frozen NEVER flags (running maxima: below-range traffic
    carries no evidence); observed > frozen does."""
    below = _cal(**{"mlp.wi": (0.5, 1.0, 1.5)})
    rep = detect_drift(FROZEN, below)
    assert not rep.drifted
    assert all(e.rel_excess == 0.0 for e in rep.entries)
    above = _cal(**{"mlp.wi": (2.0, 2.0, 3.0)})
    rep = detect_drift(FROZEN, above)
    assert rep.drifted
    assert rep.drifted_sites == ("mlp.wi",)
    (x_entry,) = [e for e in rep.entries if e.field == "x_max"]
    assert x_entry.drifted and x_entry.rel_excess == pytest.approx(1.0)
    # the other fields matched exactly: not drifted
    assert not any(e.drifted for e in rep.entries if e.field != "x_max")


def test_threshold_edges():
    """Strictly greater-than: a site sitting exactly at the threshold has
    not drifted; epsilon above it has."""
    thr = DriftThresholds(rel_excess=0.25, clip_rate=1.0)  # clip disabled
    at = _cal(**{"mlp.wi": (1.25, 2.0, 3.0)})  # rel excess exactly 0.25
    assert not detect_drift(FROZEN, at, thr).drifted
    above = _cal(**{"mlp.wi": (1.3125, 2.0, 3.0)})
    assert detect_drift(FROZEN, above, thr).drifted


def test_clip_rate_proxy():
    """The clip-rate backstop: Gaussian tail mass past the frozen range at
    the PAR assumption, monotone in the observed excess, and able to flag a
    site the rel-excess test was configured to ignore."""
    assert estimated_clip_rate(1.0, 0.5) < estimated_clip_rate(1.0, 1.0) \
        < estimated_clip_rate(1.0, 2.0)
    assert estimated_clip_rate(1.0, 0.5) < 1e-6  # over-provisioned: no clip
    thr = DriftThresholds(rel_excess=10.0, clip_rate=1e-3)  # rel disabled
    shifted = _cal(**{"mlp.wi": (1.5, 2.0, 3.0)})  # zeta_eff = 4/1.5 = 2.67
    rep = detect_drift(FROZEN, shifted, thr)
    assert rep.drifted
    (x_entry,) = [e for e in rep.entries if e.drifted]
    assert x_entry.field == "x_max" and x_entry.clip_rate > 1e-3


def test_unknown_site_checked_against_fallback():
    """An observed site the frozen calibration does not name is compared to
    the '*' entry (the stats the frozen engine actually serves it from); the
    '*' aggregate itself is skipped as a checked site."""
    obs = _cal(**{"new.site": (3.0, 2.0, 3.0), "*": (99.0, 99.0, 99.0)})
    rep = detect_drift(FROZEN, obs)
    assert rep.checked_sites == 1
    assert rep.drifted and rep.drifted_sites == ("new.site",)


def test_report_dict_shape():
    rep = detect_drift(FROZEN, _cal(**{"mlp.wi": (2.0, 2.0, 3.0)}))
    d = rep.to_dict()
    assert d["drifted"] is True
    assert d["drifted_sites"] == ["mlp.wi"]
    assert d["max_rel_excess"] == pytest.approx(1.0)
    assert all(e["drifted"] for e in d["entries"])
    assert "mlp.wi" in rep.summary_line()


# ---------------------------------------------------------------------------
# refresh: treedef preservation + monotonicity
# ---------------------------------------------------------------------------


def test_refreshed_preserves_treedef_and_is_monotone():
    obs = _cal(**{"mlp.wi": (2.5, 1.0, 9.0), "brand.new": (7.0, 7.0, 7.0)})
    ref = refreshed_calibration(FROZEN, obs)
    assert ref.site_names() == FROZEN.site_names()  # same pytree treedef
    _, td_frozen = jax.tree_util.tree_flatten(FROZEN)
    _, td_ref = jax.tree_util.tree_flatten(ref)
    assert td_frozen == td_ref
    for name, st in FROZEN.sites:
        for f in ("x_max", "w_max", "sigma_yo"):
            assert getattr(ref.get(name), f) >= getattr(st, f)
    # the drifted site took the observed max; the unknown site folded into *
    assert ref.get("mlp.wi").x_max == 2.5
    assert ref.get("*").x_max == 7.0


# ---------------------------------------------------------------------------
# analytic SNR_T proxy: degradation and recovery
# ---------------------------------------------------------------------------


def test_effective_snr_degrades_and_recovers():
    bx = 7
    fresh = effective_snr_t_db(1.0, 1.0, bx)
    stale = effective_snr_t_db(1.0, 2.0, bx)  # traffic 2x past the range
    assert stale < fresh - 3.0  # clipping costs real dB
    over = effective_snr_t_db(4.0, 1.0, bx)  # 4x over-provisioned range
    assert over == pytest.approx(fresh - 20 * np.log10(4.0), abs=0.2)
    # refresh to the observed max == the fresh-frozen reference exactly
    assert effective_snr_t_db(2.0, 2.0, bx) == pytest.approx(fresh)


def test_site_snr_table_recovery_gap():
    obs = _cal(**{"mlp.wi": (2.0, 2.0, 3.0)})
    ref = refreshed_calibration(FROZEN, obs)
    (row,) = [r for r in site_snr_table(FROZEN, ref, obs, bx=7)
              if r["site"] == "mlp.wi"]
    assert row["degradation_db"] > 3.0  # the stale range was clipping
    # drifted site: refreshed x_max == observed x_max -> exact recovery
    assert row["recovery_gap_db"] == pytest.approx(0.0, abs=1e-9)


# ---------------------------------------------------------------------------
# monitor cadence
# ---------------------------------------------------------------------------


def test_monitor_cadence():
    mon = DriftMonitor(DriftConfig(sample_every=2, check_every=3))
    pattern = [mon.take_sample() for _ in range(6)]
    assert pattern == [True, False, True, False, True, False]
    # checks fire every 3rd SAMPLE; with no observations they return None
    assert mon.check(FROZEN) is None and mon.check(FROZEN) is None
    assert mon.checks == 0
    mon.recorder.note("mlp.wi", SiteStats(9.0, 9.0, 9.0))
    assert mon.check(FROZEN) is not None  # third sample -> a check ran
    assert mon.checks == 1 and mon.drift_events == 1


def test_monitor_rejects_bad_cadence():
    with pytest.raises(ValueError):
        DriftConfig(sample_every=0)


# ---------------------------------------------------------------------------
# shared retry idiom (runtime.fault)
# ---------------------------------------------------------------------------


def _transient(msg="injected"):
    return fault_lib.TRANSIENT_ERROR_TYPES[0](msg)


def test_call_with_retries_transient_then_success():
    calls = []

    def fn():
        calls.append(1)
        if len(calls) == 1:
            raise _transient()
        return "ok"

    assert fault_lib.call_with_retries(
        fn, 1, retryable=fault_lib.is_transient_device_error) == "ok"
    assert len(calls) == 2


def test_call_with_retries_non_retryable_propagates():
    def fn():
        raise ValueError("bug")

    with pytest.raises(ValueError):
        fault_lib.call_with_retries(
            fn, 5, retryable=fault_lib.is_transient_device_error)


def test_call_with_retries_exhaustion():
    calls = []

    def fn():
        calls.append(1)
        raise _transient()

    with pytest.raises(fault_lib.TRANSIENT_ERROR_TYPES[0]):
        fault_lib.call_with_retries(
            fn, 2, retryable=fault_lib.is_transient_device_error)
    assert len(calls) == 3


def test_is_transient_device_error():
    assert fault_lib.is_transient_device_error(_transient())
    assert not fault_lib.is_transient_device_error(ValueError("x"))


# ---------------------------------------------------------------------------
# engine: atomic hot-swap, shadow passivity, drift injection, failure paths
# ---------------------------------------------------------------------------

TINY = dict(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
    max_seq=128, flash_q_block=16, flash_kv_block=16, dtype="float32",
)
DENSE = ArchConfig(name="t", family="dense", **TINY)

_PARAMS = {}


def jax_params(cfg):
    key = id(cfg)
    if key not in _PARAMS:
        _PARAMS[key] = init_params(jax.random.PRNGKey(0), cfg)
    return _PARAMS[key]


def _frozen_cfg(mode, seed=1):
    cfg_dyn = DENSE.replace(imc=IMCConfig(mode=mode, bx=7, bw=7, v_wl=0.7))
    params = jax_params(DENSE)
    ref = np.random.default_rng(seed).integers(
        0, DENSE.vocab_size, (4, 24))
    cfg = calibrate_model(cfg_dyn, params, [ref])
    _PARAMS[id(cfg)] = params
    return cfg, params


def _requests(cfg, lens, max_new, seed=3):
    rnp = np.random.default_rng(seed)
    return [Request(rid=i, prompt=rnp.integers(0, cfg.vocab_size, l),
                    max_new=max_new)
            for i, l in enumerate(lens)]


def _drive_chunks(engine, reqs, n_steps=2, swap_at=None, new_cal=None):
    """Admit everything, then decode in fixed-size chunks, optionally hot-
    swapping ``new_cal`` at the ``swap_at``-th chunk boundary.  Returns the
    list of (slots, n_steps) token blocks."""
    pending = [Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new)
               for r in reqs]
    engine.admit_pending(pending)
    assert not pending
    chunks = []
    while engine.active:
        if swap_at is not None and len(chunks) == swap_at:
            engine.swap_calibration(new_cal)
        chunks.append(engine.decode_chunk(n_steps).copy())
    return chunks


SWAP_MODES = ["fakequant", "imc_analytic", "imc_bitserial"]


@pytest.mark.parametrize("mode", SWAP_MODES)
def test_atomic_swap_bit_exact_no_recompile(mode):
    """The hot-swap contract on every quantized substrate: (a) a value-
    identical swap (rebuilt Calibration object, same stats) leaves every
    chunk bit-identical to the no-swap run; (b) a genuinely refreshed swap
    leaves all pre-swap chunks bit-identical; (c) neither swap triggers a
    recompile of the fused decode scan (the calibration is a traced
    argument, the jit cache is keyed on its treedef)."""
    cfg, params = _frozen_cfg(mode)
    lens, max_new = [5, 9], 7  # 1 prefill token + 3 decode chunks of 2
    reqs = _requests(cfg, lens, max_new)
    sub = as_substrate(cfg.imc)

    eng0 = Engine(cfg, params, batch_slots=2, cache_len=32, max_chunk=4)
    base = _drive_chunks(eng0, reqs)

    # (a) value-identical swap: a DIFFERENT Calibration object, same stats
    same_cal = Calibration.from_dict(sub.calibration.to_dict())
    assert same_cal is not sub.calibration
    eng1 = Engine(cfg, params, batch_slots=2, cache_len=32, max_chunk=4)
    swapped = _drive_chunks(eng1, reqs, swap_at=1, new_cal=same_cal)
    assert len(base) == len(swapped) and len(base) == 3
    for b, s in zip(base, swapped):
        np.testing.assert_array_equal(b, s)
    assert eng1.swap_count == 1

    # (b) + (c): a real refresh (one site's ranges grown 1.5x) - pre-swap
    # chunks identical, and the same compiled executable serves both
    grown = refreshed_calibration(
        sub.calibration,
        Calibration((("mlp.wi", SiteStats(
            1.5 * sub.calibration.get("mlp.wi").x_max,
            1.5 * sub.calibration.get("mlp.wi").w_max,
            1.5 * sub.calibration.get("mlp.wi").sigma_yo)),)))
    eng2 = Engine(cfg, params, batch_slots=2, cache_len=32, max_chunk=4)
    moved = _drive_chunks(eng2, reqs, swap_at=2, new_cal=grown)
    for b, s in zip(base[:2], moved[:2]):
        np.testing.assert_array_equal(b, s)
    fn = eng2._decode_fns[(2, False, eng2.substrate.trace_key)]
    if hasattr(fn, "_cache_size"):
        assert fn._cache_size() == 1  # swap never re-traced the scan


def test_swap_guards():
    """Swap requires a frozen substrate and a treedef-preserving refresh."""
    cfg, params = _frozen_cfg("imc_analytic")
    eng = Engine(cfg, params, batch_slots=2, cache_len=32, max_chunk=4)
    cal = as_substrate(cfg.imc).calibration
    smaller = Calibration(tuple(cal.sites[:-1]))
    with pytest.raises(ValueError, match="site-name"):
        eng.swap_calibration(smaller)
    dyn = Engine(DENSE, jax_params(DENSE), batch_slots=2, cache_len=32)
    with pytest.raises(ValueError, match="frozen"):
        dyn.swap_calibration(cal)
    with pytest.raises(ValueError, match="frozen"):
        Engine(DENSE, jax_params(DENSE), batch_slots=2, cache_len=32,
               drift_monitor=DriftMonitor())


def test_shadow_recording_is_passive():
    """Shadow-sampled chunks produce bit-identical outputs to unsampled ones
    (observation taps stats, never the execution path) and still deliver
    exactly one (slots, T) transfer per chunk."""
    cfg, params = _frozen_cfg("imc_analytic")
    reqs = _requests(cfg, [5, 9], 7)

    plain = Engine(cfg, params, batch_slots=2, cache_len=32, max_chunk=4)
    base = _drive_chunks(plain, reqs)

    mon = DriftMonitor(DriftConfig(sample_every=1, check_every=1,
                                   auto_swap=False))
    shadowed = Engine(cfg, params, batch_slots=2, cache_len=32, max_chunk=4,
                      drift_monitor=mon)
    got = _drive_chunks(shadowed, reqs)
    for b, s in zip(base, got):
        np.testing.assert_array_equal(b, s)
    assert mon.samples == len(got)
    jax.effects_barrier()
    observed = mon.recorder.finalize()
    assert observed.sites  # the shadow taps really ran
    assert shadowed.host_transfer_bytes == plain.host_transfer_bytes


def test_drift_injection_detected_and_recovered():
    """THE acceptance scenario: an activation-scale shift injected mid-serve
    is detected within a bounded number of chunks, hot-swapped without a
    recompile, and per-site SNR_T recovers to within 1 dB of a fresh-frozen
    reference; every request completes without error."""
    cfg, params = _frozen_cfg("imc_analytic")
    frozen0 = as_substrate(cfg.imc).calibration
    thr = DriftThresholds(rel_excess=0.5, clip_rate=0.05)
    mon = DriftMonitor(DriftConfig(sample_every=1, check_every=1,
                                   thresholds=thr))
    eng = Engine(cfg, params, batch_slots=2, cache_len=32, max_chunk=4,
                 drift_monitor=mon)

    serve(eng, _requests(cfg, [5, 9], 6, seed=3))
    assert mon.drift_events == 0  # calibrated traffic: no false positive
    chunks_before = mon.chunks_seen

    # inject a scale shift that SURVIVES pre-norm: growing every mlp.wi
    # weight 2.5x drifts w_max at mlp.wi and the activation range feeding
    # mlp.wo (an embed-scale shift would be normalized away)
    def _scale_wi(p, s):
        if isinstance(p, dict):
            return {k: (v * s if k == "wi" else _scale_wi(v, s))
                    for k, v in p.items()}
        return p

    eng.params = _scale_wi(eng.params, 2.5)
    n_decode_fns = len(eng._decode_fns)
    serve(eng, _requests(cfg, [5, 9], 6, seed=4))

    assert mon.drift_events >= 1 and eng.swap_count >= 1
    bound = mon.cfg.sample_every * mon.cfg.check_every + 1
    assert mon.first_drift_chunk - chunks_before <= bound
    assert len(eng._decode_fns) == n_decode_fns  # no new decode jits
    assert all(r.error is None for r in eng.finished)

    rows = site_snr_table(frozen0, eng._calib, mon.last_observed,
                          bx=as_substrate(cfg.imc).imc.bx)
    # drifted = observed EXCEEDED frozen (the one-sided direction); sites
    # whose frozen range merely over-provisions traffic carry a static
    # q-noise gap that is calibration conservatism, not drift
    drifted = [r for r in rows if r["x_max_observed"] > r["x_max_frozen"]]
    assert any(r["degradation_db"] > 1.0 for r in drifted)
    for r in drifted:
        assert abs(r["recovery_gap_db"]) <= 1.0, r


def test_poison_prefill_isolated():
    """A poison request in a batched prefill group errors out ALONE: the
    batch retries solo, the poison row retires with an error status, and
    its group-mates are served (failure isolation, never engine death)."""
    cfg = DENSE
    poison_rid = 1

    def injector(phase, info):
        if phase == "prefill" and poison_rid in info:
            raise _transient(f"poisoned rid {poison_rid}")

    eng = Engine(cfg, jax_params(cfg), batch_slots=4, cache_len=32,
                 max_chunk=4, failure_injector=injector)
    reqs = _requests(cfg, [5, 6, 7], 4)  # one bucket: one batched group
    out = {r.rid: r for r in serve(eng, reqs)}
    assert out[poison_rid].error is not None
    for rid in (0, 2):
        assert out[rid].error is None and len(out[rid].out) == 4
    assert eng.alloc.used_count == 0  # nothing leaked
    assert eng.failed_requests == 1


def test_transient_decode_error_retried_exactly():
    """A single transient decode fault is retried via the shared fault
    idiom; the chunk function is pure, so the re-run is exact and the
    served tokens are bit-identical to a fault-free run."""
    cfg = DENSE
    reqs = _requests(cfg, [5, 9], 6)
    clean = Engine(cfg, jax_params(cfg), batch_slots=2, cache_len=32,
                   max_chunk=4)
    want = {r.rid: r.out for r in serve(
        clean, [Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new)
                for r in reqs])}

    hits = []

    def injector(phase, info):
        if phase == "decode" and info == 0 and not hits:
            hits.append(1)
            raise _transient("blip")

    eng = Engine(cfg, jax_params(cfg), batch_slots=2, cache_len=32,
                 max_chunk=4, failure_injector=injector)
    got = {r.rid: r.out for r in serve(
        eng, [Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new)
              for r in reqs])}
    assert hits  # the fault really fired
    assert got == want
    assert eng.decode_failures == 0
    assert all(r.error is None for r in eng.finished)


def test_persistent_decode_error_fails_only_inflight():
    """A decode fault that survives the retry fails exactly the in-flight
    requests; the engine itself survives and serves new traffic."""
    cfg = DENSE
    boom = {"on": True}

    def injector(phase, info):
        if phase == "decode" and boom["on"]:
            raise _transient("dead lane")

    eng = Engine(cfg, jax_params(cfg), batch_slots=2, cache_len=32,
                 max_chunk=4, failure_injector=injector)
    out = serve(eng, _requests(cfg, [5, 9], 6))
    assert len(out) == 2
    assert all(r.done and r.error is not None for r in out)
    assert eng.decode_failures >= 1
    assert eng.alloc.used_count == 0

    boom["on"] = False  # the fault clears: same engine keeps serving
    fresh = _requests(cfg, [7], 4, seed=9)
    out2 = serve(eng, fresh)
    assert out2[-1].error is None and len(out2[-1].out) == 4
