"""Analytic (E) vs sample-accurate Monte Carlo (S) validation - the paper's
Fig. 8 methodology, Figs. 9-11 'E/S' overlays."""
import jax
import pytest

from repro.core import mc
from repro.core.archs import CMArch, QRArch, QSArch

KEY = jax.random.PRNGKey(0)


@pytest.mark.slow
@pytest.mark.parametrize(
    "v_wl,n", [(0.8, 64), (0.8, 125), (0.7, 128), (0.7, 256), (0.6, 256)]
)
def test_qs_arch_e_vs_s(v_wl, n):
    a = QSArch(n=n, bx=6, bw=6, v_wl=v_wl)
    r = mc.empirical_snrs(KEY, a, mc.mc_qs_arch, ens=600)
    assert abs(r["snr_A_db"] - a.snr_A_db()) < 1.0, (r, a.snr_A_db())
    # SNR_T with the Table III B_ADC stays within ~1 dB of SNR_A (MPC claim)
    assert r["snr_T_db"] > r["snr_A_db"] - 1.2


@pytest.mark.slow
def test_qs_arch_clipping_onset_matches():
    """At the clipping onset the analytic and MC curves collapse together."""
    a = QSArch(n=200, bx=6, bw=6, v_wl=0.8)
    r = mc.empirical_snrs(KEY, a, mc.mc_qs_arch, ens=600)
    assert r["snr_A_db"] < 8.0 and a.snr_A_db() < 8.0


@pytest.mark.slow
@pytest.mark.parametrize("c_o", [1e-15, 3e-15, 9e-15])
def test_qr_arch_e_vs_s(c_o):
    a = QRArch(n=128, bx=6, bw=7, c_o=c_o)
    # ens=600 carries ~1 dB of finite-ensemble bias (observed +3.6 dB gap
    # shrinking to +2.7 dB at ens=2400); run the larger ensemble so the
    # Table III bound below stays tight
    r = mc.empirical_snrs(KEY, a, mc.mc_qr_arch, ens=2400)
    # Table III is conservative for QR (ignores mean-subtraction in the
    # redistribution; DESIGN.md SS7): expect S within [E - 1, E + 3.5] dB
    assert -1.0 < r["snr_A_db"] - a.snr_A_db() < 3.5, (r, a.snr_A_db())


@pytest.mark.slow
@pytest.mark.parametrize("v_wl,bw", [(0.8, 5), (0.8, 6), (0.7, 7)])
def test_cm_e_vs_s(v_wl, bw):
    a = CMArch(n=64, bx=6, bw=bw, v_wl=v_wl)
    # ens=600 gives a -2.8 dB finite-ensemble gap that shrinks to -2.0 dB
    # at ens=2400; use the larger ensemble with a 2.5 dB bound
    r = mc.empirical_snrs(KEY, a, mc.mc_cm, ens=2400)
    assert abs(r["snr_A_db"] - a.snr_A_db()) < 2.5, (r, a.snr_A_db())


@pytest.mark.slow
def test_mpc_adc_close_to_pre_adc_snr():
    """SNR_T(B_ADC from MPC) within ~1 dB of SNR_A on the full MC chain."""
    a = QRArch(n=128, bx=6, bw=7, c_o=3e-15)
    r = mc.empirical_snrs(KEY, a, mc.mc_qr_arch, ens=600)
    assert r["snr_T_db"] > r["snr_A_db"] - 1.0


# ---------------------------------------------------------------------------
# 512-row regression pins: kernel/serve refactors must not drift the paper
# validation.  Fixed seed + fixed ensemble makes the MC output a deterministic
# function of the simulator code, so each empirical SNR is pinned BOTH to the
# Table III closed form (within its architecture's documented E/S band) and
# to a recorded reference value (tight drift window).  Deliberately NOT
# marked slow: the slow CI job is non-blocking, and these pins exist to GATE
# refactors (~1 min each).  Covers QS, QR and CM - the three architectures
# `core/archs.py` implements from the paper.
# ---------------------------------------------------------------------------

PIN_KEY = jax.random.PRNGKey(42)


def test_qs_512row_pinned_to_closed_form():
    """QS at the 512-row design point (V_WL chosen below the clipping onset):
    empirical SNR_A within 1 dB of the closed-form snr_A_db."""
    a = QSArch(n=512, bx=6, bw=6, v_wl=0.6)
    r = mc.empirical_snrs(PIN_KEY, a, mc.mc_qs_arch, ens=600)
    assert abs(r["snr_A_db"] - a.snr_A_db()) < 1.0, (r, a.snr_A_db())
    # drift pin (recorded at this seed/ensemble): E=13.36, S_A=12.89
    assert abs(r["snr_A_db"] - 12.89) < 0.5, r


def test_qr_512row_pinned():
    """QR at 512 rows: Table III is conservative (ignores mean-subtraction in
    the redistribution; DESIGN.md SS7), so S sits ABOVE E by a stable ~2.3 dB
    - pin the offset band and the absolute value."""
    a = QRArch(n=512, bx=6, bw=7, c_o=3e-15)
    r = mc.empirical_snrs(PIN_KEY, a, mc.mc_qr_arch, ens=600)
    assert 1.0 < r["snr_A_db"] - a.snr_A_db() < 3.5, (r, a.snr_A_db())
    # drift pin (recorded): E=22.41, S_A=24.73
    assert abs(r["snr_A_db"] - 24.73) < 0.5, r


def test_cm_512row_pinned():
    """CM at 512 rows: finite-ensemble bias puts S BELOW E by a stable
    ~2.4 dB at ens=600 - pin the band and the absolute value."""
    a = CMArch(n=512, bx=6, bw=6, v_wl=0.8)
    r = mc.empirical_snrs(PIN_KEY, a, mc.mc_cm, ens=600)
    assert -3.5 < r["snr_A_db"] - a.snr_A_db() < -1.0, (r, a.snr_A_db())
    # drift pin (recorded): E=22.19, S_A=19.81
    assert abs(r["snr_A_db"] - 19.81) < 0.5, r


@pytest.mark.slow
def test_coarser_adc_degrades():
    a = QRArch(n=128, bx=6, bw=7, c_o=3e-15)
    good = mc.empirical_snrs(KEY, a, mc.mc_qr_arch, ens=400, b_adc=a.b_adc_min())
    bad = mc.empirical_snrs(KEY, a, mc.mc_qr_arch, ens=400, b_adc=3)
    assert bad["snr_T_db"] < good["snr_T_db"] - 3.0
