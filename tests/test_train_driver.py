"""End-to-end driver tests: launch.train on a smoke config (CPU), with
checkpoint-resume, and the serve driver."""
import os

import numpy as np
import pytest

from repro.launch import train as train_mod


@pytest.mark.slow
def test_train_driver_end_to_end(tmp_path):
    state, hist = train_mod.main([
        "--arch", "granite-moe-1b-a400m", "--smoke",
        "--steps", "12", "--batch", "4", "--seq", "64",
        "--lr", "5e-3",
        "--ckpt-dir", str(tmp_path / "ckpt"),
        "--save-every", "6",
    ])
    losses = hist["loss"]
    assert len(losses) == 12
    assert all(np.isfinite(losses))
    # synthetic data has structure; a dozen steps should already help
    assert np.mean(losses[-3:]) < np.mean(losses[:3])
    assert os.path.isdir(str(tmp_path / "ckpt" / "step_00000012"))


@pytest.mark.slow
def test_train_driver_resume(tmp_path):
    ckpt_dir = str(tmp_path / "ckpt")
    train_mod.main([
        "--arch", "mamba2-2.7b", "--smoke", "--steps", "6", "--batch", "2",
        "--seq", "32", "--ckpt-dir", ckpt_dir, "--save-every", "3",
    ])
    # resume to 9 steps: runner restores from step 6 and runs 3 more
    state, hist = train_mod.main([
        "--arch", "mamba2-2.7b", "--smoke", "--steps", "9", "--batch", "2",
        "--seq", "32", "--ckpt-dir", ckpt_dir, "--save-every", "3",
    ])
    assert len(hist["loss"]) == 3  # only the new steps
    assert int(state["step"]) == 9
