"""HLO analyzer validation: the roofline's FLOP/collective accounting must be
exact on hand-countable modules (incl. the scan trip-count correction that
XLA's own cost_analysis lacks)."""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
import sys
sys.path.insert(0, "src")
from repro.launch.hlo_analysis import analyze

M = N = K = 1024
exp = 2 * M * N * K

def g(a, b):
    def body(c, bi):
        return jnp.tanh(c @ bi), None
    y, _ = jax.lax.scan(body, a, b)
    return y

c = jax.jit(g).lower(
    jax.ShapeDtypeStruct((M, K), jnp.float32),
    jax.ShapeDtypeStruct((8, K, N), jnp.float32),
).compile()
a = analyze(c.as_text())
assert abs(a["flops"] / (exp * 8) - 1.0) < 0.02, a["flops"] / (exp * 8)

# sharded matmul: per-device flops 1/16, plus an all-reduce
mesh = jax.make_mesh((4, 4), ("data", "model"))
sa = NamedSharding(mesh, P("data", "model"))
sb = NamedSharding(mesh, P("model", None))
f = jax.jit(lambda a, b: a @ b, in_shardings=(sa, sb),
            out_shardings=NamedSharding(mesh, P("data", None)))
c2 = f.lower(jax.ShapeDtypeStruct((M, K), jnp.float32),
             jax.ShapeDtypeStruct((K, N), jnp.float32)).compile()
a2 = analyze(c2.as_text())
assert abs(a2["flops"] / (exp / 16) - 1.0) < 0.02
assert "all-reduce" in a2["collective_bytes"]
assert a2["collective_bytes"]["all-reduce"] == M * N * 4 / 4  # per-dev shard

# nested scan 8 x 4
def h(a, b):
    def outer(c, bi):
        def inner(ci, _):
            return jnp.tanh(ci @ bi), None
        y, _ = jax.lax.scan(inner, c, None, length=4)
        return y, None
    y, _ = jax.lax.scan(outer, a, b)
    return y

c3 = jax.jit(h).lower(jax.ShapeDtypeStruct((M, K), jnp.float32),
                      jax.ShapeDtypeStruct((8, K, K), jnp.float32)).compile()
a3 = analyze(c3.as_text())
assert abs(a3["flops"] / (2 * M * K * K * 32) - 1.0) < 0.02

# grad through scan: fwd (8) + bwd (2 per step) = 3x
c4 = jax.jit(jax.grad(lambda a, b: jnp.sum(g(a, b) ** 2), argnums=1)).lower(
    jax.ShapeDtypeStruct((M, K), jnp.float32),
    jax.ShapeDtypeStruct((8, K, N), jnp.float32),
).compile()
a4 = analyze(c4.as_text())
assert abs(a4["flops"] / (exp * 8) - 3.0) < 0.1

# XLA's own cost_analysis undercounts the scan (documents the why)
cost = c.cost_analysis()
cost = cost[0] if isinstance(cost, (list, tuple)) else cost
assert cost.get("flops", 0.0) < exp * 2  # counts body once, not x8
print("HLO_ANALYSIS_OK")
"""


@pytest.mark.slow
def test_hlo_analyzer_exact(tmp_path):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, cwd=os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))),
        env=env, timeout=900,
    )
    assert "HLO_ANALYSIS_OK" in r.stdout, r.stdout + r.stderr[-2000:]
