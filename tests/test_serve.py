"""Serving driver tests + vocab padding semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.launch import serve as serve_mod
from repro.models import forward, init_params


@pytest.mark.slow
def test_serve_batched_requests():
    out = serve_mod.main([
        "--arch", "musicgen-medium", "--smoke", "--batch", "4",
        "--requests", "8", "--prompt-len", "12", "--gen", "6",
    ])
    assert len(out) == 8
    assert all(len(r.out) == 6 for r in out)


@pytest.mark.slow
def test_serve_imc_mode_changes_tokens():
    """IMC analog noise at a low design point must alter generations."""
    base = serve_mod.main([
        "--arch", "musicgen-medium", "--smoke", "--batch", "2",
        "--requests", "2", "--prompt-len", "12", "--gen", "6",
    ])
    noisy = serve_mod.main([
        "--arch", "musicgen-medium", "--smoke", "--batch", "2",
        "--requests", "2", "--prompt-len", "12", "--gen", "6",
        "--imc-mode", "imc_analytic", "--imc-vwl", "0.55",
    ])
    agree = np.mean([
        np.mean(np.array(a.out) == np.array(b.out))
        for a, b in zip(base, noisy)
    ])
    assert agree < 1.0  # low-SNR analog core perturbs decoding


def test_vocab_padding_masked():
    """Padded vocab rows must never win argmax and never receive probability."""
    cfg = configs.get_smoke("internvl2-2b")  # vocab 512 -> padded 512 (even)
    cfg = cfg.replace(vocab_size=500)  # force padding to 512
    assert cfg.padded_vocab == 512
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 500)
    pe = jax.random.normal(jax.random.PRNGKey(2), (2, cfg.prefix_len, cfg.d_model))
    logits, _ = forward(params, cfg, toks, pe)
    assert logits.shape[-1] == 512
    assert bool(jnp.all(logits[..., 500:] <= -1e8))
    assert bool(jnp.all(jnp.argmax(logits, -1) < 500))


def test_param_count_excludes_padding():
    cfg = configs.get("internvl2-2b")
    assert cfg.padded_vocab == 92672
    # param_count uses true vocab (MODEL_FLOPS bookkeeping)
    assert cfg.param_count() < 92672 * cfg.d_model * 2 + 10_000_000_000
