"""First-class Substrate API: per-site resolution, calibration policies,
bit-exact dynamic-mode compatibility, batch invariance under frozen
calibration, the deprecation shim, and substrate-billed metering."""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.design import optimize, with_b_adc
from repro.core.imc_linear import IMCConfig, linear
from repro.core.mapping import MatmulShape
from repro.core.substrate import (
    AnalyticIMC,
    BitSerialIMC,
    Calibration,
    CalibrationRecorder,
    DigitalSubstrate,
    SiteStats,
    Substrate,
    as_substrate,
    recording,
    substrate_for_design,
    substrate_from_flag,
)
from repro.launch.metering import (
    DPMeter,
    energy_for_tokens,
    serve_energy_report,
    substrate_energy_for_tokens,
)

K1, K2, K3 = jax.random.split(jax.random.PRNGKey(0), 3)
X = jax.random.normal(K1, (16, 256))
W = jax.random.normal(K2, (256, 64)) / 16


def _calibration(sub, site="mlp.wi"):
    rec = CalibrationRecorder()
    with recording(rec):
        linear(W, X, sub, site=site)
    return rec.finalize()


# ---------------------------------------------------------------------------
# construction, normalization, shim
# ---------------------------------------------------------------------------


def test_as_substrate_maps_modes_to_classes():
    assert isinstance(as_substrate(None), DigitalSubstrate)
    assert isinstance(as_substrate(IMCConfig(mode="digital")), DigitalSubstrate)
    assert isinstance(as_substrate(IMCConfig(mode="imc_analytic")), AnalyticIMC)
    assert isinstance(as_substrate(IMCConfig(mode="imc_bitserial")),
                      BitSerialIMC)
    # exotic modes fall back to the base class, mode preserved
    fq = as_substrate(IMCConfig(mode="fakequant"))
    assert type(fq) is Substrate and fq.name == "fakequant"
    # substrates pass through untouched
    sub = AnalyticIMC(bx=7, bw=7)
    assert as_substrate(sub) is sub


def test_substrate_is_hashable_and_replaceable():
    sub = BitSerialIMC(bx=6, bw=6, v_wl=0.7)
    assert hash(sub) == hash(BitSerialIMC(bx=6, bw=6, v_wl=0.7))
    assert sub == BitSerialIMC(bx=6, bw=6, v_wl=0.7)
    assert sub != BitSerialIMC(bx=7, bw=7, v_wl=0.7)
    froz = sub.frozen(_calibration(sub))
    assert froz.policy == "frozen" and froz.imc == sub.imc
    assert froz.dynamic().policy == "dynamic"
    # dataclasses.replace goes through the subclass constructor
    assert dataclasses.replace(froz, policy="dynamic",
                               calibration=None) == sub


def test_mode_mismatch_rejected():
    with pytest.raises(ValueError):
        AnalyticIMC(imc=IMCConfig(mode="imc_bitserial"))
    with pytest.raises(ValueError):
        Substrate(policy="frozen")  # frozen needs a calibration
    with pytest.raises(ValueError):
        Substrate(policy="sometimes")


def test_deprecation_shim_warns_and_builds():
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        sub = substrate_from_flag("imc_bitserial", bx=5, bw=5)
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    assert isinstance(sub, BitSerialIMC) and sub.imc.bx == 5


def test_tier1_emits_no_deprecation_warnings():
    """The migrated call paths never route through the shim."""
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        linear(W, X, IMCConfig(mode="imc_analytic", bx=7, bw=7), rng=K3)
        linear(W, X, AnalyticIMC(bx=7, bw=7), rng=K3)
    assert not any(issubclass(x.category, DeprecationWarning) for x in w)


# ---------------------------------------------------------------------------
# per-site override resolution
# ---------------------------------------------------------------------------


def test_site_override_matching():
    sub = AnalyticIMC(bx=7, bw=7, b_adc=6).with_overrides({
        "lm_head": {"b_adc": 10},
        "attn": {"b_adc": 8},
        "*": {"bx": 6},
    })
    assert sub.site_config("lm_head").b_adc == 10
    assert sub.site_config("attn.wq").b_adc == 8  # group prefix
    assert sub.site_config("attn.wo").b_adc == 8
    assert sub.site_config("mlp.wi").b_adc == 6  # falls to "*": bx only
    assert sub.site_config("mlp.wi").bx == 6
    assert sub.site_config(None).bx == 6  # unknown site -> "*"
    # base object untouched
    assert AnalyticIMC(bx=7, bw=7, b_adc=6).site_config("lm_head").b_adc == 6


def test_design_for_site_override_wins():
    pt = optimize(n=512, snr_t_target_db=14.0)
    pt_hi = with_b_adc(pt, pt.b_adc + 2)
    sub = substrate_for_design(pt).with_overrides({"lm_head": {"design": pt_hi}})
    assert sub.design_for_site("mlp.wi") == pt
    assert sub.design_for_site("lm_head") == pt_hi


def test_with_b_adc_identity_and_monotone():
    pt = optimize(n=512, snr_t_target_db=14.0)
    assert with_b_adc(pt, pt.b_adc) == pt
    hi = with_b_adc(pt, pt.b_adc + 2)
    assert hi.snr_t_db >= pt.snr_t_db
    assert hi.energy_per_dp > pt.energy_per_dp


# ---------------------------------------------------------------------------
# dynamic policy: bit-exact with the legacy IMCConfig plumbing
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["fakequant", "imc_analytic", "imc_bitserial"])
def test_dynamic_substrate_matches_imcconfig_bit_exact(mode):
    cfg = IMCConfig(mode=mode, bx=7, bw=7)
    y_legacy = np.asarray(linear(W, X, cfg, rng=K3))
    y_sub = np.asarray(linear(W, X, as_substrate(cfg), rng=K3))
    np.testing.assert_array_equal(y_legacy, y_sub)


# ---------------------------------------------------------------------------
# frozen policy: batch-composition invariance at the linear level
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cls", [AnalyticIMC, BitSerialIMC])
def test_frozen_linear_is_batch_invariant(cls):
    sub = cls(bx=7, bw=7)
    frozen = sub.frozen(_calibration(sub))
    y_full = np.asarray(linear(W, X, frozen, site="mlp.wi"))
    y_solo = np.asarray(linear(W, X[3:5], frozen, site="mlp.wi"))
    np.testing.assert_array_equal(y_full[3:5], y_solo)
    # dynamic stats are batch-coupled: the same slice differs (the behaviour
    # frozen calibration exists to remove) - scale X so max|x| moves
    y_dyn_full = np.asarray(linear(W, X.at[0, 0].set(40.0), sub))
    y_dyn_solo = np.asarray(linear(W, X[3:5], sub))
    assert not np.array_equal(y_dyn_full[3:5], y_dyn_solo)


def test_frozen_uses_star_fallback_for_unknown_site():
    sub = AnalyticIMC(bx=7, bw=7)
    frozen = sub.frozen(_calibration(sub, site="mlp.wi"))
    y1 = np.asarray(linear(W, X, frozen, site="never.seen"))
    y2 = np.asarray(linear(W, X, frozen, site="mlp.wi"))
    np.testing.assert_array_equal(y1, y2)  # "*" == the only observed site


def test_frozen_without_fallback_raises():
    cal = Calibration((("mlp.wi", SiteStats(1.0, 1.0, 1.0)),))
    frozen = AnalyticIMC(bx=7, bw=7).frozen(cal)
    with pytest.raises(KeyError):
        frozen.site_stats("never.seen")


def test_calibration_recorder_merges_scanned_layers():
    """Observing one site twice max-merges (the scan-over-layers case)."""
    rec = CalibrationRecorder()
    with recording(rec):
        linear(W, X, AnalyticIMC(bx=7, bw=7), site="mlp.wi")
        linear(W, 3.0 * X, AnalyticIMC(bx=7, bw=7), site="mlp.wi")
    cal = rec.finalize()
    solo = CalibrationRecorder()
    with recording(solo):
        linear(W, 3.0 * X, AnalyticIMC(bx=7, bw=7), site="mlp.wi")
    assert cal.get("mlp.wi") == solo.finalize().get("mlp.wi")


def test_recorder_works_under_jit():
    """The scan-over-layers forward traces even eagerly; the recorder pulls
    stats through jax.debug.callback, so it works under jit too."""
    rec = CalibrationRecorder()
    fn = jax.jit(lambda w, x: linear(w, x, AnalyticIMC(bx=7, bw=7),
                                     site="mlp.wi"))
    with recording(rec):
        fn(W, X).block_until_ready()
        jax.effects_barrier()
    cal = rec.finalize()
    assert cal.get("mlp.wi") is not None
    assert cal.get("mlp.wi").x_max == pytest.approx(
        float(jnp.max(jnp.abs(X))), rel=1e-6)


# ---------------------------------------------------------------------------
# kernels/ops: frozen operands make the public matmul batch-invariant
# ---------------------------------------------------------------------------


def test_imc_matmul_frozen_sigma_batch_invariant():
    from repro.kernels.ops import IMCMatmulConfig, imc_matmul

    cfg = IMCMatmulConfig(mode="imc_analytic", bx=7, bw=7, b_adc=8,
                          snr_a_db=25.0, use_kernel=False)
    kw = dict(x_max=4.0, w_max=float(jnp.max(jnp.abs(W))), sigma_yo=30.0)
    y_full = np.asarray(imc_matmul(X, W, cfg, **kw))
    y_solo = np.asarray(imc_matmul(X[3:5], W, cfg, **kw))
    np.testing.assert_array_equal(y_full[3:5], y_solo)


# ---------------------------------------------------------------------------
# substrate-billed metering
# ---------------------------------------------------------------------------

SITES = [MatmulShape("mlp.wi", 512, 8, 2), MatmulShape("lm_head", 512, 4, 1)]


def test_substrate_rollup_matches_uniform_design_exactly():
    pt = optimize(n=512, snr_t_target_db=14.0)
    sub = substrate_for_design(pt)
    uni = energy_for_tokens(SITES, pt, 10)
    via_sub = substrate_energy_for_tokens(SITES, sub, 10)
    assert via_sub == uni  # bitwise: same additions in the same order


def test_substrate_rollup_prices_per_site_overrides():
    pt = optimize(n=512, snr_t_target_db=14.0)
    pt_hi = with_b_adc(pt, pt.b_adc + 2)
    sub = substrate_for_design(pt).with_overrides({"lm_head": {"design": pt_hi}})
    base = substrate_energy_for_tokens(SITES, substrate_for_design(pt), 1)
    boosted = substrate_energy_for_tokens(SITES, sub, 1)
    # exactly the lm_head site's energy moved
    delta = boosted["energy_per_token_j"] - base["energy_per_token_j"]
    expected = (energy_for_tokens([SITES[1]], pt_hi, 1)["energy_per_token_j"]
                - energy_for_tokens([SITES[1]], pt, 1)["energy_per_token_j"])
    assert delta == pytest.approx(expected, rel=1e-12)
    assert delta > 0


def test_serve_energy_report_from_substrate():
    pt = optimize(n=512, snr_t_target_db=14.0)
    meter = DPMeter(sites=SITES)
    meter.note_prefill(1, 8, true_lens=[5])
    meter.note_decode(1, 5)
    legacy = serve_energy_report(meter, pt, generated_tokens=6, requests=1)
    via_sub = serve_energy_report(meter, substrate=substrate_for_design(pt),
                                  generated_tokens=6, requests=1)
    assert via_sub.prefill_j == legacy.prefill_j
    assert via_sub.decode_j == legacy.decode_j
    assert via_sub.design == pt
    assert via_sub.summary()["substrate"] == substrate_for_design(pt).name
    with pytest.raises(ValueError):
        serve_energy_report(meter)  # neither design nor substrate
    with pytest.raises(ValueError):
        serve_energy_report(meter, pt, substrate=substrate_for_design(pt))
    with pytest.raises(ValueError):
        serve_energy_report(meter, substrate=AnalyticIMC())  # no design


def test_engine_stamps_meter_with_its_substrate():
    from repro import configs
    from repro.launch.serve import Engine
    from repro.models import init_params

    cfg = configs.get_smoke("musicgen-medium")
    sub = AnalyticIMC(bx=7, bw=7)
    cfg = cfg.replace(imc=sub)
    params = init_params(jax.random.PRNGKey(0), cfg)
    meter = DPMeter(cfg)
    engine = Engine(cfg, params, 2, 32, meter=meter)
    assert engine.substrate is sub
    assert meter.substrate is sub


def test_forward_energy_accepts_substrate():
    from repro import configs
    from repro.launch import breakdown

    cfg = configs.get("musicgen-medium")
    pt = optimize(n=512, snr_t_target_db=14.0)
    a = breakdown.forward_energy(cfg, pt, tokens=1)
    b = breakdown.forward_energy(cfg, substrate_for_design(pt), tokens=1)
    assert a == b


# ---------------------------------------------------------------------------
# calibration round trips (non-hypothesis pins; property sweeps live in
# tests/test_properties.py)
# ---------------------------------------------------------------------------


def test_calibration_json_roundtrip_lossless(tmp_path):
    sub = AnalyticIMC(bx=7, bw=7)
    cal = _calibration(sub)
    path = str(tmp_path / "cal.json")
    cal.save(path)
    assert Calibration.load(path) == cal


def test_calibration_pytree_roundtrip_lossless():
    cal = Calibration((("a.b", SiteStats(1.25, 2.5, 0.1)),
                       ("*", SiteStats(3.0, 4.0, 5.0))))
    leaves, treedef = jax.tree_util.tree_flatten(cal)
    assert jax.tree_util.tree_unflatten(treedef, leaves) == cal
    # tree_map traverses into the stats (Calibration is a real pytree)
    doubled = jax.tree_util.tree_map(lambda v: v * 2, cal)
    assert doubled.get("a.b").x_max == 2.5
