"""Paged-KV serve engine correctness.

Equivalence suite: the paged engine (block-pool KV + batched bucketed
prefill) must reproduce, token for token,
  (a) per-request sequential decode (exact-length prefill, one token/step),
  (b) the FROZEN PR-2 contiguous-cache engine,
across the digital / imc_analytic / imc_bitserial substrates (rng=None: the
IMC paths run their real quantized kernels, noiseless, so greedy tokens are
bit-determined), including unequal prompt lengths, requests spanning many KV
blocks, and sliding-window ring wrap.

Plus bucketed-prefill edge cases (bucket-boundary prompt, length-1 prompt,
multi-bucket admission in one tick) and paged-allocator behaviour under a
tight physical pool.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.serve_bench import ContiguousEngine, drive_engine
from repro import configs
from repro.configs.base import ArchConfig
from repro.core.imc_linear import IMCConfig
from repro.core.substrate import as_substrate, calibrate_model
from repro.launch.serve import BlockAllocator, Engine, Request, serve
from repro.models import decode_step, init_params, prefill

TINY = dict(
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
    max_seq=128, flash_q_block=16, flash_kv_block=16, dtype="float32",
)

DENSE = ArchConfig(name="t", family="dense", **TINY)
WINDOWED = ArchConfig(
    name="t", family="dense", **TINY, pattern=("local", "attn"), window=16,
    attn_softcap=50.0, final_softcap=30.0, post_norm=True, emb_scale=True,
)

SUBSTRATES = ["digital", "imc_analytic", "imc_bitserial"]


def _with_substrate(cfg, substrate):
    if substrate == "digital":
        return cfg
    return cfg.replace(imc=IMCConfig(mode=substrate, bx=7, bw=7, v_wl=0.7))


_PARAMS = {}


def jax_params(cfg):
    key = id(cfg)
    if key not in _PARAMS:
        _PARAMS[key] = init_params(jax.random.PRNGKey(0), cfg)
    return _PARAMS[key]


def _greedy_sequential(cfg, prompt: np.ndarray, max_new: int):
    """Reference: one request alone, exact-length prefill + per-token decode."""
    cache_len = len(prompt) + max_new + 8
    logits, cache = prefill(jax_params(cfg), cfg, jnp.asarray(prompt)[None, :],
                            cache_len=cache_len)
    out = [int(jnp.argmax(logits[0, -1]))]
    while len(out) < max_new:
        tok = jnp.asarray([out[-1]], jnp.int32)
        logits, cache = decode_step(jax_params(cfg), cfg, tok, cache)
        out.append(int(jnp.argmax(logits[0, 0])))
    return out


def _requests(cfg, lens, max_new, seed=3):
    rnp = np.random.default_rng(seed)
    return [Request(rid=i, prompt=rnp.integers(0, cfg.vocab_size, l),
                    max_new=max_new)
            for i, l in enumerate(lens)]


# ---------------------------------------------------------------------------
# equivalence: paged == frozen contiguous == sequential, three substrates
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("substrate", SUBSTRATES)
def test_paged_matches_contiguous(substrate):
    """Unequal prompts admitted into one batch: the paged engine and the
    frozen PR-2 contiguous engine must emit bit-identical greedy tokens on
    every substrate (rng=None: the IMC quantized kernels are deterministic).
    Prompt lengths fall in distinct buckets so both engines issue identical
    prefill computations - the IMC modes derive quantizer ranges from batch
    statistics, so an (R, bucket) batched prefill is numerically a DIFFERENT
    analog mapping than R solo prefills (batched-prefill equivalence is
    pinned in digital, where quantization is absent and rows are exact).

    In digital the outputs must also equal solo sequential decode."""
    base = configs.get_smoke("musicgen-medium")
    cfg = _with_substrate(base, substrate)
    # bitserial routes every matmul through the bit-serial planes: keep small
    lens = [5, 9, 17] if substrate != "imc_bitserial" else [5, 9]
    max_new = 5 if substrate != "imc_bitserial" else 4
    cache_len = 32 + max_new + 8  # multiple of the 8-token block
    reqs = _requests(cfg, lens, max_new)

    paged = Engine(cfg, jax_params(cfg), batch_slots=4, cache_len=cache_len,
                   max_chunk=4)
    paged_out = {r.rid: r.out for r in serve(
        paged, [Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new)
                for r in reqs])}

    cont = ContiguousEngine(cfg, jax_params(cfg), 4, cache_len, max_chunk=4)
    cont_out = {r.rid: r.out for r in drive_engine(
        cont, [Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new)
               for r in reqs])}

    for r in reqs:
        assert paged_out[r.rid] == cont_out[r.rid], (
            substrate, r.rid, paged_out[r.rid], cont_out[r.rid])
        if substrate == "digital":
            ref = _greedy_sequential(cfg, r.prompt, r.max_new)
            assert paged_out[r.rid] == ref, (r.rid, paged_out[r.rid], ref)


@pytest.mark.parametrize("substrate", SUBSTRATES)
def test_solo_paged_matches_sequential(substrate):
    """A single-slot engine with a bucket-boundary prompt runs exactly the
    reference computation (no pad positions, no batch-stat coupling): the
    paged gather/scatter layout itself must be invisible to the IMC kernels
    - greedy tokens equal solo sequential decode on every substrate."""
    base = configs.get_smoke("musicgen-medium")
    cfg = _with_substrate(base, substrate)
    max_new = 4
    reqs = _requests(cfg, [8], max_new, seed=9)  # len 8 == MIN_BUCKET
    engine = Engine(cfg, jax_params(cfg), batch_slots=1, cache_len=16,
                    max_chunk=4)
    out = serve(engine, [Request(rid=0, prompt=reqs[0].prompt,
                                 max_new=max_new)])
    ref = _greedy_sequential(cfg, reqs[0].prompt, max_new)
    assert out[0].out == ref, (substrate, out[0].out, ref)


@pytest.mark.parametrize("substrate", ["imc_analytic", "imc_bitserial"])
def test_frozen_calibration_engine_matches_sequential(substrate):
    """THE case PR 3 had to skip: with a FROZEN-calibration substrate the
    IMC quantizer ranges are compile-time constants, so the batched paged
    engine (multi-row admission, bucket padding, fused decode over mixed
    slots) is bit-identical to solo sequential execution in the IMC
    substrates too - batched-engine==sequential now holds on all three."""
    base = configs.get_smoke("musicgen-medium")
    cfg_dyn = _with_substrate(base, substrate)
    params = jax_params(cfg_dyn)
    ref_batch = np.random.default_rng(1).integers(0, base.vocab_size, (2, 24))
    cfg = calibrate_model(cfg_dyn, params, [ref_batch])
    _PARAMS[id(cfg)] = params  # identical weights for engine + reference
    assert as_substrate(cfg.imc).policy == "frozen"
    lens = [5, 9, 17] if substrate != "imc_bitserial" else [5, 9]
    max_new = 5 if substrate != "imc_bitserial" else 4
    reqs = _requests(cfg, lens, max_new)
    engine = Engine(cfg, params, batch_slots=4, cache_len=32 + max_new + 8,
                    max_chunk=4)
    out = {r.rid: r.out for r in serve(
        engine, [Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new)
                 for r in reqs])}
    for r in reqs:
        ref = _greedy_sequential(cfg, r.prompt, r.max_new)
        assert out[r.rid] == ref, (substrate, r.rid, out[r.rid], ref)


@pytest.mark.parametrize("substrate", ["imc_analytic", "imc_bitserial"])
def test_dynamic_substrate_reproduces_legacy_engine(substrate):
    """Regression pin: a dynamic-policy Substrate object reproduces today's
    batch-coupled IMCConfig outputs bit-exactly through the whole engine
    (same ops, same per-batch quantizer statistics)."""
    base = configs.get_smoke("musicgen-medium")
    cfg_legacy = _with_substrate(base, substrate)
    cfg_sub = base.replace(imc=as_substrate(cfg_legacy.imc))
    params = jax_params(cfg_legacy)
    _PARAMS[id(cfg_sub)] = params
    lens = [5, 9] if substrate != "imc_bitserial" else [5]
    max_new = 4
    outs = []
    for cfg in (cfg_legacy, cfg_sub):
        reqs = _requests(cfg, lens, max_new)
        engine = Engine(cfg, params, batch_slots=2, cache_len=32, max_chunk=4)
        outs.append({r.rid: r.out for r in serve(engine, reqs)})
    assert outs[0] == outs[1], (substrate, outs)


def test_request_spanning_many_blocks():
    """A prompt + generation crossing several KV block boundaries (block=4:
    prompt alone spans 6 blocks, decode writes walk through 3 more)."""
    cfg = DENSE
    lens = [21, 3, 11]
    max_new = 10
    reqs = _requests(cfg, lens, max_new, seed=7)
    engine = Engine(cfg, jax_params(cfg), batch_slots=3, cache_len=40,
                    max_chunk=4, block_size=4)
    out = serve(engine, [Request(rid=r.rid, prompt=r.prompt, max_new=max_new)
                         for r in reqs])
    assert engine.alloc.used_count == 0  # all blocks returned on retire
    for r in out:
        ref = _greedy_sequential(cfg, next(q.prompt for q in reqs
                                           if q.rid == r.rid), max_new)
        assert r.out == ref, (r.rid, r.out, ref)


def test_sliding_window_wrap():
    """Windowed pattern: the local layers keep per-slot rings (wrap at their
    own phase) while the global layers run paged; generate far past the
    window from bucket-padded prefills of different true lengths."""
    cfg = WINDOWED  # window 16
    lens = [6, 13, 20, 27]
    max_new = 24  # every slot wraps the ring at its own phase
    reqs = _requests(cfg, lens, max_new, seed=4)
    engine = Engine(cfg, jax_params(cfg), batch_slots=4,
                    cache_len=32 + max_new + 8, max_chunk=8)
    out = serve(engine, [Request(rid=r.rid, prompt=r.prompt, max_new=max_new)
                         for r in reqs])
    for r in out:
        ref = _greedy_sequential(cfg, next(q.prompt for q in reqs
                                           if q.rid == r.rid), max_new)
        assert r.out == ref, (r.rid, r.out, ref)


# ---------------------------------------------------------------------------
# bucketed-prefill edge cases
# ---------------------------------------------------------------------------


def test_bucket_boundary_prompt():
    """Prompt lengths exactly at a power-of-two bucket boundary (8, 16): the
    bucket equals the length, no pad positions at all."""
    cfg = DENSE
    for length in (8, 16):
        reqs = _requests(cfg, [length], 6, seed=10 + length)
        engine = Engine(cfg, jax_params(cfg), batch_slots=2, cache_len=32,
                        max_chunk=4)
        out = serve(engine, [Request(rid=0, prompt=reqs[0].prompt, max_new=6)])
        ref = _greedy_sequential(cfg, reqs[0].prompt, 6)
        assert out[0].out == ref, (length, out[0].out, ref)


def test_length_one_prompt():
    """A single-token prompt rides the MIN_BUCKET prefill (7 pad positions)."""
    cfg = DENSE
    reqs = _requests(cfg, [1, 9], 6, seed=11)
    engine = Engine(cfg, jax_params(cfg), batch_slots=2, cache_len=24,
                    max_chunk=4)
    out = serve(engine, [Request(rid=r.rid, prompt=r.prompt, max_new=6)
                         for r in reqs])
    for r in out:
        ref = _greedy_sequential(cfg, next(q.prompt for q in reqs
                                           if q.rid == r.rid), 6)
        assert r.out == ref, (r.rid, r.out, ref)


def test_same_bucket_admissions_batch_into_one_prefill():
    """Four same-bucket requests pending at once: ONE (4, bucket) prefill
    call admits them all (PR-2 paid one dispatch per request)."""
    cfg = DENSE
    lens = [9, 12, 10, 16]  # all bucket 16
    reqs = _requests(cfg, lens, 5, seed=12)
    engine = Engine(cfg, jax_params(cfg), batch_slots=4, cache_len=32,
                    max_chunk=4)
    out = serve(engine, [Request(rid=r.rid, prompt=r.prompt, max_new=5)
                         for r in reqs])
    assert engine.prefill_calls == 1
    assert engine.prefill_rows == 4
    for r in out:
        ref = _greedy_sequential(cfg, next(q.prompt for q in reqs
                                           if q.rid == r.rid), 5)
        assert r.out == ref, (r.rid, r.out, ref)


def test_multi_bucket_admission_in_one_tick():
    """Pending requests from different buckets admitted in the same tick:
    one prefill call per bucket group, all before the first decode chunk."""
    cfg = DENSE
    lens = [5, 7, 12, 14]  # buckets 8, 8, 16, 16
    reqs = _requests(cfg, lens, 5, seed=13)
    engine = Engine(cfg, jax_params(cfg), batch_slots=4, cache_len=32,
                    max_chunk=4)
    pending = [Request(rid=r.rid, prompt=r.prompt, max_new=5) for r in reqs]
    admitted = engine.admit_pending(pending)
    assert len(admitted) == 4 and not pending
    assert engine.prefill_calls == 2  # one per bucket, not one per request
    assert engine.prefill_rows == 4
    out = serve(engine, [])
    for r in out:
        ref = _greedy_sequential(cfg, next(q.prompt for q in reqs
                                           if q.rid == r.rid), 5)
        assert r.out == ref, (r.rid, r.out, ref)


# ---------------------------------------------------------------------------
# allocator / pool behaviour inside the engine
# ---------------------------------------------------------------------------


def test_tight_pool_defers_admission_and_reuses_blocks():
    """A physical pool sized for ~one long request at a time: admission
    stalls until blocks free, then reuses them; outputs stay exact."""
    cfg = DENSE
    lens = [20, 20, 20]
    max_new = 4
    reqs = _requests(cfg, lens, max_new, seed=14)
    # each request needs ceil((20 + 3) / 8) = 3 blocks; pool holds 4 usable
    engine = Engine(cfg, jax_params(cfg), batch_slots=3, cache_len=32,
                    max_chunk=4, kv_blocks=5)
    out = serve(engine, [Request(rid=r.rid, prompt=r.prompt, max_new=max_new)
                         for r in reqs])
    assert len(out) == 3
    assert engine.alloc.used_count == 0
    assert engine.alloc.free_count == 4
    for r in out:
        ref = _greedy_sequential(cfg, next(q.prompt for q in reqs
                                           if q.rid == r.rid), max_new)
        assert r.out == ref, (r.rid, r.out, ref)


def test_oversized_request_fails_gracefully():
    """An oversized request retires with a per-request error status (never a
    hard raise): the engine and every other request keep serving."""
    cfg = DENSE
    engine = Engine(cfg, jax_params(cfg), batch_slots=2, cache_len=16,
                    max_chunk=4)
    big = Request(rid=0, prompt=np.zeros(14, np.int64), max_new=8)
    assert engine.admit_pending([big]) == []
    assert big.done and big.error is not None and "cache_len" in big.error
    assert engine.finished == [big]
    assert engine.failed_requests == 1
    # an idle engine that can never admit must not spin forever: the stuck
    # head retires with an error and serving continues for the rest
    small_pool = Engine(cfg, jax_params(cfg), batch_slots=2, cache_len=32,
                        max_chunk=4, kv_blocks=3)
    stuck = Request(rid=1, prompt=np.zeros(20, np.int64), max_new=4)
    rnp = np.random.default_rng(21)
    fine = Request(rid=2, prompt=rnp.integers(0, cfg.vocab_size, 6),
                   max_new=4)
    out = serve(small_pool, [stuck, fine])
    assert stuck in out and stuck.error is not None
    assert fine in out and fine.error is None
    assert fine.out == _greedy_sequential(cfg, fine.prompt, 4)
    assert small_pool.alloc.used_count == 0


def test_oversized_group_member_does_not_leak_blocks():
    """An oversized request BEHIND a valid same-bucket head must not join the
    group (it would blow past max_blocks mid-insert): the head admits
    cleanly, the oversized one retires with an error status only once it
    reaches the head, and no blocks leak along the way."""
    cfg = DENSE
    engine = Engine(cfg, jax_params(cfg), batch_slots=2, cache_len=16,
                    max_chunk=4)
    rnp = np.random.default_rng(16)
    ok = Request(rid=0, prompt=rnp.integers(0, cfg.vocab_size, 6), max_new=4)
    big = Request(rid=1, prompt=rnp.integers(0, cfg.vocab_size, 6),
                  max_new=64)  # same bucket (8), needs blocks > max_blocks
    pending = [ok, big]
    # the head admits cleanly; the oversized request then reaches the head
    # within the same call and retires with an error - AFTER the group
    # insert, never mid-insert, so engine state stays consistent
    admitted = engine.admit_pending(pending)
    assert admitted == [ok]
    assert pending == []  # big dequeued with an error status, not stuck
    assert big.done and big.error is not None
    assert engine.active == 1
    assert engine.alloc.used_count == engine._blocks_needed(ok)
    out = serve(engine, [])
    assert out[-1].out == _greedy_sequential(cfg, ok.prompt, 4)
    assert engine.alloc.used_count == 0  # nothing leaked


def test_kv_bytes_track_allocation():
    """kv_bytes_in_use rises with admission and falls back on retirement -
    the utilization signal the serve bench reports per active token."""
    cfg = DENSE
    engine = Engine(cfg, jax_params(cfg), batch_slots=2, cache_len=32,
                    max_chunk=4)
    idle = engine.kv_bytes_in_use()
    reqs = _requests(cfg, [9], 4, seed=15)
    pending = [Request(rid=0, prompt=reqs[0].prompt, max_new=4)]
    engine.admit_pending(pending)
    admitted_bytes = engine.kv_bytes_in_use()
    assert admitted_bytes > idle
    serve(engine, [])
    assert engine.kv_bytes_in_use() == idle


def test_allocator_basics():
    a = BlockAllocator(8)
    assert a.free_count == 7  # block 0 reserved
    got = a.alloc(7)
    assert sorted(got) == list(range(1, 8))
    assert a.alloc(1) is None
    a.free(got[:3])
    again = a.alloc(3)
    assert sorted(again) == sorted(got[:3])
    with pytest.raises(ValueError):
        a.free([0])


def test_exact_prefill_recurrent_still_served():
    """Recurrent patterns (no global-attn layers -> nothing to page) keep
    exact-length prefill and still admit unequal lengths in one batch."""
    cfg = configs.get_smoke("mamba2-2.7b")
    max_new = 4
    reqs = _requests(cfg, [5, 11], max_new, seed=6)
    engine = Engine(cfg, jax_params(cfg), batch_slots=2, cache_len=32,
                    max_chunk=4)
    out = serve(engine, [Request(rid=r.rid, prompt=r.prompt, max_new=max_new)
                         for r in reqs])
    for r in out:
        ref = _greedy_sequential(cfg, next(q.prompt for q in reqs
                                           if q.rid == r.rid), max_new)
        assert r.out == ref, (r.rid, r.out, ref)


# ---------------------------------------------------------------------------
# preemptive paged KV: lazy growth, recompute-preemption, utilization
# ---------------------------------------------------------------------------


def _frozen_smoke(substrate):
    """Frozen-calibration smoke config (batch-invariant in the IMC modes:
    the precondition for bit-exact recompute-preemption)."""
    base = configs.get_smoke("musicgen-medium")
    if substrate == "digital":
        return base
    cfg_dyn = _with_substrate(base, substrate)
    params = jax_params(cfg_dyn)
    ref_batch = np.random.default_rng(1).integers(0, base.vocab_size, (2, 24))
    cfg = calibrate_model(cfg_dyn, params, [ref_batch])
    _PARAMS[id(cfg)] = params
    assert as_substrate(cfg.imc).policy == "frozen"
    return cfg


@pytest.mark.parametrize("substrate", SUBSTRATES)
def test_recompute_preemption_bit_exact(substrate):
    """THE preemption acceptance contract: a pool too small for both
    residents' generation tails forces mid-decode lazy growth to fail, the
    victim is recompute-preempted (blocks freed, re-queued with
    prompt+generated-so-far), and every request still finishes with tokens
    BIT-IDENTICAL to an uninterrupted ample-pool run - on all three
    substrates (IMC modes frozen: batched == sequential, so the resume
    prefill replays exactly the decode state it abandoned)."""
    cfg = _frozen_smoke(substrate)
    max_new = 5  # total positions 5+5-1=9 -> worst case 2 blocks/request
    lens = [5, 5]

    def _run(kv_blocks):
        eng = Engine(cfg, jax_params(cfg), batch_slots=2, cache_len=32,
                     max_chunk=4, kv_blocks=kv_blocks)
        done = serve(eng, [Request(rid=r.rid, prompt=r.prompt,
                                   max_new=max_new)
                           for r in _requests(cfg, lens, max_new)])
        return eng, {r.rid: r for r in done}

    ample_eng, ample = _run(kv_blocks=16)
    assert ample_eng.preempt_count == 0
    # 3 usable blocks < 2 residents x 2 worst-case: growth must preempt
    tight_eng, tight = _run(kv_blocks=4)
    assert tight_eng.preempt_count >= 1
    for rid in ample:
        assert tight[rid].error is None
        assert tight[rid].out == ample[rid].out, (
            substrate, rid, tight[rid].out, ample[rid].out)
    assert sum(r.preemptions for r in tight.values()) \
        == tight_eng.preempt_count
    assert tight_eng.alloc.used_count == 0  # nothing leaked across preempts


def test_lazy_allocation_raises_pool_utilization():
    """The lazy-allocation payoff: on an early-stopping mix (stop_at well
    under the max_new cap) worst-case reservation parks blocks that are
    never written; lazy allocation holds only prompt coverage + crossed
    boundaries, so measured pool utilization (live tokens / held capacity)
    is strictly higher - with bit-identical outputs."""
    cfg = DENSE

    def _run(alloc_policy):
        eng = Engine(cfg, jax_params(cfg), batch_slots=4, cache_len=64,
                     max_chunk=4, kv_blocks=13, alloc_policy=alloc_policy)
        reqs = [Request(rid=r.rid, prompt=r.prompt, max_new=16, stop_at=3)
                for r in _requests(cfg, [5, 6, 5, 7], 16, seed=11)]
        done = serve(eng, reqs)
        assert all(r.error is None and len(r.out) == 3 for r in done)
        return eng, {r.rid: r.out for r in done}

    lazy_eng, lazy_out = _run("lazy")
    res_eng, res_out = _run("reserve")
    assert lazy_out == res_out
    assert lazy_eng.preempt_count == res_eng.preempt_count == 0
    lazy_util, res_util = lazy_eng.pool_utilization(), \
        res_eng.pool_utilization()
    assert lazy_util > res_util, (lazy_util, res_util)
    assert lazy_eng.alloc.used_count == res_eng.alloc.used_count == 0


def test_reserve_policy_still_supported():
    """--alloc reserve keeps the PR-3 worst-case admission contract: blocks
    for the whole generation tail are held from admission, so lazy growth
    (and preemption) never triggers."""
    cfg = DENSE
    eng = Engine(cfg, jax_params(cfg), batch_slots=2, cache_len=32,
                 max_chunk=4, alloc_policy="reserve")
    reqs = _requests(cfg, [5], 6, seed=12)
    pending = [Request(rid=0, prompt=reqs[0].prompt, max_new=6)]
    eng.admit_pending(pending)
    # worst case held from admission: ceil((5 + 6 - 1) / 8) = 2 blocks
    assert eng.alloc.used_count == 2
    serve(eng, [])
    assert eng.preempt_count == 0
    with pytest.raises(ValueError, match="alloc_policy"):
        Engine(cfg, jax_params(cfg), batch_slots=2, cache_len=32,
               alloc_policy="eager")
