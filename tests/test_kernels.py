"""Pallas kernel validation: interpret-mode vs the pure-jnp ref oracle and
shape sweeps (deliverable (c)).  Hypothesis property sweeps live in
test_properties.py (skipped wholesale when hypothesis is not installed)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.archs import QSArch
from repro.kernels import imc_mvm, ops, ref
from repro.kernels.ref import AnalyticSpec, BitSerialSpec, quantize_codes

KEY = jax.random.PRNGKey(7)


def _codes(key, b, k, m, bx, bw, x_signed):
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (b, k))
    if not x_signed:
        x = jnp.abs(x)
    w = jax.random.normal(k2, (k, m))
    xc, _ = quantize_codes(x, bx, x_signed, jnp.max(jnp.abs(x)))
    wc, _ = quantize_codes(w, bw, True, jnp.max(jnp.abs(w)))
    return xc, wc


SHAPES = [
    # (B, K, M, rows, bx, bw, x_signed)
    (4, 512, 16, 512, 6, 6, False),
    (130, 700, 257, 512, 4, 5, True),
    (1, 128, 128, 128, 8, 8, True),
    (64, 1536, 320, 512, 6, 6, True),
    (16, 256, 64, 64, 2, 3, False),
]


@pytest.mark.parametrize("shape", SHAPES)
def test_bitserial_kernel_matches_ref_no_noise(shape):
    b, k, m, rows, bx, bw, xs = shape
    xc, wc = _codes(jax.random.fold_in(KEY, hash(shape) % 2**30), b, k, m, bx, bw, xs)
    spec = BitSerialSpec(bx=bx, bw=bw, b_adc=8, rows=rows, k_h=60.0, v_c=55.0,
                         x_signed=xs)
    yk = imc_mvm.imc_bitserial_matmul(xc, wc, None, spec, interpret=True)
    yr = ref.imc_bitserial_ref(xc, wc, None, spec)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), rtol=1e-6, atol=1e-3)


@pytest.mark.parametrize("shape", SHAPES[:3])
def test_bitserial_kernel_matches_ref_inkernel_noise_no_adc(shape):
    """Interpret-mode fallback PRNG: kernel and oracle generate the SAME
    per-plane noise from the same seed (global-index counters).  Pre-ADC the
    outputs agree to float tolerance (last-ulp FMA-contraction differences
    between the two XLA graphs are possible, nothing larger)."""
    b, k, m, rows, bx, bw, xs = shape
    key = jax.random.fold_in(KEY, 1 + hash(shape) % 2**30)
    xc, wc = _codes(key, b, k, m, bx, bw, xs)
    spec = BitSerialSpec(bx=bx, bw=bw, b_adc=8, rows=rows, k_h=60.0, v_c=55.0,
                         x_signed=xs, apply_adc=False, sigma_noise=0.3)
    yk = imc_mvm.imc_bitserial_matmul(xc, wc, None, spec, seed=4242,
                                      interpret=True)
    yr = ref.imc_bitserial_ref(xc, wc, None, spec, seed=4242)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), rtol=1e-5,
                               atol=1e-2)


@pytest.mark.parametrize("shape", SHAPES[:3])
def test_bitserial_kernel_matches_ref_inkernel_noise_adc(shape):
    """With the ADC on, a last-ulp difference can flip one code on rounding
    knife edges - require identity away from those (< 0.1% of elements)."""
    b, k, m, rows, bx, bw, xs = shape
    key = jax.random.fold_in(KEY, 1 + hash(shape) % 2**30)
    xc, wc = _codes(key, b, k, m, bx, bw, xs)
    spec = BitSerialSpec(bx=bx, bw=bw, b_adc=8, rows=rows, k_h=60.0, v_c=55.0,
                         x_signed=xs, sigma_noise=0.3)
    yk = imc_mvm.imc_bitserial_matmul(xc, wc, None, spec, seed=4242,
                                      interpret=True)
    yr = ref.imc_bitserial_ref(xc, wc, None, spec, seed=4242)
    frac = float(jnp.mean(jnp.abs(yk - yr) > 0))
    assert frac < 1e-3, frac


@pytest.mark.parametrize("shape", SHAPES[:3])
def test_bitserial_kernel_matches_ref_gain_noise_no_adc(shape):
    """With gain + in-kernel noise but no ADC the kernel is allclose to the
    ref (real-valued gain makes plane DPs order-sensitive in f32; the ADC's
    round() knife edges are tested separately)."""
    b, k, m, rows, bx, bw, xs = shape
    key = jax.random.fold_in(KEY, 1 + hash(shape) % 2**30)
    xc, wc = _codes(key, b, k, m, bx, bw, xs)
    k1, _ = jax.random.split(key)
    gain = 1.0 + 0.1 * jax.random.normal(k1, (k, m))
    spec = BitSerialSpec(bx=bx, bw=bw, b_adc=8, rows=rows, k_h=60.0, v_c=55.0,
                         x_signed=xs, apply_adc=False, sigma_noise=0.3)
    yk = imc_mvm.imc_bitserial_matmul(xc, wc, gain, spec, seed=7, interpret=True)
    yr = ref.imc_bitserial_ref(xc, wc, gain, spec, seed=7)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), rtol=1e-4, atol=0.5)


def test_bitserial_kernel_adc_boundary_flips_rare():
    """With ADC + real-valued gains, kernel and ref may disagree by one ADC
    step on rounding knife edges - require < 0.5% of elements."""
    b, k, m, rows, bx, bw = 64, 700, 257, 256, 6, 7
    key = jax.random.fold_in(KEY, 99)
    xc, wc = _codes(key, b, k, m, bx, bw, True)
    k1, _ = jax.random.split(key)
    gain = 1.0 + 0.1 * jax.random.normal(k1, (k, m))
    spec = BitSerialSpec(bx=bx, bw=bw, b_adc=7, rows=rows, k_h=70.0, v_c=70.0,
                         x_signed=True)
    yk = imc_mvm.imc_bitserial_matmul(xc, wc, gain, spec, interpret=True)
    yr = ref.imc_bitserial_ref(xc, wc, gain, spec)
    frac = float(jnp.mean(jnp.abs(yk - yr) > 1.0))
    assert frac < 0.005, frac


@pytest.mark.parametrize("shape", SHAPES[:4])
def test_bitserial_wide_open_equals_exact_matmul(shape):
    """Property: no noise, no clipping, no ADC -> exact integer matmul."""
    b, k, m, rows, bx, bw, xs = shape
    xc, wc = _codes(jax.random.fold_in(KEY, 2), b, k, m, bx, bw, xs)
    spec = BitSerialSpec(bx=bx, bw=bw, b_adc=16, rows=rows, k_h=1e9, v_c=1e9,
                         x_signed=xs, apply_adc=False)
    yk = imc_mvm.imc_bitserial_matmul(xc, wc, None, spec, interpret=True)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(xc @ wc), rtol=1e-6)


def test_more_adc_bits_less_error():
    b, k, m = 32, 512, 64
    xc, wc = _codes(jax.random.fold_in(KEY, 3), b, k, m, 6, 6, True)
    exact = np.asarray(xc @ wc)
    errs = []
    for b_adc in (4, 6, 8, 10):
        spec = BitSerialSpec(bx=6, bw=6, b_adc=b_adc, rows=512, k_h=1e9,
                             v_c=140.0, x_signed=True)
        y = np.asarray(ref.imc_bitserial_ref(xc, wc, None, spec))
        errs.append(np.sqrt(np.mean((y - exact) ** 2)))
    assert errs[0] > errs[1] > errs[2] > errs[3]


@pytest.mark.parametrize("shape", [(8, 1024, 64), (130, 700, 257), (1, 64, 1)])
def test_analytic_kernel_matches_ref(shape):
    """In-kernel epilogue noise from the same seed -> bit-exact vs oracle."""
    b, k, m = shape
    key = jax.random.fold_in(KEY, 4)
    k1, k2 = jax.random.split(key)
    xc = jnp.round(jax.random.normal(k1, (b, k)) * 10)
    wc = jnp.round(jax.random.normal(k2, (k, m)) * 10)
    sig = float(jnp.std(xc @ wc)) + 1e-6
    spec = AnalyticSpec(b_adc=8, sigma_out=0.05, y_clip=4.0)
    yk = imc_mvm.imc_analytic_matmul(xc / sig, wc, spec, seed=99, interpret=True)
    yr = ref.imc_analytic_ref(xc / sig, wc, spec, seed=99)
    # K-padding changes f32 accumulation order -> the ADC round() can flip by
    # one step on knife edges; require exactness elsewhere
    d = np.abs(np.asarray(yk) - np.asarray(yr))
    adc_step = 2 * spec.y_clip / 2**spec.b_adc
    assert d.max() <= adc_step + 1e-6
    assert (d > 1e-6).mean() < 1e-3


def test_ops_end_to_end_snr_tracks_analytics():
    """imc_matmul with a QSArch-derived config achieves ~the analytic SNR."""
    arch = QSArch(n=256, bx=7, bw=7, v_wl=0.7)
    cfg = ops.derive_config_from_arch(arch, x_signed=False, use_kernel=True)
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(5), 3)
    x = jnp.abs(jax.random.normal(k1, (64, 256)))
    w = jax.random.uniform(k2, (256, 64), minval=-1, maxval=1)
    y = ops.imc_matmul(x, w, cfg, key=k3)
    y0 = x @ w
    err = y - y0
    snr = 10 * np.log10(float(jnp.var(y0)) /
                        float(jnp.mean((err - jnp.mean(err)) ** 2)))
    # ADC per Table III B_ADC; uniform operands -> close to analytic SNR_A
    assert snr > arch.snr_A_db() - 3.0, (snr, arch.snr_A_db())


def test_kernel_dtype_sweep():
    """Codes arrive as f32 but must accept f32/bf16 inputs to the wrapper."""
    b, k, m = 8, 256, 32
    for dtype in (jnp.float32, jnp.bfloat16):
        k1, k2 = jax.random.split(jax.random.fold_in(KEY, 6))
        x = jax.random.normal(k1, (b, k), dtype=dtype)
        w = jax.random.normal(k2, (k, m), dtype=dtype)
        cfg = ops.IMCMatmulConfig(mode="fakequant", bx=6, bw=6)
        y = ops.imc_matmul(x, w, cfg)
        assert y.shape == (b, m)
        assert bool(jnp.all(jnp.isfinite(y)))
